//! Quickstart: program a SiTe CiM I array, run a signed-ternary dot
//! product, and look at the electrical metrics — the 60-second tour of
//! the public API.
//!
//! Run: cargo run --release --example quickstart

use sitecim::array::metrics::{all_designs, ArrayGeom};
use sitecim::array::{CimArray, SiTeCim1Array};
use sitecim::device::{PeriphParams, Tech, TechParams};
use sitecim::util::rng::Rng;
use sitecim::util::units::{fmt_energy, fmt_time};

fn main() {
    // 1. A 256x256 signed-ternary CiM array (FEMFET flavor).
    let mut array = SiTeCim1Array::new(Tech::Femfet3T);

    // 2. Program ternary weights (W ∈ {-1, 0, +1}; ~50% zeros, like a
    //    TWN-quantized DNN layer).
    let mut rng = Rng::new(7);
    let weights = rng.ternary_vec(256 * 256, 0.5);
    array.write_matrix(&weights);

    // 3. One signed-ternary matrix-vector product: 16 rows assert per
    //    cycle, two 3-bit ADCs per column, outputs saturate at ±8/cycle.
    let inputs = rng.ternary_vec(256, 0.5);
    let outputs = array.dot(&inputs);
    println!("dot product of 256-long ternary input against 256 columns:");
    println!("  first 8 outputs: {:?}", &outputs[..8]);

    // 4. What does a MAC window cost, and how does it compare to the
    //    near-memory baseline?
    let p = TechParams::new(Tech::Femfet3T);
    let pp = PeriphParams::default_45nm();
    let [nm, cim1, _] = all_designs(&p, &pp, ArrayGeom::default());
    println!("\nper-window (16 rows x 256 columns) on 3T-FEMFET:");
    println!(
        "  SiTe CiM I : {} / {}",
        fmt_time(cim1.mac.latency),
        fmt_energy(cim1.mac.energy)
    );
    println!(
        "  NM baseline: {} / {}",
        fmt_time(nm.mac.latency),
        fmt_energy(nm.mac.energy)
    );
    println!(
        "  => {:.1}x faster, {:.1}x less energy",
        nm.mac.latency / cim1.mac.latency,
        nm.mac.energy / cim1.mac.energy
    );
}
