//! Serving demo: starts the coordinator (dynamic batcher + PJRT workers)
//! over the AOT-compiled ternary MLP and pushes a closed-loop synthetic
//! workload, reporting wall-clock latency/throughput and the simulated
//! SiTe CiM hardware cost of the same traffic.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example serve_demo

use std::time::Instant;

use sitecim::coordinator::{Server, ServerConfig};
use sitecim::runtime::{default_dir, Manifest};

fn main() -> anyhow::Result<()> {
    let dir = default_dir();
    let manifest = Manifest::load(&dir)?;
    let (x, y) = manifest.load_test_set()?;

    let mut cfg = ServerConfig::new(dir);
    cfg.n_workers = 2;
    let server = Server::start(cfg)?;

    // Open-loop burst: 1024 requests.
    let n = 1024;
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let s = i % manifest.test_n;
        let input = x[s * manifest.in_dim..(s + 1) * manifest.in_dim].to_vec();
        pending.push((s, server.infer_async(input).map_err(anyhow::Error::msg)?));
    }
    let mut correct = 0;
    for (s, rx) in pending {
        let r = rx.recv()?.map_err(anyhow::Error::msg)?;
        correct += usize::from(r.pred == y[s] as usize);
    }
    let dt = t0.elapsed().as_secs_f64();

    println!("burst of {n} requests: {:.0} req/s, accuracy {:.2}%",
        n as f64 / dt, 100.0 * correct as f64 / n as f64);
    println!("{}", server.metrics.report());
    println!("(simulated figures = what the FEMFET SiTe CiM I accelerator would spend)");
    server.shutdown();
    Ok(())
}
