//! System-level tour: builds the TiM-DNN-style accelerator with SiTe CiM
//! I/II arrays, runs the paper's five benchmarks against both NM
//! baselines (Figs 12/13), and prints one full per-layer breakdown.
//!
//! Run: cargo run --release --example accelerator_tour

use sitecim::arch::{AccelConfig, Accelerator};
use sitecim::array::area::Design;
use sitecim::device::Tech;
use sitecim::dnn::benchmarks;
use sitecim::repro;
use sitecim::util::units::{fmt_energy, fmt_time};

fn main() {
    print!("{}", repro::fig12());
    print!("{}", repro::fig13());

    // Breakdown of one run: AlexNet on FEMFET SiTe CiM I.
    let accel = Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, Design::Cim1));
    let r = accel.run(&benchmarks::alexnet());
    println!("\nAlexNet on 3T-FEMFET SiTe CiM I (32 arrays):");
    println!("  latency : {} (compute {}, weight-streaming {})",
        fmt_time(r.latency), fmt_time(r.compute_latency), fmt_time(r.write_latency));
    println!("  energy  : {} (compute {}, writes {}, periphery {})",
        fmt_energy(r.energy), fmt_energy(r.compute_energy),
        fmt_energy(r.write_energy), fmt_energy(r.periph_energy));
    println!("  work    : {} MAC windows, {} weight-row writes",
        r.total_windows, r.total_write_rows);
}
