//! Array-level analysis: regenerates the circuit/array figures of the
//! paper (Fig 4(c), Fig 7(c), Fig 9, Fig 11, the area table and the
//! CiM I vs II comparison) — same output as `sitecim figures`.
//!
//! Run: cargo run --release --example array_analysis

use sitecim::repro;

fn main() {
    print!("{}", repro::fig4());
    print!("{}", repro::fig7());
    print!("{}", repro::area_table());
    print!("{}", repro::fig9());
    print!("{}", repro::fig11());
    print!("{}", repro::cim1_vs_cim2());
    print!("{}", repro::error_prob());
}
