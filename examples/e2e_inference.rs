//! END-TO-END validation driver (EXPERIMENTS.md §E2E): proves all three
//! layers compose on a real (small) workload.
//!
//! Pipeline: `make artifacts` trained a ternary MLP in JAX (STE, synthetic
//! 8×8-digit corpus) and lowered its CiM-I/CiM-II/exact inference graphs —
//! Pallas kernel inlined — to HLO text. This driver, pure rust:
//!
//! 1. loads the artifacts and runs the PJRT executables on the held-out
//!    test set (accuracy for exact vs CiM I vs CiM II semantics);
//! 2. runs the SAME network through the bit-level functional array
//!    simulator (weights programmed into simulated SiTe CiM I arrays) and
//!    cross-checks predictions against the HLO path;
//! 3. injects V_TH-variation sensing noise (Monte Carlo) and measures the
//!    accuracy impact (paper: negligible at P(err) ≈ 3e-3);
//! 4. reports the simulated accelerator throughput/energy vs the NM
//!    baseline for this workload (the paper's headline claims).
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_inference

use std::time::Instant;

use sitecim::arch::{AccelConfig, Accelerator};
use sitecim::array::variation::SIGMA_VTH_SENSE_V;
use sitecim::array::{CimArray, SiTeCim1Array, TernaryStorage};
use sitecim::coordinator::server::manifest_network;
use sitecim::device::Tech;
use sitecim::array::area::Design;
use sitecim::runtime::{cpu_client, default_dir, Manifest, MlpExecutor, ModelKind};
use sitecim::util::rng::Rng;
use sitecim::util::units::{fmt_energy, fmt_time, fmt_x};

/// Functional-array forward pass of the artifact MLP on SiTe CiM I
/// simulated arrays, with optional sensing-noise Monte Carlo.
fn array_forward(
    manifest: &Manifest,
    arrays: &[SiTeCim1Array],
    thresholds: &[f64],
    input: &[i8],
    sigma_v: f64,
    rng: &mut Rng,
) -> usize {
    let mut h: Vec<i8> = input.to_vec();
    for (li, arr) in arrays.iter().enumerate() {
        // Pad the activation vector to the array's rows.
        let mut padded = vec![0i8; arr.n_rows()];
        padded[..h.len()].copy_from_slice(&h);
        let out = if sigma_v > 0.0 {
            arr.dot_analog_mc(&padded, sigma_v, rng)
        } else {
            arr.dot(&padded)
        };
        if li + 1 < arrays.len() {
            h = sitecim::dnn::ternary::ternarize_acts_i32(&out, thresholds[li]);
        } else {
            // Final layer: argmax.
            return out
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
    }
    unreachable!()
}

fn main() -> anyhow::Result<()> {
    let dir = default_dir();
    let manifest = Manifest::load(&dir)?;
    let (x, y) = manifest.load_test_set()?;
    let n = manifest.test_n;
    println!("== E2E: ternary MLP ({:?}) on {} held-out samples ==", manifest.dims, n);
    println!("AOT-recorded accuracies: {:?}\n", manifest.aot_accuracy);

    // ---- 1. HLO/PJRT path: all three semantics ----
    let client = cpu_client()?;
    let mut hlo_preds = std::collections::BTreeMap::new();
    for kind in [ModelKind::Exact, ModelKind::Cim1, ModelKind::Cim2] {
        let exe = MlpExecutor::load(&client, &manifest, kind)?;
        let t0 = Instant::now();
        let mut preds = Vec::with_capacity(n);
        for base in (0..n).step_by(exe.batch) {
            let nb = exe.batch.min(n - base);
            preds.extend(exe.classify(&x[base * manifest.in_dim..(base + nb) * manifest.in_dim], nb)?);
        }
        let dt = t0.elapsed().as_secs_f64();
        let acc = preds.iter().zip(&y).filter(|(p, &l)| **p == l as usize).count() as f64 / n as f64;
        println!(
            "HLO {kind:?}: accuracy {:.2}%  ({:.0} inf/s on PJRT CPU)",
            acc * 100.0,
            n as f64 / dt
        );
        hlo_preds.insert(format!("{kind:?}"), preds);
    }

    // ---- 2. Functional array simulator cross-check (CiM I) ----
    let mut arrays = Vec::new();
    for i in 0..manifest.weights.len() {
        let (w, (k, ncols)) = manifest.load_weight(i)?;
        let rows = k.div_ceil(16) * 16;
        let mut arr = SiTeCim1Array::with_dims(Tech::Femfet3T, rows.max(16), ncols);
        // Row-major (k × n) into the array; padding rows stay 0.
        let mut storage_w = vec![0i8; arr.n_rows() * ncols];
        storage_w[..k * ncols].copy_from_slice(&w);
        arr.write_matrix(&storage_w);
        let _ = TernaryStorage::new(16, 16); // (re-exported type sanity)
        arrays.push(arr);
    }
    let thresholds = manifest.act_thresholds.clone();
    let mut rng = Rng::new(99);
    let t0 = Instant::now();
    let sim_preds: Vec<usize> = (0..n)
        .map(|s| {
            array_forward(
                &manifest,
                &arrays,
                &thresholds,
                &x[s * manifest.in_dim..(s + 1) * manifest.in_dim],
                0.0,
                &mut rng,
            )
        })
        .collect();
    let dt_sim = t0.elapsed().as_secs_f64();
    let acc_sim =
        sim_preds.iter().zip(&y).filter(|(p, &l)| **p == l as usize).count() as f64 / n as f64;
    let agree = sim_preds
        .iter()
        .zip(&hlo_preds["Cim1"])
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "\nfunctional array sim (CiM I): accuracy {:.2}%  ({:.0} inf/s), {}/{} predictions agree with the HLO path",
        acc_sim * 100.0,
        n as f64 / dt_sim,
        agree,
        n
    );
    assert!(agree as f64 / n as f64 > 0.98, "array sim diverged from HLO path");

    // ---- 3. Sensing-noise Monte Carlo ----
    let noisy_preds: Vec<usize> = (0..n)
        .map(|s| {
            array_forward(
                &manifest,
                &arrays,
                &thresholds,
                &x[s * manifest.in_dim..(s + 1) * manifest.in_dim],
                SIGMA_VTH_SENSE_V,
                &mut rng,
            )
        })
        .collect();
    let acc_noisy =
        noisy_preds.iter().zip(&y).filter(|(p, &l)| **p == l as usize).count() as f64 / n as f64;
    println!(
        "with V_TH-variation sensing noise (σ={} mV): accuracy {:.2}% (Δ {:+.2} pp — paper: negligible)",
        SIGMA_VTH_SENSE_V * 1e3,
        acc_noisy * 100.0,
        (acc_noisy - acc_sim) * 100.0
    );

    // ---- 4. Simulated hardware cost for this workload ----
    let net = manifest_network(&manifest);
    println!("\nsimulated accelerator cost per inference (this MLP):");
    for tech in Tech::ALL {
        let cim = Accelerator::new(AccelConfig::sitecim(tech, Design::Cim1)).run(&net);
        let nm = Accelerator::new(AccelConfig::iso_capacity_nm(tech)).run(&net);
        println!(
            "  {:<10} CiM I: {} / {}   vs NM: {} faster, {} less energy",
            tech.name(),
            fmt_time(cim.latency),
            fmt_energy(cim.energy),
            fmt_x(cim.speedup_vs(&nm)),
            fmt_x(cim.energy_reduction_vs(&nm)),
        );
    }
    println!("\nE2E OK");
    Ok(())
}
