//! Executor stress battery: many concurrent GEMM submissions from many
//! caller threads must pipeline through one persistent worker pool and
//! stay bit-exact per submission, under capacity pressure (evictions
//! mid-flight), with streaming calls interleaved (slot invalidation
//! mid-flight), the queues must drain so engine drop (executor
//! shutdown) never hangs, and the load-aware affinity policy must
//! redistribute a skewed working set (hot arrays owning most shards)
//! without losing bit-exactness.

use std::sync::atomic::{AtomicU64, Ordering};

use sitecim::array::Design;
use sitecim::device::Tech;
use sitecim::engine::tiling::{reference_gemm, reference_gemm_sharded};
use sitecim::engine::{AffinityMode, EngineConfig, TernaryGemmEngine};
use sitecim::util::rng::Rng;

#[test]
fn concurrent_resident_submissions_stay_bit_exact_and_drain() {
    for design in Design::ALL {
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(design, Tech::Femfet3T)
                .with_array_dims(64, 32)
                // 3 arrays << the combined working set: placements are
                // evicted and re-programmed concurrently throughout.
                .with_capacity_words(3 * 64 * 32)
                .with_threads(3),
        );
        let mut rng = Rng::new(700);
        // 6 weights × (cold + repeated warm) passes from 6 caller
        // threads at once.
        let mut cases = Vec::new();
        for i in 0..6 {
            let (m, k, n) = (1 + i % 3, 100 + 30 * i, 40 + 10 * (i % 2));
            let x = rng.ternary_vec(m * k, 0.5);
            let w = rng.ternary_vec(k * n, 0.5);
            let want = reference_gemm(&x, &w, m, &engine.grid(k, n), design.flavor());
            let id = engine.register_weight(&w, k, n).unwrap();
            cases.push((id, x, m, want));
        }
        let completed = AtomicU64::new(0);
        let (engref, doneref) = (&engine, &completed);
        std::thread::scope(|s| {
            for (id, x, m, want) in &cases {
                s.spawn(move || {
                    for pass in 0..4 {
                        let got = engref.gemm_resident(*id, x, *m).unwrap();
                        assert_eq!(&got, want, "{design:?} pass {pass}");
                        doneref.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(completed.load(Ordering::Relaxed), 24);
        let s = engine.exec_stats();
        assert_eq!(s.submitted, s.executed, "{design:?}: queues drained");
        assert_eq!(s.panics, 0, "{design:?}");
        let es = engine.stats();
        assert_eq!(es.gemms, 24, "{design:?}");
        assert!(es.evictions > 0, "{design:?}: pressure was real");
        // Dropping the engine shuts the workers down; reaching the next
        // loop iteration proves shutdown does not hang.
    }
}

#[test]
fn streaming_and_resident_interleave_concurrently_bit_exact() {
    // Streaming callers trash pool arrays (invalidating placements)
    // while resident callers serve from them; the content tags must keep
    // every result exact under true concurrency.
    let design = Design::Cim2;
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(design, Tech::Sram8T)
            .with_array_dims(64, 32)
            .with_pool(4)
            .with_threads(4),
    );
    let mut rng = Rng::new(701);
    let (m, k, n) = (2usize, 200usize, 60usize);
    let x = rng.ternary_vec(m * k, 0.5);
    let w_res = rng.ternary_vec(k * n, 0.5);
    let w_str = rng.ternary_vec(k * n, 0.5);
    let grid = engine.grid(k, n);
    let want_res = reference_gemm(&x, &w_res, m, &grid, design.flavor());
    let want_str = reference_gemm(&x, &w_str, m, &grid, design.flavor());
    let id = engine.register_weight(&w_res, k, n).unwrap();
    let engref = &engine;
    std::thread::scope(|s| {
        for worker in 0..2 {
            let (x, w_str, want_res, want_str) = (&x, &w_str, &want_res, &want_str);
            s.spawn(move || {
                for pass in 0..4 {
                    let r = engref.gemm_resident(id, x, m).unwrap();
                    assert_eq!(&r, want_res, "resident w{worker} p{pass}");
                    let g = engref.gemm(x, w_str, m, k, n).unwrap();
                    assert_eq!(&g, want_str, "streaming w{worker} p{pass}");
                }
            });
        }
    });
    let s = engine.exec_stats();
    assert_eq!(s.submitted, s.executed);
}

#[test]
fn skewed_working_set_redistributes_and_stays_bit_exact() {
    // 8 small placement tiles (32×16 on 64×32 arrays, 4 per array) all
    // pack onto pool slots 0 and 1 of an 8-array, 8-worker engine: 2 of
    // 8 arrays own 100% of the shards. Static `slot % n_workers`
    // affinity would funnel every warm item through workers 0 and 1;
    // the load-aware policy must divert work (spills at submission —
    // deterministic, since the whole hint loop runs under the queue
    // lock against empty queues — plus whatever stealing the scheduler
    // adds), with results bit-exact throughout.
    let mut rng = Rng::new(702);
    for design in Design::ALL {
        // The approximate (relaxed-snapshot) policy and the exact
        // under-lock scan must both redistribute: submissions here are
        // serial against drained queues, where the snapshot equals the
        // exact depths and the decisions coincide deterministically.
        for mode in [AffinityMode::LoadAware, AffinityMode::LoadAwareExact] {
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_tile_dims(32, 16)
                    .with_pool(8)
                    .with_threads(8)
                    .with_spill_ratio(1)
                    .with_affinity(mode),
            );
            let (m, k, n) = (2usize, 64usize, 64usize); // 2×4 grid = 8 shards
            let x = rng.ternary_vec(m * k, 0.5);
            let w = rng.ternary_vec(k * n, 0.5);
            let want =
                reference_gemm_sharded(&x, &w, m, &engine.grid(k, n), 64, 32, design.flavor());
            let id = engine.register_weight(&w, k, n).unwrap();
            assert_eq!(engine.gemm_resident(id, &x, m).unwrap(), want, "{design:?} {mode:?} cold");
            for pass in 0..4 {
                assert_eq!(
                    engine.gemm_resident(id, &x, m).unwrap(),
                    want,
                    "{design:?} {mode:?} p{pass}"
                );
            }
            let s = engine.exec_stats();
            assert!(
                s.stolen + s.spilled > 0,
                "{design:?} {mode:?}: a 2-hot-array working set must redistribute: {s:?}"
            );
            assert!(
                s.spilled > 0,
                "{design:?} {mode:?}: submission-side spills are deterministic: {s:?}"
            );
            assert_eq!(s.affine + s.stolen + s.spilled, s.executed, "{design:?} {mode:?}");
            assert_eq!(s.panics, 0, "{design:?} {mode:?}");
        }
    }
}

#[test]
fn uniform_working_set_keeps_affinity_and_never_spills() {
    // The complementary case: 4 full-array shards placed one per slot
    // on a 4-worker engine. Warm submissions put exactly one item on
    // each preferred queue, so the spill condition (depth ≥ ratio ×
    // (shallowest + 1)) never fires — `spilled == 0` is deterministic.
    // The affine/stolen split of *execution* is scheduling-dependent,
    // but the first worker to take the queue lock after a uniform
    // submission always finds its own queue non-empty, so at least one
    // item per pass executes affine.
    let mut rng = Rng::new(703);
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim1, Tech::Femfet3T)
            .with_array_dims(64, 32)
            .with_pool(4)
            .with_threads(4),
    );
    let (m, k, n) = (2usize, 128usize, 64usize); // 2×2 grid = 4 full shards
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    let want = reference_gemm(&x, &w, m, &engine.grid(k, n), Design::Cim1.flavor());
    let id = engine.register_weight(&w, k, n).unwrap();
    engine.gemm_resident(id, &x, m).unwrap(); // cold: placements land 1/slot
    let passes = 8u64;
    let before = engine.exec_stats();
    for pass in 0..passes {
        assert_eq!(engine.gemm_resident(id, &x, m).unwrap(), want, "pass {pass}");
    }
    let s = engine.exec_stats();
    assert_eq!(s.spilled, before.spilled, "uniform load never spills");
    assert_eq!(s.spilled, 0);
    assert!(
        s.affine >= before.affine + passes,
        "at least one affine execution per uniform pass: {s:?}"
    );
    assert_eq!(s.affine + s.stolen + s.spilled, s.executed);
    assert_eq!(s.panics, 0);
}
