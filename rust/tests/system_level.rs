//! Integration: system-level reproduction (Figs 12/13 headline claims).
use sitecim::array::area::Design;
use sitecim::device::Tech;
use sitecim::repro::system::averages;

#[test]
fn headline_claims_hold() {
    // "up to 7X throughput boost and up to 2.5X energy reduction"
    let mut best_speed: f64 = 0.0;
    let mut best_energy: f64 = 0.0;
    for tech in Tech::ALL {
        let (sc, _, er) = averages(Design::Cim1, tech);
        best_speed = best_speed.max(sc);
        best_energy = best_energy.max(er);
    }
    assert!(best_speed > 6.0 && best_speed < 10.0, "max speedup {best_speed:.2}");
    assert!(best_energy > 2.0, "max energy reduction {best_energy:.2}");
}

#[test]
fn cim2_system_trails_cim1_but_beats_nm() {
    for tech in Tech::ALL {
        let (s1, _, _) = averages(Design::Cim1, tech);
        let (s2, _, _) = averages(Design::Cim2, tech);
        assert!(s2 > 1.0 && s2 < s1, "{}: {s2} vs {s1}", tech.name());
    }
}
