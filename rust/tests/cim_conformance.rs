//! Conformance suite for the `CimArray` trait layer: one generic battery
//! run against all three backends, plus engine-vs-reference GEMM
//! equivalence on random shapes.

use sitecim::array::mac::{dot_exact, dot_ref, GROUP_ROWS, SAT};
use sitecim::array::{make_array, CimArray, Design};
use sitecim::device::Tech;
use sitecim::engine::tiling::reference_gemm;
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::util::rng::Rng;

fn all_backends(rows: usize, cols: usize) -> Vec<Box<dyn CimArray>> {
    Design::ALL
        .iter()
        .zip(Tech::ALL)
        .map(|(&design, tech)| make_array(design, tech, rows, cols))
        .collect()
}

#[test]
fn write_read_roundtrip_all_backends() {
    let mut rng = Rng::new(101);
    for arr in &mut all_backends(64, 24) {
        let w = rng.ternary_vec(64 * 24, 0.4);
        arr.write_matrix(&w);
        for r in 0..64 {
            assert_eq!(arr.read_row(r), w[r * 24..(r + 1) * 24], "{:?} row {r}", arr.design());
        }
        // Point rewrites clear old state through the trait surface too.
        arr.write(5, 3, 1);
        arr.write(5, 3, -1);
        assert_eq!(arr.storage().read(5, 3), -1, "{:?}", arr.design());
    }
}

#[test]
fn dot_agrees_with_specification_all_backends() {
    let mut rng = Rng::new(102);
    for sparsity in [0.3, 0.5, 0.8] {
        for arr in &mut all_backends(128, 40) {
            let w = rng.ternary_vec(128 * 40, sparsity);
            arr.write_matrix(&w);
            let inputs = rng.ternary_vec(128, sparsity);
            let got = arr.dot(&inputs);
            let want: Vec<i32> = match arr.design().flavor() {
                Some(f) => dot_ref(arr.storage(), &inputs, f),
                None => dot_exact(arr.storage(), &inputs).into_iter().map(|x| x as i32).collect(),
            };
            assert_eq!(got, want, "{:?} at sparsity {sparsity}", arr.design());
        }
    }
}

#[test]
fn dot_batch_equals_per_row_dot_all_backends() {
    let mut rng = Rng::new(103);
    let m = 4;
    for arr in &mut all_backends(64, 16) {
        arr.write_matrix(&rng.ternary_vec(64 * 16, 0.5));
        let xs = rng.ternary_vec(m * 64, 0.5);
        let batched = arr.dot_batch(&xs, m);
        for r in 0..m {
            assert_eq!(
                &batched[r * 16..(r + 1) * 16],
                arr.dot(&xs[r * 64..(r + 1) * 64]).as_slice(),
                "{:?} row {r}",
                arr.design()
            );
        }
    }
}

#[test]
fn mac_cycles_partition_and_sum_to_dot() {
    let mut rng = Rng::new(104);
    for arr in &mut all_backends(96, 10) {
        arr.write_matrix(&rng.ternary_vec(96 * 10, 0.5));
        let inputs = rng.ternary_vec(96, 0.5);
        let n_cycles = 96 / GROUP_ROWS;
        let mut acc = vec![0i32; 10];
        for cycle in 0..n_cycles {
            let cyc_inputs: Vec<i8> = match arr.design().flavor() {
                Some(f) => f.group_rows(96, cycle).iter().map(|&r| inputs[r]).collect(),
                None => inputs[cycle * GROUP_ROWS..(cycle + 1) * GROUP_ROWS].to_vec(),
            };
            let part = arr.mac_cycle(cycle, &cyc_inputs);
            for (a, p) in acc.iter_mut().zip(part) {
                *a += p;
            }
        }
        assert_eq!(acc, arr.dot(&inputs), "{:?}", arr.design());
    }
}

/// The §III.2/§IV.3 divergence: within one 16-row group with a = 10
/// +1-products and b = 2 −1-products, CiM I digitizes the counts
/// separately (min(10,8) − min(2,8) = 6) while CiM II subtracts first
/// (sign(8)·min(8,8) = 8). The NM baseline is exact (10 − 2 = 8).
#[test]
fn cim1_vs_cim2_diverge_on_large_counts() {
    // Single 16-row group, one column: 12 rows hold +1 weights.
    let weights: Vec<i8> = (0..16).map(|r| i8::from(r < 12)).collect();
    // Inputs: +1 on rows 0..10 (products +1), −1 on rows 10..12
    // (products −1), 0 elsewhere → (a, b) = (10, 2).
    let inputs: Vec<i8> = (0..16)
        .map(|r| {
            if r < 10 {
                1
            } else if r < 12 {
                -1
            } else {
                0
            }
        })
        .collect();
    let mut results = Vec::new();
    for design in Design::ALL {
        let mut arr = make_array(design, Tech::Sram8T, 16, 1);
        arr.write_matrix(&weights);
        results.push((design, arr.dot(&inputs)[0]));
    }
    assert_eq!(results[0], (Design::NearMemory, 8), "exact MAC");
    assert_eq!(results[1], (Design::Cim1, 6), "two-ADC path clamps a at 8 first");
    assert_eq!(results[2], (Design::Cim2, 8), "subtract-then-digitize path");
    // And both flavors obey the per-group bound.
    assert!(results.iter().all(|&(_, o)| o.abs() <= SAT as i32));
}

#[test]
fn engine_matches_tiled_reference_on_random_shapes() {
    let mut rng = Rng::new(105);
    // (m, k, n) shapes chosen to hit exact fits, ragged edges, single
    // tiles and K/N both larger than one array.
    let shapes = [(1usize, 64usize, 32usize), (3, 100, 70), (2, 256, 40), (5, 300, 90), (1, 48, 130)];
    for design in Design::ALL {
        for &(m, k, n) in &shapes {
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_pool(4)
                    .with_threads(3),
            );
            let x = rng.ternary_vec(m * k, 0.5);
            let w = rng.ternary_vec(k * n, 0.5);
            let got = engine.gemm(&x, &w, m, k, n).unwrap();
            let want = reference_gemm(&x, &w, m, &engine.grid(k, n), design.flavor());
            assert_eq!(got, want, "{design:?} {m}x{k}x{n}");
        }
    }
}

#[test]
fn engine_single_and_multi_thread_are_bit_identical() {
    let mut rng = Rng::new(106);
    let (m, k, n) = (4usize, 500usize, 120usize);
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    for design in Design::ALL {
        let mk = |threads| {
            TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Sram8T)
                    .with_array_dims(128, 64)
                    .with_pool(6)
                    .with_threads(threads),
            )
            .gemm(&x, &w, m, k, n)
            .unwrap()
        };
        assert_eq!(mk(1), mk(6), "{design:?}");
    }
}

#[test]
fn resident_gemm_matches_streaming_and_reference_on_random_shapes() {
    let mut rng = Rng::new(107);
    // The 4-array pool is smaller than several of these grids, so the
    // resident path also exercises second-chance eviction mid-GEMM.
    let shapes = [(1usize, 64usize, 32usize), (3, 100, 70), (2, 256, 40), (5, 300, 90), (1, 48, 130)];
    for design in Design::ALL {
        for &(m, k, n) in &shapes {
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_pool(4)
                    .with_threads(3),
            );
            let x = rng.ternary_vec(m * k, 0.5);
            let w = rng.ternary_vec(k * n, 0.5);
            let want = reference_gemm(&x, &w, m, &engine.grid(k, n), design.flavor());
            let streaming = engine.gemm(&x, &w, m, k, n).unwrap();
            let id = engine.register_weight(&w, k, n).unwrap();
            let first = engine.gemm_resident(id, &x, m).unwrap();
            let second = engine.gemm_resident(id, &x, m).unwrap();
            assert_eq!(streaming, want, "{design:?} {m}x{k}x{n} streaming");
            assert_eq!(first, want, "{design:?} {m}x{k}x{n} resident cold");
            assert_eq!(second, want, "{design:?} {m}x{k}x{n} resident warm");
        }
    }
}

#[test]
fn resident_gemm_thread_count_is_bit_identical() {
    let mut rng = Rng::new(108);
    let (m, k, n) = (4usize, 500usize, 120usize);
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    for design in Design::ALL {
        let mk = |threads| {
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Sram8T)
                    .with_array_dims(128, 64)
                    .with_pool(6)
                    .with_threads(threads),
            );
            let id = engine.register_weight(&w, k, n).unwrap();
            // Two calls: cold (placing) and warm (hitting) must agree.
            let a = engine.gemm_resident(id, &x, m).unwrap();
            let b = engine.gemm_resident(id, &x, m).unwrap();
            assert_eq!(a, b, "{design:?} {threads} threads cold vs warm");
            a
        };
        assert_eq!(mk(1), mk(6), "{design:?}");
    }
}

#[test]
fn resident_cache_counts_hits_misses_and_evictions() {
    let mut rng = Rng::new(109);
    // 5 k-tiles × 1 n-stripe = 5 tiles on a 2-array pool, single thread:
    // a cyclic sweep under second-chance keeps C − 1 = 1 proven region
    // resident per pass (pure LRU measured 0 hits here).
    let (m, k, n) = (2usize, 300usize, 32usize);
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim1, Tech::Femfet3T)
            .with_array_dims(64, 32)
            .with_pool(2)
            .with_threads(1),
    );
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    let want = reference_gemm(&x, &w, m, &engine.grid(k, n), Design::Cim1.flavor());
    let id = engine.register_weight(&w, k, n).unwrap();

    let first = engine.gemm_resident(id, &x, m).unwrap();
    let s1 = engine.stats();
    assert_eq!(first, want, "over-subscribed cache still bit-exact");
    assert_eq!((s1.hits, s1.misses), (0, 5));
    // Tiles 3, 4, 5 displaced earlier placements (2 slots filled first).
    assert_eq!(s1.evictions, 3);
    assert_eq!(s1.tiles, 5);

    let second = engine.gemm_resident(id, &x, m).unwrap();
    let s2 = engine.stats();
    assert_eq!(second, want, "eviction-then-reuse stays bit-exact");
    // Second pass of the sweep: the first tile survived on its second
    // chance (1 hit); the probation slot churns through the other 4.
    assert_eq!((s2.hits, s2.misses), (1, 9));
    assert_eq!(s2.evictions, 7);
    assert_eq!(s2.tiles, 9);

    // Now a pool that fits the working set: steady state is all hits.
    let roomy = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim1, Tech::Femfet3T)
            .with_array_dims(64, 32)
            .with_pool(5)
            .with_threads(2),
    );
    let id = roomy.register_weight(&w, k, n).unwrap();
    assert_eq!(roomy.gemm_resident(id, &x, m).unwrap(), want);
    assert_eq!(roomy.gemm_resident(id, &x, m).unwrap(), want);
    let s = roomy.stats();
    assert_eq!((s.hits, s.misses, s.evictions), (5, 5, 0));
    assert_eq!(s.tiles, 5, "fully-resident set is programmed exactly once");
    assert_eq!(roomy.resident_tiles(), 5);
}

#[test]
fn streaming_gemm_invalidates_resident_tiles_but_stays_correct() {
    let mut rng = Rng::new(110);
    let (m, k, n) = (2usize, 150usize, 60usize); // 3×2 = 6 tiles
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim2, Tech::Sram8T)
            .with_array_dims(64, 32)
            .with_pool(6)
            .with_threads(1),
    );
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    let want = reference_gemm(&x, &w, m, &engine.grid(k, n), Design::Cim2.flavor());
    let id = engine.register_weight(&w, k, n).unwrap();
    assert_eq!(engine.gemm_resident(id, &x, m).unwrap(), want);
    let before = engine.stats();
    assert_eq!(before.tiles, 6);

    // A streaming GEMM borrows pool arrays and overwrites them.
    let w2 = rng.ternary_vec(k * n, 0.5);
    let want2 = reference_gemm(&x, &w2, m, &engine.grid(k, n), Design::Cim2.flavor());
    assert_eq!(engine.gemm(&x, &w2, m, k, n).unwrap(), want2);

    // The resident path must notice the trashed array and re-program it
    // rather than serve stale weights.
    assert_eq!(engine.gemm_resident(id, &x, m).unwrap(), want, "stale tile re-programmed");
    let after = engine.stats();
    assert!(
        after.tiles > before.tiles + 6,
        "streaming programmed 6 tiles and at least one resident tile was re-programmed \
         (before {} after {})",
        before.tiles,
        after.tiles
    );
}
