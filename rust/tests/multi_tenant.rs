//! Integration: multi-tenant serving from versioned placement
//! artifacts.
//!
//! The battery the PR's acceptance criteria name: two tenants (one
//! hard-reserved, one best-effort) served interleaved and bit-exact
//! against per-model reference forwards with per-tenant books summing
//! to the global counters; a hot-swap under concurrent load that drains
//! every in-flight reply bit-exactly and never serves a mixed-version
//! pipeline; a plan-programmed cold start that does no discovery; and
//! the committed example artifact (produced by
//! `python/compile/make_example_artifact.py`) loading with verified
//! checksums and replaying its placement plan strictly — the test that
//! pins the Python placement mirror to the Rust packing rules.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::time::Duration;

use sitecim::array::mac::Flavor;
use sitecim::array::Design;
use sitecim::coordinator::{MultiServer, MultiServerConfig};
use sitecim::device::Tech;
use sitecim::dnn::ternary::ternarize_acts_i32;
use sitecim::engine::tiling::{reference_gemm, TileGrid};
use sitecim::engine::{plan_layout, EngineConfig, PlannedShard, TernaryGemmEngine};
use sitecim::runtime::Manifest;
use sitecim::util::rng::Rng;
use sitecim::util::sha256;

/// A unique temp artifacts dir per test (tests run in parallel in one
/// process, so the tag must differ per call site).
fn synth_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sitecim-mt-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn trit_bytes(trits: &[i8]) -> Vec<u8> {
    trits.iter().map(|&t| t as u8).collect()
}

fn shards_json(shards: &[PlannedShard]) -> String {
    let rows: Vec<String> = shards
        .iter()
        .map(|s| {
            format!(
                "{{\"layer\": {}, \"shard\": {}, \"k0\": {}, \"k_len\": {}, \"n0\": {}, \
                 \"n_len\": {}, \"slot\": {}, \"row0\": {}, \"col0\": {}}}",
                s.layer, s.shard, s.k0, s.k_len, s.n0, s.n_len, s.slot, s.row0, s.col0
            )
        })
        .collect();
    rows.join(", ")
}

/// Write a servable synthetic MLP. `version2` adds per-file sha256
/// checksums; `plan_geom = (rows, cols, slots)` additionally embeds a
/// placement plan at that pool geometry (computed with the same
/// `plan_layout` the engine replays, exactly as the AOT compiler's
/// Python mirror does).
fn write_artifacts(
    dir: &Path,
    dims: &[usize],
    seed: u64,
    version2: bool,
    plan_geom: Option<(usize, usize, usize)>,
) {
    assert!(dims.len() >= 2);
    let mut rng = Rng::new(seed);
    let mut weights_json = String::new();
    let mut files = Vec::new();
    for i in 0..dims.len() - 1 {
        let (k, n) = (dims[i], dims[i + 1]);
        let w = rng.ternary_vec(k * n, 0.5);
        std::fs::write(dir.join(format!("w{i}.bin")), trit_bytes(&w)).unwrap();
        files.push(format!("w{i}.bin"));
        if i > 0 {
            weights_json.push_str(", ");
        }
        weights_json.push_str(&format!("{{\"file\": \"w{i}.bin\", \"shape\": [{k}, {n}]}}"));
    }
    let in_dim = dims[0];
    let test_n = 4usize;
    let x = rng.ternary_vec(test_n * in_dim, 0.5);
    std::fs::write(dir.join("test_x.bin"), trit_bytes(&x)).unwrap();
    std::fs::write(dir.join("test_y.bin"), vec![0u8; test_n]).unwrap();
    files.push("test_x.bin".into());
    files.push("test_y.bin".into());

    let mut extra = String::new();
    if version2 {
        let sums: Vec<String> = files
            .iter()
            .map(|f| {
                let bytes = std::fs::read(dir.join(f)).unwrap();
                format!("\"{f}\": \"{}\"", sha256::hex(&bytes))
            })
            .collect();
        extra.push_str(&format!("\"version\": 2,\n  \"sha256\": {{{}}},\n  ", sums.join(", ")));
    }
    if let Some((rows, cols, slots)) = plan_geom {
        let layers: Vec<(usize, usize)> = dims.windows(2).map(|w| (w[0], w[1])).collect();
        let plan = plan_layout(&layers, rows, cols, slots).expect("model must fit the plan pool");
        extra.push_str(&format!(
            "\"placement\": {{\"array_rows\": {rows}, \"array_cols\": {cols}, \
             \"slots\": {slots}, \"shards\": [{}]}},\n  ",
            shards_json(&plan)
        ));
    }
    let thresholds = vec!["0.5"; dims.len() - 2].join(", ");
    let dims_json = dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ");
    let manifest = format!(
        "{{\n  {extra}\"batch\": 8,\n  \"dims\": [{dims_json}],\n  \"act_thresholds\": [{thresholds}],\n  \"kernel_shape\": [8, 16, 16],\n  \"files\": {{}},\n  \"weights\": [{weights_json}],\n  \"scales\": [1.0],\n  \"test_set\": {{\"x\": \"test_x.bin\", \"y\": \"test_y.bin\", \"n\": {test_n}, \"in_dim\": {in_dim}}},\n  \"accuracy\": {{}}\n}}\n"
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
}

/// The reference forward pass every tenant must reproduce exactly:
/// `reference_gemm` over the engine's tile grid + recorded thresholds.
fn reference_forward(manifest: &Manifest, input: &[i8]) -> Vec<f32> {
    let mut h = input.to_vec();
    for i in 0..manifest.weights.len() {
        let (w, (k, n)) = manifest.load_weight(i).unwrap();
        let y = reference_gemm(&h, &w, 1, &TileGrid::new(k, n, 256, 256), Some(Flavor::Cim1));
        if i + 1 < manifest.weights.len() {
            h = ternarize_acts_i32(&y, manifest.act_thresholds[i]);
        } else {
            return y.iter().map(|&v| v as f32).collect();
        }
    }
    unreachable!()
}

fn two_tenant_config(dir_a: &Path, dir_b: &Path) -> MultiServerConfig {
    let models =
        vec![("res".to_string(), dir_a.to_path_buf()), ("shared".to_string(), dir_b.to_path_buf())];
    // 6 arrays of 256×256; "res" hard-reserves 2 of them.
    let mut cfg = MultiServerConfig::new(models, 6 * 65536);
    cfg.reserves.insert("res".to_string(), 2 * 65536);
    cfg.n_workers = 2;
    cfg.policy.max_batch = 8;
    cfg.policy.max_wait = Duration::from_millis(1);
    cfg.engine_threads = 2;
    cfg
}

#[test]
fn two_tenants_serve_interleaved_bit_exact_and_books_sum_to_global() {
    let dir_a = synth_dir("twotenant-a");
    let dir_b = synth_dir("twotenant-b");
    // One legacy (v1) manifest and one checksummed v2 manifest: both
    // schema versions must serve side by side.
    write_artifacts(&dir_a, &[32, 16, 8], 21, false, None);
    write_artifacts(&dir_b, &[48, 16, 8], 22, true, None);
    let server = MultiServer::start(two_tenant_config(&dir_a, &dir_b)).unwrap();

    let backend = server.backend();
    let res = backend.model("res").unwrap();
    let shared = backend.model("shared").unwrap();
    assert_ne!(res.partition(), 0, "reserved tenant gets its own partition");
    assert_eq!(shared.partition(), 0, "unreserved tenant shares partition 0");
    let engine = backend.engine();
    assert_eq!(engine.n_tenants(), 2);
    assert_eq!(engine.tenant_slots(res.partition()), 2);
    assert_eq!(engine.tenant_slots(0), 4, "the shared partition keeps the rest");

    let manifest_a = Manifest::load(&dir_a).unwrap();
    let manifest_b = Manifest::load(&dir_b).unwrap();
    let mut rng = Rng::new(23);
    let mut pending = Vec::new();
    for i in 0..48 {
        let (name, manifest, in_dim) =
            if i % 2 == 0 { ("res", &manifest_a, 32) } else { ("shared", &manifest_b, 48) };
        let input = rng.ternary_vec(in_dim, 0.5);
        let want = reference_forward(manifest, &input);
        pending.push((name, want, server.infer_async(name, input).unwrap()));
    }
    for (name, want, rx) in pending {
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.logits, want, "tenant {name} must match its reference forward");
    }

    // Serving metrics: per-tenant books sum to the global counters.
    let m = &server.metrics;
    let (br, bs) = (m.tenant_book("res"), m.tenant_book("shared"));
    assert_eq!(br.requests.load(Ordering::Relaxed), 24);
    assert_eq!(bs.requests.load(Ordering::Relaxed), 24);
    assert_eq!(m.requests.load(Ordering::Relaxed), 48);
    assert_eq!(
        br.batches.load(Ordering::Relaxed) + bs.batches.load(Ordering::Relaxed),
        m.batches.load(Ordering::Relaxed)
    );
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);

    // Engine books: every global charge landed in exactly one tenant
    // book, so across tenants the books sum to the global counters.
    let g = engine.stats();
    let (s0, s1) = (engine.tenant_stats(0), engine.tenant_stats(1));
    for (name, global, parts) in [
        ("gemms", g.gemms, s0.gemms + s1.gemms),
        ("tiles", g.tiles, s0.tiles + s1.tiles),
        ("windows", g.windows, s0.windows + s1.windows),
        ("macs", g.macs, s0.macs + s1.macs),
        ("write_rows", g.write_rows, s0.write_rows + s1.write_rows),
        ("plan_write_rows", g.plan_write_rows, s0.plan_write_rows + s1.plan_write_rows),
        ("hits", g.hits, s0.hits + s1.hits),
        ("misses", g.misses, s0.misses + s1.misses),
        ("evictions", g.evictions, s0.evictions + s1.evictions),
    ] {
        assert_eq!(global, parts, "tenant books must sum to the global {name} counter");
    }
    // And the books are really per-tenant: each model's weights were
    // discovered (not plan-programmed) in its own partition.
    let (rs, ss) = (res.tenant_stats(), shared.tenant_stats());
    assert_eq!(rs.write_rows, 32 + 16, "res: 2 single-tile layers programmed once");
    assert_eq!(ss.write_rows, 48 + 16, "shared: 2 single-tile layers programmed once");
    assert!(rs.hits > 0 && ss.hits > 0);
    assert_eq!(g.evictions, 0, "both working sets fit their partitions");
    server.shutdown();
}

#[test]
fn hot_swap_under_load_drains_in_flight_bit_exact_and_switches_versions() {
    let dir_v1 = synth_dir("swap-v1");
    let dir_v2 = synth_dir("swap-v2");
    // Same shape, different weights: replies tell the versions apart.
    write_artifacts(&dir_v1, &[32, 16, 8], 31, true, None);
    write_artifacts(&dir_v2, &[32, 16, 8], 32, true, None);
    let models = vec![("m".to_string(), dir_v1.clone())];
    let mut cfg = MultiServerConfig::new(models, 4 * 65536);
    cfg.n_workers = 2;
    cfg.policy.max_batch = 8;
    cfg.policy.max_wait = Duration::from_millis(1);
    let server = MultiServer::start(cfg).unwrap();
    assert_eq!(server.model_generation("m"), Some(1));

    let manifest_v1 = Manifest::load(&dir_v1).unwrap();
    let manifest_v2 = Manifest::load(&dir_v2).unwrap();
    let mut rng = Rng::new(33);
    // In-flight load across the swap: these may be answered by either
    // version, but every reply must be bit-exact against exactly one
    // of them — a mixed-version pipeline would match neither.
    let mut in_flight = Vec::new();
    for _ in 0..40 {
        let input = rng.ternary_vec(32, 0.5);
        let v1 = reference_forward(&manifest_v1, &input);
        let v2 = reference_forward(&manifest_v2, &input);
        in_flight.push((v1, v2, server.infer_async("m", input).unwrap()));
    }
    let generation = server.hot_swap("m", &dir_v2).unwrap();
    assert_eq!(generation, 2);
    assert_eq!(server.model_generation("m"), Some(2));
    // hot_swap returns only after every in-flight flush holding the old
    // version drained, so everything submitted after it is pure v2.
    let mut post_swap = Vec::new();
    for _ in 0..40 {
        let input = rng.ternary_vec(32, 0.5);
        let want = reference_forward(&manifest_v2, &input);
        post_swap.push((want, server.infer_async("m", input).unwrap()));
    }
    for (v1, v2, rx) in in_flight {
        let reply = rx.recv().unwrap().expect("reply survives the swap");
        assert!(
            reply.logits == v1 || reply.logits == v2,
            "reply matches neither version's reference — mixed-version pipeline"
        );
    }
    for (want, rx) in post_swap {
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.logits, want, "post-swap replies must come from the new version");
    }
    assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);
    server.shutdown();
}

#[test]
fn plan_programmed_cold_start_serves_with_no_discovery_misses() {
    let dir = synth_dir("coldstart");
    // 300×40 splits into two k-shards; the plan pool matches the
    // serving engine exactly (256×256 arrays, 2-array capacity).
    write_artifacts(&dir, &[300, 40, 8], 41, true, Some((256, 256, 2)));
    let models = vec![("planned".to_string(), dir.clone())];
    let mut cfg = MultiServerConfig::new(models, 2 * 65536);
    cfg.n_workers = 1;
    cfg.policy.max_wait = Duration::from_millis(1);
    let server = MultiServer::start(cfg).unwrap();

    // Cold start programmed exactly the plan: every occupied weight row
    // charged as a plan write, zero discovery traffic.
    let engine = server.backend().engine();
    let s = engine.stats();
    assert_eq!(s.plan_write_rows, (256 + 44) + 40, "Σ k_len over the plan's shards");
    assert_eq!(s.write_rows, 0, "no traffic-driven programming at load");
    assert_eq!(s.misses, 0, "no discovery");
    assert_eq!(s.tiles, 3, "both layers' shards are already resident");

    let manifest = Manifest::load(&dir).unwrap();
    let mut rng = Rng::new(42);
    for _ in 0..6 {
        let input = rng.ternary_vec(300, 0.5);
        let want = reference_forward(&manifest, &input);
        let reply = server.infer("planned", input).unwrap();
        assert_eq!(reply.logits, want, "plan-programmed serving must stay bit-exact");
    }
    let s = engine.stats();
    assert_eq!((s.misses, s.write_rows), (0, 0), "first traffic finds everything resident");
    assert!(s.hits >= 6 * 3, "every shard lookup hits");

    let m = server.measured_residency("planned").unwrap();
    assert_eq!(m.inferences, 6);
    assert_eq!(m.write_rows, 0);
    assert_eq!(m.plan_write_rows, 340);
    assert!(m.plan_write_energy_j > 0.0 && m.plan_write_latency_s > 0.0);
    server.shutdown();
}

#[test]
fn committed_example_artifact_verifies_and_replays_its_plan_strictly() {
    // The committed fixture is produced by the *Python* placement
    // mirror (`python/compile/make_example_artifact.py`); this test
    // pins it to the Rust packing rules shard for shard. CI also runs
    // `sitecim artifact verify` against the same directory.
    let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/example_artifact"));
    let manifest = Manifest::load(dir)
        .expect("committed example artifact must load with verified checksums");
    assert_eq!(manifest.version, 2);
    assert!(!manifest.sha256.is_empty(), "example artifact is checksummed");
    let plan =
        manifest.placement.as_ref().expect("example artifact carries a placement plan");

    let layers: Vec<(usize, usize)> = manifest.dims.windows(2).map(|w| (w[0], w[1])).collect();
    let recomputed =
        plan_layout(&layers, plan.array_rows, plan.array_cols, plan.slots).unwrap();
    assert_eq!(recomputed, plan.shards, "Python placement mirror diverged from the engine");

    // Strict replay: an engine with the plan's exact pool geometry must
    // accept every shard at its planned slot rank and region origin,
    // with zero discovery.
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim1, Tech::Femfet3T)
            .with_array_dims(plan.array_rows, plan.array_cols)
            .with_pool(plan.slots)
            .with_threads(1),
    );
    let mut expected_rows = 0u64;
    for (li, (k, n)) in layers.iter().enumerate() {
        let (w, shape) = manifest.load_weight(li).unwrap();
        assert_eq!(shape, (*k, *n));
        let id = engine.register_weight_arc(w.into(), *k, *n).unwrap();
        let shards: Vec<PlannedShard> =
            plan.shards.iter().filter(|s| s.layer == li).copied().collect();
        assert!(!shards.is_empty());
        engine.program_from_plan(id, &shards).expect("strict plan replay");
        expected_rows += shards.iter().map(|s| s.k_len as u64).sum::<u64>();
    }
    let s = engine.stats();
    assert_eq!(s.plan_write_rows, expected_rows);
    assert_eq!((s.misses, s.write_rows), (0, 0));
    assert_eq!(s.tiles, plan.shards.len() as u64);
}
