//! Deterministic schedule-replay harness: the same GEMM set must
//! produce bit-exact results and exact executor bookkeeping under every
//! forced scheduling order — single-worker FIFO (the fully serial
//! schedule), all-steal (every item pinned to worker 0's queue, so the
//! other workers serve purely by stealing), and all-spill (every item
//! diverted to the shallowest queue, placement affinity ignored).
//! Correctness never depends on *where* an item runs — the per-stripe
//! merge commutes and the content tags force any needed re-programming —
//! and every executed item is classified as exactly one of
//! affine / stolen / spilled.

use std::sync::Arc;

use sitecim::array::Design;
use sitecim::device::Tech;
use sitecim::engine::tiling::reference_gemm_sharded;
use sitecim::engine::{AffinityMode, EngineConfig, ExecStatsSnapshot, TernaryGemmEngine};
use sitecim::util::rng::Rng;

const ARRAY_ROWS: usize = 64;
const ARRAY_COLS: usize = 32;

/// One GEMM of the replayed set: operands plus its sharded reference.
struct Case {
    m: usize,
    k: usize,
    n: usize,
    x: Arc<[i8]>,
    w: Arc<[i8]>,
    want: Vec<i32>,
}

/// The shared GEMM set: ragged multi-shard shapes, checked against the
/// general `reference_gemm_sharded` spec (which the cross-mode test
/// below additionally replays at an oversized placement-tile shape, so
/// tile ≠ array sharding is covered under every forced order too).
fn gemm_set(engine: &TernaryGemmEngine, design: Design, seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(seed);
    let shapes = [(2usize, 150usize, 60usize), (1, 300, 32), (3, 100, 90)];
    shapes
        .iter()
        .map(|&(m, k, n)| {
            let x: Arc<[i8]> = rng.ternary_vec(m * k, 0.5).into();
            let w: Arc<[i8]> = rng.ternary_vec(k * n, 0.5).into();
            let want = reference_gemm_sharded(
                &x,
                &w,
                m,
                &engine.grid(k, n),
                ARRAY_ROWS,
                ARRAY_COLS,
                design.flavor(),
            );
            Case { m, k, n, x, w, want }
        })
        .collect()
}

/// Replay the set: streaming (slice and Arc surfaces) plus a registered
/// resident weight over several passes, asserting bit-exactness at
/// every step. Returns the drained executor snapshot.
fn replay(engine: &TernaryGemmEngine, design: Design, label: &str) -> ExecStatsSnapshot {
    let cases = gemm_set(engine, design, 0x5C4E_D01E);
    for (i, c) in cases.iter().enumerate() {
        let got = engine.gemm(&c.x, &c.w, c.m, c.k, c.n).unwrap();
        assert_eq!(got, c.want, "{label}: streaming case {i}");
        let got = engine
            .gemm_arc(Arc::clone(&c.x), Arc::clone(&c.w), c.m, c.k, c.n)
            .unwrap();
        assert_eq!(got, c.want, "{label}: arc case {i}");
    }
    let ids: Vec<_> = cases
        .iter()
        .map(|c| engine.register_weight_arc(Arc::clone(&c.w), c.k, c.n).unwrap())
        .collect();
    for pass in 0..3 {
        for (i, (c, id)) in cases.iter().zip(&ids).enumerate() {
            let got = engine.gemm_resident_arc(*id, Arc::clone(&c.x), c.m).unwrap();
            assert_eq!(got, c.want, "{label}: resident case {i} pass {pass}");
        }
    }
    engine.exec_stats()
}

/// Exact bookkeeping at a drain point: nothing lost, nothing double
/// counted, nothing panicked.
fn assert_books(s: &ExecStatsSnapshot, label: &str) {
    assert!(s.submitted > 0, "{label}: the replay submitted work");
    assert_eq!(s.submitted, s.executed, "{label}: queues drained");
    assert_eq!(
        s.affine + s.stolen + s.spilled,
        s.executed,
        "{label}: every item classified exactly once: {s:?}"
    );
    assert_eq!(s.panics, 0, "{label}");
    assert!(s.queue_depth_max >= 1, "{label}: submissions were observed");
}

fn engine_with(design: Design, threads: usize, mode: AffinityMode) -> TernaryGemmEngine {
    TernaryGemmEngine::new(
        EngineConfig::new(design, Tech::Femfet3T)
            .with_array_dims(ARRAY_ROWS, ARRAY_COLS)
            .with_pool(4)
            .with_threads(threads)
            .with_affinity(mode),
    )
}

#[test]
fn forced_single_worker_fifo_is_exact_and_all_affine() {
    for design in Design::ALL {
        let engine = engine_with(design, 1, AffinityMode::LoadAware);
        let s = replay(&engine, design, "fifo");
        assert_books(&s, "fifo");
        // One worker: no steal source, no spill target.
        assert_eq!(s.stolen, 0, "{design:?}");
        assert_eq!(s.spilled, 0, "{design:?}");
        assert_eq!(s.affine, s.executed, "{design:?}");
    }
}

#[test]
fn exact_load_aware_replay_is_exact() {
    // The under-lock depth-scan variant kept for deterministic replay:
    // its spill decisions are a pure function of the locked queue state,
    // and the replay must be bit-exact like every other mode.
    for design in Design::ALL {
        let engine = engine_with(design, 4, AffinityMode::LoadAwareExact);
        let s = replay(&engine, design, "load-aware-exact");
        assert_books(&s, "load-aware-exact");
    }
}

#[test]
fn forced_all_steal_order_is_exact() {
    // Every item lands on worker 0's queue; workers 1..4 are starved of
    // owned work and serve purely by stealing. Which worker executes a
    // given item is scheduling-dependent — the spill count is not:
    // PinToZero never spills.
    for design in Design::ALL {
        let engine = engine_with(design, 4, AffinityMode::PinToZero);
        let s = replay(&engine, design, "all-steal");
        assert_books(&s, "all-steal");
        assert_eq!(s.spilled, 0, "{design:?}: pinned submissions never spill");
    }
}

#[test]
fn forced_all_spill_order_is_exact_and_never_affine() {
    // Every item is diverted to the shallowest queue and tagged spilled;
    // an item executed from its enqueue queue therefore counts spilled,
    // and one that leaves it counts stolen — affine is impossible.
    for design in Design::ALL {
        let engine = engine_with(design, 4, AffinityMode::ForceSpill);
        let s = replay(&engine, design, "all-spill");
        assert_books(&s, "all-spill");
        assert_eq!(s.affine, 0, "{design:?}: no item may count as affine");
        assert!(s.spilled > 0, "{design:?}: the forced order spills");
    }
}

#[test]
fn forced_orders_agree_bit_for_bit() {
    // The harness's point: the three degenerate schedules (and the
    // production policy) are indistinguishable in output space. The
    // per-case assertions inside `replay` already compare each order to
    // the shared `reference_gemm_sharded` spec; this pins the cross-mode
    // equality explicitly on a fresh engine per mode.
    for design in [Design::Cim1, Design::Cim2] {
        let mut outputs: Vec<Vec<Vec<i32>>> = Vec::new();
        for (threads, mode) in [
            (1usize, AffinityMode::LoadAware),
            (4, AffinityMode::LoadAware),
            (4, AffinityMode::LoadAwareExact),
            (4, AffinityMode::PinToZero),
            (4, AffinityMode::ForceSpill),
        ] {
            // Oversized placement tiles (128×64 on 64×32 arrays): every
            // logical tile shards across several arrays, so the forced
            // orders also cover partial-sum recombination.
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(ARRAY_ROWS, ARRAY_COLS)
                    .with_tile_dims(128, 64)
                    .with_pool(4)
                    .with_threads(threads)
                    .with_affinity(mode),
            );
            let cases = gemm_set(&engine, design, 0xFEED_F00D);
            let ids: Vec<_> = cases
                .iter()
                .map(|c| engine.register_weight_arc(Arc::clone(&c.w), c.k, c.n).unwrap())
                .collect();
            let outs: Vec<Vec<i32>> = cases
                .iter()
                .zip(&ids)
                .map(|(c, id)| engine.gemm_resident_arc(*id, Arc::clone(&c.x), c.m).unwrap())
                .collect();
            outputs.push(outs);
        }
        for other in &outputs[1..] {
            assert_eq!(&outputs[0], other, "{design:?}: schedules diverged");
        }
    }
}
