//! Integration: the AOT HLO artifacts execute correctly on PJRT and are
//! numerically equivalent to the rust functional array simulation —
//! the three-layer contract. Skips gracefully without artifacts.
use sitecim::array::mac::{dot_ref, Flavor};
use sitecim::array::TernaryStorage;
use sitecim::runtime::{cpu_client, default_dir, KernelExecutor, Manifest, MlpExecutor, ModelKind};
use sitecim::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::load(default_dir()).ok()
}

#[test]
fn kernel_hlo_equals_rust_functional_sim() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let client = cpu_client().unwrap();
    let k = KernelExecutor::load(&client, &m).unwrap();
    let mut rng = Rng::new(31);
    for trial in 0..5 {
        let x = rng.ternary_vec(k.m * k.k, 0.4);
        let w = rng.ternary_vec(k.k * k.n, 0.4);
        let hlo = k.run(&x, &w).unwrap();
        // Rust reference: weights into storage, dot per input row.
        let mut st = TernaryStorage::new(k.k, k.n);
        st.write_matrix(&w);
        for row in 0..k.m {
            let inputs = &x[row * k.k..(row + 1) * k.k];
            let want = dot_ref(&st, inputs, Flavor::Cim1);
            let got: Vec<i32> = hlo[row * k.n..(row + 1) * k.n].to_vec();
            assert_eq!(got, want, "trial {trial} row {row}");
        }
    }
}

#[test]
fn mlp_hlo_accuracy_matches_aot_recording() {
    let Some(m) = manifest() else {
        return;
    };
    let client = cpu_client().unwrap();
    let (x, y) = m.load_test_set().unwrap();
    for (kind, key) in [
        (ModelKind::Exact, "exact"),
        (ModelKind::Cim1, "cim1"),
        (ModelKind::Cim2, "cim2"),
    ] {
        let exe = MlpExecutor::load(&client, &m, kind).unwrap();
        let n = m.test_n;
        let mut correct = 0usize;
        for base in (0..n).step_by(exe.batch) {
            let nb = exe.batch.min(n - base);
            let preds = exe.classify(&x[base * m.in_dim..(base + nb) * m.in_dim], nb).unwrap();
            correct += preds.iter().zip(&y[base..base + nb]).filter(|(p, &l)| **p == l as usize).count();
        }
        let acc = correct as f64 / n as f64;
        let aot = m.aot_accuracy[key];
        assert!((acc - aot).abs() < 0.01, "{key}: rust {acc} vs aot {aot}");
    }
}

#[test]
fn batch_padding_is_neutral() {
    let Some(m) = manifest() else {
        return;
    };
    let client = cpu_client().unwrap();
    let exe = MlpExecutor::load(&client, &m, ModelKind::Cim1).unwrap();
    let (x, _) = m.load_test_set().unwrap();
    // Same sample alone vs in a full batch must classify identically.
    let one = exe.classify(&x[..m.in_dim], 1).unwrap();
    let full = exe.classify(&x[..exe.batch * m.in_dim], exe.batch).unwrap();
    assert_eq!(one[0], full[0]);
}
