//! Region-kernel conformance battery.
//!
//! The contract of `CimArray::dot_batch_region` is equivalence with the
//! full-array path: for any 16-row-aligned rect, region-local inputs
//! zero-padded to the full array and run through `dot_batch` must equal
//! the region kernel's output on the rect's column slice — bit for bit,
//! for all three designs, every tech, unaligned column spans, and
//! partial final 16-row groups (shards whose occupied rows end short of
//! their padded region). The engine-level battery then checks that the
//! region-scoped execution path composes: packed small weights served
//! resident match the `reference_gemm_sharded` spec exactly.

use sitecim::array::{make_array, CimArray, Design, Rect};
use sitecim::device::Tech;
use sitecim::engine::tiling::reference_gemm_sharded;
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::util::rng::Rng;

/// The specification: zero-pad the region-local inputs to the full
/// array, run the full-array batched MAC, slice the region's columns.
fn padded_full_slice(arr: &dyn CimArray, rect: &Rect, inputs: &[i8], m: usize) -> Vec<i32> {
    let n_rows = arr.n_rows();
    let n_cols = arr.n_cols();
    let mut padded = vec![0i8; m * n_rows];
    for v in 0..m {
        padded[v * n_rows + rect.row0..v * n_rows + rect.row0 + rect.rows]
            .copy_from_slice(&inputs[v * rect.rows..(v + 1) * rect.rows]);
    }
    let full = arr.dot_batch(&padded, m);
    let mut out = Vec::with_capacity(m * rect.cols);
    for v in 0..m {
        out.extend_from_slice(&full[v * n_cols + rect.col0..v * n_cols + rect.col0 + rect.cols]);
    }
    out
}

#[test]
fn random_rects_match_full_array_slice_all_designs_and_techs() {
    let mut rng = Rng::new(600);
    let (rows, cols) = (256usize, 96usize);
    for design in Design::ALL {
        for tech in Tech::ALL {
            let mut arr = make_array(design, tech, rows, cols);
            arr.write_matrix(&rng.ternary_vec(rows * cols, 0.5));
            for trial in 0..12 {
                // Random 16-aligned row window, random (unaligned) column
                // span, random small batch.
                let r_groups = 1 + rng.below((rows / 16) as u64) as usize;
                let row0 = 16 * rng.below(((rows / 16) - r_groups + 1) as u64) as usize;
                let c_len = 1 + rng.below(cols as u64) as usize;
                let col0 = rng.below((cols - c_len + 1) as u64) as usize;
                let rect = Rect { row0, rows: 16 * r_groups, col0, cols: c_len };
                let m = 1 + rng.below(3) as usize;
                let inputs = rng.ternary_vec(m * rect.rows, 0.5);
                assert_eq!(
                    arr.dot_batch_region(&rect, &inputs, m),
                    padded_full_slice(arr.as_ref(), &rect, &inputs, m),
                    "{design:?}/{tech:?} trial {trial} rect {rect:?}"
                );
            }
        }
    }
}

#[test]
fn partial_final_groups_are_inert_padding() {
    // A shard with k_len = 36 occupies a 48-row region; rows 36..48 carry
    // zero inputs. The kernel must treat them as electrically inert: the
    // result equals the same region with the tail rows explicitly zero in
    // a longer input (which is exactly how the engine pads).
    let mut rng = Rng::new(601);
    for design in Design::ALL {
        let mut arr = make_array(design, Tech::Femfet3T, 128, 40);
        arr.write_matrix(&rng.ternary_vec(128 * 40, 0.5));
        let rect = Rect { row0: 64, rows: 48, col0: 3, cols: 17 };
        let m = 2;
        let mut inputs = rng.ternary_vec(m * rect.rows, 0.5);
        for v in 0..m {
            for j in 36..48 {
                inputs[v * rect.rows + j] = 0; // zero-padded shard tail
            }
        }
        assert_eq!(
            arr.dot_batch_region(&rect, &inputs, m),
            padded_full_slice(arr.as_ref(), &rect, &inputs, m),
            "{design:?}"
        );
    }
}

#[test]
fn whole_array_region_equals_dot_batch() {
    let mut rng = Rng::new(602);
    for design in Design::ALL {
        let mut arr = make_array(design, Tech::Sram8T, 64, 32);
        arr.write_matrix(&rng.ternary_vec(64 * 32, 0.4));
        let rect = Rect { row0: 0, rows: 64, col0: 0, cols: 32 };
        let m = 3;
        let inputs = rng.ternary_vec(m * 64, 0.4);
        assert_eq!(
            arr.dot_batch_region(&rect, &inputs, m),
            arr.dot_batch(&inputs, m),
            "{design:?}: the full-array rect is literally dot_batch"
        );
    }
}

#[test]
fn engine_region_path_composes_to_sharded_reference() {
    // Ragged GEMMs whose shards land on packed sub-array regions: the
    // region-scoped execution path must still equal the sharded dot_ref
    // composition bit-for-bit — streaming, resident cold and resident
    // warm — across designs and thread counts.
    let mut rng = Rng::new(603);
    let shapes = [(1usize, 80usize, 20usize), (3, 130, 50), (2, 300, 90)];
    for design in Design::ALL {
        for threads in [1usize, 3] {
            for &(m, k, n) in &shapes {
                let engine = TernaryGemmEngine::new(
                    EngineConfig::new(design, Tech::Edram3T)
                        .with_array_dims(64, 32)
                        .with_pool(4)
                        .with_threads(threads),
                );
                let x = rng.ternary_vec(m * k, 0.5);
                let w = rng.ternary_vec(k * n, 0.5);
                let grid = engine.grid(k, n);
                let want = reference_gemm_sharded(&x, &w, m, &grid, 64, 32, design.flavor());
                assert_eq!(
                    engine.gemm(&x, &w, m, k, n).unwrap(),
                    want,
                    "{design:?} {m}x{k}x{n} t{threads} streaming"
                );
                let id = engine.register_weight(&w, k, n).unwrap();
                assert_eq!(
                    engine.gemm_resident(id, &x, m).unwrap(),
                    want,
                    "{design:?} {m}x{k}x{n} t{threads} resident cold"
                );
                assert_eq!(
                    engine.gemm_resident(id, &x, m).unwrap(),
                    want,
                    "{design:?} {m}x{k}x{n} t{threads} resident warm"
                );
            }
        }
    }
}
