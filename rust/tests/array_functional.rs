//! Integration: bit-level array functional simulation agrees with the
//! saturating-MAC specification across flavors, techs and sparsities.
use sitecim::array::mac::{dot_exact, dot_ref, Flavor};
use sitecim::array::{CimArray, NearMemoryArray, SiTeCim1Array, SiTeCim2Array};
use sitecim::device::Tech;
use sitecim::util::rng::Rng;

#[test]
fn full_256x256_arrays_match_reference() {
    let mut rng = Rng::new(2);
    for tech in Tech::ALL {
        let w = rng.ternary_vec(256 * 256, 0.5);
        let inputs = rng.ternary_vec(256, 0.5);
        let mut a1 = SiTeCim1Array::new(tech);
        a1.write_matrix(&w);
        assert_eq!(a1.dot(&inputs), dot_ref(a1.storage(), &inputs, Flavor::Cim1));
        let mut a2 = SiTeCim2Array::new(tech);
        a2.write_matrix(&w);
        assert_eq!(a2.dot(&inputs), dot_ref(a2.storage(), &inputs, Flavor::Cim2));
    }
}

#[test]
fn nm_baseline_is_exact_and_cim_is_close_at_sparsity() {
    let mut rng = Rng::new(3);
    let w = rng.ternary_vec(256 * 128, 0.55);
    let inputs = rng.ternary_vec(256, 0.55);
    let mut nm = NearMemoryArray::with_dims(Tech::Sram8T, 256, 128);
    nm.write_matrix(&w);
    let exact = nm.dot_exact(&inputs);
    let mut c1 = SiTeCim1Array::with_dims(Tech::Sram8T, 256, 128);
    c1.write_matrix(&w);
    let sat = c1.dot(&inputs);
    assert_eq!(exact, dot_exact(c1.storage(), &inputs));
    let close = sat.iter().zip(&exact).filter(|&(&s, &e)| (s as i64 - e).abs() <= 2).count();
    assert!(close > 120, "only {close}/128 close");
}

#[test]
fn analog_paths_match_digital_under_ideal_circuits() {
    let mut rng = Rng::new(4);
    let mut a1 = SiTeCim1Array::with_dims(Tech::Edram3T, 64, 64);
    a1.write_matrix(&rng.ternary_vec(64 * 64, 0.4));
    let inputs = rng.ternary_vec(64, 0.4);
    let mut zrng = Rng::new(5);
    assert_eq!(a1.dot_analog_mc(&inputs, 0.0, &mut zrng), a1.dot(&inputs));
}

#[test]
fn read_after_cim_preserves_weights() {
    // CiM cycles must not disturb stored state (non-destructive compute).
    let mut rng = Rng::new(6);
    let w = rng.ternary_vec(64 * 32, 0.3);
    let mut a = SiTeCim1Array::with_dims(Tech::Femfet3T, 64, 32);
    a.write_matrix(&w);
    let _ = a.dot(&rng.ternary_vec(64, 0.3));
    for r in 0..64 {
        assert_eq!(a.read_row(r), w[r * 32..(r + 1) * 32]);
    }
}
