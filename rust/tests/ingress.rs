//! Integration: the ingress admission chain in front of a live server.
//!
//! The battery the PR's acceptance criteria name: a stress test driving
//! well past serving capacity and proving the latency of *admitted*
//! requests stays bounded while the excess is answered with explicit
//! `Overloaded` rejections (nonzero shed counter, offered work fully
//! conserved across the verdict columns); deterministic server-level
//! checks that malformed planes and rate-limited requests are refused
//! *before* enqueue (the batcher and metrics never see them); the
//! shed/recover hysteresis observed through a live `Server`; and the
//! multi-tenant report whose per-tenant ledgers — including
//! unknown-model rejections with no lane at all — sum to the global
//! counters.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use sitecim::coordinator::{
    BatchPolicy, IngressConfig, MultiServer, MultiServerConfig, RateLimit, Server, ServerConfig,
    Watermarks,
};
use sitecim::util::json::Json;
use sitecim::util::rng::Rng;

/// A unique temp artifacts dir per test (tests run in parallel in one
/// process, so the tag must differ per call site).
fn synth_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sitecim-ingr-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn trit_bytes(trits: &[i8]) -> Vec<u8> {
    trits.iter().map(|&t| t as u8).collect()
}

/// Write a servable synthetic MLP: random ternary weights for each
/// `dims` transition, activation thresholds between layers, and a tiny
/// test set.
fn write_synth_artifacts(dir: &Path, dims: &[usize], batch: usize, seed: u64) {
    assert!(dims.len() >= 2);
    let mut rng = Rng::new(seed);
    let mut weights_json = String::new();
    for i in 0..dims.len() - 1 {
        let (k, n) = (dims[i], dims[i + 1]);
        let w = rng.ternary_vec(k * n, 0.5);
        std::fs::write(dir.join(format!("w{i}.bin")), trit_bytes(&w)).unwrap();
        if i > 0 {
            weights_json.push_str(", ");
        }
        weights_json.push_str(&format!("{{\"file\": \"w{i}.bin\", \"shape\": [{k}, {n}]}}"));
    }
    let in_dim = dims[0];
    let test_n = 4usize;
    let x = rng.ternary_vec(test_n * in_dim, 0.5);
    std::fs::write(dir.join("test_x.bin"), trit_bytes(&x)).unwrap();
    std::fs::write(dir.join("test_y.bin"), vec![0u8; test_n]).unwrap();
    let thresholds = vec!["0.5"; dims.len() - 2].join(", ");
    let dims_json =
        dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ");
    let manifest = format!(
        "{{\n  \"batch\": {batch},\n  \"dims\": [{dims_json}],\n  \"act_thresholds\": [{thresholds}],\n  \"kernel_shape\": [8, 16, 16],\n  \"files\": {{}},\n  \"weights\": [{weights_json}],\n  \"scales\": [1.0],\n  \"test_set\": {{\"x\": \"test_x.bin\", \"y\": \"test_y.bin\", \"n\": {test_n}, \"in_dim\": {in_dim}}},\n  \"accuracy\": {{}}\n}}\n"
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
}

fn engine_server_config(dir: PathBuf, workers: usize) -> ServerConfig {
    let mut cfg = ServerConfig::new(dir).with_engine_backend();
    cfg.n_workers = workers;
    cfg.engine_threads = 2;
    cfg.policy =
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1), ..Default::default() };
    cfg
}

/// Wait for the workers to balance every admission (replies are sent
/// *before* the scatter path decrements the in-flight gauge, so a test
/// that has received every reply can still race the final decrement).
fn wait_drained(server: &Server) {
    let t0 = Instant::now();
    while (server.ingress().inflight() > 0 || server.ingress().is_shedding())
        && t0.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(server.ingress().inflight(), 0, "admissions never fully balanced");
}

/// The acceptance stress test: offer far more work than the watermark
/// admits, in a burst much faster than a flush can complete. The
/// admitted requests all come back correct with bounded latency; the
/// excess is shed with an explicit `Overloaded` reply; and the verdict
/// columns conserve every offered request.
#[test]
fn overload_sheds_excess_load_and_keeps_admitted_latency_bounded() {
    let dir = synth_dir("overload");
    write_synth_artifacts(&dir, &[24, 12, 8], 8, 3);
    let mut cfg = engine_server_config(dir, 1);
    // The flush deadline (20 ms) dwarfs the µs-scale send loop, so the
    // gauge pins at the high-water mark while the first flush is still
    // forming — shedding is guaranteed, not a scheduling accident.
    cfg.policy = BatchPolicy {
        max_batch: 8,
        max_batch_rows: 64,
        max_wait: Duration::from_millis(20),
        ..Default::default()
    };
    cfg.ingress =
        IngressConfig { shed: Some(Watermarks { high: 4, low: 1 }), ..Default::default() };
    let server = Server::start(cfg).unwrap();

    let offered = 400u64;
    let mut rng = Rng::new(11);
    let mut pending = Vec::new();
    let mut shed_replies = 0u64;
    for _ in 0..offered {
        let input = rng.ternary_vec(24, 0.5);
        match server.infer_async(input) {
            Ok(rx) => pending.push(rx),
            Err(e) => {
                assert!(e.to_string().contains("overloaded"), "unexpected rejection: {e}");
                assert_eq!(e.retry_after_s(), None, "shed clears on load, not a clock");
                shed_replies += 1;
            }
        }
    }
    for rx in &pending {
        let reply = rx.recv().unwrap().expect("admitted request must be served");
        assert_eq!(reply.logits.len(), 8);
    }
    wait_drained(&server);

    let report = server.metrics_report();
    assert!(report.ingress.shed > 0, "2x+ offered load must shed");
    assert_eq!(report.ingress.shed, shed_replies);
    assert_eq!(report.ingress.admitted, pending.len() as u64);
    assert_eq!(report.ingress.admitted + report.ingress.shed, offered);
    assert_eq!(report.ingress.offered(), offered);
    assert!(
        report.ingress.admitted >= 4,
        "the first high-water window admits: {:?}",
        report.ingress
    );
    assert_eq!(report.errors, 0, "shed is a front-door verdict, not a backend error");
    assert_eq!(report.requests, pending.len() as u64);
    // Bounded latency: admitted work waits at most a flush deadline plus
    // execution, never the whole offered backlog.
    assert!(report.latency_s.p99 > 0.0);
    assert!(
        report.latency_s.p99 < 2.0,
        "p99 {}s not bounded under overload",
        report.latency_s.p99
    );
    assert!(!report.shedding, "drained below low water must clear the latch");
    assert_eq!(report.inflight, 0);
    // Single-tenant serving: the one tenant row carries the whole ledger.
    assert_eq!(report.tenants.len(), 1);
    assert_eq!(report.tenants[0].name, "default");
    assert_eq!(report.tenants[0].ingress, report.ingress);
    assert_eq!(report.tenants[0].requests, report.requests);
    server.shutdown();
}

/// Rate limiting happens at the front door: with a burst of 2 and a
/// refill far slower than the test, exactly two requests are admitted
/// and the batcher/metrics never see the rest.
#[test]
fn rate_limit_refuses_before_enqueue_at_server_level() {
    let dir = synth_dir("rate");
    write_synth_artifacts(&dir, &[24, 12, 8], 8, 5);
    let mut cfg = engine_server_config(dir, 1);
    // 0.001 tokens/s: the bucket effectively never refills within the
    // test, so the verdicts are deterministic without a manual clock
    // (refill determinism itself is unit-tested with `ManualClock`).
    cfg.ingress = IngressConfig {
        rate: Some(RateLimit { per_s: 0.001, burst: 2.0 }),
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();

    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    let mut limited = 0u64;
    for _ in 0..6 {
        match server.infer_async(rng.ternary_vec(24, 0.5)) {
            Ok(rx) => pending.push(rx),
            Err(e) => {
                assert!(e.to_string().contains("rate limited"), "unexpected rejection: {e}");
                // The Retry-After hint: at 0.001 tokens/s an empty
                // bucket refills one token in ~1000 s — the typed error
                // carries the bucket's own estimate.
                let retry = e.retry_after_s().expect("rate limits carry a retry hint");
                assert!(retry > 900.0, "retry hint {retry}s must reflect the slow refill");
                limited += 1;
            }
        }
    }
    assert_eq!((pending.len(), limited), (2, 4), "burst admits, then the bucket is empty");
    for rx in &pending {
        rx.recv().unwrap().expect("admitted request must be served");
    }
    wait_drained(&server);

    let report = server.metrics_report();
    assert_eq!(report.ingress.admitted, 2);
    assert_eq!(report.ingress.rate_limited, 4);
    assert_eq!(report.requests, 2, "rate-limited requests never reach the batcher");
    assert_eq!(report.errors, 0);
    server.shutdown();
}

/// Shape validation happens before any queue slot is taken: malformed
/// planes come back as immediate errors and the serving counters stay
/// untouched.
#[test]
fn malformed_requests_never_reach_the_batcher() {
    let dir = synth_dir("shape");
    write_synth_artifacts(&dir, &[24, 12, 8], 8, 9);
    let server = Server::start(engine_server_config(dir, 1)).unwrap();

    let short = server.infer_async(vec![1i8; 23]).unwrap_err().to_string();
    assert!(short.contains("bad request shape") && short.contains("23"), "{short}");
    let mut bad = vec![0i8; 24];
    bad[7] = 7;
    let nontrit = server.infer_async(bad).unwrap_err().to_string();
    assert!(nontrit.contains("bad request shape") && nontrit.contains("non-trit"), "{nontrit}");

    let mut rng = Rng::new(2);
    let rx = server.infer_async(rng.ternary_vec(24, 0.5)).unwrap();
    rx.recv().unwrap().expect("well-formed request must be served");
    wait_drained(&server);

    let report = server.metrics_report();
    assert_eq!(report.ingress.rejected_shape, 2);
    assert_eq!(report.ingress.admitted, 1);
    assert_eq!(report.requests, 1, "rejected planes never count as served requests");
    assert_eq!(report.errors, 0);
    server.shutdown();
}

/// The shed latch observed through a live server: it sets at the
/// high-water mark, holds while draining through the hysteresis band,
/// and clears once the in-flight gauge reaches the low-water mark.
#[test]
fn shed_latch_recovers_at_low_water_after_drain() {
    let dir = synth_dir("hysteresis");
    write_synth_artifacts(&dir, &[24, 12, 8], 8, 13);
    let mut cfg = engine_server_config(dir, 1);
    // One flush holds both admitted requests in flight for ~100 ms —
    // plenty of time to observe the latched state deterministically.
    cfg.policy = BatchPolicy {
        max_batch: 8,
        max_batch_rows: 64,
        max_wait: Duration::from_millis(100),
        ..Default::default()
    };
    cfg.ingress =
        IngressConfig { shed: Some(Watermarks { high: 2, low: 1 }), ..Default::default() };
    let server = Server::start(cfg).unwrap();

    let mut rng = Rng::new(4);
    let a = server.infer_async(rng.ternary_vec(24, 0.5)).unwrap();
    let b = server.infer_async(rng.ternary_vec(24, 0.5)).unwrap();
    let rejected = server.infer_async(rng.ternary_vec(24, 0.5)).unwrap_err().to_string();
    assert!(rejected.contains("overloaded"), "{rejected}");
    assert!(server.ingress().is_shedding(), "high water latches the shedder");

    a.recv().unwrap().unwrap();
    b.recv().unwrap().unwrap();
    wait_drained(&server);
    assert!(!server.ingress().is_shedding(), "draining to low water clears the latch");
    let again = server.infer_async(rng.ternary_vec(24, 0.5)).unwrap();
    again.recv().unwrap().unwrap();
    wait_drained(&server);

    let s = server.ingress().snapshot();
    assert_eq!((s.admitted, s.shed), (3, 1));
    server.shutdown();
}

/// The multi-tenant report: per-model ledgers (including a ghost model
/// that only ever produced unknown-model rejections) sum to the global
/// columns, the engine/executor sections are present, and the whole
/// report round-trips through the crate's JSON parser.
#[test]
fn multi_server_report_sums_tenant_ledgers_including_unknown_models() {
    let dir_a = synth_dir("multi-a");
    let dir_b = synth_dir("multi-b");
    write_synth_artifacts(&dir_a, &[24, 12, 6], 8, 21);
    write_synth_artifacts(&dir_b, &[16, 12, 8], 8, 22);
    let models = vec![("alpha".to_string(), dir_a), ("beta".to_string(), dir_b)];
    let mut cfg = MultiServerConfig::new(models, 6 * 65536);
    cfg.n_workers = 1;
    cfg.policy.max_batch = 8;
    cfg.policy.max_wait = Duration::from_millis(1);
    let server = MultiServer::start(cfg).unwrap();

    let mut rng = Rng::new(17);
    let mut pending = Vec::new();
    for _ in 0..3 {
        pending.push(server.infer_async("alpha", rng.ternary_vec(24, 0.5)).unwrap());
    }
    for _ in 0..2 {
        pending.push(server.infer_async("beta", rng.ternary_vec(16, 0.5)).unwrap());
    }
    let ghost = server.infer_async("ghost", rng.ternary_vec(24, 0.5)).unwrap_err().to_string();
    assert!(ghost.contains("unknown model"), "{ghost}");
    // A plane shaped for beta offered to alpha: rejected by alpha's
    // manifest dimension through the shared gate.
    let cross = server.infer_async("alpha", rng.ternary_vec(16, 0.5)).unwrap_err().to_string();
    assert!(cross.contains("bad request shape"), "{cross}");
    for rx in &pending {
        rx.recv().unwrap().expect("admitted request must be served");
    }
    let t0 = Instant::now();
    while server.ingress().inflight() > 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(1));
    }

    let report = server.metrics_report();
    assert_eq!(report.ingress.admitted, 5);
    assert_eq!(report.ingress.unknown_model, 1);
    assert_eq!(report.ingress.rejected_shape, 1);
    assert_eq!(report.ingress.offered(), 7);
    assert_eq!(report.requests, 5);
    let names: Vec<&str> = report.tenants.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(names, vec!["alpha", "beta", "ghost"]);
    let sum = |f: fn(&sitecim::coordinator::TenantReport) -> u64| {
        report.tenants.iter().map(f).sum::<u64>()
    };
    assert_eq!(report.requests, sum(|t| t.requests));
    assert_eq!(report.ingress.admitted, sum(|t| t.ingress.admitted));
    assert_eq!(report.ingress.offered(), sum(|t| t.ingress.offered()));
    assert_eq!(sum(|t| t.ingress.unknown_model), 1);
    assert!(report.engine.is_some() && report.exec.is_some());
    assert!(report.exec_queue_depth.is_some());

    let json = Json::parse(&report.to_string()).expect("report must be valid JSON");
    assert_eq!(json.get("requests").and_then(|j| j.as_f64()), Some(5.0));
    assert_eq!(json.get("tenants").and_then(|j| j.as_arr()).map(|a| a.len()), Some(3));
    assert!(json.get("engine").and_then(|j| j.get("hit_rate")).is_some());
    server.shutdown();
}
