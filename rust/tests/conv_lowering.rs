//! Workload-lowering conformance battery: true im2col conv lowering and
//! stepped recurrent execution on the functional engine.
//!
//! - Conv: the engine's GEMM over an `im2col_plane` must be bit-exact
//!   against both the tiled GEMM reference *and* the direct-convolution
//!   reference (which gathers taps in conv coordinates, never building
//!   the im2col plane) for every design, thread count and window shape
//!   — 1×1, 3×3 (pad), 5×5, and strided — including truncated output
//!   planes. The exact near-memory flavor must additionally equal the
//!   naive i32 convolution outright.
//! - Recurrent: `run_recurrent_resident` must reproduce the serial
//!   stepped reference bit-for-bit (hidden state threaded h_t → h_{t+1}
//!   through the deterministic ternary cell) with the per-gate GEMMs
//!   hitting the resident cache at exactly `(steps − 1) × tiles` after
//!   the cold step programs each tile once.

use sitecim::array::Design;
use sitecim::device::Tech;
use sitecim::dnn::{lower, ConvGeom, RecurrentSpec};
use sitecim::engine::tiling::reference_gemm;
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::util::rng::Rng;

/// Window shapes chosen to cover the suite's conv vocabulary at test
/// scale: pointwise, padded 3×3, large 5×5, and a strided downsampler.
fn geoms() -> Vec<ConvGeom> {
    vec![
        ConvGeom { in_hw: 6, ksize: 1, stride: 1, pad: 0, cin: 8, cout: 12 },
        ConvGeom { in_hw: 8, ksize: 3, stride: 1, pad: 1, cin: 4, cout: 10 },
        ConvGeom { in_hw: 9, ksize: 5, stride: 1, pad: 2, cin: 3, cout: 7 },
        ConvGeom { in_hw: 11, ksize: 3, stride: 2, pad: 1, cin: 5, cout: 9 },
    ]
}

#[test]
fn im2col_gemm_is_bit_exact_vs_direct_conv_across_designs_and_threads() {
    for (gi, g) in geoms().iter().enumerate() {
        let (m, k, n) = (g.out_hw() * g.out_hw(), g.patch_k(), g.cout);
        let mut rng = Rng::new(600 + gi as u64);
        let image = rng.ternary_vec(g.cin * g.in_hw * g.in_hw, 0.4);
        let w = rng.ternary_vec(k * n, 0.5);
        let x = lower::im2col_plane(&image, g, m);
        for design in Design::ALL {
            for threads in [1usize, 2, 4] {
                // 64×32 arrays force k-sharding (5×5 taps exceed one
                // array) and engage the CiM 16-row-group saturation.
                let engine = TernaryGemmEngine::new(
                    EngineConfig::new(design, Tech::Femfet3T)
                        .with_array_dims(64, 32)
                        .with_threads(threads),
                );
                let grid = engine.grid(k, n);
                let flavor = design.flavor();
                let direct = lower::conv_ref_direct(&image, &w, g, m, &grid, flavor);
                let tiled = reference_gemm(&x, &w, m, &grid, flavor);
                assert_eq!(
                    direct, tiled,
                    "geom {gi} {design:?}: direct conv vs tiled GEMM reference"
                );
                let got = engine.gemm(&x, &w, m, k, n).unwrap();
                assert_eq!(got, direct, "geom {gi} {design:?} threads={threads}");
                if flavor.is_none() {
                    assert_eq!(
                        got,
                        lower::conv_ref_naive(&image, &w, g, m),
                        "geom {gi}: exact flavor must equal the naive convolution"
                    );
                }
                // Truncated output plane: the first windows of the full
                // plane, in the same raster order.
                let m_run = (m / 2).max(1);
                let x_run = lower::im2col_plane(&image, g, m_run);
                assert_eq!(
                    x_run[..],
                    x[..m_run * k],
                    "geom {gi}: truncated plane must be a prefix of the full plane"
                );
                assert_eq!(
                    lower::conv_ref_direct(&image, &w, g, m_run, &grid, flavor),
                    direct[..m_run * n],
                    "geom {gi} {design:?}: truncated direct conv must be a prefix"
                );
            }
        }
    }
}

#[test]
fn stepped_recurrent_resident_matches_serial_reference_with_pinned_hits() {
    let specs = [
        ("lstm", RecurrentSpec { steps: 6, input: 24, hidden: 16, gates: 4 }),
        ("gru", RecurrentSpec { steps: 5, input: 20, hidden: 12, gates: 3 }),
    ];
    for (name, spec) in specs {
        let (k, n) = (spec.input + spec.hidden, spec.gates * spec.hidden);
        let mut rng = Rng::new(700);
        let xs = rng.ternary_vec(spec.steps * spec.input, 0.3);
        let w = rng.ternary_vec(k * n, 0.5);
        for design in Design::ALL {
            for threads in [1usize, 4] {
                let engine = TernaryGemmEngine::new(
                    EngineConfig::new(design, Tech::Femfet3T)
                        .with_array_dims(64, 32)
                        .with_capacity_words(4 * 64 * 32)
                        .with_threads(threads),
                );
                let grid = engine.grid(k, n);
                let tiles = grid.n_tiles_total() as u64;
                assert!(engine.pool_arrays() as u64 >= tiles, "all tiles must fit resident");
                let want = lower::reference_recurrent_trace(
                    &xs,
                    &w,
                    &spec,
                    &grid,
                    design.flavor(),
                    spec.steps,
                );
                let id = engine.register_weight(&w, k, n).unwrap();
                let got = lower::run_recurrent_resident(&engine, id, &xs, &spec, spec.steps);
                assert_eq!(got, want, "{name} {design:?} threads={threads}: stepped trace");
                let s = engine.stats();
                assert_eq!(s.misses, tiles, "{name} {design:?}: cold step programs each tile");
                assert_eq!(
                    s.hits,
                    (spec.steps as u64 - 1) * tiles,
                    "{name} {design:?}: every later step must hit resident weights"
                );
                assert_eq!(s.evictions, 0, "{name} {design:?}");
                assert_eq!(s.gemms, spec.steps as u64, "{name}: one GEMM call per step");
            }
        }
        // A truncated unroll is the exact prefix of the full trace: the
        // hidden state threads causally, so earlier steps cannot depend
        // on later ones.
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(Design::Cim1, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_capacity_words(4 * 64 * 32)
                .with_threads(1),
        );
        let grid = engine.grid(k, n);
        let full =
            lower::reference_recurrent_trace(&xs, &w, &spec, &grid, Design::Cim1.flavor(), spec.steps);
        let id = engine.register_weight(&w, k, n).unwrap();
        let got = lower::run_recurrent_resident(&engine, id, &xs, &spec, 3);
        assert_eq!(got.len(), 3, "{name}: truncated unroll runs 3 steps");
        assert_eq!(got[..], full[..3], "{name}: truncated trace is a prefix of the full trace");
    }
}
