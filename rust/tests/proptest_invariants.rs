//! Property tests (hand-rolled driver, util::prop) on the core
//! invariants of the SiTe CiM semantics.
use sitecim::array::encoding::{decode_output, rbl_current_cim2, rbl_pulldown_cim1};
use sitecim::array::mac::{dot_exact, dot_ref, Flavor, GROUP_ROWS, SAT};
use sitecim::array::TernaryStorage;
use sitecim::util::prop::{check, Config};
use sitecim::util::rng::Rng;

fn storage_and_inputs(rng: &mut Rng, groups: usize, cols: usize, pz: f64) -> (TernaryStorage, Vec<i8>) {
    let rows = groups.max(1) * GROUP_ROWS;
    let mut s = TernaryStorage::new(rows, cols);
    s.write_matrix(&rng.ternary_vec(rows * cols, pz));
    let inputs = rng.ternary_vec(rows, pz);
    (s, inputs)
}

#[test]
fn prop_group_outputs_bounded_by_sat() {
    check(
        &Config { cases: 128, ..Default::default() },
        |rng, size| { let pz = rng.f64(); storage_and_inputs(rng, 1 + size % 4, 8, pz) },
        |(s, inputs)| {
            for flavor in [Flavor::Cim1, Flavor::Cim2] {
                let groups = (s.n_rows() / GROUP_ROWS) as i32;
                for &o in &dot_ref(s, inputs, flavor) {
                    if o.abs() > groups * SAT as i32 {
                        return Err(format!("output {o} exceeds {}", groups * SAT as i32));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_saturating_never_overshoots_exact() {
    // |saturated| <= |exact| is NOT generally true (sign mixes), but the
    // saturated result must never move further from zero than exact when
    // exact is within ±8 per group — i.e. when nothing clamps, equality.
    check(
        &Config { cases: 96, ..Default::default() },
        |rng, size| storage_and_inputs(rng, 1 + size % 3, 6, 0.75),
        |(s, inputs)| {
            // Sparse enough that counts stay < 8 per group → exact match.
            let sat = dot_ref(s, inputs, Flavor::Cim1);
            let exact = dot_exact(s, inputs);
            let mut max_ab = 0;
            for cycle in 0..s.n_rows() / GROUP_ROWS {
                for col in 0..s.n_cols() {
                    let rows = Flavor::Cim1.group_rows(s.n_rows(), cycle);
                    let (mut a, mut b) = (0, 0);
                    for &r in &rows {
                        match inputs[r] as i32 * s.read(r, col) as i32 {
                            1 => a += 1,
                            -1 => b += 1,
                            _ => {}
                        }
                    }
                    max_ab = max_ab.max(a.max(b));
                }
            }
            if max_ab <= 8 {
                for (o, e) in sat.iter().zip(&exact) {
                    if *o as i64 != *e {
                        return Err(format!("unclamped case diverged: {o} vs {e}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_linearity_in_input_negation() {
    // O(-I, W) = -O(I, W) for both flavors (the cross-coupling symmetry).
    check(
        &Config { cases: 96, ..Default::default() },
        |rng, size| storage_and_inputs(rng, 1 + size % 3, 8, 0.5),
        |(s, inputs)| {
            let neg: Vec<i8> = inputs.iter().map(|&i| -i).collect();
            for flavor in [Flavor::Cim1, Flavor::Cim2] {
                let a = dot_ref(s, inputs, flavor);
                let b = dot_ref(s, &neg, flavor);
                if a.iter().zip(&b).any(|(x, y)| *x != -*y) {
                    return Err(format!("{flavor:?} not odd in I"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cell_truth_tables_exhaustive() {
    for i in [-1i8, 0, 1] {
        for w in [-1i8, 0, 1] {
            let (r1, r2) = rbl_pulldown_cim1(i, w);
            assert_eq!(decode_output(r1, r2), i * w);
            let (c1, c2) = rbl_current_cim2(i, w);
            assert_eq!(decode_output(c1, c2), i * w);
        }
    }
}

#[test]
fn prop_storage_roundtrip_random() {
    check(
        &Config { cases: 64, ..Default::default() },
        |rng, size| {
            let cols = 1 + size % 16;
            let rows = 16 * (1 + size % 4);
            let w = rng.ternary_vec(rows * cols, 0.4);
            (rows, cols, w)
        },
        |(rows, cols, w)| {
            let mut s = TernaryStorage::new(*rows, *cols);
            s.write_matrix(w);
            for r in 0..*rows {
                for c in 0..*cols {
                    if s.read(r, c) != w[r * cols + c] {
                        return Err(format!("roundtrip failed at ({r},{c})"));
                    }
                }
            }
            Ok(())
        },
    );
}
