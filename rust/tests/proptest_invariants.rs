//! Property tests (hand-rolled driver, util::prop) on the core
//! invariants of the SiTe CiM semantics — array/MAC laws plus the
//! engine's Arc-operand invariants (zero-copy surface ≡ slice surface ≡
//! sharded reference, and per-worker scratch reuse never leaks state
//! across jobs).
use std::sync::Arc;

use sitecim::array::encoding::{decode_output, rbl_current_cim2, rbl_pulldown_cim1};
use sitecim::array::mac::{dot_exact, dot_ref, Flavor, GROUP_ROWS, SAT};
use sitecim::array::{Design, TernaryStorage};
use sitecim::device::Tech;
use sitecim::engine::tiling::reference_gemm_sharded;
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::util::prop::{check, eq, Config};
use sitecim::util::rng::Rng;

fn storage_and_inputs(rng: &mut Rng, groups: usize, cols: usize, pz: f64) -> (TernaryStorage, Vec<i8>) {
    let rows = groups.max(1) * GROUP_ROWS;
    let mut s = TernaryStorage::new(rows, cols);
    s.write_matrix(&rng.ternary_vec(rows * cols, pz));
    let inputs = rng.ternary_vec(rows, pz);
    (s, inputs)
}

#[test]
fn prop_group_outputs_bounded_by_sat() {
    check(
        &Config { cases: 128, ..Default::default() },
        |rng, size| { let pz = rng.f64(); storage_and_inputs(rng, 1 + size % 4, 8, pz) },
        |(s, inputs)| {
            for flavor in [Flavor::Cim1, Flavor::Cim2] {
                let groups = (s.n_rows() / GROUP_ROWS) as i32;
                for &o in &dot_ref(s, inputs, flavor) {
                    if o.abs() > groups * SAT as i32 {
                        return Err(format!("output {o} exceeds {}", groups * SAT as i32));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_saturating_never_overshoots_exact() {
    // |saturated| <= |exact| is NOT generally true (sign mixes), but the
    // saturated result must never move further from zero than exact when
    // exact is within ±8 per group — i.e. when nothing clamps, equality.
    check(
        &Config { cases: 96, ..Default::default() },
        |rng, size| storage_and_inputs(rng, 1 + size % 3, 6, 0.75),
        |(s, inputs)| {
            // Sparse enough that counts stay < 8 per group → exact match.
            let sat = dot_ref(s, inputs, Flavor::Cim1);
            let exact = dot_exact(s, inputs);
            let mut max_ab = 0;
            for cycle in 0..s.n_rows() / GROUP_ROWS {
                for col in 0..s.n_cols() {
                    let rows = Flavor::Cim1.group_rows(s.n_rows(), cycle);
                    let (mut a, mut b) = (0, 0);
                    for &r in &rows {
                        match inputs[r] as i32 * s.read(r, col) as i32 {
                            1 => a += 1,
                            -1 => b += 1,
                            _ => {}
                        }
                    }
                    max_ab = max_ab.max(a.max(b));
                }
            }
            if max_ab <= 8 {
                for (o, e) in sat.iter().zip(&exact) {
                    if *o as i64 != *e {
                        return Err(format!("unclamped case diverged: {o} vs {e}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_linearity_in_input_negation() {
    // O(-I, W) = -O(I, W) for both flavors (the cross-coupling symmetry).
    check(
        &Config { cases: 96, ..Default::default() },
        |rng, size| storage_and_inputs(rng, 1 + size % 3, 8, 0.5),
        |(s, inputs)| {
            let neg: Vec<i8> = inputs.iter().map(|&i| -i).collect();
            for flavor in [Flavor::Cim1, Flavor::Cim2] {
                let a = dot_ref(s, inputs, flavor);
                let b = dot_ref(s, &neg, flavor);
                if a.iter().zip(&b).any(|(x, y)| *x != -*y) {
                    return Err(format!("{flavor:?} not odd in I"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cell_truth_tables_exhaustive() {
    for i in [-1i8, 0, 1] {
        for w in [-1i8, 0, 1] {
            let (r1, r2) = rbl_pulldown_cim1(i, w);
            assert_eq!(decode_output(r1, r2), i * w);
            let (c1, c2) = rbl_current_cim2(i, w);
            assert_eq!(decode_output(c1, c2), i * w);
        }
    }
}

#[test]
fn prop_gemm_arc_equals_slice_equals_sharded_reference() {
    // Random shapes × designs × thread counts: the zero-copy Arc
    // surface, the slice surface and the pure-integer sharded reference
    // agree bit-for-bit, streaming and resident alike.
    check(
        &Config { cases: 18, seed: 0xA2C0_5EED, max_size: 48 },
        |rng, size| {
            let m = 1 + rng.below(3) as usize;
            let k = 16 + 4 * size + 4 * rng.below(16) as usize; // ragged, ≥ 16
            let n = 8 + size + rng.below(40) as usize;
            let threads = 1 + rng.below(3) as usize;
            let design = Design::ALL[rng.below(3) as usize];
            let x = rng.ternary_vec(m * k, 0.5);
            let w = rng.ternary_vec(k * n, 0.5);
            (design, threads, m, k, n, x, w)
        },
        |(design, threads, m, k, n, x, w)| {
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(*design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_pool(4)
                    .with_threads(*threads),
            );
            let want =
                reference_gemm_sharded(x, w, *m, &engine.grid(*k, *n), 64, 32, design.flavor());
            let via_slice = engine.gemm(x, w, *m, *k, *n).map_err(|e| e.to_string())?;
            eq(via_slice, want.clone())?;
            let ax: Arc<[i8]> = x.clone().into();
            let aw: Arc<[i8]> = w.clone().into();
            let via_arc = engine
                .gemm_arc(Arc::clone(&ax), Arc::clone(&aw), *m, *k, *n)
                .map_err(|e| e.to_string())?;
            eq(via_arc, want.clone())?;
            let id = engine.register_weight_arc(aw, *k, *n).map_err(|e| e.to_string())?;
            let via_resident = engine.gemm_resident_arc(id, ax, *m).map_err(|e| e.to_string())?;
            eq(via_resident, want)
        },
    );
}

#[test]
fn prop_scratch_reuse_never_leaks_across_jobs() {
    // Back-to-back jobs of different shapes through one long-lived
    // engine — whose workers reuse monotonically-grown scratch buffers —
    // give exactly the results of a fresh engine per job: no stale
    // weight image, input slice or partial sum survives a shape change.
    check(
        &Config { cases: 10, seed: 0x5C4A_7C11, max_size: 40 },
        |rng, size| {
            let design = Design::ALL[rng.below(3) as usize];
            let mut jobs = Vec::new();
            for _ in 0..4 {
                let m = 1 + rng.below(2) as usize;
                let k = 16 + size + rng.below(130) as usize;
                let n = 4 + rng.below(70) as usize;
                let x = rng.ternary_vec(m * k, 0.5);
                let w = rng.ternary_vec(k * n, 0.5);
                jobs.push((m, k, n, x, w));
            }
            (design, jobs)
        },
        |(design, jobs)| {
            let cfg = EngineConfig::new(*design, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_pool(3)
                .with_threads(2);
            let shared = TernaryGemmEngine::new(cfg.clone());
            for (m, k, n, x, w) in jobs {
                let fresh = TernaryGemmEngine::new(cfg.clone());
                let a = shared.gemm(x, w, *m, *k, *n).map_err(|e| e.to_string())?;
                let b = fresh.gemm(x, w, *m, *k, *n).map_err(|e| e.to_string())?;
                eq(a, b.clone())?;
                // Resident passes reuse the same scratch too.
                let id = shared.register_weight(w, *k, *n).map_err(|e| e.to_string())?;
                let r = shared.gemm_resident(id, x, *m).map_err(|e| e.to_string())?;
                eq(r, b)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_storage_roundtrip_random() {
    check(
        &Config { cases: 64, ..Default::default() },
        |rng, size| {
            let cols = 1 + size % 16;
            let rows = 16 * (1 + size % 4);
            let w = rng.ternary_vec(rows * cols, 0.4);
            (rows, cols, w)
        },
        |(rows, cols, w)| {
            let mut s = TernaryStorage::new(*rows, *cols);
            s.write_matrix(w);
            for r in 0..*rows {
                for c in 0..*cols {
                    if s.read(r, c) != w[r * cols + c] {
                        return Err(format!("roundtrip failed at ({r},{c})"));
                    }
                }
            }
            Ok(())
        },
    );
}
