//! Integration: continuous cross-request batching into the GEMM M
//! dimension.
//!
//! The contract under test is the batcher's bit-exactness premise: GEMM
//! rows are independent, so a merged `M × K` plane run once through the
//! layer pipeline must equal the per-request serial executions row for
//! row — across designs, thread counts, and M far above the manifest
//! `batch`. On top sit the serving-side semantics: `max_batch_rows`
//! bounds every flush, and shutdown still answers every merged
//! in-flight request.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use sitecim::array::mac::Flavor;
use sitecim::array::Design;
use sitecim::coordinator::{BatchPolicy, EngineBackend, InferenceBackend, Server, ServerConfig};
use sitecim::device::Tech;
use sitecim::dnn::ternary::ternarize_acts_i32;
use sitecim::engine::tiling::{reference_gemm, TileGrid};
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::runtime::Manifest;
use sitecim::util::rng::Rng;

/// A unique temp artifacts dir per test (tests run in parallel in one
/// process, so the tag must differ per call site).
fn synth_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sitecim-cbatch-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn trit_bytes(trits: &[i8]) -> Vec<u8> {
    trits.iter().map(|&t| t as u8).collect()
}

/// Write a servable synthetic MLP: random ternary weights for each
/// `dims` transition, activation thresholds between layers, and a tiny
/// test set.
fn write_synth_artifacts(dir: &Path, dims: &[usize], batch: usize, seed: u64) {
    assert!(dims.len() >= 2);
    let mut rng = Rng::new(seed);
    let mut weights_json = String::new();
    for i in 0..dims.len() - 1 {
        let (k, n) = (dims[i], dims[i + 1]);
        let w = rng.ternary_vec(k * n, 0.5);
        std::fs::write(dir.join(format!("w{i}.bin")), trit_bytes(&w)).unwrap();
        if i > 0 {
            weights_json.push_str(", ");
        }
        weights_json.push_str(&format!("{{\"file\": \"w{i}.bin\", \"shape\": [{k}, {n}]}}"));
    }
    let in_dim = dims[0];
    let test_n = 4usize;
    let x = rng.ternary_vec(test_n * in_dim, 0.5);
    std::fs::write(dir.join("test_x.bin"), trit_bytes(&x)).unwrap();
    std::fs::write(dir.join("test_y.bin"), vec![0u8; test_n]).unwrap();
    let thresholds = vec!["0.5"; dims.len() - 2].join(", ");
    let dims_json = dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ");
    let manifest = format!(
        "{{\n  \"batch\": {batch},\n  \"dims\": [{dims_json}],\n  \"act_thresholds\": [{thresholds}],\n  \"kernel_shape\": [8, 16, 16],\n  \"files\": {{}},\n  \"weights\": [{weights_json}],\n  \"scales\": [1.0],\n  \"test_set\": {{\"x\": \"test_x.bin\", \"y\": \"test_y.bin\", \"n\": {test_n}, \"in_dim\": {in_dim}}},\n  \"accuracy\": {{}}\n}}\n"
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
}

/// The reference forward pass for `Design::Cim1` serving:
/// `reference_gemm` over 256×256 tiles + the recorded thresholds.
fn reference_forward(manifest: &Manifest, input: &[i8]) -> Vec<f32> {
    let mut h = input.to_vec();
    for i in 0..manifest.weights.len() {
        let (w, (k, n)) = manifest.load_weight(i).unwrap();
        let y = reference_gemm(&h, &w, 1, &TileGrid::new(k, n, 256, 256), Some(Flavor::Cim1));
        if i + 1 < manifest.weights.len() {
            h = ternarize_acts_i32(&y, manifest.act_thresholds[i]);
        } else {
            return y.iter().map(|&v| v as f32).collect();
        }
    }
    unreachable!()
}

#[test]
fn merged_plane_is_bit_exact_vs_serial_per_request_across_designs_and_threads() {
    // The tentpole's correctness core: one merged M-plane (M = 12, 3×
    // the manifest batch) through the pipeline equals 12 serial
    // single-row executions, for every design and thread count.
    let dir = synth_dir("bitexact");
    write_synth_artifacts(&dir, &[48, 32, 8], 4, 20);
    let manifest = Manifest::load(&dir).unwrap();
    let rows = 12usize;
    let mut rng = Rng::new(21);
    let inputs: Vec<Vec<i8>> = (0..rows).map(|_| rng.ternary_vec(48, 0.5)).collect();
    let plane: Arc<[i8]> = inputs.concat().into();
    for design in Design::ALL {
        for threads in [1usize, 4] {
            let b = EngineBackend::load(&manifest, design, Tech::Femfet3T, threads, None).unwrap();
            let mut serial = Vec::with_capacity(rows * 8);
            for input in &inputs {
                serial.extend(b.run_batch(input, 1).unwrap());
            }
            let merged = b.run_batch_arc(Arc::clone(&plane), rows).unwrap();
            assert_eq!(merged, serial, "{design:?} threads={threads}");
        }
    }
}

#[test]
fn tall_m_resident_gemm_grows_worker_scratch_and_stays_exact() {
    // Arbitrary-M through `gemm_resident_arc` directly: the per-stripe
    // accumulators and `WorkerScratch` buffers must grow for M far above
    // any earlier call's batch (the same engine first serves M = 1, so
    // scratch starts small and must expand, not truncate).
    let mut rng = Rng::new(22);
    for design in Design::ALL {
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(design, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_pool(4)
                .with_threads(4),
        );
        let (k, n) = (150usize, 60usize);
        let w = rng.ternary_vec(k * n, 0.5);
        let id = engine.register_weight(&w, k, n).unwrap();
        for m in [1usize, 48] {
            let x: Arc<[i8]> = rng.ternary_vec(m * k, 0.5).into();
            let want = reference_gemm(&x, &w, m, &engine.grid(k, n), design.flavor());
            let got = engine.gemm_resident_arc(id, Arc::clone(&x), m).unwrap();
            assert_eq!(got, want, "{design:?} m={m}");
        }
        let s = engine.exec_stats();
        assert_eq!(s.submitted, s.executed, "{design:?}: queues drained");
        assert_eq!(s.panics, 0, "{design:?}");
    }
}

#[test]
fn merged_serving_matches_reference_forward_and_batches_above_manifest_batch() {
    // Server-level: one worker, a generous deadline, and 24 queued
    // requests against a manifest batch of 4 — the continuous batcher
    // must form flushes taller than the manifest batch (up to
    // max_batch_rows = 16) and every reply must equal the per-request
    // reference forward.
    let dir = synth_dir("serve");
    write_synth_artifacts(&dir, &[32, 16, 8], 4, 23);
    let mut cfg = ServerConfig::new(dir.clone()).with_engine_backend();
    cfg.n_workers = 1;
    cfg.engine_threads = 2;
    // The wide deadline makes the merge deterministic even on a loaded
    // CI machine: the first flush gathers rows until the 16-row cap.
    cfg.policy = BatchPolicy {
        max_batch: 4,
        max_batch_rows: 16,
        max_wait: Duration::from_millis(400),
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let mut rng = Rng::new(24);
    let mut pending = Vec::new();
    for _ in 0..24 {
        let input = rng.ternary_vec(32, 0.5);
        let want = reference_forward(&manifest, &input);
        pending.push((want, server.infer_async(input).unwrap()));
    }
    for (want, rx) in pending {
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.logits, want, "merged serving must match the reference forward");
    }
    assert_eq!(server.metrics.requests.load(Ordering::Relaxed), 24);
    assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);
    let rows = server.metrics.batch_rows_summary();
    assert!(rows.n > 0, "flush sizes were recorded");
    assert!(rows.max <= 16.0, "no flush exceeds max_batch_rows: {rows:?}");
    assert!(
        rows.max > 4.0,
        "a single busy worker must merge above the manifest batch: {rows:?}"
    );
    server.shutdown();
}

#[test]
fn max_batch_rows_bounds_every_flush() {
    // 10 pre-queued requests against max_batch_rows = 3 on one worker:
    // at least ceil(10/3) = 4 flushes, none taller than 3 rows.
    let dir = synth_dir("rowcap");
    write_synth_artifacts(&dir, &[32, 16, 8], 8, 25);
    let mut cfg = ServerConfig::new(dir).with_engine_backend();
    cfg.n_workers = 1;
    cfg.engine_threads = 1;
    cfg.policy = BatchPolicy {
        max_batch: 8,
        max_batch_rows: 3,
        max_wait: Duration::from_millis(10),
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let mut rng = Rng::new(26);
    let pending: Vec<_> =
        (0..10).map(|_| server.infer_async(rng.ternary_vec(32, 0.5)).unwrap()).collect();
    for rx in pending {
        assert!(rx.recv().unwrap().is_ok());
    }
    let rows = server.metrics.batch_rows_summary();
    assert!(rows.max <= 3.0, "row cap enforced per flush: {rows:?}");
    assert!(server.metrics.batches.load(Ordering::Relaxed) >= 4);
    server.shutdown();
}

#[test]
fn shutdown_drains_merged_in_flight_replies() {
    // Close the queue with a pile of unanswered requests: the merged
    // formers must flush everything already submitted and answer every
    // reply channel before the workers exit.
    let dir = synth_dir("mergeddrain");
    write_synth_artifacts(&dir, &[32, 16, 8], 4, 27);
    let mut cfg = ServerConfig::new(dir).with_engine_backend();
    cfg.n_workers = 2;
    cfg.engine_threads = 2;
    cfg.policy = BatchPolicy {
        max_batch: 4,
        max_batch_rows: 8,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let mut rng = Rng::new(28);
    let pending: Vec<_> =
        (0..30).map(|_| server.infer_async(rng.ternary_vec(32, 0.5)).unwrap()).collect();
    server.shutdown();
    for rx in pending {
        let reply = rx.recv().expect("reply delivered before shutdown completed");
        assert!(reply.is_ok());
    }
}
