//! Integration: the array-level metric models reproduce the paper's
//! Fig 9 / Fig 11 / §V.3 headline ratios (bands per DESIGN.md §5).
use sitecim::array::area::{cell_overhead, macro_overhead_ratio, Design};
use sitecim::array::metrics::{all_designs, ArrayGeom};
use sitecim::device::{PeriphParams, Tech, TechParams};

#[test]
fn headline_array_ratios_reproduced() {
    let pp = PeriphParams::default_45nm();
    for tech in Tech::ALL {
        let p = TechParams::new(tech);
        let [nm, c1, c2] = all_designs(&p, &pp, ArrayGeom::default());
        // "up to 88% lower CiM latency and 78% CiM energy savings"
        let lat_red1 = 1.0 - c1.mac.latency / nm.mac.latency;
        let e_sav1 = 1.0 - c1.mac.energy / nm.mac.energy;
        assert!(lat_red1 > 0.8, "{}: {lat_red1}", tech.name());
        assert!(e_sav1 > 0.6, "{}: {e_sav1}", tech.name());
        // CiM II in between.
        assert!(c2.mac.latency > c1.mac.latency && c2.mac.latency < nm.mac.latency);
    }
}

#[test]
fn area_ratios_reproduced() {
    let pp = PeriphParams::default_45nm();
    let expect = [(Tech::Sram8T, 0.18), (Tech::Edram3T, 0.34), (Tech::Femfet3T, 0.34)];
    for (tech, c1) in expect {
        let p = TechParams::new(tech);
        assert!((cell_overhead(&p, Design::Cim1) - c1).abs() < 0.04, "{}", tech.name());
        assert!((cell_overhead(&p, Design::Cim2) - 0.0625).abs() < 0.01);
        assert!(macro_overhead_ratio(&p, &pp, Design::Cim1) > macro_overhead_ratio(&p, &pp, Design::Cim2));
    }
}

#[test]
fn geometry_scaling_is_monotone() {
    // Bigger arrays cost more per op, smaller cost less — sanity of the
    // parameterized geometry (ablation support).
    let pp = PeriphParams::default_45nm();
    let p = TechParams::new(Tech::Sram8T);
    let small = all_designs(&p, &pp, ArrayGeom { n_rows: 128, n_cols: 128, n_active: 16 })[1];
    let big = all_designs(&p, &pp, ArrayGeom { n_rows: 256, n_cols: 256, n_active: 16 })[1];
    assert!(big.mac.energy > small.mac.energy);
    assert!(big.read.latency > small.read.latency);
}
