//! Eviction-pressure conformance battery: capacity-bounded resident
//! pools smaller than the working set must stay bit-exact vs the
//! `dot_ref` shard composition across all three designs and thread
//! counts, the second-chance (CLOCK) policy's cyclic-sweep counters
//! must match the closed-form expectation — capacity-proportional hits
//! where the old LRU policy measured exactly zero — sub-array packing /
//! cross-array sharding must be exact under the same pressure, and the
//! analytic `Residency::Bounded` charge must equal the engine's
//! *measured* steady-state write rows exactly across a capacity sweep —
//! including the packing-aware replayed model on conv-shaped shard
//! mixes that shelf-pack several regions per array.

use sitecim::arch::{
    packed_sweep_model, sweep_miss_fraction, sweep_miss_fraction_packed,
    sweep_miss_fraction_weighted, AccelConfig, Accelerator, Residency,
};
use sitecim::array::Design;
use sitecim::device::Tech;
use sitecim::dnn::{Layer, Network};
use sitecim::engine::tiling::{reference_gemm, TileGrid};
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::util::rng::Rng;

#[test]
fn bounded_pool_smaller_than_working_set_stays_bit_exact() {
    // 300×90 on 64×32 arrays = 15 shards; a 2-array budget serves the
    // whole set under constant eviction, for every design and thread
    // count, without a single bit of drift.
    for design in Design::ALL {
        for threads in [1usize, 4] {
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_capacity_words(2 * 64 * 32)
                    .with_threads(threads),
            );
            assert_eq!(engine.pool_arrays(), 2);
            let mut rng = Rng::new(200 + threads as u64);
            let (m, k, n) = (2usize, 300usize, 90usize);
            let x = rng.ternary_vec(m * k, 0.5);
            let w = rng.ternary_vec(k * n, 0.5);
            let want = reference_gemm(&x, &w, m, &engine.grid(k, n), design.flavor());
            let id = engine.register_weight(&w, k, n).unwrap();
            for pass in 0..3 {
                assert_eq!(
                    engine.gemm_resident(id, &x, m).unwrap(),
                    want,
                    "{design:?} threads={threads} pass={pass}"
                );
            }
            let s = engine.stats();
            assert!(s.misses > 0, "{design:?}: an over-subscribed pool must miss");
            assert!(s.evictions > 0, "{design:?}: an over-subscribed pool must evict");
        }
    }
}

#[test]
fn streaming_interleaved_with_pressured_resident_stays_bit_exact() {
    // A streaming GEMM on a different weight trashes pool arrays between
    // resident passes; the per-region content tags must force exactly
    // the re-programming needed to keep both bit-exact.
    for design in Design::ALL {
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(design, Tech::Sram8T)
                .with_array_dims(64, 32)
                .with_capacity_words(2 * 64 * 32)
                .with_threads(2),
        );
        let mut rng = Rng::new(300);
        let (m, k, n) = (2usize, 200usize, 60usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w1 = rng.ternary_vec(k * n, 0.5);
        let w2 = rng.ternary_vec(k * n, 0.5);
        let grid = engine.grid(k, n);
        let want1 = reference_gemm(&x, &w1, m, &grid, design.flavor());
        let want2 = reference_gemm(&x, &w2, m, &grid, design.flavor());
        let id = engine.register_weight(&w1, k, n).unwrap();
        for pass in 0..3 {
            assert_eq!(engine.gemm_resident(id, &x, m).unwrap(), want1, "{design:?} p{pass}");
            assert_eq!(engine.gemm(&x, &w2, m, k, n).unwrap(), want2, "{design:?} p{pass}");
        }
        assert_eq!(engine.gemm_resident(id, &x, m).unwrap(), want1, "{design:?} final");
    }
}

#[test]
fn second_chance_sweep_counters_match_closed_form() {
    // Uniform full-array tiles, single thread: a cyclic sweep of W tiles
    // through a C-array pool (W > C) is the classic LRU pathology —
    // under LRU this measured hits = 0 at *any* capacity. The
    // second-chance policy keeps C − 1 proven regions resident while the
    // probation slot churns through the sweep. Closed form:
    //
    //   pass 1:        hits 0,      misses W,          evictions W − C
    //   passes 2..P:   hits C − 1,  misses W − C + 1,  evictions W − C + 1
    //
    // so over P passes: hits = (P−1)(C−1), misses = W + (P−1)(W−C+1),
    // evictions = misses − C (the first C placements land in free
    // arrays; uniform tiles evict exactly one region per later miss),
    // tiles programmed = misses.
    let (w_tiles, cap, passes) = (5u64, 3u64, 4u64);
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim1, Tech::Femfet3T)
            .with_array_dims(64, 32)
            .with_capacity_words(cap * 64 * 32)
            .with_threads(1),
    );
    assert_eq!(engine.pool_arrays(), cap as usize);
    let mut rng = Rng::new(400);
    let (m, k, n) = (1usize, w_tiles as usize * 64, 32usize);
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    let grid = engine.grid(k, n);
    assert_eq!(grid.n_tiles_total() as u64, w_tiles);
    let want = reference_gemm(&x, &w, m, &grid, Design::Cim1.flavor());
    let id = engine.register_weight(&w, k, n).unwrap();
    for pass in 0..passes {
        assert_eq!(engine.gemm_resident(id, &x, m).unwrap(), want, "pass {pass}");
    }
    let s = engine.stats();
    let hits = (passes - 1) * (cap - 1);
    let misses = w_tiles + (passes - 1) * (w_tiles - cap + 1);
    assert_eq!(s.hits, hits, "capacity-proportional steady-state hits");
    assert_eq!(s.misses, misses);
    assert_eq!(s.evictions, misses - cap);
    assert_eq!(s.tiles, misses, "every miss re-programs");
    assert_eq!(s.write_rows, misses * 64);
    // The rate the capacity bench records: (P−1)(C−1) / P·W.
    let want_rate = hits as f64 / (passes * w_tiles) as f64;
    assert!((s.hit_rate() - want_rate).abs() < 1e-12, "{} vs {want_rate}", s.hit_rate());
}

#[test]
fn pool_at_working_set_size_serves_all_hit_after_warmup() {
    // The complementary closed form: capacity = working set → cold
    // misses once, then pure hits, zero evictions.
    let (w_tiles, passes) = (5u64, 3u64);
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim2, Tech::Femfet3T)
            .with_array_dims(64, 32)
            .with_capacity_words(w_tiles * 64 * 32)
            .with_threads(1),
    );
    let mut rng = Rng::new(401);
    let (m, k, n) = (1usize, w_tiles as usize * 64, 32usize);
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    let want = reference_gemm(&x, &w, m, &engine.grid(k, n), Design::Cim2.flavor());
    let id = engine.register_weight(&w, k, n).unwrap();
    for _ in 0..passes {
        assert_eq!(engine.gemm_resident(id, &x, m).unwrap(), want);
    }
    let s = engine.stats();
    assert_eq!(s.misses, w_tiles);
    assert_eq!(s.hits, (passes - 1) * w_tiles);
    assert_eq!(s.evictions, 0);
    assert_eq!(s.tiles, w_tiles, "fully-resident set programmed exactly once");
    let snap_rate = s.hit_rate();
    let want_rate = (passes - 1) as f64 / passes as f64;
    assert!((snap_rate - want_rate).abs() < 1e-12, "{snap_rate} vs {want_rate}");
}

#[test]
fn bounded_analytic_charge_matches_measured_sweep_write_rows() {
    // The analytic `Residency::Bounded` model must equal the engine's
    // *measured* steady-state programming on the cyclic-sweep workload:
    // W uniform full-array tiles through a C-array pool re-program
    // W − C + 1 tiles per pass (the closed form was re-verified in a
    // Python CLOCK simulation, per repo convention, and is pinned by
    // `second_chance_sweep_counters_match_closed_form` above), so the
    // accelerator's per-inference write charge — write_rows ×
    // (W − C + 1)/W — equals `write_charge(measured rows)` exactly.
    // C sweeps W/4 ..= W; W and the 256-row tiles keep every fraction
    // exactly representable, so the assertions are `==`, not ≈.
    let w_tiles = 8u64;
    let (m, k, n) = (1usize, w_tiles as usize * 256, 256usize);
    let accel = Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, Design::Cim1));
    let net = Network { name: "sweep".into(), layers: vec![Layer::linear("fc", m, k, n)] };
    assert_eq!(accel.arrays_packed(&net), w_tiles, "uniform full tiles: no packing");
    let streaming = accel.run_with_residency(&net, Residency::Streaming);
    let mut rng = Rng::new(500);
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    for cap in w_tiles / 4..=w_tiles {
        // Measured: steady-state per-pass write rows on the real engine
        // (256×256 arrays — the accelerator's own geometry — with one
        // worker for the deterministic placement order).
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(Design::Cim1, Tech::Femfet3T)
                .with_capacity_words(cap * 256 * 256)
                .with_threads(1),
        );
        assert_eq!(engine.pool_arrays(), cap as usize);
        let id = engine.register_weight(&w, k, n).unwrap();
        engine.gemm_resident(id, &x, m).unwrap(); // cold pass
        engine.gemm_resident(id, &x, m).unwrap(); // reach steady state
        let before = engine.stats();
        engine.gemm_resident(id, &x, m).unwrap(); // one steady pass
        let measured = engine.stats().since(&before).write_rows;
        let want_rows = if cap >= w_tiles { 0 } else { (w_tiles - cap + 1) * 256 };
        assert_eq!(measured, want_rows, "cap {cap}: steady-state sweep misses");

        // Analytic: the bounded charge equals the accelerator's write
        // charge for exactly those measured rows.
        let bounded = accel.run_with_residency(
            &net,
            Residency::Bounded { capacity_words: cap * 256 * 256, inferences: 0 },
        );
        let frac = sweep_miss_fraction(w_tiles, cap);
        assert_eq!(frac, measured as f64 / (w_tiles * 256) as f64, "cap {cap}: miss fraction");
        let (want_lat, want_energy) = accel.write_charge(measured, accel.cfg.n_arrays);
        assert_eq!(bounded.write_energy, want_energy, "cap {cap}: energy charge");
        assert_eq!(bounded.write_latency, want_lat, "cap {cap}: latency charge");
        // Compute never depends on residency; the under-capacity charge
        // never exceeds the old streaming worst case.
        assert_eq!(bounded.compute_latency, streaming.compute_latency);
        assert!(bounded.write_energy <= streaming.write_energy, "cap {cap}");
    }
}

#[test]
fn weighted_sweep_closed_form_matches_measured_ragged_tile_counters() {
    // Non-uniform region sizes: k = 7·256 + 128 shards into seven full
    // 256-row tiles plus a 128-row tail (all full-width, one region per
    // array), S = 1920 write rows per full pass. The size-weighted
    // closed form says the second-chance steady state keeps the *first*
    // C − 1 sweep regions resident, so S − (C−1)·256 rows re-program
    // per pass — verified region-by-region in a Python port of
    // `SlotSpace`/`TileCache::place` (repo convention) before pinning
    // the `==` here, and cross-checked against the engine's measured
    // per-pass `write_rows` across the whole capacity sweep.
    let (m, k, n) = (1usize, 7 * 256 + 128, 256usize);
    let sizes: Vec<u64> = [[256u64; 7].as_slice(), &[128]].concat();
    let total: u64 = sizes.iter().sum();
    assert_eq!(total, 1920);
    let mut rng = Rng::new(501);
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    for cap in 2..=8u64 {
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(Design::Cim1, Tech::Femfet3T)
                .with_capacity_words(cap * 256 * 256)
                .with_threads(1),
        );
        assert_eq!(engine.pool_arrays(), cap as usize);
        let grid = engine.grid(k, n);
        assert_eq!(grid.n_tiles_total() as u64, 8, "7 full + 1 tail tile");
        let id = engine.register_weight(&w, k, n).unwrap();
        engine.gemm_resident(id, &x, m).unwrap(); // cold pass
        engine.gemm_resident(id, &x, m).unwrap(); // reach steady state
        let before = engine.stats();
        engine.gemm_resident(id, &x, m).unwrap(); // one steady pass
        let measured = engine.stats().since(&before).write_rows;
        let want_rows = if cap >= 8 { 0 } else { total - (cap - 1) * 256 };
        assert_eq!(measured, want_rows, "cap {cap}: steady ragged-sweep miss rows");
        // The closed form equals the measured fraction exactly (both
        // are the same integer ratio), and the uniform function applied
        // to the region *count* would misprice the ragged set — the
        // weighted form exists precisely for this gap.
        let frac = sweep_miss_fraction_weighted(&sizes, cap);
        assert_eq!(frac, measured as f64 / total as f64, "cap {cap}: weighted fraction");
        if cap < 8 {
            assert_ne!(
                frac,
                sweep_miss_fraction(8, cap),
                "cap {cap}: ragged sizes must not price like uniform regions"
            );
        }
    }
}

#[test]
fn packed_sweep_model_matches_measured_conv_shaped_shelf_packed_rows() {
    // Conv-shaped grids break the one-region-per-array premise of the
    // weighted closed form: AlexNet conv1's im2col GEMM (363×96) shards
    // into (256,96) + (107,96) — both narrower than half an array — and
    // the shelf packer puts them in ONE array, while conv2 (2400×256)
    // adds nine full tiles and a 96-row tail. `packed_sweep_model`
    // replays the real shelf packer and CLOCK scan (it drives the same
    // `TileCache`), so its per-cycle miss rows must equal the engine's
    // measured `write_rows` delta *exactly* at every capacity — and at
    // the packed fit point (11 arrays for 12 regions) it reports zero
    // steady-state misses where the region-count closed form still
    // charges the sweep tail every pass.
    let convs = [(363usize, 96usize), (2400usize, 256usize)];
    let m = 1usize;
    let mut rng = Rng::new(502);
    let weights: Vec<(Vec<i8>, usize, usize)> =
        convs.iter().map(|&(k, n)| (rng.ternary_vec(k * n, 0.5), k, n)).collect();
    let xs: Vec<Vec<i8>> = convs.iter().map(|&(k, _)| rng.ternary_vec(m * k, 0.5)).collect();
    // Placement order under one worker is FIFO: each call's shards in
    // grid order (k-major per n-stripe), calls in submission order.
    let regions: Vec<(usize, usize)> = convs
        .iter()
        .flat_map(|&(k, n)| TileGrid::new(k, n, 256, 256).shards(256, 256))
        .map(|s| (s.k_len, s.n_len))
        .collect();
    assert_eq!(regions.len(), 12, "2 conv1 shards + 10 conv2 shards");
    assert_eq!(regions[..2], [(256, 96), (107, 96)], "the pair that shelf-packs one array");
    let rows: Vec<u64> = regions.iter().map(|&(r, _)| r as u64).collect();
    let total: u64 = rows.iter().sum();
    assert_eq!(total, 2763);

    for cap in [2u64, 3, 5, 8, 10, 11] {
        let model = packed_sweep_model(&regions, cap, 256, 256);
        assert_eq!(model.total_rows, total);
        assert!(
            model.warmup_passes + model.period <= 32,
            "cap {cap}: CLOCK orbit unexpectedly long ({model:?})"
        );
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(Design::Cim1, Tech::Femfet3T)
                .with_capacity_words(cap * 256 * 256)
                .with_threads(1),
        );
        assert_eq!(engine.pool_arrays(), cap as usize);
        let ids: Vec<_> = weights
            .iter()
            .map(|(w, k, n)| engine.register_weight(w, *k, *n).unwrap())
            .collect();
        let wants: Vec<Vec<i32>> = weights
            .iter()
            .zip(&xs)
            .map(|((w, k, n), x)| {
                reference_gemm(x, w, m, &engine.grid(*k, *n), Design::Cim1.flavor())
            })
            .collect();
        let one_pass = |tag: &str| {
            for ((id, x), want) in ids.iter().zip(&xs).zip(&wants) {
                assert_eq!(&engine.gemm_resident(*id, x, m).unwrap(), want, "cap {cap} {tag}");
            }
        };
        for _ in 0..model.warmup_passes {
            one_pass("warmup");
        }
        let before = engine.stats();
        for _ in 0..model.period {
            one_pass("steady");
        }
        let measured = engine.stats().since(&before).write_rows;
        assert_eq!(measured, model.miss_rows_per_cycle, "cap {cap}: packed model vs measured");
        assert_eq!(
            sweep_miss_fraction_packed(&regions, cap, 256, 256),
            measured as f64 / (model.period * total) as f64,
            "cap {cap}: the packed fraction is exactly the measured ratio"
        );
    }

    // The fit point the packed model finds and the weighted form cannot:
    // conv1's two sub-half-width shards share one array, so 11 arrays
    // hold all 12 regions — measured zero steady-state rows above —
    // while the region-count form still charges rows until 12.
    assert_eq!(sweep_miss_fraction_packed(&regions, 11, 256, 256), 0.0);
    assert!(sweep_miss_fraction_weighted(&rows, 11) > 0.0);
    assert_eq!(sweep_miss_fraction_weighted(&rows, 12), 0.0);
}

#[test]
fn packed_small_weights_survive_eviction_pressure() {
    // Six 32×32 weights (each half an array's rows, half its columns)
    // through a 1-array pool: four pack resident, placing the other two
    // sweeps regions in and out. Bit-exactness must hold throughout.
    for design in Design::ALL {
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(design, Tech::Edram3T)
                .with_array_dims(64, 64)
                .with_capacity_words(64 * 64)
                .with_threads(1),
        );
        assert_eq!(engine.pool_arrays(), 1);
        let mut rng = Rng::new(402);
        let mut ids = Vec::new();
        let mut xs = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..6 {
            let w = rng.ternary_vec(32 * 32, 0.5);
            let x = rng.ternary_vec(32, 0.5);
            wants.push(reference_gemm(&x, &w, 1, &engine.grid(32, 32), design.flavor()));
            ids.push(engine.register_weight(&w, 32, 32).unwrap());
            xs.push(x);
        }
        for pass in 0..3 {
            for i in 0..6 {
                assert_eq!(
                    engine.gemm_resident(ids[i], &xs[i], 1).unwrap(),
                    wants[i],
                    "{design:?} weight {i} pass {pass}"
                );
            }
        }
        let s = engine.stats();
        assert!(s.evictions > 0, "{design:?}: 6 regions through 4 slots must evict");
    }
}
