//! Integration: layer-pipelined continuous batching — admission at
//! every layer boundary, not just layer 0.
//!
//! The hard invariant is bit-exactness: rows are independent in the
//! GEMM M dimension and late rows are caught up through the layers they
//! missed against the *same resident weights*, so a flush that absorbs
//! rows mid-pipeline must produce, for every request, exactly the
//! logits a serial per-request execution produces — across all three
//! designs and thread counts. On top sit the serving semantics: late
//! admission happens at every interior boundary (observable in the
//! per-stage metrics histogram), deadline-partial flushes stay correct,
//! and shutdown drains rows no matter which stage admitted them.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use sitecim::array::mac::Flavor;
use sitecim::array::Design;
use sitecim::coordinator::server::Request;
use sitecim::coordinator::{
    run_pipelined_flush, BatchPolicy, EngineBackend, InferenceBackend, LayerPipeline, Metrics,
    Server, ServerConfig,
};
use sitecim::device::Tech;
use sitecim::dnn::ternary::ternarize_acts_i32;
use sitecim::engine::tiling::{reference_gemm, TileGrid};
use sitecim::runtime::Manifest;
use sitecim::util::rng::Rng;

/// A unique temp artifacts dir per test (tests run in parallel in one
/// process, so the tag must differ per call site).
fn synth_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sitecim-pbatch-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn trit_bytes(trits: &[i8]) -> Vec<u8> {
    trits.iter().map(|&t| t as u8).collect()
}

/// Write a servable synthetic MLP: random ternary weights for each
/// `dims` transition, activation thresholds between layers, and a tiny
/// test set.
fn write_synth_artifacts(dir: &Path, dims: &[usize], batch: usize, seed: u64) {
    assert!(dims.len() >= 2);
    let mut rng = Rng::new(seed);
    let mut weights_json = String::new();
    for i in 0..dims.len() - 1 {
        let (k, n) = (dims[i], dims[i + 1]);
        let w = rng.ternary_vec(k * n, 0.5);
        std::fs::write(dir.join(format!("w{i}.bin")), trit_bytes(&w)).unwrap();
        if i > 0 {
            weights_json.push_str(", ");
        }
        weights_json.push_str(&format!("{{\"file\": \"w{i}.bin\", \"shape\": [{k}, {n}]}}"));
    }
    let in_dim = dims[0];
    let test_n = 4usize;
    let x = rng.ternary_vec(test_n * in_dim, 0.5);
    std::fs::write(dir.join("test_x.bin"), trit_bytes(&x)).unwrap();
    std::fs::write(dir.join("test_y.bin"), vec![0u8; test_n]).unwrap();
    let thresholds = vec!["0.5"; dims.len() - 2].join(", ");
    let dims_json = dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ");
    let manifest = format!(
        "{{\n  \"batch\": {batch},\n  \"dims\": [{dims_json}],\n  \"act_thresholds\": [{thresholds}],\n  \"kernel_shape\": [8, 16, 16],\n  \"files\": {{}},\n  \"weights\": [{weights_json}],\n  \"scales\": [1.0],\n  \"test_set\": {{\"x\": \"test_x.bin\", \"y\": \"test_y.bin\", \"n\": {test_n}, \"in_dim\": {in_dim}}},\n  \"accuracy\": {{}}\n}}\n"
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
}

/// The reference forward pass for `Design::Cim1` serving:
/// `reference_gemm` over 256×256 tiles + the recorded thresholds.
fn reference_forward(manifest: &Manifest, input: &[i8]) -> Vec<f32> {
    let mut h = input.to_vec();
    for i in 0..manifest.weights.len() {
        let (w, (k, n)) = manifest.load_weight(i).unwrap();
        let y = reference_gemm(&h, &w, 1, &TileGrid::new(k, n, 256, 256), Some(Flavor::Cim1));
        if i + 1 < manifest.weights.len() {
            h = ternarize_acts_i32(&y, manifest.act_thresholds[i]);
        } else {
            return y.iter().map(|&v| v as f32).collect();
        }
    }
    unreachable!()
}

/// Wrap `input` as a queued request. The direct-drive tests never send
/// replies (that is the worker loop's scatter, not the flush), so the
/// reply receiver can drop immediately.
fn request(input: Vec<i8>) -> Request {
    let (rtx, _) = std::sync::mpsc::sync_channel(1);
    Request { input, enqueued: Instant::now(), resp: rtx }
}

/// Drive one pipelined flush by hand: `initial` rows form the plane,
/// `late` rows wait in the queue and are admitted at layer boundaries
/// under `policy`. Returns the flush logits in final item order plus
/// the per-stage admissions histogram.
fn drive_flush(
    backend: &EngineBackend,
    policy: &BatchPolicy,
    initial: &[Vec<i8>],
    late: &[Vec<i8>],
) -> (Vec<Vec<f32>>, Vec<(usize, u64, u64)>) {
    let (tx, rx) = channel::<Request>();
    for input in late {
        tx.send(request(input.clone())).unwrap();
    }
    let rx = Mutex::new(rx);
    let metrics = Metrics::new();
    let mut items: Vec<Request> = initial.iter().map(|i| request(i.clone())).collect();
    let plane: Arc<[i8]> = initial.concat().into();
    let logits =
        run_pipelined_flush(backend, policy, &rx, &metrics, &mut items, plane).unwrap();
    let out_dim = backend.out_dim();
    assert_eq!(logits.len(), items.len() * out_dim, "one logit row per absorbed request");
    assert_eq!(items.len(), initial.len() + late.len(), "every queued row was absorbed");
    // Final item order must be initial rows first, then late rows in
    // queue order — the scatter relies on it.
    for (i, want) in initial.iter().chain(late.iter()).enumerate() {
        assert_eq!(&items[i].input, want, "row {i} out of order");
    }
    let rows = logits.chunks(out_dim).map(|c| c.to_vec()).collect();
    let hist = metrics
        .stage_admit_histogram()
        .into_iter()
        .map(|s| (s.stage, s.admissions, s.rows))
        .collect();
    (rows, hist)
}

#[test]
fn boundary_admission_is_bit_exact_vs_serial_across_designs_and_threads() {
    // The tentpole's headline invariant. 3 layers → interior boundaries
    // at layers 1 and 2; with `max_stage_admit_rows: 1` exactly one of
    // the two queued late rows is admitted at each boundary, so both
    // catch-up depths (1 layer missed, 2 layers missed) are exercised.
    // Every absorbed row must equal its own serial single-row run.
    let dir = synth_dir("bitexact");
    write_synth_artifacts(&dir, &[48, 32, 16, 8], 4, 40);
    let manifest = Manifest::load(&dir).unwrap();
    let policy = BatchPolicy { max_stage_admit_rows: 1, ..Default::default() };
    let mut rng = Rng::new(41);
    let initial: Vec<Vec<i8>> = (0..3).map(|_| rng.ternary_vec(48, 0.5)).collect();
    let late: Vec<Vec<i8>> = (0..2).map(|_| rng.ternary_vec(48, 0.5)).collect();
    for design in Design::ALL {
        for threads in [1usize, 4] {
            let b = EngineBackend::load(&manifest, design, Tech::Femfet3T, threads, None).unwrap();
            assert_eq!(b.n_layers(), 3);
            let (rows, hist) = drive_flush(&b, &policy, &initial, &late);
            for (i, input) in initial.iter().chain(late.iter()).enumerate() {
                let serial = b.run_batch(input, 1).unwrap();
                assert_eq!(rows[i], serial, "{design:?} threads={threads} row {i}");
            }
            // One single-row admission at each interior boundary.
            assert_eq!(
                hist,
                vec![(0, 0, 0), (1, 1, 1), (2, 1, 1)],
                "{design:?} threads={threads}: every interior boundary admits"
            );
        }
    }
}

#[test]
fn pipelined_flush_without_arrivals_equals_serial_batch_path() {
    // With an empty queue the stage loop must degenerate to exactly the
    // serial `run_batch_arc` composition — same plane, same result.
    let dir = synth_dir("degenerate");
    write_synth_artifacts(&dir, &[40, 24, 8], 4, 42);
    let manifest = Manifest::load(&dir).unwrap();
    let policy = BatchPolicy::default();
    let mut rng = Rng::new(43);
    let inputs: Vec<Vec<i8>> = (0..5).map(|_| rng.ternary_vec(40, 0.5)).collect();
    for design in Design::ALL {
        let b = EngineBackend::load(&manifest, design, Tech::Femfet3T, 2, None).unwrap();
        let (rows, hist) = drive_flush(&b, &policy, &inputs, &[]);
        let serial = b.run_batch_arc(inputs.concat().into(), inputs.len()).unwrap();
        let flat: Vec<f32> = rows.concat();
        assert_eq!(flat, serial, "{design:?}");
        assert!(
            hist.iter().all(|&(_, admissions, rows)| admissions == 0 && rows == 0),
            "{design:?}: nothing to admit"
        );
    }
}

#[test]
fn stage_budget_respects_row_cap_and_catchup_cutoff_in_flight() {
    // `max_batch_rows` caps the whole in-flight plane, not just layer
    // 0: with 4 resident rows and a cap of 5, only one late row fits —
    // the second stays queued. A `max_catchup_frac` of 0 turns
    // boundary admission off entirely even with budget available.
    let dir = synth_dir("budget");
    write_synth_artifacts(&dir, &[32, 16, 8], 4, 44);
    let manifest = Manifest::load(&dir).unwrap();
    let b = EngineBackend::load(&manifest, Design::Cim1, Tech::Femfet3T, 1, None).unwrap();
    let mut rng = Rng::new(45);
    let initial: Vec<Vec<i8>> = (0..4).map(|_| rng.ternary_vec(32, 0.5)).collect();
    let late: Vec<Vec<i8>> = (0..2).map(|_| rng.ternary_vec(32, 0.5)).collect();

    let capped = BatchPolicy { max_batch_rows: 5, ..Default::default() };
    let (tx, rx) = channel::<Request>();
    for input in &late {
        tx.send(request(input.clone())).unwrap();
    }
    let rx = Mutex::new(rx);
    let metrics = Metrics::new();
    let mut items: Vec<Request> = initial.iter().map(|i| request(i.clone())).collect();
    let logits =
        run_pipelined_flush(&b, &capped, &rx, &metrics, &mut items, initial.concat().into())
            .unwrap();
    assert_eq!(items.len(), 5, "row cap admits exactly one late row");
    assert_eq!(logits.len(), 5 * b.out_dim());
    assert_eq!(
        rx.lock().unwrap().try_recv().unwrap().input,
        late[1],
        "the over-cap row stays queued for the next flush"
    );
    for (i, input) in initial.iter().chain(late.iter().take(1)).enumerate() {
        let serial = b.run_batch(input, 1).unwrap();
        assert_eq!(&logits[i * b.out_dim()..(i + 1) * b.out_dim()], serial, "row {i}");
    }

    let frozen = BatchPolicy { max_catchup_frac: 0.0, ..Default::default() };
    let (rows, hist) = drive_flush_partial(&b, &frozen, &initial);
    assert_eq!(rows.len(), initial.len());
    assert!(hist.iter().all(|&(_, a, r)| a == 0 && r == 0), "cutoff 0 admits nowhere");
}

/// `drive_flush` against an empty queue, for policies that must not
/// admit anything.
fn drive_flush_partial(
    backend: &EngineBackend,
    policy: &BatchPolicy,
    initial: &[Vec<i8>],
) -> (Vec<Vec<f32>>, Vec<(usize, u64, u64)>) {
    drive_flush(backend, policy, initial, &[])
}

#[test]
fn served_replies_match_reference_forward_with_boundary_admission_on() {
    // Server-level end-to-end: boundary admission is on by default and
    // a continuous request stream (no barriers between submissions)
    // gives flushes every chance to absorb rows mid-pipeline. Every
    // reply must equal the per-request reference forward regardless of
    // which flush, and which stage of it, served the row.
    let dir = synth_dir("serve");
    write_synth_artifacts(&dir, &[32, 24, 16, 8], 4, 46);
    let mut cfg = ServerConfig::new(dir.clone()).with_engine_backend();
    cfg.n_workers = 2;
    cfg.engine_threads = 2;
    cfg.policy = BatchPolicy {
        max_batch: 4,
        max_batch_rows: 16,
        max_wait: Duration::from_millis(5),
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let mut rng = Rng::new(47);
    let mut pending = Vec::new();
    for _ in 0..48 {
        let input = rng.ternary_vec(32, 0.5);
        let want = reference_forward(&manifest, &input);
        pending.push((want, server.infer_async(input).unwrap()));
    }
    for (want, rx) in pending {
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.logits, want, "pipelined serving must match the reference forward");
    }
    assert_eq!(server.metrics.requests.load(Ordering::Relaxed), 48);
    assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);
    let hist = server.metrics.stage_admit_histogram();
    assert!(!hist.is_empty() && hist[0].rows > 0, "layer-0 admissions recorded");
    assert_eq!(
        hist.iter().map(|s| s.rows).sum::<u64>(),
        48,
        "every request admitted at exactly one stage"
    );
    assert_eq!(server.metrics.pipeline_active(), 0, "no flush left in flight");
    server.shutdown();
}

#[test]
fn deadline_partial_flushes_stay_correct_under_trickled_load() {
    // A 1 ms deadline with requests trickled in one at a time forces
    // deadline-partial flushes (and gives late rows a real chance to
    // land mid-pipeline on the busy worker). Correctness must not
    // depend on how the rows happened to be cut into flushes.
    let dir = synth_dir("deadline");
    write_synth_artifacts(&dir, &[32, 16, 8], 4, 48);
    let mut cfg = ServerConfig::new(dir.clone()).with_engine_backend();
    cfg.n_workers = 1;
    cfg.engine_threads = 1;
    cfg.policy = BatchPolicy {
        max_batch: 8,
        max_batch_rows: 8,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let mut rng = Rng::new(49);
    let mut pending = Vec::new();
    for i in 0..12 {
        let input = rng.ternary_vec(32, 0.5);
        let want = reference_forward(&manifest, &input);
        pending.push((want, server.infer_async(input).unwrap()));
        if i % 3 == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    for (want, rx) in pending {
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.logits, want);
    }
    assert_eq!(server.metrics.requests.load(Ordering::Relaxed), 12);
    assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);
    server.shutdown();
}

#[test]
fn shutdown_drains_rows_admitted_at_any_stage() {
    // Close the queue with a pile of unanswered requests on workers
    // whose flushes admit at every boundary: every reply channel must
    // still be answered — rows absorbed mid-pipeline included — before
    // the workers exit.
    let dir = synth_dir("drain");
    write_synth_artifacts(&dir, &[32, 24, 16, 8], 4, 50);
    let mut cfg = ServerConfig::new(dir).with_engine_backend();
    cfg.n_workers = 2;
    cfg.engine_threads = 2;
    cfg.policy = BatchPolicy {
        max_batch: 4,
        max_batch_rows: 8,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let mut rng = Rng::new(51);
    let pending: Vec<_> =
        (0..30).map(|_| server.infer_async(rng.ternary_vec(32, 0.5)).unwrap()).collect();
    server.shutdown();
    for rx in pending {
        let reply = rx.recv().expect("reply delivered before shutdown completed");
        assert!(reply.is_ok());
    }
}
