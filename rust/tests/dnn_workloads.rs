//! Integration: benchmark workloads and ternary quantization.
use sitecim::dnn::{benchmarks, ternary};
use sitecim::util::rng::Rng;

#[test]
fn suite_matches_paper_lineup() {
    let names: Vec<String> = benchmarks::suite().into_iter().map(|n| n.name).collect();
    assert_eq!(names, ["AlexNet", "ResNet34", "Inception", "LSTM", "GRU"]);
}

#[test]
fn all_benchmarks_exceed_onchip_capacity() {
    // The paper's suite streams weights (> 2M ternary words).
    for net in benchmarks::suite() {
        assert!(net.total_weight_words() > 2 * 1024 * 1024, "{}", net.name);
    }
}

#[test]
fn twn_quantization_roundtrip_statistics() {
    let mut rng = Rng::new(11);
    let w: Vec<f32> = (0..20_000).map(|_| rng.normal() as f32).collect();
    let t = ternary::ternarize(&w);
    let s = ternary::sparsity(&t);
    assert!((0.3..0.6).contains(&s), "sparsity {s}");
    assert!(ternary::twn_scale(&w) > 0.5);
}
