//! Integration: circuit-layer models hold their paper anchors together.
use sitecim::circuit::bitline::VoltageBitline;
use sitecim::circuit::sense_margin::{current_mode_margins, voltage_mode_margins, CurrentModeSetup};
use sitecim::circuit::{CurrentAdc, VoltageAdc};
use sitecim::device::{Tech, TechParams};

#[test]
fn voltage_ladder_and_adc_consistent_end_to_end() {
    let bl = VoltageBitline::new(1.0);
    let adc = VoltageAdc::ideal(&bl);
    for n in 0..=16usize {
        assert_eq!(adc.quantize(bl.v_after(n)), n.min(8) as u32, "n={n}");
    }
}

#[test]
fn margins_anchor_both_flavors_at_8() {
    let v = voltage_mode_margins(1.0, 16);
    assert!(v[8].margin >= 0.0399 && v[9].margin < 0.040);
    for tech in Tech::ALL {
        let p = TechParams::new(tech);
        let setup = CurrentModeSetup { n_rows_block_total: 16, c_lrbl: 1e-15, t_sense: 0.45e-9 };
        let c = current_mode_margins(&p, &setup);
        assert!(c[1].margin > c[16].margin, "{}", tech.name());
    }
}

#[test]
fn current_adc_and_comparator_pipeline() {
    use sitecim::circuit::sensing::{comparator_sign, subtractor_magnitude_units};
    let adc = CurrentAdc::ideal();
    let p = TechParams::new(Tech::Femfet3T);
    let unit = p.i_lrs;
    // 5 LRS on RBL1, 2 on RBL2.
    let (i1, i2) = (5.0 * unit, 2.0 * unit);
    let o = comparator_sign(i1, i2) * adc.quantize(subtractor_magnitude_units(i1, i2, unit)) as i32;
    assert_eq!(o, 3);
}
