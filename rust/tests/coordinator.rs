//! Integration: serving coordinator over the PJRT runtime.
//! Skips gracefully if artifacts are missing.
use sitecim::coordinator::{BatchPolicy, Server, ServerConfig};
use sitecim::runtime::{default_dir, Manifest};

fn artifacts_available() -> bool {
    Manifest::load(default_dir()).is_ok()
}

#[test]
fn serves_requests_with_batching() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(default_dir()).unwrap();
    let (x, y) = manifest.load_test_set().unwrap();
    let mut cfg = ServerConfig::new(default_dir());
    cfg.n_workers = 2;
    cfg.policy = BatchPolicy { max_batch: 32, max_wait: std::time::Duration::from_millis(1) };
    let server = Server::start(cfg).unwrap();

    let n = 256;
    let mut pending = Vec::new();
    for i in 0..n {
        let input = x[i * manifest.in_dim..(i + 1) * manifest.in_dim].to_vec();
        pending.push((i, server.infer_async(input).unwrap()));
    }
    let mut correct = 0;
    for (i, rx) in pending {
        let r = rx.recv().unwrap().unwrap();
        correct += usize::from(r.pred == y[i] as usize);
    }
    assert!(correct as f64 / n as f64 > 0.95, "accuracy {correct}/{n}");
    assert!(server.metrics.avg_batch_size() > 2.0, "batching ineffective");
    assert!(server.metrics.sim_energy_j() > 0.0);
    server.shutdown();
}

#[test]
fn rejects_malformed_input() {
    if !artifacts_available() {
        return;
    }
    let server = Server::start(ServerConfig::new(default_dir())).unwrap();
    assert!(server.infer(vec![0i8; 3]).is_err());
    server.shutdown();
}
