//! Integration: the serving coordinator.
//!
//! The engine backend needs no compiled artifacts — these tests write a
//! small synthetic manifest (ternary weights + thresholds) into a temp
//! dir and serve through the functional GEMM engine, so the multi-worker
//! paths run in every environment. The PJRT tests still skip gracefully
//! when `make artifacts` has not run.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::time::Duration;

use sitecim::array::mac::Flavor;
use sitecim::array::Design;
use sitecim::coordinator::{
    BatchPolicy, EngineBackend, InferenceBackend, Server, ServerConfig,
};
use sitecim::device::Tech;
use sitecim::dnn::ternary::ternarize_acts_i32;
use sitecim::engine::tiling::{reference_gemm, TileGrid};
use sitecim::runtime::{default_dir, Manifest};
use sitecim::util::rng::Rng;

fn artifacts_available() -> bool {
    Manifest::load(default_dir()).is_ok()
}

/// A unique temp artifacts dir per test (tests run in parallel in one
/// process, so the tag must differ per call site).
fn synth_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sitecim-coord-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn trit_bytes(trits: &[i8]) -> Vec<u8> {
    trits.iter().map(|&t| t as u8).collect()
}

/// Write a servable synthetic MLP: random ternary weights for each
/// `dims` transition, activation thresholds between layers, and a tiny
/// test set.
fn write_synth_artifacts(dir: &Path, dims: &[usize], batch: usize, seed: u64) {
    assert!(dims.len() >= 2);
    let mut rng = Rng::new(seed);
    let mut weights_json = String::new();
    for i in 0..dims.len() - 1 {
        let (k, n) = (dims[i], dims[i + 1]);
        let w = rng.ternary_vec(k * n, 0.5);
        std::fs::write(dir.join(format!("w{i}.bin")), trit_bytes(&w)).unwrap();
        if i > 0 {
            weights_json.push_str(", ");
        }
        weights_json.push_str(&format!("{{\"file\": \"w{i}.bin\", \"shape\": [{k}, {n}]}}"));
    }
    let in_dim = dims[0];
    let test_n = 4usize;
    let x = rng.ternary_vec(test_n * in_dim, 0.5);
    std::fs::write(dir.join("test_x.bin"), trit_bytes(&x)).unwrap();
    std::fs::write(dir.join("test_y.bin"), vec![0u8; test_n]).unwrap();
    let thresholds = vec!["0.5"; dims.len() - 2].join(", ");
    let dims_json =
        dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ");
    let manifest = format!(
        "{{\n  \"batch\": {batch},\n  \"dims\": [{dims_json}],\n  \"act_thresholds\": [{thresholds}],\n  \"kernel_shape\": [8, 16, 16],\n  \"files\": {{}},\n  \"weights\": [{weights_json}],\n  \"scales\": [1.0],\n  \"test_set\": {{\"x\": \"test_x.bin\", \"y\": \"test_y.bin\", \"n\": {test_n}, \"in_dim\": {in_dim}}},\n  \"accuracy\": {{}}\n}}\n"
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
}

fn engine_server_config(dir: PathBuf, workers: usize) -> ServerConfig {
    let mut cfg = ServerConfig::new(dir).with_engine_backend();
    cfg.n_workers = workers;
    cfg.engine_threads = 2;
    cfg.policy =
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1), ..Default::default() };
    cfg
}

/// The reference forward pass the engine backend must reproduce exactly:
/// `reference_gemm` over 256×256 tiles + the recorded thresholds.
fn reference_forward(manifest: &Manifest, input: &[i8]) -> Vec<f32> {
    let mut h = input.to_vec();
    for i in 0..manifest.weights.len() {
        let (w, (k, n)) = manifest.load_weight(i).unwrap();
        let y = reference_gemm(&h, &w, 1, &TileGrid::new(k, n, 256, 256), Some(Flavor::Cim1));
        if i + 1 < manifest.weights.len() {
            h = ternarize_acts_i32(&y, manifest.act_thresholds[i]);
        } else {
            return y.iter().map(|&v| v as f32).collect();
        }
    }
    unreachable!()
}

#[test]
fn engine_server_serves_concurrent_requests_with_shared_resident_model() {
    let dir = synth_dir("concurrent");
    write_synth_artifacts(&dir, &[32, 16, 8], 8, 1);
    let server = Server::start(engine_server_config(dir.clone(), 3)).unwrap();

    let mut rng = Rng::new(9);
    let manifest = Manifest::load(&dir).unwrap();
    let mut pending = Vec::new();
    for _ in 0..48 {
        let input = rng.ternary_vec(32, 0.5);
        let want = reference_forward(&manifest, &input);
        pending.push((want, server.infer_async(input).unwrap()));
    }
    for (want, rx) in pending {
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.logits.len(), 8);
        assert_eq!(reply.logits, want, "engine backend must match the reference forward");
    }
    assert_eq!(server.metrics.requests.load(Ordering::Relaxed), 48);
    assert_eq!(server.metrics.errors.load(Ordering::Relaxed), 0);

    // The tentpole property: one shared model, tiles programmed exactly
    // once (2 single-tile layers), every later GEMM hits the cache.
    let stats = server.engine_model().unwrap().engine_stats();
    assert_eq!(stats.tiles, 2, "weights stay resident across all workers/batches");
    assert!(stats.hits > 0, "steady-state serving must hit the tile cache");
    assert_eq!(stats.evictions, 0);
    server.shutdown();
}

#[test]
fn engine_server_rejects_malformed_input_and_keeps_serving() {
    let dir = synth_dir("malformed");
    write_synth_artifacts(&dir, &[32, 16, 8], 8, 2);
    let server = Server::start(engine_server_config(dir.clone(), 2)).unwrap();

    // Wrong input length is rejected up-front…
    assert!(server.infer(vec![0i8; 3]).is_err());
    // …and the workers are unaffected: valid traffic still flows.
    let mut rng = Rng::new(10);
    for _ in 0..8 {
        let reply = server.infer(rng.ternary_vec(32, 0.5)).unwrap();
        assert_eq!(reply.logits.len(), 8);
    }
    assert_eq!(server.metrics.requests.load(Ordering::Relaxed), 8);
    server.shutdown();
}

#[test]
fn engine_server_shutdown_drains_all_pending_replies() {
    let dir = synth_dir("drain");
    write_synth_artifacts(&dir, &[32, 16, 8], 8, 3);
    let server = Server::start(engine_server_config(dir, 2)).unwrap();
    let mut rng = Rng::new(11);
    let mut pending = Vec::new();
    for _ in 0..24 {
        pending.push(server.infer_async(rng.ternary_vec(32, 0.5)).unwrap());
    }
    // Close the queue immediately: every already-submitted request must
    // still be answered before the workers exit.
    server.shutdown();
    for rx in pending {
        let reply = rx.recv().expect("reply delivered before shutdown completed");
        assert!(reply.is_ok());
    }
}

#[test]
fn empty_dims_manifest_is_a_startup_error_not_a_panic() {
    let dir = synth_dir("emptydims");
    let manifest = "{\n  \"batch\": 8,\n  \"dims\": [],\n  \"act_thresholds\": [],\n  \"kernel_shape\": [8, 16, 16],\n  \"files\": {},\n  \"weights\": [],\n  \"scales\": [],\n  \"test_set\": {\"x\": \"test_x.bin\", \"y\": \"test_y.bin\", \"n\": 0, \"in_dim\": 0},\n  \"accuracy\": {}\n}\n";
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    std::fs::write(dir.join("test_x.bin"), Vec::<u8>::new()).unwrap();
    std::fs::write(dir.join("test_y.bin"), Vec::<u8>::new()).unwrap();
    let err = Server::start(ServerConfig::new(dir)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("dims"), "error should explain the bad manifest: {msg}");
}

#[test]
fn engine_backend_rejects_bad_batches_as_errors() {
    let dir = synth_dir("badbatch");
    write_synth_artifacts(&dir, &[32, 16, 8], 4, 4);
    let manifest = Manifest::load(&dir).unwrap();
    let b = EngineBackend::load(&manifest, Design::Cim1, Tech::Femfet3T, 1, None).unwrap();
    assert_eq!((b.batch(), b.in_dim(), b.out_dim()), (4, 32, 8));
    assert!(b.run_batch(&[0i8; 32], 0).is_err(), "n_valid = 0");
    assert!(b.run_batch(&[0i8; 32], 5).is_err(), "5 rows need 5 × in_dim trits");
    assert!(b.run_batch(&[0i8; 16], 1).is_err(), "length mismatch");
    // The backend still serves after rejecting bad batches.
    let ok = b.run_batch(&[0i8; 64], 2).unwrap();
    assert_eq!(ok.len(), 2 * 8);
    // The manifest `batch` is no longer an engine cap: a correctly
    // shaped plane taller than it (here 5 > 4) runs in one pipeline
    // pass — the continuous batcher's whole premise.
    let tall = b.run_batch(&[0i8; 5 * 32], 5).unwrap();
    assert_eq!(tall.len(), 5 * 8, "M above manifest batch is served");
}

#[test]
fn bounded_engine_backend_serves_bit_exact_under_eviction_pressure() {
    // A 512×512 first layer is 4 full 256×256 tiles; a 1-array word
    // budget (65536 words) forces eviction on every pass. Outputs
    // must stay bit-identical to the unbounded reference forward.
    let dir = synth_dir("bounded");
    write_synth_artifacts(&dir, &[512, 512, 8], 4, 5);
    let manifest = Manifest::load(&dir).unwrap();
    let b =
        EngineBackend::load(&manifest, Design::Cim1, Tech::Femfet3T, 2, Some(65536)).unwrap();
    assert_eq!(b.pool_arrays(), 1);
    assert_eq!(b.capacity_words(), 65536);
    let mut rng = Rng::new(12);
    for pass in 0..3 {
        let input = rng.ternary_vec(512, 0.5);
        let want = reference_forward(&manifest, &input);
        let got = b.run_batch(&input, 1).unwrap();
        assert_eq!(got, want, "bounded pool must stay bit-exact (pass {pass})");
    }
    let s = b.engine_stats();
    assert!(s.misses > 0 && s.evictions > 0, "working set exceeds the bound: {s:?}");
}

#[test]
fn serve_reports_measured_amortized_residency() {
    // The accounting satellite: `serve` must report amortized
    // energy/latency from its *own* counters — write rows the engine
    // actually programmed over inferences actually served — not a
    // steady-state assumption.
    let dir = synth_dir("measured");
    write_synth_artifacts(&dir, &[32, 16, 8], 8, 6);
    let server = Server::start(engine_server_config(dir, 2)).unwrap();
    let mut rng = Rng::new(13);
    for _ in 0..10 {
        server.infer(rng.ternary_vec(32, 0.5)).unwrap();
    }
    let m = server.measured_residency().expect("engine backend reports measured residency");
    assert_eq!(m.inferences, 10);
    // Two single-tile layers programmed once ever: 32 + 16 occupied rows.
    assert_eq!(m.write_rows, 48);
    assert!(m.write_energy_j > 0.0 && m.write_latency_s > 0.0);
    assert!(m.hit_rate > 0.5, "steady-state serving hits the cache: {}", m.hit_rate);
    // Serving more traffic re-programs nothing and amortizes the same
    // charge over more inferences: the measured per-inference cost falls.
    for _ in 0..10 {
        server.infer(rng.ternary_vec(32, 0.5)).unwrap();
    }
    let m2 = server.measured_residency().unwrap();
    assert_eq!(m2.inferences, 20);
    assert_eq!(m2.write_rows, 48, "steady state: no re-programming");
    assert!(m2.energy_per_inf_j < m.energy_per_inf_j, "amortization deepens");
    assert!(m2.latency_per_inf_s < m.latency_per_inf_s);
    server.shutdown();
}

// ---- PJRT-backed tests (need `make artifacts` + the pjrt feature) ----

#[test]
fn serves_requests_with_batching() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(default_dir()).unwrap();
    let (x, y) = manifest.load_test_set().unwrap();
    let mut cfg = ServerConfig::new(default_dir());
    cfg.n_workers = 2;
    cfg.policy = BatchPolicy {
        max_batch: 32,
        max_wait: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();

    let n = 256;
    let mut pending = Vec::new();
    for i in 0..n {
        let input = x[i * manifest.in_dim..(i + 1) * manifest.in_dim].to_vec();
        pending.push((i, server.infer_async(input).unwrap()));
    }
    let mut correct = 0;
    for (i, rx) in pending {
        let r = rx.recv().unwrap().unwrap();
        correct += usize::from(r.pred == y[i] as usize);
    }
    assert!(correct as f64 / n as f64 > 0.95, "accuracy {correct}/{n}");
    assert!(server.metrics.avg_batch_size() > 2.0, "batching ineffective");
    assert!(server.metrics.sim_energy_j() > 0.0);
    server.shutdown();
}

#[test]
fn rejects_malformed_input() {
    if !artifacts_available() {
        return;
    }
    let server = Server::start(ServerConfig::new(default_dir())).unwrap();
    assert!(server.infer(vec![0i8; 3]).is_err());
    server.shutdown();
}
