//! Integration: every figure/table renderer produces paper-comparable
//! output (smoke + key-content checks).
use sitecim::repro;

#[test]
fn fig4_and_fig7_render_margin_tables() {
    let f4 = repro::fig4();
    assert!(f4.contains("Fig 4(c)"));
    assert!(f4.contains("50"));
    let f7 = repro::fig7();
    assert!(f7.contains("Fig 7(c)"));
    assert!(f7.contains("diminishing"));
}

#[test]
fn array_figures_have_all_techs() {
    for s in [repro::fig9(), repro::fig11(), repro::area_table(), repro::cim1_vs_cim2()] {
        for tech in ["8T-SRAM", "3T-eDRAM", "3T-FEMFET"] {
            assert!(s.contains(tech), "missing {tech}");
        }
    }
}

#[test]
fn system_figures_have_all_benchmarks() {
    let s = repro::fig12();
    for b in ["AlexNet", "ResNet34", "Inception", "LSTM", "GRU", "AVG (paper)"] {
        assert!(s.contains(b), "missing {b}");
    }
    assert!(repro::fig13().contains("SiTe CiM II"));
}

#[test]
fn error_prob_table_cites_paper_value() {
    let s = repro::error_prob();
    assert!(s.contains("3.10e-3"));
}
