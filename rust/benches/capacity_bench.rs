//! Capacity-sweep bench: serve an AlexNet-FC-shaped working set through
//! the resident engine at a range of pool capacities — from heavy LRU
//! eviction pressure up to fully resident — and record measured hit
//! rates, eviction counts and serving throughput for all three designs.
//! The paper's 2 M-word budget is always one of the sweep points, and
//! the full-size working set (~58 M words of FC weights) exceeds it, so
//! the 2 M row reports genuinely pressured (nonzero-miss) serving.
//!
//! Emits `BENCH_capacity.json` (uploaded as a CI artifact alongside
//! `BENCH_engine.json`).
//!
//! `SITECIM_BENCH_FAST=1` scales the FC stack by 1/8 for CI smoke runs.

use std::time::Instant;

use sitecim::array::Design;
use sitecim::device::Tech;
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::util::rng::Rng;

const ARRAY: usize = 256;
const WORDS_PER_ARRAY: u64 = (ARRAY * ARRAY) as u64;

struct Entry {
    design: Design,
    capacity_words: u64,
    arrays: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
    hit_rate: f64,
    inf_per_s: f64,
}

/// 16-row-padded words the layer's tiles occupy (what a pool must hold
/// for all-hit serving, before packing).
fn padded_words(k: usize, n: usize) -> u64 {
    let mut total = 0u64;
    for nt in 0..n.div_ceil(ARRAY) {
        let n_len = ARRAY.min(n - nt * ARRAY);
        for kt in 0..k.div_ceil(ARRAY) {
            let k_len = ARRAY.min(k - kt * ARRAY);
            total += (k_len.div_ceil(16) * 16 * n_len) as u64;
        }
    }
    total
}

fn tiles(k: usize, n: usize) -> u64 {
    (k.div_ceil(ARRAY) * n.div_ceil(ARRAY)) as u64
}

fn main() {
    let fast = std::env::var("SITECIM_BENCH_FAST").is_ok();
    // AlexNet's FC stack (fc6/fc7/fc8), scaled 1/8 in fast mode.
    let dims: Vec<(usize, usize)> = if fast {
        vec![(1152, 512), (512, 512), (512, 128)]
    } else {
        vec![(9216, 4096), (4096, 4096), (4096, 1000)]
    };
    let workload = if fast { "alexnet-fc/8" } else { "alexnet-fc" };
    let reps = if fast { 2 } else { 3 };

    let mut rng = Rng::new(0x5EED);
    let weights: Vec<(Vec<i8>, usize, usize)> =
        dims.iter().map(|&(k, n)| (rng.ternary_vec(k * n, 0.5), k, n)).collect();
    let xs: Vec<Vec<i8>> = dims.iter().map(|&(k, _)| rng.ternary_vec(k, 0.5)).collect();

    let ws_words: u64 = dims.iter().map(|&(k, n)| padded_words(k, n)).sum();
    let tiles_total: u64 = dims.iter().map(|&(k, n)| tiles(k, n)).sum();
    // One array per tile always serves all-hit; sweep fractions of that
    // plus the paper's 2 M-word system budget.
    let fit_words = tiles_total * WORDS_PER_ARRAY;
    let mut caps: Vec<u64> =
        vec![fit_words / 4, fit_words / 2, 3 * fit_words / 4, fit_words, 2 * 1024 * 1024];
    caps.sort_unstable();
    caps.dedup();

    println!("== capacity_bench ({workload}) ==");
    println!(
        "working set: {} layers, {tiles_total} tiles, {ws_words} padded words ({fit_words} words unpacked)",
        dims.len()
    );

    let mut entries: Vec<Entry> = Vec::new();
    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        for &cap in &caps {
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T).with_capacity_words(cap),
            );
            let ids: Vec<_> = weights
                .iter()
                .map(|(w, k, n)| engine.register_weight(w, *k, *n).unwrap())
                .collect();
            // Warm pass: cold programming excluded from the measurement.
            for (id, x) in ids.iter().zip(&xs) {
                engine.gemm_resident(*id, x, 1).unwrap();
            }
            let before = engine.stats();
            let t0 = Instant::now();
            for _ in 0..reps {
                for (id, x) in ids.iter().zip(&xs) {
                    engine.gemm_resident(*id, x, 1).unwrap();
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            let d = engine.stats().since(&before);
            let (hits, misses, evictions) = (d.hits, d.misses, d.evictions);
            let hit_rate = d.hit_rate();
            let inf_per_s = reps as f64 / dt;
            println!(
                "{:<11} cap {:>10} words ({:>3} arrays): hit rate {:>5.1}%  ({} h / {} m / {} e)  {:.2} inf/s",
                format!("{design:?}"),
                cap,
                engine.pool_arrays(),
                100.0 * hit_rate,
                hits,
                misses,
                evictions,
                inf_per_s,
            );
            entries.push(Entry {
                design,
                capacity_words: cap,
                arrays: engine.pool_arrays(),
                hits,
                misses,
                evictions,
                hit_rate,
                inf_per_s,
            });
        }
    }

    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"bench\": \"capacity_sweep\",\n  \"fast_mode\": {fast},\n  \"workload\": \"{workload}\",\n"
    ));
    json.push_str(&format!(
        "  \"working_set_words\": {ws_words},\n  \"fit_words\": {fit_words},\n  \"results\": [\n"
    ));
    for (i, e) in entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"design\": \"{:?}\", \"capacity_words\": {}, \"arrays\": {}, \"hits\": {}, \"misses\": {}, \"evictions\": {}, \"hit_rate\": {:.4}, \"inf_per_s\": {:.3}}}{}\n",
            e.design,
            e.capacity_words,
            e.arrays,
            e.hits,
            e.misses,
            e.evictions,
            e.hit_rate,
            e.inf_per_s,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_capacity.json", &json) {
        Ok(()) => println!("\nwrote BENCH_capacity.json"),
        Err(e) => eprintln!("\ncould not write BENCH_capacity.json: {e}"),
    }
}
