//! Capacity-sweep bench: serve an AlexNet-FC-shaped working set through
//! the resident engine at a range of pool capacities — from heavy
//! eviction pressure up to fully resident — and record hit rates,
//! eviction counts and serving throughput for all three designs. The
//! paper's 2 M-word budget is always one of the sweep points, and the
//! full-size working set (~58 M words of FC weights) exceeds it, so the
//! 2 M row reports genuinely pressured (nonzero-miss) serving.
//!
//! The hit-rate columns are recorded from a *deterministic placement
//! replay*: a single-threaded proxy engine with 32×32 arrays and dims/8
//! layers, which has exactly the same tile-grid structure, shelf
//! packing decisions and second-chance eviction sequence as the
//! full-size engine (every tile edge in this workload scales by 8 with
//! its 16-row padding fraction preserved), but costs negligible MAC
//! time and is bit-reproducible on any machine — which is what lets
//! `sitecim bench-check` gate these columns against a committed
//! baseline. Serving throughput (`inf_per_s`) still comes from the real
//! multi-threaded engine and is never gated.
//!
//! Each capacity also gets two per-tenant rows (`tenant:res` /
//! `tenant:shared`) from a second replay in which the first FC layer
//! hard-reserves half the pool as its own partition — the multi-tenant
//! analogue of the shared sweep, recorded from the per-tenant stat
//! books so the isolation of the reserved partition is gateable too.
//!
//! A separate `conv:mix` sweep replays the im2col GEMM shapes of
//! AlexNet's conv layers at full array size: their ragged K tails and
//! sub-half-width N columns shelf-pack several regions per array, so
//! those rows put the CLOCK pool under pressure on the *packing*
//! capacity currency that the uniform FC stack never exercises.
//!
//! Emits `BENCH_capacity.json` (uploaded as a CI artifact alongside
//! `BENCH_engine.json`).
//!
//! `SITECIM_BENCH_FAST=1` scales the FC stack by 1/8 for CI smoke runs.

use std::time::Instant;

use sitecim::array::Design;
use sitecim::device::Tech;
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::util::rng::Rng;

const ARRAY: usize = 256;
const WORDS_PER_ARRAY: u64 = (ARRAY * ARRAY) as u64;
/// Proxy scale for the deterministic placement replay: array and layer
/// dims divide by 8 (32×32 arrays), preserving every tile's shape
/// *fraction* of the array — row edges in this workload are multiples
/// of 128, so padded 16-row-group fractions survive the scaling too.
const PROXY_SCALE: usize = 8;
const PROXY_ARRAY: usize = ARRAY / PROXY_SCALE;

/// Replay the sweep's placement sequence on the proxy engine and return
/// the measured (hits, misses, evictions, hit_rate) over `reps` passes
/// after a warm pass — deterministic for any machine and thread count
/// (the proxy always runs single-threaded).
fn proxy_hit_counters(
    dims: &[(usize, usize)],
    arrays: usize,
    reps: usize,
) -> (u64, u64, u64, f64) {
    for &(k, n) in dims {
        assert!(
            k % (PROXY_SCALE * 16) == 0 && n % PROXY_SCALE == 0,
            "proxy fidelity needs k % 128 == 0 and n % 8 == 0, got {k}x{n}"
        );
    }
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim1, Tech::Femfet3T)
            .with_array_dims(PROXY_ARRAY, PROXY_ARRAY)
            .with_capacity_words((arrays * PROXY_ARRAY * PROXY_ARRAY) as u64)
            .with_threads(1),
    );
    assert_eq!(engine.pool_arrays(), arrays);
    // Placement ignores weight values: zero trits keep the replay cheap.
    let ids: Vec<_> = dims
        .iter()
        .map(|&(k, n)| {
            let (pk, pn) = (k / PROXY_SCALE, n / PROXY_SCALE);
            engine.register_weight(&vec![0i8; pk * pn], pk, pn).unwrap()
        })
        .collect();
    let xs: Vec<Vec<i8>> = dims.iter().map(|&(k, _)| vec![0i8; k / PROXY_SCALE]).collect();
    let one_pass = || {
        for (id, x) in ids.iter().zip(&xs) {
            engine.gemm_resident(*id, x, 1).unwrap();
        }
    };
    one_pass(); // warm
    let before = engine.stats();
    for _ in 0..reps {
        one_pass();
    }
    let d = engine.stats().since(&before);
    (d.hits, d.misses, d.evictions, d.hit_rate())
}

/// Two-tenant variant of the deterministic replay: the first FC layer
/// hard-reserves half the pool as its own partition while the remaining
/// layers share the rest best-effort. Returns per-tenant
/// `(arrays, hits, misses, evictions, hit_rate)` rows — reserved first,
/// shared second — or `None` when the pool is too small to split.
fn proxy_tenant_counters(
    dims: &[(usize, usize)],
    arrays: usize,
    reps: usize,
) -> Option<[(usize, u64, u64, u64, f64); 2]> {
    if arrays < 2 {
        return None;
    }
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim1, Tech::Femfet3T)
            .with_array_dims(PROXY_ARRAY, PROXY_ARRAY)
            .with_capacity_words((arrays * PROXY_ARRAY * PROXY_ARRAY) as u64)
            .with_threads(1),
    );
    let reserve = arrays / 2;
    let words = (reserve * PROXY_ARRAY * PROXY_ARRAY) as u64;
    let res = engine.reserve_tenant(words).unwrap();
    let ids: Vec<_> = dims
        .iter()
        .enumerate()
        .map(|(i, &(k, n))| {
            let (pk, pn) = (k / PROXY_SCALE, n / PROXY_SCALE);
            let tenant = if i == 0 { res } else { 0 };
            let w: Vec<i8> = vec![0; pk * pn];
            engine.register_weight_arc_in(w.into(), pk, pn, tenant).unwrap()
        })
        .collect();
    let xs: Vec<Vec<i8>> = dims.iter().map(|&(k, _)| vec![0i8; k / PROXY_SCALE]).collect();
    let one_pass = || {
        for (id, x) in ids.iter().zip(&xs) {
            engine.gemm_resident(*id, x, 1).unwrap();
        }
    };
    one_pass(); // warm
    let before = [engine.tenant_stats(res), engine.tenant_stats(0)];
    for _ in 0..reps {
        one_pass();
    }
    let dr = engine.tenant_stats(res).since(&before[0]);
    let ds = engine.tenant_stats(0).since(&before[1]);
    Some([
        (reserve, dr.hits, dr.misses, dr.evictions, dr.hit_rate()),
        (arrays - reserve, ds.hits, ds.misses, ds.evictions, ds.hit_rate()),
    ])
}

/// Conv-shaped tile mix: the im2col GEMM shapes of AlexNet's five conv
/// layers (k = cin·ksize², n = cout). Their ragged K edges (363, 2400,
/// 2304, 3456) and the narrow first-layer N shard into a mix of full
/// 256-row tiles, short tails and sub-half-width regions — exactly the
/// class where shelf *packing* (not region count) is the capacity
/// currency — so sweeping them through an undersized pool exercises the
/// CLOCK scan's packing path the uniform FC stack never touches.
const CONV_DIMS: [(usize, usize); 5] =
    [(363, 96), (2400, 256), (2304, 384), (3456, 384), (3456, 256)];

/// Deterministic replay of the conv-shaped mix. The 1/8 proxy cannot
/// represent these edges (363 % 128 ≠ 0 would shift the padded 16-row
/// group fractions), so this replay runs at full array size with zero
/// weights: still single-threaded, bit-reproducible on any machine, and
/// the m=1 MAC cost is negligible.
fn conv_replay_counters(
    dims: &[(usize, usize)],
    arrays: usize,
    reps: usize,
) -> (u64, u64, u64, f64) {
    let engine = TernaryGemmEngine::new(
        EngineConfig::new(Design::Cim1, Tech::Femfet3T)
            .with_capacity_words(arrays as u64 * WORDS_PER_ARRAY)
            .with_threads(1),
    );
    assert_eq!(engine.pool_arrays(), arrays);
    let ids: Vec<_> = dims
        .iter()
        .map(|&(k, n)| engine.register_weight(&vec![0i8; k * n], k, n).unwrap())
        .collect();
    let xs: Vec<Vec<i8>> = dims.iter().map(|&(k, _)| vec![0i8; k]).collect();
    let one_pass = || {
        for (id, x) in ids.iter().zip(&xs) {
            engine.gemm_resident(*id, x, 1).unwrap();
        }
    };
    one_pass(); // warm
    let before = engine.stats();
    for _ in 0..reps {
        one_pass();
    }
    let d = engine.stats().since(&before);
    (d.hits, d.misses, d.evictions, d.hit_rate())
}

struct Entry {
    design: String,
    capacity_words: u64,
    arrays: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
    hit_rate: f64,
    inf_per_s: f64,
}

/// 16-row-padded words the layer's tiles occupy (what a pool must hold
/// for all-hit serving, before packing).
fn padded_words(k: usize, n: usize) -> u64 {
    let mut total = 0u64;
    for nt in 0..n.div_ceil(ARRAY) {
        let n_len = ARRAY.min(n - nt * ARRAY);
        for kt in 0..k.div_ceil(ARRAY) {
            let k_len = ARRAY.min(k - kt * ARRAY);
            total += (k_len.div_ceil(16) * 16 * n_len) as u64;
        }
    }
    total
}

fn tiles(k: usize, n: usize) -> u64 {
    (k.div_ceil(ARRAY) * n.div_ceil(ARRAY)) as u64
}

fn main() {
    let fast = std::env::var("SITECIM_BENCH_FAST").is_ok();
    // AlexNet's FC stack (fc6/fc7/fc8), scaled 1/8 in fast mode.
    let dims: Vec<(usize, usize)> = if fast {
        vec![(1152, 512), (512, 512), (512, 128)]
    } else {
        vec![(9216, 4096), (4096, 4096), (4096, 1000)]
    };
    let workload = if fast { "alexnet-fc/8" } else { "alexnet-fc" };
    let reps = if fast { 2 } else { 3 };

    let mut rng = Rng::new(0x5EED);
    let weights: Vec<(Vec<i8>, usize, usize)> =
        dims.iter().map(|&(k, n)| (rng.ternary_vec(k * n, 0.5), k, n)).collect();
    let xs: Vec<Vec<i8>> = dims.iter().map(|&(k, _)| rng.ternary_vec(k, 0.5)).collect();

    let ws_words: u64 = dims.iter().map(|&(k, n)| padded_words(k, n)).sum();
    let tiles_total: u64 = dims.iter().map(|&(k, n)| tiles(k, n)).sum();
    // One array per tile always serves all-hit; sweep fractions of that
    // plus the paper's 2 M-word system budget.
    let fit_words = tiles_total * WORDS_PER_ARRAY;
    let mut caps: Vec<u64> =
        vec![fit_words / 4, fit_words / 2, 3 * fit_words / 4, fit_words, 2 * 1024 * 1024];
    caps.sort_unstable();
    caps.dedup();

    println!("== capacity_bench ({workload}) ==");
    println!(
        "working set: {} layers, {tiles_total} tiles, {ws_words} padded words ({fit_words} words unpacked)",
        dims.len()
    );

    // Machine-independent hit-rate columns from the deterministic
    // single-threaded placement replay (identical grid/packing/eviction
    // structure at 1/8 scale; see module docs). Placement is
    // design-independent, so each capacity is replayed exactly once and
    // shared by all three designs' rows.
    let proxy: Vec<(u64, u64, u64, f64)> = caps
        .iter()
        .map(|&cap| {
            let arrays = ((cap / WORDS_PER_ARRAY) as usize).max(1);
            proxy_hit_counters(&dims, arrays, reps)
        })
        .collect();

    let mut entries: Vec<Entry> = Vec::new();
    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        for (ci, &cap) in caps.iter().enumerate() {
            let engine = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T).with_capacity_words(cap),
            );
            let ids: Vec<_> = weights
                .iter()
                .map(|(w, k, n)| engine.register_weight(w, *k, *n).unwrap())
                .collect();
            // Warm pass: cold programming excluded from the measurement.
            for (id, x) in ids.iter().zip(&xs) {
                engine.gemm_resident(*id, x, 1).unwrap();
            }
            let t0 = Instant::now();
            for _ in 0..reps {
                for (id, x) in ids.iter().zip(&xs) {
                    engine.gemm_resident(*id, x, 1).unwrap();
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            let inf_per_s = reps as f64 / dt;
            let (hits, misses, evictions, hit_rate) = proxy[ci];
            println!(
                "{:<11} cap {:>10} words ({:>3} arrays): hit rate {:>5.1}%  ({} h / {} m / {} e, deterministic replay)  {:.2} inf/s",
                format!("{design:?}"),
                cap,
                engine.pool_arrays(),
                100.0 * hit_rate,
                hits,
                misses,
                evictions,
                inf_per_s,
            );
            entries.push(Entry {
                design: format!("{design:?}"),
                capacity_words: cap,
                arrays: engine.pool_arrays(),
                hits,
                misses,
                evictions,
                hit_rate,
                inf_per_s,
            });
        }
    }

    // Per-tenant hit-rate columns from the same deterministic replay,
    // split two ways: layer 0 in a hard-reserved half-pool partition,
    // layers 1.. in the shared remainder. Placement is design-
    // independent, so one replay per capacity covers all designs; the
    // rows carry no throughput figure (inf_per_s recorded as 0).
    for &cap in &caps {
        let arrays = ((cap / WORDS_PER_ARRAY) as usize).max(1);
        let Some(tenants) = proxy_tenant_counters(&dims, arrays, reps) else {
            println!("tenant replay skipped at cap {cap}: pool too small to split");
            continue;
        };
        for (name, (t_arrays, hits, misses, evictions, hit_rate)) in
            [("tenant:res", tenants[0]), ("tenant:shared", tenants[1])]
        {
            println!(
                "{:<13} cap {:>10} words ({:>3} arrays): hit rate {:>5.1}%  ({} h / {} m / {} e, deterministic replay)",
                name,
                cap,
                t_arrays,
                100.0 * hit_rate,
                hits,
                misses,
                evictions,
            );
            entries.push(Entry {
                design: name.to_string(),
                capacity_words: cap,
                arrays: t_arrays,
                hits,
                misses,
                evictions,
                hit_rate,
                inf_per_s: 0.0,
            });
        }
    }

    // Conv-shaped tile-mix sweep (`conv:mix` rows): the im2col GEMM
    // shapes replayed at full array size (see `conv_replay_counters`),
    // from 1/4 of the one-array-per-tile budget up to fully resident.
    // Ragged short-tail and sub-half-width regions shelf-pack several
    // per array here, so these rows pressure the CLOCK pool on the
    // packing currency the uniform FC stack never exercises. The rows
    // carry no throughput figure (inf_per_s recorded as 0).
    let conv_tiles: u64 = CONV_DIMS.iter().map(|&(k, n)| tiles(k, n)).sum();
    let conv_fit = conv_tiles * WORDS_PER_ARRAY;
    for cap in [conv_fit / 4, conv_fit / 2, 3 * conv_fit / 4, conv_fit] {
        let arrays = ((cap / WORDS_PER_ARRAY) as usize).max(1);
        let (hits, misses, evictions, hit_rate) = conv_replay_counters(&CONV_DIMS, arrays, reps);
        println!(
            "{:<13} cap {:>10} words ({:>3} arrays): hit rate {:>5.1}%  ({} h / {} m / {} e, deterministic replay)",
            "conv:mix", cap, arrays, 100.0 * hit_rate, hits, misses, evictions,
        );
        entries.push(Entry {
            design: "conv:mix".to_string(),
            capacity_words: cap,
            arrays,
            hits,
            misses,
            evictions,
            hit_rate,
            inf_per_s: 0.0,
        });
    }

    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"bench\": \"capacity_sweep\",\n  \"fast_mode\": {fast},\n  \"workload\": \"{workload}\",\n"
    ));
    json.push_str(&format!(
        "  \"working_set_words\": {ws_words},\n  \"fit_words\": {fit_words},\n  \"results\": [\n"
    ));
    for (i, e) in entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"design\": \"{}\", \"capacity_words\": {}, \"arrays\": {}, \"hits\": {}, \"misses\": {}, \"evictions\": {}, \"hit_rate\": {:.4}, \"inf_per_s\": {:.3}}}{}\n",
            e.design,
            e.capacity_words,
            e.arrays,
            e.hits,
            e.misses,
            e.evictions,
            e.hit_rate,
            e.inf_per_s,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_capacity.json", &json) {
        Ok(()) => println!("\nwrote BENCH_capacity.json"),
        Err(e) => eprintln!("\ncould not write BENCH_capacity.json: {e}"),
    }
}
