//! L3 hot-path micro-benchmarks: the functional array MAC (bit-packed
//! fast paths vs scalar reference vs analog model) and the tiled GEMM
//! engine — single- vs multi-threaded, all three backends, the
//! streaming path vs the resident-tile cache at a serving-shaped
//! repeated GEMM, packed-small-tile serving through the region-scoped
//! kernels vs the full-array path, the slice-copy vs zero-copy Arc
//! operand comparison (`arc_speedup`), and per-request vs merged-M
//! serving over a resident weight (`batched_speedup` — the continuous
//! batcher's amortization). §Perf L3(a).
//!
//! Emits `BENCH_engine.json` next to the working directory so future PRs
//! can track the engine's perf trajectory (every entry carries a `mode`
//! of `streaming` or `resident`, plus the per-design resident and
//! region speedups).
//!
//! The `pipelined_speedup` section replays a staggered-arrival serving
//! trace against a resident multi-layer model two ways — layer-0-only
//! admission (one full-pipeline flush per arrival wave) vs boundary
//! admission (`run_pipelined_flush`, late waves merged into the
//! in-flight M-plane at layer boundaries) — equality-checked before
//! timing.
//!
//! `SITECIM_BENCH_FAST=1` shrinks the GEMMs to smoke sizes for CI.
use std::path::Path;
use std::sync::mpsc::{channel, sync_channel};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use sitecim::array::mac::{dot_fast, dot_fast_cim1, dot_ref, Flavor};
use sitecim::array::{make_array, CimArray, Design, Rect, SiTeCim1Array, TernaryStorage};
use sitecim::coordinator::server::Request;
use sitecim::coordinator::{run_pipelined_flush, BatchPolicy, EngineBackend, Metrics};
use sitecim::device::Tech;
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::runtime::Manifest;
use sitecim::util::bench::{config_from_env, run, BenchResult};
use sitecim::util::rng::Rng;

/// Write a servable synthetic MLP (ternary weights per `dims`
/// transition, thresholds, a tiny test set) so the pipelined-batching
/// replay can load a real `EngineBackend`.
fn write_synth_artifacts(dir: &Path, dims: &[usize], rng: &mut Rng) {
    let trit_bytes = |trits: &[i8]| trits.iter().map(|&t| t as u8).collect::<Vec<u8>>();
    let mut weights_json = String::new();
    for i in 0..dims.len() - 1 {
        let (k, n) = (dims[i], dims[i + 1]);
        let w = rng.ternary_vec(k * n, 0.5);
        std::fs::write(dir.join(format!("w{i}.bin")), trit_bytes(&w)).unwrap();
        if i > 0 {
            weights_json.push_str(", ");
        }
        weights_json.push_str(&format!("{{\"file\": \"w{i}.bin\", \"shape\": [{k}, {n}]}}"));
    }
    let in_dim = dims[0];
    let x = rng.ternary_vec(4 * in_dim, 0.5);
    std::fs::write(dir.join("test_x.bin"), trit_bytes(&x)).unwrap();
    std::fs::write(dir.join("test_y.bin"), vec![0u8; 4]).unwrap();
    let thresholds = vec!["0.5"; dims.len() - 2].join(", ");
    let dims_json = dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ");
    let manifest = format!(
        "{{\n  \"batch\": 4,\n  \"dims\": [{dims_json}],\n  \"act_thresholds\": [{thresholds}],\n  \"kernel_shape\": [8, 16, 16],\n  \"files\": {{}},\n  \"weights\": [{weights_json}],\n  \"scales\": [1.0],\n  \"test_set\": {{\"x\": \"test_x.bin\", \"y\": \"test_y.bin\", \"n\": 4, \"in_dim\": {in_dim}}},\n  \"accuracy\": {{}}\n}}\n"
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
}

struct EngineEntry {
    design: Design,
    mode: &'static str,
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    result: BenchResult,
    gmacs_per_s: f64,
}

fn main() {
    let cfg = config_from_env();
    let mut rng = Rng::new(1);
    let mut storage = TernaryStorage::new(256, 256);
    storage.write_matrix(&rng.ternary_vec(256 * 256, 0.5));
    let inputs = rng.ternary_vec(256, 0.5);

    println!("== array_bench (256x256 ternary array, full dot product) ==");
    let fast = run("dot_fast cim1 (bit-packed)", &cfg, || dot_fast_cim1(&storage, &inputs));
    run("dot_fast cim2 (stride-masked)", &cfg, || dot_fast(&storage, &inputs, Flavor::Cim2));
    let slow = run("dot_ref cim1 (scalar spec)", &cfg, || dot_ref(&storage, &inputs, Flavor::Cim1));
    run("dot_ref cim2 (strided)", &cfg, || dot_ref(&storage, &inputs, Flavor::Cim2));

    let mut arr = SiTeCim1Array::new(Tech::Femfet3T);
    arr.write_matrix(&rng.ternary_vec(256 * 256, 0.5));
    let mut mc_rng = Rng::new(2);
    run("dot_analog_mc σ=16mV (circuit model)", &cfg, || {
        arr.dot_analog_mc(&inputs, 0.016, &mut mc_rng)
    });

    println!(
        "\nbit-packing speedup over scalar spec: {:.1}x",
        slow.mean_s / fast.mean_s
    );
    // Equivalent simulated-hardware rate for context: one array does 16
    // windows per dot; FEMFET CiM I window ≈ 0.78 ns.
    println!(
        "functional sim rate: {:.1} M dot-products/s/array (hardware would do ~80 M/s)",
        1.0 / fast.mean_s / 1e6
    );

    let fast_mode = std::env::var("SITECIM_BENCH_FAST").is_ok();
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(4);
    let mut entries: Vec<EngineEntry> = Vec::new();

    // ---- batched GEMM over the tiled engine (streaming path) ----
    let (m, k, n) = if fast_mode { (32, 256, 256) } else { (1024, 1024, 1024) };
    println!("\n== engine_bench (ternary GEMM {m}x{k}x{n}, pool of 32 256x256 arrays) ==");
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    let macs = (m * k * n) as f64;

    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        for t in [1usize, threads] {
            let engine =
                TernaryGemmEngine::new(EngineConfig::new(design, Tech::Femfet3T).with_threads(t));
            let name = format!("engine {:<11} {t:>2} thread(s)", format!("{design:?}"));
            let result = run(&name, &cfg, || engine.gemm(&x, &w, m, k, n).unwrap());
            let gmacs_per_s = macs / result.mean_s / 1e9;
            entries.push(EngineEntry {
                design,
                mode: "streaming",
                threads: t,
                m,
                k,
                n,
                result,
                gmacs_per_s,
            });
        }
    }

    println!();
    for pair in entries.chunks(2) {
        let (single, multi) = (&pair[0], &pair[1]);
        let speedup = single.result.mean_s / multi.result.mean_s;
        println!(
            "{:?}: {:.2} GMAC/s single → {:.2} GMAC/s on {} threads ({speedup:.2}x){}",
            single.design,
            single.gmacs_per_s,
            multi.gmacs_per_s,
            multi.threads,
            if speedup > 1.0 { "" } else { "  ** multi-thread NOT faster **" }
        );
    }

    // ---- streaming vs resident at a serving-shaped repeated GEMM ----
    // Small batches over a fixed weight: the serving regime where the
    // resident-tile cache amortizes tile programming away. The working
    // set fits the pool exactly (one array per tile), so after the warm
    // pass every placement hits.
    let (sm, sk, sn) = if fast_mode { (4, 256, 256) } else { (8, 1024, 1024) };
    println!("\n== engine_bench serving shape ({sm}x{sk}x{sn}, fully-resident working set) ==");
    let sx = rng.ternary_vec(sm * sk, 0.5);
    let sw = rng.ternary_vec(sk * sn, 0.5);
    let smacs = (sm * sk * sn) as f64;
    let mut speedups: Vec<(Design, f64)> = Vec::new();
    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        let base = EngineConfig::new(design, Tech::Femfet3T).with_threads(threads);
        let tiles = base.tiles_for(sk, sn);

        let streaming = TernaryGemmEngine::new(base.clone().with_pool(tiles.max(1)));
        let name = format!("engine {:<11} streaming rep", format!("{design:?}"));
        let rs = run(&name, &cfg, || streaming.gemm(&sx, &sw, sm, sk, sn).unwrap());
        entries.push(EngineEntry {
            design,
            mode: "streaming",
            threads,
            m: sm,
            k: sk,
            n: sn,
            result: rs.clone(),
            gmacs_per_s: smacs / rs.mean_s / 1e9,
        });

        let resident = TernaryGemmEngine::new(base.with_pool(tiles.max(1)));
        let id = resident.register_weight(&sw, sk, sn).unwrap();
        let name = format!("engine {:<11} resident rep", format!("{design:?}"));
        let rr = run(&name, &cfg, || resident.gemm_resident(id, &sx, sm).unwrap());
        entries.push(EngineEntry {
            design,
            mode: "resident",
            threads,
            m: sm,
            k: sk,
            n: sn,
            result: rr.clone(),
            gmacs_per_s: smacs / rr.mean_s / 1e9,
        });

        let speedup = rs.mean_s / rr.mean_s;
        let s = resident.stats();
        println!(
            "{:?}: resident {:.2}x streaming ({:.2} → {:.2} GMAC/s; cache {} hits / {} misses){}",
            design,
            speedup,
            smacs / rs.mean_s / 1e9,
            smacs / rr.mean_s / 1e9,
            s.hits,
            s.misses,
            if speedup >= 3.0 { "" } else { "  ** resident < 3x **" }
        );
        speedups.push((design, speedup));
    }

    // ---- packed-small-tile serving: region-scoped vs full-array ----
    // 16 small tiles (64×64) packed onto one 256×256 array — the shape
    // sub-array packing produces. The full-array path (what the engine
    // executed before the region kernels) runs every tile's dot as a
    // whole-array `dot_batch` on zero-padded inputs and slices the
    // tile's columns; the region path cycles only the tile's 16-row
    // groups and column span. The accounting always charged the
    // occupied windows — `region_speedup` measures the wall-clock
    // finally matching it.
    let (rm, tiles_per_side) = if fast_mode { (2usize, 4usize) } else { (8usize, 4usize) };
    let tile = 256 / tiles_per_side;
    println!(
        "\n== engine_bench packed small tiles ({n} {tile}x{tile} tiles / 256x256 array, batch {rm}) ==",
        n = tiles_per_side * tiles_per_side
    );
    let mut region_speedups: Vec<(Design, f64)> = Vec::new();
    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        let mut arr = make_array(design, Tech::Femfet3T, 256, 256);
        arr.write_matrix(&rng.ternary_vec(256 * 256, 0.5));
        let rects: Vec<Rect> = (0..tiles_per_side * tiles_per_side)
            .map(|i| Rect {
                row0: tile * (i / tiles_per_side),
                rows: tile,
                col0: tile * (i % tiles_per_side),
                cols: tile,
            })
            .collect();
        let region_inputs: Vec<Vec<i8>> =
            rects.iter().map(|r| rng.ternary_vec(rm * r.rows, 0.5)).collect();
        // Zero-padded full-array inputs, as the pre-region engine built.
        let padded_inputs: Vec<Vec<i8>> = rects
            .iter()
            .zip(&region_inputs)
            .map(|(rect, xs)| {
                let mut padded = vec![0i8; rm * 256];
                for v in 0..rm {
                    padded[v * 256 + rect.row0..v * 256 + rect.row0 + rect.rows]
                        .copy_from_slice(&xs[v * rect.rows..(v + 1) * rect.rows]);
                }
                padded
            })
            .collect();
        // Sanity: the region kernel is the full path's column slice.
        for (rect, (xs, padded)) in rects.iter().zip(region_inputs.iter().zip(&padded_inputs)) {
            let got = arr.dot_batch_region(rect, xs, rm);
            let full = arr.dot_batch(padded, rm);
            for v in 0..rm {
                assert_eq!(
                    &got[v * rect.cols..(v + 1) * rect.cols],
                    &full[v * 256 + rect.col0..v * 256 + rect.col0 + rect.cols],
                    "region kernel diverged from full-array slice"
                );
            }
        }
        let name = format!("packed {:<11} full-array", format!("{design:?}"));
        let rf = run(&name, &cfg, || {
            let mut acc = 0i64;
            for (rect, padded) in rects.iter().zip(&padded_inputs) {
                let full = arr.dot_batch(padded, rm);
                for v in 0..rm {
                    acc += full[v * 256 + rect.col0] as i64;
                }
            }
            acc
        });
        let name = format!("packed {:<11} region", format!("{design:?}"));
        let rr = run(&name, &cfg, || {
            let mut acc = 0i64;
            for (rect, xs) in rects.iter().zip(&region_inputs) {
                let out = arr.dot_batch_region(rect, xs, rm);
                for v in 0..rm {
                    acc += out[v * rect.cols] as i64;
                }
            }
            acc
        });
        let speedup = rf.mean_s / rr.mean_s;
        println!(
            "{:?}: region {speedup:.2}x full-array{}",
            design,
            if speedup > 1.0 { "" } else { "  ** region NOT faster **" }
        );
        region_speedups.push((design, speedup));
    }

    // ---- streaming overhead: slice-copy vs Arc operand path ----
    // The same GEMM through the slice surface (`gemm` — one operand copy
    // at the API boundary) and the zero-copy Arc surface (`gemm_arc` —
    // the job shares the caller's planes, workers reuse scratch).
    // Equality-checked before timing; `arc_speedup` is the constant
    // orchestration overhead the Arc data path shaves off streaming.
    let (am, ak, an) = if fast_mode { (2usize, 256usize, 256usize) } else { (4, 1024, 1024) };
    println!("\n== engine_bench streaming overhead ({am}x{ak}x{an}, slice-copy vs Arc) ==");
    let ax: Arc<[i8]> = rng.ternary_vec(am * ak, 0.5).into();
    let aw: Arc<[i8]> = rng.ternary_vec(ak * an, 0.5).into();
    let amacs = (am * ak * an) as f64;
    let mut arc_speedups: Vec<(Design, f64)> = Vec::new();
    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        let engine =
            TernaryGemmEngine::new(EngineConfig::new(design, Tech::Femfet3T).with_threads(threads));
        let via_slice = engine.gemm(&ax, &aw, am, ak, an).unwrap();
        let via_arc = engine.gemm_arc(Arc::clone(&ax), Arc::clone(&aw), am, ak, an).unwrap();
        assert_eq!(via_slice, via_arc, "slice and Arc paths diverged");
        let name = format!("overhead {:<11} slice-copy", format!("{design:?}"));
        let rs = run(&name, &cfg, || engine.gemm(&ax, &aw, am, ak, an).unwrap());
        entries.push(EngineEntry {
            design,
            mode: "streaming-slice",
            threads,
            m: am,
            k: ak,
            n: an,
            result: rs.clone(),
            gmacs_per_s: amacs / rs.mean_s / 1e9,
        });
        let name = format!("overhead {:<11} arc", format!("{design:?}"));
        let ra = run(&name, &cfg, || {
            engine.gemm_arc(Arc::clone(&ax), Arc::clone(&aw), am, ak, an).unwrap()
        });
        entries.push(EngineEntry {
            design,
            mode: "streaming-arc",
            threads,
            m: am,
            k: ak,
            n: an,
            result: ra.clone(),
            gmacs_per_s: amacs / ra.mean_s / 1e9,
        });
        let speedup = rs.mean_s / ra.mean_s;
        println!(
            "{:?}: arc {speedup:.2}x slice-copy{}",
            design,
            if speedup >= 1.0 { "" } else { "  ** arc NOT faster **" }
        );
        arc_speedups.push((design, speedup));
    }

    // ---- continuous batching: per-request vs merged-M serving ----
    // The serving-shaped comparison behind the coordinator's continuous
    // batcher: R independent single-row requests against a resident
    // weight, executed either as R separate M=1 pipeline passes
    // (per-request serving) or as one merged R×K plane (one GEMM with
    // M = R). Equality-checked before timing; `batched_speedup` is the
    // orchestration amortization the merged M dimension buys on a
    // streaming-dominated workload.
    let (br, bk, bn) = if fast_mode { (8usize, 256usize, 256usize) } else { (32, 1024, 1024) };
    println!(
        "\n== engine_bench continuous batching ({br} requests of 1x{bk}x{bn}, per-request vs merged-M) =="
    );
    let bw = rng.ternary_vec(bk * bn, 0.5);
    let rows: Vec<Arc<[i8]>> = (0..br).map(|_| rng.ternary_vec(bk, 0.5).into()).collect();
    let plane: Arc<[i8]> =
        rows.iter().flat_map(|r| r.iter().copied()).collect::<Vec<i8>>().into();
    let bmacs = (br * bk * bn) as f64;
    let mut batched_speedups: Vec<(Design, f64)> = Vec::new();
    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        let base = EngineConfig::new(design, Tech::Femfet3T).with_threads(threads);
        let tiles = base.tiles_for(bk, bn);
        let engine = TernaryGemmEngine::new(base.with_pool(tiles.max(1)));
        let id = engine.register_weight(&bw, bk, bn).unwrap();
        // Equality first: the merged plane must be the per-request
        // results concatenated in submission order, bit for bit.
        let mut serial = Vec::with_capacity(br * bn);
        for row in &rows {
            serial.extend(engine.gemm_resident_arc(id, Arc::clone(row), 1).unwrap());
        }
        let merged = engine.gemm_resident_arc(id, Arc::clone(&plane), br).unwrap();
        assert_eq!(serial, merged, "merged M-plane diverged from per-request serial");
        let name = format!("batching {:<11} per-request", format!("{design:?}"));
        let rp = run(&name, &cfg, || {
            let mut acc = 0i64;
            for row in &rows {
                acc += engine.gemm_resident_arc(id, Arc::clone(row), 1).unwrap()[0] as i64;
            }
            acc
        });
        entries.push(EngineEntry {
            design,
            mode: "serving-per-request",
            threads,
            m: 1,
            k: bk,
            n: bn,
            result: rp.clone(),
            gmacs_per_s: bmacs / rp.mean_s / 1e9,
        });
        let name = format!("batching {:<11} merged-M", format!("{design:?}"));
        let rb = run(&name, &cfg, || engine.gemm_resident_arc(id, Arc::clone(&plane), br).unwrap());
        entries.push(EngineEntry {
            design,
            mode: "serving-merged",
            threads,
            m: br,
            k: bk,
            n: bn,
            result: rb.clone(),
            gmacs_per_s: bmacs / rb.mean_s / 1e9,
        });
        let speedup = rp.mean_s / rb.mean_s;
        println!(
            "{:?}: merged-M {speedup:.2}x per-request{}",
            design,
            if speedup >= 1.0 { "" } else { "  ** merged NOT faster **" }
        );
        batched_speedups.push((design, speedup));
    }

    // ---- layer-pipelined batching: boundary admission vs layer-0-only ----
    // Staggered-arrival replay over a resident multi-layer MLP: `waves`
    // waves of rows, the first present at flush formation, the rest
    // arriving while the flush is mid-pipeline. Layer-0-only admission
    // (the pre-pipelined engine loop) runs one full-pipeline flush per
    // wave; boundary admission merges each late wave into the in-flight
    // M-plane at the next layer boundary (catch-up GEMMs through the
    // layers it missed, against the same resident weights) and finishes
    // in a single flush. Equality-checked before timing;
    // `pipelined_speedup` is the throughput ratio and
    // `pipelined_rows_per_flush` the rows-per-flush ratio (exactly
    // `waves`, by construction).
    let pdims: Vec<usize> =
        if fast_mode { vec![256, 128, 64, 8] } else { vec![1024, 512, 256, 8] };
    let pr = if fast_mode { 8usize } else { 32 };
    let waves = pdims.len() - 1;
    println!(
        "\n== engine_bench layer-pipelined batching ({waves} waves x {pr} rows, {}-layer MLP) ==",
        pdims.len() - 1
    );
    let pdir = std::env::temp_dir().join(format!("sitecim-bench-pipelined-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&pdir);
    std::fs::create_dir_all(&pdir).unwrap();
    write_synth_artifacts(&pdir, &pdims, &mut rng);
    let pmanifest = Manifest::load(&pdir).unwrap();
    let wave_inputs: Vec<Vec<Vec<i8>>> = (0..waves)
        .map(|_| (0..pr).map(|_| rng.ternary_vec(pdims[0], 0.5)).collect())
        .collect();
    let wave_planes: Vec<Arc<[i8]>> = wave_inputs.iter().map(|w| w.concat().into()).collect();
    // One wave per boundary: each interior boundary admits exactly the
    // wave that "arrived" while the previous layer ran.
    let policy = BatchPolicy {
        max_batch_rows: waves * pr,
        max_stage_admit_rows: pr,
        ..Default::default()
    };
    let request = |input: &Vec<i8>| {
        let (rtx, _) = sync_channel(1);
        Request { input: input.clone(), enqueued: Instant::now(), resp: rtx }
    };
    let mut pipelined_speedups: Vec<(Design, f64)> = Vec::new();
    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        let backend =
            EngineBackend::load(&pmanifest, design, Tech::Femfet3T, threads, None).unwrap();
        // Layer-0-only reference: every wave is its own flush.
        let serial: Vec<f32> = wave_planes
            .iter()
            .flat_map(|p| backend.run_batch_arc(Arc::clone(p), pr).unwrap())
            .collect();
        // One pipelined flush: wave 0 forms it, later waves sit in the
        // queue and are admitted at successive layer boundaries.
        let pipelined = || {
            let (qtx, qrx) = channel::<Request>();
            for wave in &wave_inputs[1..] {
                for input in wave {
                    qtx.send(request(input)).unwrap();
                }
            }
            let rx = Mutex::new(qrx);
            let metrics = Metrics::new();
            let mut items: Vec<Request> = wave_inputs[0].iter().map(&request).collect();
            let logits = run_pipelined_flush(
                &backend,
                &policy,
                &rx,
                &metrics,
                &mut items,
                Arc::clone(&wave_planes[0]),
            )
            .unwrap();
            (logits, metrics)
        };
        // Equality first, and every interior boundary must actually have
        // admitted its wave — otherwise the comparison silently
        // degenerates to two layer-0-only runs.
        let (plogits, pmetrics) = pipelined();
        assert_eq!(plogits, serial, "{design:?}: pipelined flush diverged from layer-0-only");
        let hist = pmetrics.stage_admit_histogram();
        for li in 1..waves {
            assert_eq!(
                hist[li].rows, pr as u64,
                "{design:?}: boundary {li} admitted a full wave"
            );
        }
        let name = format!("pipelined {:<11} layer0-only", format!("{design:?}"));
        let r0 = run(&name, &cfg, || {
            let mut acc = 0f64;
            for p in &wave_planes {
                acc += backend.run_batch_arc(Arc::clone(p), pr).unwrap()[0] as f64;
            }
            acc
        });
        let name = format!("pipelined {:<11} boundary", format!("{design:?}"));
        let rp = run(&name, &cfg, || pipelined().0.len());
        let speedup = r0.mean_s / rp.mean_s;
        println!(
            "{:?}: boundary admission {speedup:.2}x layer-0-only ({} vs {} rows/flush){}",
            design,
            waves * pr,
            pr,
            if speedup > 1.0 { "" } else { "  ** pipelined NOT faster **" }
        );
        pipelined_speedups.push((design, speedup));
    }
    let _ = std::fs::remove_dir_all(&pdir);

    // ---- perf-trajectory record ----
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"bench\": \"engine_gemm\",\n  \"fast_mode\": {fast_mode},\n  \"results\": [\n"
    ));
    for (i, e) in entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"design\": \"{:?}\", \"mode\": \"{}\", \"threads\": {}, \"m\": {}, \"k\": {}, \"n\": {}, \"mean_s\": {:.6}, \"min_s\": {:.6}, \"gmacs_per_s\": {:.3}}}{}\n",
            e.design,
            e.mode,
            e.threads,
            e.m,
            e.k,
            e.n,
            e.result.mean_s,
            e.result.min_s,
            e.gmacs_per_s,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"resident_speedup\": {\n");
    for (i, (design, s)) in speedups.iter().enumerate() {
        json.push_str(&format!(
            "    \"{design:?}\": {s:.3}{}\n",
            if i + 1 < speedups.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n  \"region_speedup\": {\n");
    for (i, (design, s)) in region_speedups.iter().enumerate() {
        json.push_str(&format!(
            "    \"{design:?}\": {s:.3}{}\n",
            if i + 1 < region_speedups.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n  \"arc_speedup\": {\n");
    for (i, (design, s)) in arc_speedups.iter().enumerate() {
        json.push_str(&format!(
            "    \"{design:?}\": {s:.3}{}\n",
            if i + 1 < arc_speedups.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n  \"batched_speedup\": {\n");
    for (i, (design, s)) in batched_speedups.iter().enumerate() {
        json.push_str(&format!(
            "    \"{design:?}\": {s:.3}{}\n",
            if i + 1 < batched_speedups.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n  \"pipelined_speedup\": {\n");
    for (i, (design, s)) in pipelined_speedups.iter().enumerate() {
        json.push_str(&format!(
            "    \"{design:?}\": {s:.3}{}\n",
            if i + 1 < pipelined_speedups.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n  \"pipelined_rows_per_flush\": {\n");
    for (i, (design, _)) in pipelined_speedups.iter().enumerate() {
        json.push_str(&format!(
            "    \"{design:?}\": {:.3}{}\n",
            waves as f64,
            if i + 1 < pipelined_speedups.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    match std::fs::write("BENCH_engine.json", &json) {
        Ok(()) => println!("\nwrote BENCH_engine.json"),
        Err(e) => eprintln!("\ncould not write BENCH_engine.json: {e}"),
    }
}
