//! L3 hot-path micro-benchmarks: the functional array MAC (bit-packed
//! fast path vs scalar reference vs analog model). §Perf L3(a).
use sitecim::array::mac::{dot_fast_cim1, dot_ref, Flavor};
use sitecim::array::{SiTeCim1Array, TernaryStorage};
use sitecim::device::Tech;
use sitecim::util::bench::{config_from_env, run};
use sitecim::util::rng::Rng;

fn main() {
    let cfg = config_from_env();
    let mut rng = Rng::new(1);
    let mut storage = TernaryStorage::new(256, 256);
    storage.write_matrix(&rng.ternary_vec(256 * 256, 0.5));
    let inputs = rng.ternary_vec(256, 0.5);

    println!("== array_bench (256x256 ternary array, full dot product) ==");
    let fast = run("dot_fast_cim1 (bit-packed)", &cfg, || dot_fast_cim1(&storage, &inputs));
    let slow = run("dot_ref cim1 (scalar spec)", &cfg, || dot_ref(&storage, &inputs, Flavor::Cim1));
    run("dot_ref cim2 (strided)", &cfg, || dot_ref(&storage, &inputs, Flavor::Cim2));

    let mut arr = SiTeCim1Array::new(Tech::Femfet3T);
    arr.write_matrix(&rng.ternary_vec(256 * 256, 0.5));
    let mut mc_rng = Rng::new(2);
    run("dot_analog_mc σ=16mV (circuit model)", &cfg, || {
        arr.dot_analog_mc(&inputs, 0.016, &mut mc_rng)
    });

    println!(
        "\nbit-packing speedup over scalar spec: {:.1}x",
        slow.mean_s / fast.mean_s
    );
    // Equivalent simulated-hardware rate for context: one array does 16
    // windows per dot; FEMFET CiM I window ≈ 0.78 ns.
    println!(
        "functional sim rate: {:.1} M dot-products/s/array (hardware would do ~80 M/s)",
        1.0 / fast.mean_s / 1e6
    );
}
