//! L3 hot-path micro-benchmarks: the functional array MAC (bit-packed
//! fast paths vs scalar reference vs analog model) and the tiled GEMM
//! engine (single- vs multi-threaded, all three backends). §Perf L3(a).
//!
//! Emits `BENCH_engine.json` next to the working directory so future PRs
//! can track the engine's perf trajectory.
//!
//! `SITECIM_BENCH_FAST=1` shrinks the GEMM to a smoke size for CI.
use sitecim::array::mac::{dot_fast, dot_fast_cim1, dot_ref, Flavor};
use sitecim::array::{CimArray, Design, SiTeCim1Array, TernaryStorage};
use sitecim::device::Tech;
use sitecim::engine::{EngineConfig, TernaryGemmEngine};
use sitecim::util::bench::{config_from_env, run, BenchResult};
use sitecim::util::rng::Rng;

struct EngineEntry {
    design: Design,
    threads: usize,
    result: BenchResult,
    gmacs_per_s: f64,
}

fn main() {
    let cfg = config_from_env();
    let mut rng = Rng::new(1);
    let mut storage = TernaryStorage::new(256, 256);
    storage.write_matrix(&rng.ternary_vec(256 * 256, 0.5));
    let inputs = rng.ternary_vec(256, 0.5);

    println!("== array_bench (256x256 ternary array, full dot product) ==");
    let fast = run("dot_fast cim1 (bit-packed)", &cfg, || dot_fast_cim1(&storage, &inputs));
    run("dot_fast cim2 (stride-masked)", &cfg, || dot_fast(&storage, &inputs, Flavor::Cim2));
    let slow = run("dot_ref cim1 (scalar spec)", &cfg, || dot_ref(&storage, &inputs, Flavor::Cim1));
    run("dot_ref cim2 (strided)", &cfg, || dot_ref(&storage, &inputs, Flavor::Cim2));

    let mut arr = SiTeCim1Array::new(Tech::Femfet3T);
    arr.write_matrix(&rng.ternary_vec(256 * 256, 0.5));
    let mut mc_rng = Rng::new(2);
    run("dot_analog_mc σ=16mV (circuit model)", &cfg, || {
        arr.dot_analog_mc(&inputs, 0.016, &mut mc_rng)
    });

    println!(
        "\nbit-packing speedup over scalar spec: {:.1}x",
        slow.mean_s / fast.mean_s
    );
    // Equivalent simulated-hardware rate for context: one array does 16
    // windows per dot; FEMFET CiM I window ≈ 0.78 ns.
    println!(
        "functional sim rate: {:.1} M dot-products/s/array (hardware would do ~80 M/s)",
        1.0 / fast.mean_s / 1e6
    );

    // ---- batched GEMM over the tiled engine ----
    let fast_mode = std::env::var("SITECIM_BENCH_FAST").is_ok();
    let (m, k, n) = if fast_mode { (32, 256, 256) } else { (1024, 1024, 1024) };
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(4);
    println!("\n== engine_bench (ternary GEMM {m}x{k}x{n}, pool of 32 256x256 arrays) ==");
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    let macs = (m * k * n) as f64;

    let mut entries: Vec<EngineEntry> = Vec::new();
    for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
        for t in [1usize, threads] {
            let engine =
                TernaryGemmEngine::new(EngineConfig::new(design, Tech::Femfet3T).with_threads(t));
            let name = format!("engine {:<11} {t:>2} thread(s)", format!("{design:?}"));
            let result = run(&name, &cfg, || engine.gemm(&x, &w, m, k, n));
            let gmacs_per_s = macs / result.mean_s / 1e9;
            entries.push(EngineEntry { design, threads: t, result, gmacs_per_s });
        }
    }

    println!();
    for pair in entries.chunks(2) {
        let (single, multi) = (&pair[0], &pair[1]);
        let speedup = single.result.mean_s / multi.result.mean_s;
        println!(
            "{:?}: {:.2} GMAC/s single → {:.2} GMAC/s on {} threads ({speedup:.2}x){}",
            single.design,
            single.gmacs_per_s,
            multi.gmacs_per_s,
            multi.threads,
            if speedup > 1.0 { "" } else { "  ** multi-thread NOT faster **" }
        );
    }

    // ---- perf-trajectory record ----
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"bench\": \"engine_gemm\",\n  \"m\": {m},\n  \"k\": {k},\n  \"n\": {n},\n  \"fast_mode\": {fast_mode},\n  \"results\": [\n"
    ));
    for (i, e) in entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"design\": \"{:?}\", \"threads\": {}, \"mean_s\": {:.6}, \"min_s\": {:.6}, \"gmacs_per_s\": {:.3}}}{}\n",
            e.design,
            e.threads,
            e.result.mean_s,
            e.result.min_s,
            e.gmacs_per_s,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_engine.json", &json) {
        Ok(()) => println!("\nwrote BENCH_engine.json"),
        Err(e) => eprintln!("\ncould not write BENCH_engine.json: {e}"),
    }
}
