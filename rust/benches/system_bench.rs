//! System-simulator benchmarks: full benchmark-suite evaluation cost —
//! this is what `figures --fig12/--fig13` pays. §Perf L3(b).
use sitecim::arch::{AccelConfig, Accelerator};
use sitecim::array::area::Design;
use sitecim::device::Tech;
use sitecim::dnn::benchmarks;
use sitecim::util::bench::{config_from_env, run};

fn main() {
    let cfg = config_from_env();
    println!("== system_bench ==");
    let nets = benchmarks::suite();
    run("accel.run(AlexNet) CiM I", &cfg, || {
        Accelerator::new(AccelConfig::sitecim(Tech::Sram8T, Design::Cim1)).run(&nets[0])
    });
    run("accel.run(ResNet34) CiM I", &cfg, || {
        Accelerator::new(AccelConfig::sitecim(Tech::Sram8T, Design::Cim1)).run(&nets[1])
    });
    let accel = Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, Design::Cim1));
    run("accel.run full suite (prebuilt accel)", &cfg, || {
        nets.iter().map(|n| accel.run(n).latency).sum::<f64>()
    });
}
