//! System-simulator benchmarks: full benchmark-suite evaluation cost —
//! this is what `figures --fig12/--fig13` pays — plus the functional
//! co-simulation path (analytic accounting vs executed engine) in both
//! weight-residency modes. §Perf L3(b).
use std::time::Instant;

use sitecim::arch::{AccelConfig, Accelerator, CosimConfig, Residency};
use sitecim::array::area::Design;
use sitecim::device::Tech;
use sitecim::dnn::benchmarks;
use sitecim::util::bench::{config_from_env, run};

fn main() {
    let cfg = config_from_env();
    println!("== system_bench ==");
    let nets = benchmarks::suite();
    run("accel.run(AlexNet) CiM I", &cfg, || {
        Accelerator::new(AccelConfig::sitecim(Tech::Sram8T, Design::Cim1)).run(&nets[0])
    });
    run("accel.run(ResNet34) CiM I", &cfg, || {
        Accelerator::new(AccelConfig::sitecim(Tech::Sram8T, Design::Cim1)).run(&nets[1])
    });
    let accel = Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, Design::Cim1));
    run("accel.run full suite (prebuilt accel)", &cfg, || {
        nets.iter().map(|n| accel.run(n).latency).sum::<f64>()
    });

    // Streaming vs resident analytic accounting: what steady-state
    // serving saves once weights stay programmed in the arrays.
    let streaming = accel.run_with_residency(&nets[0], Residency::Streaming);
    let resident = accel.run_with_residency(&nets[0], Residency::Resident { inferences: 0 });
    println!(
        "AlexNet CiM I per-inference latency: {:.3e}s streaming → {:.3e}s resident ({:.2}x; write share {:.1}%)",
        streaming.latency,
        resident.latency,
        streaming.latency / resident.latency,
        100.0 * streaming.write_latency / streaming.latency
    );
    // The capacity-bounded analytic model (what `accel.run` charges):
    // the second-chance cache keeps C − 1 of the W packed arrays
    // resident, so only (W − C + 1)/W of the write rows re-program per
    // inference — tighter than the old all-streaming over-capacity bound.
    let bounded = accel.run_with_residency(
        &nets[0],
        Residency::Bounded { capacity_words: accel.cfg.capacity_words(), inferences: 0 },
    );
    let packed = accel.arrays_packed(&nets[0]);
    println!(
        "AlexNet CiM I bounded (2M-word pool, {packed} packed arrays): {:.3e}s/inf — sweep-miss fraction {:.3} vs streaming bound {:.3e}s",
        bounded.latency,
        sitecim::arch::sweep_miss_fraction(packed, accel.cfg.n_arrays as u64),
        streaming.latency
    );

    // Functional co-simulation: one timed pass per mode (the engine
    // executes real tile work, so the bench harness's repeated runs
    // would dominate).
    let ccfg = CosimConfig { max_vectors: 1, max_layers: 5, ..Default::default() };
    let t0 = Instant::now();
    let r = accel.run_cosim(&nets[0], &ccfg);
    println!(
        "cosim AlexNet[..5] CiM I streaming: {:.2}s, {} outputs checked, {} mismatches, {} windows executed, accounting {}",
        t0.elapsed().as_secs_f64(),
        r.total_outputs(),
        r.total_mismatches(),
        r.engine.windows,
        if r.accounting_matches() { "OK" } else { "MISMATCH" }
    );

    let ccfg = CosimConfig {
        max_vectors: 1,
        max_layers: 5,
        resident: true,
        repeats: 3,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = accel.run_cosim(&nets[0], &ccfg);
    println!(
        "cosim AlexNet[..5] CiM I resident ×3: {:.2}s, {} outputs checked, {} mismatches, cache {}h/{}m/{}e, accounting {}",
        t0.elapsed().as_secs_f64(),
        r.total_outputs(),
        r.total_mismatches(),
        r.engine.hits,
        r.engine.misses,
        r.engine.evictions,
        if r.accounting_matches() { "OK" } else { "MISMATCH" }
    );
}
