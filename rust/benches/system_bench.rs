//! System-simulator benchmarks: full benchmark-suite evaluation cost —
//! this is what `figures --fig12/--fig13` pays — plus the functional
//! co-simulation path (analytic accounting vs executed engine). §Perf L3(b).
use std::time::Instant;

use sitecim::arch::{AccelConfig, Accelerator, CosimConfig};
use sitecim::array::area::Design;
use sitecim::device::Tech;
use sitecim::dnn::benchmarks;
use sitecim::util::bench::{config_from_env, run};

fn main() {
    let cfg = config_from_env();
    println!("== system_bench ==");
    let nets = benchmarks::suite();
    run("accel.run(AlexNet) CiM I", &cfg, || {
        Accelerator::new(AccelConfig::sitecim(Tech::Sram8T, Design::Cim1)).run(&nets[0])
    });
    run("accel.run(ResNet34) CiM I", &cfg, || {
        Accelerator::new(AccelConfig::sitecim(Tech::Sram8T, Design::Cim1)).run(&nets[1])
    });
    let accel = Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, Design::Cim1));
    run("accel.run full suite (prebuilt accel)", &cfg, || {
        nets.iter().map(|n| accel.run(n).latency).sum::<f64>()
    });

    // Functional co-simulation: one timed pass (the engine executes real
    // tile work, so the bench harness's repeated runs would dominate).
    let ccfg = CosimConfig { max_vectors: 1, max_layers: 5, ..Default::default() };
    let t0 = Instant::now();
    let r = accel.run_cosim(&nets[0], &ccfg);
    println!(
        "cosim AlexNet[..5] CiM I: {:.2}s, {} outputs checked, {} mismatches, {} windows executed",
        t0.elapsed().as_secs_f64(),
        r.total_outputs(),
        r.total_mismatches(),
        r.engine.windows
    );
}
