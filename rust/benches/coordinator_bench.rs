//! Coordinator benchmarks: batcher throughput and (if artifacts exist)
//! closed-loop serving round-trips. §Perf L3(c).
use std::time::Duration;

use sitecim::coordinator::batcher::{next_batch, BatchPolicy};
use sitecim::coordinator::{Server, ServerConfig};
use sitecim::runtime::{default_dir, Manifest};
use sitecim::util::bench::{config_from_env, run};

fn main() {
    let cfg = config_from_env();
    println!("== coordinator_bench ==");

    // Batcher in isolation: pre-filled queue drain rate.
    run("next_batch over full queue (32)", &cfg, || {
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..32 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_micros(10),
            ..Default::default()
        };
        next_batch(&rx, &policy)
    });

    // End-to-end serving round-trip (needs artifacts).
    if let Ok(manifest) = Manifest::load(default_dir()) {
        let (x, _) = manifest.load_test_set().unwrap();
        let server = Server::start(ServerConfig::new(default_dir())).unwrap();
        let input = x[..manifest.in_dim].to_vec();
        let r = run("server round-trip (single request)", &cfg, || {
            server.infer(input.clone()).unwrap()
        });
        println!("single-request latency: {:.3} ms", r.mean_s * 1e3);
        server.shutdown();
    } else {
        println!("(skipping serving bench: run `make artifacts`)");
    }
}
