//! Regenerates every paper table/figure (the canonical `cargo bench`
//! reproduction output) and times the full harness.
use std::time::Instant;

use sitecim::repro;

fn main() {
    let t0 = Instant::now();
    print!("{}", repro::run_all());
    println!("\n[figures_bench] full reproduction harness: {:.2}s", t0.elapsed().as_secs_f64());
}
