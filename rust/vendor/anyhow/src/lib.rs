//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline crate registry only carries the `xla` dependency tree, so
//! this vendored path dependency implements the small surface the
//! workspace actually uses:
//!
//! - [`Error`]: a context-chained error value (message chain, no
//!   backtraces). `{e}` prints the outermost context, `{e:#}` the whole
//!   chain separated by `: `, matching upstream `anyhow` semantics.
//! - [`Result<T>`] with the `E = Error` default parameter.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Anything an upstream `anyhow` consumer would notice (downcasting,
//! backtrace capture) is intentionally out of scope.

use std::error::Error as StdError;
use std::fmt;

/// A context-chained error. Stored innermost (root cause) first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error { chain: vec![message.to_string()] }
    }

    fn push_context(mut self, context: String) -> Error {
        self.chain.push(context);
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: the whole chain, outermost first.
            for (i, msg) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain.last().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut msgs = self.chain();
        write!(f, "{}", msgs.next().unwrap_or(""))?;
        let causes: Vec<&str> = msgs.collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        msgs.reverse(); // innermost first
        Error { chain: msgs }
    }
}

mod private {
    use super::{Error, StdError};

    /// Sealed conversion used by [`super::Context`]. The blanket impl
    /// covers std errors; the direct impl lets `.context(..)` chain onto
    /// an existing `anyhow::Error` (which deliberately does NOT
    /// implement `std::error::Error`, mirroring upstream).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: StdError + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to errors, like upstream `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: private::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().push_context(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().push_context(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(::std::format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg(::std::format!("{}", $err)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(::std::format!($fmt, $($arg)*)) };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return ::std::result::Result::Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an [`Error`] if the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Result::<(), _>::Err(io_err()).context("loading config").unwrap_err();
        assert_eq!(e.to_string(), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: file missing");
    }

    #[test]
    fn with_context_chains_onto_anyhow_errors() {
        let base: Result<()> = Err(anyhow!("root {}", 7));
        let e = base.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: root 7");
        assert_eq!(e.root_cause(), "root 7");
    }

    #[test]
    fn option_context_creates_error() {
        let e = None::<u32>.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 10 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e: Error = Result::<(), _>::Err(io_err()).context("outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by"));
    }
}
