//! # SiTe CiM — signed ternary computing-in-memory for ultra-low-precision DNNs
//!
//! Full-system reproduction of *"SiTe CiM: Signed Ternary
//! Computing-in-Memory for Ultra-Low Precision Deep Neural Networks"*
//! (Thakuria et al., 2024). The crate layers:
//!
//! - [`device`] — analytic 45 nm FET + FEMFET models and the technology
//!   presets (8T-SRAM / 3T-eDRAM / 3T-FEMFET) that calibrate everything.
//! - [`circuit`] — bit-lines, sensing, ADCs and sense-margin analysis.
//! - [`array`] — the paper's contribution: SiTe CiM I (cross-coupled
//!   bit-cells, voltage sensing) and SiTe CiM II (cross-coupled
//!   sub-columns, current sensing) functional + energy/latency/area
//!   models, against near-memory baselines — all behind the
//!   [`array::CimArray`] trait (see its docs for the grouping /
//!   saturation / flavor contract).
//! - [`engine`] — the tiled ternary GEMM execution engine: maps
//!   arbitrary M×K×N GEMMs onto a pool of `CimArray` backends
//!   (K×N weight-stationary tiling, region-scoped bit-packed MAC
//!   kernels) with a `dot_ref`-composed reference specification.
//!   Placement granularity is independent of the physical arrays: tiles
//!   split into array-fitting shards placed on 16-row-aligned sub-array
//!   *regions*, so small tiles pack several to an array and oversized
//!   tiles shard across arrays with partial-sum recombination; each
//!   shard executes through `CimArray::dot_batch_region`, costing
//!   wall-clock proportional to its occupied window. Execution runs on
//!   a persistent stripe-scheduled worker pool (`engine::exec`): one
//!   work item per (GEMM, shard, n-stripe), load-aware per-slot
//!   affinity for resident shards (deep owner queues spill to the
//!   shallowest), work stealing, per-n-stripe partial-sum merge, and a
//!   zero-copy data path (`Arc<[Trit]>` operand planes + per-worker
//!   scratch) — no per-call thread spawn, no global output mutex, no
//!   per-item allocation in steady state. Two paths:
//!   streaming (shards re-programmed every call) and resident
//!   (`register_weight` + `gemm_resident` — regions placed by the
//!   sweep-resistant second-chance `engine::resident` cache and reused,
//!   with hit/miss/evict counters), bit-identical to each other. Pools
//!   size directly (`with_pool`) or by word budget
//!   (`with_capacity_words`, the paper's 2 M words = 32 arrays),
//!   serving bit-exact under eviction pressure when the working set
//!   exceeds the budget.
//! - [`arch`] — the TiM-DNN-style accelerator (32 arrays, 32 PCUs) plus
//!   iso-capacity / iso-area near-memory baseline systems, explicit
//!   streaming / resident / capacity-bounded weight accounting
//!   (`arch::Residency` — the bounded mode charges the analytic
//!   second-chance sweep-miss rate `arch::sweep_miss_fraction`; packed
//!   array counts from the same shelf packer
//!   the engine uses), and the functional co-simulation mode that
//!   cross-checks the analytic model against the engine in both modes
//!   (outputs *and* work counters).
//! - [`dnn`] — the five benchmark workloads (AlexNet, ResNet34,
//!   Inception, LSTM, GRU) as ternary GEMM workloads.
//! - [`runtime`] — the versioned artifact contract
//!   (`runtime::artifact`: manifest schema v2 with eagerly verified
//!   per-file sha256 checksums and an optional placement plan; legacy
//!   manifests still load) plus the PJRT CPU executor for the
//!   AOT-compiled JAX/Pallas artifacts (python never runs at inference
//!   time; gated behind the `pjrt` feature, stubbed by default).
//! - [`coordinator`] — a thread-based inference service with two
//!   servable backends: per-worker PJRT numerics, or one `Arc`-shared
//!   engine model whose weights stay resident in a single array pool —
//!   server workers submit to the engine's shared executor, and serving
//!   reports *measured* amortized residency costs
//!   (`Server::measured_residency`) from the engine's own counters.
//!   `coordinator::MultiServer` serves N models from one pool:
//!   per-model tenant partitions (hard reservations vs the shared
//!   second-chance remainder), per-tenant metrics books that sum to the
//!   global counters, plan-programmed cold start, and hot-swap that
//!   drains in-flight batches before retiring the old version. The
//!   front door is the [`coordinator::ingress`] admission chain (shape
//!   validation, per-tenant token-bucket rate limiting, watermark load
//!   shedding with hysteresis — all *before* enqueue), and the whole
//!   observable surface freezes into one scrapeable
//!   [`coordinator::MetricsReport`] (`sitecim metrics snapshot`).
//! - [`repro`] — one entry point per paper figure/table.

pub mod arch;
pub mod array;
pub mod circuit;
pub mod cli;
pub mod coordinator;
pub mod device;
pub mod dnn;
pub mod engine;
pub mod repro;
pub mod runtime;
pub mod util;
