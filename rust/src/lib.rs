//! # SiTe CiM — signed ternary computing-in-memory for ultra-low-precision DNNs
//!
//! Full-system reproduction of *"SiTe CiM: Signed Ternary
//! Computing-in-Memory for Ultra-Low Precision Deep Neural Networks"*
//! (Thakuria et al., 2024). The crate layers:
//!
//! - [`device`] — analytic 45 nm FET + FEMFET models and the technology
//!   presets (8T-SRAM / 3T-eDRAM / 3T-FEMFET) that calibrate everything.
//! - [`circuit`] — bit-lines, sensing, ADCs and sense-margin analysis.
//! - [`array`] — the paper's contribution: SiTe CiM I (cross-coupled
//!   bit-cells, voltage sensing) and SiTe CiM II (cross-coupled
//!   sub-columns, current sensing) functional + energy/latency/area
//!   models, against near-memory baselines.
//! - [`arch`] — the TiM-DNN-style accelerator (32 arrays, 32 PCUs) plus
//!   iso-capacity / iso-area near-memory baseline systems.
//! - [`dnn`] — the five benchmark workloads (AlexNet, ResNet34,
//!   Inception, LSTM, GRU) as ternary GEMM workloads.
//! - [`runtime`] — PJRT CPU executor for the AOT-compiled JAX/Pallas
//!   artifacts (python never runs at inference time).
//! - [`coordinator`] — a thread-based inference service over the
//!   simulated accelerator + PJRT numerics.
//! - [`repro`] — one entry point per paper figure/table.

pub mod arch;
pub mod array;
pub mod circuit;
pub mod cli;
pub mod coordinator;
pub mod device;
pub mod dnn;
pub mod repro;
pub mod runtime;
pub mod util;
