//! System-level accelerator simulator (§VI): executes a benchmark network
//! on an `AccelConfig` and accounts latency + energy from the array-level
//! metrics, the PCU/peripheral costs and the weight-streaming writes.
//!
//! Latency: compute windows (CiM cycles or NM row reads) and weight writes
//! serialize over the available arrays; PCU accumulation and the
//! quantize+activation stage are pipelined behind compute (they add
//! energy, not latency — checked against the PCU drain-rate constraint).
//!
//! Weight accounting has three modes ([`Residency`]): **streaming** —
//! every tile programmed once per inference, the paper's batch-1
//! accounting — **resident** — weights programmed once and amortized
//! over the inferences served, the weight-stationary serving regime the
//! functional engine's resident-tile cache implements — and **bounded**,
//! which resolves against the packed working set: amortized when it
//! fits the pool, otherwise the analytic second-chance steady state
//! ([`sweep_miss_fraction`]: W − C + 1 of W packed arrays re-program
//! per inference, matching the engine's measured cyclic-sweep
//! counters). [`Accelerator::run_cosim`] executes the streaming and
//! resident modes on the functional engine and cross-checks the
//! engine's tile/window/write-row counters against [`map_layer`] exactly.

use std::sync::Arc;

use super::config::AccelConfig;
use super::mapper::{map_layer, LayerWork};
use crate::array::area::Design;
use crate::array::encoding::Trit;
use crate::array::metrics::{all_designs, DesignMetrics};
use crate::array::Rect;
use crate::device::{PeriphParams, TechParams};
use crate::dnn::{lower, Layer, Network};
use crate::engine::resident::TileCache;
use crate::engine::tiling::reference_gemm;
use crate::engine::{EngineConfig, EngineStatsSnapshot, TernaryGemmEngine};
use crate::util::rng::Rng;

/// Per-output quantize + activation energy in the digital periphery (J).
const E_ACT_OUT: f64 = 60e-15;

/// How weight programming is charged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// Weights streamed in on every inference (paper batch-1 accounting).
    Streaming,
    /// Weights stay resident in the arrays; the one-time programming is
    /// amortized over `inferences` served. `0` = steady state (fully
    /// amortized to zero); `1` charges the whole programming cost to a
    /// single inference (write *energy* equals the streaming charge;
    /// write latency uses the amortized fractional share, without the
    /// streaming path's per-inference ceil). The serving coordinator
    /// ties this to reality: it charges the `inferences: 0` marginal
    /// cost per request and adds the engine's *measured* programming
    /// counters at report time ([`Accelerator::write_charge`]), so the
    /// amortization horizon is the number of inferences actually served
    /// rather than an assumed steady state.
    Resident { inferences: u64 },
    /// Weights served from a capacity-bounded resident pool of
    /// `capacity_words` ternary words (⌊words / array_words⌋ arrays,
    /// matching `EngineConfig::with_capacity_words`). When the network's
    /// *packed* working set (`LayerWork::arrays_packed` summed over
    /// layers) fits, programming amortizes as `Resident { inferences }`;
    /// when it does not, the charge uses the analytic second-chance
    /// steady-state model ([`sweep_miss_fraction`]): the CLOCK cache
    /// keeps C − 1 of the W packed arrays resident across a cyclic
    /// sweep, so (W − C + 1)/W of each layer's write rows re-program
    /// every inference — exactly the engine's measured steady-state
    /// `write_rows` on the uniform cyclic-sweep workload
    /// (tests/eviction_pressure.rs), and a tight bound where the old
    /// all-streaming charge was the worst case. The measured path,
    /// `Server::measured_residency`, still reports actual hit rates.
    Bounded { capacity_words: u64, inferences: u64 },
}

/// Steady-state miss fraction of the second-chance (CLOCK) placement
/// cache for a working set of `packed` arrays cyclically swept through
/// a pool of `capacity` arrays: the cache keeps C − 1 proven regions
/// resident while the probation slot churns, so W − C + 1 of the W
/// regions miss (and re-program) per pass — the closed form pinned by
/// the measured counters in `tests/eviction_pressure.rs`. `0` when the
/// set fits (no eviction pressure at all), capped at `1` (the streaming
/// worst case) so a zero-capacity argument — callers may not apply the
/// engine's one-array floor — can never charge more than streaming.
pub fn sweep_miss_fraction(packed: u64, capacity: u64) -> f64 {
    if packed <= capacity {
        0.0
    } else {
        ((packed - capacity + 1) as f64 / packed as f64).min(1.0)
    }
}

/// Size-weighted [`sweep_miss_fraction`] for **non-uniform** region
/// sizes: the fraction of the total *write rows* (not regions) that
/// re-program per steady-state pass when `region_rows` (sizes in rows,
/// listed in sweep order) cycle through a pool of `capacity` arrays.
///
/// Mechanism, not hand-waving: at region granularity the second-chance
/// steady state is the same as the uniform case — the scan keeps the
/// *first* `capacity − 1` regions of the sweep resident (their
/// referenced bits are always set when the probe reaches them) while
/// every later region churns through the remaining space — so the rows
/// missed per pass are `S − Σ(first C−1 sizes)` where `S` is the total.
/// With uniform sizes this is `(W − C + 1)/W` of the rows, reducing
/// *exactly* (same real quotient, same IEEE rounding) to the uniform
/// closed form. Pinned against the engine's measured per-pass
/// `write_rows` on a ragged tile grid (seven full tiles plus a tail
/// tile) in `tests/eviction_pressure.rs`.
///
/// Valid for the placement class the engine's weight tiles occupy: one
/// region per array (each region taller than half an array), so region
/// count is the capacity currency. Smaller regions that shelf-pack two
/// (or more) to an array — exactly the mix conv-shaped shard grids
/// produce — live on a different capacity currency (packed rows, not
/// regions) and this form is only a bound there; use
/// [`sweep_miss_fraction_packed`], which replays the real shelf packer
/// and CLOCK scan and is exact for every mix (and bitwise-equal to
/// this closed form on the one-region-per-array class). `0` when the
/// set fits (`W ≤ capacity`).
pub fn sweep_miss_fraction_weighted(region_rows: &[u64], capacity: u64) -> f64 {
    let w = region_rows.len() as u64;
    let total: u64 = region_rows.iter().sum();
    if w <= capacity || total == 0 {
        return 0.0;
    }
    let resident: u64 =
        region_rows.iter().take(capacity.saturating_sub(1) as usize).sum();
    (((total - resident) as f64) / total as f64).min(1.0)
}

/// Steady-state outcome of [`packed_sweep_model`]: the second-chance
/// cache's periodic orbit on a cyclic sweep of shelf-packed regions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedSweepModel {
    /// Passes from cold start until the cache state enters the cycle.
    pub warmup_passes: u64,
    /// Cycle length in passes (1 for the classic one-region-per-array
    /// steady state; packing holes can produce longer orbits).
    pub period: u64,
    /// Weight rows re-programmed over one full cycle — the engine's
    /// measured `write_rows` delta over any `period` consecutive
    /// steady-state passes, content-tag reuse included.
    pub miss_rows_per_cycle: u64,
    /// Total true rows across all regions (one cold pass programs
    /// exactly this).
    pub total_rows: u64,
}

impl PackedSweepModel {
    /// Fraction of the total write rows that re-program per pass,
    /// averaged over the cycle. Bitwise-equal to
    /// [`sweep_miss_fraction_weighted`] on one-region-per-array mixes
    /// (period 1, same integer quotient).
    pub fn miss_fraction(&self) -> f64 {
        if self.total_rows == 0 {
            return 0.0;
        }
        (self.miss_rows_per_cycle as f64 / (self.period * self.total_rows) as f64).min(1.0)
    }
}

/// Upper bound on replay passes before the model gives up looking for a
/// cycle (the CLOCK state space is finite so a cycle always exists;
/// this is a safety valve, not an expected path).
const PACKED_SWEEP_MAX_PASSES: usize = 1024;

/// Packing-aware sweep-miss model: replays a cyclic sweep of `regions`
/// (true `(rows, cols)` per region, in sweep order) against the
/// engine's *actual* placement machinery — the same shelf packer,
/// second-chance victim scan, and content-tag reuse rule the resident
/// path runs — on a pool of `capacity_arrays` arrays of
/// `array_rows × array_cols` cells (floored at one array, like the
/// engine pool), then detects the steady-state cycle of the cache
/// state and returns its period and per-cycle re-programmed rows.
///
/// Two effects make this exact where the closed forms are only bounds:
/// regions at most half an array tall **shelf-pack two (or more) per
/// array**, so the capacity currency is packed rows rather than region
/// count, and programming charges follow the engine's **content tags**
/// — a region evicted and later re-placed at its old rect with the tag
/// intact (nothing overwrote those cells in between) re-programs zero
/// rows despite the placement miss. Both are replayed, not
/// approximated, so the result matches the engine's measured per-pass
/// `write_rows` exactly (cross-checked in `tests/eviction_pressure.rs`
/// on a conv-shaped ragged grid).
pub fn packed_sweep_model(
    regions: &[(usize, usize)],
    capacity_arrays: u64,
    array_rows: usize,
    array_cols: usize,
) -> PackedSweepModel {
    let total_rows: u64 = regions.iter().map(|&(r, _)| r as u64).sum();
    if regions.is_empty() || total_rows == 0 {
        return PackedSweepModel { warmup_passes: 0, period: 1, miss_rows_per_cycle: 0, total_rows };
    }
    let n_slots = capacity_arrays.max(1) as usize;
    let mut cache = TileCache::new(n_slots, array_rows, array_cols);
    // Mirror of each pool slot's content tags (`PoolSlot::programmed`):
    // programming a rect clobbers every overlapping tag; cache eviction
    // leaves tags alone, which is what lets an exact re-placement skip
    // the write.
    let mut tags: Vec<Vec<(Rect, usize)>> = vec![Vec::new(); n_slots];
    let mut signatures = Vec::new();
    let mut miss_rows: Vec<u64> = Vec::new();
    loop {
        let mut pass_rows = 0u64;
        for (i, &(rows, cols)) in regions.iter().enumerate() {
            let p = cache.place((0, i), rows, cols);
            let slot_tags = &mut tags[p.slot];
            let programmed = slot_tags.iter().any(|(r, key)| *r == p.rect && *key == i);
            if !programmed {
                slot_tags.retain(|(r, _)| !r.overlaps(&p.rect));
                slot_tags.push((p.rect, i));
                pass_rows += rows as u64;
            }
        }
        miss_rows.push(pass_rows);
        let sig = (cache.clock_signature(), tags.clone());
        if let Some(first) = signatures.iter().position(|s| *s == sig) {
            // The state after this pass equals the state after pass
            // `first`: passes `first+1 ..= now` form the cycle.
            return PackedSweepModel {
                warmup_passes: first as u64 + 1,
                period: (signatures.len() - first) as u64,
                miss_rows_per_cycle: miss_rows[first + 1..].iter().sum(),
                total_rows,
            };
        }
        signatures.push(sig);
        if signatures.len() >= PACKED_SWEEP_MAX_PASSES {
            // Safety valve: charge the last observed pass as if it were
            // the steady state.
            return PackedSweepModel {
                warmup_passes: signatures.len() as u64 - 1,
                period: 1,
                miss_rows_per_cycle: *miss_rows.last().unwrap(),
                total_rows,
            };
        }
    }
}

/// Packing-aware [`sweep_miss_fraction_weighted`]: the fraction of the
/// total write rows that re-program per steady-state pass when
/// `regions` (true `(rows, cols)` sizes, in sweep order) cycle through
/// a pool of `capacity_arrays` arrays. Exact for shelf-packed mixes
/// (replayed, not closed-form) and bitwise-equal to the weighted
/// closed form on one-region-per-array mixes.
pub fn sweep_miss_fraction_packed(
    regions: &[(usize, usize)],
    capacity_arrays: u64,
    array_rows: usize,
    array_cols: usize,
) -> f64 {
    packed_sweep_model(regions, capacity_arrays, array_rows, array_cols).miss_fraction()
}

/// [`Residency`] resolved against a concrete working set: what
/// `layer_cost` actually charges for weight programming.
#[derive(Clone, Copy, Debug)]
enum Charge {
    /// Full re-programming every inference.
    Streaming,
    /// One-time programming amortized over the horizon.
    Amortized { inferences: u64 },
    /// Capacity-pressured steady state: this fraction of each layer's
    /// write rows misses the second-chance cache (and re-programs)
    /// every inference. Charged as a steady-state average — fractional
    /// pool-parallel latency, no per-inference ceil — matching
    /// [`Accelerator::write_charge`] on the measured miss rows.
    SweepMisses { frac: f64 },
}

/// Execution report for one network on one config.
#[derive(Clone, Debug)]
pub struct SystemReport {
    pub config: String,
    pub network: String,
    /// End-to-end latency per inference (s).
    pub latency: f64,
    /// Energy per inference (J).
    pub energy: f64,
    /// Breakdown.
    pub compute_latency: f64,
    pub write_latency: f64,
    pub compute_energy: f64,
    pub write_energy: f64,
    pub periph_energy: f64,
    pub total_windows: u64,
    pub total_write_rows: u64,
}

impl SystemReport {
    pub fn speedup_vs(&self, base: &SystemReport) -> f64 {
        base.latency / self.latency
    }

    pub fn energy_reduction_vs(&self, base: &SystemReport) -> f64 {
        base.energy / self.energy
    }

    /// Throughput in inferences/second.
    pub fn throughput(&self) -> f64 {
        1.0 / self.latency
    }
}

/// The simulator.
#[derive(Clone, Debug)]
pub struct Accelerator {
    pub cfg: AccelConfig,
    pub metrics: DesignMetrics,
    params: TechParams,
    periph: PeriphParams,
}

impl Accelerator {
    pub fn new(cfg: AccelConfig) -> Accelerator {
        let params = TechParams::new(cfg.tech);
        let periph = PeriphParams::default_45nm();
        let all = all_designs(&params, &periph, cfg.geom);
        let metrics = match cfg.design {
            Design::NearMemory => all[0],
            Design::Cim1 => all[1],
            Design::Cim2 => all[2],
        };
        Accelerator { cfg, metrics, params, periph }
    }

    /// Execute one layer's work accounting under the resolved charge.
    fn layer_cost(&self, w: &LayerWork, charge: Charge) -> (f64, f64, f64, f64, f64) {
        let n_arrays = self.cfg.n_arrays as f64;
        let m = &self.metrics;

        let (compute_latency, compute_energy) = if self.cfg.design == Design::NearMemory {
            // NM: reads serialize at the pipelined row-stream cycle (the
            // per-row share of the 16-read MAC window); the NMC MAC is
            // pipelined behind them.
            let row_cycle = m.mac.latency / self.cfg.geom.n_active as f64;
            let serial_reads = (w.nm_reads as f64 / n_arrays).ceil();
            let lat = serial_reads * row_cycle + self.periph.t_nm_mac;
            let e = w.nm_reads as f64 * (m.mac.energy / self.cfg.geom.n_active as f64);
            (lat, e)
        } else {
            let serial_windows = (w.windows as f64 / n_arrays).ceil();
            (serial_windows * m.mac.latency, w.windows as f64 * m.mac.energy)
        };

        // Weight programming (same write path family for all designs):
        // full charge when streaming, amortized per-inference share when
        // resident, steady-state sweep-miss share when capacity-bounded
        // under pressure. `Residency` is resolved to a `Charge` by
        // `run_with_residency` before layer costing.
        let (write_latency, write_energy) = match charge {
            Charge::Streaming => {
                let serial_writes = (w.write_rows as f64 / n_arrays).ceil();
                (serial_writes * m.write.latency, w.write_rows as f64 * m.write.energy)
            }
            Charge::Amortized { inferences } => {
                let rows = w.write_rows_amortized(inferences);
                // Amortized fractional share: no ceil on a steady-state
                // average.
                (rows / n_arrays * m.write.latency, rows * m.write.energy)
            }
            Charge::SweepMisses { frac } => {
                // The W − C + 1 missing regions re-program every pass;
                // like the amortized arm this is a steady-state average
                // (no ceil), so it equals `write_charge` on the
                // engine's measured steady-state write rows.
                let rows = w.write_rows as f64 * frac;
                (rows / n_arrays * m.write.latency, rows * m.write.energy)
            }
        };

        // Periphery: PCU sample/hold+accumulate per window per column, and
        // quantize+activation per output element.
        let pcu = w.windows as f64 * self.cfg.geom.n_cols as f64 * self.periph.e_pcu;
        let act = w.outputs as f64 * E_ACT_OUT;
        (compute_latency, write_latency, compute_energy, write_energy, pcu + act)
    }

    /// Run a full network with automatic residency: the capacity-bounded
    /// pool at the config's own capacity. Networks whose packed working
    /// set fits on-chip are charged as resident in steady state (weights
    /// programmed once, amortized to zero), larger ones at the analytic
    /// second-chance sweep-miss rate ((W − C + 1)/W of the write rows
    /// per inference — see [`sweep_miss_fraction`]).
    pub fn run(&self, net: &Network) -> SystemReport {
        self.run_with_residency(
            net,
            Residency::Bounded { capacity_words: self.cfg.capacity_words(), inferences: 0 },
        )
    }

    /// The network's packed working set: physical arrays its layers'
    /// tiles occupy under sub-array shelf packing (summed per layer — no
    /// cross-layer array sharing, matching how the accounting keeps
    /// layers separable).
    pub fn arrays_packed(&self, net: &Network) -> u64 {
        net.layers.iter().map(|l| map_layer(&self.cfg, l).arrays_packed).sum()
    }

    /// The simulated cost of programming `rows` weight rows onto a pool
    /// of `n_arrays` arrays: the pool-parallel write latency (rows
    /// serialize over the arrays actually available; an amortized
    /// fractional share, no per-inference ceil — the resident regime's
    /// steady-state average) and the total write energy. `n_arrays` is
    /// explicit because the serving pool can be capacity-bounded well
    /// below the chip's array count — a 1-array bounded pool serializes
    /// every re-program onto that one array. The serving path passes the
    /// engine's *actual* pool size and *measured* `write_rows` counter —
    /// cache misses and streaming-trash re-programs included — so
    /// `serve` reports measured amortized residency costs instead of an
    /// analytic steady-state bound (see
    /// `coordinator::Server::measured_residency`).
    pub fn write_charge(&self, rows: u64, n_arrays: usize) -> (f64, f64) {
        let latency = rows as f64 / n_arrays.max(1) as f64 * self.metrics.write.latency;
        let energy = rows as f64 * self.metrics.write.energy;
        (latency, energy)
    }

    /// Run a full network under an explicit weight-residency mode.
    pub fn run_with_residency(&self, net: &Network, residency: Residency) -> SystemReport {
        // Map every layer once: the Bounded resolution and the costing
        // loop share the same LayerWork (map_layer runs the shelf
        // packer, which is not free on many-tile FC layers).
        let works: Vec<LayerWork> = net.layers.iter().map(|l| map_layer(&self.cfg, l)).collect();
        // Resolve the residency mode against the packed working set
        // once, for the whole network.
        let charge = match residency {
            Residency::Streaming => Charge::Streaming,
            Residency::Resident { inferences } => Charge::Amortized { inferences },
            Residency::Bounded { capacity_words, inferences } => {
                let array_words = (self.cfg.geom.n_rows * self.cfg.geom.n_cols) as u64;
                // Same floor as `EngineConfig::pool_arrays`: the engine
                // always builds at least one array, so the analytic
                // model must not charge misses for a working set that
                // one array would in fact hold resident.
                let capacity_arrays = (capacity_words / array_words).max(1);
                let packed: u64 = works.iter().map(|w| w.arrays_packed).sum();
                if packed <= capacity_arrays {
                    Charge::Amortized { inferences }
                } else {
                    Charge::SweepMisses { frac: sweep_miss_fraction(packed, capacity_arrays) }
                }
            }
        };
        let mut r = SystemReport {
            config: self.cfg.name.clone(),
            network: net.name.clone(),
            latency: 0.0,
            energy: 0.0,
            compute_latency: 0.0,
            write_latency: 0.0,
            compute_energy: 0.0,
            write_energy: 0.0,
            periph_energy: 0.0,
            total_windows: 0,
            total_write_rows: 0,
        };
        for w in &works {
            let (cl, wl, ce, we, pe) = self.layer_cost(w, charge);
            r.compute_latency += cl;
            r.write_latency += wl;
            r.compute_energy += ce;
            r.write_energy += we;
            r.periph_energy += pe;
            r.total_windows += w.windows;
            r.total_write_rows += w.write_rows;
        }
        r.latency = r.compute_latency + r.write_latency;
        r.energy = r.compute_energy + r.write_energy + r.periph_energy;
        r
    }

    pub fn params(&self) -> &TechParams {
        &self.params
    }

    /// The functional GEMM engine matching this accelerator's shape:
    /// same design, tech, array geometry and array count.
    pub fn engine(&self, n_threads: usize) -> TernaryGemmEngine {
        self.engine_sized(n_threads, self.cfg.n_arrays)
    }

    /// Same, with an explicit pool size (the resident co-simulation sizes
    /// the pool to hold the whole working set so the accounting
    /// cross-check is exact).
    pub fn engine_sized(&self, n_threads: usize, n_arrays: usize) -> TernaryGemmEngine {
        TernaryGemmEngine::new(
            EngineConfig::new(self.cfg.design, self.cfg.tech)
                .with_array_dims(self.cfg.geom.n_rows, self.cfg.geom.n_cols)
                .with_pool(n_arrays.max(1))
                .with_threads(n_threads),
        )
    }

    /// Functional co-simulation: actually *execute* (a bounded slice of)
    /// the network's layers on the tiled GEMM engine with random ternary
    /// operands at each layer's recorded sparsity, cross-checking every
    /// output element against the `dot_ref` tile composition, and the
    /// engine's tile/window/write-row counters against [`map_layer`]
    /// exactly. In resident mode the weights are registered once, the
    /// pool is sized to the working set, and repeated passes must hit the
    /// tile cache instead of re-programming.
    ///
    /// Layers carrying lowering metadata execute through [`crate::dnn::lower`]:
    /// conv layers run on a true im2col plane extracted from a random
    /// activation image (and are additionally cross-checked against the
    /// direct-convolution reference, window by window), and recurrent
    /// layers run step by step against resident gate weights with the
    /// hidden state threaded through the deterministic ternary cell
    /// update — in *both* residency modes, since recurrent weights are
    /// stationary by construction.
    pub fn run_cosim(&self, net: &Network, ccfg: &CosimConfig) -> CosimReport {
        let flavor = self.cfg.design.flavor();
        let repeats = ccfg.repeats.max(1);
        let slice: Vec<&Layer> = net.layers.iter().take(ccfg.max_layers).collect();

        // Pool sizing: resident mode must hold every tile of the slice at
        // once so the expected accounting is exact (no evictions).
        // Recurrent layers take the resident path even in streaming mode,
        // so the streaming pool must still hold every recurrent tile.
        let (rows, cols) = (self.cfg.geom.n_rows, self.cfg.geom.n_cols);
        let tiles_of = |l: &Layer| l.gemm.k.div_ceil(rows) * l.gemm.n.div_ceil(cols);
        let total_tiles: usize = slice.iter().map(|l| tiles_of(l)).sum();
        let recurrent_tiles: usize =
            slice.iter().filter(|l| l.rnn.is_some()).map(|l| tiles_of(l)).sum();
        let n_arrays = if ccfg.resident {
            total_tiles.max(1)
        } else {
            self.cfg.n_arrays.max(recurrent_tiles).max(1)
        };
        let engine = self.engine_sized(ccfg.n_threads, n_arrays);

        let mut rng = Rng::new(ccfg.seed);
        let mut layers = Vec::new();
        let mut expected = EngineStatsSnapshot::default();
        for layer in &slice {
            let g = &layer.gemm;
            let w = rng.ternary_vec(g.k * g.n, 1.0 - layer.w_nz);
            let grid = engine.grid(g.k, g.n);

            if let Some(spec) = layer.rnn {
                let steps_run = spec.steps.min(ccfg.max_steps).max(1);
                let xs = rng.ternary_vec(spec.steps * spec.input, 1.0 - layer.act_nz);
                let want =
                    lower::reference_recurrent_trace(&xs, &w, &spec, &grid, flavor, steps_run);

                // Mapper accounting for exactly the steps this cosim
                // runs: each step is one m=1 GEMM over the full gate
                // block, weights programmed once and hit ever after.
                let mut probe = (*layer).clone();
                probe.repeats = steps_run;
                let lw = map_layer(&self.cfg, &probe);
                let calls = (repeats * steps_run) as u64;
                expected.gemms += calls;
                expected.windows += repeats as u64 * lw.windows;
                expected.macs += calls * (g.k * g.n) as u64;
                expected.tiles += lw.tiles;
                expected.write_rows += lw.write_rows;
                expected.misses += lw.tiles;
                expected.hits += (calls - 1) * lw.tiles;

                let id = engine.register_weight(&w, g.k, g.n).expect("cosim weight is valid");
                let mut mismatches = 0u64;
                for _ in 0..repeats {
                    let got = lower::run_recurrent_resident(&engine, id, &xs, &spec, steps_run);
                    for (gs, ws) in got.iter().zip(&want) {
                        mismatches += gs.iter().zip(ws).filter(|(a, b)| a != b).count() as u64;
                    }
                }
                layers.push(CosimLayerReport {
                    name: layer.name.clone(),
                    m: 1,
                    m_full: 1,
                    k: g.k,
                    n: g.n,
                    steps: steps_run,
                    steps_full: spec.steps,
                    truncated: steps_run < spec.steps,
                    outputs: (g.n * steps_run * repeats) as u64,
                    mismatches,
                });
                continue;
            }

            let m = g.m.min(ccfg.max_vectors).max(1);
            let mut direct = None;
            let x: Arc<[Trit]> = match layer.conv {
                Some(geom) => {
                    let image =
                        rng.ternary_vec(geom.cin * geom.in_hw * geom.in_hw, 1.0 - layer.act_nz);
                    direct = Some(lower::conv_ref_direct(&image, &w, &geom, m, &grid, flavor));
                    lower::im2col_plane(&image, &geom, m)
                }
                None => Arc::from(rng.ternary_vec(m * g.k, 1.0 - layer.act_nz)),
            };
            let want = reference_gemm(&x, &w, m, &grid, flavor);
            let mut mismatches = 0u64;
            if let Some(d) = &direct {
                // The im2col lowering itself: the direct-convolution
                // reference must agree with the GEMM-plane reference.
                mismatches += d.iter().zip(&want).filter(|(a, b)| a != b).count() as u64;
            }

            // Mapper accounting for exactly the work this cosim runs.
            let mut probe = (*layer).clone();
            probe.gemm.m = m;
            probe.repeats = 1;
            let lw = map_layer(&self.cfg, &probe);
            expected.gemms += repeats as u64;
            expected.windows += repeats as u64 * lw.windows;
            expected.macs += repeats as u64 * (m * g.k * g.n) as u64;
            if ccfg.resident {
                // Programmed once, hit on every later pass, never evicted.
                expected.tiles += lw.tiles;
                expected.write_rows += lw.write_rows;
                expected.misses += lw.tiles;
                expected.hits += (repeats as u64 - 1) * lw.tiles;
            } else {
                expected.tiles += repeats as u64 * lw.tiles;
                expected.write_rows += repeats as u64 * lw.write_rows;
            }

            let w_arc: Arc<[Trit]> = Arc::from(w);
            if ccfg.resident {
                let id =
                    engine.register_weight_arc(w_arc, g.k, g.n).expect("cosim weight is valid");
                for _ in 0..repeats {
                    let got = engine
                        .gemm_resident_arc(id, x.clone(), m)
                        .expect("cosim shapes are valid");
                    mismatches += got.iter().zip(&want).filter(|(a, b)| a != b).count() as u64;
                }
            } else {
                for _ in 0..repeats {
                    let got = engine
                        .gemm_arc(x.clone(), w_arc.clone(), m, g.k, g.n)
                        .expect("cosim shapes are valid");
                    mismatches += got.iter().zip(&want).filter(|(a, b)| a != b).count() as u64;
                }
            }
            layers.push(CosimLayerReport {
                name: layer.name.clone(),
                m,
                m_full: g.m,
                k: g.k,
                n: g.n,
                steps: 1,
                steps_full: 1,
                truncated: m < g.m,
                outputs: (m * g.n * repeats) as u64,
                mismatches,
            });
        }
        CosimReport {
            config: self.cfg.name.clone(),
            network: net.name.clone(),
            resident: ccfg.resident,
            repeats,
            layers,
            engine: engine.stats(),
            expected,
        }
    }
}

/// Bounds for the functional co-simulation (full benchmark layers are
/// billions of MACs; a few vectors per layer already exercise every tile
/// of every weight matrix).
#[derive(Clone, Debug)]
pub struct CosimConfig {
    /// Input vectors (M rows) to run per layer.
    pub max_vectors: usize,
    /// Layers to co-simulate (front of the network first).
    pub max_layers: usize,
    pub seed: u64,
    /// Engine worker threads.
    pub n_threads: usize,
    /// Use the resident-tile path (register weights once, pool sized to
    /// the working set) instead of streaming every tile every call.
    pub resident: bool,
    /// Passes over the layer slice (>1 exercises the steady-state cache
    /// hit path in resident mode).
    pub repeats: usize,
    /// Recurrent steps to execute per recurrent layer (the full unroll
    /// by default; lower it to bound RNN cosim runtime the same way
    /// `max_vectors` bounds conv/FC layers).
    pub max_steps: usize,
}

impl Default for CosimConfig {
    fn default() -> CosimConfig {
        CosimConfig {
            max_vectors: 2,
            max_layers: usize::MAX,
            seed: 0x517E_C1A0,
            n_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            resident: false,
            repeats: 1,
            max_steps: usize::MAX,
        }
    }
}

/// Per-layer co-simulation outcome.
#[derive(Clone, Debug)]
pub struct CosimLayerReport {
    pub name: String,
    /// Vectors actually executed (after the `max_vectors` bound).
    pub m: usize,
    /// The layer's full M (conv: whole output plane).
    pub m_full: usize,
    pub k: usize,
    pub n: usize,
    /// Recurrent steps actually executed (1 for non-recurrent layers).
    pub steps: usize,
    /// The layer's full unroll length (1 for non-recurrent layers).
    pub steps_full: usize,
    /// True when `max_vectors`/`max_steps` bounded this layer below its
    /// full workload.
    pub truncated: bool,
    pub outputs: u64,
    pub mismatches: u64,
}

/// Co-simulation report: engine outputs vs the tiled `dot_ref`
/// specification (layer by layer), plus engine counters vs the mapper's
/// analytic accounting.
#[derive(Clone, Debug)]
pub struct CosimReport {
    pub config: String,
    pub network: String,
    pub resident: bool,
    pub repeats: usize,
    pub layers: Vec<CosimLayerReport>,
    /// What the engine actually counted.
    pub engine: EngineStatsSnapshot,
    /// What `arch::mapper` accounting predicts for the same work.
    pub expected: EngineStatsSnapshot,
}

impl CosimReport {
    pub fn total_outputs(&self) -> u64 {
        self.layers.iter().map(|l| l.outputs).sum()
    }

    pub fn total_mismatches(&self) -> u64 {
        self.layers.iter().map(|l| l.mismatches).sum()
    }

    /// True when the engine reproduced the reference bit-for-bit.
    pub fn all_match(&self) -> bool {
        self.total_mismatches() == 0
    }

    /// Layers whose executed slice was bounded below the full workload
    /// by `max_vectors` / `max_steps`.
    pub fn truncated_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.truncated).count()
    }

    /// True when the engine's work counters equal the mapper accounting
    /// exactly (tiles programmed, MAC windows, write rows, and — in
    /// resident mode — cache hit/miss/evict counts).
    pub fn accounting_matches(&self) -> bool {
        self.engine == self.expected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Tech;
    use crate::dnn::benchmarks;

    fn run(tech: Tech, design: Design, net: &Network) -> SystemReport {
        let cfg = match design {
            Design::NearMemory => AccelConfig::iso_capacity_nm(tech),
            d => AccelConfig::sitecim(tech, d),
        };
        Accelerator::new(cfg).run(net)
    }

    fn accel_for(design: Design, tech: Tech) -> Accelerator {
        match design {
            Design::NearMemory => Accelerator::new(AccelConfig::iso_capacity_nm(tech)),
            d => Accelerator::new(AccelConfig::sitecim(tech, d)),
        }
    }

    #[test]
    fn cim1_speedup_vs_iso_capacity_in_paper_band() {
        // Paper Fig 12: 6.74X / 6.59X / 7.12X average over the suite.
        for tech in Tech::ALL {
            let mut speedups = Vec::new();
            for net in benchmarks::suite() {
                let cim = run(tech, Design::Cim1, &net);
                let nm = run(tech, Design::NearMemory, &net);
                speedups.push(cim.speedup_vs(&nm));
            }
            let avg = crate::util::stats::mean(&speedups);
            assert!((4.5..=9.5).contains(&avg), "{}: avg speedup {avg:.2}", tech.name());
        }
    }

    #[test]
    fn cim1_energy_reduction_in_paper_band() {
        // Paper: 2.46X / 2.52X / 2.54X average energy reduction.
        for tech in Tech::ALL {
            let mut reds = Vec::new();
            for net in benchmarks::suite() {
                let cim = run(tech, Design::Cim1, &net);
                let nm = run(tech, Design::NearMemory, &net);
                reds.push(cim.energy_reduction_vs(&nm));
            }
            let avg = crate::util::stats::mean(&reds);
            assert!((1.8..=3.6).contains(&avg), "{}: avg energy red {avg:.2}", tech.name());
        }
    }

    #[test]
    fn cim2_slower_than_cim1_but_faster_than_nm() {
        for tech in Tech::ALL {
            let net = benchmarks::alexnet();
            let c1 = run(tech, Design::Cim1, &net);
            let c2 = run(tech, Design::Cim2, &net);
            let nm = run(tech, Design::NearMemory, &net);
            assert!(c2.latency > c1.latency, "{}", tech.name());
            assert!(c2.latency < nm.latency, "{}", tech.name());
            assert!(c2.energy < nm.energy, "{}", tech.name());
        }
    }

    #[test]
    fn iso_area_baseline_faster_than_iso_capacity() {
        let net = benchmarks::resnet34();
        let isoc = Accelerator::new(AccelConfig::iso_capacity_nm(Tech::Sram8T)).run(&net);
        let isoa = Accelerator::new(AccelConfig::iso_area_nm(Tech::Sram8T, Design::Cim1)).run(&net);
        assert!(isoa.latency < isoc.latency);
        // Energy is ~unchanged (same op count — §VI.C).
        let ratio = isoa.energy / isoc.energy;
        assert!((0.95..=1.05).contains(&ratio), "energy ratio {ratio}");
    }

    #[test]
    fn report_breakdown_sums() {
        let net = benchmarks::gru();
        let r = run(Tech::Femfet3T, Design::Cim1, &net);
        assert!((r.latency - (r.compute_latency + r.write_latency)).abs() < 1e-12);
        assert!(
            (r.energy - (r.compute_energy + r.write_energy + r.periph_energy)).abs()
                < 1e-9 * r.energy.max(1.0)
        );
        assert!(r.total_windows > 0);
    }

    #[test]
    fn resident_accounting_interpolates_between_free_and_streaming() {
        let net = benchmarks::alexnet();
        let accel = Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, Design::Cim1));
        let streaming = accel.run_with_residency(&net, Residency::Streaming);
        let one = accel.run_with_residency(&net, Residency::Resident { inferences: 1 });
        let many = accel.run_with_residency(&net, Residency::Resident { inferences: 1000 });
        let steady = accel.run_with_residency(&net, Residency::Resident { inferences: 0 });
        // Amortizing over one inference charges the full write energy.
        assert!((one.write_energy - streaming.write_energy).abs() < 1e-9 * streaming.write_energy);
        assert!((many.write_energy - streaming.write_energy / 1000.0).abs()
            < 1e-9 * streaming.write_energy);
        assert_eq!(steady.write_energy, 0.0);
        assert_eq!(steady.write_latency, 0.0);
        assert!(steady.latency < streaming.latency);
        // Compute is residency-independent.
        assert_eq!(steady.compute_latency, streaming.compute_latency);
    }

    #[test]
    fn bounded_residency_resolves_by_packed_capacity() {
        let accel = Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, Design::Cim1));

        // AlexNet's packed working set exceeds 32 arrays by far: the
        // bounded pool is charged at the analytic second-chance
        // steady-state rate — (W − C + 1)/W of the streaming write
        // energy, strictly below the old all-streaming bound — which is
        // exactly what `run` charges.
        let net = benchmarks::alexnet();
        let packed = accel.arrays_packed(&net);
        assert!(packed > accel.cfg.n_arrays as u64);
        let bounded = accel.run_with_residency(
            &net,
            Residency::Bounded { capacity_words: accel.cfg.capacity_words(), inferences: 0 },
        );
        let streaming = accel.run_with_residency(&net, Residency::Streaming);
        let frac = sweep_miss_fraction(packed, accel.cfg.n_arrays as u64);
        assert!((0.0..1.0).contains(&frac));
        assert!(
            (bounded.write_energy - streaming.write_energy * frac).abs()
                < 1e-9 * streaming.write_energy,
            "sweep-miss energy share: {} vs {} × {frac}",
            bounded.write_energy,
            streaming.write_energy
        );
        assert!(bounded.write_latency < streaming.write_latency);
        assert!(bounded.latency < streaming.latency);
        assert_eq!(bounded.compute_latency, streaming.compute_latency);
        assert_eq!(accel.run(&net).latency, bounded.latency);

        // A small MLP packs into the pool: the bounded charge equals the
        // steady-state resident charge.
        let tiny = Network {
            name: "tiny-mlp".into(),
            layers: vec![
                Layer::linear("fc0", 1, 256, 128),
                Layer::linear("fc1", 1, 128, 64),
            ],
        };
        assert!(accel.arrays_packed(&tiny) <= accel.cfg.n_arrays as u64);
        let bounded = accel.run_with_residency(
            &tiny,
            Residency::Bounded { capacity_words: accel.cfg.capacity_words(), inferences: 0 },
        );
        let resident = accel.run_with_residency(&tiny, Residency::Resident { inferences: 0 });
        assert_eq!(bounded.write_energy, resident.write_energy);
        assert_eq!(bounded.latency, resident.latency);
        // And a starved budget (floored to the engine's one-array
        // minimum, below the 2-array packed set) charges the full sweep:
        // W = 2, C = 1 → miss fraction (2 − 1 + 1)/2 = 1, the whole
        // write energy every inference — the streaming worst case is
        // recovered exactly where it is real.
        assert_eq!(accel.arrays_packed(&tiny), 2);
        assert_eq!(sweep_miss_fraction(2, 1), 1.0);
        let starved = accel.run_with_residency(
            &tiny,
            Residency::Bounded { capacity_words: 0, inferences: 0 },
        );
        let tiny_streaming = accel.run_with_residency(&tiny, Residency::Streaming);
        assert_eq!(starved.write_energy, tiny_streaming.write_energy);
    }

    #[test]
    fn sweep_miss_fraction_closed_form() {
        // Fits → no misses; C = 1 → full streaming; in between, W − C + 1
        // of W regions miss per steady pass (the CLOCK probation churn
        // pinned by tests/eviction_pressure.rs).
        assert_eq!(sweep_miss_fraction(8, 8), 0.0);
        assert_eq!(sweep_miss_fraction(8, 100), 0.0);
        assert_eq!(sweep_miss_fraction(8, 1), 1.0);
        // A floor-less caller passing capacity 0 is capped at streaming.
        assert_eq!(sweep_miss_fraction(8, 0), 1.0);
        assert_eq!(sweep_miss_fraction(8, 3), 6.0 / 8.0);
        assert_eq!(sweep_miss_fraction(8, 7), 2.0 / 8.0);
        // Monotone in capacity under pressure.
        for c in 2..8 {
            assert!(sweep_miss_fraction(8, c) > sweep_miss_fraction(8, c + 1));
        }
    }

    #[test]
    fn weighted_sweep_miss_fraction_closed_form() {
        // Uniform sizes reduce *exactly* to the region-count form: the
        // weighted quotient (W−C+1)s / Ws and the uniform (W−C+1)/W are
        // the same real number, so IEEE division rounds them to the
        // same f64 — `==`, not ≈.
        for s in [1u64, 64, 256, 300] {
            for c in 0..10 {
                assert_eq!(
                    sweep_miss_fraction_weighted(&[s; 8], c),
                    sweep_miss_fraction(8, c),
                    "uniform reduction s={s} c={c}"
                );
            }
        }
        // Ragged tile grid (seven full 256-row tiles + a 128-row tail):
        // the first C − 1 sweep regions stay resident, everything after
        // churns — the values the measured cross-check in
        // tests/eviction_pressure.rs pins against the engine.
        let tail: Vec<u64> = [[256u64; 7].as_slice(), &[128]].concat();
        assert_eq!(sweep_miss_fraction_weighted(&tail, 8), 0.0);
        assert_eq!(sweep_miss_fraction_weighted(&tail, 100), 0.0);
        for cap in 2..8u64 {
            let resident = (cap - 1) * 256;
            assert_eq!(
                sweep_miss_fraction_weighted(&tail, cap),
                (1920 - resident) as f64 / 1920.0,
                "cap {cap}"
            );
        }
        // Floor-less capacities are the streaming worst case, and the
        // fraction is monotone non-increasing in capacity.
        assert_eq!(sweep_miss_fraction_weighted(&tail, 0), 1.0);
        assert_eq!(sweep_miss_fraction_weighted(&tail, 1), 1.0);
        for c in 1..8u64 {
            assert!(
                sweep_miss_fraction_weighted(&tail, c)
                    >= sweep_miss_fraction_weighted(&tail, c + 1)
            );
        }
        // Degenerate inputs stay in range.
        assert_eq!(sweep_miss_fraction_weighted(&[], 0), 0.0);
        assert_eq!(sweep_miss_fraction_weighted(&[0, 0], 1), 0.0);
    }

    #[test]
    fn cosim_engine_matches_reference_on_benchmark_layers() {
        // Functional co-simulation of the front of AlexNet on all three
        // designs: the engine must reproduce the tiled dot_ref spec
        // bit-for-bit, and its work counters must equal the mapper
        // accounting exactly.
        let net = benchmarks::alexnet();
        let ccfg = CosimConfig {
            max_vectors: 1,
            max_layers: 3,
            seed: 7,
            n_threads: 2,
            ..Default::default()
        };
        for design in [Design::Cim1, Design::Cim2, Design::NearMemory] {
            let accel = accel_for(design, Tech::Sram8T);
            let r = accel.run_cosim(&net, &ccfg);
            assert_eq!(r.layers.len(), 3);
            assert!(r.total_outputs() > 0);
            assert!(r.all_match(), "{design:?}: {} mismatches", r.total_mismatches());
            assert!(r.engine.tiles > 0 && r.engine.macs > 0);
            assert!(
                r.accounting_matches(),
                "{design:?}: engine {:?} != mapper {:?}",
                r.engine,
                r.expected
            );
        }
    }

    #[test]
    fn cosim_resident_mode_hits_cache_and_accounts_exactly() {
        let net = benchmarks::alexnet();
        let ccfg = CosimConfig {
            max_vectors: 1,
            max_layers: 2,
            seed: 11,
            n_threads: 2,
            resident: true,
            repeats: 3,
            ..Default::default()
        };
        for design in [Design::Cim1, Design::NearMemory] {
            let accel = accel_for(design, Tech::Femfet3T);
            let r = accel.run_cosim(&net, &ccfg);
            assert!(r.all_match(), "{design:?}: {} mismatches", r.total_mismatches());
            assert!(
                r.accounting_matches(),
                "{design:?}: engine {:?} != mapper {:?}",
                r.engine,
                r.expected
            );
            // Steady state: tiles programmed once, hit twice per tile.
            assert!(r.engine.misses > 0);
            assert_eq!(r.engine.hits, 2 * r.engine.misses);
            assert_eq!(r.engine.evictions, 0);
            assert_eq!(r.engine.tiles, r.engine.misses);
        }
    }

    #[test]
    fn write_charge_scales_linearly_and_matches_resident_accounting() {
        let accel = Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, Design::Cim1));
        let chip = accel.cfg.n_arrays;
        let (l1, e1) = accel.write_charge(1, chip);
        let (l32, e32) = accel.write_charge(32, chip);
        assert!(l1 > 0.0 && e1 > 0.0);
        assert!((l32 - 32.0 * l1).abs() < 1e-18 && (e32 - 32.0 * e1).abs() < 1e-18);
        // A capacity-bounded 1-array pool serializes every write onto
        // that one array: chip-width parallelism must not leak in.
        let (l_one, e_one) = accel.write_charge(32, 1);
        assert!((l_one - chip as f64 * l32).abs() < 1e-9 * l_one);
        assert_eq!(e_one, e32, "energy is parallelism-independent");
        // Charging a network's full write_rows over 1 inference at chip
        // width must reproduce the Resident { inferences: 1 } report.
        let net = benchmarks::alexnet();
        let resident =
            accel.run_with_residency(&net, Residency::Resident { inferences: 1 });
        let rows: u64 = net.layers.iter().map(|l| map_layer(&accel.cfg, l).write_rows).sum();
        let (lat, energy) = accel.write_charge(rows, chip);
        assert!((energy - resident.write_energy).abs() < 1e-9 * resident.write_energy);
        assert!((lat - resident.write_latency).abs() < 1e-9 * resident.write_latency);
    }

    #[test]
    fn recurrent_nets_dominated_by_projection_layer() {
        // Sanity: the 10k-way projection dwarfs the cell GEMMs.
        let net = benchmarks::lstm();
        let r = run(Tech::Sram8T, Design::Cim1, &net);
        assert!(r.total_windows > 100_000);
    }

    #[test]
    fn cosim_recurrent_layers_step_with_exact_per_step_accounting() {
        // The LSTM suite entry under a bounded unroll: the stepped
        // recurrent path must thread hidden state deterministically
        // (the engine trace equals the serial reference bit-for-bit),
        // charge per-step work — one m=1 GEMM per step per pass, gate
        // weights programmed once and hit on every later call — and
        // report the truncated unroll honestly.
        let net = benchmarks::lstm();
        for design in [Design::Cim1, Design::NearMemory] {
            for resident in [false, true] {
                let ccfg = CosimConfig {
                    max_vectors: 1,
                    max_layers: 2,
                    seed: 13,
                    n_threads: 2,
                    resident,
                    repeats: 2,
                    max_steps: 3,
                };
                let accel = accel_for(design, Tech::Sram8T);
                let r = accel.run_cosim(&net, &ccfg);
                assert!(
                    r.all_match(),
                    "{design:?} resident={resident}: {} mismatches",
                    r.total_mismatches()
                );
                assert!(
                    r.accounting_matches(),
                    "{design:?} resident={resident}: engine {:?} != mapper {:?}",
                    r.engine,
                    r.expected
                );
                assert_eq!(r.layers.len(), 2);
                for l in &r.layers {
                    assert_eq!((l.m, l.steps, l.steps_full), (1, 3, 35), "{}", l.name);
                    assert!(l.truncated, "{}: 3 of 35 steps is a truncated unroll", l.name);
                }
                assert_eq!(r.truncated_layers(), 2);
                // 2 layers × 2 passes × 3 steps of m=1 GEMM calls.
                assert_eq!(r.engine.gemms, 12);
                // Stationary gate weights hit in *both* residency modes,
                // and the pool is sized so nothing ever churns.
                assert!(r.engine.hits > 0, "{design:?} resident={resident}");
                assert_eq!(r.engine.evictions, 0, "{design:?} resident={resident}");
            }
        }
    }

    #[test]
    fn packed_sweep_model_degenerates_to_weighted_closed_form() {
        // One-region-per-array mixes: the replayed model must reproduce
        // the closed forms *bitwise* — same integer miss rows, same IEEE
        // quotient — across the capacity range the measured
        // eviction-pressure battery pins against the engine.
        let uniform = vec![(256usize, 256usize); 8];
        for cap in [0u64, 1, 2, 3, 5, 7, 8, 100] {
            let m = packed_sweep_model(&uniform, cap, 256, 256);
            assert_eq!(m.total_rows, 2048);
            assert_eq!(m.miss_fraction(), sweep_miss_fraction(8, cap), "uniform cap {cap}");
        }
        // Ragged tail (seven full tiles + a half-height tail, all full
        // width so still one region per array): the size-weighted form.
        let ragged: Vec<(usize, usize)> =
            [[(256usize, 256usize); 7].as_slice(), &[(128, 256)]].concat();
        let rows: Vec<u64> = ragged.iter().map(|&(r, _)| r as u64).collect();
        for cap in 2..=8u64 {
            assert_eq!(
                sweep_miss_fraction_packed(&ragged, cap, 256, 256),
                sweep_miss_fraction_weighted(&rows, cap),
                "ragged cap {cap}"
            );
        }
        // Degenerate inputs stay in range.
        let empty = packed_sweep_model(&[], 4, 256, 256);
        assert_eq!((empty.miss_fraction(), empty.miss_rows_per_cycle), (0.0, 0));
    }

    #[test]
    fn packed_sweep_model_accounts_shelf_packed_small_regions() {
        // Four half-array regions shelf-pack two per array, so a 2-array
        // pool holds all four resident: the exact model reports zero
        // steady-state misses where the region-count closed form (4
        // regions through 2 arrays) would charge 75% of the rows every
        // pass. This gap is precisely the conv-shaped-shard mispricing
        // the packed model exists to close.
        let regions = [(128usize, 256usize); 4];
        let m = packed_sweep_model(&regions, 2, 256, 256);
        assert_eq!(m.total_rows, 512);
        assert_eq!(m.miss_rows_per_cycle, 0);
        assert_eq!(m.miss_fraction(), 0.0);
        assert!(m.warmup_passes >= 1);
        assert_eq!(sweep_miss_fraction_weighted(&[128; 4], 2), 0.75);
        // Under genuine pressure the currency is packed *shelves*, not
        // arrays: the same four regions through one array (two shelves)
        // behave exactly like 4 uniform regions through capacity 2 —
        // one proven region stays resident, three churn.
        assert_eq!(sweep_miss_fraction_packed(&regions, 1, 256, 256), 0.75);
        assert_eq!(sweep_miss_fraction_weighted(&[128; 4], 1), 1.0);
    }
}
