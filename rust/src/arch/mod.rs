//! Accelerator architecture layer: the TiM-DNN-style SiTe CiM system
//! (32 arrays × 256×256, 32 PCUs) plus iso-capacity / iso-area
//! near-memory baselines, a weight-stationary layer mapper and the
//! system-level latency/energy simulator behind Figs 12/13 — with a
//! functional co-simulation mode that executes benchmark layers on the
//! `engine::TernaryGemmEngine` (streaming or resident-tile path) and
//! cross-checks outputs against `mac::dot_ref` and work counters against
//! the mapper accounting, plus an explicit weight-[`Residency`] mode for
//! streaming-vs-resident serving cost.

pub mod accel;
pub mod config;
pub mod mapper;

pub use accel::{
    packed_sweep_model, sweep_miss_fraction, sweep_miss_fraction_packed,
    sweep_miss_fraction_weighted, Accelerator, CosimConfig, CosimLayerReport, CosimReport,
    PackedSweepModel, Residency, SystemReport,
};
pub use config::AccelConfig;
