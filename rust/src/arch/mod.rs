//! Accelerator architecture layer: the TiM-DNN-style SiTe CiM system
//! (32 arrays × 256×256, 32 PCUs) plus iso-capacity / iso-area
//! near-memory baselines, a weight-stationary layer mapper and the
//! system-level latency/energy simulator behind Figs 12/13 — now with a
//! functional co-simulation mode that executes benchmark layers on the
//! `engine::TernaryGemmEngine` and cross-checks against `mac::dot_ref`.

pub mod accel;
pub mod config;
pub mod mapper;

pub use accel::{Accelerator, CosimConfig, CosimReport, SystemReport};
pub use config::AccelConfig;
