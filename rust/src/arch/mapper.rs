//! Layer → array mapping.
//!
//! Weight-stationary mapping: a GEMM's K (reduction) dimension maps to
//! array rows, N (output channels) to columns. A weight *tile* is one
//! 256(K)×256(N) array-full. For every input vector, a tile's dot product
//! takes ⌈K_tile/16⌉ MAC windows (16 rows per cycle); the NM baseline
//! instead performs K_tile sequential row reads feeding the NMC unit.
//!
//! All benchmarks exceed the 2 M-word on-chip capacity, so weights stream:
//! every tile is programmed once per inference (256 row writes), matching
//! the paper's batch-1 inference accounting.

use super::config::AccelConfig;
use crate::array::area::Design;
use crate::dnn::Layer;
use crate::engine::resident::packed_array_count;
use crate::engine::tiling::TileGrid;

/// Work accounting for one layer on one accelerator config.
#[derive(Clone, Debug)]
pub struct LayerWork {
    pub name: String,
    /// Weight tiles (k_tiles × n_tiles).
    pub tiles: u64,
    /// Physical arrays the layer's tiles occupy under sub-array packing
    /// (first-fit shelf packing of 16-row-padded tiles — the same
    /// allocator the engine's resident cache drives). `tiles` is the
    /// one-tile-per-array count; packing needs at most that, and fewer
    /// whenever edge tiles leave array rows/columns idle.
    pub arrays_packed: u64,
    /// Total MAC windows (CiM cycle / NM 16-read window equivalents).
    pub windows: u64,
    /// Total single-row reads the NM design performs (0 for CiM).
    pub nm_reads: u64,
    /// Row writes to stream the layer's weights in.
    pub write_rows: u64,
    /// Output elements produced (for PCU/activation accounting).
    pub outputs: u64,
    /// Operand sparsity carried through for energy/error analyses.
    pub act_nz: f64,
}

impl LayerWork {
    /// Write rows charged *per inference* when the layer's weights stay
    /// resident in the arrays and the one-time programming is amortized
    /// over `inferences` served. `0` means steady state (infinite
    /// horizon): programming fully amortizes to zero, the weight-
    /// stationary ideal. `1` charges the full write count to a single
    /// inference (same energy as streaming).
    pub fn write_rows_amortized(&self, inferences: u64) -> f64 {
        if inferences == 0 {
            0.0
        } else {
            self.write_rows as f64 / inferences as f64
        }
    }
}

/// Map one layer onto a config.
///
/// Window accounting is the reference the functional engine must match:
/// ⌈K/16⌉ MAC windows per input vector per N-tile — i.e. partial final
/// k-tiles only count their occupied windows, ⌈k_len/16⌉, not a full
/// array's worth (`EngineStats.windows` agrees tile-by-tile; the cosim
/// cross-check in `arch::Accelerator::run_cosim` asserts equality).
/// Since the engine executes shards through the region-scoped
/// `dot_batch_region` kernels, the functional simulation's wall-clock
/// cost now scales with the occupied region charged here (its row span
/// × its columns), not with the full array a packed tile happens to sit
/// in. For CiM I the kernel literally runs ⌈k_len/16⌉ cycles; for CiM II
/// the stride grouping spans the whole array, so the kernel still
/// evaluates every intersecting group, but each at a cost proportional
/// to the region's word span — the *count* of charged windows stays a
/// hardware-occupancy accounting, not a claim about simulated group
/// evaluations.
pub fn map_layer(cfg: &AccelConfig, layer: &Layer) -> LayerWork {
    let g = &layer.gemm;
    let rows = cfg.geom.n_rows;
    let cols = cfg.geom.n_cols;
    let k_tiles = g.k.div_ceil(rows) as u64;
    let n_tiles = g.n.div_ceil(cols) as u64;
    let vectors = (g.m * layer.repeats) as u64;

    // Windows: ⌈K/16⌉ spread across the K-tiles, per vector, per N-tile.
    let windows_per_vec = (g.k.div_ceil(cfg.geom.n_active)) as u64;
    let windows = vectors * windows_per_vec * n_tiles;

    // NM: one read per (occupied) row per vector per N-tile. The paper's
    // baseline reads row-by-row without zero-input gating (§V preamble).
    let nm_reads = if cfg.design == Design::NearMemory { vectors * g.k as u64 * n_tiles } else { 0 };

    // Streaming weights: every tile programmed once per inference. Only
    // occupied rows are written.
    let write_rows = {
        let full = (g.k as u64 / rows as u64) * rows as u64;
        let partial = (g.k as u64) % rows as u64;
        (full + partial) * n_tiles
    };

    // Packed array count: the tiles' occupied shapes, in the engine's
    // own placement order (TileGrid::tiles), through the shelf packer.
    let shapes: Vec<(usize, usize)> = TileGrid::new(g.k, g.n, rows, cols)
        .tiles()
        .iter()
        .map(|t| (t.k_len, t.n_len))
        .collect();
    let arrays_packed = packed_array_count(&shapes, rows, cols) as u64;

    LayerWork {
        name: layer.name.clone(),
        tiles: k_tiles * n_tiles,
        arrays_packed,
        windows,
        nm_reads,
        write_rows,
        outputs: vectors * g.n as u64,
        act_nz: layer.act_nz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Tech;

    fn cim_cfg() -> AccelConfig {
        AccelConfig::sitecim(Tech::Sram8T, Design::Cim1)
    }

    fn nm_cfg() -> AccelConfig {
        AccelConfig::iso_capacity_nm(Tech::Sram8T)
    }

    #[test]
    fn exact_tile_fit() {
        let l = Layer::linear("fc", 4, 512, 512);
        let w = map_layer(&cim_cfg(), &l);
        assert_eq!(w.tiles, 4); // 2 k-tiles × 2 n-tiles
        assert_eq!(w.windows, 4 * (512 / 16) * 2); // vecs × ⌈K/16⌉ × n_tiles
        assert_eq!(w.write_rows, 512 * 2);
        assert_eq!(w.outputs, 4 * 512);
        assert_eq!(w.nm_reads, 0);
        // Full tiles cannot pack: one array each.
        assert_eq!(w.arrays_packed, 4);
    }

    #[test]
    fn ragged_dims_round_up() {
        let l = Layer::linear("fc", 1, 300, 300);
        let w = map_layer(&cim_cfg(), &l);
        assert_eq!(w.tiles, 4); // ⌈300/256⌉² = 2×2
        assert_eq!(w.windows, (300f64 / 16.0).ceil() as u64 * 2);
        assert_eq!(w.write_rows, 300 * 2);
        // Edge tiles pack: (256,256) alone, (44,256) and (44,44) share
        // an array as two shelves, (256,44) on its own — 3 arrays for 4
        // tiles.
        assert_eq!(w.arrays_packed, 3);
    }

    #[test]
    fn small_layers_pack_below_one_array_per_tile() {
        // Four small layers of 64×64 would each waste a 256×256 array
        // tile-per-array; packed accounting shows the sub-array truth.
        let l = Layer::linear("tiny", 1, 64, 64);
        let w = map_layer(&cim_cfg(), &l);
        assert_eq!(w.tiles, 1);
        assert_eq!(w.arrays_packed, 1);
        // And a whole stack of them still fits one array when packed
        // jointly (the per-network accounting in `Accelerator` sums
        // per-layer counts, which is conservative — this pins the
        // allocator-level truth).
        use crate::engine::resident::packed_array_count;
        assert_eq!(packed_array_count(&[(64, 64); 16], 256, 256), 1);
    }

    #[test]
    fn nm_reads_every_row_per_vector() {
        let l = Layer::linear("fc", 8, 256, 256);
        let w = map_layer(&nm_cfg(), &l);
        assert_eq!(w.nm_reads, 8 * 256);
        // Windows still accounted (16-read groups) for cross-checks.
        assert_eq!(w.windows, 8 * 16);
    }

    #[test]
    fn amortized_write_rows_scale_with_horizon() {
        let l = Layer::linear("fc", 1, 512, 512);
        let w = map_layer(&cim_cfg(), &l);
        assert_eq!(w.write_rows_amortized(1), w.write_rows as f64);
        assert_eq!(w.write_rows_amortized(4), w.write_rows as f64 / 4.0);
        assert_eq!(w.write_rows_amortized(0), 0.0, "steady state amortizes to zero");
    }

    #[test]
    fn recurrent_layers_multiply_by_steps() {
        let l = Layer::recurrent("lstm", 35, 650, 650, 4);
        let w = map_layer(&cim_cfg(), &l);
        // K = 1300 (6 k-tiles… ⌈1300/256⌉ = 6), N = 2600 (11 n-tiles).
        assert_eq!(w.tiles, 6 * 11);
        assert_eq!(w.windows, 35 * (1300f64 / 16.0).ceil() as u64 * 11);
        // Weights written once per inference, NOT per step.
        assert_eq!(w.write_rows, 1300 * 11);
    }
}
