//! Accelerator configuration (TiM-DNN-style, §VI.A) and the construction
//! of the iso-capacity / iso-area near-memory baselines.
//!
//! SiTe systems: 32 arrays of 256×256 ternary cells (2 M ternary words,
//! 512 kB), 32 PCUs per array, 16 rows asserted per cycle → 8192 parallel
//! dot-product lanes. Baselines:
//! - iso-capacity: 32 NM arrays (same 2 M words).
//! - iso-area: as many NM arrays as fit in the CiM system's macro area —
//!   *derived from the area model*, which lands on the paper's 41/48/47
//!   (vs CiM I) and 38/42/41 (vs CiM II) within ±2 arrays.

use crate::array::area::{macro_area, Design};
use crate::array::metrics::ArrayGeom;
use crate::device::{PeriphParams, Tech, TechParams};

#[derive(Clone, Debug)]
pub struct AccelConfig {
    pub name: String,
    pub tech: Tech,
    pub design: Design,
    pub n_arrays: usize,
    pub geom: ArrayGeom,
    pub n_pcus: usize,
}

impl AccelConfig {
    /// The paper's SiTe CiM system (either flavor).
    pub fn sitecim(tech: Tech, design: Design) -> AccelConfig {
        assert!(design != Design::NearMemory, "use iso_* constructors for baselines");
        AccelConfig {
            name: format!("{} {}", design.name(), tech.name()),
            tech,
            design,
            n_arrays: 32,
            geom: ArrayGeom::default(),
            n_pcus: 32,
        }
    }

    /// Iso-capacity NM baseline: same number of arrays (same 2 M words).
    pub fn iso_capacity_nm(tech: Tech) -> AccelConfig {
        AccelConfig {
            name: format!("NM iso-capacity {}", tech.name()),
            tech,
            design: Design::NearMemory,
            n_arrays: 32,
            geom: ArrayGeom::default(),
            n_pcus: 32,
        }
    }

    /// Iso-area NM baseline vs the given CiM flavor: array count derived
    /// from the macro-area model.
    pub fn iso_area_nm(tech: Tech, vs: Design) -> AccelConfig {
        let p = TechParams::new(tech);
        let pp = PeriphParams::default_45nm();
        let cim = 32.0 * macro_area(&p, &pp, vs, 256, 256);
        let nm_one = macro_area(&p, &pp, Design::NearMemory, 256, 256);
        let n_arrays = (cim / nm_one).floor() as usize;
        AccelConfig {
            name: format!("NM iso-area({}) {}", vs.name(), tech.name()),
            tech,
            design: Design::NearMemory,
            n_arrays,
            geom: ArrayGeom::default(),
            n_pcus: 32,
        }
    }

    /// Ternary-word capacity of the whole system.
    pub fn capacity_words(&self) -> u64 {
        (self.n_arrays * self.geom.n_rows * self.geom.n_cols) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sitecim_capacity_is_2m_words() {
        let c = AccelConfig::sitecim(Tech::Sram8T, Design::Cim1);
        assert_eq!(c.capacity_words(), 2 * 1024 * 1024);
    }

    #[test]
    fn iso_area_array_counts_near_paper() {
        // Paper: 41/48/47 arrays vs CiM I; 38/42/41 vs CiM II (±3).
        let expect1 = [(Tech::Sram8T, 41), (Tech::Edram3T, 48), (Tech::Femfet3T, 47)];
        for (tech, n) in expect1 {
            let c = AccelConfig::iso_area_nm(tech, Design::Cim1);
            assert!(
                (c.n_arrays as i64 - n).abs() <= 3,
                "{}: {} arrays vs paper {n}",
                tech.name(),
                c.n_arrays
            );
        }
        let expect2 = [(Tech::Sram8T, 38), (Tech::Edram3T, 42), (Tech::Femfet3T, 41)];
        for (tech, n) in expect2 {
            let c = AccelConfig::iso_area_nm(tech, Design::Cim2);
            assert!(
                (c.n_arrays as i64 - n).abs() <= 3,
                "{}: {} arrays vs paper {n}",
                tech.name(),
                c.n_arrays
            );
        }
    }

    #[test]
    fn iso_area_has_more_arrays_than_iso_capacity() {
        for tech in Tech::ALL {
            for d in [Design::Cim1, Design::Cim2] {
                assert!(AccelConfig::iso_area_nm(tech, d).n_arrays > 32);
            }
        }
    }

    #[test]
    #[should_panic]
    fn sitecim_rejects_nm_design() {
        AccelConfig::sitecim(Tech::Sram8T, Design::NearMemory);
    }
}
