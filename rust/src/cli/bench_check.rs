//! The CI bench-regression gate behind `sitecim bench-check`.
//!
//! Compares a freshly-written `BENCH_engine.json` against the committed
//! `BENCH_baseline.json`: per-entry throughput (`gmacs_per_s`, keyed by
//! design/mode/threads/shape) and the per-design `resident_speedup` /
//! `region_speedup` / `arc_speedup` / `batched_speedup` /
//! `pipelined_speedup` ratios, each
//! within a relative tolerance. Only
//! *regressions* fail — a fresh value above baseline always passes —
//! and a baseline metric recorded as `null` is treated as unseeded
//! (reported, never failed), so the gate can be committed before the
//! reference runner has produced real numbers. A baseline metric
//! *missing* from the fresh run fails: losing a benchmark silently is
//! itself a regression.
//!
//! [`compare_capacity`] additionally gates the *machine-independent*
//! hit-rate columns of `BENCH_capacity.json` (the bench records them
//! from a deterministic single-threaded placement replay, so they are
//! exact on any runner); throughput columns of that record stay
//! ungated. Entries recorded for a different workload (fast vs full
//! mode) are skipped, not failed.

use crate::util::json::Json;
use crate::util::table::Table;

/// One comparison outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Verdict {
    Ok,
    Improved,
    Regressed,
    Unseeded,
    Missing,
    /// Baseline entry not comparable on this run: keyed by a
    /// runner-dependent thread count (the multi-thread bench entries
    /// embed `available_parallelism()`), or recorded for a different
    /// capacity-sweep workload. Reported, never failed, so seeding the
    /// baseline from one configuration cannot brick CI on another.
    Skipped,
}

impl Verdict {
    fn label(self) -> &'static str {
        match self {
            Verdict::Ok => "OK",
            Verdict::Improved => "OK (faster)",
            Verdict::Regressed => "REGRESSED",
            Verdict::Unseeded => "unseeded",
            Verdict::Missing => "MISSING",
            Verdict::Skipped => "skipped (not comparable here)",
        }
    }

    fn fails(self) -> bool {
        matches!(self, Verdict::Regressed | Verdict::Missing)
    }
}

fn fmt_val(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.3}"),
        None => "n/a".to_string(),
    }
}

fn fmt_delta(base: Option<f64>, fresh: Option<f64>) -> String {
    match (base, fresh) {
        (Some(b), Some(f)) if b > 0.0 => format!("{:+.1}%", (f / b - 1.0) * 100.0),
        _ => "-".to_string(),
    }
}

/// Judge one higher-is-better metric against the tolerance.
fn judge(base: Option<f64>, fresh: Option<f64>, tol_pct: f64) -> Verdict {
    match (base, fresh) {
        (None, _) => Verdict::Unseeded,
        (Some(_), None) => Verdict::Missing,
        (Some(b), Some(f)) => {
            if f < b * (1.0 - tol_pct / 100.0) {
                Verdict::Regressed
            } else if f > b {
                Verdict::Improved
            } else {
                Verdict::Ok
            }
        }
    }
}

/// Identity of one `results[]` entry: design/mode/threads/shape.
fn entry_key(e: &Json) -> Option<String> {
    let design = e.get("design")?.as_str()?;
    let mode = e.get("mode")?.as_str()?;
    let threads = e.get("threads")?.as_usize()?;
    let (m, k, n) = (
        e.get("m")?.as_usize()?,
        e.get("k")?.as_usize()?,
        e.get("n")?.as_usize()?,
    );
    Some(format!("{design}/{mode} {threads}t {m}x{k}x{n}"))
}

/// Metric value, treating JSON `null` (or absence) as unseeded.
fn metric(e: &Json, key: &str) -> Option<f64> {
    e.get(key).and_then(Json::as_f64)
}

/// Render the per-metric delta table and return it with the overall
/// verdict (`true` = no regression).
pub fn compare(baseline: &Json, fresh: &Json, tol_pct: f64) -> (String, bool) {
    let mut t = Table::new(format!("bench-check — regression gate at ±{tol_pct:.0}%"))
        .header(&["metric (higher is better)", "baseline", "fresh", "delta", "status"]);
    let mut failures = 0usize;
    let mut unseeded = 0usize;
    let mut checked = 0usize;

    let empty: Vec<Json> = Vec::new();
    let base_entries = baseline.get("results").and_then(Json::as_arr).unwrap_or(&empty);
    let fresh_entries = fresh.get("results").and_then(Json::as_arr).unwrap_or(&empty);

    for be in base_entries {
        let Some(key) = entry_key(be) else { continue };
        let base_v = metric(be, "gmacs_per_s");
        let fresh_v = fresh_entries
            .iter()
            .find(|&fe| entry_key(fe).as_deref() == Some(key.as_str()))
            .and_then(|fe| metric(fe, "gmacs_per_s"));
        // Multi-thread entries embed the recording machine's core count
        // in their key; only single-thread entries are machine-portable.
        let portable = be.get("threads").and_then(Json::as_usize) == Some(1);
        let v = if portable { judge(base_v, fresh_v, tol_pct) } else { Verdict::Skipped };
        checked += usize::from(v != Verdict::Skipped);
        failures += usize::from(v.fails());
        unseeded += usize::from(v == Verdict::Unseeded);
        t.row(&[
            format!("GMAC/s {key}"),
            fmt_val(base_v),
            fmt_val(fresh_v),
            fmt_delta(base_v, fresh_v),
            v.label().to_string(),
        ]);
    }

    for section in [
        "resident_speedup",
        "region_speedup",
        "arc_speedup",
        "batched_speedup",
        "pipelined_speedup",
    ] {
        if let Some(base_sp) = baseline.get(section).and_then(Json::as_obj) {
            for (design, bv) in base_sp {
                let base_v = bv.as_f64();
                let fresh_v =
                    fresh.get(section).and_then(|o| o.get(design)).and_then(Json::as_f64);
                let v = judge(base_v, fresh_v, tol_pct);
                checked += 1;
                failures += usize::from(v.fails());
                unseeded += usize::from(v == Verdict::Unseeded);
                t.row(&[
                    format!("{section} {design}"),
                    fmt_val(base_v),
                    fmt_val(fresh_v),
                    fmt_delta(base_v, fresh_v),
                    v.label().to_string(),
                ]);
            }
        }
    }

    let ok = failures == 0 && checked > 0;
    if checked == 0 {
        t.note("baseline lists no metrics — seed BENCH_baseline.json from a bench run");
    } else if unseeded == checked {
        t.note(
            "all baseline metrics are null (unseeded): gate passes vacuously; copy a real \
             BENCH_engine.json over BENCH_baseline.json on the reference runner to arm it",
        );
    }
    t.note(format!(
        "{checked} metric(s) checked, {failures} regression(s), {unseeded} unseeded"
    ));
    let verdict = if ok {
        "bench-check: PASS\n".to_string()
    } else {
        format!("bench-check: FAIL ({failures} regression(s))\n")
    };
    (t.render() + &verdict, ok)
}

/// Identity of one capacity-sweep `results[]` entry.
fn capacity_key(e: &Json) -> Option<String> {
    let design = e.get("design")?.as_str()?;
    let cap = e.get("capacity_words")?.as_usize()?;
    Some(format!("{design} cap={cap}"))
}

/// Gate the machine-independent hit-rate columns of a capacity-sweep
/// record against a committed baseline. Only `hit_rate` is judged: the
/// bench records it from a deterministic single-threaded placement
/// replay (exact on any runner), while `inf_per_s` is machine-dependent
/// and never gated. A baseline recorded for a different workload (fast
/// vs full sweep) is skipped wholesale rather than failed.
pub fn compare_capacity(baseline: &Json, fresh: &Json, tol_pct: f64) -> (String, bool) {
    let mut t =
        Table::new(format!("bench-check capacity — hit-rate gate at ±{tol_pct:.0}%"))
            .header(&["metric (higher is better)", "baseline", "fresh", "delta", "status"]);
    let empty: Vec<Json> = Vec::new();
    let base_entries = baseline.get("results").and_then(Json::as_arr).unwrap_or(&empty);
    let fresh_entries = fresh.get("results").and_then(Json::as_arr).unwrap_or(&empty);
    let base_workload = baseline.get("workload").and_then(Json::as_str);
    let fresh_workload = fresh.get("workload").and_then(Json::as_str);
    let comparable = base_workload.is_some() && base_workload == fresh_workload;

    let mut failures = 0usize;
    let mut unseeded = 0usize;
    let mut checked = 0usize;
    for be in base_entries {
        let Some(key) = capacity_key(be) else { continue };
        let base_v = metric(be, "hit_rate");
        let fresh_v = fresh_entries
            .iter()
            .find(|&fe| capacity_key(fe).as_deref() == Some(key.as_str()))
            .and_then(|fe| metric(fe, "hit_rate"));
        let v = if comparable { judge(base_v, fresh_v, tol_pct) } else { Verdict::Skipped };
        checked += usize::from(v != Verdict::Skipped);
        failures += usize::from(v.fails());
        unseeded += usize::from(v == Verdict::Unseeded);
        t.row(&[
            format!("hit_rate {key}"),
            fmt_val(base_v),
            fmt_val(fresh_v),
            fmt_delta(base_v, fresh_v),
            v.label().to_string(),
        ]);
    }
    if !comparable {
        t.note(format!(
            "workload mismatch (baseline {base_workload:?}, fresh {fresh_workload:?}): \
             entries skipped, not compared"
        ));
    }
    t.note(format!(
        "{checked} metric(s) checked, {failures} regression(s), {unseeded} unseeded"
    ));
    let ok = failures == 0;
    let verdict = if ok {
        "bench-check capacity: PASS\n".to_string()
    } else {
        format!("bench-check capacity: FAIL ({failures} regression(s))\n")
    };
    (t.render() + &verdict, ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(design: &str, gmacs: &str) -> String {
        entry_threads(design, 1, gmacs)
    }

    fn entry_threads(design: &str, threads: usize, gmacs: &str) -> String {
        format!(
            "{{\"design\": \"{design}\", \"mode\": \"streaming\", \"threads\": {threads}, \
             \"m\": 8, \"k\": 256, \"n\": 256, \"mean_s\": 0.01, \"gmacs_per_s\": {gmacs}}}"
        )
    }

    fn doc(entries: &[String], speedups: &str) -> Json {
        Json::parse(&format!(
            "{{\"bench\": \"engine_gemm\", \"results\": [{}], \"resident_speedup\": {speedups}}}",
            entries.join(", ")
        ))
        .unwrap()
    }

    #[test]
    fn within_tolerance_and_improvements_pass() {
        let base = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 4.0}");
        let fresh = doc(&[entry("Cim1", "8.5")], "{\"Cim1\": 5.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("PASS"));
        assert!(report.contains("OK (faster)"), "speedup improved: {report}");
    }

    #[test]
    fn regression_beyond_tolerance_fails() {
        let base = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 4.0}");
        let fresh = doc(&[entry("Cim1", "7.9")], "{\"Cim1\": 4.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(!ok, "{report}");
        assert!(report.contains("REGRESSED"));
        assert!(report.contains("FAIL"));
    }

    #[test]
    fn speedup_regression_fails_independently() {
        let base = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 4.0}");
        let fresh = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 2.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(!ok, "{report}");
    }

    #[test]
    fn null_baseline_metrics_pass_as_unseeded() {
        let base = doc(&[entry("Cim1", "null")], "{\"Cim1\": null}");
        let fresh = doc(&[entry("Cim1", "12.0")], "{\"Cim1\": 4.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("unseeded"));
    }

    #[test]
    fn baseline_metric_missing_from_fresh_fails() {
        let base = doc(
            &[entry("Cim1", "10.0"), entry("Cim2", "9.0")],
            "{\"Cim1\": 4.0}",
        );
        let fresh = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 4.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(!ok, "{report}");
        assert!(report.contains("MISSING"));
    }

    #[test]
    fn runner_dependent_thread_keys_are_skipped_not_failed() {
        // A baseline seeded on an 8-core runner carries threads=8
        // entries; a 4-core CI runner emits no matching key. That must
        // not fail the gate — only single-thread keys are compared.
        let base = doc(
            &[entry("Cim1", "10.0"), entry_threads("Cim1", 8, "40.0")],
            "{\"Cim1\": 4.0}",
        );
        let fresh = doc(
            &[entry("Cim1", "10.0"), entry_threads("Cim1", 4, "1.0")],
            "{\"Cim1\": 4.0}",
        );
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("skipped"));
    }

    #[test]
    fn empty_baseline_is_not_a_pass() {
        let base = Json::parse("{\"results\": []}").unwrap();
        let fresh = doc(&[entry("Cim1", "10.0")], "{}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(!ok, "an empty baseline must not green-light the gate: {report}");
    }

    #[test]
    fn region_speedup_section_is_gated_like_resident() {
        let parse_doc = |region: &str| {
            Json::parse(&format!(
                "{{\"results\": [{}], \"resident_speedup\": {{\"Cim1\": 4.0}}, \
                 \"region_speedup\": {region}}}",
                entry("Cim1", "10.0")
            ))
            .unwrap()
        };
        let base = parse_doc("{\"Cim1\": 3.0}");
        let good = parse_doc("{\"Cim1\": 3.5}");
        let (report, ok) = compare(&base, &good, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("region_speedup Cim1"));
        let bad = parse_doc("{\"Cim1\": 1.0}");
        let (report, ok) = compare(&base, &bad, 20.0);
        assert!(!ok, "region speedup regression must fail: {report}");
    }

    #[test]
    fn arc_speedup_section_is_gated_like_the_others() {
        let parse_doc = |arc: &str| {
            Json::parse(&format!(
                "{{\"results\": [{}], \"resident_speedup\": {{\"Cim1\": 4.0}}, \
                 \"arc_speedup\": {arc}}}",
                entry("Cim1", "10.0")
            ))
            .unwrap()
        };
        let base = parse_doc("{\"Cim1\": 1.2}");
        let good = parse_doc("{\"Cim1\": 1.3}");
        let (report, ok) = compare(&base, &good, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("arc_speedup Cim1"));
        let bad = parse_doc("{\"Cim1\": 0.5}");
        let (report, ok) = compare(&base, &bad, 20.0);
        assert!(!ok, "arc speedup regression must fail: {report}");
        // Null-seeded arc entries pass as unseeded, per convention.
        let unseeded = parse_doc("{\"Cim1\": null}");
        let (report, ok) = compare(&unseeded, &good, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("unseeded"));
    }

    #[test]
    fn batched_speedup_section_is_gated_like_the_others() {
        let parse_doc = |batched: &str| {
            Json::parse(&format!(
                "{{\"results\": [{}], \"resident_speedup\": {{\"Cim1\": 4.0}}, \
                 \"batched_speedup\": {batched}}}",
                entry("Cim1", "10.0")
            ))
            .unwrap()
        };
        let base = parse_doc("{\"Cim1\": 2.0}");
        let good = parse_doc("{\"Cim1\": 2.4}");
        let (report, ok) = compare(&base, &good, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("batched_speedup Cim1"));
        let bad = parse_doc("{\"Cim1\": 0.8}");
        let (report, ok) = compare(&base, &bad, 20.0);
        assert!(!ok, "batched speedup regression must fail: {report}");
        // Null-seeded batched entries pass as unseeded, per convention.
        let unseeded = parse_doc("{\"Cim1\": null}");
        let (report, ok) = compare(&unseeded, &good, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("unseeded"));
    }

    #[test]
    fn pipelined_speedup_section_is_gated_like_the_others() {
        let parse_doc = |pipelined: &str| {
            Json::parse(&format!(
                "{{\"results\": [{}], \"resident_speedup\": {{\"Cim1\": 4.0}}, \
                 \"pipelined_speedup\": {pipelined}}}",
                entry("Cim1", "10.0")
            ))
            .unwrap()
        };
        let base = parse_doc("{\"Cim1\": 1.5}");
        let good = parse_doc("{\"Cim1\": 1.8}");
        let (report, ok) = compare(&base, &good, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("pipelined_speedup Cim1"));
        let bad = parse_doc("{\"Cim1\": 0.7}");
        let (report, ok) = compare(&base, &bad, 20.0);
        assert!(!ok, "pipelined speedup regression must fail: {report}");
        // Null-seeded pipelined entries pass as unseeded, per convention.
        let unseeded = parse_doc("{\"Cim1\": null}");
        let (report, ok) = compare(&unseeded, &good, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("unseeded"));
    }

    fn cap_entry(design: &str, cap: u64, hit_rate: &str) -> String {
        format!(
            "{{\"design\": \"{design}\", \"capacity_words\": {cap}, \"arrays\": 4, \
             \"hits\": 6, \"misses\": 26, \"evictions\": 26, \"hit_rate\": {hit_rate}, \
             \"inf_per_s\": null}}"
        )
    }

    fn cap_doc(workload: &str, entries: &[String]) -> Json {
        Json::parse(&format!(
            "{{\"bench\": \"capacity_sweep\", \"workload\": \"{workload}\", \"results\": [{}]}}",
            entries.join(", ")
        ))
        .unwrap()
    }

    #[test]
    fn capacity_hit_rates_gate_within_tolerance() {
        let base = cap_doc("alexnet-fc/8", &[cap_entry("Cim1", 262144, "0.1875")]);
        let same = cap_doc("alexnet-fc/8", &[cap_entry("Cim1", 262144, "0.1875")]);
        let (report, ok) = compare_capacity(&base, &same, 20.0);
        assert!(ok, "{report}");
        let worse = cap_doc("alexnet-fc/8", &[cap_entry("Cim1", 262144, "0.05")]);
        let (report, ok) = compare_capacity(&base, &worse, 20.0);
        assert!(!ok, "hit-rate collapse must fail the gate: {report}");
        assert!(report.contains("REGRESSED"));
    }

    #[test]
    fn capacity_missing_entry_fails_but_workload_mismatch_skips() {
        let base = cap_doc(
            "alexnet-fc/8",
            &[
                cap_entry("Cim1", 262144, "0.1875"),
                cap_entry("Cim1", 524288, "0.4375"),
            ],
        );
        let missing = cap_doc("alexnet-fc/8", &[cap_entry("Cim1", 262144, "0.1875")]);
        let (report, ok) = compare_capacity(&base, &missing, 20.0);
        assert!(!ok, "losing a sweep point must fail: {report}");
        assert!(report.contains("MISSING"));
        // A full-size run against a fast-mode baseline is not comparable:
        // skipped, never failed.
        let other = cap_doc("alexnet-fc", &[cap_entry("Cim1", 2097152, "0.03")]);
        let (report, ok) = compare_capacity(&base, &other, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("skipped"));
    }

    #[test]
    fn capacity_null_baseline_is_unseeded_pass() {
        let base = cap_doc("alexnet-fc/8", &[cap_entry("Cim1", 262144, "null")]);
        let fresh = cap_doc("alexnet-fc/8", &[cap_entry("Cim1", 262144, "0.5")]);
        let (report, ok) = compare_capacity(&base, &fresh, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("unseeded"));
    }
}
