//! The CI bench-regression gate behind `sitecim bench-check`.
//!
//! Compares a freshly-written `BENCH_engine.json` against the committed
//! `BENCH_baseline.json`: per-entry throughput (`gmacs_per_s`, keyed by
//! design/mode/threads/shape) and the per-design `resident_speedup`
//! ratios, each within a relative tolerance. Only *regressions* fail —
//! a fresh value above baseline always passes — and a baseline metric
//! recorded as `null` is treated as unseeded (reported, never failed),
//! so the gate can be committed before the reference runner has produced
//! real numbers. A baseline metric *missing* from the fresh run fails:
//! losing a benchmark silently is itself a regression.

use crate::util::json::Json;
use crate::util::table::Table;

/// One comparison outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Verdict {
    Ok,
    Improved,
    Regressed,
    Unseeded,
    Missing,
    /// Baseline entry keyed by a runner-dependent thread count (the
    /// multi-thread bench entries embed `available_parallelism()`):
    /// reported, never failed, so seeding the baseline by copying a
    /// whole BENCH_engine.json from one machine cannot brick CI on a
    /// machine with a different core count.
    Skipped,
}

impl Verdict {
    fn label(self) -> &'static str {
        match self {
            Verdict::Ok => "OK",
            Verdict::Improved => "OK (faster)",
            Verdict::Regressed => "REGRESSED",
            Verdict::Unseeded => "unseeded",
            Verdict::Missing => "MISSING",
            Verdict::Skipped => "skipped (runner-dependent key)",
        }
    }

    fn fails(self) -> bool {
        matches!(self, Verdict::Regressed | Verdict::Missing)
    }
}

fn fmt_val(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.3}"),
        None => "n/a".to_string(),
    }
}

fn fmt_delta(base: Option<f64>, fresh: Option<f64>) -> String {
    match (base, fresh) {
        (Some(b), Some(f)) if b > 0.0 => format!("{:+.1}%", (f / b - 1.0) * 100.0),
        _ => "-".to_string(),
    }
}

/// Judge one higher-is-better metric against the tolerance.
fn judge(base: Option<f64>, fresh: Option<f64>, tol_pct: f64) -> Verdict {
    match (base, fresh) {
        (None, _) => Verdict::Unseeded,
        (Some(_), None) => Verdict::Missing,
        (Some(b), Some(f)) => {
            if f < b * (1.0 - tol_pct / 100.0) {
                Verdict::Regressed
            } else if f > b {
                Verdict::Improved
            } else {
                Verdict::Ok
            }
        }
    }
}

/// Identity of one `results[]` entry: design/mode/threads/shape.
fn entry_key(e: &Json) -> Option<String> {
    let design = e.get("design")?.as_str()?;
    let mode = e.get("mode")?.as_str()?;
    let threads = e.get("threads")?.as_usize()?;
    let (m, k, n) = (
        e.get("m")?.as_usize()?,
        e.get("k")?.as_usize()?,
        e.get("n")?.as_usize()?,
    );
    Some(format!("{design}/{mode} {threads}t {m}x{k}x{n}"))
}

/// Metric value, treating JSON `null` (or absence) as unseeded.
fn metric(e: &Json, key: &str) -> Option<f64> {
    e.get(key).and_then(Json::as_f64)
}

/// Render the per-metric delta table and return it with the overall
/// verdict (`true` = no regression).
pub fn compare(baseline: &Json, fresh: &Json, tol_pct: f64) -> (String, bool) {
    let mut t = Table::new(format!("bench-check — regression gate at ±{tol_pct:.0}%"))
        .header(&["metric (higher is better)", "baseline", "fresh", "delta", "status"]);
    let mut failures = 0usize;
    let mut unseeded = 0usize;
    let mut checked = 0usize;

    let empty: Vec<Json> = Vec::new();
    let base_entries = baseline.get("results").and_then(Json::as_arr).unwrap_or(&empty);
    let fresh_entries = fresh.get("results").and_then(Json::as_arr).unwrap_or(&empty);

    for be in base_entries {
        let Some(key) = entry_key(be) else { continue };
        let base_v = metric(be, "gmacs_per_s");
        let fresh_v = fresh_entries
            .iter()
            .find(|&fe| entry_key(fe).as_deref() == Some(key.as_str()))
            .and_then(|fe| metric(fe, "gmacs_per_s"));
        // Multi-thread entries embed the recording machine's core count
        // in their key; only single-thread entries are machine-portable.
        let portable = be.get("threads").and_then(Json::as_usize) == Some(1);
        let v = if portable { judge(base_v, fresh_v, tol_pct) } else { Verdict::Skipped };
        checked += usize::from(v != Verdict::Skipped);
        failures += usize::from(v.fails());
        unseeded += usize::from(v == Verdict::Unseeded);
        t.row(&[
            format!("GMAC/s {key}"),
            fmt_val(base_v),
            fmt_val(fresh_v),
            fmt_delta(base_v, fresh_v),
            v.label().to_string(),
        ]);
    }

    if let Some(base_sp) = baseline.get("resident_speedup").and_then(Json::as_obj) {
        for (design, bv) in base_sp {
            let base_v = bv.as_f64();
            let fresh_v = fresh
                .get("resident_speedup")
                .and_then(|o| o.get(design))
                .and_then(Json::as_f64);
            let v = judge(base_v, fresh_v, tol_pct);
            checked += 1;
            failures += usize::from(v.fails());
            unseeded += usize::from(v == Verdict::Unseeded);
            t.row(&[
                format!("resident_speedup {design}"),
                fmt_val(base_v),
                fmt_val(fresh_v),
                fmt_delta(base_v, fresh_v),
                v.label().to_string(),
            ]);
        }
    }

    let ok = failures == 0 && checked > 0;
    if checked == 0 {
        t.note("baseline lists no metrics — seed BENCH_baseline.json from a bench run");
    } else if unseeded == checked {
        t.note(
            "all baseline metrics are null (unseeded): gate passes vacuously; copy a real \
             BENCH_engine.json over BENCH_baseline.json on the reference runner to arm it",
        );
    }
    t.note(format!(
        "{checked} metric(s) checked, {failures} regression(s), {unseeded} unseeded"
    ));
    let verdict = if ok {
        "bench-check: PASS\n".to_string()
    } else {
        format!("bench-check: FAIL ({failures} regression(s))\n")
    };
    (t.render() + &verdict, ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(design: &str, gmacs: &str) -> String {
        entry_threads(design, 1, gmacs)
    }

    fn entry_threads(design: &str, threads: usize, gmacs: &str) -> String {
        format!(
            "{{\"design\": \"{design}\", \"mode\": \"streaming\", \"threads\": {threads}, \
             \"m\": 8, \"k\": 256, \"n\": 256, \"mean_s\": 0.01, \"gmacs_per_s\": {gmacs}}}"
        )
    }

    fn doc(entries: &[String], speedups: &str) -> Json {
        Json::parse(&format!(
            "{{\"bench\": \"engine_gemm\", \"results\": [{}], \"resident_speedup\": {speedups}}}",
            entries.join(", ")
        ))
        .unwrap()
    }

    #[test]
    fn within_tolerance_and_improvements_pass() {
        let base = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 4.0}");
        let fresh = doc(&[entry("Cim1", "8.5")], "{\"Cim1\": 5.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("PASS"));
        assert!(report.contains("OK (faster)"), "speedup improved: {report}");
    }

    #[test]
    fn regression_beyond_tolerance_fails() {
        let base = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 4.0}");
        let fresh = doc(&[entry("Cim1", "7.9")], "{\"Cim1\": 4.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(!ok, "{report}");
        assert!(report.contains("REGRESSED"));
        assert!(report.contains("FAIL"));
    }

    #[test]
    fn speedup_regression_fails_independently() {
        let base = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 4.0}");
        let fresh = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 2.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(!ok, "{report}");
    }

    #[test]
    fn null_baseline_metrics_pass_as_unseeded() {
        let base = doc(&[entry("Cim1", "null")], "{\"Cim1\": null}");
        let fresh = doc(&[entry("Cim1", "12.0")], "{\"Cim1\": 4.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("unseeded"));
    }

    #[test]
    fn baseline_metric_missing_from_fresh_fails() {
        let base = doc(
            &[entry("Cim1", "10.0"), entry("Cim2", "9.0")],
            "{\"Cim1\": 4.0}",
        );
        let fresh = doc(&[entry("Cim1", "10.0")], "{\"Cim1\": 4.0}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(!ok, "{report}");
        assert!(report.contains("MISSING"));
    }

    #[test]
    fn runner_dependent_thread_keys_are_skipped_not_failed() {
        // A baseline seeded on an 8-core runner carries threads=8
        // entries; a 4-core CI runner emits no matching key. That must
        // not fail the gate — only single-thread keys are compared.
        let base = doc(
            &[entry("Cim1", "10.0"), entry_threads("Cim1", 8, "40.0")],
            "{\"Cim1\": 4.0}",
        );
        let fresh = doc(
            &[entry("Cim1", "10.0"), entry_threads("Cim1", 4, "1.0")],
            "{\"Cim1\": 4.0}",
        );
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(ok, "{report}");
        assert!(report.contains("skipped"));
    }

    #[test]
    fn empty_baseline_is_not_a_pass() {
        let base = Json::parse("{\"results\": []}").unwrap();
        let fresh = doc(&[entry("Cim1", "10.0")], "{}");
        let (report, ok) = compare(&base, &fresh, 20.0);
        assert!(!ok, "an empty baseline must not green-light the gate: {report}");
    }
}
