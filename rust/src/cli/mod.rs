//! Command-line interface (hand-rolled; no clap offline).
//!
//! Subcommands:
//!   figures  [--all|--fig4|--fig7|--fig9|--fig11|--fig12|--fig13|--area|--cmp|--err|--cosim]
//!            (--cosim exits nonzero on any engine/accounting mismatch)
//!   selftest             quick functional cross-check of both array flavors
//!   engine   [--m M --k K --n N] [--design cim1|cim2|nm] [--threads T] [--resident] [--reps R]
//!            [--capacity-words W]
//!   bench-check [--baseline PATH] [--fresh PATH] [--tolerance PCT]
//!   infer    [--artifacts DIR] [--model cim1|cim2|exact] [--n N]
//!   serve    [--artifacts DIR] [--requests N] [--workers W] [--backend pjrt|engine] [--threads T]
//!            [--capacity-words W] [--max-batch-rows R]
//!            pipelining: [--no-pipeline-admission] [--max-stage-admit-rows R] [--max-catchup-frac F]
//!            ingress: [--rate R] [--burst B] [--shed-high H] [--shed-low L] [--shed-exec-weight W]
//!            client retry: [--retries N] (backoff on Retry-After hints, goodput report)
//!            multi-model: [--model a=dir1,b=dir2] [--reserve a=WORDS]
//!   metrics snapshot [--artifacts DIR] [--requests N] [--out PATH]   scrapeable MetricsReport JSON
//!   artifact verify DIR   offline artifact check (schema, checksums, plan)

mod bench_check;

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::array::area::Design;
use crate::array::{mac, CimArray, SiTeCim1Array, SiTeCim2Array};
use crate::coordinator::{
    BackendKind, InferError, IngressConfig, MultiServer, MultiServerConfig, RateLimit, Server,
    ServerConfig, Watermarks,
};
use crate::device::Tech;
use crate::engine::tiling::reference_gemm;
use crate::engine::{plan_layout, EngineConfig, TernaryGemmEngine};
use crate::repro;
use crate::runtime::{self, Manifest, ModelKind};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;

pub const USAGE: &str = "sitecim — SiTe CiM reproduction (signed ternary computing-in-memory)

USAGE: sitecim <subcommand> [flags]

  figures [--all | --fig4 --fig7 --fig9 --fig11 --fig12 --fig13 --area --cmp --err --cosim]
          regenerate the paper's tables/figures (paper vs measured);
          --cosim exits nonzero if any engine output or work counter
          diverges from the analytic accounting (the CI gate)
  selftest [--seed S]
          functional cross-check: CiM I/II arrays vs reference semantics
  engine  [--m M] [--k K] [--n N] [--design cim1|cim2|nm] [--threads T] [--seed S]
          [--resident] [--reps R] [--capacity-words W]
          run a ternary GEMM through the tiled array engine (persistent
          stripe-scheduled executor), verify it against the dot_ref tile
          composition, and report throughput; --resident registers the
          weights once and repeats the GEMM through the resident-tile
          cache, reporting streaming-vs-resident throughput, cache
          hit/miss/evict counters and executor affinity stats;
          --capacity-words bounds the resident pool (e.g. 2097152 = the
          paper's 2 M words) and serves under second-chance eviction
          pressure
  bench-check [--baseline PATH] [--fresh PATH] [--tolerance PCT]
              [--capacity-baseline PATH] [--capacity-fresh PATH]
          compare a fresh BENCH_engine.json against the committed
          baseline (default BENCH_baseline.json): per-design throughput,
          resident/region/arc/batched/pipelined speedups, ±20% by default; also gates the
          machine-independent hit-rate columns of BENCH_capacity.json
          against BENCH_capacity_baseline.json when present; exits
          nonzero and prints per-metric delta tables on regression
  infer   [--artifacts DIR] [--model cim1|cim2|exact] [--n N]
          run the AOT-compiled ternary MLP on the held-out test set
  serve   [--artifacts DIR] [--requests N] [--workers W] [--batch B] [--backend pjrt|engine]
          [--threads T] [--capacity-words W] [--max-batch-rows R]
          [--no-pipeline-admission] [--max-stage-admit-rows R] [--max-catchup-frac F]
          [--rate R] [--burst B] [--shed-high H] [--shed-low L] [--shed-exec-weight W]
          [--retries N]
          start the serving coordinator and push synthetic traffic (the
          engine backend shares one resident-weight model and one
          persistent executor across workers, and merges all in-flight
          requests into one GEMM M-plane per flush — --max-batch-rows
          caps the rows per merged flush, --batch caps the PJRT path;
          newly arrived rows join an in-flight flush at layer boundaries
          unless --no-pipeline-admission; --max-stage-admit-rows caps
          rows admitted per boundary and --max-catchup-frac bounds how
          deep a boundary may still admit late rows (1.0 = every
          boundary); --capacity-words serves from a bounded pool instead
          of sizing it to the whole network; the report includes
          rows-per-flush p50/p95, the per-stage admission histogram and
          measured amortized residency costs from the engine's own
          counters)
          multi-model: --model a=dir1,b=dir2 serves N models from one
          engine pool (per-model continuous-batching lanes; requests
          round-robin across models); --reserve a=WORDS[,b=WORDS] gives
          a model a hard-reserved capacity partition of the pool —
          everything else shares the rest best-effort; the report adds
          per-tenant request counts, hit rates and plan/traffic write
          rows
          ingress (both modes): --rate R admits R requests/s per tenant
          (token bucket, --burst B, default B=R) and --shed-high H sheds
          with an explicit 'overloaded' reply once H admitted requests
          are in flight, recovering at --shed-low L (default H/2) —
          rejected requests are counted, never queued; rate-limited
          replies carry the bucket's computed earliest-retry time;
          --shed-exec-weight W folds the engine executor's queue backlog
          into the shed signal (load = in-flight + W x backlog);
          --retries N (default 3) re-submits rate-limited requests after
          sleeping out the reply's Retry-After hint and reports measured
          goodput (answered vs offered req/s) — refusals without a clock
          (shed, bad shape) are terminal and never retried
  metrics snapshot [--artifacts DIR] [--requests N] [--workers W] [--threads T]
          [--capacity-words W] [--max-batch-rows R]
          [--rate R] [--burst B] [--shed-high H] [--shed-low L] [--out PATH]
          serve the test set through the engine backend, then emit the
          scrapeable MetricsReport as one JSON object (p50/p95/p99
          latency, rows-per-flush histogram, admission ledger with
          per-tenant rows summing to the globals, engine cache and
          executor counters, live queue depth); --out also writes the
          JSON to a file
  artifact verify <dir>
          load the artifact at <dir> and check it offline: manifest
          schema version, per-file sha256 checksums, and (when present)
          that the placement plan validates and matches the engine's
          own packing rules exactly; exits nonzero on any mismatch
  help    this message
";

/// Entry point used by main.rs. Returns the process exit code.
pub fn run(args: Args) -> Result<i32> {
    match args.subcommand() {
        Some("figures") => cmd_figures(&args),
        Some("selftest") => cmd_selftest(&args),
        Some("engine") => cmd_engine(&args),
        Some("bench-check") => cmd_bench_check(&args),
        Some("infer") => cmd_infer(&args),
        Some("serve") => cmd_serve(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("artifact") => cmd_artifact(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(0)
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n{USAGE}");
            Ok(2)
        }
    }
}

fn cmd_figures(args: &Args) -> Result<i32> {
    let all = args.has("all") || args.flags.is_empty();
    let mut printed = false;
    let mut emit = |flag: &str, f: &dyn Fn() -> String| {
        if all || args.has(flag) {
            print!("{}", f());
            printed = true;
        }
    };
    emit("fig4", &repro::fig4);
    emit("fig7", &repro::fig7);
    emit("area", &repro::area_table);
    emit("fig9", &repro::fig9);
    emit("fig11", &repro::fig11);
    emit("cmp", &repro::cim1_vs_cim2);
    emit("fig12", &repro::fig12);
    emit("fig13", &repro::fig13);
    emit("err", &repro::error_prob);
    // The cosim is a verdict, not just a table: report its status
    // through the exit code so CI can assert it directly.
    let mut cosim_failed = false;
    if all || args.has("cosim") {
        let (table, ok) = repro::engine_cosim_status();
        print!("{table}");
        printed = true;
        if !ok {
            eprintln!("cosim FAILED: engine diverged from the reference or the accounting");
            cosim_failed = true;
        }
    }
    if !printed {
        eprintln!("no figure selected\n{USAGE}");
        return Ok(2);
    }
    Ok(if cosim_failed { 1 } else { 0 })
}

fn cmd_bench_check(args: &Args) -> Result<i32> {
    let baseline_path = args.get_or("baseline", "BENCH_baseline.json");
    let fresh_path = args.get_or("fresh", "BENCH_engine.json");
    let cap_baseline_path = args.get_or("capacity-baseline", "BENCH_capacity_baseline.json");
    let cap_fresh_path = args.get_or("capacity-fresh", "BENCH_capacity.json");
    let tol = args.get_f64("tolerance", 20.0);
    let read = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    };
    let baseline = read(&baseline_path)?;
    let fresh = read(&fresh_path)?;
    let (report, mut ok) = bench_check::compare(&baseline, &fresh, tol);
    print!("{report}");
    // The capacity gate is optional when no capacity baseline is
    // committed; once one exists, a missing fresh BENCH_capacity.json
    // is itself a failure (losing the bench silently is a regression).
    if std::path::Path::new(&cap_baseline_path).exists() {
        let cap_baseline = read(&cap_baseline_path)?;
        let cap_fresh = read(&cap_fresh_path)?;
        let (cap_report, cap_ok) = bench_check::compare_capacity(&cap_baseline, &cap_fresh, tol);
        print!("{cap_report}");
        ok = ok && cap_ok;
    } else {
        println!("(no {cap_baseline_path} — capacity hit-rate gate skipped)");
    }
    Ok(if ok { 0 } else { 1 })
}

fn cmd_selftest(args: &Args) -> Result<i32> {
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);
    let mut failures = 0;
    for tech in Tech::ALL {
        let mut a1 = SiTeCim1Array::with_dims(tech, 256, 64);
        let mut a2 = SiTeCim2Array::with_dims(tech, 256, 64);
        let w = rng.ternary_vec(256 * 64, 0.5);
        a1.write_matrix(&w);
        a2.write_matrix(&w);
        let inputs = rng.ternary_vec(256, 0.5);
        let ok1 = a1.dot(&inputs) == mac::dot_ref(a1.storage(), &inputs, mac::Flavor::Cim1);
        let ok2 = a2.dot(&inputs) == mac::dot_ref(a2.storage(), &inputs, mac::Flavor::Cim2);
        println!(
            "{:<10} CiM I functional: {}  CiM II functional: {}",
            tech.name(),
            if ok1 { "OK" } else { "FAIL" },
            if ok2 { "OK" } else { "FAIL" }
        );
        failures += usize::from(!ok1) + usize::from(!ok2);
    }
    Ok(if failures == 0 { 0 } else { 1 })
}

fn cmd_engine(args: &Args) -> Result<i32> {
    let m = args.get_usize("m", 8);
    let k = args.get_usize("k", 1024);
    let n = args.get_usize("n", 1024);
    let threads = args.get_usize("threads", 0);
    let seed = args.get_u64("seed", 42);
    let resident = args.has("resident");
    let reps = args.get_usize("reps", if resident { 8 } else { 1 }).max(1);
    let design = match args.get_or("design", "cim1").as_str() {
        "cim1" => Design::Cim1,
        "cim2" => Design::Cim2,
        "nm" => Design::NearMemory,
        other => {
            eprintln!("unknown --design '{other}' (expected cim1|cim2|nm)");
            return Ok(2);
        }
    };
    let capacity = args.get_u64("capacity-words", 0);
    let mut cfg = EngineConfig::new(design, Tech::Femfet3T);
    if threads > 0 {
        cfg = cfg.with_threads(threads);
    }
    if capacity > 0 {
        // Capacity-bounded pool: serve under second-chance eviction
        // pressure when the working set exceeds the word budget.
        cfg = cfg.with_capacity_words(capacity);
    } else if resident {
        // Size the pool to the working set so repeated GEMMs are fully
        // resident (one array per tile).
        let tiles = cfg.tiles_for(k, n);
        cfg = cfg.with_pool(tiles.max(1));
    }
    let engine = TernaryGemmEngine::new(cfg);
    if capacity > 0 {
        println!(
            "capacity-bounded pool: {} words → {} arrays of {}x{}",
            capacity,
            engine.pool_arrays(),
            engine.cfg().array_rows,
            engine.cfg().array_cols,
        );
    }
    let mut rng = Rng::new(seed);
    let x = rng.ternary_vec(m * k, 0.5);
    let w = rng.ternary_vec(k * n, 0.5);
    let macs = (reps * m * k * n) as f64;

    // Streaming: every rep re-programs every tile.
    let t0 = Instant::now();
    let mut got = engine.gemm(&x, &w, m, k, n)?;
    for _ in 1..reps {
        got = engine.gemm(&x, &w, m, k, n)?;
    }
    let dt_stream = t0.elapsed().as_secs_f64();

    let want = reference_gemm(&x, &w, m, &engine.grid(k, n), design.flavor());
    let mut mismatches = got.iter().zip(&want).filter(|(a, b)| a != b).count();

    println!(
        "{:?} GEMM {m}x{k}x{n} ×{reps} on {} threads (streaming): {:.3}s, {:.2} GMAC/s",
        design,
        engine.cfg().n_threads,
        dt_stream,
        macs / dt_stream / 1e9,
    );

    if resident {
        // Resident: tiles are programmed on first touch, then every rep
        // hits the placement cache.
        let id = engine.register_weight(&w, k, n)?;
        let before = engine.stats();
        let t1 = Instant::now();
        let mut rgot = engine.gemm_resident(id, &x, m)?;
        for _ in 1..reps {
            rgot = engine.gemm_resident(id, &x, m)?;
        }
        let dt_res = t1.elapsed().as_secs_f64();
        let d = engine.stats().since(&before);
        mismatches += rgot.iter().zip(&want).filter(|(a, b)| a != b).count();
        println!(
            "{:?} GEMM {m}x{k}x{n} ×{reps} on {} threads (resident):  {:.3}s, {:.2} GMAC/s ({:.2}x vs streaming)",
            design,
            engine.cfg().n_threads,
            dt_res,
            macs / dt_res / 1e9,
            dt_stream / dt_res,
        );
        println!(
            "tile cache: {} hits, {} misses ({:.1}% hit rate), {} evictions, {} regions programmed ({} resident)",
            d.hits,
            d.misses,
            100.0 * d.hit_rate(),
            d.evictions,
            d.tiles,
            engine.resident_tiles(),
        );
        let e = engine.exec_stats();
        println!(
            "executor: {} items ({} affine / {} stolen / {} spilled), max queue depth {}, {} panics",
            e.executed, e.affine, e.stolen, e.spilled, e.queue_depth_max, e.panics
        );
    } else {
        let s = engine.stats();
        println!("{} tiles programmed, {} MAC windows", s.tiles, s.windows);
    }

    if mismatches == 0 {
        println!("verified: bit-identical to dot_ref composed over tiles");
        Ok(0)
    } else {
        eprintln!("FAIL: {mismatches}/{} outputs diverge from the reference", got.len());
        Ok(1)
    }
}

fn cmd_infer(args: &Args) -> Result<i32> {
    let dir = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(runtime::default_dir);
    let kind = match args.get_or("model", "cim1").as_str() {
        "cim2" => ModelKind::Cim2,
        "exact" => ModelKind::Exact,
        _ => ModelKind::Cim1,
    };
    let manifest = Manifest::load(&dir)?;
    let client = runtime::cpu_client()?;
    let exe = runtime::MlpExecutor::load(&client, &manifest, kind)?;
    let (x, y) = manifest.load_test_set()?;
    let n = args.get_usize("n", manifest.test_n).min(manifest.test_n);

    let t0 = Instant::now();
    let mut correct = 0usize;
    for base in (0..n).step_by(exe.batch) {
        let nb = exe.batch.min(n - base);
        let preds = exe.classify(&x[base * manifest.in_dim..(base + nb) * manifest.in_dim], nb)?;
        correct += preds
            .iter()
            .zip(&y[base..base + nb])
            .filter(|(p, &l)| **p == l as usize)
            .count();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{kind:?}: {}/{} correct ({:.2}%), {:.1} inferences/s (PJRT CPU)",
        correct,
        n,
        100.0 * correct as f64 / n as f64,
        n as f64 / dt
    );
    Ok(0)
}

fn cmd_serve(args: &Args) -> Result<i32> {
    if let Some(spec) = args.get("model") {
        if spec.contains('=') {
            return cmd_serve_multi(args, spec);
        }
    }
    let dir = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(runtime::default_dir);
    let n_requests = args.get_usize("requests", 2048);
    let mut cfg = ServerConfig::new(dir.clone());
    cfg.n_workers = args.get_usize("workers", 2);
    cfg.policy.max_batch = args.get_usize("batch", 32);
    cfg.policy.max_batch_rows = args.get_usize("max-batch-rows", cfg.policy.max_batch_rows);
    apply_pipeline_flags(args, &mut cfg.policy);
    cfg.engine_threads = args.get_usize("threads", 2);
    let capacity = args.get_u64("capacity-words", 0);
    cfg.capacity_words = if capacity > 0 { Some(capacity) } else { None };
    cfg.ingress = ingress_from_args(args);
    cfg.backend = match args.get_or("backend", "pjrt").as_str() {
        "pjrt" => BackendKind::Pjrt,
        "engine" => BackendKind::Engine,
        other => {
            eprintln!("unknown --backend '{other}' (expected pjrt|engine)");
            return Ok(2);
        }
    };
    let manifest = Manifest::load(&dir)?;
    let (x, y) = manifest.load_test_set()?;

    let retries = args.get_usize("retries", 3);
    let server = Server::start(cfg)?;
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    let mut retry_attempts = 0usize;
    let mut retried_requests = 0usize;
    for i in 0..n_requests {
        let s = i % manifest.test_n;
        let input = x[s * manifest.in_dim..(s + 1) * manifest.in_dim].to_vec();
        // With an ingress policy armed, refusals are expected behavior,
        // not driver failures: rate limits carry a Retry-After hint and
        // get re-submitted after backoff; everything else (shed, bad
        // shape) is counted and skipped.
        let (res, spent) = submit_with_retry(|| server.infer_async(input.clone()), retries);
        retry_attempts += spent;
        retried_requests += usize::from(spent > 0);
        match res {
            Ok(rx) => pending.push((s, rx)),
            Err(_) => rejected += 1,
        }
    }
    let answered = pending.len();
    let mut correct = 0usize;
    for (s, rx) in pending {
        let reply = rx.recv()?.map_err(anyhow::Error::msg)?;
        if reply.pred == y[s] as usize {
            correct += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {answered}/{n_requests} requests in {dt:.2}s ({:.0} req/s), accuracy {:.2}%",
        answered as f64 / dt,
        100.0 * correct as f64 / answered.max(1) as f64
    );
    if retry_attempts > 0 || rejected > 0 {
        println!(
            "client retry: {retry_attempts} backoff retries across {retried_requests} requests \
             (budget {retries} each), {rejected} refused for good; \
             measured goodput {:.0} of {:.0} offered req/s",
            answered as f64 / dt,
            n_requests as f64 / dt
        );
    }
    println!("{}", server.metrics.report());
    let ing = server.ingress().snapshot();
    if rejected > 0 || ing.offered() > ing.admitted {
        println!(
            "ingress: {} offered, {} admitted, {} bad shape, {} rate limited, {} shed",
            ing.offered(),
            ing.admitted,
            ing.rejected_shape,
            ing.rate_limited,
            ing.shed
        );
    }
    if let Some(model) = server.engine_model() {
        let s = model.engine_stats();
        println!(
            "engine pool: {} arrays ({} words); tile cache: {} hits, {} misses ({:.1}% hit rate), {} evictions, {} regions programmed",
            model.pool_arrays(),
            model.capacity_words(),
            s.hits,
            s.misses,
            100.0 * s.hit_rate(),
            s.evictions,
            s.tiles
        );
        let e = model.exec_stats();
        println!(
            "executor: {} items across all workers ({} affine / {} stolen / {} spilled), max queue depth {}, {} panics",
            e.executed, e.affine, e.stolen, e.spilled, e.queue_depth_max, e.panics
        );
    }
    if let Some(m) = server.measured_residency() {
        println!(
            "measured residency: {} write rows over {} inferences → {}/inf energy, {}/inf latency (amortized write {} + marginal)",
            m.write_rows,
            m.inferences,
            crate::util::units::fmt_energy(m.energy_per_inf_j),
            crate::util::units::fmt_time(m.latency_per_inf_s),
            crate::util::units::fmt_energy(m.write_energy_j / m.inferences.max(1) as f64),
        );
    }
    server.shutdown();
    Ok(0)
}

fn cmd_serve_multi(args: &Args, spec: &str) -> Result<i32> {
    let mut models: Vec<(String, PathBuf)> = Vec::new();
    for part in spec.split(',') {
        let (name, dir) = part
            .split_once('=')
            .with_context(|| format!("bad --model entry {part:?} (expected name=dir)"))?;
        models.push((name.to_string(), PathBuf::from(dir)));
    }
    let n_requests = args.get_usize("requests", 512);
    let capacity = args.get_u64("capacity-words", 2 * 1024 * 1024);
    let mut cfg = MultiServerConfig::new(models.clone(), capacity);
    cfg.n_workers = args.get_usize("workers", 1);
    cfg.policy.max_batch = args.get_usize("batch", 32);
    cfg.policy.max_batch_rows = args.get_usize("max-batch-rows", cfg.policy.max_batch_rows);
    apply_pipeline_flags(args, &mut cfg.policy);
    cfg.engine_threads = args.get_usize("threads", 2);
    cfg.ingress = ingress_from_args(args);
    if let Some(rspec) = args.get("reserve") {
        for part in rspec.split(',') {
            let (name, words) = part
                .split_once('=')
                .with_context(|| format!("bad --reserve entry {part:?} (expected name=words)"))?;
            let words: u64 =
                words.parse().with_context(|| format!("bad --reserve words in {part:?}"))?;
            cfg.reserves.insert(name.to_string(), words);
        }
    }

    let mut sets = Vec::new();
    for (name, dir) in &models {
        let manifest = Manifest::load(dir)?;
        let (x, y) = manifest.load_test_set()?;
        sets.push((name.clone(), manifest.in_dim, manifest.test_n, x, y));
    }
    let retries = args.get_usize("retries", 3);
    let server = MultiServer::start(cfg)?;
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    let mut retry_attempts = 0usize;
    let mut retried_requests = 0usize;
    for i in 0..n_requests {
        let (name, in_dim, test_n, x, _) = &sets[i % sets.len()];
        let s = (i / sets.len()) % test_n;
        let input = x[s * in_dim..(s + 1) * in_dim].to_vec();
        let (res, spent) = submit_with_retry(|| server.infer_async(name, input.clone()), retries);
        retry_attempts += spent;
        retried_requests += usize::from(spent > 0);
        match res {
            Ok(rx) => pending.push((i % sets.len(), s, rx)),
            Err(_) => rejected += 1,
        }
    }
    let answered = pending.len();
    let mut correct = 0usize;
    for (mi, s, rx) in pending {
        let reply = rx.recv()?.map_err(anyhow::Error::msg)?;
        if reply.pred == sets[mi].4[s] as usize {
            correct += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {answered}/{n_requests} requests across {} models in {dt:.2}s ({:.0} req/s), accuracy {:.2}%",
        sets.len(),
        answered as f64 / dt,
        100.0 * correct as f64 / answered.max(1) as f64
    );
    if retry_attempts > 0 || rejected > 0 {
        println!(
            "client retry: {retry_attempts} backoff retries across {retried_requests} requests \
             (budget {retries} each), {rejected} refused for good; \
             measured goodput {:.0} of {:.0} offered req/s",
            answered as f64 / dt,
            n_requests as f64 / dt
        );
    }
    println!("{}", server.metrics.report());
    if rejected > 0 {
        let ing = server.ingress().snapshot();
        println!(
            "ingress: {} offered, {} admitted, {} bad shape, {} rate limited, {} shed, {} unknown model",
            ing.offered(),
            ing.admitted,
            ing.rejected_shape,
            ing.rate_limited,
            ing.shed,
            ing.unknown_model
        );
    }
    for name in server.model_names() {
        let gen = server.model_generation(&name).unwrap_or(0);
        if let Some(m) = server.measured_residency(&name) {
            println!(
                "tenant {name} (v{gen}): {} requests, {:.1}% hit rate, {} plan + {} traffic write rows, {}/inf energy, {}/inf latency",
                m.inferences,
                100.0 * m.hit_rate,
                m.plan_write_rows,
                m.write_rows,
                crate::util::units::fmt_energy(m.energy_per_inf_j),
                crate::util::units::fmt_time(m.latency_per_inf_s),
            );
        }
    }
    server.shutdown();
    Ok(0)
}

/// Client-side retry with backoff: re-submit a refused request when the
/// refusal carries the rate limiter's Retry-After hint
/// ([`InferError::retry_after_s`]), sleeping out the hint (bounded, so a
/// misconfigured limiter cannot stall the driver) up to `retries`
/// times. Refusals without a clock — shed, bad shape, shutdown — are
/// terminal: sleeping cannot clear them from the client side. Returns
/// the final outcome plus the retries actually spent.
fn submit_with_retry<T>(
    mut submit: impl FnMut() -> Result<T, InferError>,
    retries: usize,
) -> (Result<T, InferError>, usize) {
    let mut spent = 0usize;
    loop {
        match submit() {
            Ok(v) => return (Ok(v), spent),
            Err(e) => match e.retry_after_s() {
                Some(t) if spent < retries => {
                    std::thread::sleep(std::time::Duration::from_secs_f64(t.clamp(0.0005, 0.25)));
                    spent += 1;
                }
                _ => return (Err(e), spent),
            },
        }
    }
}

/// Shared ingress flags: `--rate R [--burst B]` arms the per-tenant
/// token bucket, `--shed-high H [--shed-low L]` arms the load-shedding
/// watermarks (L defaults to H/2), and `--shed-exec-weight W` folds the
/// engine executor's queue backlog into the shed signal (load =
/// in-flight + W × backlog; 0 keeps the backlog gauge-only). Absent
/// flags leave the gate open.
fn ingress_from_args(args: &Args) -> IngressConfig {
    let mut cfg = IngressConfig::default();
    let rate = args.get_f64("rate", 0.0);
    if rate > 0.0 {
        cfg.rate = Some(RateLimit { per_s: rate, burst: args.get_f64("burst", rate).max(1.0) });
    }
    let high = args.get_u64("shed-high", 0);
    if high > 0 {
        let low = args.get_u64("shed-low", high / 2);
        cfg.shed = Some(Watermarks { high, low: low.min(high - 1) });
    }
    cfg.exec_backlog_weight = args.get_f64("shed-exec-weight", cfg.exec_backlog_weight);
    cfg
}

/// Shared layer-pipelined batching flags (engine backend):
/// `--no-pipeline-admission` reverts to layer-0-only flush formation,
/// `--max-stage-admit-rows R` caps rows admitted at any single layer
/// boundary, and `--max-catchup-frac F` bounds how deep a boundary may
/// still admit (the late-admission cost model; 1.0 = every boundary).
fn apply_pipeline_flags(args: &Args, policy: &mut crate::coordinator::BatchPolicy) {
    if args.has("no-pipeline-admission") {
        policy.pipeline_admission = false;
    }
    policy.max_stage_admit_rows =
        args.get_usize("max-stage-admit-rows", policy.max_stage_admit_rows);
    policy.max_catchup_frac = args.get_f64("max-catchup-frac", policy.max_catchup_frac);
}

/// `metrics snapshot`: serve the artifact's test set through the engine
/// backend under the requested ingress policy, then emit the full
/// scrapeable [`crate::coordinator::MetricsReport`] as one JSON object
/// (optionally also written to `--out`).
fn cmd_metrics(args: &Args) -> Result<i32> {
    if args.positional.get(1).map(String::as_str) != Some("snapshot") {
        eprintln!("usage: sitecim metrics snapshot [--artifacts DIR] [--requests N] [--out PATH]");
        return Ok(2);
    }
    let dir = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(runtime::default_dir);
    let n_requests = args.get_usize("requests", 256);
    let mut cfg = ServerConfig::new(dir.clone()).with_engine_backend();
    cfg.n_workers = args.get_usize("workers", 2);
    cfg.policy.max_batch_rows = args.get_usize("max-batch-rows", cfg.policy.max_batch_rows);
    cfg.engine_threads = args.get_usize("threads", 2);
    let capacity = args.get_u64("capacity-words", 0);
    cfg.capacity_words = if capacity > 0 { Some(capacity) } else { None };
    cfg.ingress = ingress_from_args(args);
    let manifest = Manifest::load(&dir)?;
    let (x, _) = manifest.load_test_set()?;

    let server = Server::start(cfg)?;
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let s = i % manifest.test_n;
        let input = x[s * manifest.in_dim..(s + 1) * manifest.in_dim].to_vec();
        if let Ok(rx) = server.infer_async(input) {
            pending.push(rx);
        }
    }
    for rx in pending {
        let _ = rx.recv()?; // backend errors still count in the report
    }
    let report = server.metrics_report();
    let json = report.to_json().to_string();
    println!("{json}");
    if let Some(path) = args.get("out") {
        std::fs::write(path, &json).with_context(|| format!("writing {path}"))?;
    }
    server.shutdown();
    Ok(0)
}

fn cmd_artifact(args: &Args) -> Result<i32> {
    if args.positional.get(1).map(String::as_str) != Some("verify") {
        eprintln!("usage: sitecim artifact verify <dir>");
        return Ok(2);
    }
    let dir: PathBuf = args
        .positional
        .get(2)
        .map(Into::into)
        .unwrap_or_else(runtime::default_dir);
    // Manifest::load already enforces the schema version and re-hashes
    // every checksummed file — an error here IS a failed verification.
    let manifest = Manifest::load(&dir)
        .with_context(|| format!("artifact at {} failed verification", dir.display()))?;
    println!(
        "manifest v{}: {} weight layers, {} checksummed files — checksums OK",
        manifest.version,
        manifest.weights.len(),
        manifest.sha256.len()
    );
    match &manifest.placement {
        None => println!("no placement plan (serving will discover placements on first touch)"),
        Some(plan) => {
            let layers: Vec<(usize, usize)> =
                manifest.dims.windows(2).map(|w| (w[0], w[1])).collect();
            let recomputed = plan_layout(&layers, plan.array_rows, plan.array_cols, plan.slots)
                .context("placement plan claims a pool the model does not fit")?;
            if recomputed != plan.shards {
                eprintln!(
                    "FAILED: placement plan diverges from the engine's packing rules \
                     ({} shards in plan, {} recomputed)",
                    plan.shards.len(),
                    recomputed.len()
                );
                return Ok(1);
            }
            println!(
                "placement plan OK: {} shards over {} {}x{} arrays, matches engine packing",
                plan.shards.len(),
                plan.slots,
                plan.array_rows,
                plan.array_cols
            );
        }
    }
    Ok(0)
}
