//! Device layer: analytic transistor and ferroelectric models plus the
//! per-technology parameter presets that calibrate the whole simulator.
//!
//! This replaces the paper's SPICE + 45 nm PTM + Preisach/Miller modelling
//! flow (DESIGN.md §1, substitution table).

pub mod bitcell;
pub mod femfet;
pub mod ptm;
pub mod tech;

pub use bitcell::BitCell;
pub use tech::{PeriphParams, Tech, TechParams};
