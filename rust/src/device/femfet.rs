//! Ferroelectric-metal-FET (FEMFET) device model.
//!
//! Reproduces the paper's modelling setup (§II.D): a Preisach-based
//! Miller-equation ferroelectric coupled to the underlying 45 nm FET.
//! Constants are the paper's calibration to the IEDM'17 HZO data:
//! P_R = 27 µC/cm², P_S = 30 µC/cm², E_C = 2.3 MV/cm, switching time
//! constant τ = 200 ps, T_FE = 15 nm. Write uses −5 V (global reset to −P)
//! and +4.8 V (selective set to +P).
//!
//! The FE polarization shifts the effective threshold of the underlying
//! metal-gate FET: +P (set, '1') → low-V_T → low-resistance read path
//! (LRS); −P → high-V_T → HRS. We model the read-path distinguishability
//! as an LRS/HRS current ratio derived from the V_T shift.

use super::ptm::Fet;

/// Paper constants (SI units).
pub const P_R: f64 = 27.0e-6 * 1e4; // 27 µC/cm² -> C/m²
pub const P_S: f64 = 30.0e-6 * 1e4; // 30 µC/cm² -> C/m²
pub const E_C: f64 = 2.3e8; // 2.3 MV/cm -> V/m
pub const TAU_SWITCH: f64 = 200e-12; // 200 ps
pub const T_FE: f64 = 15e-9; // 15 nm
pub const V_RESET: f64 = -5.0;
pub const V_SET: f64 = 4.8;

/// Miller saturation-curve slope parameter δ. Calibrated so the paper's
/// set condition (+4.8 V across 15 nm) drives ≥97% of P_S — the paper's
/// write protocol treats 4.8 V as a robust set, and remanence then relaxes
/// to P_R (27 µC/cm²) at zero field. With this δ the descending branch at
/// E = 0 sits essentially at P_S, so `release()` clamps to ±P_R.
fn miller_delta() -> f64 {
    let e_set = V_SET / T_FE;
    (e_set - E_C) / (2.0 * 0.97f64.atanh())
}

/// Dynamic state of one FEMFET's ferroelectric.
#[derive(Clone, Debug)]
pub struct Femfet {
    /// Current polarization (C/m²), negative = reset/HRS, positive = LRS.
    pub p: f64,
    /// Underlying transistor (metal-gate FET under the FE).
    pub fet: Fet,
    /// FE film area equals the FET gate area (paper: same cross-section,
    /// allowing minimum-size underlying FET).
    pub area: f64,
}

impl Femfet {
    pub fn new() -> Femfet {
        // Underlying metal-gate FET centred at V_T = 0.5 V so the FE's
        // ±0.5 V shift puts LRS at V_T ≈ 0 and HRS fully sub-threshold —
        // the "significantly larger distinguishability" the paper credits
        // FEMFETs with (§II.C).
        let mut fet = Fet::nfet_min();
        fet.vth = 0.50;
        let area = fet.width * fet.length;
        Femfet { p: -P_R, fet, area }
    }

    /// Target (saturation-branch) polarization at applied field `e` (V/m).
    pub fn p_target(e: f64) -> f64 {
        let d = miller_delta();
        if e >= 0.0 {
            P_S * ((e - E_C) / (2.0 * d)).tanh()
        } else {
            P_S * ((e + E_C) / (2.0 * d)).tanh()
        }
    }

    /// Apply a voltage pulse of the given duration across the FE
    /// (first-order Miller dynamics: dP/dt = (P_tgt − P)/τ).
    pub fn pulse(&mut self, v: f64, duration: f64) {
        let e = v / T_FE;
        let tgt = Self::p_target(e);
        let frac = 1.0 - (-duration / TAU_SWITCH).exp();
        self.p += (tgt - self.p) * frac;
    }

    /// Relax the applied field (remanence): polarization decays toward the
    /// remanent value of its sign. We approximate retention as ideal over
    /// inference timescales (non-volatile).
    pub fn release(&mut self) {
        self.p = self.p.clamp(-P_R, P_R);
    }

    /// Stored bit: +P = '1' (LRS), −P = '0' (HRS). Mid-range polarization
    /// (partial switching) resolves by sign.
    pub fn bit(&self) -> bool {
        self.p > 0.0
    }

    /// Threshold shift of the underlying FET caused by polarization
    /// (ΔV_T = P · T_FE / ε_FE, linearized; calibrated to give ~0.8 V
    /// separation between states — typical of HZO FEMFET demonstrations).
    pub fn vth_shift(&self) -> f64 {
        // Normalize: full ±P_R swings V_T by ∓0.5 V around the base value.
        -0.5 * (self.p / P_R)
    }

    /// Effective read-path transistor for the current state.
    pub fn effective_fet(&self) -> Fet {
        let mut f = self.fet.clone();
        f.vth = (f.vth + self.vth_shift()).max(0.05);
        f
    }

    /// Read current at the given RWL gate drive (A), LRS vs HRS.
    pub fn read_current(&self, vdd: f64) -> f64 {
        self.effective_fet().i_d(vdd, vdd / 2.0)
    }

    /// Time to switch polarization from fully-reset to ≥90% of +P_R at
    /// the set voltage (used for write-latency modelling).
    pub fn set_time() -> f64 {
        // 1 - exp(-t/τ) on the gap to target; target at V_SET is ≈ P_S.
        let mut f = Femfet::new();
        let step = 50e-12;
        let mut t = 0.0;
        while f.p < 0.9 * P_R && t < 100e-9 {
            f.pulse(V_SET, step);
            t += step;
        }
        t
    }
}

impl Default for Femfet {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remanence_matches_calibration() {
        // After full positive saturation and release, P ≈ P_R.
        let mut f = Femfet::new();
        f.pulse(V_SET, 10e-9);
        f.release();
        assert!((f.p - P_R).abs() / P_R < 0.05, "p = {}", f.p);
    }

    #[test]
    fn reset_then_set_flips_bit() {
        let mut f = Femfet::new();
        f.pulse(V_RESET, 5e-9);
        assert!(!f.bit());
        f.pulse(V_SET, 5e-9);
        assert!(f.bit());
    }

    #[test]
    fn subcoercive_pulse_does_not_switch() {
        let mut f = Femfet::new(); // starts at -P_R
        // 1 V across 15 nm = 0.67 MV/cm << E_C = 2.3 MV/cm.
        f.pulse(1.0, 1e-9);
        f.release();
        assert!(!f.bit(), "read disturb switched the cell: p={}", f.p);
    }

    #[test]
    fn lrs_hrs_ratio_large() {
        let mut lrs = Femfet::new();
        lrs.pulse(V_SET, 5e-9);
        lrs.release();
        let mut hrs = Femfet::new();
        hrs.pulse(V_RESET, 5e-9);
        hrs.release();
        let ratio = lrs.read_current(1.0) / hrs.read_current(1.0).max(1e-18);
        assert!(ratio > 50.0, "LRS/HRS = {ratio}");
    }

    #[test]
    fn set_time_is_subnanosecond_scale() {
        let t = Femfet::set_time();
        // τ = 200 ps → ~a few hundred ps to 90%.
        assert!(t > 50e-12 && t < 5e-9, "t_set = {t}");
    }

    #[test]
    fn miller_curve_saturates() {
        let p_hi = Femfet::p_target(5.0 / T_FE);
        assert!(p_hi > 0.95 * P_S);
        let p_lo = Femfet::p_target(-5.0 / T_FE);
        assert!(p_lo < -0.95 * P_S);
    }
}
