//! Behavioral bit-cell models for the three technologies.
//!
//! A `BitCell` stores one binary value and exposes the read-path current
//! the cell injects onto its read bit-line when its read word-line is
//! asserted. Write semantics differ per technology:
//! - 8T-SRAM: direct BL/BLB drive, destructive of the old value, fast.
//! - 3T-eDRAM: charge C_G through the PMOS WAX; volatile — a retention
//!   clock ages the stored level and `needs_refresh` turns true.
//! - 3T-FEMFET: global reset (−P) then selective set (+P) via the
//!   `femfet::Femfet` polarization model; non-volatile.

use super::femfet::{Femfet, V_RESET, V_SET};
use super::tech::{Tech, TechParams};

/// eDRAM retention time at 45 nm-class gain cells (conservative ~40 µs;
/// [23] reports 10–100 µs class retention with boosting).
pub const EDRAM_RETENTION_S: f64 = 40e-6;

#[derive(Clone, Debug)]
enum Storage {
    Sram { q: bool },
    Edram { level: f64, age_s: f64 },
    Femfet { dev: Femfet },
}

/// One binary bit-cell.
#[derive(Clone, Debug)]
pub struct BitCell {
    storage: Storage,
    tech: Tech,
}

impl BitCell {
    pub fn new(tech: Tech) -> BitCell {
        let storage = match tech {
            Tech::Sram8T => Storage::Sram { q: false },
            Tech::Edram3T => Storage::Edram { level: 0.0, age_s: 0.0 },
            Tech::Femfet3T => Storage::Femfet { dev: Femfet::new() },
        };
        BitCell { storage, tech }
    }

    pub fn tech(&self) -> Tech {
        self.tech
    }

    /// Program the cell.
    pub fn write(&mut self, bit: bool) {
        match &mut self.storage {
            Storage::Sram { q } => *q = bit,
            Storage::Edram { level, age_s } => {
                *level = if bit { 1.0 } else { 0.0 };
                *age_s = 0.0;
            }
            Storage::Femfet { dev } => {
                // Paper write protocol: global reset to −P, then selective
                // set. At single-cell granularity this is reset-then-set.
                dev.pulse(V_RESET, 5e-9);
                if bit {
                    dev.pulse(V_SET, 5e-9);
                }
                dev.release();
            }
        }
    }

    /// The stored bit as currently sensed.
    pub fn bit(&self) -> bool {
        match &self.storage {
            Storage::Sram { q } => *q,
            Storage::Edram { level, .. } => *level > 0.5,
            Storage::Femfet { dev } => dev.bit(),
        }
    }

    /// Advance time (retention ageing; only eDRAM cares).
    pub fn tick(&mut self, dt_s: f64) {
        if let Storage::Edram { level, age_s } = &mut self.storage {
            *age_s += dt_s;
            // Exponential droop of the stored '1' level toward 0. Time
            // constant 3× the refresh deadline: at the deadline the level
            // has fallen to ~0.72 — still safely sensed, which is the
            // point of refreshing *before* data is lost.
            if *level > 0.0 {
                *level = (-*age_s / (EDRAM_RETENTION_S * 3.0)).exp();
            }
        }
    }

    /// True when a refresh is required to guarantee correct sensing.
    pub fn needs_refresh(&self) -> bool {
        match &self.storage {
            Storage::Edram { age_s, .. } => *age_s >= EDRAM_RETENTION_S,
            _ => false,
        }
    }

    /// Refresh (rewrite the currently-sensed value).
    pub fn refresh(&mut self) {
        let b = self.bit();
        self.write(b);
    }

    /// Read-path current injected on the RBL when RWL is asserted at
    /// `vdd`, given the technology parameters (A).
    pub fn read_current(&self, p: &TechParams) -> f64 {
        let on = match &self.storage {
            Storage::Sram { q } => *q,
            Storage::Edram { level, .. } => *level > 0.5,
            Storage::Femfet { dev } => dev.bit(),
        };
        if on {
            // eDRAM read strength degrades with droop.
            if let Storage::Edram { level, .. } = &self.storage {
                return p.i_lrs * level.clamp(0.0, 1.0);
            }
            p.i_lrs
        } else {
            p.i_hrs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_all_techs() {
        for tech in Tech::ALL {
            let mut c = BitCell::new(tech);
            assert!(!c.bit(), "{:?} should initialize to 0", tech);
            c.write(true);
            assert!(c.bit(), "{:?} failed to store 1", tech);
            c.write(false);
            assert!(!c.bit(), "{:?} failed to store 0", tech);
        }
    }

    #[test]
    fn read_current_ratio() {
        for tech in Tech::ALL {
            let p = TechParams::new(tech);
            let mut c = BitCell::new(tech);
            c.write(true);
            let i1 = c.read_current(&p);
            c.write(false);
            let i0 = c.read_current(&p);
            assert!(i1 / i0.max(1e-18) > 100.0, "{:?}: {i1}/{i0}", tech);
        }
    }

    #[test]
    fn edram_needs_refresh_after_retention() {
        let mut c = BitCell::new(Tech::Edram3T);
        c.write(true);
        assert!(!c.needs_refresh());
        c.tick(EDRAM_RETENTION_S * 1.1);
        assert!(c.needs_refresh());
        c.refresh();
        assert!(!c.needs_refresh());
        assert!(c.bit());
    }

    #[test]
    fn edram_droop_weakens_read_current() {
        let p = TechParams::new(Tech::Edram3T);
        let mut c = BitCell::new(Tech::Edram3T);
        c.write(true);
        let fresh = c.read_current(&p);
        c.tick(EDRAM_RETENTION_S);
        let aged = c.read_current(&p);
        assert!(aged < fresh);
        assert!(aged > 0.3 * fresh, "droop too aggressive before refresh deadline");
    }

    #[test]
    fn sram_and_femfet_do_not_age() {
        for tech in [Tech::Sram8T, Tech::Femfet3T] {
            let mut c = BitCell::new(tech);
            c.write(true);
            c.tick(1.0);
            assert!(!c.needs_refresh());
            assert!(c.bit());
        }
    }
}
