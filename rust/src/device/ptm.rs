//! Analytic 45 nm-class FET model (PTM-flavoured).
//!
//! The paper simulates cells in SPICE with 45 nm Predictive Technology
//! Models. We replace SPICE with a first-order alpha-power-law model with
//! velocity saturation — sufficient to capture what the array analysis
//! depends on: on/off current ratio, read-path stacking, gate/junction
//! capacitance, and RC discharge trends. Constants are 45 nm-class values
//! (I_on ≈ 1 mA/µm, I_off ≈ nA/µm, C_gate ≈ 1 fF/µm).

/// Transistor polarity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Polarity {
    N,
    P,
}

/// Alpha-power-law FET.
#[derive(Clone, Debug)]
pub struct Fet {
    pub polarity: Polarity,
    /// Channel width in metres.
    pub width: f64,
    /// Channel length in metres (the technology's drawn gate length).
    pub length: f64,
    /// Threshold voltage magnitude (V).
    pub vth: f64,
    /// Velocity-saturation exponent (α ≈ 1.3 at 45 nm).
    pub alpha: f64,
    /// Drive coefficient: saturation current per metre of width at
    /// overdrive of 1 V (A/m).
    pub k_sat: f64,
    /// Off-state leakage per metre of width at Vgs = 0 (A/m).
    pub i_off_per_m: f64,
    /// Gate capacitance per metre of width (F/m).
    pub c_gate_per_m: f64,
    /// Source/drain junction capacitance per metre of width (F/m).
    pub c_junction_per_m: f64,
}

/// 45 nm technology constants shared by both polarities.
pub const L_45NM: f64 = 45e-9;
/// Minimum drawn width used for high-density cells (2F).
pub const W_MIN_45NM: f64 = 90e-9;

impl Fet {
    /// Minimum-size NFET at the 45 nm node.
    pub fn nfet_min() -> Fet {
        Fet {
            polarity: Polarity::N,
            width: W_MIN_45NM,
            length: L_45NM,
            vth: 0.40,
            alpha: 1.3,
            // Calibrated to I_on ≈ 1.1 mA/µm at Vgs=Vds=1.0 V:
            // I_on = k_sat * W * (1.0 - 0.40)^1.3  ->  k_sat ≈ 2.15e3 A/m.
            k_sat: 2.15e3,
            i_off_per_m: 1.0e-4, // ~10 nA/µm (LSTP-flavoured; memory cells)
            c_gate_per_m: 1.0e-9, // ≈1 fF/µm
            c_junction_per_m: 0.9e-9, // ≈0.9 fF/µm (diffusion contact)
        }
    }

    /// Minimum-size PFET (≈40% weaker drive).
    pub fn pfet_min() -> Fet {
        Fet {
            polarity: Polarity::P,
            vth: 0.42,
            k_sat: 1.3e3,
            ..Fet::nfet_min()
        }
    }

    /// Same FET scaled to `w_mult` × minimum width.
    pub fn scaled(&self, w_mult: f64) -> Fet {
        Fet { width: self.width * w_mult, ..self.clone() }
    }

    /// Gate overdrive for the given |Vgs|.
    fn overdrive(&self, vgs: f64) -> f64 {
        (vgs - self.vth).max(0.0)
    }

    /// Saturation current at |Vgs| (A).
    pub fn i_dsat(&self, vgs: f64) -> f64 {
        let vov = self.overdrive(vgs);
        if vov <= 0.0 {
            return self.i_leak();
        }
        self.k_sat * self.width * vov.powf(self.alpha)
    }

    /// Drain current with a simple linear/saturation split:
    /// Vdsat = Vov/2 (alpha-power approximation).
    pub fn i_d(&self, vgs: f64, vds: f64) -> f64 {
        let vov = self.overdrive(vgs);
        if vov <= 0.0 {
            return self.i_leak();
        }
        let vdsat = vov / 2.0;
        let isat = self.i_dsat(vgs);
        if vds >= vdsat {
            isat
        } else {
            // Smooth triode: I = Isat * (2 - vds/vdsat) * (vds/vdsat)
            let x = (vds / vdsat).clamp(0.0, 1.0);
            isat * x * (2.0 - x)
        }
    }

    /// Subthreshold leakage (A) at Vgs = 0.
    pub fn i_leak(&self) -> f64 {
        self.i_off_per_m * self.width
    }

    /// Effective on-resistance when used as a pass/pull-down device at
    /// full gate drive `vdd`, evaluated at Vds = vdd/2 (mid-swing).
    pub fn r_on(&self, vdd: f64) -> f64 {
        let i = self.i_d(vdd, vdd / 2.0).max(1e-15);
        (vdd / 2.0) / i
    }

    /// Total gate capacitance (F).
    pub fn c_gate(&self) -> f64 {
        self.c_gate_per_m * self.width
    }

    /// Single-side junction capacitance (F).
    pub fn c_junction(&self) -> f64 {
        self.c_junction_per_m * self.width
    }
}

/// Series stack of two identical-drive devices — the classic read-port
/// structure (storage FET + access FET). Effective drive is roughly half.
pub fn stacked_current(top: &Fet, bottom: &Fet, vdd: f64) -> f64 {
    // Solve crudely: both in saturation is impossible in a stack at low
    // Vds; use series resistance approximation.
    let r = top.r_on(vdd) + bottom.r_on(vdd);
    (vdd / 2.0) / r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_current_is_45nm_class() {
        let n = Fet::nfet_min();
        let ion = n.i_dsat(1.0);
        let per_um = ion / (n.width * 1e6);
        // ~0.5–1.5 mA/µm is the 45nm HP ballpark.
        assert!(per_um > 0.5e-3 && per_um < 2.0e-3, "I_on/µm = {per_um}");
    }

    #[test]
    fn off_current_much_smaller() {
        let n = Fet::nfet_min();
        assert!(n.i_leak() < n.i_dsat(1.0) / 1e3);
    }

    #[test]
    fn triode_monotonic_in_vds() {
        let n = Fet::nfet_min();
        let mut last = 0.0;
        for i in 1..=20 {
            let vds = i as f64 * 0.05;
            let id = n.i_d(1.0, vds);
            assert!(id >= last - 1e-18, "non-monotonic at vds={vds}");
            last = id;
        }
    }

    #[test]
    fn zero_overdrive_leaks_only() {
        let n = Fet::nfet_min();
        assert_eq!(n.i_d(0.2, 0.5), n.i_leak());
    }

    #[test]
    fn pfet_weaker_than_nfet() {
        assert!(Fet::pfet_min().i_dsat(1.0) < Fet::nfet_min().i_dsat(1.0));
    }

    #[test]
    fn stack_halves_drive_roughly() {
        let n = Fet::nfet_min();
        let single = n.i_d(1.0, 0.5);
        let stack = stacked_current(&n, &n, 1.0);
        assert!(stack < single);
        assert!(stack > single / 4.0);
    }

    #[test]
    fn wider_device_scales_linearly() {
        let n = Fet::nfet_min();
        let w2 = n.scaled(2.0);
        let r = w2.i_dsat(1.0) / n.i_dsat(1.0);
        assert!((r - 2.0).abs() < 1e-9);
    }
}
