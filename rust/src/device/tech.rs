//! Technology presets — the single calibration hub for the whole stack.
//!
//! Every electrical/geometric number the array- and system-level models
//! consume lives here, per memory technology (8T-SRAM, 3T-eDRAM,
//! 3T-FEMFET). Values are 45 nm-class first-principles numbers (derived
//! from `ptm`/`femfet`) adjusted within plausible ranges so that the
//! *ratios* the paper reports emerge from the model equations — see
//! DESIGN.md §5 (calibration methodology). Nothing downstream hard-codes a
//! paper result; change a number here and every figure moves consistently.

use super::femfet::Femfet;
use super::ptm::{stacked_current, Fet};

/// The three memory technologies evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tech {
    Sram8T,
    Edram3T,
    Femfet3T,
}

impl Tech {
    pub const ALL: [Tech; 3] = [Tech::Sram8T, Tech::Edram3T, Tech::Femfet3T];

    pub fn name(&self) -> &'static str {
        match self {
            Tech::Sram8T => "8T-SRAM",
            Tech::Edram3T => "3T-eDRAM",
            Tech::Femfet3T => "3T-FEMFET",
        }
    }

    pub fn parse(s: &str) -> Option<Tech> {
        match s.to_ascii_lowercase().as_str() {
            "sram" | "8t-sram" | "sram8t" => Some(Tech::Sram8T),
            "edram" | "3t-edram" | "edram3t" => Some(Tech::Edram3T),
            "femfet" | "3t-femfet" | "femfet3t" => Some(Tech::Femfet3T),
            _ => None,
        }
    }
}

/// Per-technology electrical + geometric parameters for one *binary*
/// bit-cell (the ternary cell is built from two of these).
#[derive(Clone, Debug)]
pub struct TechParams {
    pub tech: Tech,
    /// Supply voltage for read/CiM (paper: 1 V for all).
    pub vdd: f64,
    /// Feature size (metres per F).
    pub f_m: f64,

    // ---- geometry of the NM-baseline binary cell (in F) ----
    pub cell_w_f: f64,
    pub cell_h_f: f64,

    // ---- read path ----
    /// Read current when the cell stores '1' (LRS path on), A.
    pub i_lrs: f64,
    /// Read-path current when the cell stores '0' (HRS/off), A.
    pub i_hrs: f64,
    /// Junction capacitance one read port adds to an RBL (F).
    pub c_junct_port: f64,
    /// Wire capacitance per F of bit-line length (F).
    pub c_wire_per_f: f64,
    /// Gate load one cell presents to its read word-line (F).
    pub c_wl_gate: f64,

    // ---- write path ----
    pub v_write: f64,
    /// Intrinsic cell write time (s) — storage-node settling (SRAM flip,
    /// C_G charge, FE polarization switch).
    pub t_write_cell: f64,
    /// Intrinsic per-cell write energy (J).
    pub e_write_cell: f64,

    // ---- sensing ----
    /// Voltage sense-amp resolve time (s) and energy (J).
    pub t_sa_v: f64,
    pub e_sa_v: f64,
    /// Current sense resolve time (s) and energy (J) — slower/hungrier.
    pub t_sa_i: f64,
    pub e_sa_i: f64,
}

/// Peripheral (45 nm CMOS, technology-independent) parameters.
#[derive(Clone, Debug)]
pub struct PeriphParams {
    /// 3-bit flash ADC: conversion time, energy, area (m²).
    pub t_adc: f64,
    pub e_adc: f64,
    pub a_adc: f64,
    /// Extra sense amplifier for the output-value-8 detection.
    pub e_sa_extra: f64,
    /// 3-bit digital subtractor (CiM I path).
    pub t_sub_dig: f64,
    pub e_sub_dig: f64,
    /// Analog comparator + current subtractor (CiM II path, Fig 6).
    pub t_cmp_sub: f64,
    pub e_cmp_sub: f64,
    /// Comparator + current-subtractor area per column (m²).
    pub a_cmp_sub: f64,
    /// Near-memory MAC unit: per ternary multiply-accumulate.
    pub t_nm_mac: f64,
    pub e_nm_mac: f64,
    /// NM MAC unit area per column-slice (m²) and the SiTe control logic.
    pub a_nm_mac_col: f64,
    /// Row decoder / WL driver energy per activation.
    pub e_wldrv: f64,
    /// Precharge/WL-driver cycle overhead (s).
    pub t_prech: f64,
    pub t_wl: f64,
    /// PCU (sample & hold + accumulator) per partial-sum op.
    pub e_pcu: f64,
    pub t_pcu: f64,
}

impl PeriphParams {
    pub fn default_45nm() -> PeriphParams {
        PeriphParams {
            // 3-bit flash: 7 comparators + thermometer decode. Low-res
            // flash at 45 nm: ~0.35 ns, ~0.1 pJ, ~20 µm² — small per
            // converter, but one (or two) per column still dominates the
            // column periphery (the paper's motivation for 3-bit).
            t_adc: 0.35e-9,
            e_adc: 0.10e-12,
            a_adc: 20e-12,
            e_sa_extra: 15e-15,
            t_sub_dig: 0.10e-9,
            e_sub_dig: 20e-15,
            // Analog comparator + subtractor (Fig 6): current mirrors —
            // slower and more energy than the digital path.
            t_cmp_sub: 0.30e-9,
            e_cmp_sub: 120e-15,
            a_cmp_sub: 18e-12,
            // Ternary MAC in the NM unit is a mux+increment: cheap, fast,
            // fully pipelined behind the read.
            t_nm_mac: 0.08e-9,
            e_nm_mac: 20e-15,
            a_nm_mac_col: 18e-12,
            e_wldrv: 15e-15,
            t_prech: 0.15e-9,
            t_wl: 0.08e-9,
            e_pcu: 40e-15,
            t_pcu: 0.12e-9,
        }
    }
}

impl TechParams {
    pub fn new(tech: Tech) -> TechParams {
        let f_m = 45e-9;
        let n = Fet::nfet_min();
        match tech {
            // 8T-SRAM (Fig 1(a)): cross-coupled inverters + 2 write access
            // + 2-T read port. Read current = storage FET + RAX stack.
            Tech::Sram8T => {
                let i_lrs = stacked_current(&n, &n, 1.0);
                TechParams {
                    tech,
                    vdd: 1.0,
                    f_m,
                    // 8T SRAM ≈ 200 F² (20F x 10F) at 45 nm.
                    cell_w_f: 20.0,
                    cell_h_f: 10.0,
                    i_lrs,
                    i_hrs: n.i_leak(),
                    c_junct_port: n.c_junction(),
                    c_wire_per_f: 0.010e-15,
                    c_wl_gate: n.c_gate(),
                    v_write: 1.0,
                    t_write_cell: 0.15e-9, // latch flip
                    e_write_cell: 4.0e-15, // BL/BLB swing share
                    t_sa_v: 0.12e-9,
                    e_sa_v: 15e-15,
                    t_sa_i: 0.45e-9,
                    e_sa_i: 180e-15,
                }
            }
            // 3T-eDRAM (Fig 1(b)): storage-FET gate cap + PMOS WAX + NMOS
            // RAX. Denser, slightly weaker read (storage gate at VDD−Vt
            // boost assumed per [23]'s preferential boosting).
            Tech::Edram3T => {
                let i_lrs = 0.85 * stacked_current(&n, &n, 1.0);
                TechParams {
                    tech,
                    vdd: 1.0,
                    f_m,
                    // 3T gain cell ≈ 80 F² (10F x 8F).
                    cell_w_f: 10.0,
                    cell_h_f: 8.0,
                    i_lrs,
                    i_hrs: n.i_leak(),
                    c_junct_port: n.c_junction(),
                    c_wire_per_f: 0.010e-15,
                    c_wl_gate: n.c_gate(),
                    v_write: 1.0,
                    t_write_cell: 0.20e-9, // charge C_G through PMOS WAX
                    e_write_cell: 1.5e-15, // small storage cap
                    t_sa_v: 0.12e-9,
                    e_sa_v: 15e-15,
                    t_sa_i: 0.45e-9,
                    e_sa_i: 180e-15,
                }
            }
            // 3T-FEMFET (Fig 1(c)): FEMFET + read/write access NFETs.
            // LRS drive comes from the FE-shifted threshold (V_T ≈ 0).
            Tech::Femfet3T => {
                let mut lrs_cell = Femfet::new();
                lrs_cell.pulse(super::femfet::V_SET, 5e-9);
                lrs_cell.release();
                let lrs_fet = lrs_cell.effective_fet();
                let i_lrs = stacked_current(&lrs_fet, &n, 1.0);
                let mut hrs_cell = Femfet::new();
                hrs_cell.pulse(super::femfet::V_RESET, 5e-9);
                hrs_cell.release();
                let i_hrs = hrs_cell.effective_fet().i_leak();
                TechParams {
                    tech,
                    vdd: 1.0,
                    f_m,
                    // 3T + FE stack ≈ 80 F² (10F x 8F) — the ~3.3× density
                    // win over the TiM-DNN SRAM cell [21].
                    cell_w_f: 10.0,
                    cell_h_f: 8.0,
                    i_lrs,
                    i_hrs,
                    c_junct_port: n.c_junction(),
                    c_wire_per_f: 0.010e-15,
                    c_wl_gate: n.c_gate(),
                    v_write: super::femfet::V_SET,
                    // Polarization switching (τ=200 ps → ~0.5 ns to 90%)
                    // plus the global-reset amortized per cell.
                    t_write_cell: 0.6e-9,
                    // FE displacement charge at ±5 V: Q·V ≈ 2·P_S·A·V.
                    e_write_cell: 6.0e-15,
                    t_sa_v: 0.12e-9,
                    e_sa_v: 15e-15,
                    t_sa_i: 0.45e-9,
                    e_sa_i: 180e-15,
                }
            }
        }
    }

    pub fn all() -> Vec<TechParams> {
        Tech::ALL.iter().map(|&t| TechParams::new(t)).collect()
    }

    /// LRS/HRS read-current ratio (distinguishability).
    pub fn on_off_ratio(&self) -> f64 {
        self.i_lrs / self.i_hrs.max(1e-18)
    }

    /// RBL capacitance for `n_rows` cells each contributing
    /// `ports_per_cell` read-port junctions, with wire length
    /// `n_rows * cell_h_f` (F).
    pub fn c_rbl(&self, n_rows: usize, ports_per_cell: f64, cell_h_f: f64) -> f64 {
        let junction = n_rows as f64 * ports_per_cell * self.c_junct_port;
        let wire = n_rows as f64 * cell_h_f * self.c_wire_per_f;
        junction + wire
    }

    /// Word-line capacitance across `n_cols` ternary cells, each loading
    /// the WL with `gates_per_cell` transistor gates plus wire.
    pub fn c_wl(&self, n_cols: usize, gates_per_cell: f64, cell_w_f: f64) -> f64 {
        let gates = n_cols as f64 * gates_per_cell * self.c_wl_gate;
        let wire = n_cols as f64 * cell_w_f * self.c_wire_per_f;
        gates + wire
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_three_technologies_exist() {
        let all = TechParams::all();
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].tech.name(), "8T-SRAM");
    }

    #[test]
    fn read_currents_are_45nm_class() {
        for p in TechParams::all() {
            assert!(p.i_lrs > 10e-6 && p.i_lrs < 200e-6, "{}: i_lrs={}", p.tech.name(), p.i_lrs);
            assert!(p.on_off_ratio() > 100.0, "{}: ratio={}", p.tech.name(), p.on_off_ratio());
        }
    }

    #[test]
    fn femfet_distinguishability_largest() {
        let sram = TechParams::new(Tech::Sram8T);
        let fem = TechParams::new(Tech::Femfet3T);
        assert!(fem.on_off_ratio() > sram.on_off_ratio());
    }

    #[test]
    fn edram_and_femfet_denser_than_sram() {
        let sram = TechParams::new(Tech::Sram8T);
        for t in [Tech::Edram3T, Tech::Femfet3T] {
            let p = TechParams::new(t);
            assert!(p.cell_w_f * p.cell_h_f < sram.cell_w_f * sram.cell_h_f);
        }
    }

    #[test]
    fn rbl_cap_tens_of_ff_for_256_rows() {
        let p = TechParams::new(Tech::Sram8T);
        let c = p.c_rbl(256, 1.0, p.cell_h_f);
        assert!(c > 10e-15 && c < 100e-15, "c_rbl = {c}");
    }

    #[test]
    fn wl_cap_scales_with_columns() {
        let p = TechParams::new(Tech::Sram8T);
        let c1 = p.c_wl(128, 2.0, 2.0 * p.cell_w_f);
        let c2 = p.c_wl(256, 2.0, 2.0 * p.cell_w_f);
        assert!((c2 / c1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tech_parse_roundtrip() {
        for t in Tech::ALL {
            assert_eq!(Tech::parse(t.name()), Some(t));
        }
        assert_eq!(Tech::parse("sram"), Some(Tech::Sram8T));
        assert_eq!(Tech::parse("bogus"), None);
    }

    #[test]
    fn femfet_write_slower_and_higher_voltage() {
        let s = TechParams::new(Tech::Sram8T);
        let f = TechParams::new(Tech::Femfet3T);
        assert!(f.t_write_cell > s.t_write_cell);
        assert!(f.v_write > s.v_write);
    }
}
