//! The inference service: a thread-based request loop over a pluggable
//! inference backend (PJRT numerics or the functional GEMM engine — see
//! `coordinator::backend`), with dynamic batching, per-request latency
//! tracking, and simulated-accelerator accounting (what the SiTe CiM
//! hardware would spend on the same traffic).
//!
//! Topology: N worker threads share one request channel (work-stealing by
//! contention); each worker pulls batches via the `batcher`, executes,
//! and answers each request on its private response channel. On the
//! engine backend the pull is *continuous batching*: every in-flight
//! request is merged into one contiguous M-plane (M = total live rows,
//! capped by `BatchPolicy::max_batch_rows`, **not** the manifest
//! `batch`), the layer pipeline runs at that M **admitting newly
//! arrived rows at every layer boundary** ([`run_pipelined_flush`] —
//! late rows are caught up through the layers they missed against the
//! resident weights, then ride the merged plane), and the logit rows
//! scatter back to each request's reply channel. The engine
//! backend is loaded **once** and shared by every worker through an
//! `Arc` — one copy of the weights, one resident array pool, one
//! persistent stripe-scheduled executor: server workers *submit* their
//! batches' GEMMs to the shared executor (per-shard work items with
//! load-aware per-slot affinity — a hot array's backlog spills to the
//! shallowest queue instead of serializing behind one worker) instead
//! of each running whole GEMMs on private scoped threads, so concurrent
//! batches pipeline through disjoint arrays explicitly. (PJRT handles
//! are not `Send`, so that backend is still created per-worker,
//! in-thread.)
//!
//! Accounting: engine-backed serving records the *marginal*
//! (weights-resident) simulated cost per inference and reports the
//! programming charge from the engine's measured counters at the end
//! ([`Server::measured_residency`]) — `Residency::Resident/Bounded`'s
//! amortization horizon tied to the inferences actually served.
//!
//! A worker never dies on a bad batch: backend errors (and even panics)
//! are caught, counted in the metrics, and reported to the affected
//! requests; the worker keeps serving.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{
    BackendKind, EngineBackend, InferenceBackend, LayerOutput, LayerPipeline, MultiTenantBackend,
    PjrtBackend, TenantModel,
};
use super::batcher::{
    concat_planes, drain_ready, form_merged_batch, merge_rows, next_batch, stage_admit_budget,
    BatchPolicy,
};
use super::ingress::{Ingress, IngressConfig, Rejection};
use super::metrics::{Metrics, MetricsReport};
use crate::arch::{AccelConfig, Accelerator, Residency};
use crate::array::area::Design;
use crate::device::Tech;
use crate::dnn::{Layer, Network};
use crate::runtime::{Manifest, ModelKind};

/// Tenant key the single-model [`Server`] charges its ingress ledger
/// under (the multi-tenant ledger keys by model name).
pub const DEFAULT_TENANT: &str = "default";

/// One inference request.
pub struct Request {
    pub input: Vec<i8>,
    pub enqueued: Instant,
    pub resp: SyncSender<Result<InferReply, String>>,
}

/// Reply: predicted class + raw logits.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub pred: usize,
    pub logits: Vec<f32>,
    pub wall_latency_s: f64,
}

/// Why `infer_async` refused a request before it ever reached the
/// queue. Carries the full ingress verdict so clients can react to the
/// *kind* of refusal — in particular [`InferError::retry_after_s`]
/// surfaces the rate limiter's already-computed earliest-retry time as
/// a Retry-After-style backoff hint instead of a bare terminal error.
#[derive(Clone, Debug, PartialEq)]
pub enum InferError {
    /// Refused by the ingress admission chain (bad shape, rate limit,
    /// overload shed, unknown model).
    Rejected(Rejection),
    /// The server (or this model's lane) has shut down.
    ShutDown,
}

impl InferError {
    /// Seconds until a retry can succeed, when the refusal is a rate
    /// limit (the token bucket's own refill arithmetic — the same
    /// number its `Display` renders). `None` for every other refusal:
    /// shed/overload clears on load, not on a clock.
    pub fn retry_after_s(&self) -> Option<f64> {
        match self {
            InferError::Rejected(r) => r.retry_after_s(),
            InferError::ShutDown => None,
        }
    }

    /// The ingress verdict behind the refusal, if there is one.
    pub fn rejection(&self) -> Option<&Rejection> {
        match self {
            InferError::Rejected(r) => Some(r),
            InferError::ShutDown => None,
        }
    }
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::Rejected(r) => write!(f, "{r}"),
            InferError::ShutDown => write!(f, "server shut down"),
        }
    }
}

impl std::error::Error for InferError {}

impl From<Rejection> for InferError {
    fn from(r: Rejection) -> InferError {
        InferError::Rejected(r)
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts: PathBuf,
    pub kind: ModelKind,
    /// Which execution backend serves requests.
    pub backend: BackendKind,
    pub n_workers: usize,
    pub policy: BatchPolicy,
    /// Which simulated hardware the accounting reflects (and, for the
    /// engine backend, which functional arrays execute the GEMMs).
    pub sim_tech: Tech,
    pub sim_design: Design,
    /// Tile-worker threads inside each engine-backend GEMM call (the
    /// server already parallelizes across workers/batches).
    pub engine_threads: usize,
    /// Engine-backend pool bound in ternary words (`None` = size the
    /// pool to hold the whole network). Bounding below the working set
    /// serves under second-chance eviction pressure — bit-exact, measured hit
    /// rates in the serve report.
    pub capacity_words: Option<u64>,
    /// Admission policy applied before enqueue (rate limit, load-shed
    /// watermarks; shape validation is always on). Default admits
    /// everything well-formed.
    pub ingress: IngressConfig,
}

impl ServerConfig {
    pub fn new(artifacts: PathBuf) -> ServerConfig {
        ServerConfig {
            artifacts,
            kind: ModelKind::Cim1,
            backend: BackendKind::Pjrt,
            n_workers: 2,
            policy: BatchPolicy::default(),
            sim_tech: Tech::Femfet3T,
            sim_design: Design::Cim1,
            engine_threads: 2,
            capacity_words: None,
            ingress: IngressConfig::default(),
        }
    }

    /// Serve through the functional GEMM engine instead of PJRT.
    pub fn with_engine_backend(mut self) -> ServerConfig {
        self.backend = BackendKind::Engine;
        self
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<Request>>,
    pub metrics: Arc<Metrics>,
    /// Admission gate every `infer_async` passes before enqueue.
    ingress: Arc<Ingress>,
    workers: Vec<JoinHandle<()>>,
    /// The shared engine model (engine backend only; exposes cache stats).
    engine_model: Option<Arc<EngineBackend>>,
    /// The simulated hardware the accounting reflects (write-charge
    /// model for the measured residency report).
    accel: Accelerator,
    /// Marginal per-inference (energy J, latency s) recorded per batch.
    sim_per_inf: (f64, f64),
}

/// Measured residency accounting for one serving run: what the
/// `Residency::Resident { inferences }` model *assumes*, this report
/// *measures* — the amortization horizon is the number of inferences
/// actually served, and the programming charge comes from the engine's
/// own `write_rows` counter (initial placement, capacity-pressure
/// re-programs and streaming-trash refills all included), not from a
/// steady-state bound.
#[derive(Clone, Copy, Debug)]
pub struct MeasuredResidency {
    /// Inferences actually served so far.
    pub inferences: u64,
    /// Weight rows programmed by *traffic* (discovery misses, capacity-
    /// pressure re-programs, streaming-trash refills) — the amortized
    /// share below comes from these.
    pub write_rows: u64,
    /// Total simulated programming energy for those rows (J).
    pub write_energy_j: f64,
    /// Total simulated pool-parallel programming latency (s).
    pub write_latency_s: f64,
    /// Weight rows programmed by placement-plan replay at load or
    /// hot-swap — a one-time charge, reported separately and **not**
    /// amortized into the per-inference numbers.
    pub plan_write_rows: u64,
    /// One-time simulated programming energy for the plan rows (J).
    pub plan_write_energy_j: f64,
    /// One-time simulated programming latency for the plan rows (s).
    pub plan_write_latency_s: f64,
    /// Marginal compute/periphery energy per inference plus the
    /// amortized measured programming share (J).
    pub energy_per_inf_j: f64,
    /// Marginal compute latency per inference plus the amortized
    /// measured programming share (s).
    pub latency_per_inf_s: f64,
    /// The tile cache hit rate behind those write rows.
    pub hit_rate: f64,
}

impl Server {
    /// Start worker threads. Fails fast if the artifacts are unloadable
    /// or describe no usable model.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let manifest = Manifest::load(&cfg.artifacts).context("loading artifacts")?;
        if manifest.dims.len() < 2 {
            bail!(
                "manifest at {} describes no usable model: `dims` must list at least \
                 an input and an output dimension (got {:?})",
                cfg.artifacts.display(),
                manifest.dims
            );
        }
        let in_dim = manifest.dims[0];
        let metrics = Arc::new(Metrics::new());
        let ingress = Arc::new(Ingress::new(in_dim, cfg.ingress));
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));

        // Per-inference simulated cost on the chosen hardware, computed
        // once from the network the artifacts describe. For the engine
        // backend this is the *marginal* (weights-resident) cost — the
        // programming charge is added at report time from the engine's
        // measured counters (`Server::measured_residency`), so the
        // accounting reflects the inferences actually served instead of
        // a steady-state bound. PJRT has no engine counters, so it keeps
        // the analytic capacity-bounded estimate.
        let accel = Accelerator::new(AccelConfig::sitecim(cfg.sim_tech, cfg.sim_design));
        let net = manifest_network(&manifest);
        let (sim_e, sim_t) = match cfg.backend {
            BackendKind::Engine => {
                let marginal =
                    accel.run_with_residency(&net, Residency::Resident { inferences: 0 });
                (marginal.energy, marginal.latency)
            }
            BackendKind::Pjrt => {
                let per_inf = accel.run(&net);
                (per_inf.energy, per_inf.latency)
            }
        };

        // The engine model is loaded once, up front, and shared: one
        // weight copy, one resident array pool for all workers. Loading
        // here (not in the worker) also turns a broken manifest into a
        // start-time error instead of silently dead workers.
        let engine_model = match cfg.backend {
            BackendKind::Engine => Some(Arc::new(
                EngineBackend::load(
                    &manifest,
                    cfg.sim_design,
                    cfg.sim_tech,
                    cfg.engine_threads,
                    cfg.capacity_words,
                )
                .context("loading engine backend")?,
            )),
            BackendKind::Pjrt => None,
        };

        // Composite shed signal: on the engine backend, the ingress
        // watermarks weigh the live executor backlog alongside the
        // in-flight request gauge (`exec_backlog_weight`), so a few
        // giant flushes saturating the executor shed load just like
        // many small queued requests would.
        if let Some(model) = &engine_model {
            let model = Arc::clone(model);
            ingress.set_backlog_source(move || model.exec_queue_depth());
        }

        let mut workers = Vec::new();
        for wid in 0..cfg.n_workers.max(1) {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let ingress = Arc::clone(&ingress);
            let cfg = cfg.clone();
            let shared = engine_model.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sitecim-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(wid, cfg, shared, rx, metrics, ingress, sim_e, sim_t)
                    })
                    .context("spawning worker")?,
            );
        }
        Ok(Server {
            tx: Some(tx),
            metrics,
            ingress,
            workers,
            engine_model,
            accel,
            sim_per_inf: (sim_e, sim_t),
        })
    }

    /// The admission gate (live in-flight gauge, shed latch, and the
    /// per-verdict counters behind [`Server::metrics_report`]).
    pub fn ingress(&self) -> &Arc<Ingress> {
        &self.ingress
    }

    /// Freeze everything scrapeable — serving metrics, admission
    /// ledger, and (on the engine backend) the engine/executor
    /// counters plus the live executor backlog — into one
    /// [`MetricsReport`] (`Display` = JSON).
    pub fn metrics_report(&self) -> MetricsReport {
        let (engine, exec, depth) = match &self.engine_model {
            Some(m) => {
                (Some(m.engine_stats()), Some(m.exec_stats()), Some(m.exec_queue_depth()))
            }
            None => (None, None, None),
        };
        MetricsReport::gather(&self.metrics, &self.ingress, engine, exec, depth)
    }

    /// The shared engine model, when serving through the engine backend.
    pub fn engine_model(&self) -> Option<&Arc<EngineBackend>> {
        self.engine_model.as_ref()
    }

    /// Measured amortized residency costs for the engine backend (`None`
    /// for PJRT): per-inference energy/latency derived from the
    /// inferences actually served and the engine's measured programming
    /// counters. See [`MeasuredResidency`].
    pub fn measured_residency(&self) -> Option<MeasuredResidency> {
        let model = self.engine_model.as_ref()?;
        let s = model.engine_stats();
        let inferences = self.metrics.requests.load(Ordering::Relaxed);
        // Writes serialize over the arrays the serving pool actually
        // has — a capacity-bounded pool can be far narrower than the
        // chip, so the measured charge uses the engine's pool size.
        let (write_latency_s, write_energy_j) =
            self.accel.write_charge(s.write_rows, model.pool_arrays());
        let (plan_write_latency_s, plan_write_energy_j) =
            self.accel.write_charge(s.plan_write_rows, model.pool_arrays());
        let denom = inferences.max(1) as f64;
        Some(MeasuredResidency {
            inferences,
            write_rows: s.write_rows,
            write_energy_j,
            write_latency_s,
            plan_write_rows: s.plan_write_rows,
            plan_write_energy_j,
            plan_write_latency_s,
            energy_per_inf_j: self.sim_per_inf.0 + write_energy_j / denom,
            latency_per_inf_s: self.sim_per_inf.1 + write_latency_s / denom,
            hit_rate: s.hit_rate(),
        })
    }

    /// Submit a request and wait for the reply.
    pub fn infer(&self, input: Vec<i8>) -> Result<InferReply, String> {
        let rx = self.infer_async(input).map_err(|e| e.to_string())?;
        rx.recv().map_err(|e| format!("server dropped request: {e}"))?
    }

    /// Submit a request; returns the reply channel immediately. The
    /// request passes the [`Ingress`] chain first — a
    /// [`Rejection`](super::ingress::Rejection) (bad shape, rate limit,
    /// overload shed) comes back as an immediate typed [`InferError`]
    /// without ever occupying a queue slot; a rate-limited refusal
    /// carries the Retry-After hint ([`InferError::retry_after_s`]).
    pub fn infer_async(
        &self,
        input: Vec<i8>,
    ) -> Result<Receiver<Result<InferReply, String>>, InferError> {
        self.ingress
            .admit(DEFAULT_TENANT, &input)
            .map_err(InferError::Rejected)?;
        let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
        let req = Request { input, enqueued: Instant::now(), resp: rtx };
        self.tx.as_ref().expect("server running").send(req).map_err(|_| {
            self.ingress.request_done(); // balance the admission
            InferError::ShutDown
        })?;
        Ok(rrx)
    }

    /// Graceful shutdown: close the queue, join workers (every queued
    /// request is still answered — the batcher drains the channel before
    /// the workers exit).
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    _wid: usize,
    cfg: ServerConfig,
    shared: Option<Arc<EngineBackend>>,
    rx: Arc<Mutex<Receiver<Request>>>,
    metrics: Arc<Metrics>,
    ingress: Arc<Ingress>,
    sim_e_per_inf: f64,
    sim_t_per_inf: f64,
) {
    // Engine backend: continuous batching through the shared model.
    // PJRT: handles are created in-thread (they are not Send) and the
    // executable's batch dimension is a hard per-call cap.
    match shared {
        Some(model) => {
            engine_worker_loop(model, cfg, rx, metrics, ingress, sim_e_per_inf, sim_t_per_inf)
        }
        None => pjrt_worker_loop(cfg, rx, metrics, ingress, sim_e_per_inf, sim_t_per_inf),
    }
}

/// Run one merged batch through the layer pipeline **with admission at
/// every layer boundary**: before each layer `li ≥ 1`, up to
/// [`stage_admit_budget`] newly queued requests are drained (without
/// blocking — `try_lock`, so an in-flight batch never stalls behind a
/// worker that holds the queue while forming its own batch), caught up
/// through layers `0..li` as a small-M side pipeline against the same
/// resident weights, and concatenated onto the in-flight plane. Rows
/// are independent in M, so the result is bit-exact against serial
/// per-request execution; see `coordinator::batcher`'s module docs for
/// the cost model.
///
/// `items` is updated **in place** and late arrivals join it *before*
/// their catch-up GEMMs run, so on error — or a panic unwinding through
/// this frame — the caller still holds every request this flush
/// absorbed and can answer (and ingress-balance) all of them. On
/// success the returned logits hold `items.len()` rows in item order.
///
/// Public so the conformance battery can drive a flush
/// boundary-by-boundary against a pre-filled queue; servers call it
/// from their worker loops.
pub fn run_pipelined_flush<P: LayerPipeline>(
    pipeline: &P,
    policy: &BatchPolicy,
    rx: &Mutex<Receiver<Request>>,
    metrics: &Metrics,
    items: &mut Vec<Request>,
    mut plane: Arc<[i8]>,
) -> Result<Vec<f32>> {
    let n_layers = pipeline.n_layers();
    let mut m = items.len();
    if m == 0 {
        bail!("a flush needs at least one request");
    }
    if plane.len() != m * pipeline.layer_in_dim(0) {
        bail!(
            "expected {} trits, got {}",
            m * pipeline.layer_in_dim(0),
            plane.len()
        );
    }
    for li in 0..n_layers {
        if li > 0 {
            let budget = stage_admit_budget(policy, li, n_layers, m);
            let late = if budget > 0 {
                match rx.try_lock() {
                    Ok(guard) => drain_ready(&guard, budget),
                    // Another worker is forming a batch on this queue;
                    // its deadline bounds the skipped rows' wait.
                    Err(_) => Vec::new(),
                }
            } else {
                Vec::new()
            };
            if !late.is_empty() {
                let first = items.len();
                let late_n = late.len();
                items.extend(late);
                // Catch the late rows up through the layers they missed
                // (small-M GEMMs on the already-resident weights), then
                // join the in-flight plane for the remaining layers.
                let mut catchup = merge_rows(&items[first..], |r| r.input.as_slice());
                for cli in 0..li {
                    match pipeline.run_layer_arc(cli, catchup, late_n)? {
                        LayerOutput::Hidden(h) => catchup = h,
                        LayerOutput::Logits(_) => {
                            unreachable!("catch-up stages precede the final layer")
                        }
                    }
                }
                plane = concat_planes(&plane, &catchup);
                m += late_n;
                metrics.record_stage_admission(li, late_n);
            }
        }
        match pipeline.run_layer_arc(li, plane, m)? {
            LayerOutput::Hidden(next) => plane = next,
            LayerOutput::Logits(y) => return Ok(y),
        }
    }
    unreachable!("layers is non-empty; the final layer returns Logits")
}

/// The continuous-batching loop: merge every in-flight request into one
/// contiguous M-plane (`form_merged_batch` — one copy), then run the
/// layer pipeline at M = total live rows via [`run_pipelined_flush`],
/// which admits newly arrived rows at every layer boundary (catch-up
/// GEMMs against the resident weights — bit-exact, see the batcher's
/// module docs for the cost model), and scatter the logit rows back to
/// each request's reply channel.
fn engine_worker_loop(
    model: Arc<EngineBackend>,
    cfg: ServerConfig,
    rx: Arc<Mutex<Receiver<Request>>>,
    metrics: Arc<Metrics>,
    ingress: Arc<Ingress>,
    sim_e_per_inf: f64,
    sim_t_per_inf: f64,
) {
    loop {
        // Hold the queue lock only while forming the merged plane.
        let merged = {
            let guard = rx.lock().unwrap();
            form_merged_batch(&guard, &cfg.policy, |r: &Request| r.input.as_slice())
        };
        let Some(merged) = merged else { return }; // channel closed: shutdown

        let mut items = merged.items;
        let plane = Arc::clone(&merged.plane);
        metrics.record_stage_admission(0, merged.rows);
        metrics.pipeline_enter();
        // A panicking backend must not kill the worker: that would
        // strand the in-flight batch and permanently shrink serving
        // capacity. Catch it, answer the batch (including any rows
        // admitted mid-pipeline — `items` is updated in place before
        // any catch-up work) with an error, continue.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_pipelined_flush(model.as_ref(), &cfg.policy, &rx, &metrics, &mut items, plane)
        }));
        metrics.pipeline_exit();
        scatter_replies(
            None,
            items,
            result,
            model.out_dim(),
            &metrics,
            &ingress,
            sim_e_per_inf,
            sim_t_per_inf,
        );
    }
}

/// The fixed-batch PJRT loop: collect up to the executable's batch
/// dimension, flatten, execute, scatter.
fn pjrt_worker_loop(
    cfg: ServerConfig,
    rx: Arc<Mutex<Receiver<Request>>>,
    metrics: Arc<Metrics>,
    ingress: Arc<Ingress>,
    sim_e_per_inf: f64,
    sim_t_per_inf: f64,
) {
    let manifest = match Manifest::load(&cfg.artifacts) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("worker: manifest load failed: {e:#}");
            return;
        }
    };
    let backend: PjrtBackend = match PjrtBackend::load(&manifest, cfg.kind) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("worker: PJRT backend load failed: {e:#}");
            return;
        }
    };

    loop {
        // Hold the queue lock only while assembling the batch.
        let batch = {
            let guard = rx.lock().unwrap();
            let policy = BatchPolicy {
                max_batch: backend.batch().min(cfg.policy.max_batch),
                ..cfg.policy.clone()
            };
            next_batch(&guard, &policy)
        };
        let Some(batch) = batch else { return }; // channel closed: shutdown

        let n = batch.len();
        let mut flat = Vec::with_capacity(n * backend.in_dim());
        for r in &batch {
            flat.extend_from_slice(&r.input);
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend.run_batch(&flat, n)
        }));
        scatter_replies(
            None,
            batch,
            result,
            backend.out_dim(),
            &metrics,
            &ingress,
            sim_e_per_inf,
            sim_t_per_inf,
        );
    }
}

/// Answer every request of an executed batch: on success, carve the
/// logit plane into per-request rows (argmax + latency per request); on
/// backend error or caught panic, report the failure to each request and
/// keep the worker alive. With `tenant` set, every metric charge also
/// lands in that tenant's book (multi-tenant serving). Every reply —
/// success or failure — balances one ingress admission, draining the
/// in-flight gauge the shed watermarks act on.
fn scatter_replies(
    tenant: Option<&str>,
    batch: Vec<Request>,
    result: std::thread::Result<Result<Vec<f32>>>,
    out_dim: usize,
    metrics: &Metrics,
    ingress: &Ingress,
    sim_e_per_inf: f64,
    sim_t_per_inf: f64,
) {
    let n = batch.len();
    match result {
        Ok(Ok(logits)) => {
            let (e, t) = (sim_e_per_inf * n as f64, sim_t_per_inf * n as f64);
            match tenant {
                Some(name) => metrics.record_batch_for(name, n, e, t),
                None => metrics.record_batch(n, e, t),
            }
            for (i, req) in batch.into_iter().enumerate() {
                let row = &logits[i * out_dim..(i + 1) * out_dim];
                let pred = crate::runtime::executor::argmax_rows(row, out_dim)[0];
                let wall = req.enqueued.elapsed().as_secs_f64();
                match tenant {
                    Some(name) => metrics.record_request_for(name, wall),
                    None => metrics.record_request(wall),
                }
                let _ = req.resp.send(Ok(InferReply {
                    pred,
                    logits: row.to_vec(),
                    wall_latency_s: wall,
                }));
            }
        }
        Ok(Err(e)) => {
            match tenant {
                Some(name) => metrics.record_error_for(name),
                None => metrics.record_error(),
            }
            let msg = format!("inference failed: {e:#}");
            for req in batch {
                let _ = req.resp.send(Err(msg.clone()));
            }
        }
        Err(_) => {
            match tenant {
                Some(name) => metrics.record_error_for(name),
                None => metrics.record_error(),
            }
            let msg = "inference worker caught a backend panic".to_string();
            for req in batch {
                let _ = req.resp.send(Err(msg.clone()));
            }
        }
    }
    ingress.requests_done(n as u64);
}

/// Configuration for a [`MultiServer`]: N models on one engine pool.
#[derive(Clone, Debug)]
pub struct MultiServerConfig {
    /// (model name, artifact dir) pairs, loaded in order.
    pub models: Vec<(String, PathBuf)>,
    /// Hard per-tenant pool reservations in ternary words, by model
    /// name. Models without an entry share the best-effort partition
    /// under second-chance eviction.
    pub reserves: BTreeMap<String, u64>,
    /// Total engine pool bound in ternary words (reservations are
    /// carved out of this).
    pub capacity_words: u64,
    /// Worker threads per model lane.
    pub n_workers: usize,
    pub policy: BatchPolicy,
    pub sim_tech: Tech,
    pub sim_design: Design,
    /// Tile-worker threads inside the shared engine.
    pub engine_threads: usize,
    /// Admission policy shared by every lane: per-model token buckets,
    /// one pool-wide in-flight gauge for the shed watermarks.
    pub ingress: IngressConfig,
}

impl MultiServerConfig {
    pub fn new(models: Vec<(String, PathBuf)>, capacity_words: u64) -> MultiServerConfig {
        MultiServerConfig {
            models,
            reserves: BTreeMap::new(),
            capacity_words,
            n_workers: 1,
            policy: BatchPolicy::default(),
            sim_tech: Tech::Femfet3T,
            sim_design: Design::Cim1,
            engine_threads: 2,
            ingress: IngressConfig::default(),
        }
    }
}

/// One model's serving lane: a private request channel (so continuous
/// batching only ever merges rows of the *same* model — rows from
/// different tenants never share an M-plane), its workers, and the
/// published current version.
struct Lane {
    tx: Option<Sender<Request>>,
    workers: Vec<JoinHandle<()>>,
    in_dim: usize,
    /// The version new flushes pick up. A flush captures one `Arc` for
    /// its whole pipeline, so a hot-swap mid-flight never mixes
    /// versions inside a pipeline.
    current: Arc<RwLock<Arc<TenantModel>>>,
    /// Marginal simulated (energy J, latency s) per inference for this
    /// model's network.
    sim_per_inf: (f64, f64),
}

/// A multi-model inference service over one shared
/// [`MultiTenantBackend`]: per-model request lanes route by model name
/// through the same continuous batcher as the single-model [`Server`],
/// per-tenant metrics books sum to the global counters, and
/// [`MultiServer::hot_swap`] replaces a model's artifact version without
/// dropping in-flight requests.
pub struct MultiServer {
    backend: Arc<MultiTenantBackend>,
    pub metrics: Arc<Metrics>,
    /// One admission gate for all lanes: per-model buckets and ledgers,
    /// a pool-wide in-flight gauge for the shed watermarks.
    ingress: Arc<Ingress>,
    lanes: BTreeMap<String, Lane>,
    accel: Accelerator,
}

impl MultiServer {
    /// Load every configured model and start its serving lane. Fails
    /// fast on unloadable artifacts, duplicate names, or a reservation
    /// that does not fit the pool.
    pub fn start(cfg: MultiServerConfig) -> Result<MultiServer> {
        if cfg.models.is_empty() {
            bail!("no models configured (need at least one name=dir pair)");
        }
        let backend = Arc::new(MultiTenantBackend::new(
            cfg.sim_design,
            cfg.sim_tech,
            cfg.engine_threads,
            cfg.capacity_words,
        ));
        let metrics = Arc::new(Metrics::new());
        // Lanes have different input dimensions, so the shared gate
        // validates with the per-lane dimension at admit time
        // (`admit_shaped`); the constructor dimension is unused here.
        let ingress = Arc::new(Ingress::new(0, cfg.ingress));
        let accel = Accelerator::new(AccelConfig::sitecim(cfg.sim_tech, cfg.sim_design));
        // Composite shed signal over the one shared engine: every
        // lane's flushes land in the same executor, so its backlog is
        // the pool-wide pressure term for the shared watermarks.
        {
            let engine = Arc::clone(backend.engine());
            ingress.set_backlog_source(move || engine.exec_queue_depth());
        }
        let mut lanes = BTreeMap::new();
        for (name, dir) in &cfg.models {
            if lanes.contains_key(name) {
                bail!("model name {name:?} is configured twice");
            }
            let manifest = Manifest::load(dir)
                .with_context(|| format!("loading artifacts for model {name:?}"))?;
            let reserve = cfg.reserves.get(name).copied();
            let model = backend.add_model(name, &manifest, reserve)?;
            let marginal = accel.run_with_residency(
                &manifest_network(&manifest),
                Residency::Resident { inferences: 0 },
            );
            let sim_per_inf = (marginal.energy, marginal.latency);
            let in_dim = model.in_dim();
            let current = Arc::new(RwLock::new(model));
            let (tx, rx) = channel::<Request>();
            let rx = Arc::new(Mutex::new(rx));
            let mut workers = Vec::new();
            for wid in 0..cfg.n_workers.max(1) {
                let (name, current, rx, metrics, ingress, policy) = (
                    name.clone(),
                    Arc::clone(&current),
                    Arc::clone(&rx),
                    Arc::clone(&metrics),
                    Arc::clone(&ingress),
                    cfg.policy.clone(),
                );
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("sitecim-{name}-{wid}"))
                        .spawn(move || {
                            tenant_worker_loop(
                                &name,
                                current,
                                policy,
                                rx,
                                metrics,
                                ingress,
                                sim_per_inf.0,
                                sim_per_inf.1,
                            )
                        })
                        .context("spawning tenant worker")?,
                );
            }
            lanes.insert(
                name.clone(),
                Lane { tx: Some(tx), workers, in_dim, current, sim_per_inf },
            );
        }
        Ok(MultiServer { backend, metrics, ingress, lanes, accel })
    }

    /// The shared admission gate (per-model ledgers, pool-wide gauge).
    pub fn ingress(&self) -> &Arc<Ingress> {
        &self.ingress
    }

    /// Freeze the whole multi-tenant picture — global + per-model
    /// serving metrics, the admission ledger, and the shared engine /
    /// executor counters — into one [`MetricsReport`] (`Display` =
    /// JSON). Per-tenant rows sum to the global columns.
    pub fn metrics_report(&self) -> MetricsReport {
        let engine = self.backend.engine();
        MetricsReport::gather(
            &self.metrics,
            &self.ingress,
            Some(engine.stats()),
            Some(engine.exec_stats()),
            Some(engine.exec_queue_depth()),
        )
    }

    pub fn backend(&self) -> &Arc<MultiTenantBackend> {
        &self.backend
    }

    /// Loaded model names, sorted.
    pub fn model_names(&self) -> Vec<String> {
        self.lanes.keys().cloned().collect()
    }

    /// The currently published version of `model`.
    pub fn model_generation(&self, model: &str) -> Option<u64> {
        self.backend.model(model).map(|m| m.generation())
    }

    /// Submit a request to `model`; returns the reply channel
    /// immediately. The request passes the shared [`Ingress`] chain
    /// first: an unknown model name, a plane not matching the lane's
    /// manifest, an empty token bucket, or a shedding pool all come back
    /// as an immediate `Err` without ever occupying a queue slot.
    pub fn infer_async(
        &self,
        model: &str,
        input: Vec<i8>,
    ) -> Result<Receiver<Result<InferReply, String>>, InferError> {
        let Some(lane) = self.lanes.get(model) else {
            return Err(InferError::Rejected(self.ingress.reject_unknown_model(model)));
        };
        self.ingress
            .admit_shaped(model, lane.in_dim, &input)
            .map_err(InferError::Rejected)?;
        let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
        let req = Request { input, enqueued: Instant::now(), resp: rtx };
        lane.tx.as_ref().expect("lane running").send(req).map_err(|_| {
            self.ingress.request_done(); // balance the admission
            InferError::ShutDown
        })?;
        Ok(rrx)
    }

    /// Submit a request to `model` and wait for the reply.
    pub fn infer(&self, model: &str, input: Vec<i8>) -> Result<InferReply, String> {
        let rx = self.infer_async(model, input).map_err(|e| e.to_string())?;
        rx.recv().map_err(|e| format!("server dropped request: {e}"))?
    }

    /// Replace `model`'s artifacts with the version at `artifacts`,
    /// without a serving gap: the new version registers and programs
    /// into the partition's headroom while the old one keeps serving,
    /// the lane atomically switches to the new version (flushes capture
    /// one version for their whole pipeline, so no reply ever mixes
    /// versions), and the old version's regions are freed once every
    /// in-flight flush holding it has drained. Returns the new
    /// generation number.
    pub fn hot_swap(&self, model: &str, artifacts: &Path) -> Result<u64> {
        let lane = self.lanes.get(model).with_context(|| format!("unknown model {model:?}"))?;
        let manifest = Manifest::load(artifacts)
            .with_context(|| format!("loading swap artifacts for model {model:?}"))?;
        if manifest.dims.first() != Some(&lane.in_dim) {
            bail!(
                "swap artifacts for model {model:?} change the input dimension ({:?} != {}) — \
                 in-flight clients would break",
                manifest.dims.first(),
                lane.in_dim
            );
        }
        let (new, old) = self.backend.swap_model(model, &manifest)?;
        // Publish: flushes formed after this line run the new version.
        *lane.current.write().unwrap_or_else(std::sync::PoisonError::into_inner) =
            Arc::clone(&new);
        // Drain: wait until no in-flight flush still holds the old
        // version (we hold the only other strong reference), then free
        // its regions. Requests queued before the swap are answered by
        // whichever version their flush captured — never a mix.
        while Arc::strong_count(&old) > 1 {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        self.backend.retire(&old);
        Ok(new.generation())
    }

    /// Per-tenant measured residency (see [`MeasuredResidency`]): the
    /// model's own engine book over the inferences its lane served.
    /// Write charges serialize over the arrays the model's partition
    /// actually owns.
    pub fn measured_residency(&self, model: &str) -> Option<MeasuredResidency> {
        let lane = self.lanes.get(model)?;
        let tm = self.backend.model(model)?;
        let s = tm.tenant_stats();
        let book = self.metrics.tenant_book(model);
        let inferences = book.requests.load(Ordering::Relaxed);
        let arrays = self.backend.engine().tenant_slots(tm.partition()).max(1);
        let (write_latency_s, write_energy_j) = self.accel.write_charge(s.write_rows, arrays);
        let (plan_write_latency_s, plan_write_energy_j) =
            self.accel.write_charge(s.plan_write_rows, arrays);
        let denom = inferences.max(1) as f64;
        Some(MeasuredResidency {
            inferences,
            write_rows: s.write_rows,
            write_energy_j,
            write_latency_s,
            plan_write_rows: s.plan_write_rows,
            plan_write_energy_j,
            plan_write_latency_s,
            energy_per_inf_j: lane.sim_per_inf.0 + write_energy_j / denom,
            latency_per_inf_s: lane.sim_per_inf.1 + write_latency_s / denom,
            hit_rate: s.hit_rate(),
        })
    }

    /// Graceful shutdown: close every lane, join every worker (queued
    /// requests are still answered).
    pub fn shutdown(mut self) {
        for lane in self.lanes.values_mut() {
            drop(lane.tx.take());
        }
        for lane in self.lanes.values_mut() {
            for w in lane.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

/// One model lane's continuous-batching loop: identical to
/// [`engine_worker_loop`] except the model is re-read from the lane's
/// published slot at every flush (hot-swap) and metrics charge the
/// tenant's book. Boundary admission only ever drains this lane's own
/// queue, so late rows always belong to the same model — and the same
/// captured version — as the plane they join.
fn tenant_worker_loop(
    name: &str,
    current: Arc<RwLock<Arc<TenantModel>>>,
    policy: BatchPolicy,
    rx: Arc<Mutex<Receiver<Request>>>,
    metrics: Arc<Metrics>,
    ingress: Arc<Ingress>,
    sim_e_per_inf: f64,
    sim_t_per_inf: f64,
) {
    loop {
        let merged = {
            let guard = rx.lock().unwrap();
            form_merged_batch(&guard, &policy, |r: &Request| r.input.as_slice())
        };
        let Some(merged) = merged else { return }; // lane closed: shutdown

        // One version per flush: the whole pipeline (and its replies,
        // including rows admitted at layer boundaries) runs on this Arc
        // even if a hot-swap publishes a new version mid-flight.
        let model =
            Arc::clone(&current.read().unwrap_or_else(std::sync::PoisonError::into_inner));
        let mut items = merged.items;
        let plane = Arc::clone(&merged.plane);
        metrics.record_stage_admission(0, merged.rows);
        metrics.pipeline_enter();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_pipelined_flush(model.as_ref(), &policy, &rx, &metrics, &mut items, plane)
        }));
        metrics.pipeline_exit();
        scatter_replies(
            Some(name),
            items,
            result,
            model.out_dim(),
            &metrics,
            &ingress,
            sim_e_per_inf,
            sim_t_per_inf,
        );
    }
}

/// The network the artifacts' MLP corresponds to (for simulated costs).
pub fn manifest_network(m: &Manifest) -> Network {
    let mut layers = Vec::new();
    for i in 0..m.dims.len().saturating_sub(1) {
        layers.push(Layer::linear(&format!("fc{i}"), 1, m.dims[i], m.dims[i + 1]));
    }
    Network { name: "artifact-mlp".into(), layers }
}
