//! The inference service: a thread-based request loop over a pluggable
//! inference backend (PJRT numerics or the functional GEMM engine — see
//! `coordinator::backend`), with dynamic batching, per-request latency
//! tracking, and simulated-accelerator accounting (what the SiTe CiM
//! hardware would spend on the same traffic).
//!
//! Topology: N worker threads share one request channel (work-stealing by
//! contention); each worker owns its own backend instance (PJRT handles
//! are created in-thread, so no Send bounds are needed), pulls batches
//! via the `batcher`, executes, and answers each request on its private
//! response channel.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::backend::{BackendKind, EngineBackend, InferenceBackend, PjrtBackend};
use super::batcher::{next_batch, BatchPolicy};
use super::metrics::Metrics;
use crate::arch::{AccelConfig, Accelerator};
use crate::array::area::Design;
use crate::device::Tech;
use crate::dnn::{Layer, Network};
use crate::runtime::{Manifest, ModelKind};

/// One inference request.
pub struct Request {
    pub input: Vec<i8>,
    pub enqueued: Instant,
    pub resp: SyncSender<Result<InferReply, String>>,
}

/// Reply: predicted class + raw logits.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub pred: usize,
    pub logits: Vec<f32>,
    pub wall_latency_s: f64,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts: PathBuf,
    pub kind: ModelKind,
    /// Which execution backend serves requests.
    pub backend: BackendKind,
    pub n_workers: usize,
    pub policy: BatchPolicy,
    /// Which simulated hardware the accounting reflects (and, for the
    /// engine backend, which functional arrays execute the GEMMs).
    pub sim_tech: Tech,
    pub sim_design: Design,
}

impl ServerConfig {
    pub fn new(artifacts: PathBuf) -> ServerConfig {
        ServerConfig {
            artifacts,
            kind: ModelKind::Cim1,
            backend: BackendKind::Pjrt,
            n_workers: 2,
            policy: BatchPolicy::default(),
            sim_tech: Tech::Femfet3T,
            sim_design: Design::Cim1,
        }
    }

    /// Serve through the functional GEMM engine instead of PJRT.
    pub fn with_engine_backend(mut self) -> ServerConfig {
        self.backend = BackendKind::Engine;
        self
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<Request>>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    in_dim: usize,
}

impl Server {
    /// Start worker threads. Fails fast if the artifacts are unloadable.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let manifest = Manifest::load(&cfg.artifacts).context("loading artifacts")?;
        let in_dim = *manifest.dims.first().unwrap();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));

        // Per-inference simulated cost on the chosen hardware, computed
        // once from the network the artifacts describe.
        let accel = Accelerator::new(AccelConfig::sitecim(cfg.sim_tech, cfg.sim_design));
        let net = manifest_network(&manifest);
        let per_inf = accel.run(&net);
        let (sim_e, sim_t) = (per_inf.energy, per_inf.latency);

        let mut workers = Vec::new();
        for wid in 0..cfg.n_workers.max(1) {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            let dir = cfg.artifacts.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sitecim-worker-{wid}"))
                    .spawn(move || worker_loop(wid, dir, cfg, rx, metrics, sim_e, sim_t))
                    .context("spawning worker")?,
            );
        }
        Ok(Server { tx: Some(tx), metrics, workers, in_dim })
    }

    /// Submit a request and wait for the reply.
    pub fn infer(&self, input: Vec<i8>) -> Result<InferReply, String> {
        let rx = self.infer_async(input)?;
        rx.recv().map_err(|e| format!("server dropped request: {e}"))?
    }

    /// Submit a request; returns the reply channel immediately.
    pub fn infer_async(
        &self,
        input: Vec<i8>,
    ) -> Result<Receiver<Result<InferReply, String>>, String> {
        if input.len() != self.in_dim {
            return Err(format!("input len {} != {}", input.len(), self.in_dim));
        }
        let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
        let req = Request { input, enqueued: Instant::now(), resp: rtx };
        self.tx
            .as_ref()
            .expect("server running")
            .send(req)
            .map_err(|_| "server shut down".to_string())?;
        Ok(rrx)
    }

    /// Graceful shutdown: close the queue, join workers.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    _wid: usize,
    dir: PathBuf,
    cfg: ServerConfig,
    rx: Arc<Mutex<Receiver<Request>>>,
    metrics: Arc<Metrics>,
    sim_e_per_inf: f64,
    sim_t_per_inf: f64,
) {
    // Backend handles (PJRT client / engine pool) are created in-thread.
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("worker: manifest load failed: {e:#}");
            return;
        }
    };
    let backend: Box<dyn InferenceBackend> = match cfg.backend {
        BackendKind::Pjrt => match PjrtBackend::load(&manifest, cfg.kind) {
            Ok(b) => Box::new(b),
            Err(e) => {
                eprintln!("worker: PJRT backend load failed: {e:#}");
                return;
            }
        },
        // One engine thread per worker: the server already parallelizes
        // across workers.
        BackendKind::Engine => match EngineBackend::load(&manifest, cfg.sim_design, cfg.sim_tech, 1) {
            Ok(b) => Box::new(b),
            Err(e) => {
                eprintln!("worker: engine backend load failed: {e:#}");
                return;
            }
        },
    };

    loop {
        // Hold the queue lock only while assembling the batch.
        let batch = {
            let guard = rx.lock().unwrap();
            let policy =
                BatchPolicy { max_batch: backend.batch().min(cfg.policy.max_batch), ..cfg.policy.clone() };
            next_batch(&guard, &policy)
        };
        let Some(batch) = batch else { return }; // channel closed: shutdown

        let n = batch.len();
        let mut flat = Vec::with_capacity(n * backend.in_dim());
        for r in &batch {
            flat.extend_from_slice(&r.input);
        }
        match backend.run_batch(&flat, n) {
            Ok(logits) => {
                metrics.record_batch(n, sim_e_per_inf * n as f64, sim_t_per_inf * n as f64);
                let out_dim = backend.out_dim();
                for (i, req) in batch.into_iter().enumerate() {
                    let row = &logits[i * out_dim..(i + 1) * out_dim];
                    let pred = crate::runtime::executor::argmax_rows(row, out_dim)[0];
                    let wall = req.enqueued.elapsed().as_secs_f64();
                    metrics.record_request(wall);
                    let _ = req.resp.send(Ok(InferReply {
                        pred,
                        logits: row.to_vec(),
                        wall_latency_s: wall,
                    }));
                }
            }
            Err(e) => {
                metrics.record_error();
                let msg = format!("inference failed: {e:#}");
                for req in batch {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// The network the artifacts' MLP corresponds to (for simulated costs).
pub fn manifest_network(m: &Manifest) -> Network {
    let mut layers = Vec::new();
    for i in 0..m.dims.len() - 1 {
        layers.push(Layer::linear(&format!("fc{i}"), 1, m.dims[i], m.dims[i + 1]));
    }
    Network { name: "artifact-mlp".into(), layers }
}
