//! Service metrics: request/batch counters, wall-clock latency
//! distribution, and the simulated-hardware accounting (what the SiTe
//! CiM accelerator would have spent on the same work).
//!
//! Multi-tenant serving additionally keeps one [`TenantBook`] per model
//! name: the `*_for` recording methods charge both the global counters
//! and exactly one book, so across all tenants the books sum to the
//! global counters by construction.
//!
//! For scraping, [`MetricsReport::gather`] freezes the whole picture —
//! these counters, the ingress admission counters, and the engine /
//! executor snapshots — into one serializable [`MetricsReport`] whose
//! `Display` is its JSON rendering (`sitecim metrics snapshot`).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::ingress::{Ingress, IngressSnapshot};
use crate::engine::{EngineStatsSnapshot, ExecStatsSnapshot};
use crate::util::json::Json;
use crate::util::stats::{summarize, Summary};

/// Retained latency samples (most recent N; see [`LatencyRing`]).
const LATENCY_WINDOW: usize = 100_000;

/// Fixed-size ring of the most recent latency samples. A plain `Vec`
/// that gets cleared at capacity would make every p95/p99 summary right
/// after the reset reflect only a handful of samples; the ring always
/// holds the last `cap` observations.
#[derive(Debug)]
struct LatencyRing {
    buf: Vec<f64>,
    cap: usize,
    /// Next slot to overwrite once the ring is full.
    head: usize,
}

impl LatencyRing {
    fn new(cap: usize) -> LatencyRing {
        LatencyRing { buf: Vec::new(), cap: cap.max(1), head: 0 }
    }

    fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// The retained samples (order is irrelevant to the summaries).
    fn samples(&self) -> &[f64] {
        &self.buf
    }
}

#[derive(Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub errors: AtomicU64,
    /// Wall-clock end-to-end request latencies (seconds), rolling window.
    latencies: Mutex<LatencyRing>,
    /// Rows per executed flush (merged-batch size), rolling window — the
    /// continuous batcher's effectiveness histogram (p50/p95 rows).
    batch_rows: Mutex<LatencyRing>,
    /// Simulated accelerator energy (femtojoule-granularity, stored as
    /// integer attojoules to stay atomic) and busy time (picoseconds).
    sim_energy_aj: AtomicU64,
    sim_time_ps: AtomicU64,
    /// Per-tenant books by model name (multi-tenant serving only; empty
    /// unless the `*_for` methods are used).
    tenants: RwLock<BTreeMap<String, Arc<TenantBook>>>,
    /// Per-layer-boundary admission histogram: `(admissions, rows)`
    /// charged at stage boundary `li`, grown on first use. Stage 0 is
    /// the initial merged former; stages ≥ 1 are mid-pipeline admission
    /// points (the layer-pipelined path).
    stage_admits: Mutex<Vec<(u64, u64)>>,
    /// Pipeline occupancy gauge: merged flushes currently mid-pipeline
    /// (between enter and exit of the layer loop) across all workers.
    pipeline_active: AtomicU64,
    /// Latency-window capacity handed to newly created tenant books.
    window: usize,
}

/// One row of the per-stage admission histogram: how many times the
/// admission point at layer boundary `stage` admitted rows, and how many
/// rows in total. Stage 0 counts initial flush formation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageAdmits {
    pub stage: usize,
    pub admissions: u64,
    pub rows: u64,
}

/// One tenant's slice of the serving counters: requests, errors,
/// flushes, and rolling latency / rows-per-flush windows. Charged only
/// through [`Metrics::record_request_for`] /
/// [`Metrics::record_batch_for`] / [`Metrics::record_error_for`], which
/// also charge the global counters — books sum to the globals.
#[derive(Debug)]
pub struct TenantBook {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub errors: AtomicU64,
    latencies: Mutex<LatencyRing>,
    batch_rows: Mutex<LatencyRing>,
}

impl TenantBook {
    fn new(window: usize) -> TenantBook {
        TenantBook {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            latencies: Mutex::new(LatencyRing::new(window)),
            batch_rows: Mutex::new(LatencyRing::new(window)),
        }
    }

    pub fn latency_summary(&self) -> Summary {
        summarize(self.latencies.lock().unwrap().samples())
    }

    /// Rows per executed flush for this tenant (rolling window).
    pub fn batch_rows_summary(&self) -> Summary {
        summarize(self.batch_rows.lock().unwrap().samples())
    }

    pub fn avg_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::with_window(LATENCY_WINDOW)
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// A metrics sink retaining the last `window` latency samples
    /// (tests use small windows to exercise the rollover).
    pub fn with_window(window: usize) -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            latencies: Mutex::new(LatencyRing::new(window)),
            batch_rows: Mutex::new(LatencyRing::new(window)),
            sim_energy_aj: AtomicU64::new(0),
            sim_time_ps: AtomicU64::new(0),
            tenants: RwLock::new(BTreeMap::new()),
            stage_admits: Mutex::new(Vec::new()),
            pipeline_active: AtomicU64::new(0),
            window,
        }
    }

    /// Charge `rows` admitted rows to stage boundary `stage`'s
    /// histogram bucket (0 = initial flush formation, ≥ 1 = mid-pipeline
    /// admission points).
    pub fn record_stage_admission(&self, stage: usize, rows: usize) {
        let mut book = self.stage_admits.lock().unwrap();
        if book.len() <= stage {
            book.resize(stage + 1, (0, 0));
        }
        book[stage].0 += 1;
        book[stage].1 += rows as u64;
    }

    /// The per-stage admission histogram, one entry per stage boundary
    /// charged so far (empty before any flush).
    pub fn stage_admit_histogram(&self) -> Vec<StageAdmits> {
        self.stage_admits
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(stage, &(admissions, rows))| StageAdmits { stage, admissions, rows })
            .collect()
    }

    /// A merged flush entered its layer loop (pipeline occupancy +1).
    pub fn pipeline_enter(&self) {
        self.pipeline_active.fetch_add(1, Ordering::Relaxed);
    }

    /// A merged flush left its layer loop (pipeline occupancy −1;
    /// saturating, so an unbalanced exit can never wrap the gauge).
    pub fn pipeline_exit(&self) {
        let _ = self.pipeline_active.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(1))
        });
    }

    /// Merged flushes currently mid-pipeline across all workers.
    pub fn pipeline_active(&self) -> u64 {
        self.pipeline_active.load(Ordering::Relaxed)
    }

    /// The named tenant's book, created on first use (window matches the
    /// global latency window).
    pub fn tenant_book(&self, name: &str) -> Arc<TenantBook> {
        if let Some(b) = self.tenants.read().unwrap().get(name) {
            return Arc::clone(b);
        }
        let mut map = self.tenants.write().unwrap();
        let book = map
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(TenantBook::new(self.window)));
        Arc::clone(book)
    }

    /// Names with a tenant book, sorted.
    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.read().unwrap().keys().cloned().collect()
    }

    /// [`Self::record_request`] charged to both the globals and
    /// `name`'s book.
    pub fn record_request_for(&self, name: &str, latency_s: f64) {
        self.record_request(latency_s);
        let book = self.tenant_book(name);
        book.requests.fetch_add(1, Ordering::Relaxed);
        book.latencies.lock().unwrap().push(latency_s);
    }

    /// [`Self::record_batch`] charged to both the globals and `name`'s
    /// book.
    pub fn record_batch_for(&self, name: &str, n: usize, sim_energy_j: f64, sim_time_s: f64) {
        self.record_batch(n, sim_energy_j, sim_time_s);
        let book = self.tenant_book(name);
        book.batches.fetch_add(1, Ordering::Relaxed);
        book.batched_items.fetch_add(n as u64, Ordering::Relaxed);
        book.batch_rows.lock().unwrap().push(n as f64);
    }

    /// [`Self::record_error`] charged to both the globals and `name`'s
    /// book.
    pub fn record_error_for(&self, name: &str) {
        self.record_error();
        self.tenant_book(name).errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_request(&self, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap().push(latency_s);
    }

    pub fn record_batch(&self, n: usize, sim_energy_j: f64, sim_time_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(n as u64, Ordering::Relaxed);
        self.batch_rows.lock().unwrap().push(n as f64);
        self.sim_energy_aj
            .fetch_add((sim_energy_j * 1e18) as u64, Ordering::Relaxed);
        self.sim_time_ps.fetch_add((sim_time_s * 1e12) as u64, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn latency_summary(&self) -> Summary {
        summarize(self.latencies.lock().unwrap().samples())
    }

    /// Distribution of rows per executed flush (rolling window): the
    /// continuous batcher's batch-size histogram (p50/p95 in particular).
    pub fn batch_rows_summary(&self) -> Summary {
        summarize(self.batch_rows.lock().unwrap().samples())
    }

    pub fn avg_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn sim_energy_j(&self) -> f64 {
        self.sim_energy_aj.load(Ordering::Relaxed) as f64 * 1e-18
    }

    pub fn sim_time_s(&self) -> f64 {
        self.sim_time_ps.load(Ordering::Relaxed) as f64 * 1e-12
    }

    pub fn report(&self) -> String {
        let s = self.latency_summary();
        let rows = self.batch_rows_summary();
        format!(
            "requests={} batches={} avg_batch={:.1} rows/flush p50={:.0} p95={:.0} errors={} | wall p50={} p99={} | simulated: {} busy, {} ({}/inf)",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.avg_batch_size(),
            rows.p50,
            rows.p95,
            self.errors.load(Ordering::Relaxed),
            crate::util::units::fmt_time(s.p50),
            crate::util::units::fmt_time(s.p99),
            crate::util::units::fmt_time(self.sim_time_s()),
            crate::util::units::fmt_energy(self.sim_energy_j()),
            crate::util::units::fmt_energy(
                self.sim_energy_j() / self.requests.load(Ordering::Relaxed).max(1) as f64
            ),
        )
    }
}

/// One tenant's slice of a [`MetricsReport`]: the tenant book's
/// counters and windows plus the tenant's ingress verdicts. Tenants
/// appear if they have either a metrics book or an ingress entry; both
/// sum to the report's global columns.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantReport {
    pub name: String,
    pub requests: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub errors: u64,
    pub avg_batch_rows: f64,
    /// End-to-end wall-clock latency (seconds), rolling window.
    pub latency_s: Summary,
    /// Rows per executed flush, rolling window.
    pub rows_per_flush: Summary,
    pub ingress: IngressSnapshot,
}

/// Point-in-time serialization of everything an operator scrapes: the
/// serving counters and rolling windows, the ingress admission ledger,
/// the live in-flight gauge and shed latch, and (on the engine backend)
/// the engine / executor snapshots. Produced by
/// [`MetricsReport::gather`] (the servers wrap it as
/// `Server::metrics_report`); `Display` renders the JSON from
/// [`MetricsReport::to_json`].
///
/// ```
/// use sitecim::coordinator::ingress::{Ingress, IngressConfig};
/// use sitecim::coordinator::metrics::{Metrics, MetricsReport};
///
/// let metrics = Metrics::new();
/// let ingress = Ingress::new(2, IngressConfig::default());
/// ingress.admit("default", &[1, -1]).unwrap();
/// metrics.record_request_for("default", 1.5e-3);
/// let report = MetricsReport::gather(&metrics, &ingress, None, None, None);
/// assert_eq!((report.requests, report.ingress.admitted), (1, 1));
/// assert_eq!(report.tenants[0].name, "default");
/// let json = report.to_json().to_string();
/// assert!(json.contains("\"admitted\""));
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsReport {
    pub requests: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub errors: u64,
    pub avg_batch_rows: f64,
    /// End-to-end wall-clock latency (seconds), rolling window.
    pub latency_s: Summary,
    /// Rows per executed flush, rolling window.
    pub rows_per_flush: Summary,
    /// Simulated accelerator spend for the served work.
    pub sim_energy_j: f64,
    pub sim_time_s: f64,
    /// Global admission ledger (per-tenant slices sum to this).
    pub ingress: IngressSnapshot,
    /// Admitted-but-unanswered requests at snapshot time.
    pub inflight: u64,
    /// Whether the shed latch was set at snapshot time.
    pub shedding: bool,
    /// Engine counters (`None` on the PJRT backend).
    pub engine: Option<EngineStatsSnapshot>,
    /// Executor counters (`None` on the PJRT backend).
    pub exec: Option<ExecStatsSnapshot>,
    /// Live executor backlog at snapshot time (`None` on PJRT).
    pub exec_queue_depth: Option<u64>,
    /// Per-layer-boundary admission histogram (empty before any flush;
    /// stage 0 = initial formation, ≥ 1 = mid-pipeline admissions).
    pub stage_admits: Vec<StageAdmits>,
    /// Merged flushes mid-pipeline at snapshot time.
    pub pipeline_active: u64,
    pub tenants: Vec<TenantReport>,
}

impl MetricsReport {
    /// Freeze `metrics` + `ingress` (and, on the engine backend, the
    /// engine/executor snapshots) into one report. Tenant rows cover the
    /// union of metrics books and ingress ledgers.
    pub fn gather(
        metrics: &Metrics,
        ingress: &Ingress,
        engine: Option<EngineStatsSnapshot>,
        exec: Option<ExecStatsSnapshot>,
        exec_queue_depth: Option<u64>,
    ) -> MetricsReport {
        let mut names = metrics.tenant_names();
        for n in ingress.tenant_names() {
            if !names.contains(&n) {
                names.push(n);
            }
        }
        names.sort();
        let tenants = names
            .into_iter()
            .map(|name| {
                let book = metrics.tenant_book(&name);
                TenantReport {
                    requests: book.requests.load(Ordering::Relaxed),
                    batches: book.batches.load(Ordering::Relaxed),
                    batched_items: book.batched_items.load(Ordering::Relaxed),
                    errors: book.errors.load(Ordering::Relaxed),
                    avg_batch_rows: book.avg_batch_size(),
                    latency_s: book.latency_summary(),
                    rows_per_flush: book.batch_rows_summary(),
                    ingress: ingress.tenant_snapshot(&name),
                    name,
                }
            })
            .collect();
        MetricsReport {
            requests: metrics.requests.load(Ordering::Relaxed),
            batches: metrics.batches.load(Ordering::Relaxed),
            batched_items: metrics.batched_items.load(Ordering::Relaxed),
            errors: metrics.errors.load(Ordering::Relaxed),
            avg_batch_rows: metrics.avg_batch_size(),
            latency_s: metrics.latency_summary(),
            rows_per_flush: metrics.batch_rows_summary(),
            sim_energy_j: metrics.sim_energy_j(),
            sim_time_s: metrics.sim_time_s(),
            ingress: ingress.snapshot(),
            inflight: ingress.inflight(),
            shedding: ingress.is_shedding(),
            engine,
            exec,
            exec_queue_depth,
            stage_admits: metrics.stage_admit_histogram(),
            pipeline_active: metrics.pipeline_active(),
            tenants,
        }
    }

    /// The scrape format: one JSON object, stable keys, numbers only
    /// (plus `null` for backend-absent sections) — see
    /// `docs/OPERATIONS.md` for the field-by-field reference.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("requests".into(), num(self.requests));
        o.insert("batches".into(), num(self.batches));
        o.insert("batched_items".into(), num(self.batched_items));
        o.insert("errors".into(), num(self.errors));
        o.insert("avg_batch_rows".into(), Json::Num(self.avg_batch_rows));
        o.insert("latency_s".into(), summary_json(&self.latency_s));
        o.insert("rows_per_flush".into(), summary_json(&self.rows_per_flush));
        o.insert("sim_energy_j".into(), Json::Num(self.sim_energy_j));
        o.insert("sim_time_s".into(), Json::Num(self.sim_time_s));
        o.insert("ingress".into(), ingress_json(&self.ingress));
        o.insert("inflight".into(), num(self.inflight));
        o.insert("shedding".into(), Json::Bool(self.shedding));
        o.insert("engine".into(), self.engine.as_ref().map_or(Json::Null, engine_json));
        o.insert("exec".into(), self.exec.as_ref().map_or(Json::Null, exec_json));
        o.insert(
            "exec_queue_depth".into(),
            self.exec_queue_depth.map_or(Json::Null, num),
        );
        let stages = self
            .stage_admits
            .iter()
            .map(|s| {
                let mut so = BTreeMap::new();
                so.insert("stage".into(), Json::Num(s.stage as f64));
                so.insert("admissions".into(), num(s.admissions));
                so.insert("rows".into(), num(s.rows));
                Json::Obj(so)
            })
            .collect();
        o.insert("stage_admits".into(), Json::Arr(stages));
        o.insert("pipeline_active".into(), num(self.pipeline_active));
        let tenants = self
            .tenants
            .iter()
            .map(|t| {
                let mut to = BTreeMap::new();
                to.insert("name".into(), Json::Str(t.name.clone()));
                to.insert("requests".into(), num(t.requests));
                to.insert("batches".into(), num(t.batches));
                to.insert("batched_items".into(), num(t.batched_items));
                to.insert("errors".into(), num(t.errors));
                to.insert("avg_batch_rows".into(), Json::Num(t.avg_batch_rows));
                to.insert("latency_s".into(), summary_json(&t.latency_s));
                to.insert("rows_per_flush".into(), summary_json(&t.rows_per_flush));
                to.insert("ingress".into(), ingress_json(&t.ingress));
                Json::Obj(to)
            })
            .collect();
        o.insert("tenants".into(), Json::Arr(tenants));
        Json::Obj(o)
    }
}

impl fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_json())
    }
}

fn num(x: u64) -> Json {
    Json::Num(x as f64)
}

fn summary_json(s: &Summary) -> Json {
    let mut o = BTreeMap::new();
    o.insert("n".into(), Json::Num(s.n as f64));
    o.insert("mean".into(), Json::Num(s.mean));
    o.insert("min".into(), Json::Num(s.min));
    o.insert("max".into(), Json::Num(s.max));
    o.insert("p50".into(), Json::Num(s.p50));
    o.insert("p95".into(), Json::Num(s.p95));
    o.insert("p99".into(), Json::Num(s.p99));
    Json::Obj(o)
}

fn ingress_json(s: &IngressSnapshot) -> Json {
    let mut o = BTreeMap::new();
    o.insert("admitted".into(), num(s.admitted));
    o.insert("rejected_shape".into(), num(s.rejected_shape));
    o.insert("rate_limited".into(), num(s.rate_limited));
    o.insert("shed".into(), num(s.shed));
    o.insert("unknown_model".into(), num(s.unknown_model));
    o.insert("offered".into(), num(s.offered()));
    Json::Obj(o)
}

fn engine_json(s: &EngineStatsSnapshot) -> Json {
    let mut o = BTreeMap::new();
    o.insert("gemms".into(), num(s.gemms));
    o.insert("tiles".into(), num(s.tiles));
    o.insert("windows".into(), num(s.windows));
    o.insert("macs".into(), num(s.macs));
    o.insert("write_rows".into(), num(s.write_rows));
    o.insert("plan_write_rows".into(), num(s.plan_write_rows));
    o.insert("hits".into(), num(s.hits));
    o.insert("misses".into(), num(s.misses));
    o.insert("evictions".into(), num(s.evictions));
    o.insert("hit_rate".into(), Json::Num(s.hit_rate()));
    Json::Obj(o)
}

fn exec_json(s: &ExecStatsSnapshot) -> Json {
    let mut o = BTreeMap::new();
    o.insert("submitted".into(), num(s.submitted));
    o.insert("executed".into(), num(s.executed));
    o.insert("affine".into(), num(s.affine));
    o.insert("stolen".into(), num(s.stolen));
    o.insert("spilled".into(), num(s.spilled));
    o.insert("queue_depth_max".into(), num(s.queue_depth_max));
    o.insert("panics".into(), num(s.panics));
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(1e-3);
        m.record_request(2e-3);
        m.record_batch(2, 1e-9, 5e-6);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.avg_batch_size(), 2.0);
        assert!((m.sim_energy_j() - 1e-9).abs() < 1e-12);
        assert!((m.sim_time_s() - 5e-6).abs() < 1e-9);
        let s = m.latency_summary();
        assert_eq!(s.n, 2);
    }

    #[test]
    fn latency_window_rolls_over_without_losing_history() {
        let m = Metrics::with_window(4);
        for i in 1..=10 {
            m.record_request(i as f64);
        }
        let s = m.latency_summary();
        // The summary always spans the full window — never a freshly
        // cleared vector of one or two samples.
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 7.0);
        assert_eq!(s.max, 10.0);
        assert_eq!(m.requests.load(Ordering::Relaxed), 10);
        // Exactly at the wrap boundary the oldest sample is replaced.
        let m2 = Metrics::with_window(3);
        for i in 1..=4 {
            m2.record_request(i as f64);
        }
        let s2 = m2.latency_summary();
        assert_eq!((s2.n, s2.min, s2.max), (3, 2.0, 4.0));
    }

    #[test]
    fn report_renders() {
        let m = Metrics::new();
        m.record_request(1e-3);
        m.record_batch(1, 2e-9, 1e-6);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("rows/flush"));
    }

    #[test]
    fn tenant_books_sum_to_the_global_counters() {
        let m = Metrics::with_window(8);
        m.record_request_for("a", 1e-3);
        m.record_request_for("a", 2e-3);
        m.record_request_for("b", 3e-3);
        m.record_batch_for("a", 2, 1e-9, 1e-6);
        m.record_batch_for("b", 1, 1e-9, 1e-6);
        m.record_error_for("b");
        assert_eq!(m.tenant_names(), vec!["a".to_string(), "b".to_string()]);
        let (a, b) = (m.tenant_book("a"), m.tenant_book("b"));
        let get = |x: &AtomicU64| x.load(Ordering::Relaxed);
        assert_eq!(get(&m.requests), get(&a.requests) + get(&b.requests));
        assert_eq!(get(&m.batches), get(&a.batches) + get(&b.batches));
        assert_eq!(get(&m.batched_items), get(&a.batched_items) + get(&b.batched_items));
        assert_eq!(get(&m.errors), get(&a.errors) + get(&b.errors));
        assert_eq!((get(&a.requests), get(&b.requests)), (2, 1));
        assert_eq!(a.avg_batch_size(), 2.0);
        assert_eq!(a.latency_summary().n, 2);
        assert_eq!(b.batch_rows_summary().max, 1.0);
    }

    #[test]
    fn report_gathers_union_of_books_and_ledgers_and_sums_to_global() {
        use crate::coordinator::ingress::{Ingress, IngressConfig};
        let m = Metrics::with_window(8);
        let ing = Ingress::new(2, IngressConfig::default());
        // "a" has both a book and a ledger; "b" only an ingress ledger
        // (admitted then rejected before any batch completed); "c" only
        // a metrics book (PJRT-style recording without ingress).
        ing.admit("a", &[1, -1]).unwrap();
        m.record_request_for("a", 1e-3);
        m.record_batch_for("a", 1, 0.0, 0.0);
        assert!(ing.admit("b", &[0, 1]).is_ok());
        assert!(ing.admit("b", &[9, 1]).is_err());
        m.record_request_for("c", 2e-3);
        let r = MetricsReport::gather(&m, &ing, None, None, None);
        let names: Vec<&str> = r.tenants.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c"]);
        let sum = |f: fn(&TenantReport) -> u64| r.tenants.iter().map(f).sum::<u64>();
        assert_eq!(r.requests, sum(|t| t.requests));
        assert_eq!(r.batches, sum(|t| t.batches));
        assert_eq!(r.ingress.admitted, sum(|t| t.ingress.admitted));
        assert_eq!(r.ingress.rejected_shape, sum(|t| t.ingress.rejected_shape));
        assert_eq!(r.ingress.offered(), sum(|t| t.ingress.offered()));
        assert_eq!(r.inflight, 2);
        assert!(!r.shedding);
        assert_eq!(r.engine, None);
        // JSON round-trips through the crate's own parser with the
        // expected columns in place.
        let json = crate::util::json::Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(json.get("requests").and_then(|j| j.as_f64()), Some(2.0));
        assert_eq!(
            json.get("ingress").and_then(|j| j.get("offered")).and_then(|j| j.as_f64()),
            Some(3.0)
        );
        assert_eq!(json.get("exec_queue_depth"), Some(&crate::util::json::Json::Null));
        assert_eq!(json.get("tenants").and_then(|j| j.as_arr()).map(|a| a.len()), Some(3));
    }

    #[test]
    fn stage_histogram_and_pipeline_gauge_track_the_layer_loop() {
        use crate::coordinator::ingress::{Ingress, IngressConfig};
        let m = Metrics::new();
        assert!(m.stage_admit_histogram().is_empty(), "no flushes yet");
        // One flush forms 4 rows at stage 0, admits 2 at boundary 1 and
        // 1 at boundary 2.
        m.pipeline_enter();
        m.record_stage_admission(0, 4);
        m.record_stage_admission(1, 2);
        m.record_stage_admission(2, 1);
        assert_eq!(m.pipeline_active(), 1);
        m.pipeline_exit();
        assert_eq!(m.pipeline_active(), 0);
        m.pipeline_exit();
        assert_eq!(m.pipeline_active(), 0, "gauge saturates, never wraps");
        let h = m.stage_admit_histogram();
        assert_eq!(h.len(), 3);
        assert_eq!(h[0], StageAdmits { stage: 0, admissions: 1, rows: 4 });
        assert_eq!(h[1], StageAdmits { stage: 1, admissions: 1, rows: 2 });
        assert_eq!(h[2], StageAdmits { stage: 2, admissions: 1, rows: 1 });
        // The report serializes both: stage rows and the gauge.
        let ing = Ingress::new(2, IngressConfig::default());
        let r = MetricsReport::gather(&m, &ing, None, None, None);
        assert_eq!(r.stage_admits, h);
        assert_eq!(r.pipeline_active, 0);
        let json = crate::util::json::Json::parse(&r.to_json().to_string()).unwrap();
        let stages = json.get("stage_admits").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(stages.len(), 3);
        assert_eq!(stages[1].get("rows").and_then(|j| j.as_f64()), Some(2.0));
        assert_eq!(json.get("pipeline_active").and_then(|j| j.as_f64()), Some(0.0));
    }

    #[test]
    fn batch_rows_histogram_tracks_flush_sizes() {
        let m = Metrics::new();
        for n in [1usize, 4, 4, 4, 32] {
            m.record_batch(n, 0.0, 0.0);
        }
        let s = m.batch_rows_summary();
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 32.0);
        assert_eq!(s.p50, 4.0);
    }
}
