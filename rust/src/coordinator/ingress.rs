//! Gateway-grade admission control in front of the serving queues.
//!
//! Everything the batcher and workers see has passed through one
//! [`Ingress`] — a small middleware chain applied *before* enqueue, so
//! malformed, excessive or unserviceable work is refused at the front
//! door instead of occupying queue slots and flush capacity:
//!
//! 1. **Shape validation** ([`Rejection::BadShape`]) — the request's
//!    trit plane must match the loaded manifest's input dimension and
//!    carry only signed-ternary values (−1/0/+1). A malformed request is
//!    a deterministic client bug: it is rejected first and never charges
//!    the client's rate bucket or flaps the shedder.
//! 2. **Per-tenant rate limiting** ([`Rejection::RateLimited`]) — a
//!    classic [`TokenBucket`] per tenant (model name; the single-model
//!    server uses one bucket), refilled continuously at `per_s` up to a
//!    `burst` ceiling. Time comes from an injected [`IngressClock`], so
//!    tests (and the doctest below) drive refill deterministically with
//!    a [`ManualClock`].
//! 3. **Watermark load shedding** ([`Rejection::Overloaded`]) — the
//!    ingress tracks admitted-but-unanswered requests in a live
//!    `inflight` gauge (the workers decrement it as replies scatter).
//!    When the gauge reaches the high-water mark the ingress *sheds*:
//!    excess requests get an immediate explicit `Overloaded` reply
//!    instead of a queue slot, so the latency of admitted work stays
//!    bounded by the watermark instead of growing with offered load.
//!    Shedding clears only once the gauge drains to the low-water mark
//!    (hysteresis — a queue hovering at the threshold does not flap
//!    between admitting and shedding on every reply). The watermark
//!    signal is *composite* when wired: with a positive
//!    [`IngressConfig::exec_backlog_weight`] and a backlog source
//!    ([`Ingress::set_backlog_source`] — the servers wire the engine's
//!    live `TernaryGemmEngine::exec_queue_depth`), the compared load is
//!    `inflight + weight × exec_backlog`, so shedding triggers early
//!    when flushes are large but few — a handful of giant merged
//!    batches can swamp the executor while the request-level gauge
//!    still looks calm. The weight defaults to 0 (request gauge only).
//!
//! Every verdict is counted — globally and per tenant, with the same
//! books-sum-to-global construction as `coordinator::metrics` — and the
//! counters surface in the scrapeable
//! [`MetricsReport`](super::metrics::MetricsReport) (`sitecim metrics
//! snapshot`).
//!
//! # Deterministic rate limiting
//!
//! ```
//! use sitecim::coordinator::ingress::{IngressClock, ManualClock, TokenBucket};
//!
//! let clock = ManualClock::default();
//! let bucket = TokenBucket::new(2.0, 2.0); // 2 req/s, burst of 2, starts full
//! assert!(bucket.try_take(clock.now_ns()));
//! assert!(bucket.try_take(clock.now_ns()));
//! assert!(!bucket.try_take(clock.now_ns()), "burst exhausted");
//! clock.advance_ms(500); // at 2 tokens/s this refills exactly one token
//! assert!(bucket.try_take(clock.now_ns()));
//! assert!(!bucket.try_take(clock.now_ns()));
//! ```
//!
//! # Shed / recover hysteresis
//!
//! ```
//! use sitecim::coordinator::ingress::{Ingress, IngressConfig, Rejection, Watermarks};
//!
//! let cfg = IngressConfig { shed: Some(Watermarks { high: 2, low: 1 }), ..Default::default() };
//! let ingress = Ingress::new(3, cfg); // serving a 3-trit input dimension
//! assert!(ingress.admit("m", &[1, 0, -1]).is_ok());
//! assert!(ingress.admit("m", &[0, 1, 1]).is_ok());
//! // Two requests in flight reach the high-water mark: shed.
//! assert!(matches!(ingress.admit("m", &[0, 0, 0]), Err(Rejection::Overloaded { .. })));
//! // One reply drains the gauge to the low-water mark: recovered.
//! ingress.request_done();
//! assert!(ingress.admit("m", &[0, 0, 0]).is_ok());
//! // Malformed shapes are refused outright — wrong length or non-trit values.
//! assert!(matches!(ingress.admit("m", &[1, 0]), Err(Rejection::BadShape { .. })));
//! assert!(matches!(ingress.admit("m", &[2, 0, 0]), Err(Rejection::BadShape { .. })));
//! assert_eq!(ingress.snapshot().rejected_shape, 2);
//! ```

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Time source for the rate limiter: monotonic nanoseconds since an
/// arbitrary origin. Injected so tests advance time explicitly instead
/// of sleeping (see [`ManualClock`]); production uses [`MonotonicClock`].
pub trait IngressClock: Send + Sync {
    fn now_ns(&self) -> u64;
}

/// The production clock: `Instant`-based monotonic nanoseconds.
#[derive(Debug)]
pub struct MonotonicClock {
    origin: Instant,
}

impl Default for MonotonicClock {
    fn default() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl IngressClock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// A hand-cranked clock for deterministic tests: time only moves when
/// the test calls [`ManualClock::advance_ns`] / [`ManualClock::advance_ms`].
#[derive(Debug, Default)]
pub struct ManualClock {
    now_ns: AtomicU64,
}

impl ManualClock {
    pub fn advance_ns(&self, ns: u64) {
        self.now_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn advance_ms(&self, ms: u64) {
        self.advance_ns(ms * 1_000_000);
    }
}

impl IngressClock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.now_ns.load(Ordering::Relaxed)
    }
}

/// Continuous token-bucket rate limiter: capacity `burst` tokens,
/// refilled at `per_s` tokens per second, one token per admission. The
/// bucket starts full, so a cold client gets its full burst immediately.
#[derive(Debug)]
pub struct TokenBucket {
    per_s: f64,
    burst: f64,
    state: Mutex<BucketState>,
}

#[derive(Debug)]
struct BucketState {
    tokens: f64,
    last_ns: u64,
}

impl TokenBucket {
    /// A full bucket of `burst` tokens refilling at `per_s` per second.
    /// Both must be positive (a zero rate would never refill; callers
    /// expressing "unlimited" simply skip the bucket).
    pub fn new(per_s: f64, burst: f64) -> TokenBucket {
        assert!(per_s > 0.0 && burst > 0.0, "rate and burst must be positive");
        TokenBucket {
            per_s,
            burst,
            state: Mutex::new(BucketState { tokens: burst, last_ns: 0 }),
        }
    }

    /// Take one token at time `now_ns` (from the injected clock).
    /// Returns `false` — rate limited — when less than a whole token has
    /// accumulated.
    pub fn try_take(&self, now_ns: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        let dt_ns = now_ns.saturating_sub(st.last_ns);
        st.last_ns = st.last_ns.max(now_ns);
        st.tokens = (st.tokens + dt_ns as f64 * 1e-9 * self.per_s).min(self.burst);
        if st.tokens >= 1.0 {
            st.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens currently available at `now_ns` (diagnostic; does not take).
    pub fn available(&self, now_ns: u64) -> f64 {
        let st = self.state.lock().unwrap();
        (st.tokens + now_ns.saturating_sub(st.last_ns) as f64 * 1e-9 * self.per_s).min(self.burst)
    }
}

/// Per-tenant rate-limit knob: sustained `per_s` admissions per second
/// with transient bursts up to `burst`.
#[derive(Clone, Copy, Debug)]
pub struct RateLimit {
    pub per_s: f64,
    pub burst: f64,
}

/// Load-shedding watermarks over the in-flight gauge: shed at
/// `inflight ≥ high`, recover at `inflight ≤ low` (hysteresis).
#[derive(Clone, Copy, Debug)]
pub struct Watermarks {
    pub high: u64,
    pub low: u64,
}

/// Ingress policy. `Default` is fully open: no rate limit, no shedding,
/// shape validation always on (a malformed plane can never be served
/// correctly, so there is no knob to admit one).
#[derive(Clone, Copy, Debug, Default)]
pub struct IngressConfig {
    /// Per-tenant token-bucket rate limit; `None` admits any rate.
    pub rate: Option<RateLimit>,
    /// Load-shedding watermarks over the in-flight gauge; `None` never
    /// sheds.
    pub shed: Option<Watermarks>,
    /// Weight of the executor's live queue depth in the shed signal:
    /// the watermarks compare `inflight + weight × exec_backlog` once a
    /// backlog source is wired ([`Ingress::set_backlog_source`]). 0
    /// (default) watches the request-level gauge alone; positive values
    /// trigger shedding early when flushes are large but few.
    pub exec_backlog_weight: f64,
}

/// Why the ingress refused a request. Every variant is an *immediate*
/// reply — a rejected request never occupies a queue slot.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Rejection {
    /// The request plane does not match the loaded manifest (wrong
    /// length, or a value outside {−1, 0, +1}).
    BadShape { reason: String },
    /// The tenant's token bucket is empty — retry after `retry_in_s`.
    RateLimited { tenant: String, retry_in_s: f64 },
    /// The shed load crossed the high-water mark; the server sheds
    /// until it drains to `low` (hysteresis). `load` is the compared
    /// signal: the in-flight gauge alone, or the composite
    /// `inflight + weight × exec_backlog` when a backlog source is
    /// wired ([`Ingress::set_backlog_source`]).
    Overloaded { load: u64, high: u64, low: u64 },
    /// No model lane with that name is loaded (multi-tenant serving).
    UnknownModel { model: String },
}

impl Rejection {
    /// Seconds until a retry can succeed, for refusals with a clock
    /// behind them: the rate limiter's own refill arithmetic
    /// ([`Rejection::RateLimited`]'s `retry_in_s`). `None` otherwise —
    /// shed and shape refusals clear on load or on a client fix, not on
    /// a timer. The servers surface this through
    /// `InferError::retry_after_s` as a Retry-After-style hint.
    pub fn retry_after_s(&self) -> Option<f64> {
        match self {
            Rejection::RateLimited { retry_in_s, .. } => Some(*retry_in_s),
            _ => None,
        }
    }
}

impl std::error::Error for Rejection {}

impl fmt::Display for Rejection {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Rejection::BadShape { reason } => write!(f, "bad request shape: {reason}"),
            Rejection::RateLimited { tenant, retry_in_s } => {
                write!(f, "rate limited (tenant {tenant:?}): retry in {retry_in_s:.3}s")
            }
            Rejection::Overloaded { load, high, low } => write!(
                f,
                "overloaded: shed load {load} ≥ high water {high} \
                 (shedding until ≤ {low})"
            ),
            Rejection::UnknownModel { model } => write!(f, "unknown model {model:?}"),
        }
    }
}

/// Cumulative admission counters (one global set plus one per tenant).
#[derive(Debug, Default)]
struct Counters {
    admitted: AtomicU64,
    rejected_shape: AtomicU64,
    rate_limited: AtomicU64,
    shed: AtomicU64,
    unknown_model: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> IngressSnapshot {
        IngressSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected_shape: self.rejected_shape.load(Ordering::Relaxed),
            rate_limited: self.rate_limited.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            unknown_model: self.unknown_model.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the admission counters. `offered()` is the
/// total work presented to the front door; every offered request lands
/// in exactly one column, and each per-tenant snapshot sums into the
/// global one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IngressSnapshot {
    /// Requests that passed the whole chain and were enqueued.
    pub admitted: u64,
    /// Refused by shape/size validation (wrong plane length, non-trit
    /// values).
    pub rejected_shape: u64,
    /// Refused by the per-tenant token bucket.
    pub rate_limited: u64,
    /// Refused by watermark load shedding (explicit `Overloaded` reply).
    pub shed: u64,
    /// Refused because no such model lane is loaded.
    pub unknown_model: u64,
}

impl IngressSnapshot {
    /// Total requests offered to the ingress (admitted + every rejection).
    pub fn offered(&self) -> u64 {
        self.admitted + self.rejected_shape + self.rate_limited + self.shed + self.unknown_model
    }
}

/// The admission gate: one per server, shared by every caller of
/// `infer_async`. See the module docs for the middleware chain and the
/// doctests for the contract.
pub struct Ingress {
    cfg: IngressConfig,
    clock: Arc<dyn IngressClock>,
    in_dim: usize,
    /// Admitted-but-unanswered requests. Incremented on admission,
    /// decremented by the workers as replies scatter — the live signal
    /// the shed watermarks act on.
    inflight: AtomicU64,
    /// Latched shed state (the hysteresis bit).
    shedding: AtomicBool,
    /// Live executor-backlog source for the composite shed signal
    /// (wired by the servers after the engine backend exists; `None`
    /// until then, and on the PJRT backend).
    backlog: RwLock<Option<Arc<dyn Fn() -> u64 + Send + Sync>>>,
    buckets: RwLock<BTreeMap<String, Arc<TokenBucket>>>,
    global: Counters,
    tenants: RwLock<BTreeMap<String, Arc<Counters>>>,
}

impl Ingress {
    /// An ingress validating against input dimension `in_dim`, using the
    /// production monotonic clock.
    pub fn new(in_dim: usize, cfg: IngressConfig) -> Ingress {
        Ingress::with_clock(in_dim, cfg, Arc::new(MonotonicClock::default()))
    }

    /// [`Ingress::new`] with an injected clock (tests pass a
    /// [`ManualClock`] to drive token refill deterministically).
    pub fn with_clock(in_dim: usize, cfg: IngressConfig, clock: Arc<dyn IngressClock>) -> Ingress {
        if let Some(w) = cfg.shed {
            assert!(w.high >= 1, "a zero high-water mark would shed everything");
            assert!(w.low < w.high, "hysteresis needs low < high");
        }
        Ingress {
            cfg,
            clock,
            in_dim,
            inflight: AtomicU64::new(0),
            shedding: AtomicBool::new(false),
            backlog: RwLock::new(None),
            buckets: RwLock::new(BTreeMap::new()),
            global: Counters::default(),
            tenants: RwLock::new(BTreeMap::new()),
        }
    }

    /// The policy this ingress enforces.
    pub fn config(&self) -> &IngressConfig {
        &self.cfg
    }

    /// Wire the live executor-backlog source for the composite shed
    /// signal. Only meaningful with a positive
    /// [`IngressConfig::exec_backlog_weight`]; the servers pass the
    /// engine backend's `exec_queue_depth` once it exists (the ingress
    /// is built before the backend, so this is a post-construction
    /// hook).
    pub fn set_backlog_source(&self, source: impl Fn() -> u64 + Send + Sync + 'static) {
        *self.backlog.write().unwrap_or_else(std::sync::PoisonError::into_inner) =
            Some(Arc::new(source));
    }

    /// The load the shed watermarks compare: the in-flight request
    /// gauge, plus `exec_backlog_weight × backlog` when a source is
    /// wired. With the default weight of 0 this *is* the gauge.
    pub fn shed_load(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed) + self.backlog_load()
    }

    /// The weighted executor-backlog contribution to the shed signal
    /// (0 without a source or with a zero weight).
    fn backlog_load(&self) -> u64 {
        if self.cfg.exec_backlog_weight <= 0.0 {
            return 0;
        }
        let source = self.backlog.read().unwrap_or_else(std::sync::PoisonError::into_inner);
        match source.as_ref() {
            Some(f) => (self.cfg.exec_backlog_weight * f() as f64).round() as u64,
            None => 0,
        }
    }

    /// Run the admission chain for one request of `tenant`. `Ok` means
    /// the caller may enqueue (and the in-flight gauge was charged —
    /// every admitted request must eventually be balanced by
    /// [`Ingress::request_done`]); `Err` carries the explicit rejection
    /// reply.
    pub fn admit(&self, tenant: &str, input: &[i8]) -> Result<(), Rejection> {
        self.admit_shaped(tenant, self.in_dim, input)
    }

    /// [`Ingress::admit`] validating against a caller-supplied input
    /// dimension — the multi-tenant router passes each lane's manifest
    /// dimension through one shared gate.
    pub fn admit_shaped(&self, tenant: &str, in_dim: usize, input: &[i8]) -> Result<(), Rejection> {
        // 1. Shape: deterministic client bugs, refused before they touch
        //    the client's budget or the shed state.
        if input.len() != in_dim {
            return Err(self.reject_shape(
                tenant,
                format!("input len {} != manifest in_dim {}", input.len(), in_dim),
            ));
        }
        if let Some(bad) = input.iter().find(|&&t| !(-1..=1).contains(&t)) {
            return Err(self.reject_shape(
                tenant,
                format!("input holds non-trit value {bad} (want -1, 0 or +1)"),
            ));
        }
        // 2. Rate: one token per admission from the tenant's bucket.
        if let Some(rl) = self.cfg.rate {
            let bucket = self.bucket(tenant, rl);
            if !bucket.try_take(self.clock.now_ns()) {
                self.charge(tenant, |c| &c.rate_limited);
                // Time until a whole token has accumulated at `per_s`.
                let deficit = 1.0 - bucket.available(self.clock.now_ns());
                return Err(Rejection::RateLimited {
                    tenant: tenant.to_string(),
                    retry_in_s: (deficit / rl.per_s).max(0.0),
                });
            }
        }
        // 3. Load: shed above the high-water mark, recover at the low
        //    one. The compared load is composite when a backlog source
        //    is wired: `inflight + weight × exec_backlog` triggers
        //    early when flushes are large but few.
        if let Some(w) = self.cfg.shed {
            let load = self.shed_load();
            let was_shedding = self.shedding.load(Ordering::Relaxed);
            let shedding = if was_shedding { load > w.low } else { load >= w.high };
            if shedding != was_shedding {
                self.shedding.store(shedding, Ordering::Relaxed);
            }
            if shedding {
                self.charge(tenant, |c| &c.shed);
                return Err(Rejection::Overloaded { load, high: w.high, low: w.low });
            }
        }
        self.inflight.fetch_add(1, Ordering::Relaxed);
        self.charge(tenant, |c| &c.admitted);
        Ok(())
    }

    /// Balance one admission: a reply (success *or* backend error) was
    /// delivered for an admitted request. Drives shed recovery.
    pub fn request_done(&self) {
        self.requests_done(1);
    }

    /// [`Ingress::request_done`] for a whole scattered batch.
    pub fn requests_done(&self, n: u64) {
        let prev = self.inflight.fetch_sub(n, Ordering::Relaxed);
        debug_assert!(prev >= n, "more replies than admissions");
        if let Some(w) = self.cfg.shed {
            // Recovery watches the same composite load admission sheds
            // on: a drained request gauge with a still-swamped executor
            // keeps the latch set.
            if (prev - n) + self.backlog_load() <= w.low
                && self.shedding.load(Ordering::Relaxed)
            {
                self.shedding.store(false, Ordering::Relaxed);
            }
        }
    }

    /// Record an unknown-model rejection (the multi-tenant router fails
    /// the lane lookup before any lane-specific validation can run).
    pub fn reject_unknown_model(&self, model: &str) -> Rejection {
        self.charge(model, |c| &c.unknown_model);
        Rejection::UnknownModel { model: model.to_string() }
    }

    /// Admitted-but-unanswered requests right now.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Whether the shed latch is currently set (between watermarks this
    /// reflects the direction the gauge last crossed — hysteresis).
    pub fn is_shedding(&self) -> bool {
        self.shedding.load(Ordering::Relaxed)
    }

    /// Global admission counters.
    pub fn snapshot(&self) -> IngressSnapshot {
        self.global.snapshot()
    }

    /// One tenant's admission counters (zeros if the tenant never
    /// appeared).
    pub fn tenant_snapshot(&self, tenant: &str) -> IngressSnapshot {
        self.tenants
            .read()
            .unwrap()
            .get(tenant)
            .map(|c| c.snapshot())
            .unwrap_or_default()
    }

    /// Tenants with at least one counted verdict, sorted.
    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.read().unwrap().keys().cloned().collect()
    }

    fn reject_shape(&self, tenant: &str, reason: String) -> Rejection {
        self.charge(tenant, |c| &c.rejected_shape);
        Rejection::BadShape { reason }
    }

    /// Charge one counter globally and in `tenant`'s book (created on
    /// first use) — books sum to the globals by construction.
    fn charge(&self, tenant: &str, which: impl Fn(&Counters) -> &AtomicU64) {
        which(&self.global).fetch_add(1, Ordering::Relaxed);
        if let Some(book) = self.tenants.read().unwrap().get(tenant) {
            which(book).fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut map = self.tenants.write().unwrap();
        let book = map.entry(tenant.to_string()).or_default();
        which(book).fetch_add(1, Ordering::Relaxed);
    }

    fn bucket(&self, tenant: &str, rl: RateLimit) -> Arc<TokenBucket> {
        if let Some(b) = self.buckets.read().unwrap().get(tenant) {
            return Arc::clone(b);
        }
        let mut map = self.buckets.write().unwrap();
        let bucket = map
            .entry(tenant.to_string())
            .or_insert_with(|| Arc::new(TokenBucket::new(rl.per_s, rl.burst)));
        Arc::clone(bucket)
    }
}

impl fmt::Debug for Ingress {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Ingress")
            .field("cfg", &self.cfg)
            .field("in_dim", &self.in_dim)
            .field("inflight", &self.inflight)
            .field("shedding", &self.shedding)
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manual() -> Arc<ManualClock> {
        Arc::new(ManualClock::default())
    }

    #[test]
    fn token_bucket_burst_then_deterministic_refill() {
        let clock = manual();
        let b = TokenBucket::new(10.0, 3.0);
        // Full burst up front, then empty.
        for _ in 0..3 {
            assert!(b.try_take(clock.now_ns()));
        }
        assert!(!b.try_take(clock.now_ns()));
        // 99 ms at 10/s is 0.99 tokens: still limited. One more ms tips it.
        clock.advance_ms(99);
        assert!(!b.try_take(clock.now_ns()));
        clock.advance_ms(1);
        assert!(b.try_take(clock.now_ns()));
        // Refill caps at the burst: a long idle stretch grants 3, not 100.
        clock.advance_ms(10_000);
        for _ in 0..3 {
            assert!(b.try_take(clock.now_ns()));
        }
        assert!(!b.try_take(clock.now_ns()));
    }

    #[test]
    fn rate_limit_is_per_tenant_and_reports_retry() {
        let clock = manual();
        let cfg = IngressConfig {
            rate: Some(RateLimit { per_s: 1.0, burst: 1.0 }),
            ..Default::default()
        };
        let ing = Ingress::with_clock(2, cfg, clock.clone());
        assert!(ing.admit("a", &[1, -1]).is_ok());
        // `a` is out of tokens; `b` has its own untouched bucket.
        let r = ing.admit("a", &[1, -1]).unwrap_err();
        match r {
            Rejection::RateLimited { ref tenant, retry_in_s } => {
                assert_eq!(tenant, "a");
                assert!(retry_in_s > 0.0 && retry_in_s <= 1.0, "retry {retry_in_s}");
            }
            other => panic!("expected RateLimited, got {other:?}"),
        }
        assert!(ing.admit("b", &[0, 0]).is_ok());
        // Refill admits `a` again.
        clock.advance_ms(1_000);
        assert!(ing.admit("a", &[1, 1]).is_ok());
        assert_eq!(ing.tenant_snapshot("a").rate_limited, 1);
        assert_eq!(ing.tenant_snapshot("b").rate_limited, 0);
    }

    #[test]
    fn malformed_shapes_are_rejected_with_reasons() {
        let ing = Ingress::new(3, IngressConfig::default());
        let short = ing.admit("m", &[1, 0]).unwrap_err();
        assert!(matches!(short, Rejection::BadShape { ref reason } if reason.contains("len 2")));
        let bad = ing.admit("m", &[1, 2, 0]).unwrap_err();
        assert!(matches!(bad, Rejection::BadShape { ref reason } if reason.contains("2")));
        assert!(ing.admit("m", &[1, 0, -1]).is_ok());
        let s = ing.snapshot();
        assert_eq!((s.rejected_shape, s.admitted), (2, 1));
        // Rejections never charge the in-flight gauge.
        assert_eq!(ing.inflight(), 1);
    }

    #[test]
    fn shed_hysteresis_recovers_only_at_low_water() {
        let cfg = IngressConfig {
            shed: Some(Watermarks { high: 3, low: 1 }),
            ..Default::default()
        };
        let ing = Ingress::new(1, cfg);
        for _ in 0..3 {
            assert!(ing.admit("m", &[1]).is_ok());
        }
        // Gauge at high water: shedding starts and latches.
        assert!(matches!(ing.admit("m", &[1]), Err(Rejection::Overloaded { .. })));
        assert!(ing.is_shedding());
        // Draining to 2 (> low) keeps shedding — no flapping between the
        // watermarks.
        ing.request_done();
        assert_eq!(ing.inflight(), 2);
        assert!(matches!(ing.admit("m", &[1]), Err(Rejection::Overloaded { .. })));
        // Draining to the low-water mark recovers.
        ing.request_done();
        assert!(!ing.is_shedding(), "request_done at low water clears the latch");
        assert!(ing.admit("m", &[1]).is_ok());
        let s = ing.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.admitted, 4);
    }

    #[test]
    fn counters_sum_to_global_across_tenants_and_conserve_offered() {
        let cfg = IngressConfig {
            rate: Some(RateLimit { per_s: 1.0, burst: 3.0 }),
            shed: Some(Watermarks { high: 2, low: 0 }),
            ..Default::default()
        };
        let ing = Ingress::with_clock(1, cfg, manual());
        // a: 2 admitted (fills the gauge), then 1 shed (burst 3 keeps
        // a's bucket from emptying first — rate runs before shed). b:
        // 1 bad shape + 1 shed (b's own fresh bucket). Unknown model too.
        assert!(ing.admit("a", &[1]).is_ok());
        assert!(ing.admit("a", &[0]).is_ok());
        assert!(matches!(ing.admit("a", &[1]), Err(Rejection::Overloaded { .. })));
        assert!(matches!(ing.admit("b", &[1, 1]), Err(Rejection::BadShape { .. })));
        assert!(matches!(ing.admit("b", &[1]), Err(Rejection::Overloaded { .. })));
        let _ = ing.reject_unknown_model("ghost");
        let (g, a, b, ghost) = (
            ing.snapshot(),
            ing.tenant_snapshot("a"),
            ing.tenant_snapshot("b"),
            ing.tenant_snapshot("ghost"),
        );
        assert_eq!(g.offered(), 6);
        assert_eq!(g.admitted, a.admitted + b.admitted + ghost.admitted);
        assert_eq!(g.shed, a.shed + b.shed + ghost.shed);
        assert_eq!(g.rejected_shape, a.rejected_shape + b.rejected_shape + ghost.rejected_shape);
        assert_eq!(g.unknown_model, a.unknown_model + b.unknown_model + ghost.unknown_model);
        assert_eq!(g.offered(), a.offered() + b.offered() + ghost.offered());
        assert_eq!(ing.tenant_names(), vec!["a", "b", "ghost"]);
    }

    #[test]
    fn composite_shed_signal_weighs_exec_backlog() {
        let cfg = IngressConfig {
            shed: Some(Watermarks { high: 4, low: 1 }),
            exec_backlog_weight: 0.5,
            ..Default::default()
        };
        let ing = Ingress::new(1, cfg);
        let depth = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&depth);
        ing.set_backlog_source(move || d.load(Ordering::Relaxed));
        // Backlog 0: the request gauge alone drives the signal.
        assert!(ing.admit("m", &[1]).is_ok());
        assert_eq!(ing.shed_load(), 1);
        // A deep executor backlog (few but giant flushes) pushes the
        // composite load over the high-water mark while the request
        // gauge sits at 1.
        depth.store(6, Ordering::Relaxed);
        assert_eq!(ing.shed_load(), 1 + 3);
        let r = ing.admit("m", &[1]).unwrap_err();
        assert!(
            matches!(r, Rejection::Overloaded { load: 4, high: 4, .. }),
            "expected composite overload, got {r:?}"
        );
        assert!(ing.is_shedding());
        // Draining the request gauge alone does not recover while the
        // executor stays swamped...
        ing.request_done();
        assert_eq!(ing.inflight(), 0);
        assert!(ing.is_shedding(), "latch holds: backlog still above low water");
        assert!(matches!(ing.admit("m", &[1]), Err(Rejection::Overloaded { .. })));
        // ...and clears once the backlog does.
        depth.store(0, Ordering::Relaxed);
        assert!(ing.admit("m", &[1]).is_ok());
        assert!(!ing.is_shedding());
    }

    #[test]
    fn zero_weight_ignores_backlog_source() {
        let cfg = IngressConfig { shed: Some(Watermarks { high: 2, low: 0 }), ..Default::default() };
        let ing = Ingress::new(1, cfg);
        ing.set_backlog_source(|| 1_000_000);
        assert_eq!(ing.shed_load(), 0, "weight 0 keeps the gauge-only signal");
        assert!(ing.admit("m", &[1]).is_ok());
    }

    #[test]
    fn retry_after_surfaces_only_for_rate_limits() {
        let clock = manual();
        let cfg = IngressConfig {
            rate: Some(RateLimit { per_s: 2.0, burst: 1.0 }),
            ..Default::default()
        };
        let ing = Ingress::with_clock(1, cfg, clock);
        assert!(ing.admit("a", &[1]).is_ok());
        let limited = ing.admit("a", &[1]).unwrap_err();
        let retry = limited.retry_after_s().expect("rate limit carries a retry hint");
        // An empty bucket at 2 tokens/s refills a whole token in 0.5 s.
        assert!(retry > 0.0 && retry <= 0.5, "retry {retry}");
        assert!(format!("{limited}").contains("retry in"), "Display renders the hint");
        let bad = ing.admit("a", &[9]).unwrap_err();
        assert_eq!(bad.retry_after_s(), None, "shape bugs have no retry clock");
    }

    #[test]
    fn default_config_admits_everything_wellformed() {
        let ing = Ingress::new(2, IngressConfig::default());
        for _ in 0..10_000 {
            assert!(ing.admit("m", &[1, -1]).is_ok());
        }
        assert_eq!(ing.snapshot().admitted, 10_000);
        assert!(!ing.is_shedding());
    }
}
