//! Servable inference backends.
//!
//! The coordinator can execute requests through either of two engines:
//!
//! - [`PjrtBackend`] — the AOT-compiled HLO graphs on the PJRT CPU
//!   client (numerics identical to the JAX/Pallas reference; requires
//!   artifacts + the `pjrt` feature). PJRT handles are not `Send`, so
//!   each worker thread builds its own instance in-thread.
//! - [`EngineBackend`] — the functional [`TernaryGemmEngine`] in
//!   *resident* mode: the manifest's ternary weights are registered with
//!   the engine once, their tiles live in one shared array pool, and
//!   inference routes input batches to the already-programmed arrays
//!   (`gemm_resident`), layer by layer, with the AOT-recorded activation
//!   thresholds between layers. The backend is `Sync`: the server wraps
//!   one instance in an `Arc` and every worker serves through it — one
//!   weight copy, one pool, one persistent stripe-scheduled executor.
//!   Server workers therefore *submit* work to a shared worker pool
//!   (per-shard items with load-aware per-slot affinity, see
//!   `engine::exec`) rather than each spinning up threads per GEMM;
//!   concurrent batches pipeline through disjoint arrays, and the data
//!   path is zero-copy — weights are registered as shared `Arc` planes
//!   and each layer's activation plane is handed to the engine by
//!   reference count (`gemm_resident_arc`), never recopied per job.
//!
//! Both present the same padded-batch trits → logits surface, so the
//! server's worker loop is backend-agnostic.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::array::area::Design;
use crate::device::Tech;
use crate::dnn::ternary;
use crate::engine::resident::WeightId;
use crate::engine::{EngineConfig, EngineStatsSnapshot, ExecStatsSnapshot, TernaryGemmEngine};
use crate::runtime::executor::PjrtClient;
use crate::runtime::{cpu_client, Manifest, MlpExecutor, ModelKind};

/// Which execution backend serves inference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO on the PJRT CPU client.
    Pjrt,
    /// Functional ternary GEMM engine over simulated CiM arrays.
    Engine,
}

/// A loaded, servable model: a batch of trit inputs in, logits out.
pub trait InferenceBackend {
    /// The manifest's batch dimension. For the PJRT path this is a hard
    /// per-call cap (the compiled executable's fixed batch dim); for the
    /// engine path it is only a policy default — `run_batch` accepts any
    /// M (see [`EngineBackend::run_batch_arc`]).
    fn batch(&self) -> usize;
    fn in_dim(&self) -> usize;
    fn out_dim(&self) -> usize;
    /// Run `n_valid` row-major input rows; returns `n_valid × out_dim`
    /// row-major logits.
    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>>;
}

/// Shared backends serve through an `Arc` without a wrapper type.
impl<T: InferenceBackend> InferenceBackend for Arc<T> {
    fn batch(&self) -> usize {
        (**self).batch()
    }

    fn in_dim(&self) -> usize {
        (**self).in_dim()
    }

    fn out_dim(&self) -> usize {
        (**self).out_dim()
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        (**self).run_batch(trits, n_valid)
    }
}

/// The PJRT path: compiled executable + held client.
pub struct PjrtBackend {
    // The executable's buffers live on the client; keep it alive.
    _client: PjrtClient,
    exe: MlpExecutor,
}

impl PjrtBackend {
    pub fn load(manifest: &Manifest, kind: ModelKind) -> Result<PjrtBackend> {
        let client = cpu_client()?;
        let exe = MlpExecutor::load(&client, manifest, kind).context("loading executable")?;
        Ok(PjrtBackend { _client: client, exe })
    }
}

impl InferenceBackend for PjrtBackend {
    fn batch(&self) -> usize {
        self.exe.batch
    }

    fn in_dim(&self) -> usize {
        self.exe.in_dim
    }

    fn out_dim(&self) -> usize {
        self.exe.out_dim
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        self.exe.run_batch(trits, n_valid)
    }
}

/// The functional path: manifest weights resident on one shared tiled
/// GEMM engine.
pub struct EngineBackend {
    engine: TernaryGemmEngine,
    /// (registered weight handle, k, n) per layer.
    layers: Vec<(WeightId, usize, usize)>,
    /// Activation thresholds between layers (AOT-recorded).
    thresholds: Vec<f64>,
    batch: usize,
    in_dim: usize,
    out_dim: usize,
}

impl EngineBackend {
    /// Load the manifest's layers and register their weights with a
    /// fresh engine. With `capacity_words = None` the pool is sized to
    /// hold the whole network (one array per tile — conservative, since
    /// sub-array packing can fit the shards into fewer arrays); with a
    /// word budget the pool is capacity-bounded
    /// (`EngineConfig::with_capacity_words`) and serves under
    /// second-chance eviction pressure when the network exceeds it —
    /// still bit-exact,
    /// with measured hit rates in [`Self::engine_stats`]. Weights are
    /// programmed lazily on first use and stay resident until evicted.
    pub fn load(
        manifest: &Manifest,
        design: Design,
        tech: Tech,
        n_threads: usize,
        capacity_words: Option<u64>,
    ) -> Result<EngineBackend> {
        let mut weights = Vec::new();
        for i in 0..manifest.weights.len() {
            let (w, (k, n)) = manifest.load_weight(i)?;
            weights.push((w, k, n));
        }
        if weights.is_empty() {
            bail!("manifest describes no weight layers");
        }
        for pair in weights.windows(2) {
            if pair[0].2 != pair[1].1 {
                bail!(
                    "layer shapes do not chain: {}×{} then {}×{}",
                    pair[0].1,
                    pair[0].2,
                    pair[1].1,
                    pair[1].2
                );
            }
        }
        if manifest.act_thresholds.len() + 1 < weights.len() {
            bail!(
                "manifest has {} activation thresholds for {} layers (need {})",
                manifest.act_thresholds.len(),
                weights.len(),
                weights.len() - 1
            );
        }
        let in_dim = weights[0].1;
        let out_dim = weights.last().unwrap().2;

        let cfg = EngineConfig::new(design, tech).with_threads(n_threads);
        let engine = match capacity_words {
            // Bounded pool: serve at the given word budget.
            Some(words) => TernaryGemmEngine::new(cfg.with_capacity_words(words)),
            // One array per tile of the whole network: fully resident.
            None => {
                let total: usize = weights.iter().map(|(_, k, n)| cfg.tiles_for(*k, *n)).sum();
                TernaryGemmEngine::new(cfg.with_pool(total.max(1)))
            }
        };

        let mut layers = Vec::new();
        for (w, k, n) in weights {
            // Zero-copy registration: the engine takes over this (sole)
            // copy of the layer's trits as a shared plane.
            let id = engine
                .register_weight_arc(w.into(), k, n)
                .with_context(|| format!("registering {k}×{n} layer weights"))?;
            layers.push((id, k, n));
        }
        Ok(EngineBackend {
            engine,
            layers,
            thresholds: manifest.act_thresholds.clone(),
            batch: manifest.batch,
            in_dim,
            out_dim,
        })
    }

    /// Engine work/cache counters (tile hits, misses, programming).
    pub fn engine_stats(&self) -> EngineStatsSnapshot {
        self.engine.stats()
    }

    /// Executor counters: items submitted/executed across all serving
    /// workers, affinity-vs-steal split, panics survived.
    pub fn exec_stats(&self) -> ExecStatsSnapshot {
        self.engine.exec_stats()
    }

    /// Physical arrays in the serving pool.
    pub fn pool_arrays(&self) -> usize {
        self.engine.pool_arrays()
    }

    /// Ternary-word capacity of the serving pool.
    pub fn capacity_words(&self) -> u64 {
        self.engine.capacity_words()
    }

    /// The continuous-batching entry point: run an already-merged
    /// `n_valid × in_dim` activation plane through the layer pipeline.
    ///
    /// Unlike the trait's `run_batch`, M is **not** capped by the
    /// manifest `batch` — that number is the AOT executable's fixed
    /// batch dimension (a PJRT compile-time constant), not an engine
    /// limit. GEMM rows are independent, the stripe accumulators and
    /// `WorkerScratch` buffers grow with M, so any merged row count the
    /// batcher forms is served in one pipeline pass. The plane is handed
    /// to every layer by reference count (zero-copy).
    pub fn run_batch_arc(&self, plane: Arc<[i8]>, n_valid: usize) -> Result<Vec<f32>> {
        if n_valid == 0 {
            bail!("n_valid must be >= 1");
        }
        if plane.len() != n_valid * self.in_dim {
            bail!("expected {} trits, got {}", n_valid * self.in_dim, plane.len());
        }
        let m = n_valid;
        // One shared activation plane per layer boundary: the engine's
        // zero-copy resident path hands it to every shard's work item by
        // reference count, never by cloning trits.
        let mut h = plane;
        for (li, (id, _k, _n)) in self.layers.iter().enumerate() {
            let y = self
                .engine
                .gemm_resident_arc(*id, Arc::clone(&h), m)
                .with_context(|| format!("layer {li} resident GEMM"))?;
            if li + 1 < self.layers.len() {
                // Ternarize hidden activations at the recorded threshold
                // (length validated at load).
                h = ternary::ternarize_acts_i32(&y, self.thresholds[li]).into();
            } else {
                return Ok(y.iter().map(|&v| v as f32).collect());
            }
        }
        unreachable!("layers is non-empty; the final layer returns")
    }
}

impl InferenceBackend for EngineBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn in_dim(&self) -> usize {
        self.in_dim
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        // No `n_valid > self.batch` cap: the engine serves arbitrary M
        // (see `run_batch_arc`); `self.batch` only informs batching
        // policy defaults.
        self.run_batch_arc(Arc::from(trits), n_valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The server shares one EngineBackend across worker threads.
    #[test]
    fn engine_backend_is_sync_and_send() {
        fn assert_sync_send<T: Sync + Send>() {}
        assert_sync_send::<EngineBackend>();
        assert_sync_send::<Arc<EngineBackend>>();
    }
}
