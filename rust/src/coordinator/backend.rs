//! Servable inference backends.
//!
//! The coordinator can execute requests through either of two engines:
//!
//! - [`PjrtBackend`] — the AOT-compiled HLO graphs on the PJRT CPU
//!   client (numerics identical to the JAX/Pallas reference; requires
//!   artifacts + the `pjrt` feature). PJRT handles are not `Send`, so
//!   each worker thread builds its own instance in-thread.
//! - [`EngineBackend`] — the functional [`TernaryGemmEngine`] in
//!   *resident* mode: the manifest's ternary weights are registered with
//!   the engine once, their tiles live in one shared array pool, and
//!   inference routes input batches to the already-programmed arrays
//!   (`gemm_resident`), layer by layer, with the AOT-recorded activation
//!   thresholds between layers. The backend is `Sync`: the server wraps
//!   one instance in an `Arc` and every worker serves through it — one
//!   weight copy, one pool, one persistent stripe-scheduled executor.
//!   Server workers therefore *submit* work to a shared worker pool
//!   (per-shard items with load-aware per-slot affinity, see
//!   `engine::exec`) rather than each spinning up threads per GEMM;
//!   concurrent batches pipeline through disjoint arrays, and the data
//!   path is zero-copy — weights are registered as shared `Arc` planes
//!   and each layer's activation plane is handed to the engine by
//!   reference count (`gemm_resident_arc`), never recopied per job.
//!
//! Both present the same padded-batch trits → logits surface, so the
//! server's worker loop is backend-agnostic.
//!
//! [`MultiTenantBackend`] extends the engine path to N models on **one**
//! shared pool: each model is a [`TenantModel`] whose weights register
//! into a cache partition (a hard reservation carved by
//! `TernaryGemmEngine::reserve_tenant`, or the best-effort shared
//! partition 0), cold-starts from the artifact's placement plan when one
//! matches the engine geometry, and can be hot-swapped to a new artifact
//! version — the new version registers fresh weight ids and programs
//! into the partition's headroom, the old version keeps serving until
//! the swap returns it for draining, and bit-exactness never depends on
//! placement (content tags are authoritative).

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use anyhow::{bail, ensure, Context, Result};

use crate::array::area::Design;
use crate::device::Tech;
use crate::dnn::ternary;
use crate::engine::resident::{WeightId, SHARED_PARTITION};
use crate::engine::{
    EngineConfig, EngineStatsSnapshot, ExecStatsSnapshot, PlannedShard, StageFlushSnapshot,
    TernaryGemmEngine,
};
use crate::runtime::executor::PjrtClient;
use crate::runtime::{cpu_client, Manifest, MlpExecutor, ModelKind, PlacementPlan};

/// Which execution backend serves inference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO on the PJRT CPU client.
    Pjrt,
    /// Functional ternary GEMM engine over simulated CiM arrays.
    Engine,
}

/// A loaded, servable model: a batch of trit inputs in, logits out.
pub trait InferenceBackend {
    /// The manifest's batch dimension. For the PJRT path this is a hard
    /// per-call cap (the compiled executable's fixed batch dim); for the
    /// engine path it is only a policy default — `run_batch` accepts any
    /// M (see [`EngineBackend::run_batch_arc`]).
    fn batch(&self) -> usize;
    fn in_dim(&self) -> usize;
    fn out_dim(&self) -> usize;
    /// Run `n_valid` row-major input rows; returns `n_valid × out_dim`
    /// row-major logits.
    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>>;
}

/// What one layer stage of a resident pipeline produced: either the
/// next stage's input plane (hidden activations, already ternarized at
/// the recorded threshold) or the final logits.
pub enum LayerOutput {
    /// Hidden activations for layer `li + 1`, shared zero-copy.
    Hidden(Arc<[i8]>),
    /// Final-layer logits, row-major `m × out_dim`.
    Logits(Vec<f32>),
}

/// A backend whose forward pass can be driven one layer at a time —
/// the surface the layer-pipelined server loop batches against. Each
/// layer boundary is an admission point: the caller may concatenate
/// newly arrived rows onto the plane between `run_layer_arc` calls
/// (after catching those rows up through stages `0..li`), and because
/// GEMM rows are independent in M the result stays bit-exact against
/// serial per-request execution.
///
/// Implemented by [`EngineBackend`] and [`TenantModel`]; `run_batch_arc`
/// on both is literally a fold over this trait.
pub trait LayerPipeline {
    /// Number of layer stages (≥ 1).
    fn n_layers(&self) -> usize;
    /// Input width of stage `li` (= `in_dim` at stage 0, the previous
    /// layer's output width after that). A plane entering stage `li`
    /// must hold `m × layer_in_dim(li)` trits.
    fn layer_in_dim(&self, li: usize) -> usize;
    /// Run stage `li` on a merged `m × layer_in_dim(li)` plane.
    fn run_layer_arc(&self, li: usize, plane: Arc<[i8]>, m: usize) -> Result<LayerOutput>;
}

/// Shared backends serve through an `Arc` without a wrapper type.
impl<T: InferenceBackend> InferenceBackend for Arc<T> {
    fn batch(&self) -> usize {
        (**self).batch()
    }

    fn in_dim(&self) -> usize {
        (**self).in_dim()
    }

    fn out_dim(&self) -> usize {
        (**self).out_dim()
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        (**self).run_batch(trits, n_valid)
    }
}

/// The PJRT path: compiled executable + held client.
pub struct PjrtBackend {
    // The executable's buffers live on the client; keep it alive.
    _client: PjrtClient,
    exe: MlpExecutor,
}

impl PjrtBackend {
    pub fn load(manifest: &Manifest, kind: ModelKind) -> Result<PjrtBackend> {
        let client = cpu_client()?;
        let exe = MlpExecutor::load(&client, manifest, kind).context("loading executable")?;
        Ok(PjrtBackend { _client: client, exe })
    }
}

impl InferenceBackend for PjrtBackend {
    fn batch(&self) -> usize {
        self.exe.batch
    }

    fn in_dim(&self) -> usize {
        self.exe.in_dim
    }

    fn out_dim(&self) -> usize {
        self.exe.out_dim
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        self.exe.run_batch(trits, n_valid)
    }
}

/// The functional path: manifest weights resident on one shared tiled
/// GEMM engine.
pub struct EngineBackend {
    engine: TernaryGemmEngine,
    /// (registered weight handle, k, n) per layer.
    layers: Vec<(WeightId, usize, usize)>,
    /// Activation thresholds between layers (AOT-recorded).
    thresholds: Vec<f64>,
    batch: usize,
    in_dim: usize,
    out_dim: usize,
}

impl EngineBackend {
    /// Load the manifest's layers and register their weights with a
    /// fresh engine. With `capacity_words = None` the pool is sized to
    /// hold the whole network (one array per tile — conservative, since
    /// sub-array packing can fit the shards into fewer arrays); with a
    /// word budget the pool is capacity-bounded
    /// (`EngineConfig::with_capacity_words`) and serves under
    /// second-chance eviction pressure when the network exceeds it —
    /// still bit-exact,
    /// with measured hit rates in [`Self::engine_stats`]. Weights are
    /// programmed lazily on first use and stay resident until evicted.
    pub fn load(
        manifest: &Manifest,
        design: Design,
        tech: Tech,
        n_threads: usize,
        capacity_words: Option<u64>,
    ) -> Result<EngineBackend> {
        let weights = load_layer_chain(manifest)?;
        let in_dim = weights[0].1;
        let out_dim = weights.last().unwrap().2;

        let cfg = EngineConfig::new(design, tech).with_threads(n_threads);
        let engine = match capacity_words {
            // Bounded pool: serve at the given word budget.
            Some(words) => TernaryGemmEngine::new(cfg.with_capacity_words(words)),
            // One array per tile of the whole network: fully resident.
            None => {
                let total: usize = weights.iter().map(|(_, k, n)| cfg.tiles_for(*k, *n)).sum();
                TernaryGemmEngine::new(cfg.with_pool(total.max(1)))
            }
        };

        let mut layers = Vec::new();
        for (w, k, n) in weights {
            // Zero-copy registration: the engine takes over this (sole)
            // copy of the layer's trits as a shared plane.
            let id = engine
                .register_weight_arc(w.into(), k, n)
                .with_context(|| format!("registering {k}×{n} layer weights"))?;
            layers.push((id, k, n));
        }
        Ok(EngineBackend {
            engine,
            layers,
            thresholds: manifest.act_thresholds.clone(),
            batch: manifest.batch,
            in_dim,
            out_dim,
        })
    }

    /// Engine work/cache counters (tile hits, misses, programming).
    pub fn engine_stats(&self) -> EngineStatsSnapshot {
        self.engine.stats()
    }

    /// Executor counters: items submitted/executed across all serving
    /// workers, affinity-vs-steal split, panics survived.
    pub fn exec_stats(&self) -> ExecStatsSnapshot {
        self.engine.exec_stats()
    }

    /// Live executor backlog (see `TernaryGemmEngine::exec_queue_depth`):
    /// the watermark signal scraped into `MetricsReport`.
    pub fn exec_queue_depth(&self) -> u64 {
        self.engine.exec_queue_depth()
    }

    /// Physical arrays in the serving pool.
    pub fn pool_arrays(&self) -> usize {
        self.engine.pool_arrays()
    }

    /// Ternary-word capacity of the serving pool.
    pub fn capacity_words(&self) -> u64 {
        self.engine.capacity_words()
    }

    /// Per-stage flush counters charged by the per-layer resident path
    /// (see [`TernaryGemmEngine::stage_flush_stats`]).
    pub fn stage_flush_stats(&self) -> Vec<StageFlushSnapshot> {
        self.engine.stage_flush_stats()
    }

    /// The continuous-batching entry point: run an already-merged
    /// `n_valid × in_dim` activation plane through the layer pipeline.
    ///
    /// Unlike the trait's `run_batch`, M is **not** capped by the
    /// manifest `batch` — that number is the AOT executable's fixed
    /// batch dimension (a PJRT compile-time constant), not an engine
    /// limit. GEMM rows are independent, the stripe accumulators and
    /// `WorkerScratch` buffers grow with M, so any merged row count the
    /// batcher forms is served in one pipeline pass. The plane is handed
    /// to every layer by reference count (zero-copy).
    pub fn run_batch_arc(&self, plane: Arc<[i8]>, n_valid: usize) -> Result<Vec<f32>> {
        run_pipeline_serial(self, plane, n_valid)
    }
}

impl LayerPipeline for EngineBackend {
    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn layer_in_dim(&self, li: usize) -> usize {
        self.layers[li].1
    }

    fn run_layer_arc(&self, li: usize, plane: Arc<[i8]>, m: usize) -> Result<LayerOutput> {
        run_layer_resident(&self.engine, &self.layers, &self.thresholds, li, plane, m, None)
    }
}

impl InferenceBackend for EngineBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn in_dim(&self) -> usize {
        self.in_dim
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        // No `n_valid > self.batch` cap: the engine serves arbitrary M
        // (see `run_batch_arc`); `self.batch` only informs batching
        // policy defaults.
        self.run_batch_arc(Arc::from(trits), n_valid)
    }
}

impl<T: LayerPipeline> LayerPipeline for Arc<T> {
    fn n_layers(&self) -> usize {
        (**self).n_layers()
    }

    fn layer_in_dim(&self, li: usize) -> usize {
        (**self).layer_in_dim(li)
    }

    fn run_layer_arc(&self, li: usize, plane: Arc<[i8]>, m: usize) -> Result<LayerOutput> {
        (**self).run_layer_arc(li, plane, m)
    }
}

/// One layer stage of a resident chain, shared by [`EngineBackend`] and
/// [`TenantModel`]: validate the plane, run the merged GEMM zero-copy
/// against the registered weights, charge the engine's per-stage flush
/// book, then ternarize at the recorded threshold (hidden layers) or
/// widen to logits (final layer).
fn run_layer_resident(
    engine: &TernaryGemmEngine,
    layers: &[(WeightId, usize, usize)],
    thresholds: &[f64],
    li: usize,
    plane: Arc<[i8]>,
    m: usize,
    model: Option<&str>,
) -> Result<LayerOutput> {
    if m == 0 {
        bail!("m must be >= 1");
    }
    let Some(&(id, k, _n)) = layers.get(li) else {
        bail!("layer index {li} out of range ({} layers)", layers.len());
    };
    if plane.len() != m * k {
        bail!("layer {li} expects {} trits ({m}×{k}), got {}", m * k, plane.len());
    }
    let y = engine.gemm_resident_arc(id, plane, m).with_context(|| match model {
        Some(name) => format!("model {name} layer {li} resident GEMM"),
        None => format!("layer {li} resident GEMM"),
    })?;
    engine.note_stage_flush(li, m);
    if li + 1 < layers.len() {
        // Ternarize hidden activations at the recorded threshold
        // (threshold coverage validated at load).
        Ok(LayerOutput::Hidden(ternary::ternarize_acts_i32(&y, thresholds[li]).into()))
    } else {
        Ok(LayerOutput::Logits(y.iter().map(|&v| v as f32).collect()))
    }
}

/// Fold a [`LayerPipeline`] serially over one merged plane — the
/// monolithic (no mid-pipeline admission) execution both backends'
/// `run_batch_arc` delegates to, and the reference the pipelined server
/// loop must match bit-for-bit.
fn run_pipeline_serial<P: LayerPipeline + ?Sized>(
    pipeline: &P,
    plane: Arc<[i8]>,
    n_valid: usize,
) -> Result<Vec<f32>> {
    if n_valid == 0 {
        bail!("n_valid must be >= 1");
    }
    if plane.len() != n_valid * pipeline.layer_in_dim(0) {
        bail!(
            "expected {} trits, got {}",
            n_valid * pipeline.layer_in_dim(0),
            plane.len()
        );
    }
    // One shared activation plane per layer boundary: the engine's
    // zero-copy resident path hands it to every shard's work item by
    // reference count, never by cloning trits.
    let mut h = plane;
    for li in 0..pipeline.n_layers() {
        match pipeline.run_layer_arc(li, h, n_valid)? {
            LayerOutput::Hidden(next) => h = next,
            LayerOutput::Logits(y) => return Ok(y),
        }
    }
    unreachable!("layers is non-empty; the final layer returns Logits")
}

/// Load the manifest's weight layers and check that their shapes chain
/// and the activation thresholds cover the layer boundaries. Shared by
/// the single-model [`EngineBackend`] and [`MultiTenantBackend`].
fn load_layer_chain(manifest: &Manifest) -> Result<Vec<(Vec<i8>, usize, usize)>> {
    let mut weights = Vec::new();
    for i in 0..manifest.weights.len() {
        let (w, (k, n)) = manifest.load_weight(i)?;
        weights.push((w, k, n));
    }
    if weights.is_empty() {
        bail!("manifest describes no weight layers");
    }
    for pair in weights.windows(2) {
        if pair[0].2 != pair[1].1 {
            bail!(
                "layer shapes do not chain: {}×{} then {}×{}",
                pair[0].1,
                pair[0].2,
                pair[1].1,
                pair[1].2
            );
        }
    }
    if manifest.act_thresholds.len() + 1 < weights.len() {
        bail!(
            "manifest has {} activation thresholds for {} layers (need {})",
            manifest.act_thresholds.len(),
            weights.len(),
            weights.len() - 1
        );
    }
    Ok(weights)
}

/// One loaded model version inside a [`MultiTenantBackend`]: its
/// registered layer weights, the cache partition they place into, and
/// the layer pipeline to run them. Immutable once built — hot-swap
/// builds a *new* `TenantModel` (new weight ids, `generation + 1`) and
/// atomically replaces the map entry, so a server flush that captured
/// this `Arc` runs its whole pipeline on one version.
pub struct TenantModel {
    engine: Arc<TernaryGemmEngine>,
    name: String,
    /// Monotonic per-name version instance (1 on first load, +1 per
    /// hot-swap). Replies can be attributed to the exact version that
    /// served them.
    generation: u64,
    /// The cache partition the model's shards place into (0 = shared
    /// best-effort partition).
    partition: usize,
    /// (registered weight handle, k, n) per layer.
    layers: Vec<(WeightId, usize, usize)>,
    thresholds: Vec<f64>,
    batch: usize,
    in_dim: usize,
    out_dim: usize,
}

impl TenantModel {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The cache partition this model's shards place into.
    pub fn partition(&self) -> usize {
        self.partition
    }

    /// The model's engine-side work book (see
    /// [`TernaryGemmEngine::tenant_stats`]). Shared-partition models
    /// share partition 0's book.
    pub fn tenant_stats(&self) -> EngineStatsSnapshot {
        self.engine.tenant_stats(self.partition)
    }

    /// Same continuous-batching surface as
    /// [`EngineBackend::run_batch_arc`]: one merged `n_valid × in_dim`
    /// plane through the layer pipeline, zero-copy.
    pub fn run_batch_arc(&self, plane: Arc<[i8]>, n_valid: usize) -> Result<Vec<f32>> {
        run_pipeline_serial(self, plane, n_valid)
    }
}

impl LayerPipeline for TenantModel {
    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn layer_in_dim(&self, li: usize) -> usize {
        self.layers[li].1
    }

    fn run_layer_arc(&self, li: usize, plane: Arc<[i8]>, m: usize) -> Result<LayerOutput> {
        run_layer_resident(
            &self.engine,
            &self.layers,
            &self.thresholds,
            li,
            plane,
            m,
            Some(&self.name),
        )
    }
}

impl InferenceBackend for TenantModel {
    fn batch(&self) -> usize {
        self.batch
    }

    fn in_dim(&self) -> usize {
        self.in_dim
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        self.run_batch_arc(Arc::from(trits), n_valid)
    }
}

/// N models resident on **one** shared engine pool, each in its own
/// capacity partition (hard reservation) or the best-effort shared
/// partition, each hot-swappable to a new artifact version without a
/// serving gap. See the module docs.
pub struct MultiTenantBackend {
    engine: Arc<TernaryGemmEngine>,
    models: RwLock<BTreeMap<String, Arc<TenantModel>>>,
}

impl MultiTenantBackend {
    /// An empty multi-tenant backend over a `capacity_words`-bounded
    /// pool. Models are added with [`Self::add_model`].
    pub fn new(
        design: Design,
        tech: Tech,
        n_threads: usize,
        capacity_words: u64,
    ) -> MultiTenantBackend {
        let cfg = EngineConfig::new(design, tech)
            .with_threads(n_threads)
            .with_capacity_words(capacity_words);
        MultiTenantBackend {
            engine: Arc::new(TernaryGemmEngine::new(cfg)),
            models: RwLock::new(BTreeMap::new()),
        }
    }

    pub fn engine(&self) -> &Arc<TernaryGemmEngine> {
        &self.engine
    }

    /// Loaded model names, sorted.
    pub fn model_names(&self) -> Vec<String> {
        self.lock_models().keys().cloned().collect()
    }

    /// The current version of `name`, if loaded.
    pub fn model(&self, name: &str) -> Option<Arc<TenantModel>> {
        self.lock_models().get(name).cloned()
    }

    fn lock_models(&self) -> std::sync::RwLockReadGuard<'_, BTreeMap<String, Arc<TenantModel>>> {
        self.models.read().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Load `manifest` as tenant `name`. With `reserve_words` the model
    /// gets a hard-reserved partition of that many pool words (its
    /// residency is isolated from every other tenant's traffic);
    /// without, it shares the best-effort partition 0 under second-
    /// chance eviction. When the manifest carries a placement plan
    /// matching this engine's geometry, the weights are programmed from
    /// the plan (strict replay on the empty partition — cold start does
    /// no discovery).
    pub fn add_model(
        &self,
        name: &str,
        manifest: &Manifest,
        reserve_words: Option<u64>,
    ) -> Result<Arc<TenantModel>> {
        ensure!(
            self.model(name).is_none(),
            "model {name:?} is already loaded (hot_swap replaces versions)"
        );
        let partition = match reserve_words {
            Some(words) => self
                .engine
                .reserve_tenant(words)
                .with_context(|| format!("reserving {words} pool words for model {name:?}"))?,
            None => SHARED_PARTITION,
        };
        let model = self.build_version(name, manifest, partition, 1)?;
        self.models
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .insert(name.to_string(), Arc::clone(&model));
        Ok(model)
    }

    /// Hot-swap `name` to a new artifact version: register the new
    /// weights into the same partition, program them into its headroom
    /// (plan-guided when available — non-strict, since the old version
    /// is still resident), and atomically publish the new version.
    /// Returns `(new, old)`; the caller keeps serving through `new`
    /// immediately, drains in-flight work holding `old`, then calls
    /// [`Self::retire`] on it to free its regions.
    pub fn swap_model(
        &self,
        name: &str,
        manifest: &Manifest,
    ) -> Result<(Arc<TenantModel>, Arc<TenantModel>)> {
        let old = self
            .model(name)
            .with_context(|| format!("model {name:?} is not loaded (add_model first)"))?;
        let new = self.build_version(name, manifest, old.partition, old.generation + 1)?;
        self.models
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .insert(name.to_string(), Arc::clone(&new));
        Ok((new, old))
    }

    /// Free a drained model version's placed regions (content tags and
    /// placements; the registration stays — weight ids are never
    /// reused). Call after every in-flight batch holding the version has
    /// completed.
    pub fn retire(&self, old: &TenantModel) {
        for (id, _, _) in &old.layers {
            self.engine.invalidate_weight(*id);
        }
    }

    fn build_version(
        &self,
        name: &str,
        manifest: &Manifest,
        partition: usize,
        generation: u64,
    ) -> Result<Arc<TenantModel>> {
        let weights = load_layer_chain(manifest)
            .with_context(|| format!("loading model {name:?} v{generation}"))?;
        let in_dim = weights[0].1;
        let out_dim = weights.last().unwrap().2;
        let mut layers = Vec::new();
        for (w, k, n) in weights {
            let id = self
                .engine
                .register_weight_arc_in(w.into(), k, n, partition)
                .with_context(|| format!("registering {k}×{n} weights for model {name:?}"))?;
            layers.push((id, k, n));
        }
        if let Some(plan) = self.usable_plan(manifest, partition) {
            for (li, (id, _, _)) in layers.iter().enumerate() {
                let shards: Vec<PlannedShard> =
                    plan.shards.iter().filter(|s| s.layer == li).copied().collect();
                self.engine.program_from_plan(*id, &shards).with_context(|| {
                    format!("programming model {name:?} v{generation} layer {li} from its plan")
                })?;
            }
        }
        Ok(Arc::new(TenantModel {
            engine: Arc::clone(&self.engine),
            name: name.to_string(),
            generation,
            partition,
            layers,
            thresholds: manifest.act_thresholds.clone(),
            batch: manifest.batch,
            in_dim,
            out_dim,
        }))
    }

    /// The manifest's placement plan, if it can drive this engine:
    /// same array geometry, and every planned slot rank exists in the
    /// model's partition. A mismatched plan is not an error — the model
    /// just falls back to discovery-on-first-traffic.
    fn usable_plan<'m>(
        &self,
        manifest: &'m Manifest,
        partition: usize,
    ) -> Option<&'m PlacementPlan> {
        let plan = manifest.placement.as_ref()?;
        let cfg = self.engine.cfg();
        let fits = plan.array_rows == cfg.array_rows
            && plan.array_cols == cfg.array_cols
            && plan.shards.iter().all(|s| s.slot < self.engine.tenant_slots(partition));
        fits.then_some(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The server shares one EngineBackend across worker threads.
    #[test]
    fn engine_backend_is_sync_and_send() {
        fn assert_sync_send<T: Sync + Send>() {}
        assert_sync_send::<EngineBackend>();
        assert_sync_send::<Arc<EngineBackend>>();
        assert_sync_send::<MultiTenantBackend>();
        assert_sync_send::<Arc<TenantModel>>();
    }
}
