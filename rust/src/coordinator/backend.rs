//! Servable inference backends.
//!
//! The coordinator can execute requests through either of two engines:
//!
//! - [`PjrtBackend`] — the AOT-compiled HLO graphs on the PJRT CPU
//!   client (numerics identical to the JAX/Pallas reference; requires
//!   artifacts + the `pjrt` feature).
//! - [`EngineBackend`] — the functional [`TernaryGemmEngine`]: the
//!   manifest's ternary weights run on simulated SiTe CiM arrays, layer
//!   by layer, with the AOT-recorded activation thresholds between
//!   layers (the same forward semantics the e2e_inference example
//!   validates against the HLO path).
//!
//! Both present the same padded-batch trits → logits surface, so the
//! server's worker loop is backend-agnostic.

use anyhow::{bail, Context, Result};

use crate::array::area::Design;
use crate::device::Tech;
use crate::dnn::ternary;
use crate::engine::{EngineConfig, TernaryGemmEngine};
use crate::runtime::executor::PjrtClient;
use crate::runtime::{cpu_client, Manifest, MlpExecutor, ModelKind};

/// Which execution backend serves inference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO on the PJRT CPU client.
    Pjrt,
    /// Functional ternary GEMM engine over simulated CiM arrays.
    Engine,
}

/// A loaded, servable model: a batch of trit inputs in, logits out.
pub trait InferenceBackend {
    /// Maximum batch rows per `run_batch` call.
    fn batch(&self) -> usize;
    fn in_dim(&self) -> usize;
    fn out_dim(&self) -> usize;
    /// Run `n_valid` row-major input rows; returns `n_valid × out_dim`
    /// row-major logits.
    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>>;
}

/// The PJRT path: compiled executable + held client.
pub struct PjrtBackend {
    // The executable's buffers live on the client; keep it alive.
    _client: PjrtClient,
    exe: MlpExecutor,
}

impl PjrtBackend {
    pub fn load(manifest: &Manifest, kind: ModelKind) -> Result<PjrtBackend> {
        let client = cpu_client()?;
        let exe = MlpExecutor::load(&client, manifest, kind).context("loading executable")?;
        Ok(PjrtBackend { _client: client, exe })
    }
}

impl InferenceBackend for PjrtBackend {
    fn batch(&self) -> usize {
        self.exe.batch
    }

    fn in_dim(&self) -> usize {
        self.exe.in_dim
    }

    fn out_dim(&self) -> usize {
        self.exe.out_dim
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        self.exe.run_batch(trits, n_valid)
    }
}

/// The functional path: manifest weights on the tiled GEMM engine.
pub struct EngineBackend {
    engine: TernaryGemmEngine,
    /// (row-major k×n ternary weights, k, n) per layer.
    layers: Vec<(Vec<i8>, usize, usize)>,
    /// Activation thresholds between layers (AOT-recorded).
    thresholds: Vec<f64>,
    batch: usize,
    in_dim: usize,
    out_dim: usize,
}

impl EngineBackend {
    pub fn load(
        manifest: &Manifest,
        design: Design,
        tech: Tech,
        n_threads: usize,
    ) -> Result<EngineBackend> {
        let mut layers = Vec::new();
        for i in 0..manifest.weights.len() {
            let (w, (k, n)) = manifest.load_weight(i)?;
            layers.push((w, k, n));
        }
        if layers.is_empty() {
            bail!("manifest describes no weight layers");
        }
        for pair in layers.windows(2) {
            if pair[0].2 != pair[1].1 {
                bail!("layer shapes do not chain: {}×{} then {}×{}", pair[0].1, pair[0].2, pair[1].1, pair[1].2);
            }
        }
        if manifest.act_thresholds.len() + 1 < layers.len() {
            bail!(
                "manifest has {} activation thresholds for {} layers (need {})",
                manifest.act_thresholds.len(),
                layers.len(),
                layers.len() - 1
            );
        }
        let in_dim = layers[0].1;
        let out_dim = layers.last().unwrap().2;
        let engine = TernaryGemmEngine::new(
            EngineConfig::new(design, tech).with_pool(8).with_threads(n_threads),
        );
        Ok(EngineBackend {
            engine,
            layers,
            thresholds: manifest.act_thresholds.clone(),
            batch: manifest.batch,
            in_dim,
            out_dim,
        })
    }
}

impl InferenceBackend for EngineBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn in_dim(&self) -> usize {
        self.in_dim
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        if n_valid == 0 || n_valid > self.batch {
            bail!("n_valid {} out of range 1..={}", n_valid, self.batch);
        }
        if trits.len() != n_valid * self.in_dim {
            bail!("expected {} trits, got {}", n_valid * self.in_dim, trits.len());
        }
        let m = n_valid;
        let mut h: Vec<i8> = trits.to_vec();
        for (li, (w, k, n)) in self.layers.iter().enumerate() {
            let y = self.engine.gemm(&h, w, m, *k, *n);
            if li + 1 < self.layers.len() {
                // Ternarize hidden activations at the recorded threshold
                // (length validated at load).
                h = ternary::ternarize_acts_i32(&y, self.thresholds[li]);
            } else {
                return Ok(y.iter().map(|&v| v as f32).collect());
            }
        }
        unreachable!("layers is non-empty; the final layer returns")
    }
}
