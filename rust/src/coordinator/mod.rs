//! Serving coordinator: a thread-based inference service over the PJRT
//! runtime — bounded request queue, dynamic batcher, N worker threads
//! (each owning its own PJRT client), request/latency metrics and
//! simulated-accelerator accounting.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::BatchPolicy;
pub use metrics::Metrics;
pub use server::{InferReply, Server, ServerConfig};
