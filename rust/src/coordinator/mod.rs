//! Serving coordinator: a thread-based inference service with pluggable
//! execution backends — the PJRT runtime (per-worker instances; PJRT
//! handles are not `Send`) or the functional ternary GEMM engine (one
//! `Arc`-shared resident model: one weight copy, one array pool, tiles
//! programmed once and reused across all workers) — behind a bounded
//! request queue, dynamic batcher, N panic-isolated worker threads,
//! request/latency metrics (rolling ring-buffer window) and
//! simulated-accelerator accounting.
//!
//! Multi-tenant serving ([`MultiServer`] over a [`MultiTenantBackend`])
//! loads N models onto **one** shared engine pool — each in a hard-
//! reserved capacity partition or the best-effort shared one — routes
//! requests by model name through per-model continuous-batching lanes
//! (rows from different models never share an M-plane), keeps
//! per-tenant metric books that sum to the global counters, and
//! hot-swaps a model to a new artifact version without dropping
//! in-flight requests.
//!
//! The front door is guarded: every `infer_async` passes the
//! [`ingress`] admission chain (manifest shape validation, per-tenant
//! token-bucket rate limiting, watermark load shedding with hysteresis)
//! *before* enqueue, so malformed or excess work is answered with an
//! explicit rejection instead of a queue slot. The whole picture —
//! serving counters, admission ledger, engine/executor snapshots — is
//! scrapeable as one [`MetricsReport`] (`sitecim metrics snapshot`).

pub mod backend;
pub mod batcher;
pub mod ingress;
pub mod metrics;
pub mod server;

pub use backend::{
    BackendKind, EngineBackend, InferenceBackend, LayerOutput, LayerPipeline, MultiTenantBackend,
    PjrtBackend, TenantModel,
};
pub use batcher::BatchPolicy;
pub use ingress::{Ingress, IngressConfig, IngressSnapshot, RateLimit, Rejection, Watermarks};
pub use metrics::{Metrics, MetricsReport, StageAdmits, TenantBook, TenantReport};
pub use server::{
    run_pipelined_flush, InferError, InferReply, MeasuredResidency, MultiServer,
    MultiServerConfig, Server, ServerConfig,
};
