//! Serving coordinator: a thread-based inference service with pluggable
//! execution backends — the PJRT runtime or the functional ternary GEMM
//! engine — behind a bounded request queue, dynamic batcher, N worker
//! threads (each owning its own backend instance), request/latency
//! metrics and simulated-accelerator accounting.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use backend::{BackendKind, EngineBackend, InferenceBackend, PjrtBackend};
pub use batcher::BatchPolicy;
pub use metrics::Metrics;
pub use server::{InferReply, Server, ServerConfig};
