//! Serving coordinator: a thread-based inference service with pluggable
//! execution backends — the PJRT runtime (per-worker instances; PJRT
//! handles are not `Send`) or the functional ternary GEMM engine (one
//! `Arc`-shared resident model: one weight copy, one array pool, tiles
//! programmed once and reused across all workers) — behind a bounded
//! request queue, dynamic batcher, N panic-isolated worker threads,
//! request/latency metrics (rolling ring-buffer window) and
//! simulated-accelerator accounting.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use backend::{BackendKind, EngineBackend, InferenceBackend, PjrtBackend};
pub use batcher::BatchPolicy;
pub use metrics::Metrics;
pub use server::{InferReply, MeasuredResidency, Server, ServerConfig};
