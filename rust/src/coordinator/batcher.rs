//! Dynamic batching: coalesces queued requests under a latency deadline —
//! the standard serving trade-off (bigger batches amortize dispatch; the
//! deadline bounds queueing delay).
//!
//! Two formers share one [`BatchPolicy`]:
//!
//! - [`next_batch`] — the PJRT former: collects up to `max_batch` items
//!   (the compiled executable's fixed batch dimension) and hands them
//!   back as a `Vec` for the caller to flatten.
//! - [`form_merged_batch`] — the engine backend's *continuous* former:
//!   merges every in-flight request into one contiguous `Arc<[i8]>`
//!   M-plane (one activation row per request, M = total live rows),
//!   capped by `max_batch_rows` instead of the manifest batch. The
//!   concatenation here is the **only** copy on the merged path — the
//!   engine's zero-copy resident surface (`gemm_resident_arc`) threads
//!   the plane through every layer by reference count.
//!
//! # Why flush at layer 0 only
//!
//! GEMM rows are independent, so merging any set of requests into one
//! M-plane is *always* bit-exact — each row's outputs equal its
//! single-request execution regardless of what shares the batch.
//! Admitting a late-arriving request *between layer boundaries* of an
//! in-flight merged batch is a different matter: the newcomer has not
//! been through layers `0..i`, so it would need catch-up GEMMs through
//! the earlier layers before its row could join the plane — exactly the
//! per-request small-M executions the merge exists to amortize away,
//! plus ragged per-row bookkeeping in the scatter path. The batcher
//! therefore admits requests only when a merged batch *starts* (flush at
//! layer 0); requests arriving mid-pipeline seed the next merge, whose
//! deadline is already bounded by `max_wait`.
//!
//! The batcher only ever sees pre-screened work: requests reach the
//! channel through the `coordinator::ingress` admission chain, so
//! malformed planes never enter a merge and, under overload, excess
//! requests are shed at the front door instead of growing the queue this
//! module drains (the queue the shed watermarks bound is exactly the
//! in-flight population these formers merge from).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Hard cap for the PJRT former — the compiled executable's batch
    /// dimension.
    pub max_batch: usize,
    /// Hard cap on merged M-plane rows for the engine former (one row
    /// per request; independent of the manifest `batch`).
    pub max_batch_rows: usize,
    /// Max time the first request in a batch may wait for company.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_batch_rows: 256,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// All in-flight requests merged into one contiguous activation plane:
/// `plane` is the row-major `rows × row_len` concatenation of
/// `items[i]`'s activation rows, in item order.
pub struct MergedBatch<T> {
    pub items: Vec<T>,
    pub plane: Arc<[i8]>,
    pub rows: usize,
}

/// Collect the next batch from `rx`. Blocks for the first item; then
/// drains up to `max_batch` items or until `max_wait` expires. Returns
/// `None` when the channel is closed and empty (shutdown).
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    drain(rx, policy.max_batch, policy.max_wait)
}

/// The continuous former: collect up to `max_batch_rows` requests (or
/// until `max_wait` expires after the first), then concatenate each
/// item's activation row — `row(item)` — into one shared M-plane. The
/// concatenation is the only copy; everything downstream shares the
/// `Arc`. Returns `None` when the channel is closed and empty
/// (shutdown). Each item contributes exactly one row, so `rows ==
/// items.len()` and a deadline flush yields a partial (but never empty)
/// plane.
pub fn form_merged_batch<T>(
    rx: &Receiver<T>,
    policy: &BatchPolicy,
    row: impl Fn(&T) -> &[i8],
) -> Option<MergedBatch<T>> {
    let items = drain(rx, policy.max_batch_rows.max(1), policy.max_wait)?;
    let rows = items.len();
    let mut plane = Vec::with_capacity(items.iter().map(|it| row(it).len()).sum());
    for it in &items {
        plane.extend_from_slice(row(it));
    }
    Some(MergedBatch { items, plane: plane.into(), rows })
}

/// Shared drain loop: block for the first item, then greedily collect
/// until `cap` items or the deadline.
fn drain<T>(rx: &Receiver<T>, cap: usize, max_wait: Duration) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + max_wait;
    while batch.len() < cap {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn drains_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy =
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5), ..Default::default() };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
    }

    #[test]
    fn deadline_caps_waiting() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy =
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10), ..Default::default() };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_empty_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn shutdown_flushes_pending() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = next_batch(&rx, &BatchPolicy::default()).unwrap();
        assert_eq!(b, vec![7]);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn merged_plane_is_row_concatenation_in_item_order() {
        let (tx, rx) = channel::<Vec<i8>>();
        tx.send(vec![1, -1, 0]).unwrap();
        tx.send(vec![0, 1, 1]).unwrap();
        tx.send(vec![-1, -1, -1]).unwrap();
        drop(tx);
        let mb = form_merged_batch(&rx, &BatchPolicy::default(), |v| v.as_slice()).unwrap();
        assert_eq!(mb.rows, 3);
        assert_eq!(mb.items.len(), 3);
        assert_eq!(&mb.plane[..], &[1, -1, 0, 0, 1, 1, -1, -1, -1]);
        assert!(form_merged_batch(&rx, &BatchPolicy::default(), |v| v.as_slice()).is_none());
    }

    #[test]
    fn merged_batch_respects_max_batch_rows_not_max_batch() {
        let (tx, rx) = channel::<Vec<i8>>();
        for i in 0..10i8 {
            tx.send(vec![i]).unwrap();
        }
        // max_batch (the PJRT cap) must not constrain the merged former.
        let policy = BatchPolicy {
            max_batch: 2,
            max_batch_rows: 4,
            max_wait: Duration::from_millis(5),
        };
        let mb = form_merged_batch(&rx, &policy, |v| v.as_slice()).unwrap();
        assert_eq!(mb.rows, 4, "exactly the row cap");
        assert_eq!(&mb.plane[..], &[0, 1, 2, 3]);
        let mb2 = form_merged_batch(&rx, &policy, |v| v.as_slice()).unwrap();
        assert_eq!(&mb2.plane[..], &[4, 5, 6, 7], "FIFO across flushes");
    }

    #[test]
    fn merged_deadline_flushes_partial_batch() {
        let (tx, rx) = channel::<Vec<i8>>();
        tx.send(vec![9]).unwrap();
        let policy = BatchPolicy {
            max_batch_rows: 64,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        };
        let t0 = Instant::now();
        let mb = form_merged_batch(&rx, &policy, |v| v.as_slice()).unwrap();
        assert_eq!(mb.rows, 1, "deadline flush is partial, never empty");
        assert!(t0.elapsed() < Duration::from_millis(500));
    }
}
