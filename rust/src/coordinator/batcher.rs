//! Dynamic batcher: greedily coalesces queued requests into PJRT-sized
//! batches under a latency deadline — the standard serving trade-off
//! (bigger batches amortize dispatch; the deadline bounds queueing delay).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Hard cap — the compiled executable's batch dimension.
    pub max_batch: usize,
    /// Max time the first request in a batch may wait for company.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Collect the next batch from `rx`. Blocks for the first item; then
/// drains up to `max_batch` items or until `max_wait` expires. Returns
/// `None` when the channel is closed and empty (shutdown).
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn drains_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
    }

    #[test]
    fn deadline_caps_waiting() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_empty_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn shutdown_flushes_pending() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = next_batch(&rx, &BatchPolicy::default()).unwrap();
        assert_eq!(b, vec![7]);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }
}
