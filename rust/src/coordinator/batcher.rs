//! Dynamic batching: coalesces queued requests under a latency deadline —
//! the standard serving trade-off (bigger batches amortize dispatch; the
//! deadline bounds queueing delay).
//!
//! Two formers share one [`BatchPolicy`]:
//!
//! - [`next_batch`] — the PJRT former: collects up to `max_batch` items
//!   (the compiled executable's fixed batch dimension) and hands them
//!   back as a `Vec` for the caller to flatten.
//! - [`form_merged_batch`] — the engine backend's *continuous* former:
//!   merges every in-flight request into one contiguous `Arc<[i8]>`
//!   M-plane (one activation row per request, M = total live rows),
//!   capped by `max_batch_rows` instead of the manifest batch. The
//!   concatenation here is the **only** copy on the merged path — the
//!   engine's zero-copy resident surface (`gemm_resident_arc`) threads
//!   the plane through every layer by reference count.
//!
//! # Admission at every layer boundary
//!
//! GEMM rows are independent, so merging any set of requests into one
//! M-plane is *always* bit-exact — each row's outputs equal its
//! single-request execution regardless of what shares the batch. That
//! holds *between layers* too: a request arriving while a merged batch
//! is mid-pipeline can be caught up through the layers it missed
//! (small-M GEMMs against the already-resident weights — no
//! re-programming, so the expensive amortization is untouched) and its
//! rows concatenated onto the in-flight plane before the next layer's
//! merged GEMM. Every layer boundary is therefore an admission point:
//! [`stage_admit_budget`] decides how many rows a boundary may admit
//! (bounded by the plane cap and by the late-admission cost model
//! below), [`drain_ready`] collects that many without ever stalling the
//! pipeline, and the server runs the catch-up and keeps the row→request
//! map per stage.
//!
//! **Late-admission cost model.** A row admitted at boundary `li` first
//! redoes `li` layers at small M — exactly the per-request work merging
//! exists to amortize — to then share the remaining `n_layers - li`
//! merged layers. The catch-up is worth paying while `li / n_layers ≤`
//! [`BatchPolicy::max_catchup_frac`]: beyond that fraction the row
//! would redo most of the network for little shared tail, so deeper
//! boundaries admit nothing and the row seeds the next flush (whose
//! deadline `max_wait` already bounds its wait). The default of 1.0
//! admits at every boundary — catch-up runs on resident arrays, so even
//! the last boundary still beats waiting a full network traversal.
//!
//! The batcher only ever sees pre-screened work: requests reach the
//! channel through the `coordinator::ingress` admission chain, so
//! malformed planes never enter a merge and, under overload, excess
//! requests are shed at the front door instead of growing the queue this
//! module drains (the queue the shed watermarks bound is exactly the
//! in-flight population these formers merge from).

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Hard cap for the PJRT former — the compiled executable's batch
    /// dimension.
    pub max_batch: usize,
    /// Hard cap on merged M-plane rows for the engine former (one row
    /// per request; independent of the manifest `batch`).
    pub max_batch_rows: usize,
    /// Max time the first request in a batch may wait for company.
    pub max_wait: Duration,
    /// Admit newly arrived rows at layer boundaries of an in-flight
    /// merged batch (the layer-pipelined path). Off = classic layer-0-
    /// only admission; mid-pipeline arrivals seed the next flush.
    pub pipeline_admission: bool,
    /// Cap on rows admitted at any *single* layer boundary (the plane
    /// total is still capped by `max_batch_rows`).
    pub max_stage_admit_rows: usize,
    /// Late-admission cost model knob: boundary `li` admits only while
    /// `li / n_layers ≤ max_catchup_frac` — the fraction of the network
    /// a late row is allowed to redo as small-M catch-up GEMMs for the
    /// privilege of sharing the remaining merged layers. 1.0 admits at
    /// every boundary; 0.0 is equivalent to `pipeline_admission: false`.
    pub max_catchup_frac: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_batch_rows: 256,
            max_wait: Duration::from_millis(2),
            pipeline_admission: true,
            max_stage_admit_rows: 256,
            max_catchup_frac: 1.0,
        }
    }
}

/// All in-flight requests merged into one contiguous activation plane:
/// `plane` is the row-major `rows × row_len` concatenation of
/// `items[i]`'s activation rows, in item order.
pub struct MergedBatch<T> {
    pub items: Vec<T>,
    pub plane: Arc<[i8]>,
    pub rows: usize,
}

/// Collect the next batch from `rx`. Blocks for the first item; then
/// drains up to `max_batch` items or until `max_wait` expires. Returns
/// `None` when the channel is closed and empty (shutdown).
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    drain(rx, policy.max_batch, policy.max_wait)
}

/// The continuous former: collect up to `max_batch_rows` requests (or
/// until `max_wait` expires after the first), then concatenate each
/// item's activation row — `row(item)` — into one shared M-plane. The
/// concatenation is the only copy; everything downstream shares the
/// `Arc`. Returns `None` when the channel is closed and empty
/// (shutdown). Each item contributes exactly one row, so `rows ==
/// items.len()` and a deadline flush yields a partial (but never empty)
/// plane.
pub fn form_merged_batch<T>(
    rx: &Receiver<T>,
    policy: &BatchPolicy,
    row: impl Fn(&T) -> &[i8],
) -> Option<MergedBatch<T>> {
    let items = drain(rx, policy.max_batch_rows.max(1), policy.max_wait)?;
    let rows = items.len();
    let mut plane = Vec::with_capacity(items.iter().map(|it| row(it).len()).sum());
    for it in &items {
        plane.extend_from_slice(row(it));
    }
    Some(MergedBatch { items, plane: plane.into(), rows })
}

/// How many rows the admission point at layer boundary `li` (the
/// boundary *entering* layer `li`; `li ≥ 1` — layer 0 is the initial
/// former's job) may admit into an in-flight plane already carrying
/// `in_flight_rows` rows of a `n_layers`-deep network. Applies the
/// late-admission cost model (see the module docs): 0 when pipelined
/// admission is off, when the boundary is deeper than
/// `max_catchup_frac` of the network, or when the plane is already at
/// `max_batch_rows`.
pub fn stage_admit_budget(
    policy: &BatchPolicy,
    li: usize,
    n_layers: usize,
    in_flight_rows: usize,
) -> usize {
    if !policy.pipeline_admission || li == 0 || li >= n_layers {
        return 0;
    }
    if (li as f64) / (n_layers as f64) > policy.max_catchup_frac {
        return 0;
    }
    policy
        .max_stage_admit_rows
        .min(policy.max_batch_rows.saturating_sub(in_flight_rows))
}

/// Collect up to `cap` already-queued items without blocking — the
/// boundary-admission drain. Unlike [`form_merged_batch`]'s deadline
/// drain this never waits: a layer boundary admits whoever is *there*
/// and moves on, so pipelined admission can only shorten latency, never
/// stall the in-flight batch.
pub fn drain_ready<T>(rx: &Receiver<T>, cap: usize) -> Vec<T> {
    let mut items = Vec::new();
    while items.len() < cap {
        match rx.try_recv() {
            Ok(item) => items.push(item),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
        }
    }
    items
}

/// Concatenate each item's activation row into one shared plane — the
/// same single-copy merge [`form_merged_batch`] performs, exposed for
/// the boundary-admission path (late rows merge into their own catch-up
/// plane first, then join the in-flight plane via [`concat_planes`]).
pub fn merge_rows<T>(items: &[T], row: impl Fn(&T) -> &[i8]) -> Arc<[i8]> {
    let mut plane = Vec::with_capacity(items.iter().map(|it| row(it).len()).sum());
    for it in items {
        plane.extend_from_slice(row(it));
    }
    plane.into()
}

/// Row-major concatenation of two same-width planes: the in-flight rows
/// followed by the caught-up late rows. Item order and plane row order
/// stay aligned, so the scatter path needs no per-row index map beyond
/// the ordered item list.
pub fn concat_planes(resident: &[i8], late: &[i8]) -> Arc<[i8]> {
    let mut plane = Vec::with_capacity(resident.len() + late.len());
    plane.extend_from_slice(resident);
    plane.extend_from_slice(late);
    plane.into()
}

/// Shared drain loop: block for the first item, then greedily collect
/// until `cap` items or the deadline.
fn drain<T>(rx: &Receiver<T>, cap: usize, max_wait: Duration) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + max_wait;
    while batch.len() < cap {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn drains_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy =
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5), ..Default::default() };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
    }

    #[test]
    fn deadline_caps_waiting() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy =
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10), ..Default::default() };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_empty_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn shutdown_flushes_pending() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = next_batch(&rx, &BatchPolicy::default()).unwrap();
        assert_eq!(b, vec![7]);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn merged_plane_is_row_concatenation_in_item_order() {
        let (tx, rx) = channel::<Vec<i8>>();
        tx.send(vec![1, -1, 0]).unwrap();
        tx.send(vec![0, 1, 1]).unwrap();
        tx.send(vec![-1, -1, -1]).unwrap();
        drop(tx);
        let mb = form_merged_batch(&rx, &BatchPolicy::default(), |v| v.as_slice()).unwrap();
        assert_eq!(mb.rows, 3);
        assert_eq!(mb.items.len(), 3);
        assert_eq!(&mb.plane[..], &[1, -1, 0, 0, 1, 1, -1, -1, -1]);
        assert!(form_merged_batch(&rx, &BatchPolicy::default(), |v| v.as_slice()).is_none());
    }

    #[test]
    fn merged_batch_respects_max_batch_rows_not_max_batch() {
        let (tx, rx) = channel::<Vec<i8>>();
        for i in 0..10i8 {
            tx.send(vec![i]).unwrap();
        }
        // max_batch (the PJRT cap) must not constrain the merged former.
        let policy = BatchPolicy {
            max_batch: 2,
            max_batch_rows: 4,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        };
        let mb = form_merged_batch(&rx, &policy, |v| v.as_slice()).unwrap();
        assert_eq!(mb.rows, 4, "exactly the row cap");
        assert_eq!(&mb.plane[..], &[0, 1, 2, 3]);
        let mb2 = form_merged_batch(&rx, &policy, |v| v.as_slice()).unwrap();
        assert_eq!(&mb2.plane[..], &[4, 5, 6, 7], "FIFO across flushes");
    }

    #[test]
    fn stage_budget_respects_caps_and_catchup_frac() {
        let policy = BatchPolicy {
            max_batch_rows: 8,
            max_stage_admit_rows: 3,
            max_catchup_frac: 0.5,
            ..Default::default()
        };
        // Boundary 1 of 4 (25% catch-up): admits up to the stage cap.
        assert_eq!(stage_admit_budget(&policy, 1, 4, 0), 3);
        // Plane headroom tightens the budget below the stage cap.
        assert_eq!(stage_admit_budget(&policy, 1, 4, 6), 2);
        assert_eq!(stage_admit_budget(&policy, 1, 4, 8), 0, "plane already full");
        // Boundary 2 of 4 is exactly at the 0.5 fraction: still admits.
        assert_eq!(stage_admit_budget(&policy, 2, 4, 0), 3);
        // Boundary 3 of 4 (75% catch-up) exceeds the allowed fraction.
        assert_eq!(stage_admit_budget(&policy, 3, 4, 0), 0);
        // Layer 0 belongs to the initial former, never stage admission;
        // past-the-end boundaries admit nothing.
        assert_eq!(stage_admit_budget(&policy, 0, 4, 0), 0);
        assert_eq!(stage_admit_budget(&policy, 4, 4, 0), 0);
    }

    #[test]
    fn stage_budget_is_zero_when_pipelining_is_off() {
        let policy = BatchPolicy { pipeline_admission: false, ..Default::default() };
        for li in 0..4 {
            assert_eq!(stage_admit_budget(&policy, li, 4, 0), 0);
        }
        // max_catchup_frac = 0.0 is the same switch spelled differently.
        let frac_zero = BatchPolicy { max_catchup_frac: 0.0, ..Default::default() };
        assert_eq!(stage_admit_budget(&frac_zero, 1, 4, 0), 0);
    }

    #[test]
    fn default_policy_admits_at_every_interior_boundary() {
        let policy = BatchPolicy::default();
        for li in 1..4 {
            assert!(
                stage_admit_budget(&policy, li, 4, 1) > 0,
                "default must admit at boundary {li}"
            );
        }
    }

    #[test]
    fn drain_ready_never_blocks_and_respects_cap() {
        let (tx, rx) = channel::<u32>();
        assert!(drain_ready(&rx, 4).is_empty(), "empty queue admits nobody");
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        assert_eq!(drain_ready(&rx, 4), vec![0, 1, 2, 3]);
        assert_eq!(drain_ready(&rx, 4), vec![4, 5], "FIFO remainder");
        drop(tx);
        assert!(drain_ready(&rx, 4).is_empty(), "closed channel admits nobody");
    }

    #[test]
    fn merge_and_concat_preserve_row_order() {
        let late = [vec![1i8, -1], vec![0i8, 1]];
        let late_plane = merge_rows(&late, |v| v.as_slice());
        assert_eq!(&late_plane[..], &[1, -1, 0, 1]);
        let joined = concat_planes(&[7, 7, 8, 8], &late_plane);
        assert_eq!(&joined[..], &[7, 7, 8, 8, 1, -1, 0, 1], "in-flight rows first");
    }

    #[test]
    fn merged_deadline_flushes_partial_batch() {
        let (tx, rx) = channel::<Vec<i8>>();
        tx.send(vec![9]).unwrap();
        let policy = BatchPolicy {
            max_batch_rows: 64,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        };
        let t0 = Instant::now();
        let mb = form_merged_batch(&rx, &policy, |v| v.as_slice()).unwrap();
        assert_eq!(mb.rows, 1, "deadline flush is partial, never empty");
        assert!(t0.elapsed() < Duration::from_millis(500));
    }
}
