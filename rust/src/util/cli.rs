//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const FLAG_SET: &str = "true";

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), FLAG_SET.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_args() {
        let a = parse(&["figures", "--fig4", "--seed", "7", "--tech=sram"]);
        assert_eq!(a.subcommand(), Some("figures"));
        assert!(a.has("fig4"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert_eq!(a.get("tech"), Some("sram"));
    }

    #[test]
    fn key_value_pairs_not_greedy_on_flags() {
        let a = parse(&["--all", "--out", "file.txt"]);
        assert_eq!(a.get("all"), Some(FLAG_SET));
        assert_eq!(a.get("out"), Some("file.txt"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("n", 5), 5);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.subcommand(), None);
    }
}
