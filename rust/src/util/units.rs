//! SI-unit formatting and the unit conventions used across the simulator.
//!
//! Internal convention (documented once, used everywhere):
//! - time:     seconds (f64)
//! - energy:   joules (f64)
//! - power:    watts
//! - voltage:  volts
//! - current:  amperes
//! - capacitance: farads
//! - resistance:  ohms
//! - area:     square metres (helpers exist for F² at a given node pitch)

/// Format a value with an SI prefix, e.g. `1.3e-9 s` -> `"1.30 ns"`.
pub fn si(value: f64, unit: &str) -> String {
    if value == 0.0 {
        return format!("0 {unit}");
    }
    let neg = value < 0.0;
    let v = value.abs();
    const PREFIXES: &[(f64, &str)] = &[
        (1e12, "T"),
        (1e9, "G"),
        (1e6, "M"),
        (1e3, "k"),
        (1.0, ""),
        (1e-3, "m"),
        (1e-6, "µ"),
        (1e-9, "n"),
        (1e-12, "p"),
        (1e-15, "f"),
        (1e-18, "a"),
    ];
    for &(scale, prefix) in PREFIXES {
        if v >= scale {
            let x = v / scale;
            let s = if x >= 100.0 {
                format!("{x:.0}")
            } else if x >= 10.0 {
                format!("{x:.1}")
            } else {
                format!("{x:.2}")
            };
            return format!("{}{s} {prefix}{unit}", if neg { "-" } else { "" });
        }
    }
    format!("{value:.3e} {unit}")
}

/// Convenience wrappers for the common quantities.
pub fn fmt_time(seconds: f64) -> String {
    si(seconds, "s")
}
pub fn fmt_energy(joules: f64) -> String {
    si(joules, "J")
}
pub fn fmt_power(watts: f64) -> String {
    si(watts, "W")
}
pub fn fmt_cap(farads: f64) -> String {
    si(farads, "F")
}
pub fn fmt_volt(volts: f64) -> String {
    si(volts, "V")
}
pub fn fmt_amp(amps: f64) -> String {
    si(amps, "A")
}

/// Format a ratio like `6.74` as `"6.74X"`.
pub fn fmt_x(r: f64) -> String {
    format!("{r:.2}X")
}

/// Format a fraction like `0.88` as `"88%"`.
pub fn fmt_pct(f: f64) -> String {
    format!("{:.0}%", f * 100.0)
}

/// Area helpers: technology feature size `f_m` (metres per F). Cell areas
/// in the layout model are computed in F² then converted.
pub fn f2_to_m2(area_f2: f64, f_m: f64) -> f64 {
    area_f2 * f_m * f_m
}

/// Bytes with binary prefixes (for VMEM footprint reporting).
pub fn fmt_bytes(bytes: f64) -> String {
    const P: &[(f64, &str)] = &[(1024.0 * 1024.0 * 1024.0, "GiB"), (1024.0 * 1024.0, "MiB"), (1024.0, "KiB")];
    for &(s, p) in P {
        if bytes >= s {
            return format!("{:.2} {p}", bytes / s);
        }
    }
    format!("{bytes:.0} B")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_picks_prefix() {
        assert_eq!(si(1.3e-9, "s"), "1.30 ns");
        assert_eq!(si(2.5e-12, "J"), "2.50 pJ");
        assert_eq!(si(1e6, "Hz"), "1.00 MHz");
        assert_eq!(si(0.04, "V"), "40.0 mV");
    }

    #[test]
    fn si_zero_and_negative() {
        assert_eq!(si(0.0, "s"), "0 s");
        assert_eq!(si(-3.0e-3, "A"), "-3.00 mA");
    }

    #[test]
    fn ratio_and_pct() {
        assert_eq!(fmt_x(6.743), "6.74X");
        assert_eq!(fmt_pct(0.88), "88%");
    }

    #[test]
    fn f2_conversion() {
        // 100 F² at 45 nm = 100 * (45e-9)^2
        let a = f2_to_m2(100.0, 45e-9);
        assert!((a - 100.0 * 45e-9 * 45e-9).abs() < 1e-24);
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(4.0 * 1024.0 * 1024.0), "4.00 MiB");
    }
}
