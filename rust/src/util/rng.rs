//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`; everything stochastic in the
//! simulator (Monte-Carlo variation analysis, synthetic workloads,
//! property tests) runs on this small, reproducible generator instead.
//!
//! `SplitMix64` seeds `Xoshiro256**`, the same construction the `rand`
//! ecosystem recommends. All simulators take explicit seeds so runs are
//! replayable (`--seed` on the CLI).

/// SplitMix64 — used for seeding and for cheap one-off streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is fine for sim use.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; variation MC is not the hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// A random signed-ternary value with `P(0) = p_zero`, remaining mass
    /// split evenly between −1 and +1 (models DNN weight/input sparsity).
    #[inline]
    pub fn ternary(&mut self, p_zero: f64) -> i8 {
        let u = self.f64();
        if u < p_zero {
            0
        } else if u < p_zero + (1.0 - p_zero) / 2.0 {
            1
        } else {
            -1
        }
    }

    /// Fill a slice with sparse ternary values.
    pub fn ternary_vec(&mut self, n: usize, p_zero: f64) -> Vec<i8> {
        (0..n).map(|_| self.ternary(p_zero)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments_roughly_correct() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn ternary_sparsity_matches() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let v = r.ternary_vec(n, 0.4);
        let zeros = v.iter().filter(|&&x| x == 0).count() as f64 / n as f64;
        assert!((zeros - 0.4).abs() < 0.02, "zeros={zeros}");
        let pos = v.iter().filter(|&&x| x == 1).count();
        let neg = v.iter().filter(|&&x| x == -1).count();
        let ratio = pos as f64 / neg as f64;
        assert!((ratio - 1.0).abs() < 0.1, "pos/neg={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_independent() {
        let mut a = Rng::new(13);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
