//! Hand-rolled micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are built with `harness = false` and drive this.
//! The harness warms up, then runs timed batches until a target wall time
//! or iteration count is reached, and reports mean/σ/min plus derived
//! throughput. Results are also appended to `bench_results.json` style
//! output if requested by the caller.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats;
use super::units::fmt_time;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1000),
            min_iters: 10,
            max_iters: 10_000_000,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 { 1.0 / self.mean_s } else { 0.0 }
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (σ {:>10}, min {:>10})  {:>14.1} iters/s",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
            self.per_sec()
        )
    }
}

/// Benchmark a closure. The closure should return a value, which is
/// black-boxed to prevent the optimizer from deleting the work.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        black_box(f());
    }
    // Calibrate batch size so one batch is ~1ms (keeps timer overhead low).
    let t0 = Instant::now();
    black_box(f());
    let single = t0.elapsed().as_secs_f64().max(1e-9);
    let batch = ((1e-3 / single).ceil() as u64).clamp(1, 10_000);

    let mut samples = Vec::new();
    let mut iters = 0u64;
    let measure_start = Instant::now();
    while measure_start.elapsed() < cfg.measure && iters < cfg.max_iters {
        let t = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        let dt = t.elapsed().as_secs_f64() / batch as f64;
        samples.push(dt);
        iters += batch;
        if iters >= cfg.min_iters && samples.len() >= 200 && measure_start.elapsed() > cfg.measure / 2 {
            break;
        }
    }
    let s = stats::summarize(&samples);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean,
        std_s: s.std,
        min_s: s.min,
        p50_s: s.p50,
    }
}

/// Run and print. Returns the result for further aggregation.
pub fn run<T>(name: &str, cfg: &BenchConfig, f: impl FnMut() -> T) -> BenchResult {
    let r = bench(name, cfg, f);
    println!("{}", r.report_line());
    r
}

/// Fast config for CI-style smoke runs (`SITECIM_BENCH_FAST=1`).
pub fn config_from_env() -> BenchConfig {
    if std::env::var("SITECIM_BENCH_FAST").is_ok() {
        BenchConfig {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            ..Default::default()
        }
    } else {
        BenchConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            ..Default::default()
        };
        let r = bench("noop-sum", &cfg, || (0..100u64).sum::<u64>());
        assert!(r.iters > 0);
        assert!(r.mean_s > 0.0);
        assert!(r.mean_s < 1e-3);
    }
}
