//! Small statistics helpers used by the metric collectors, the benchmark
//! harness and the Monte-Carlo analyses.

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Compute summary statistics. Empty input yields a zeroed summary.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
        p99: percentile_sorted(&sorted, 99.0),
    }
}

/// Percentile over a pre-sorted slice (nearest-rank with linear interp).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (ignores non-positive entries, which would be model bugs).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Relative error |a-b| / |b|.
pub fn rel_err(measured: f64, reference: f64) -> f64 {
    if reference == 0.0 {
        return measured.abs();
    }
    (measured - reference).abs() / reference.abs()
}

/// True if `measured` is within `tol` relative tolerance of `reference`.
pub fn within(measured: f64, reference: f64, tol: f64) -> bool {
    rel_err(measured, reference) <= tol
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }

    #[test]
    fn within_tolerance() {
        assert!(within(1.05, 1.0, 0.06));
        assert!(!within(1.2, 1.0, 0.1));
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let s = summarize(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
        // Welford uses sample variance (n-1); summarize uses population (n).
        let pop_var = o.m2 / o.n as f64;
        assert!((pop_var.sqrt() - s.std).abs() < 1e-12);
    }
}
