//! Minimal JSON parser/writer.
//!
//! The artifact manifest written by `python/compile/aot.py` is JSON; the
//! offline crate set has no serde, so this module implements the small
//! subset we need: objects, arrays, strings, numbers, booleans, null, with
//! standard escapes. It is a strict recursive-descent parser with position
//! information in errors.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj["a"]["b"][2]`-style path access, `/`-separated; numeric
    /// components index arrays.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('/') {
            cur = match cur {
                Json::Obj(o) => o.get(part)?,
                Json::Arr(a) => a.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Serialize (stable key order thanks to BTreeMap).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path("a/2/b").unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(Json::parse("\"µJ\"").unwrap(), Json::Str("µJ".into()));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arrays": 32, "name": "sitecim", "shapes": [[256, 256], [16, 16]]}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }
}
