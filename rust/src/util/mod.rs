//! Shared utilities: deterministic RNG, statistics, unit formatting,
//! table rendering, a minimal JSON codec, SHA-256 (artifact checksums),
//! CLI parsing, a property-test driver and a micro-benchmark harness.
//!
//! Everything here exists because the offline crate registry only carries
//! the `xla` dependency tree — see DESIGN.md §2 for the constraint note.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod table;
pub mod units;
