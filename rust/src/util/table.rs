//! ASCII table renderer for the reproduction harness.
//!
//! Every figure/table reproduction prints through this so the output is
//! uniform and easy to diff against EXPERIMENTS.md.

/// A simple left-aligned-first-column table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.notes.push(n.into());
        self
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let sep: String = widths.iter().map(|w| format!("+{}", "-".repeat(w + 2))).collect::<String>() + "+\n";
        out.push_str(&sep);
        out.push('|');
        for (i, h) in self.header.iter().enumerate() {
            out.push_str(&format!(" {:<width$} |", h, width = widths[i]));
        }
        out.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push('|');
            for i in 0..ncols {
                let c = row.get(i).map(String::as_str).unwrap_or("");
                let pad = widths[i].saturating_sub(c.chars().count());
                if i == 0 {
                    out.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
                } else {
                    out.push_str(&format!(" {}{} |", " ".repeat(pad), c));
                }
            }
            out.push('\n');
        }
        out.push_str(&sep);
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

/// Render a simple two-column "series" (x, y) block — used for figure-style
/// outputs like Fig 4(c)'s RBL-voltage-vs-discharges curve.
pub fn series(title: &str, xlabel: &str, ylabel: &str, pts: &[(f64, f64)]) -> String {
    let mut t = Table::new(title).header(&[xlabel, ylabel]);
    for &(x, y) in pts {
        t.row(&[format!("{x}"), format!("{y:.4}")]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T").header(&["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["longer", "23"]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("| a      |"));
        assert!(r.contains("| longer |"));
        // right-aligned numeric column
        assert!(r.contains("|     1 |"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("T").header(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn series_block() {
        let s = series("fig", "n", "v", &[(1.0, 0.95), (2.0, 0.9)]);
        assert!(s.contains("fig"));
        assert!(s.contains("0.9500"));
    }
}
