//! Hand-rolled property-testing driver (no proptest offline).
//!
//! `check` runs a closure over `n` generated cases from a seeded RNG and,
//! on failure, retries with a simple input-shrinking loop when the
//! generator supports it (we shrink by re-generating with smaller size
//! hints, which is what matters for vector-shaped inputs).

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to the generator (e.g. vector length).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: 0xC1A0_5EED, max_size: 64 }
    }
}

/// Outcome of a single case.
pub type CaseResult = Result<(), String>;

/// Run a property: `gen` builds an input of roughly the given size,
/// `prop` checks it. Panics with a reproducible report on failure.
pub fn check<T: std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> CaseResult,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        // Ramp sizes up so early failures are small.
        let size = 1 + (cfg.max_size * case) / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Shrink: try progressively smaller sizes from a derived stream.
            let mut best: (usize, T, String) = (size, input, msg);
            let mut srng = Rng::new(cfg.seed ^ 0xDEAD_BEEF);
            let mut s = size;
            while s > 1 {
                s /= 2;
                for _ in 0..16 {
                    let cand = gen(&mut srng, s);
                    if let Err(m) = prop(&cand) {
                        best = (s, cand, m);
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  input (size {}): {:?}\n  error: {}",
                cfg.seed, best.0, best.1, best.2
            );
        }
    }
}

/// Convenience: assert two f64 are close.
pub fn close(a: f64, b: f64, tol: f64) -> CaseResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

/// Convenience: assert equality with a message.
pub fn eq<T: PartialEq + std::fmt::Debug>(a: T, b: T) -> CaseResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{a:?} != {b:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            &Config { cases: 50, ..Default::default() },
            |r, size| r.ternary_vec(size, 0.3),
            |v| {
                count += 1;
                if v.iter().all(|&x| (-1..=1).contains(&(x as i32))) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_report() {
        check(
            &Config { cases: 20, ..Default::default() },
            |r, size| r.ternary_vec(size.max(4), 0.0),
            |v| if v.len() < 3 { Ok(()) } else { Err("too long".into()) },
        );
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1.0, 2.0, 1e-9).is_err());
    }
}
