//! DNN workload layer: layer/GEMM descriptors, the paper's five benchmark
//! networks, and ternary quantization helpers.

pub mod benchmarks;
pub mod layer;
pub mod lower;
pub mod ternary;

pub use layer::{ConvGeom, Gemm, Layer, LayerKind, Network, RecurrentSpec};
