//! Ternary quantization helpers (TWN-style) used when loading real
//! float weights into the simulated arrays, plus sparsity measurement.
//!
//! Quantization rule (Li et al., Ternary Weight Networks): threshold
//! Δ = 0.7·E|w|; w → sign(w)·1[|w| > Δ]. The python training pipeline
//! uses the same rule with a straight-through estimator; this module is
//! the runtime-side equivalent for weights arriving as f32.

use super::super::array::encoding::Trit;

/// TWN threshold factor.
pub const TWN_DELTA_FACTOR: f64 = 0.7;

/// Ternarize a float tensor with the TWN rule.
pub fn ternarize(w: &[f32]) -> Vec<Trit> {
    if w.is_empty() {
        return Vec::new();
    }
    let mean_abs = w.iter().map(|x| x.abs() as f64).sum::<f64>() / w.len() as f64;
    let delta = (TWN_DELTA_FACTOR * mean_abs) as f32;
    w.iter()
        .map(|&x| {
            if x > delta {
                1
            } else if x < -delta {
                -1
            } else {
                0
            }
        })
        .collect()
}

/// Scaling factor α = E[|w| : |w| > Δ] that accompanies TWN ternarization
/// (applied in the digital periphery after the CiM dot product).
pub fn twn_scale(w: &[f32]) -> f32 {
    if w.is_empty() {
        return 1.0;
    }
    let mean_abs = w.iter().map(|x| x.abs() as f64).sum::<f64>() / w.len() as f64;
    let delta = TWN_DELTA_FACTOR * mean_abs;
    let over: Vec<f64> = w.iter().map(|x| x.abs() as f64).filter(|&a| a > delta).collect();
    if over.is_empty() {
        1.0
    } else {
        (over.iter().sum::<f64>() / over.len() as f64) as f32
    }
}

/// Fraction of zeros in a trit tensor.
pub fn sparsity(t: &[Trit]) -> f64 {
    if t.is_empty() {
        return 0.0;
    }
    t.iter().filter(|&&x| x == 0).count() as f64 / t.len() as f64
}

/// Ternarize activations with a fixed threshold (used for input
/// ternarization at inference: x → sign(x)·1[|x| > θ]).
pub fn ternarize_acts(x: &[f32], theta: f32) -> Vec<Trit> {
    x.iter()
        .map(|&v| {
            if v > theta {
                1
            } else if v < -theta {
                -1
            } else {
                0
            }
        })
        .collect()
}

/// The same rule for integer pre-activations — the hidden-layer step of
/// the functional MLP forward pass (engine serving backend and the
/// e2e_inference example share this).
pub fn ternarize_acts_i32(y: &[i32], theta: f64) -> Vec<Trit> {
    y.iter()
        .map(|&v| {
            let v = v as f64;
            if v > theta {
                1
            } else if v < -theta {
                -1
            } else {
                0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ternarize_thresholds_correctly() {
        // mean|w| = 0.5, Δ = 0.35.
        let w = [0.9f32, -0.9, 0.3, -0.3, 0.5, -0.1, 0.4, 0.6];
        let t = ternarize(&w);
        assert_eq!(t, vec![1, -1, 0, 0, 1, 0, 1, 1]);
    }

    #[test]
    fn scale_is_mean_of_survivors() {
        let w = [1.0f32, -1.0, 0.0, 0.0];
        // mean|w| = 0.5, Δ = 0.35; survivors = {1, 1} → α = 1.
        assert!((twn_scale(&w) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn typical_gaussian_weights_are_half_sparse() {
        // For Gaussian weights the TWN rule zeroes ~50% (|w| < 0.7·E|w|
        // ⇔ |z| < 0.7·sqrt(2/π) ≈ 0.56 → P ≈ 0.43).
        let mut rng = crate::util::rng::Rng::new(77);
        let w: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
        let s = sparsity(&ternarize(&w));
        assert!((s - 0.43).abs() < 0.03, "sparsity = {s}");
    }

    #[test]
    fn empty_inputs_are_fine() {
        assert!(ternarize(&[]).is_empty());
        assert_eq!(twn_scale(&[]), 1.0);
        assert_eq!(sparsity(&[]), 0.0);
    }

    #[test]
    fn act_ternarization_symmetric() {
        let t = ternarize_acts(&[0.5, -0.5, 0.05, -0.05], 0.1);
        assert_eq!(t, vec![1, -1, 0, 0]);
    }
}
