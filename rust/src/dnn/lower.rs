//! Workload lowering: how convolutions and recurrent cells actually run
//! on the tiled ternary GEMM engine.
//!
//! Convolutions lower via **im2col**: each output-plane position becomes
//! one M-row whose K entries are the kernel-window patch gathered from a
//! real activation plane ([`im2col_plane`]). The lowered GEMM is checked
//! two independent ways: [`conv_ref_direct`] re-derives every operand in
//! convolution coordinates (window-centric gather straight from the
//! image, never touching the im2col plane) while composing per tile with
//! the same `dot_ref` flavor semantics as [`reference_gemm`], and
//! [`conv_ref_naive`] is the plain integer convolution the exact
//! (near-memory) flavor must equal outright.
//!
//! Recurrent cells run **step by step**: the gate GEMM executes once per
//! time step against *resident* weights (registered once, hit from the
//! tile cache on every later step), with the hidden state threaded
//! `h_t → h_{t+1}` through a deterministic ternarization of the cell
//! output ([`cell_update`]). The surrogate cell keeps LSTM/GRU dataflow
//! (gate partitioning, cell-state carry, update/reset gating) with
//! sign-threshold nonlinearities so the whole trace stays exact integer
//! math — reproducible across designs, thread counts, and runs.

use std::sync::Arc;

use crate::array::encoding::Trit;
use crate::array::mac::{dot_exact, dot_ref, Flavor};
use crate::array::TernaryStorage;
use crate::dnn::layer::{ConvGeom, RecurrentSpec};
use crate::engine::resident::WeightId;
use crate::engine::tiling::{extract_tile_weights, reference_gemm, TileGrid};
use crate::engine::TernaryGemmEngine;

/// Gather the first `m_run` kernel-window patches of `image` into a
/// row-major `m_run × patch_k` im2col plane, ready to be the M-plane of
/// a GEMM. `image` is channel-major (`c · in_hw² + y · in_hw + x`); row
/// `wi` is output position `(wi / out_hw, wi % out_hw)` and column
/// `(c · ksize + kr) · ksize + kc` is that window's tap, with padding
/// taps (coordinates off the plane) contributing zero.
pub fn im2col_plane(image: &[Trit], g: &ConvGeom, m_run: usize) -> Arc<[Trit]> {
    assert_eq!(image.len(), g.cin * g.in_hw * g.in_hw, "image must be cin×in_hw²");
    let out_hw = g.out_hw();
    assert!(m_run <= out_hw * out_hw, "m_run exceeds the output plane");
    let k = g.patch_k();
    let mut plane = vec![0 as Trit; m_run * k];
    for wi in 0..m_run {
        let (oy, ox) = (wi / out_hw, wi % out_hw);
        let row = &mut plane[wi * k..(wi + 1) * k];
        for c in 0..g.cin {
            for kr in 0..g.ksize {
                let iy = (oy * g.stride + kr) as isize - g.pad as isize;
                if iy < 0 || iy >= g.in_hw as isize {
                    continue; // whole kernel row is padding
                }
                for kc in 0..g.ksize {
                    let ix = (ox * g.stride + kc) as isize - g.pad as isize;
                    if ix < 0 || ix >= g.in_hw as isize {
                        continue;
                    }
                    row[(c * g.ksize + kr) * g.ksize + kc] =
                        image[c * g.in_hw * g.in_hw + iy as usize * g.in_hw + ix as usize];
                }
            }
        }
    }
    Arc::from(plane)
}

/// Plain integer direct convolution over the first `m_run` output
/// positions: `out[wi · cout + co] = Σ_taps image[tap] · w[tap][co]`,
/// exact i32 accumulation, no tiling, no saturation. The engine's
/// near-memory (exact-flavor) output must equal this outright.
pub fn conv_ref_naive(image: &[Trit], w: &[Trit], g: &ConvGeom, m_run: usize) -> Vec<i32> {
    assert_eq!(image.len(), g.cin * g.in_hw * g.in_hw);
    assert_eq!(w.len(), g.patch_k() * g.cout);
    let out_hw = g.out_hw();
    let mut out = vec![0i32; m_run * g.cout];
    for wi in 0..m_run {
        let (oy, ox) = (wi / out_hw, wi % out_hw);
        for c in 0..g.cin {
            for kr in 0..g.ksize {
                let iy = (oy * g.stride + kr) as isize - g.pad as isize;
                if iy < 0 || iy >= g.in_hw as isize {
                    continue;
                }
                for kc in 0..g.ksize {
                    let ix = (ox * g.stride + kc) as isize - g.pad as isize;
                    if ix < 0 || ix >= g.in_hw as isize {
                        continue;
                    }
                    let a = image[c * g.in_hw * g.in_hw + iy as usize * g.in_hw + ix as usize];
                    if a == 0 {
                        continue;
                    }
                    let tap = (c * g.ksize + kr) * g.ksize + kc;
                    for co in 0..g.cout {
                        out[wi * g.cout + co] += a as i32 * w[tap * g.cout + co] as i32;
                    }
                }
            }
        }
    }
    out
}

/// Direct-convolution reference with the *engine's* tile composition:
/// per tile of `grid`, the K-slice of each window patch is gathered
/// straight from `image` in convolution coordinates (never via an
/// im2col plane) and evaluated with `dot_ref` (or the exact MAC when
/// `flavor` is `None`), partial sums recombined exactly as
/// [`reference_gemm`] does. Bit-equal to
/// `reference_gemm(im2col_plane(...), ...)` if and only if the im2col
/// gather and the conv-coordinate gather agree on every tap — the
/// conformance check for the lowering itself, saturation included.
pub fn conv_ref_direct(
    image: &[Trit],
    w: &[Trit],
    g: &ConvGeom,
    m_run: usize,
    grid: &TileGrid,
    flavor: Option<Flavor>,
) -> Vec<i32> {
    assert_eq!(image.len(), g.cin * g.in_hw * g.in_hw);
    assert_eq!(grid.k, g.patch_k());
    assert_eq!(grid.n, g.cout);
    assert_eq!(w.len(), grid.k * grid.n);
    let out_hw = g.out_hw();
    let (rows, cols) = (grid.rows, grid.cols);
    let mut out = vec![0i32; m_run * grid.n];
    let mut wbuf = vec![0 as Trit; rows * cols];
    let mut xbuf = vec![0 as Trit; rows];
    for tile in grid.tiles() {
        extract_tile_weights(w, grid.k, grid.n, &tile, rows, cols, &mut wbuf);
        let mut storage = TernaryStorage::new(rows, cols);
        storage.write_matrix(&wbuf);
        for wi in 0..m_run {
            let (oy, ox) = (wi / out_hw, wi % out_hw);
            xbuf.fill(0);
            // Gather this tile's K-slice of the patch in conv coords:
            // absolute patch index kk ↦ (channel, kernel row, kernel col).
            for (slot, kk) in (tile.k0..tile.k0 + tile.k_len).enumerate() {
                let kc = kk % g.ksize;
                let kr = (kk / g.ksize) % g.ksize;
                let c = kk / (g.ksize * g.ksize);
                let iy = (oy * g.stride + kr) as isize - g.pad as isize;
                let ix = (ox * g.stride + kc) as isize - g.pad as isize;
                if iy < 0 || iy >= g.in_hw as isize || ix < 0 || ix >= g.in_hw as isize {
                    continue;
                }
                xbuf[slot] =
                    image[c * g.in_hw * g.in_hw + iy as usize * g.in_hw + ix as usize];
            }
            let partial: Vec<i32> = match flavor {
                Some(f) => dot_ref(&storage, &xbuf, f),
                None => dot_exact(&storage, &xbuf).into_iter().map(|v| v as i32).collect(),
            };
            let dst = &mut out[wi * grid.n + tile.n0..wi * grid.n + tile.n0 + tile.n_len];
            for (d, s) in dst.iter_mut().zip(&partial[..tile.n_len]) {
                *d += s;
            }
        }
    }
    out
}

/// Deterministic ternarization threshold for recurrent state: half the
/// standard deviation of a K-long ternary dot product at ~50% operand
/// density (`√k / 2`, floored at 1 so ±1 pre-activations never all
/// saturate on tiny cells). Matches the TWN-style `0.7·E|x|` intent
/// while staying a pure function of the layer shape.
pub fn cell_theta(k: usize) -> f64 {
    ((k as f64).sqrt() / 2.0).max(1.0)
}

fn tern(v: i32, theta: f64) -> Trit {
    if v as f64 > theta {
        1
    } else if (v as f64) < -theta {
        -1
    } else {
        0
    }
}

/// One recurrent state update from the gate pre-activations of a step.
///
/// Gate columns are laid out `[gate0 · hidden | gate1 · hidden | ...]`
/// (the order the per-step GEMM produces). The cell is a deterministic
/// ternary surrogate that preserves the real cells' dataflow:
///
/// * **LSTM** (gates `i, f, g, o`): `c_t = clamp(f̂·c + î·ĝ, −1, 1)`,
///   `h_t = ô·c_t` — forget-gated carry plus input-gated candidate,
///   output-gated exposure.
/// * **GRU** (gates `z, r, n`): `h_t = h` where the update gate fires
///   (`ẑ ≠ 0`), else `n̂·|r̂|` — update-gated carry vs reset-gated
///   candidate.
///
/// where `x̂ = tern(x, theta)`. Returns the new hidden state; `cell` is
/// the carried LSTM cell state (ignored and left untouched for 3-gate
/// cells).
pub fn cell_update(
    spec: &RecurrentSpec,
    gates: &[i32],
    h: &mut [Trit],
    cell: &mut [Trit],
    theta: f64,
) {
    assert_eq!(gates.len(), spec.gates * spec.hidden);
    assert_eq!(h.len(), spec.hidden);
    let hid = spec.hidden;
    if spec.gates == 4 {
        assert_eq!(cell.len(), hid);
        for j in 0..hid {
            let (i_g, f_g, g_g, o_g) = (
                tern(gates[j], theta),
                tern(gates[hid + j], theta),
                tern(gates[2 * hid + j], theta),
                tern(gates[3 * hid + j], theta),
            );
            let c = (f_g as i32 * cell[j] as i32 + i_g as i32 * g_g as i32).clamp(-1, 1);
            cell[j] = c as Trit;
            h[j] = (o_g as i32 * c) as Trit;
        }
    } else {
        for j in 0..hid {
            let (z_g, r_g, n_g) = (
                tern(gates[j], theta),
                tern(gates[hid + j], theta),
                tern(gates[2 * hid + j], theta),
            );
            if z_g == 0 {
                h[j] = (n_g as i32 * (r_g as i32).abs()) as Trit;
            }
            // z_g ≠ 0: carry h[j] unchanged.
        }
    }
}

/// Serial single-threaded reference for a stepped recurrent layer:
/// `h_0 = 0`, per step `z_t = [x_t ; h_{t−1}]` runs through
/// [`reference_gemm`] (m = 1) and [`cell_update`] threads the state.
/// Returns the per-step gate pre-activations — the values the engine's
/// resident stepped execution must reproduce bit-for-bit.
pub fn reference_recurrent_trace(
    xs: &[Trit],
    w: &[Trit],
    spec: &RecurrentSpec,
    grid: &TileGrid,
    flavor: Option<Flavor>,
    steps_run: usize,
) -> Vec<Vec<i32>> {
    assert_eq!(xs.len(), spec.steps * spec.input, "xs must be steps×input");
    assert!(steps_run <= spec.steps);
    let k = spec.input + spec.hidden;
    let theta = cell_theta(k);
    let mut h = vec![0 as Trit; spec.hidden];
    let mut cell = vec![0 as Trit; spec.hidden];
    let mut trace = Vec::with_capacity(steps_run);
    let mut z = vec![0 as Trit; k];
    for t in 0..steps_run {
        z[..spec.input].copy_from_slice(&xs[t * spec.input..(t + 1) * spec.input]);
        z[spec.input..].copy_from_slice(&h);
        let y = reference_gemm(&z, w, 1, grid, flavor);
        cell_update(spec, &y, &mut h, &mut cell, theta);
        trace.push(y);
    }
    trace
}

/// Execute a stepped recurrent layer on the engine against resident
/// weights: the gate GEMM runs once per step via `gemm_resident_arc`
/// (every step after the first hits the tile cache), hidden state
/// threaded exactly as [`reference_recurrent_trace`] does. Returns the
/// per-step gate pre-activations.
pub fn run_recurrent_resident(
    engine: &TernaryGemmEngine,
    id: WeightId,
    xs: &[Trit],
    spec: &RecurrentSpec,
    steps_run: usize,
) -> Vec<Vec<i32>> {
    assert_eq!(xs.len(), spec.steps * spec.input, "xs must be steps×input");
    assert!(steps_run <= spec.steps);
    let k = spec.input + spec.hidden;
    let theta = cell_theta(k);
    let mut h = vec![0 as Trit; spec.hidden];
    let mut cell = vec![0 as Trit; spec.hidden];
    let mut trace = Vec::with_capacity(steps_run);
    let mut z = vec![0 as Trit; k];
    for t in 0..steps_run {
        z[..spec.input].copy_from_slice(&xs[t * spec.input..(t + 1) * spec.input]);
        z[spec.input..].copy_from_slice(&h);
        let y = engine
            .gemm_resident_arc(id, Arc::from(&z[..]), 1)
            .expect("recurrent step shapes are valid");
        cell_update(spec, &y, &mut h, &mut cell, theta);
        trace.push(y);
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::Layer;
    use crate::util::rng::Rng;

    #[test]
    fn im2col_identity_conv_is_the_image() {
        // 1×1 kernel, stride 1, no padding: the plane is the image with
        // rows in scan order.
        let g = ConvGeom { in_hw: 4, ksize: 1, stride: 1, pad: 0, cin: 2, cout: 3 };
        let mut rng = Rng::new(1);
        let image = rng.ternary_vec(2 * 16, 0.4);
        let plane = im2col_plane(&image, &g, 16);
        for wi in 0..16 {
            assert_eq!(plane[wi * 2], image[wi]);
            assert_eq!(plane[wi * 2 + 1], image[16 + wi]);
        }
    }

    #[test]
    fn im2col_padding_taps_are_zero() {
        // 3×3 same-padded on a 3×3 plane: window 0 (corner) has its
        // first row and column of taps off-plane.
        let g = ConvGeom { in_hw: 3, ksize: 3, stride: 1, pad: 1, cin: 1, cout: 1 };
        let image: Vec<Trit> = vec![1; 9];
        let plane = im2col_plane(&image, &g, 9);
        let w0 = &plane[..9];
        // Taps (kr=0, *) and (kc=0, *) of the corner window are padding.
        assert_eq!(w0, &[0, 0, 0, 0, 1, 1, 0, 1, 1]);
        // Center window sees the full plane.
        assert_eq!(&plane[4 * 9..5 * 9], &[1; 9]);
    }

    #[test]
    fn naive_conv_equals_exact_im2col_gemm() {
        let g = ConvGeom { in_hw: 8, ksize: 3, stride: 2, pad: 1, cin: 3, cout: 5 };
        let m = g.out_hw() * g.out_hw();
        let mut rng = Rng::new(7);
        let image = rng.ternary_vec(3 * 64, 0.5);
        let w = rng.ternary_vec(g.patch_k() * g.cout, 0.5);
        let grid = TileGrid::new(g.patch_k(), g.cout, 16, 8);
        let via_plane = reference_gemm(&im2col_plane(&image, &g, m), &w, m, &grid, None);
        assert_eq!(conv_ref_naive(&image, &w, &g, m), via_plane);
        assert_eq!(conv_ref_direct(&image, &w, &g, m, &grid, None), via_plane);
    }

    #[test]
    fn direct_reference_matches_plane_reference_with_saturation() {
        let g = ConvGeom { in_hw: 6, ksize: 5, stride: 1, pad: 2, cin: 2, cout: 4 };
        let m = g.out_hw() * g.out_hw();
        let mut rng = Rng::new(11);
        let image = rng.ternary_vec(2 * 36, 0.3);
        let w = rng.ternary_vec(g.patch_k() * g.cout, 0.3);
        let grid = TileGrid::new(g.patch_k(), g.cout, 16, 4);
        for flavor in [Some(Flavor::Cim1), Some(Flavor::Cim2)] {
            let plane = im2col_plane(&image, &g, m);
            assert_eq!(
                conv_ref_direct(&image, &w, &g, m, &grid, flavor),
                reference_gemm(&plane, &w, m, &grid, flavor),
                "{flavor:?}"
            );
        }
    }

    #[test]
    fn lstm_cell_gates_behave() {
        let spec = RecurrentSpec { steps: 1, input: 4, hidden: 2, gates: 4 };
        let mut h = vec![0 as Trit; 2];
        let mut c = vec![1 as Trit, -1];
        // θ=1: gate fires above |1|. Unit j=0: i=+, f=0, g=+, o=+ →
        // c=clamp(0+1)=1, h=1. Unit j=1: all gates quiet → c, h decay
        // to 0.
        let gates = vec![2, 0, /* i */ 0, 0, /* f */ 2, 0, /* g */ 2, 0 /* o */];
        cell_update(&spec, &gates, &mut h, &mut c, 1.0);
        assert_eq!(c, vec![1, 0]);
        assert_eq!(h, vec![1, 0]);
    }

    #[test]
    fn gru_update_gate_carries_state() {
        let spec = RecurrentSpec { steps: 1, input: 4, hidden: 2, gates: 3 };
        let mut h = vec![1 as Trit, 1];
        let mut c = Vec::new();
        // j=0: z fires → carry h=1. j=1: z quiet, r fires, n negative →
        // h = −1.
        let gates = vec![2, 0, /* z */ 0, 2, /* r */ 0, -2 /* n */];
        cell_update(&spec, &gates, &mut h, &mut c, 1.0);
        assert_eq!(h, vec![1, -1]);
    }

    #[test]
    fn recurrent_trace_threads_hidden_state() {
        // With a fixed input, the trace must differ from the h≡0
        // restart after the state first moves — i.e. hidden state is
        // genuinely threaded between steps.
        let l = Layer::recurrent("r", 6, 32, 16, 4);
        let spec = l.rnn.unwrap();
        let mut rng = Rng::new(3);
        let xs = rng.ternary_vec(spec.steps * spec.input, 0.2);
        let w = rng.ternary_vec(l.gemm.k * l.gemm.n, 0.2);
        let grid = TileGrid::new(l.gemm.k, l.gemm.n, 16, 16);
        let trace = reference_recurrent_trace(&xs, &w, &spec, &grid, None, spec.steps);
        assert_eq!(trace.len(), spec.steps);
        // Restarting each step with h = 0 must diverge somewhere (the
        // state-carry term is live).
        let stateless: Vec<Vec<i32>> = (0..spec.steps)
            .map(|t| {
                let mut z = vec![0 as Trit; l.gemm.k];
                z[..spec.input].copy_from_slice(&xs[t * spec.input..(t + 1) * spec.input]);
                reference_gemm(&z, &w, 1, &grid, None)
            })
            .collect();
        assert_ne!(trace, stateless);
        // But step 0 (h starts at 0) is identical by construction.
        assert_eq!(trace[0], stateless[0]);
    }

    #[test]
    fn theta_floors_at_one() {
        assert_eq!(cell_theta(1), 1.0);
        assert!(cell_theta(1300) > 17.0);
    }
}
