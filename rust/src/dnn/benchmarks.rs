//! The paper's five DNN benchmarks (§VI: AlexNet, ResNet34, Inception,
//! LSTM, GRU) as ternary GEMM workloads.
//!
//! Shapes are the standard published architectures (ImageNet-scale CNNs,
//! Penn-Treebank-scale RNNs). Ternary sparsity assumptions follow the
//! TWN/TiM-DNN line of work: ~50% of ternary weights are zero and ~45–55%
//! of activations are zero after ternarization, varying slightly by layer
//! type (first conv layers see denser activations).

use super::layer::{Layer, Network};

/// AlexNet (5 conv + 3 FC).
pub fn alexnet() -> Network {
    let layers = vec![
        Layer::conv2d("conv1", 227, 3, 11, 4, 0, 96).with_sparsity(0.7, 0.5),
        Layer::conv2d("conv2", 27, 96, 5, 1, 2, 256),
        Layer::conv2d("conv3", 13, 256, 3, 1, 1, 384),
        Layer::conv2d("conv4", 13, 384, 3, 1, 1, 384),
        Layer::conv2d("conv5", 13, 384, 3, 1, 1, 256),
        Layer::linear("fc6", 1, 9216, 4096),
        Layer::linear("fc7", 1, 4096, 4096),
        Layer::linear("fc8", 1, 4096, 1000),
    ];
    Network { name: "AlexNet".into(), layers }
}

/// ResNet-34 (grouped by stage; basic blocks = two 3×3 convs each).
pub fn resnet34() -> Network {
    let mut layers = vec![Layer::conv2d("conv1", 224, 3, 7, 2, 3, 64).with_sparsity(0.7, 0.5)];
    // (stage output size, channels, #basic blocks)
    let stages = [(56usize, 64usize, 3usize), (28, 128, 4), (14, 256, 6), (7, 512, 3)];
    let mut cin = 64;
    for (si, (hw, ch, blocks)) in stages.into_iter().enumerate() {
        for b in 0..blocks {
            let in_ch = if b == 0 { cin } else { ch };
            layers.push(Layer::conv2d(&format!("s{}b{}_conv1", si + 2, b), hw, in_ch, 3, 1, 1, ch));
            layers.push(Layer::conv2d(&format!("s{}b{}_conv2", si + 2, b), hw, ch, 3, 1, 1, ch));
            if b == 0 && in_ch != ch {
                layers
                    .push(Layer::conv2d(&format!("s{}b{}_down", si + 2, b), hw, in_ch, 1, 1, 0, ch));
            }
        }
        cin = ch;
    }
    layers.push(Layer::linear("fc", 1, 512, 1000));
    Network { name: "ResNet34".into(), layers }
}

/// Inception (GoogLeNet-style): stem + representative inception blocks.
pub fn inception() -> Network {
    let mut layers = vec![
        Layer::conv2d("stem_conv1", 224, 3, 7, 2, 3, 64).with_sparsity(0.7, 0.5),
        Layer::conv2d("stem_conv2", 56, 64, 1, 1, 0, 64),
        Layer::conv2d("stem_conv3", 56, 64, 3, 1, 1, 192),
    ];
    // Each inception block: 1×1, 3×3 (with reduce), 5×5 (with reduce),
    // pool-proj. (hw, cin, [b1, b3r, b3, b5r, b5, pp])
    let blocks: [(usize, usize, [usize; 6]); 9] = [
        (28, 192, [64, 96, 128, 16, 32, 32]),
        (28, 256, [128, 128, 192, 32, 96, 64]),
        (14, 480, [192, 96, 208, 16, 48, 64]),
        (14, 512, [160, 112, 224, 24, 64, 64]),
        (14, 512, [128, 128, 256, 24, 64, 64]),
        (14, 512, [112, 144, 288, 32, 64, 64]),
        (14, 528, [256, 160, 320, 32, 128, 128]),
        (7, 832, [256, 160, 320, 32, 128, 128]),
        (7, 832, [384, 192, 384, 48, 128, 128]),
    ];
    for (i, (hw, cin, b)) in blocks.into_iter().enumerate() {
        let tag = format!("inc{}", i + 3);
        layers.push(Layer::conv2d(&format!("{tag}_1x1"), hw, cin, 1, 1, 0, b[0]));
        layers.push(Layer::conv2d(&format!("{tag}_3x3r"), hw, cin, 1, 1, 0, b[1]));
        layers.push(Layer::conv2d(&format!("{tag}_3x3"), hw, b[1], 3, 1, 1, b[2]));
        layers.push(Layer::conv2d(&format!("{tag}_5x5r"), hw, cin, 1, 1, 0, b[3]));
        layers.push(Layer::conv2d(&format!("{tag}_5x5"), hw, b[3], 5, 1, 2, b[4]));
        layers.push(Layer::conv2d(&format!("{tag}_pp"), hw, cin, 1, 1, 0, b[5]));
    }
    layers.push(Layer::linear("fc", 1, 1024, 1000));
    Network { name: "Inception".into(), layers }
}

/// 2-layer LSTM language model (PTB-scale: embed 650, hidden 650,
/// 35-step unroll — Zaremba et al. medium config, the standard ternary-RNN
/// benchmark).
pub fn lstm() -> Network {
    let layers = vec![
        Layer::recurrent("lstm1", 35, 650, 650, 4),
        Layer::recurrent("lstm2", 35, 650, 650, 4),
        Layer::linear("proj", 35, 650, 10000).with_sparsity(0.5, 0.5),
    ];
    Network { name: "LSTM".into(), layers }
}

/// 2-layer GRU language model (same scale; 3 gates).
pub fn gru() -> Network {
    let layers = vec![
        Layer::recurrent("gru1", 35, 650, 650, 3),
        Layer::recurrent("gru2", 35, 650, 650, 3),
        Layer::linear("proj", 35, 650, 10000).with_sparsity(0.5, 0.5),
    ];
    Network { name: "GRU".into(), layers }
}

/// The paper's benchmark suite, in its Figure 12/13 order.
pub fn suite() -> Vec<Network> {
    vec![alexnet(), resnet34(), inception(), lstm(), gru()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_five_benchmarks() {
        let s = suite();
        assert_eq!(s.len(), 5);
        let names: Vec<&str> = s.iter().map(|n| n.name.as_str()).collect();
        assert_eq!(names, ["AlexNet", "ResNet34", "Inception", "LSTM", "GRU"]);
    }

    #[test]
    fn alexnet_mac_count_is_canonical() {
        // AlexNet ≈ 0.7–1.2 GMACs (ours has no grouping → upper range).
        let m = alexnet().total_macs() as f64;
        assert!(m > 0.6e9 && m < 1.5e9, "AlexNet MACs = {m:.3e}");
    }

    #[test]
    fn resnet34_macs_in_range() {
        // ResNet-34 ≈ 3.6 GMACs.
        let m = resnet34().total_macs() as f64;
        assert!(m > 2.5e9 && m < 4.5e9, "ResNet34 MACs = {m:.3e}");
    }

    #[test]
    fn inception_macs_in_range() {
        // GoogLeNet ≈ 1.5 GMACs.
        let m = inception().total_macs() as f64;
        assert!(m > 0.8e9 && m < 2.5e9, "Inception MACs = {m:.3e}");
    }

    #[test]
    fn rnn_weight_reuse_across_steps() {
        let l = lstm();
        // Weights fit in a few M words even though MACs are ~0.8 G.
        assert!(l.total_weight_words() < 15_000_000);
        assert!(l.total_macs() > 0.3e9 as u64);
    }

    #[test]
    fn every_suite_layer_carries_executable_lowering_metadata() {
        // Every conv layer's spatial geometry must fold back to exactly
        // the GEMM shape the mapper sees, and every recurrent layer's
        // spec must match its per-step GEMM — the functional lowering
        // path (dnn::lower) relies on this.
        for net in suite() {
            for l in &net.layers {
                if let Some(g) = l.conv {
                    assert_eq!(g.out_hw() * g.out_hw(), l.gemm.m, "{}/{}", net.name, l.name);
                    assert_eq!(g.patch_k(), l.gemm.k, "{}/{}", net.name, l.name);
                    assert_eq!(g.cout, l.gemm.n, "{}/{}", net.name, l.name);
                }
                if let Some(s) = l.rnn {
                    assert_eq!(s.input + s.hidden, l.gemm.k, "{}/{}", net.name, l.name);
                    assert_eq!(s.gates * s.hidden, l.gemm.n, "{}/{}", net.name, l.name);
                    assert_eq!(s.steps, l.repeats, "{}/{}", net.name, l.name);
                }
            }
        }
    }

    #[test]
    fn all_sparsities_are_probabilities() {
        for net in suite() {
            for l in &net.layers {
                assert!((0.0..=1.0).contains(&l.act_nz), "{}", l.name);
                assert!((0.0..=1.0).contains(&l.w_nz), "{}", l.name);
            }
        }
    }
}
