//! DNN layer descriptors. Every layer the accelerator executes reduces to
//! one or more ternary GEMMs (im2col for convolutions, per-gate matmuls
//! for recurrent cells); the system-level analysis only needs the GEMM
//! shapes, how often they run, and the operand sparsity.

/// Layer kind (for reporting; the mapper only sees the GEMM view).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Linear,
    Recurrent,
}

/// One GEMM workload: `m` input vectors (rows of activations), reduction
/// dimension `k`, `n` output channels.
#[derive(Clone, Debug)]
pub struct Gemm {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl Gemm {
    /// Multiply-accumulate operations.
    pub fn macs(&self) -> u64 {
        (self.m as u64) * (self.k as u64) * (self.n as u64)
    }
}

/// Spatial geometry of a convolution, kept alongside the folded GEMM view
/// so the functional engine can lower the layer via real im2col (patch
/// extraction from an activation plane) rather than a flat random GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvGeom {
    /// Input plane height = width (square planes throughout the suite).
    pub in_hw: usize,
    /// Square kernel size.
    pub ksize: usize,
    /// Stride (same in both dimensions).
    pub stride: usize,
    /// Zero padding on every edge.
    pub pad: usize,
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
}

impl ConvGeom {
    /// Output plane height = width.
    pub fn out_hw(&self) -> usize {
        (self.in_hw + 2 * self.pad - self.ksize) / self.stride + 1
    }

    /// im2col reduction dimension: one column per (channel, kernel row,
    /// kernel col) tap.
    pub fn patch_k(&self) -> usize {
        self.cin * self.ksize * self.ksize
    }
}

/// Step structure of a recurrent cell, kept alongside the per-step GEMM
/// view so the functional engine can thread hidden state `h_t → h_{t+1}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecurrentSpec {
    /// Time steps per inference (weights are shared across steps).
    pub steps: usize,
    /// Input feature width per step.
    pub input: usize,
    /// Hidden state width.
    pub hidden: usize,
    /// Gate count (4 for LSTM, 3 for GRU).
    pub gates: usize,
}

/// A network layer as the accelerator sees it.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub gemm: Gemm,
    /// How many times this GEMM executes per inference (e.g. recurrent
    /// time steps share weights; conv is already folded into `m`).
    pub repeats: usize,
    /// Probability an activation is non-zero (ternary input sparsity).
    pub act_nz: f64,
    /// Probability a weight is non-zero (ternary weight sparsity).
    pub w_nz: f64,
    /// Spatial geometry when this layer is a convolution; `None` for
    /// layers constructed without it (the GEMM view is still complete).
    pub conv: Option<ConvGeom>,
    /// Step structure when this layer is a recurrent cell.
    pub rnn: Option<RecurrentSpec>,
}

impl Layer {
    /// A convolution with explicit spatial geometry. The folded GEMM is
    /// `m = out_hw²`, `k = cin·ksize²`, `n = cout`.
    pub fn conv2d(
        name: &str,
        in_hw: usize,
        cin: usize,
        ksize: usize,
        stride: usize,
        pad: usize,
        cout: usize,
    ) -> Layer {
        let geom = ConvGeom { in_hw, ksize, stride, pad, cin, cout };
        let out_hw = geom.out_hw();
        Layer {
            name: name.to_string(),
            kind: LayerKind::Conv,
            gemm: Gemm { m: out_hw * out_hw, k: geom.patch_k(), n: cout },
            repeats: 1,
            act_nz: 0.5,
            w_nz: 0.5,
            conv: Some(geom),
            rnn: None,
        }
    }

    /// Back-compat conv constructor from the folded output size. A valid
    /// stride-1 / pad-0 geometry is synthesized (`in_hw = out_hw + ksize
    /// − 1`), so the layer is always executable via im2col even when the
    /// caller only specified the GEMM fold.
    pub fn conv(name: &str, out_hw: usize, cin: usize, ksize: usize, cout: usize) -> Layer {
        Layer::conv2d(name, out_hw + ksize - 1, cin, ksize, 1, 0, cout)
    }

    pub fn linear(name: &str, m: usize, k: usize, n: usize) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Linear,
            gemm: Gemm { m, k, n },
            repeats: 1,
            act_nz: 0.5,
            w_nz: 0.5,
            conv: None,
            rnn: None,
        }
    }

    /// A recurrent cell step: `gates`·hidden output columns, executed
    /// `steps` times per inference.
    pub fn recurrent(name: &str, steps: usize, input: usize, hidden: usize, gates: usize) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Recurrent,
            gemm: Gemm { m: 1, k: input + hidden, n: gates * hidden },
            repeats: steps,
            act_nz: 0.5,
            w_nz: 0.5,
            conv: None,
            rnn: Some(RecurrentSpec { steps, input, hidden, gates }),
        }
    }

    /// Total MACs per inference.
    pub fn macs(&self) -> u64 {
        self.gemm.macs() * self.repeats as u64
    }

    /// Ternary weight words this layer stores.
    pub fn weight_words(&self) -> u64 {
        (self.gemm.k as u64) * (self.gemm.n as u64)
    }

    /// Builder-style sparsity override.
    pub fn with_sparsity(mut self, act_nz: f64, w_nz: f64) -> Layer {
        self.act_nz = act_nz;
        self.w_nz = w_nz;
        self
    }
}

/// A benchmark network: an ordered set of layers.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    pub fn total_weight_words(&self) -> u64 {
        self.layers.iter().map(Layer::weight_words).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_folds_to_gemm() {
        let l = Layer::conv("c", 55, 3, 11, 96);
        assert_eq!(l.gemm.m, 3025);
        assert_eq!(l.gemm.k, 363);
        assert_eq!(l.gemm.n, 96);
        assert_eq!(l.macs(), 3025 * 363 * 96);
        // The synthesized geometry reproduces the folded output plane.
        let g = l.conv.unwrap();
        assert_eq!(g.out_hw(), 55);
        assert_eq!(g.patch_k(), 363);
    }

    #[test]
    fn conv2d_geometry_folds_with_stride_and_pad() {
        // AlexNet conv1: 227×227×3, 11×11 stride 4 pad 0 → 55×55×96.
        let l = Layer::conv2d("c1", 227, 3, 11, 4, 0, 96);
        assert_eq!(l.gemm.m, 3025);
        assert_eq!(l.gemm.k, 363);
        assert_eq!(l.gemm.n, 96);
        // ResNet stem: 224×224×3, 7×7 stride 2 pad 3 → 112×112×64.
        let l = Layer::conv2d("stem", 224, 3, 7, 2, 3, 64);
        assert_eq!(l.conv.unwrap().out_hw(), 112);
        assert_eq!(l.gemm.m, 112 * 112);
        // Same-padded 3×3 keeps the plane size.
        let l = Layer::conv2d("b", 14, 256, 3, 1, 1, 512);
        assert_eq!(l.conv.unwrap().out_hw(), 14);
    }

    #[test]
    fn recurrent_carries_spec() {
        let l = Layer::recurrent("lstm", 35, 650, 650, 4);
        let s = l.rnn.unwrap();
        assert_eq!(s.steps, 35);
        assert_eq!(s.input + s.hidden, l.gemm.k);
        assert_eq!(s.gates * s.hidden, l.gemm.n);
    }

    #[test]
    fn recurrent_repeats_share_weights() {
        let l = Layer::recurrent("lstm", 25, 256, 512, 4);
        assert_eq!(l.gemm.k, 768);
        assert_eq!(l.gemm.n, 2048);
        assert_eq!(l.macs(), 25 * 768 * 2048);
        assert_eq!(l.weight_words(), 768 * 2048);
    }

    #[test]
    fn network_totals() {
        let net = Network {
            name: "toy".into(),
            layers: vec![Layer::linear("a", 1, 64, 64), Layer::linear("b", 1, 64, 10)],
        };
        assert_eq!(net.total_macs(), 64 * 64 + 64 * 10);
        assert_eq!(net.total_weight_words(), 64 * 64 + 64 * 10);
    }
}
