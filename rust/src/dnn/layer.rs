//! DNN layer descriptors. Every layer the accelerator executes reduces to
//! one or more ternary GEMMs (im2col for convolutions, per-gate matmuls
//! for recurrent cells); the system-level analysis only needs the GEMM
//! shapes, how often they run, and the operand sparsity.

/// Layer kind (for reporting; the mapper only sees the GEMM view).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Linear,
    Recurrent,
}

/// One GEMM workload: `m` input vectors (rows of activations), reduction
/// dimension `k`, `n` output channels.
#[derive(Clone, Debug)]
pub struct Gemm {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl Gemm {
    /// Multiply-accumulate operations.
    pub fn macs(&self) -> u64 {
        (self.m as u64) * (self.k as u64) * (self.n as u64)
    }
}

/// A network layer as the accelerator sees it.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub gemm: Gemm,
    /// How many times this GEMM executes per inference (e.g. recurrent
    /// time steps share weights; conv is already folded into `m`).
    pub repeats: usize,
    /// Probability an activation is non-zero (ternary input sparsity).
    pub act_nz: f64,
    /// Probability a weight is non-zero (ternary weight sparsity).
    pub w_nz: f64,
}

impl Layer {
    pub fn conv(name: &str, out_hw: usize, cin: usize, ksize: usize, cout: usize) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Conv,
            gemm: Gemm { m: out_hw * out_hw, k: cin * ksize * ksize, n: cout },
            repeats: 1,
            act_nz: 0.5,
            w_nz: 0.5,
        }
    }

    pub fn linear(name: &str, m: usize, k: usize, n: usize) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Linear,
            gemm: Gemm { m, k, n },
            repeats: 1,
            act_nz: 0.5,
            w_nz: 0.5,
        }
    }

    /// A recurrent cell step: `gates`·hidden output columns, executed
    /// `steps` times per inference.
    pub fn recurrent(name: &str, steps: usize, input: usize, hidden: usize, gates: usize) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Recurrent,
            gemm: Gemm { m: 1, k: input + hidden, n: gates * hidden },
            repeats: steps,
            act_nz: 0.5,
            w_nz: 0.5,
        }
    }

    /// Total MACs per inference.
    pub fn macs(&self) -> u64 {
        self.gemm.macs() * self.repeats as u64
    }

    /// Ternary weight words this layer stores.
    pub fn weight_words(&self) -> u64 {
        (self.gemm.k as u64) * (self.gemm.n as u64)
    }

    /// Builder-style sparsity override.
    pub fn with_sparsity(mut self, act_nz: f64, w_nz: f64) -> Layer {
        self.act_nz = act_nz;
        self.w_nz = w_nz;
        self
    }
}

/// A benchmark network: an ordered set of layers.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    pub fn total_weight_words(&self) -> u64 {
        self.layers.iter().map(Layer::weight_words).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_folds_to_gemm() {
        let l = Layer::conv("c", 55, 3, 11, 96);
        assert_eq!(l.gemm.m, 3025);
        assert_eq!(l.gemm.k, 363);
        assert_eq!(l.gemm.n, 96);
        assert_eq!(l.macs(), 3025 * 363 * 96);
    }

    #[test]
    fn recurrent_repeats_share_weights() {
        let l = Layer::recurrent("lstm", 25, 256, 512, 4);
        assert_eq!(l.gemm.k, 768);
        assert_eq!(l.gemm.n, 2048);
        assert_eq!(l.macs(), 25 * 768 * 2048);
        assert_eq!(l.weight_words(), 768 * 2048);
    }

    #[test]
    fn network_totals() {
        let net = Network {
            name: "toy".into(),
            layers: vec![Layer::linear("a", 1, 64, 64), Layer::linear("b", 1, 64, 10)],
        };
        assert_eq!(net.total_macs(), 64 * 64 + 64 * 10);
        assert_eq!(net.total_weight_words(), 64 * 64 + 64 * 10);
    }
}
