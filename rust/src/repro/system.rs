//! System-level reproductions: Fig 12 (SiTe CiM I) and Fig 13 (SiTe CiM
//! II) — normalized execution time and energy vs the iso-capacity and
//! iso-area near-memory baselines over the five-benchmark suite — plus
//! the functional engine co-simulation cross-check.

use crate::arch::{AccelConfig, Accelerator, CosimConfig};
use crate::array::area::Design;
use crate::device::Tech;
use crate::dnn::benchmarks;
use crate::util::stats::mean;
use crate::util::table::Table;
use crate::util::units::fmt_x;

/// Paper-reported average speedups/energy for annotation.
struct PaperAvgs {
    speed_isoc: [f64; 3],
    speed_isoa: [f64; 3],
    energy: [f64; 3],
}

fn system_fig(design: Design, title: &str, paper: &PaperAvgs) -> String {
    let nets = benchmarks::suite();
    let mut out = String::new();
    for (ti, tech) in Tech::ALL.iter().enumerate() {
        let cim = Accelerator::new(AccelConfig::sitecim(*tech, design));
        let isoc = Accelerator::new(AccelConfig::iso_capacity_nm(*tech));
        let isoa = Accelerator::new(AccelConfig::iso_area_nm(*tech, design));
        let mut t = Table::new(format!("{title} — {}", tech.name()))
            .header(&["benchmark", "speedup iso-cap", "speedup iso-area", "energy red."]);
        let mut s_c = Vec::new();
        let mut s_a = Vec::new();
        let mut e_r = Vec::new();
        for net in &nets {
            let rc = cim.run(net);
            let r_isoc = isoc.run(net);
            let r_isoa = isoa.run(net);
            let sc = rc.speedup_vs(&r_isoc);
            let sa = rc.speedup_vs(&r_isoa);
            let er = rc.energy_reduction_vs(&r_isoc);
            s_c.push(sc);
            s_a.push(sa);
            e_r.push(er);
            t.row(&[net.name.clone(), fmt_x(sc), fmt_x(sa), fmt_x(er)]);
        }
        t.row(&[
            "AVG (paper)".into(),
            format!("{} ({})", fmt_x(mean(&s_c)), fmt_x(paper.speed_isoc[ti])),
            format!("{} ({})", fmt_x(mean(&s_a)), fmt_x(paper.speed_isoa[ti])),
            format!("{} ({})", fmt_x(mean(&e_r)), fmt_x(paper.energy[ti])),
        ]);
        t.note(format!(
            "iso-area baseline uses {} NM arrays (area-model derived)",
            isoa.cfg.n_arrays
        ));
        t.note(
            "write charges use the analytic bounded-residency model: over-capacity \
             networks re-program (W−C+1)/W of their rows per inference (second-chance \
             steady state), not the full streaming worst case",
        );
        out.push_str(&t.render());
    }
    out
}

/// Fig 12: SiTe CiM I system-level vs NM baselines.
pub fn fig12() -> String {
    system_fig(
        Design::Cim1,
        "Fig 12 — SiTe CiM I system level",
        &PaperAvgs {
            speed_isoc: [6.74, 6.59, 7.12],
            speed_isoa: [5.41, 4.63, 5.00],
            energy: [2.46, 2.52, 2.54],
        },
    )
}

/// Fig 13: SiTe CiM II system-level vs NM baselines.
pub fn fig13() -> String {
    system_fig(
        Design::Cim2,
        "Fig 13 — SiTe CiM II system level",
        &PaperAvgs {
            speed_isoc: [4.90, 4.78, 5.06],
            speed_isoa: [4.21, 3.85, 3.99],
            energy: [2.12, 2.14, 2.14],
        },
    )
}

/// Functional co-simulation: the tiled GEMM engine executes a bounded
/// slice of *all five* suite networks on every design's array fabric —
/// in streaming mode (every tile re-programmed each pass) and in
/// resident mode (tiles placed once, later passes hit the resident tile
/// cache). Conv layers run on true im2col planes (cross-checked against
/// the direct-convolution reference), recurrent layers run step by step
/// with the hidden state threaded through the ternary cell update
/// (cross-checked against the serial stepped reference), and the
/// engine's tile/window/write-row counters are checked against
/// `arch::mapper` accounting — including per-step RNN charges. No paper
/// figure corresponds — this validates that the system the analytic
/// model *accounts for* actually computes (and caches) correctly.
pub fn engine_cosim() -> String {
    engine_cosim_status().0
}

/// [`engine_cosim`] plus a machine-checkable verdict: `true` only when
/// every network × design × mode combination is bit-exact *and* its
/// counters equal the mapper accounting. `figures --cosim` exits nonzero
/// on `false`, so CI asserts the exit code instead of grepping the
/// rendered table.
pub fn engine_cosim_status() -> (String, bool) {
    let nets = benchmarks::suite();
    let mut ok = true;
    let mut detail = Vec::new();
    let mut t =
        Table::new("Engine co-simulation — five-network suite, ≤5 layers each, 2 passes").header(
            &[
                "network",
                "design",
                "mode",
                "outputs checked",
                "mismatches",
                "tiles prog.",
                "MAC windows",
                "cache h/m/e",
                "truncated",
                "accounting",
            ],
        );
    for net in &nets {
        for design in Design::ALL {
            let accel = match design {
                Design::NearMemory => {
                    Accelerator::new(AccelConfig::iso_capacity_nm(Tech::Femfet3T))
                }
                d => Accelerator::new(AccelConfig::sitecim(Tech::Femfet3T, d)),
            };
            for resident in [false, true] {
                let ccfg = CosimConfig {
                    max_vectors: 1,
                    max_layers: 5,
                    max_steps: 3,
                    n_threads: 4,
                    resident,
                    repeats: 2,
                    ..Default::default()
                };
                let r = accel.run_cosim(net, &ccfg);
                ok &= r.all_match() && r.accounting_matches();
                t.row(&[
                    net.name.clone(),
                    design.name().to_string(),
                    if resident { "resident" } else { "streaming" }.to_string(),
                    r.total_outputs().to_string(),
                    r.total_mismatches().to_string(),
                    r.engine.tiles.to_string(),
                    r.engine.windows.to_string(),
                    format!("{}/{}/{}", r.engine.hits, r.engine.misses, r.engine.evictions),
                    format!("{}/{}", r.truncated_layers(), r.layers.len()),
                    if r.accounting_matches() { "OK" } else { "MISMATCH" }.to_string(),
                ]);
                if matches!(design, Design::Cim1) && resident {
                    detail.push(r);
                }
            }
        }
    }
    t.note(
        "engine outputs must be bit-identical to the reference composition over tiles \
         (0 mismatches): conv layers execute true im2col planes cross-checked against the \
         direct-convolution reference, recurrent layers execute step by step against the \
         serial stepped-cell reference; counters must equal arch::mapper accounting, \
         including per-step RNN charges",
    );
    t.note(
        "truncated = layers whose executed slice is bounded below the full workload \
         (1 vector of the conv output plane, 3 of the RNN unroll steps) — bounds are \
         reported, never hidden",
    );
    let mut out = t.render();

    let mut d = Table::new("Co-simulated slice per layer — SiTe CiM I, resident mode").header(&[
        "network",
        "layer",
        "m run/full",
        "steps run/full",
        "outputs",
        "mismatches",
    ]);
    for r in &detail {
        for l in &r.layers {
            d.row(&[
                r.network.clone(),
                l.name.clone(),
                format!("{}/{}", l.m, l.m_full),
                format!("{}/{}", l.steps, l.steps_full),
                l.outputs.to_string(),
                l.mismatches.to_string(),
            ]);
        }
    }
    d.note(
        "m = im2col windows executed of the conv output plane; \
         steps = recurrent unroll steps executed of the full sequence",
    );
    out.push_str(&d.render());
    (out, ok)
}

/// Average speedups/energy-reductions for one design (used by tests and
/// EXPERIMENTS.md generation).
pub fn averages(design: Design, tech: Tech) -> (f64, f64, f64) {
    let nets = benchmarks::suite();
    let cim = Accelerator::new(AccelConfig::sitecim(tech, design));
    let isoc = Accelerator::new(AccelConfig::iso_capacity_nm(tech));
    let isoa = Accelerator::new(AccelConfig::iso_area_nm(tech, design));
    let mut s_c = Vec::new();
    let mut s_a = Vec::new();
    let mut e_r = Vec::new();
    for net in &nets {
        let rc = cim.run(net);
        s_c.push(rc.speedup_vs(&isoc.run(net)));
        s_a.push(rc.speedup_vs(&isoa.run(net)));
        e_r.push(rc.energy_reduction_vs(&isoc.run(net)));
    }
    (mean(&s_c), mean(&s_a), mean(&e_r))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_averages_near_paper() {
        // Paper: 6.74/6.59/7.12 iso-cap speedup, 2.46-2.54X energy.
        for (ti, tech) in Tech::ALL.iter().enumerate() {
            let (sc, sa, er) = averages(Design::Cim1, *tech);
            let paper_sc = [6.74, 6.59, 7.12][ti];
            assert!(
                (sc / paper_sc - 1.0).abs() < 0.35,
                "{}: iso-cap speedup {sc:.2} vs paper {paper_sc}",
                tech.name()
            );
            assert!(sa < sc, "{}: iso-area should be harder", tech.name());
            assert!((1.8..=3.6).contains(&er), "{}: energy red {er:.2}", tech.name());
        }
    }

    #[test]
    fn fig13_lower_than_fig12() {
        for tech in Tech::ALL {
            let (sc1, _, er1) = averages(Design::Cim1, tech);
            let (sc2, _, er2) = averages(Design::Cim2, tech);
            assert!(sc2 < sc1, "{}", tech.name());
            assert!(er2 < er1, "{}", tech.name());
            // Paper: CiM II still ~4.8-5.1X faster.
            assert!(sc2 > 2.5, "{}: {sc2}", tech.name());
        }
    }

    #[test]
    fn figures_render() {
        assert!(fig12().contains("AlexNet"));
        assert!(fig13().contains("GRU"));
    }

    #[test]
    fn cosim_table_renders_full_suite_across_designs_and_modes() {
        // Bit-level agreement itself is asserted by the arch::accel cosim
        // tests; here we check the repro surface executes every suite
        // network on every design in both execution modes with a passing
        // accounting cross-check, and reports the per-layer slice.
        let (s, ok) = engine_cosim_status();
        assert!(ok, "cosim verdict must be green when the table shows OK");
        for name in ["AlexNet", "ResNet34", "Inception", "LSTM", "GRU"] {
            assert!(s.contains(name), "suite network {name} missing from cosim table");
        }
        assert!(s.contains("SiTe CiM I"));
        assert!(s.contains("SiTe CiM II"));
        assert!(s.contains("NM baseline"));
        assert!(s.contains("streaming"));
        assert!(s.contains("resident"));
        assert!(s.contains("steps run/full"));
        assert!(s.contains("3/35"), "bounded RNN unroll must be reported honestly");
        assert!(s.contains("OK"));
        assert!(!s.contains("MISMATCH"));
    }
}
