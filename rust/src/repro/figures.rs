//! Array-level reproductions: Fig 4(c), Fig 7(c), the area table
//! (§V.1a/V.2a + Figs 8/10), Fig 9, Fig 11, the §V.3 comparison and the
//! §III.2 error-probability analysis.

use crate::array::area::{self, Design};
use crate::array::metrics::{all_designs, ArrayGeom};
use crate::array::variation;
use crate::circuit::sense_margin::{
    current_mode_margins, voltage_mode_margins, CurrentModeSetup,
};
use crate::device::{PeriphParams, Tech, TechParams};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::util::units::{fmt_energy, fmt_pct, fmt_time, fmt_x};

/// Fig 4(c): RBL voltage + sense margin vs number of discharges (CiM I,
/// voltage sensing). Paper anchors: SM(1) = 50 mV, SM(8) = 40 mV, lower
/// beyond; 3-bit ADC + extra SA → assert 16 rows, saturate at 8.
pub fn fig4() -> String {
    let pts = voltage_mode_margins(1.0, 16);
    let mut t = Table::new("Fig 4(c) — RBL voltage & sense margin vs #discharges (SiTe CiM I)")
        .header(&["n", "V_RBL (V)", "SM (mV)", "paper"]);
    for p in &pts {
        let paper = match p.n {
            1 => "50 mV",
            8 => "40 mV",
            n if n > 8 => "< 40 mV",
            _ => "-",
        };
        t.row(&[
            p.n.to_string(),
            format!("{:.3}", p.level),
            if p.margin.is_nan() { "-".into() } else { format!("{:.1}", p.margin * 1e3) },
            paper.to_string(),
        ]);
    }
    t.note("robust range ends at n = 8 → 3-bit ADC, outputs 9..16 ≈ 8 (§III.2)");
    t.render()
}

/// Fig 7(c): current-mode sense margin under BC/WC loading, outputs 0..16
/// (SiTe CiM II). Paper: SM diminishes for O > 8.
pub fn fig7() -> String {
    let p = TechParams::new(Tech::Femfet3T);
    let setup = CurrentModeSetup { n_rows_block_total: 16, c_lrbl: 1.0e-15, t_sense: 0.45e-9 };
    let pts = current_mode_margins(&p, &setup);
    let mut t = Table::new("Fig 7(c) — sense margin vs expected output (SiTe CiM II, current sensing)")
        .header(&["O", "BC level (units)", "SM (units)", "paper"]);
    for pt in &pts {
        let paper = if pt.n > 8 { "diminishing" } else { "> target" };
        t.row(&[
            pt.n.to_string(),
            format!("{:.3}", pt.level),
            if pt.margin.is_nan() { "-".into() } else { format!("{:.3}", pt.margin) },
            paper.to_string(),
        ]);
    }
    t.note("units = (I_LRS − I_HRS); BC/WC construction of Fig 7(a,b)");
    t.render()
}

/// Area table: cell overheads (§V.1a/V.2a), TiM-DNN comparison, macro
/// ratios with periphery.
pub fn area_table() -> String {
    let pp = PeriphParams::default_45nm();
    let mut t = Table::new("Area — cell & macro overheads vs NM baselines (Figs 8/10, §V)")
        .header(&["tech", "CiM I cell", "paper", "CiM II cell", "paper", "CiM I macro", "paper", "CiM II macro", "paper"]);
    let paper_cell1 = [0.18, 0.34, 0.34];
    for (i, tech) in Tech::ALL.iter().enumerate() {
        let p = TechParams::new(*tech);
        let c1 = area::cell_overhead(&p, Design::Cim1);
        let c2 = area::cell_overhead(&p, Design::Cim2);
        let m1 = area::macro_overhead_ratio(&p, &pp, Design::Cim1);
        let m2 = area::macro_overhead_ratio(&p, &pp, Design::Cim2);
        t.row(&[
            tech.name().to_string(),
            format!("+{}", fmt_pct(c1)),
            format!("+{}", fmt_pct(paper_cell1[i])),
            format!("+{}", fmt_pct(c2)),
            "+6%".into(),
            format!("{m1:.2}x"),
            "1.3-1.53x".into(),
            format!("{m2:.2}x"),
            "1.21-1.33x".into(),
        ]);
    }
    let sram = TechParams::new(Tech::Sram8T);
    let ours = area::cell_geom(&sram, Design::Cim1).area_f2();
    let red = 1.0 - ours / area::timdnn_cell_f2();
    t.note(format!(
        "SiTe CiM I SRAM cell vs TiM-DNN [20] cell: {} smaller (paper: 44%)",
        fmt_pct(red)
    ));
    t.render()
}

fn array_fig(design: Design, title: &str, paper_mac_d: [&str; 3], paper_mac_e: [&str; 3]) -> String {
    let pp = PeriphParams::default_45nm();
    let g = ArrayGeom::default();
    let mut t = Table::new(title).header(&[
        "tech",
        "CiM lat",
        "vs NM",
        "paper",
        "CiM energy",
        "vs NM",
        "paper",
        "read D/E over NM",
        "write D over NM",
    ]);
    for (i, tech) in Tech::ALL.iter().enumerate() {
        let p = TechParams::new(*tech);
        let [nm, c1, c2] = all_designs(&p, &pp, g);
        let m = if design == Design::Cim1 { c1 } else { c2 };
        let dred = 1.0 - m.mac.latency / nm.mac.latency;
        let esav = m.mac.energy_saving_vs(&nm.mac);
        t.row(&[
            tech.name().to_string(),
            fmt_time(m.mac.latency),
            format!("-{}", fmt_pct(dred)),
            paper_mac_d[i].to_string(),
            fmt_energy(m.mac.energy),
            format!("-{}", fmt_pct(esav)),
            paper_mac_e[i].to_string(),
            format!(
                "+{}/+{}",
                fmt_pct(m.read.latency / nm.read.latency - 1.0),
                fmt_pct(m.read.energy / nm.read.energy - 1.0)
            ),
            format!("+{}", fmt_pct(m.write.latency / nm.write.latency - 1.0)),
        ]);
    }
    t.note("MAC op = one 16-row window over 256 ternary columns; NM = pipelined row-by-row + NMC unit");
    t.render()
}

/// Fig 9: SiTe CiM I array-level analysis vs NM (3 technologies).
pub fn fig9() -> String {
    array_fig(
        Design::Cim1,
        "Fig 9 — SiTe CiM I array-level vs NM baseline",
        ["-88%", "-88%", "-88%"],
        ["-74%", "-78%", "-78%"],
    )
}

/// Fig 11: SiTe CiM II array-level analysis vs NM.
pub fn fig11() -> String {
    array_fig(
        Design::Cim2,
        "Fig 11 — SiTe CiM II array-level vs NM baseline",
        ["-80%", "-78%", "-84%"],
        ["-61%", "-63%", "-62%"],
    )
}

/// §V.3: SiTe CiM I vs II head-to-head.
pub fn cim1_vs_cim2() -> String {
    let pp = PeriphParams::default_45nm();
    let g = ArrayGeom::default();
    let paper = [("8T-SRAM", 1.5, 1.7, 0.10), ("3T-eDRAM", 1.7, 1.8, 0.21), ("3T-FEMFET", 1.7, 1.3, 0.21)];
    let mut t = Table::new("§V.3 — SiTe CiM I vs SiTe CiM II")
        .header(&["tech", "II/I energy", "paper", "II/I latency", "paper", "II cell saving", "paper"]);
    for (i, tech) in Tech::ALL.iter().enumerate() {
        let p = TechParams::new(*tech);
        let [_, c1, c2] = all_designs(&p, &pp, g);
        let a1 = area::cell_geom(&p, Design::Cim1).area_f2();
        let a2 = area::cell_geom(&p, Design::Cim2).area_f2();
        t.row(&[
            tech.name().to_string(),
            fmt_x(c2.mac.energy / c1.mac.energy),
            fmt_x(paper[i].1),
            fmt_x(c2.mac.latency / c1.mac.latency),
            fmt_x(paper[i].2),
            fmt_pct(1.0 - a2 / a1),
            fmt_pct(paper[i].3),
        ]);
    }
    t.render()
}

/// §III.2 error probability: analytic + Monte-Carlo, vs the paper's
/// 3.10e-3, plus its sensitivity to workload sparsity.
pub fn error_prob() -> String {
    let mut rng = Rng::new(0xE44);
    let sigma = variation::SIGMA_VTH_SENSE_V;
    let mut t = Table::new("§III.2 — compute error probability (V_TH variation MC)")
        .header(&["p_nz(in)·p_nz(w)", "analytic P(err)", "MC P(err)", "paper"]);
    for (pi, pw) in [(0.5, 0.5), (0.3, 0.5), (0.7, 0.7)] {
        let ana = variation::total_error_prob(sigma, pi, pw);
        let mc = variation::mc_error_prob(sigma, pi, pw, 300_000, &mut rng);
        let paper = if (pi, pw) == (0.5, 0.5) { "3.10e-3" } else { "-" };
        t.row(&[
            format!("{pi:.1}·{pw:.1}"),
            format!("{ana:.2e}"),
            format!("{mc:.2e}"),
            paper.to_string(),
        ]);
    }
    t.note(format!("σ_sense = {} mV; negligible accuracy impact shown by e2e_inference", sigma * 1e3));
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_render_nonempty() {
        for (name, s) in [
            ("fig4", fig4()),
            ("fig7", fig7()),
            ("area", area_table()),
            ("fig9", fig9()),
            ("fig11", fig11()),
            ("cmp", cim1_vs_cim2()),
        ] {
            assert!(s.len() > 200, "{name} too short");
            assert!(s.contains("paper") || s.contains("Fig") || s.contains('%'), "{name}");
        }
    }

    #[test]
    fn fig4_has_17_rows() {
        let s = fig4();
        assert!(s.contains("| 16 |") || s.contains("16"));
    }
}
