//! Reproduction harness: one entry point per paper figure/table, each
//! printing the paper's reported values next to this model's measured
//! values. See DESIGN.md §4 for the experiment index.

pub mod figures;
pub mod system;

pub use figures::{area_table, cim1_vs_cim2, error_prob, fig11, fig4, fig7, fig9};
pub use system::{engine_cosim, engine_cosim_status, fig12, fig13};

/// Run every reproduction, returning the combined report.
pub fn run_all() -> String {
    let mut out = String::new();
    out.push_str(&fig4());
    out.push_str(&fig7());
    out.push_str(&area_table());
    out.push_str(&fig9());
    out.push_str(&fig11());
    out.push_str(&cim1_vs_cim2());
    out.push_str(&fig12());
    out.push_str(&fig13());
    out.push_str(&error_prob());
    out.push_str(&engine_cosim());
    out
}
