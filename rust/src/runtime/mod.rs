//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text)
//! and executes them on the CPU PJRT client. Python never runs here —
//! the rust binary is self-contained once `make artifacts` has run.
//!
//! The PJRT bindings (`xla` crate) are only linked when the `pjrt`
//! feature is enabled; the default build substitutes [`pjrt_stub`] so
//! the crate builds offline, and every PJRT entry point errors at call
//! time instead (callers already skip gracefully).

pub mod artifact;
pub mod executor;
#[cfg(not(feature = "pjrt"))]
pub mod pjrt_stub;

pub use artifact::{default_dir, Manifest, PlacementPlan, MANIFEST_VERSION};
pub use executor::{cpu_client, KernelExecutor, MlpExecutor, ModelKind};
