//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text)
//! and executes them on the CPU PJRT client. Python never runs here —
//! the rust binary is self-contained once `make artifacts` has run.

pub mod artifact;
pub mod executor;

pub use artifact::{default_dir, Manifest};
pub use executor::{cpu_client, KernelExecutor, MlpExecutor, ModelKind};
