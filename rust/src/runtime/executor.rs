//! PJRT executor: loads the AOT HLO-text artifacts, compiles them on the
//! CPU PJRT client and serves inference from the L3 hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute`. Outputs are 1-tuples (lowered with `return_tuple=True`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifact::Manifest;

// Without the `pjrt` feature the real `xla` bindings are not linked;
// alias the stub (same API surface, errors at call time) in their place.
#[cfg(not(feature = "pjrt"))]
use super::pjrt_stub as xla;

/// Which exported model graph to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Cim1,
    Cim2,
    Exact,
}

impl ModelKind {
    pub fn manifest_key(&self) -> &'static str {
        match self {
            ModelKind::Cim1 => "mlp_cim1",
            ModelKind::Cim2 => "mlp_cim2",
            ModelKind::Exact => "mlp_exact",
        }
    }
}

/// A compiled MLP inference executable (fixed batch). Weights cross the
/// AOT boundary as f32 parameters (see aot.py) and are held here as
/// ready-to-execute literals.
pub struct MlpExecutor {
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::Literal>,
    pub batch: usize,
    pub in_dim: usize,
    pub out_dim: usize,
}

impl MlpExecutor {
    /// Compile the given model graph from the artifacts.
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest, kind: ModelKind) -> Result<MlpExecutor> {
        let path = manifest
            .hlo
            .get(kind.manifest_key())
            .with_context(|| format!("manifest has no {}", kind.manifest_key()))?;
        let exe = compile_hlo_file(client, path)?;
        let mut weights = Vec::new();
        for i in 0..manifest.weights.len() {
            let (trits, (k, n)) = manifest.load_weight(i)?;
            let wf: Vec<f32> = trits.iter().map(|&t| t as f32).collect();
            weights.push(xla::Literal::vec1(&wf).reshape(&[k as i64, n as i64])?);
        }
        Ok(MlpExecutor {
            exe,
            weights,
            batch: manifest.batch,
            in_dim: *manifest.dims.first().unwrap_or(&64),
            out_dim: *manifest.dims.last().unwrap_or(&10),
        })
    }

    /// Run one padded batch of trit inputs; returns row-major logits for
    /// the first `n_valid` rows.
    pub fn run_batch(&self, trits: &[i8], n_valid: usize) -> Result<Vec<f32>> {
        if n_valid == 0 || n_valid > self.batch {
            bail!("n_valid {} out of range 1..={}", n_valid, self.batch);
        }
        if trits.len() != n_valid * self.in_dim {
            bail!("expected {} trits, got {}", n_valid * self.in_dim, trits.len());
        }
        // Pad to the fixed batch with zeros; trits cross as f32.
        let mut buf = vec![0f32; self.batch * self.in_dim];
        for (i, &t) in trits.iter().enumerate() {
            buf[i] = t as f32;
        }
        let x = xla::Literal::vec1(&buf).reshape(&[self.batch as i64, self.in_dim as i64])?;
        let mut args: Vec<&xla::Literal> = vec![&x];
        args.extend(self.weights.iter());
        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let logits = result.to_tuple1()?.to_vec::<f32>()?;
        Ok(logits[..n_valid * self.out_dim].to_vec())
    }

    /// Classify a batch: argmax over logits.
    pub fn classify(&self, trits: &[i8], n_valid: usize) -> Result<Vec<usize>> {
        let logits = self.run_batch(trits, n_valid)?;
        Ok(argmax_rows(&logits, self.out_dim))
    }
}

/// The standalone CiM-matmul kernel executable (equivalence testing).
pub struct KernelExecutor {
    exe: xla::PjRtLoadedExecutable,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl KernelExecutor {
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest) -> Result<KernelExecutor> {
        let path = manifest.hlo.get("kernel").context("manifest has no kernel")?;
        let exe = compile_hlo_file(client, path)?;
        let (m, k, n) = manifest.kernel_shape;
        Ok(KernelExecutor { exe, m, k, n })
    }

    /// Run the kernel: x (m×k trits), w (k×n trits) → m×n i32 outputs.
    pub fn run(&self, x: &[i8], w: &[i8]) -> Result<Vec<i32>> {
        if x.len() != self.m * self.k || w.len() != self.k * self.n {
            bail!("kernel operand size mismatch");
        }
        let xf: Vec<f32> = x.iter().map(|&t| t as f32).collect();
        let wf: Vec<f32> = w.iter().map(|&t| t as f32).collect();
        let xl = xla::Literal::vec1(&xf).reshape(&[self.m as i64, self.k as i64])?;
        let wl = xla::Literal::vec1(&wf).reshape(&[self.k as i64, self.n as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[xl, wl])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?.to_vec::<f32>()?;
        Ok(out.iter().map(|&f| f as i32).collect())
    }
}

/// Compile an HLO-text file on the client.
pub fn compile_hlo_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf8")?)
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
}

/// The PJRT client type (real bindings or the stub, per the `pjrt`
/// feature) — nameable by other modules without repeating the cfg gate.
pub type PjrtClient = xla::PjRtClient;

/// New CPU PJRT client.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().context("creating PJRT CPU client")
}

/// Row-wise argmax helper.
pub fn argmax_rows(flat: &[f32], width: usize) -> Vec<usize> {
    flat.chunks(width)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows_basic() {
        let flat = [0.0, 2.0, 1.0, 5.0, 4.0, 3.0];
        assert_eq!(argmax_rows(&flat, 3), vec![1, 0]);
    }

    // PJRT-dependent paths are covered by the `runtime_hlo` integration
    // test (requires built artifacts + the CPU plugin).
}
