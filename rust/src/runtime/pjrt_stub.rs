//! Build-time stub for the `xla` PJRT bindings.
//!
//! The default build carries no `xla` dependency (the offline registry is
//! not always present), so `runtime::executor` aliases this module as
//! `xla` unless the `pjrt` feature is enabled. It mirrors exactly the API
//! surface the executor uses; every entry point that would touch PJRT
//! returns [`Error`] at call time, which the callers already handle (the
//! integration tests and the serving path skip gracefully when artifacts
//! or the runtime are unavailable).

use std::fmt;

/// The stub's uniform error: the runtime is compiled out.
#[derive(Clone, Copy, Debug)]
pub struct Error;

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (add the `xla` dependency and rebuild with --features pjrt)"
        )
    }
}

impl std::error::Error for Error {}

#[derive(Clone, Copy, Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error)
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_name_the_feature_gate() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("pjrt"));
    }
}
