//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. Python runs once (`make artifacts`); everything the
//! inference path needs is read from `artifacts/` via this module.
//!
//! # Versioned schema
//!
//! The manifest carries a schema `version` so producers and consumers
//! can evolve independently; unknown versions are rejected with a
//! pointed error instead of misparsed.
//!
//! - **Version 1** (legacy; an absent `version` field means 1): weights,
//!   HLO file names, quantization scales, activation thresholds and the
//!   held-out test set. No integrity or placement metadata.
//! - **Version 2** adds two objects. `sha256` maps every emitted file
//!   name to its lowercase-hex SHA-256; [`Manifest::load`] re-hashes the
//!   files and refuses corrupt or stale artifacts. `placement`
//!   (optional) is the AOT-computed placement plan — `array_rows`,
//!   `array_cols`, `slots` and a `shards` list in the engine's flat
//!   shard order, each with its partition-relative slot rank and region
//!   origin — computed by `python/compile/placement.py` with the same
//!   16-row-aligned first-fit shelf packing as `engine::resident`, so
//!   cold-start can program arrays from the plan instead of discovering
//!   placement on first traffic (`TernaryGemmEngine::program_from_plan`).
//!
//! `sitecim artifact verify <dir>` checks all of this offline:
//! checksums, schema version, and that the plan both fits its declared
//! pool and matches the Rust replay of the packing rules.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::array::mac::GROUP_ROWS;
use crate::engine::resident::PlannedShard;
use crate::util::json::Json;
use crate::util::sha256;

/// Highest manifest schema version this build understands.
pub const MANIFEST_VERSION: usize = 2;

/// One weight tensor: row-major int8 trits.
#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub file: PathBuf,
    pub shape: (usize, usize),
}

/// AOT-computed placement plan (schema version 2, optional): the
/// shelf/shard assignments an empty `slots`-array partition gives this
/// model, in the engine's flat shard order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementPlan {
    pub array_rows: usize,
    pub array_cols: usize,
    pub slots: usize,
    pub shards: Vec<PlannedShard>,
}

impl PlacementPlan {
    /// Structural checks: every shard's region is 16-row aligned, fits
    /// its array, and names a slot inside the plan's declared pool.
    pub fn validate(&self) -> Result<()> {
        if self.slots == 0 {
            bail!("placement plan declares no slots");
        }
        if self.array_rows == 0 || self.array_rows % GROUP_ROWS != 0 || self.array_cols == 0 {
            bail!(
                "placement plan array shape {}×{} is not a legal pool array",
                self.array_rows,
                self.array_cols
            );
        }
        for s in &self.shards {
            if s.k_len == 0 || s.n_len == 0 {
                bail!("placement shard {}/{} is empty", s.layer, s.shard);
            }
            if s.slot >= self.slots {
                bail!(
                    "placement shard {}/{} names slot {} of a {}-slot plan",
                    s.layer,
                    s.shard,
                    s.slot,
                    self.slots
                );
            }
            let rows = s.k_len.div_ceil(GROUP_ROWS) * GROUP_ROWS;
            if s.row0 % GROUP_ROWS != 0
                || s.row0 + rows > self.array_rows
                || s.col0 + s.n_len > self.array_cols
            {
                bail!(
                    "placement shard {}/{} region ({}+{} rows, {}+{} cols) breaks the \
                     16-row-aligned {}×{} array bound",
                    s.layer,
                    s.shard,
                    s.row0,
                    rows,
                    s.col0,
                    s.n_len,
                    self.array_rows,
                    self.array_cols
                );
            }
        }
        Ok(())
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Schema version (1 when the field is absent — the legacy layout).
    pub version: usize,
    pub batch: usize,
    pub dims: Vec<usize>,
    pub act_thresholds: Vec<f64>,
    pub kernel_shape: (usize, usize, usize),
    /// HLO files by logical name (mlp_cim1, mlp_cim2, mlp_exact, kernel).
    pub hlo: std::collections::BTreeMap<String, PathBuf>,
    pub weights: Vec<WeightSpec>,
    pub scales: Vec<f64>,
    /// Per-file SHA-256 (lowercase hex) keyed by file name, verified at
    /// load. Empty for legacy (version 1) manifests.
    pub sha256: std::collections::BTreeMap<String, String>,
    /// AOT-computed placement plan, when the producer emitted one.
    pub placement: Option<PlacementPlan>,
    pub test_x: PathBuf,
    pub test_y: PathBuf,
    pub test_n: usize,
    pub in_dim: usize,
    /// Accuracies recorded at AOT time (exact/cim1/cim2).
    pub aot_accuracy: std::collections::BTreeMap<String, f64>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let version = match j.get("version") {
            None => 1,
            Some(v) => v.as_usize().context("manifest `version` must be a number")?,
        };
        if !(1..=MANIFEST_VERSION).contains(&version) {
            bail!(
                "unsupported manifest version {version} in {} (this build understands \
                 1..={MANIFEST_VERSION}; re-run the AOT compiler or upgrade the runtime)",
                dir.display()
            );
        }

        let mut sha = std::collections::BTreeMap::new();
        if let Some(map) = j.get("sha256").and_then(Json::as_obj) {
            for (file, hexval) in map {
                sha.insert(
                    file.clone(),
                    hexval
                        .as_str()
                        .with_context(|| format!("sha256[{file}] must be a hex string"))?
                        .to_string(),
                );
            }
        }

        let placement = match j.get("placement") {
            None => None,
            Some(p) => {
                let plan = parse_placement(p).context("parsing manifest placement plan")?;
                plan.validate().context("validating manifest placement plan")?;
                Some(plan)
            }
        };

        let usize_at = |p: &str| -> Result<usize> {
            j.path(p).and_then(Json::as_usize).with_context(|| format!("manifest missing {p}"))
        };

        let mut hlo = std::collections::BTreeMap::new();
        for (k, v) in j.get("files").and_then(Json::as_obj).context("files")? {
            hlo.insert(k.clone(), dir.join(v.as_str().context("file name")?));
        }

        let mut weights = Vec::new();
        for w in j.get("weights").and_then(Json::as_arr).context("weights")? {
            let shape = w.get("shape").and_then(Json::as_arr).context("shape")?;
            weights.push(WeightSpec {
                file: dir.join(w.get("file").and_then(Json::as_str).context("file")?),
                shape: (
                    shape[0].as_usize().context("shape[0]")?,
                    shape[1].as_usize().context("shape[1]")?,
                ),
            });
        }

        let ks = j.get("kernel_shape").and_then(Json::as_arr).context("kernel_shape")?;
        let dims: Vec<usize> = j
            .get("dims")
            .and_then(Json::as_arr)
            .context("dims")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let act_thresholds: Vec<f64> = j
            .get("act_thresholds")
            .and_then(Json::as_arr)
            .context("act_thresholds")?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let scales: Vec<f64> = j
            .get("scales")
            .and_then(Json::as_arr)
            .context("scales")?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let mut aot_accuracy = std::collections::BTreeMap::new();
        if let Some(acc) = j.get("accuracy").and_then(Json::as_obj) {
            for (k, v) in acc {
                if let Some(f) = v.as_f64() {
                    aot_accuracy.insert(k.clone(), f);
                }
            }
        }

        let m = Manifest {
            version,
            sha256: sha,
            placement,
            batch: usize_at("batch")?,
            dims,
            act_thresholds,
            kernel_shape: (
                ks[0].as_usize().context("ks0")?,
                ks[1].as_usize().context("ks1")?,
                ks[2].as_usize().context("ks2")?,
            ),
            hlo,
            weights,
            scales,
            test_x: dir.join(
                j.path("test_set/x").and_then(Json::as_str).context("test_set.x")?,
            ),
            test_y: dir.join(
                j.path("test_set/y").and_then(Json::as_str).context("test_set.y")?,
            ),
            test_n: j.path("test_set/n").and_then(Json::as_usize).context("test_set.n")?,
            in_dim: j.path("test_set/in_dim").and_then(Json::as_usize).context("in_dim")?,
            aot_accuracy,
            dir,
        };
        m.verify_checksums()?;
        Ok(m)
    }

    /// Verify every per-file SHA-256 the manifest records against the
    /// bytes on disk. Legacy manifests record none and pass vacuously;
    /// [`Self::load`] calls this, so a version-2 artifact with a flipped
    /// bit is refused before anything consumes it.
    pub fn verify_checksums(&self) -> Result<()> {
        for (file, want) in &self.sha256 {
            let path = self.dir.join(file);
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading {} for checksum verification", path.display()))?;
            let got = sha256::hex(&bytes);
            if got != *want {
                bail!(
                    "sha256 mismatch for {}: manifest records {want}, file hashes to {got} \
                     (artifact corrupt or stale — re-run the AOT compiler)",
                    path.display()
                );
            }
        }
        Ok(())
    }

    /// Load a weight tensor as trits (row-major).
    pub fn load_weight(&self, idx: usize) -> Result<(Vec<i8>, (usize, usize))> {
        let spec = &self.weights[idx];
        let bytes = std::fs::read(&spec.file)
            .with_context(|| format!("reading {}", spec.file.display()))?;
        if bytes.len() != spec.shape.0 * spec.shape.1 {
            bail!(
                "{}: {} bytes != shape {:?}",
                spec.file.display(),
                bytes.len(),
                spec.shape
            );
        }
        let trits: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
        if let Some(bad) = trits.iter().find(|&&t| !(-1..=1).contains(&t)) {
            bail!("{}: non-ternary weight {bad}", spec.file.display());
        }
        Ok((trits, spec.shape))
    }

    /// Load the held-out test set: (inputs (n × in_dim trits), labels).
    pub fn load_test_set(&self) -> Result<(Vec<i8>, Vec<u8>)> {
        let x = std::fs::read(&self.test_x)?.iter().map(|&b| b as i8).collect::<Vec<_>>();
        let y = std::fs::read(&self.test_y)?;
        if x.len() != self.test_n * self.in_dim || y.len() != self.test_n {
            bail!("test set size mismatch");
        }
        Ok((x, y))
    }
}

/// Parse a manifest `placement` object into a [`PlacementPlan`].
fn parse_placement(p: &Json) -> Result<PlacementPlan> {
    let at = |q: &str| -> Result<usize> {
        p.get(q).and_then(Json::as_usize).with_context(|| format!("placement missing {q}"))
    };
    let mut shards = Vec::new();
    for (i, s) in
        p.get("shards").and_then(Json::as_arr).context("placement.shards")?.iter().enumerate()
    {
        let f = |k: &str| -> Result<usize> {
            s.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("placement shard {i} missing {k}"))
        };
        shards.push(PlannedShard {
            layer: f("layer")?,
            shard: f("shard")?,
            k0: f("k0")?,
            k_len: f("k_len")?,
            n0: f("n0")?,
            n_len: f("n_len")?,
            slot: f("slot")?,
            row0: f("row0")?,
            col0: f("col0")?,
        });
    }
    Ok(PlacementPlan {
        array_rows: at("array_rows")?,
        array_cols: at("array_cols")?,
        slots: at("slots")?,
        shards,
    })
}

/// Default artifacts directory: `$SITECIM_ARTIFACTS` or `artifacts/`
/// relative to the crate root (falling back to cwd).
pub fn default_dir() -> PathBuf {
    if let Ok(d) = std::env::var("SITECIM_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest_dir.exists() {
        return manifest_dir;
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full manifest parsing is exercised by the `runtime_hlo` integration
    // test (requires built artifacts); here we test the failure paths.
    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent-path").unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    #[test]
    fn default_dir_is_artifacts() {
        assert!(default_dir().to_string_lossy().contains("artifacts"));
    }

    /// A minimal on-disk artifact: one 2×4 ternary weight + a 2-sample
    /// test set, optionally version-stamped and optionally with its
    /// recorded checksum corrupted.
    fn write_min_artifact(tag: &str, version: Option<usize>, corrupt: bool) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("sitecim-artifact-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let w0: Vec<u8> = vec![1, 0, 255, 1, 0, 255, 1, 0]; // 255 = -1 as i8
        std::fs::write(dir.join("w0.bin"), &w0).unwrap();
        std::fs::write(dir.join("test_x.bin"), [0u8; 4]).unwrap();
        std::fs::write(dir.join("test_y.bin"), [0u8; 2]).unwrap();
        let sha = crate::util::sha256::hex(if corrupt { b"not the file" } else { &w0 });
        let version_line =
            version.map(|v| format!("\"version\": {v},\n  ")).unwrap_or_default();
        let manifest = format!(
            r#"{{
  {version_line}"batch": 1,
  "dims": [2, 4],
  "act_thresholds": [],
  "kernel_shape": [8, 16, 16],
  "files": {{}},
  "weights": [{{"file": "w0.bin", "shape": [2, 4]}}],
  "scales": [1.0],
  "sha256": {{"w0.bin": "{sha}"}},
  "test_set": {{"x": "test_x.bin", "y": "test_y.bin", "n": 2, "in_dim": 2}}
}}"#
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        dir
    }

    #[test]
    fn legacy_and_current_versions_load() {
        let legacy = Manifest::load(write_min_artifact("legacy", None, false)).unwrap();
        assert_eq!(legacy.version, 1);
        let v2 = Manifest::load(write_min_artifact("v2", Some(2), false)).unwrap();
        assert_eq!(v2.version, 2);
        assert_eq!(v2.sha256.len(), 1);
        let (trits, shape) = v2.load_weight(0).unwrap();
        assert_eq!((trits.len(), shape), (8, (2, 4)));
    }

    #[test]
    fn unknown_version_is_rejected_with_context() {
        let err = Manifest::load(write_min_artifact("future", Some(99), false)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unsupported manifest version 99"), "{msg}");
        assert!(msg.contains("1..=2"), "{msg}");
    }

    #[test]
    fn corrupt_sha256_is_rejected_naming_the_file() {
        let err = Manifest::load(write_min_artifact("corrupt", Some(2), true)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sha256 mismatch"), "{msg}");
        assert!(msg.contains("w0.bin"), "{msg}");
    }

    #[test]
    fn placement_plans_parse_and_validate() {
        let p = Json::parse(
            r#"{"array_rows": 32, "array_cols": 16, "slots": 2, "shards": [
                {"layer": 0, "shard": 0, "k0": 0, "k_len": 20, "n0": 0, "n_len": 16,
                 "slot": 1, "row0": 0, "col0": 0}]}"#,
        )
        .unwrap();
        let plan = parse_placement(&p).unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.shards[0].k_len, 20);
        // A shard whose padded rows overflow the array fails validation.
        let bad = PlacementPlan {
            shards: vec![PlannedShard { row0: 16, ..plan.shards[0] }],
            ..plan.clone()
        };
        assert!(bad.validate().unwrap_err().to_string().contains("array bound"));
        // A slot rank outside the declared pool fails too.
        let bad = PlacementPlan {
            shards: vec![PlannedShard { slot: 2, ..plan.shards[0] }],
            ..plan
        };
        assert!(bad.validate().unwrap_err().to_string().contains("2-slot plan"));
    }
}
