//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. Python runs once (`make artifacts`); everything the
//! inference path needs is read from `artifacts/` via this module.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One weight tensor: row-major int8 trits.
#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub file: PathBuf,
    pub shape: (usize, usize),
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub dims: Vec<usize>,
    pub act_thresholds: Vec<f64>,
    pub kernel_shape: (usize, usize, usize),
    /// HLO files by logical name (mlp_cim1, mlp_cim2, mlp_exact, kernel).
    pub hlo: std::collections::BTreeMap<String, PathBuf>,
    pub weights: Vec<WeightSpec>,
    pub scales: Vec<f64>,
    pub test_x: PathBuf,
    pub test_y: PathBuf,
    pub test_n: usize,
    pub in_dim: usize,
    /// Accuracies recorded at AOT time (exact/cim1/cim2).
    pub aot_accuracy: std::collections::BTreeMap<String, f64>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let usize_at = |p: &str| -> Result<usize> {
            j.path(p).and_then(Json::as_usize).with_context(|| format!("manifest missing {p}"))
        };

        let mut hlo = std::collections::BTreeMap::new();
        for (k, v) in j.get("files").and_then(Json::as_obj).context("files")? {
            hlo.insert(k.clone(), dir.join(v.as_str().context("file name")?));
        }

        let mut weights = Vec::new();
        for w in j.get("weights").and_then(Json::as_arr).context("weights")? {
            let shape = w.get("shape").and_then(Json::as_arr).context("shape")?;
            weights.push(WeightSpec {
                file: dir.join(w.get("file").and_then(Json::as_str).context("file")?),
                shape: (
                    shape[0].as_usize().context("shape[0]")?,
                    shape[1].as_usize().context("shape[1]")?,
                ),
            });
        }

        let ks = j.get("kernel_shape").and_then(Json::as_arr).context("kernel_shape")?;
        let dims: Vec<usize> = j
            .get("dims")
            .and_then(Json::as_arr)
            .context("dims")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let act_thresholds: Vec<f64> = j
            .get("act_thresholds")
            .and_then(Json::as_arr)
            .context("act_thresholds")?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let scales: Vec<f64> = j
            .get("scales")
            .and_then(Json::as_arr)
            .context("scales")?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let mut aot_accuracy = std::collections::BTreeMap::new();
        if let Some(acc) = j.get("accuracy").and_then(Json::as_obj) {
            for (k, v) in acc {
                if let Some(f) = v.as_f64() {
                    aot_accuracy.insert(k.clone(), f);
                }
            }
        }

        Ok(Manifest {
            batch: usize_at("batch")?,
            dims,
            act_thresholds,
            kernel_shape: (
                ks[0].as_usize().context("ks0")?,
                ks[1].as_usize().context("ks1")?,
                ks[2].as_usize().context("ks2")?,
            ),
            hlo,
            weights,
            scales,
            test_x: dir.join(
                j.path("test_set/x").and_then(Json::as_str).context("test_set.x")?,
            ),
            test_y: dir.join(
                j.path("test_set/y").and_then(Json::as_str).context("test_set.y")?,
            ),
            test_n: j.path("test_set/n").and_then(Json::as_usize).context("test_set.n")?,
            in_dim: j.path("test_set/in_dim").and_then(Json::as_usize).context("in_dim")?,
            aot_accuracy,
            dir,
        })
    }

    /// Load a weight tensor as trits (row-major).
    pub fn load_weight(&self, idx: usize) -> Result<(Vec<i8>, (usize, usize))> {
        let spec = &self.weights[idx];
        let bytes = std::fs::read(&spec.file)
            .with_context(|| format!("reading {}", spec.file.display()))?;
        if bytes.len() != spec.shape.0 * spec.shape.1 {
            bail!(
                "{}: {} bytes != shape {:?}",
                spec.file.display(),
                bytes.len(),
                spec.shape
            );
        }
        let trits: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
        if let Some(bad) = trits.iter().find(|&&t| !(-1..=1).contains(&t)) {
            bail!("{}: non-ternary weight {bad}", spec.file.display());
        }
        Ok((trits, spec.shape))
    }

    /// Load the held-out test set: (inputs (n × in_dim trits), labels).
    pub fn load_test_set(&self) -> Result<(Vec<i8>, Vec<u8>)> {
        let x = std::fs::read(&self.test_x)?.iter().map(|&b| b as i8).collect::<Vec<_>>();
        let y = std::fs::read(&self.test_y)?;
        if x.len() != self.test_n * self.in_dim || y.len() != self.test_n {
            bail!("test set size mismatch");
        }
        Ok((x, y))
    }
}

/// Default artifacts directory: `$SITECIM_ARTIFACTS` or `artifacts/`
/// relative to the crate root (falling back to cwd).
pub fn default_dir() -> PathBuf {
    if let Ok(d) = std::env::var("SITECIM_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest_dir.exists() {
        return manifest_dir;
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full manifest parsing is exercised by the `runtime_hlo` integration
    // test (requires built artifacts); here we test the failure paths.
    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent-path").unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    #[test]
    fn default_dir_is_artifacts() {
        assert!(default_dir().to_string_lossy().contains("artifacts"));
    }
}
