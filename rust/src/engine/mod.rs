//! The ternary GEMM execution engine: tiles arbitrary M×K×N ternary
//! GEMMs across a pool of functional [`CimArray`] backends and runs the
//! tiles on worker threads — the functional counterpart of the analytic
//! `arch::Accelerator` (which only *accounts* for this work).
//!
//! Mapping (same weight-stationary scheme as `arch::mapper::map_layer`):
//! K → array rows, N → array columns, one tile = one array-full of
//! weights, zero-padded at the edges (inert — see [`tiling`]). Each tile
//! job programs its worker's array once and streams all M input vectors
//! through the backend's batched bit-packed fast path; partial products
//! accumulate into the shared output under a mutex (i32 addition is
//! order-independent, so single- and multi-threaded runs are
//! bit-identical).
//!
//! The specification is [`tiling::reference_gemm`] — `mac::dot_ref`
//! composed over tiles — and `gemm` matches it bit-for-bit for all three
//! backends (see tests/cim_conformance.rs).

pub mod tiling;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::array::area::Design;
use crate::array::encoding::Trit;
use crate::array::mac::GROUP_ROWS;
use crate::array::{make_array, CimArray};
use crate::device::Tech;
use self::tiling::TileGrid;

/// Engine shape: which backend design/tech, the array geometry, the pool
/// size and the worker-thread count.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub design: Design,
    pub tech: Tech,
    /// Rows per array (K capacity per tile); multiple of 16.
    pub array_rows: usize,
    /// Columns per array (N capacity per tile).
    pub array_cols: usize,
    /// Arrays in the pool (the paper's system has 32).
    pub n_arrays: usize,
    /// Worker threads (clamped to the pool size; 1 = single-threaded).
    pub n_threads: usize,
}

impl EngineConfig {
    /// The paper's system shape: 32 arrays of 256×256, one worker per
    /// available core.
    pub fn new(design: Design, tech: Tech) -> EngineConfig {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        EngineConfig {
            design,
            tech,
            array_rows: 256,
            array_cols: 256,
            n_arrays: 32,
            n_threads: threads.min(32),
        }
    }

    pub fn with_threads(mut self, n_threads: usize) -> EngineConfig {
        self.n_threads = n_threads.max(1);
        self
    }

    pub fn with_pool(mut self, n_arrays: usize) -> EngineConfig {
        self.n_arrays = n_arrays.max(1);
        self
    }

    pub fn with_array_dims(mut self, rows: usize, cols: usize) -> EngineConfig {
        self.array_rows = rows;
        self.array_cols = cols;
        self
    }
}

/// Cumulative work counters (functional-simulation accounting, feeding
/// the co-simulation cross-checks and the benches).
#[derive(Debug, Default)]
pub struct EngineStats {
    gemms: AtomicU64,
    tiles: AtomicU64,
    windows: AtomicU64,
    macs: AtomicU64,
}

/// Point-in-time copy of [`EngineStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStatsSnapshot {
    pub gemms: u64,
    /// Weight tiles programmed (array-fulls streamed in).
    pub tiles: u64,
    /// 16-row MAC windows executed across all tiles and input vectors.
    pub windows: u64,
    /// Useful multiply-accumulates covered (excludes padding).
    pub macs: u64,
}

/// Functional tiled ternary GEMM over a pool of [`CimArray`] backends.
pub struct TernaryGemmEngine {
    cfg: EngineConfig,
    pool: Vec<Mutex<Box<dyn CimArray>>>,
    stats: EngineStats,
}

impl TernaryGemmEngine {
    pub fn new(cfg: EngineConfig) -> TernaryGemmEngine {
        assert!(cfg.array_rows > 0 && cfg.array_rows % GROUP_ROWS == 0,
            "array_rows must be a positive multiple of {GROUP_ROWS}");
        assert!(cfg.array_cols > 0 && cfg.n_arrays > 0);
        let pool = (0..cfg.n_arrays)
            .map(|_| Mutex::new(make_array(cfg.design, cfg.tech, cfg.array_rows, cfg.array_cols)))
            .collect();
        TernaryGemmEngine { cfg, pool, stats: EngineStats::default() }
    }

    pub fn cfg(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn stats(&self) -> EngineStatsSnapshot {
        EngineStatsSnapshot {
            gemms: self.stats.gemms.load(Ordering::Relaxed),
            tiles: self.stats.tiles.load(Ordering::Relaxed),
            windows: self.stats.windows.load(Ordering::Relaxed),
            macs: self.stats.macs.load(Ordering::Relaxed),
        }
    }

    /// The tile grid a GEMM of this shape maps to on this engine.
    pub fn grid(&self, k: usize, n: usize) -> TileGrid {
        TileGrid::new(k, n, self.cfg.array_rows, self.cfg.array_cols)
    }

    /// Execute a ternary GEMM: `x` (row-major M×K trits) × `w` (row-major
    /// K×N trits) → row-major M×N i32 outputs, under the backend's MAC
    /// semantics (saturating per 16-row group for the CiM flavors, exact
    /// for near-memory). Deterministic: bit-identical to
    /// [`tiling::reference_gemm`] regardless of thread count.
    pub fn gemm(&self, x: &[Trit], w: &[Trit], m: usize, k: usize, n: usize) -> Vec<i32> {
        assert!(m > 0, "empty batch");
        assert_eq!(x.len(), m * k, "x must be m×k = {m}×{k}");
        assert_eq!(w.len(), k * n, "w must be k×n = {k}×{n}");
        let grid = self.grid(k, n);
        let tiles = grid.tiles();
        let out = Mutex::new(vec![0i32; m * n]);
        let next = AtomicUsize::new(0);
        let workers = self.cfg.n_threads.clamp(1, self.pool.len()).min(tiles.len());
        std::thread::scope(|s| {
            for wid in 0..workers {
                let (tiles, out, next, grid) = (&tiles, &out, &next, &grid);
                s.spawn(move || self.run_tiles(wid, x, w, m, grid, tiles, next, out));
            }
        });
        self.stats.gemms.fetch_add(1, Ordering::Relaxed);
        out.into_inner().unwrap()
    }

    /// Worker loop: claim tiles off the shared counter, program this
    /// worker's array, stream the batch through it, merge partials.
    #[allow(clippy::too_many_arguments)]
    fn run_tiles(
        &self,
        wid: usize,
        x: &[Trit],
        w: &[Trit],
        m: usize,
        grid: &TileGrid,
        tiles: &[tiling::Tile],
        next: &AtomicUsize,
        out: &Mutex<Vec<i32>>,
    ) {
        let (rows, cols) = (self.cfg.array_rows, self.cfg.array_cols);
        let mut arr = self.pool[wid].lock().unwrap();
        let mut wbuf = vec![0i8; rows * cols];
        let mut xbuf = vec![0i8; m * rows];
        loop {
            let ti = next.fetch_add(1, Ordering::Relaxed);
            let Some(tile) = tiles.get(ti) else { break };
            // Stream the tile's weights in (once per tile, weight-
            // stationary across the whole batch).
            tiling::extract_tile_weights(w, grid.k, grid.n, tile, rows, cols, &mut wbuf);
            arr.write_matrix(&wbuf);
            for r in 0..m {
                tiling::extract_tile_inputs(
                    &x[r * grid.k..(r + 1) * grid.k],
                    tile,
                    rows,
                    &mut xbuf[r * rows..(r + 1) * rows],
                );
            }
            let partial = arr.dot_batch(&xbuf, m);
            {
                let mut o = out.lock().unwrap();
                for r in 0..m {
                    let src = &partial[r * cols..r * cols + tile.n_len];
                    let base = r * grid.n + tile.n0;
                    for (d, s) in o[base..base + tile.n_len].iter_mut().zip(src) {
                        *d += s;
                    }
                }
            }
            self.stats.tiles.fetch_add(1, Ordering::Relaxed);
            self.stats.windows.fetch_add((m * (rows / GROUP_ROWS)) as u64, Ordering::Relaxed);
            self.stats.macs.fetch_add((m * tile.k_len * tile.n_len) as u64, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::mac::Flavor;
    use crate::util::rng::Rng;

    fn small_engine(design: Design, threads: usize) -> TernaryGemmEngine {
        TernaryGemmEngine::new(
            EngineConfig::new(design, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_pool(4)
                .with_threads(threads),
        )
    }

    #[test]
    fn gemm_matches_tiled_reference_all_designs() {
        let mut rng = Rng::new(41);
        let (m, k, n) = (3usize, 150usize, 50usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        for design in Design::ALL {
            let eng = small_engine(design, 2);
            let got = eng.gemm(&x, &w, m, k, n);
            let want = tiling::reference_gemm(&x, &w, m, &eng.grid(k, n), design.flavor());
            assert_eq!(got, want, "{design:?}");
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = Rng::new(42);
        let (m, k, n) = (2usize, 200usize, 90usize);
        let x = rng.ternary_vec(m * k, 0.4);
        let w = rng.ternary_vec(k * n, 0.4);
        let single = small_engine(Design::Cim1, 1).gemm(&x, &w, m, k, n);
        let multi = small_engine(Design::Cim1, 4).gemm(&x, &w, m, k, n);
        assert_eq!(single, multi);
    }

    #[test]
    fn stats_account_tiles_and_macs() {
        let mut rng = Rng::new(43);
        let (m, k, n) = (2usize, 100usize, 40usize);
        let eng = small_engine(Design::Cim2, 2);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        let _ = eng.gemm(&x, &w, m, k, n);
        let s = eng.stats();
        assert_eq!(s.gemms, 1);
        assert_eq!(s.tiles, eng.grid(k, n).n_tiles_total() as u64);
        assert_eq!(s.macs, (m * k * n) as u64);
        assert_eq!(s.windows, s.tiles * (m * (64 / 16)) as u64);
    }

    #[test]
    fn single_tile_gemm_equals_plain_dot() {
        let mut rng = Rng::new(44);
        let eng = small_engine(Design::Cim1, 1);
        let x = rng.ternary_vec(64, 0.5);
        let w = rng.ternary_vec(64 * 32, 0.5);
        let got = eng.gemm(&x, &w, 1, 64, 32);
        let mut storage = crate::array::TernaryStorage::new(64, 32);
        storage.write_matrix(&w);
        assert_eq!(got, crate::array::mac::dot_ref(&storage, &x, Flavor::Cim1));
    }
}
