//! The ternary GEMM execution engine: tiles arbitrary M×K×N ternary
//! GEMMs across a pool of functional [`CimArray`] backends and runs the
//! tiles on worker threads — the functional counterpart of the analytic
//! `arch::Accelerator` (which only *accounts* for this work).
//!
//! Mapping (same weight-stationary scheme as `arch::mapper::map_layer`):
//! K → array rows, N → array columns, one tile = one array-full of
//! weights, zero-padded at the edges (inert — see [`tiling`]). Partial
//! products accumulate into the shared output under a mutex (i32
//! addition is order-independent, so single- and multi-threaded runs are
//! bit-identical).
//!
//! Two execution paths share the pool:
//!
//! - **Streaming** ([`TernaryGemmEngine::gemm`]): every worker programs
//!   its own array once per claimed tile and streams the batch through —
//!   the paper's batch-1 accounting, where weights are re-programmed on
//!   every call.
//! - **Resident** ([`TernaryGemmEngine::register_weight`] +
//!   [`TernaryGemmEngine::gemm_resident`]): weights are registered once;
//!   an LRU [`resident::TileCache`] places their tiles across the pool
//!   and a tile is only (re)programmed on a cache miss, so steady-state
//!   serving pays zero weight-programming — the paper's actual
//!   weight-stationary premise. Cache hit/miss/evict counters land in
//!   [`EngineStats`].
//!
//! The specification for both paths is [`tiling::reference_gemm`] —
//! `mac::dot_ref` composed over tiles — and both match it bit-for-bit
//! for all three backends and any thread count (tests/cim_conformance.rs).

pub mod resident;
pub mod tiling;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{ensure, Result};

use crate::array::area::Design;
use crate::array::encoding::Trit;
use crate::array::mac::GROUP_ROWS;
use crate::array::{make_array, CimArray};
use crate::device::Tech;
use self::resident::{RegisteredWeight, TileCache, TileKey, WeightId};
use self::tiling::TileGrid;

/// Engine shape: which backend design/tech, the array geometry, the pool
/// size and the worker-thread count.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub design: Design,
    pub tech: Tech,
    /// Rows per array (K capacity per tile); multiple of 16.
    pub array_rows: usize,
    /// Columns per array (N capacity per tile).
    pub array_cols: usize,
    /// Arrays in the pool (the paper's system has 32). This is also the
    /// resident tile capacity: one placed tile per array.
    pub n_arrays: usize,
    /// Worker threads (clamped to the pool size; 1 = single-threaded).
    pub n_threads: usize,
}

impl EngineConfig {
    /// The paper's system shape: 32 arrays of 256×256, one worker per
    /// available core.
    pub fn new(design: Design, tech: Tech) -> EngineConfig {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        EngineConfig {
            design,
            tech,
            array_rows: 256,
            array_cols: 256,
            n_arrays: 32,
            n_threads: threads.min(32),
        }
    }

    pub fn with_threads(mut self, n_threads: usize) -> EngineConfig {
        self.n_threads = n_threads.max(1);
        self
    }

    pub fn with_pool(mut self, n_arrays: usize) -> EngineConfig {
        self.n_arrays = n_arrays.max(1);
        self
    }

    pub fn with_array_dims(mut self, rows: usize, cols: usize) -> EngineConfig {
        self.array_rows = rows;
        self.array_cols = cols;
        self
    }

    /// Tiles a K×N weight matrix occupies on this array geometry — the
    /// pool size needed to keep it fully resident (one array per tile).
    pub fn tiles_for(&self, k: usize, n: usize) -> usize {
        k.div_ceil(self.array_rows) * n.div_ceil(self.array_cols)
    }
}

/// Cumulative work counters (functional-simulation accounting, feeding
/// the co-simulation cross-checks and the benches).
///
/// `tiles`/`write_rows` count *actual array programming* (content
/// level); `hits`/`misses`/`evictions` count resident-cache placement
/// lookups. The two can drift under adversarial interleavings (e.g. a
/// streaming call trashing a placed tile makes the next resident access
/// a placement hit that still re-programs), which is exactly what the
/// split is meant to surface.
#[derive(Debug, Default)]
pub struct EngineStats {
    gemms: AtomicU64,
    tiles: AtomicU64,
    windows: AtomicU64,
    macs: AtomicU64,
    write_rows: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

/// Point-in-time copy of [`EngineStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStatsSnapshot {
    pub gemms: u64,
    /// Weight tiles actually programmed (array-fulls streamed in).
    pub tiles: u64,
    /// 16-row MAC windows executed across all tiles and input vectors.
    /// Partial k-tiles only count their occupied windows (⌈k_len/16⌉),
    /// matching `arch::mapper::map_layer`.
    pub windows: u64,
    /// Useful multiply-accumulates covered (excludes padding).
    pub macs: u64,
    /// Occupied weight rows programmed (matches mapper `write_rows`).
    pub write_rows: u64,
    /// Resident-cache placement hits (tile already routed to an array).
    pub hits: u64,
    /// Resident-cache placement misses (tile had to be placed).
    pub misses: u64,
    /// Placements that displaced another resident tile (LRU victim).
    pub evictions: u64,
}

/// One pool slot: the functional array plus the identity of the resident
/// tile its cells currently hold (`None` after the streaming path
/// borrowed it). The tag is authoritative for array *content*; the
/// placement cache is only routing. A resident worker re-programs
/// whenever tag ≠ its tile key, which keeps every interleaving of
/// streaming/resident/concurrent callers bit-exact.
struct PoolSlot {
    arr: Box<dyn CimArray>,
    programmed: Option<TileKey>,
}

/// Functional tiled ternary GEMM over a pool of [`CimArray`] backends.
pub struct TernaryGemmEngine {
    cfg: EngineConfig,
    pool: Vec<Mutex<PoolSlot>>,
    stats: EngineStats,
    /// LRU placement of registered tiles onto pool slots.
    cache: Mutex<TileCache>,
    /// Registered weights by id (ids are never reused).
    registry: RwLock<Vec<Arc<RegisteredWeight>>>,
}

impl TernaryGemmEngine {
    pub fn new(cfg: EngineConfig) -> TernaryGemmEngine {
        assert!(cfg.array_rows > 0 && cfg.array_rows % GROUP_ROWS == 0,
            "array_rows must be a positive multiple of {GROUP_ROWS}");
        assert!(cfg.array_cols > 0 && cfg.n_arrays > 0);
        let pool = (0..cfg.n_arrays)
            .map(|_| {
                Mutex::new(PoolSlot {
                    arr: make_array(cfg.design, cfg.tech, cfg.array_rows, cfg.array_cols),
                    programmed: None,
                })
            })
            .collect();
        TernaryGemmEngine {
            cache: Mutex::new(TileCache::new(cfg.n_arrays)),
            registry: RwLock::new(Vec::new()),
            cfg,
            pool,
            stats: EngineStats::default(),
        }
    }

    pub fn cfg(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Lock a pool slot, recovering from poisoning. The engine is shared
    /// across serving workers that catch panics and keep going; a panic
    /// mid-programming must not brick every later request. Recovery is
    /// safe because the `programmed` tag is cleared *before* any write
    /// and only set after it completes — an interrupted write leaves the
    /// slot tagged `None`, so the next user re-programs it.
    fn lock_slot(&self, slot: usize) -> std::sync::MutexGuard<'_, PoolSlot> {
        self.pool[slot].lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Lock the placement cache, recovering from poisoning (the cache is
    /// routing only — stale routing at worst costs a re-program).
    fn lock_cache(&self) -> std::sync::MutexGuard<'_, TileCache> {
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Resident tile capacity: one placed tile per pool array.
    pub fn capacity_tiles(&self) -> usize {
        self.pool.len()
    }

    /// Tiles currently placed in the pool.
    pub fn resident_tiles(&self) -> usize {
        self.lock_cache().resident_tiles()
    }

    pub fn stats(&self) -> EngineStatsSnapshot {
        EngineStatsSnapshot {
            gemms: self.stats.gemms.load(Ordering::Relaxed),
            tiles: self.stats.tiles.load(Ordering::Relaxed),
            windows: self.stats.windows.load(Ordering::Relaxed),
            macs: self.stats.macs.load(Ordering::Relaxed),
            write_rows: self.stats.write_rows.load(Ordering::Relaxed),
            hits: self.stats.hits.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            evictions: self.stats.evictions.load(Ordering::Relaxed),
        }
    }

    /// The tile grid a GEMM of this shape maps to on this engine.
    pub fn grid(&self, k: usize, n: usize) -> TileGrid {
        TileGrid::new(k, n, self.cfg.array_rows, self.cfg.array_cols)
    }

    /// Register a row-major K×N ternary weight matrix for resident
    /// execution. The engine keeps the single weight copy (callers can
    /// drop theirs); its tiles are placed lazily by [`Self::gemm_resident`]
    /// and stay programmed until evicted or trashed by a streaming call.
    pub fn register_weight(&self, w: &[Trit], k: usize, n: usize) -> Result<WeightId> {
        ensure!(k > 0 && n > 0, "empty weight matrix ({k}×{n})");
        ensure!(w.len() == k * n, "weights must be k×n = {k}×{n}, got {} trits", w.len());
        let grid = self.grid(k, n);
        let mut reg =
            self.registry.write().unwrap_or_else(std::sync::PoisonError::into_inner);
        let id = reg.len();
        reg.push(Arc::new(RegisteredWeight {
            id,
            k,
            n,
            grid,
            tiles: grid.tiles(),
            w: w.to_vec(),
        }));
        Ok(WeightId(id))
    }

    /// Shape (k, n) of a registered weight.
    pub fn registered_shape(&self, id: WeightId) -> Option<(usize, usize)> {
        self.registry
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .get(id.0)
            .map(|r| (r.k, r.n))
    }

    /// Execute a ternary GEMM in streaming mode: `x` (row-major M×K
    /// trits) × `w` (row-major K×N trits) → row-major M×N i32 outputs,
    /// under the backend's MAC semantics (saturating per 16-row group for
    /// the CiM flavors, exact for near-memory). Every tile is programmed
    /// on every call. Deterministic: bit-identical to
    /// [`tiling::reference_gemm`] regardless of thread count.
    pub fn gemm(&self, x: &[Trit], w: &[Trit], m: usize, k: usize, n: usize) -> Result<Vec<i32>> {
        ensure!(m > 0, "empty batch (m = 0)");
        ensure!(k > 0 && n > 0, "empty GEMM ({k}×{n})");
        ensure!(x.len() == m * k, "x must be m×k = {m}×{k}, got {} trits", x.len());
        ensure!(w.len() == k * n, "w must be k×n = {k}×{n}, got {} trits", w.len());
        let grid = self.grid(k, n);
        let tiles = grid.tiles();
        let out = Mutex::new(vec![0i32; m * n]);
        let next = AtomicUsize::new(0);
        let workers = self.cfg.n_threads.clamp(1, self.pool.len()).min(tiles.len());
        std::thread::scope(|s| {
            for wid in 0..workers {
                let (tiles, out, next, grid) = (&tiles, &out, &next, &grid);
                s.spawn(move || self.run_tiles(wid, x, w, m, grid, tiles, next, out));
            }
        });
        self.stats.gemms.fetch_add(1, Ordering::Relaxed);
        Ok(out.into_inner().unwrap())
    }

    /// Execute a ternary GEMM against a registered weight in resident
    /// mode: tiles already placed in the pool are reused as-is
    /// (placement hit → no programming), missing tiles are placed via
    /// LRU eviction and programmed once. Bit-identical to the streaming
    /// path and to [`tiling::reference_gemm`] for any thread count and
    /// any cache state.
    pub fn gemm_resident(&self, id: WeightId, x: &[Trit], m: usize) -> Result<Vec<i32>> {
        let reg = {
            let registry =
                self.registry.read().unwrap_or_else(std::sync::PoisonError::into_inner);
            match registry.get(id.0) {
                Some(r) => Arc::clone(r),
                None => anyhow::bail!("unknown weight id {} (register_weight first)", id.0),
            }
        };
        ensure!(m > 0, "empty batch (m = 0)");
        ensure!(
            x.len() == m * reg.k,
            "x must be m×k = {m}×{}, got {} trits",
            reg.k,
            x.len()
        );
        let out = Mutex::new(vec![0i32; m * reg.n]);
        let next = AtomicUsize::new(0);
        let workers = self.cfg.n_threads.clamp(1, self.pool.len()).min(reg.tiles.len());
        std::thread::scope(|s| {
            for _ in 0..workers {
                let (reg, out, next) = (&reg, &out, &next);
                s.spawn(move || self.run_tiles_resident(reg, x, m, next, out));
            }
        });
        self.stats.gemms.fetch_add(1, Ordering::Relaxed);
        Ok(out.into_inner().unwrap())
    }

    /// Streaming worker loop: claim tiles off the shared counter, program
    /// this worker's own array, stream the batch, merge partials.
    #[allow(clippy::too_many_arguments)]
    fn run_tiles(
        &self,
        wid: usize,
        x: &[Trit],
        w: &[Trit],
        m: usize,
        grid: &TileGrid,
        tiles: &[tiling::Tile],
        next: &AtomicUsize,
        out: &Mutex<Vec<i32>>,
    ) {
        let (rows, cols) = (self.cfg.array_rows, self.cfg.array_cols);
        // This worker is about to overwrite its array: drop any resident
        // placement routed to it (lock order is always cache → pool).
        self.lock_cache().invalidate_slot(wid);
        let mut slot = self.lock_slot(wid);
        let mut wbuf = vec![0i8; rows * cols];
        let mut xbuf = vec![0i8; m * rows];
        loop {
            let ti = next.fetch_add(1, Ordering::Relaxed);
            let Some(tile) = tiles.get(ti) else { break };
            // Stream the tile's weights in (once per tile, weight-
            // stationary across the whole batch).
            tiling::extract_tile_weights(w, grid.k, grid.n, tile, rows, cols, &mut wbuf);
            slot.programmed = None;
            slot.arr.write_matrix(&wbuf);
            for r in 0..m {
                tiling::extract_tile_inputs(
                    &x[r * grid.k..(r + 1) * grid.k],
                    tile,
                    rows,
                    &mut xbuf[r * rows..(r + 1) * rows],
                );
            }
            let partial = slot.arr.dot_batch(&xbuf, m);
            self.merge_partial(out, &partial, tile, grid.n, m, cols);
            self.stats.tiles.fetch_add(1, Ordering::Relaxed);
            self.stats.write_rows.fetch_add(tile.k_len as u64, Ordering::Relaxed);
            self.stats
                .windows
                .fetch_add((m * tile.k_len.div_ceil(GROUP_ROWS)) as u64, Ordering::Relaxed);
            self.stats.macs.fetch_add((m * tile.k_len * tile.n_len) as u64, Ordering::Relaxed);
        }
    }

    /// Resident worker loop: claim tiles, route each through the
    /// placement cache, program only when the slot's content tag does
    /// not already hold the tile, stream the batch, merge partials.
    fn run_tiles_resident(
        &self,
        reg: &RegisteredWeight,
        x: &[Trit],
        m: usize,
        next: &AtomicUsize,
        out: &Mutex<Vec<i32>>,
    ) {
        let (rows, cols) = (self.cfg.array_rows, self.cfg.array_cols);
        // Weight buffer is only needed on a miss; the steady-state
        // all-hit serving path never allocates it.
        let mut wbuf: Vec<i8> = Vec::new();
        let mut xbuf = vec![0i8; m * rows];
        loop {
            let ti = next.fetch_add(1, Ordering::Relaxed);
            let Some(tile) = reg.tiles.get(ti) else { break };
            let key: TileKey = (reg.id, ti);
            let placement = self.lock_cache().place(key);
            if placement.hit {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
            } else {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                if placement.evicted {
                    self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
            let mut slot = self.lock_slot(placement.slot);
            if slot.programmed != Some(key) {
                if wbuf.is_empty() {
                    wbuf = vec![0i8; rows * cols];
                }
                tiling::extract_tile_weights(
                    &reg.w, reg.grid.k, reg.grid.n, tile, rows, cols, &mut wbuf,
                );
                // Tag is cleared across the write so an interrupted
                // programming pass can never masquerade as a valid tile.
                slot.programmed = None;
                slot.arr.write_matrix(&wbuf);
                slot.programmed = Some(key);
                self.stats.tiles.fetch_add(1, Ordering::Relaxed);
                self.stats.write_rows.fetch_add(tile.k_len as u64, Ordering::Relaxed);
            }
            for r in 0..m {
                tiling::extract_tile_inputs(
                    &x[r * reg.grid.k..(r + 1) * reg.grid.k],
                    tile,
                    rows,
                    &mut xbuf[r * rows..(r + 1) * rows],
                );
            }
            let partial = slot.arr.dot_batch(&xbuf, m);
            drop(slot);
            self.merge_partial(out, &partial, tile, reg.grid.n, m, cols);
            self.stats
                .windows
                .fetch_add((m * tile.k_len.div_ceil(GROUP_ROWS)) as u64, Ordering::Relaxed);
            self.stats.macs.fetch_add((m * tile.k_len * tile.n_len) as u64, Ordering::Relaxed);
        }
    }

    /// Accumulate one tile's batch of partial products into the shared
    /// output (i32 addition commutes, so merge order never matters).
    fn merge_partial(
        &self,
        out: &Mutex<Vec<i32>>,
        partial: &[i32],
        tile: &tiling::Tile,
        n: usize,
        m: usize,
        cols: usize,
    ) {
        let mut o = out.lock().unwrap();
        for r in 0..m {
            let src = &partial[r * cols..r * cols + tile.n_len];
            let base = r * n + tile.n0;
            for (d, s) in o[base..base + tile.n_len].iter_mut().zip(src) {
                *d += s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::mac::Flavor;
    use crate::util::rng::Rng;

    fn small_engine(design: Design, threads: usize) -> TernaryGemmEngine {
        TernaryGemmEngine::new(
            EngineConfig::new(design, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_pool(4)
                .with_threads(threads),
        )
    }

    #[test]
    fn gemm_matches_tiled_reference_all_designs() {
        let mut rng = Rng::new(41);
        let (m, k, n) = (3usize, 150usize, 50usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        for design in Design::ALL {
            let eng = small_engine(design, 2);
            let got = eng.gemm(&x, &w, m, k, n).unwrap();
            let want = tiling::reference_gemm(&x, &w, m, &eng.grid(k, n), design.flavor());
            assert_eq!(got, want, "{design:?}");
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = Rng::new(42);
        let (m, k, n) = (2usize, 200usize, 90usize);
        let x = rng.ternary_vec(m * k, 0.4);
        let w = rng.ternary_vec(k * n, 0.4);
        let single = small_engine(Design::Cim1, 1).gemm(&x, &w, m, k, n).unwrap();
        let multi = small_engine(Design::Cim1, 4).gemm(&x, &w, m, k, n).unwrap();
        assert_eq!(single, multi);
    }

    #[test]
    fn stats_account_tiles_windows_and_macs() {
        let mut rng = Rng::new(43);
        // k = 100 on 64-row arrays: the second k-tile holds 36 rows, so
        // its windows must count ⌈36/16⌉ = 3, not 64/16 = 4 (the ragged
        // partial-tile accounting bug this pins down).
        let (m, k, n) = (2usize, 100usize, 40usize);
        let eng = small_engine(Design::Cim2, 2);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        let _ = eng.gemm(&x, &w, m, k, n).unwrap();
        let s = eng.stats();
        let grid = eng.grid(k, n);
        assert_eq!(s.gemms, 1);
        assert_eq!(s.tiles, grid.n_tiles_total() as u64);
        assert_eq!(s.macs, (m * k * n) as u64);
        // ⌈100/16⌉ = 7 windows per vector per n-stripe, 2 n-stripes.
        assert_eq!(s.windows, (m * k.div_ceil(GROUP_ROWS) * grid.n_tiles) as u64);
        assert_eq!(s.windows, 28);
        // Occupied rows only: K rows per n-stripe.
        assert_eq!(s.write_rows, (k * grid.n_tiles) as u64);
    }

    #[test]
    fn gemm_shape_violations_are_errors_not_panics() {
        let eng = small_engine(Design::Cim1, 1);
        let x_short = vec![0i8; 10];
        let x_full = vec![0i8; 64];
        let w = vec![0i8; 64 * 32];
        assert!(eng.gemm(&x_short, &w, 0, 64, 32).is_err(), "m = 0");
        assert!(eng.gemm(&x_short, &w, 1, 64, 32).is_err(), "bad x len");
        assert!(eng.gemm(&x_full, &w, 1, 64, 31).is_err(), "bad w len");
        assert!(eng.gemm(&x_full, &w, 1, 0, 32).is_err(), "k = 0");
        // The engine still works after rejecting bad shapes.
        let mut rng = Rng::new(7);
        let x = rng.ternary_vec(64, 0.5);
        let w = rng.ternary_vec(64 * 32, 0.5);
        assert!(eng.gemm(&x, &w, 1, 64, 32).is_ok());
    }

    #[test]
    fn single_tile_gemm_equals_plain_dot() {
        let mut rng = Rng::new(44);
        let eng = small_engine(Design::Cim1, 1);
        let x = rng.ternary_vec(64, 0.5);
        let w = rng.ternary_vec(64 * 32, 0.5);
        let got = eng.gemm(&x, &w, 1, 64, 32).unwrap();
        let mut storage = crate::array::TernaryStorage::new(64, 32);
        storage.write_matrix(&w);
        assert_eq!(got, crate::array::mac::dot_ref(&storage, &x, Flavor::Cim1));
    }

    #[test]
    fn resident_matches_streaming_and_counts_hits() {
        let mut rng = Rng::new(45);
        let (m, k, n) = (2usize, 150usize, 60usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        for design in Design::ALL {
            // Pool of 6 ≥ the 3×2 = 6 tiles: fully resident.
            let eng = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_pool(6)
                    .with_threads(2),
            );
            let id = eng.register_weight(&w, k, n).unwrap();
            let n_tiles = eng.grid(k, n).n_tiles_total() as u64;
            let streaming = eng.gemm(&x, &w, m, k, n).unwrap();
            let r1 = eng.gemm_resident(id, &x, m).unwrap();
            let r2 = eng.gemm_resident(id, &x, m).unwrap();
            assert_eq!(r1, streaming, "{design:?} resident vs streaming");
            assert_eq!(r2, streaming, "{design:?} warm resident vs streaming");
            let s = eng.stats();
            assert_eq!(s.misses, n_tiles, "{design:?} cold pass places every tile");
            assert_eq!(s.hits, n_tiles, "{design:?} warm pass hits every tile");
            assert_eq!(s.evictions, 0, "{design:?} fully-resident set never evicts");
        }
    }

    #[test]
    fn resident_rejects_bad_inputs() {
        let eng = small_engine(Design::Cim1, 1);
        let mut rng = Rng::new(46);
        let w = rng.ternary_vec(64 * 32, 0.5);
        assert!(eng.register_weight(&w, 64, 31).is_err(), "len mismatch");
        assert!(eng.register_weight(&w, 0, 32).is_err(), "k = 0");
        let id = eng.register_weight(&w, 64, 32).unwrap();
        assert_eq!(eng.registered_shape(id), Some((64, 32)));
        let x = rng.ternary_vec(64, 0.5);
        assert!(eng.gemm_resident(id, &x, 0).is_err(), "m = 0");
        assert!(eng.gemm_resident(id, &x[..10], 1).is_err(), "bad x len");
        assert!(eng.gemm_resident(WeightId(99), &x, 1).is_err(), "unknown id");
        assert!(eng.gemm_resident(id, &x, 1).is_ok());
    }
}
