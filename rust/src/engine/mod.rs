//! The ternary GEMM execution engine: tiles arbitrary M×K×N ternary
//! GEMMs across a pool of functional [`CimArray`] backends and runs the
//! tiles on worker threads — the functional counterpart of the analytic
//! `arch::Accelerator` (which only *accounts* for this work).
//!
//! Mapping (same weight-stationary scheme as `arch::mapper::map_layer`):
//! K → array rows, N → array columns, zero-padded at the edges (inert —
//! see [`tiling`]). Placement granularity is independent of the physical
//! arrays: a grid's tiles split into array-fitting [`tiling::Shard`]s,
//! and each shard executes on a 16-row-aligned *region* (sub-rectangle)
//! of one array, so several small shards pack into one array and one
//! oversized tile shards across arrays. Partial products accumulate into
//! per-n-stripe accumulators (i32 addition is order-independent, so
//! single- and multi-threaded runs are bit-identical).
//!
//! Two execution paths share the pool:
//!
//! - **Streaming** ([`TernaryGemmEngine::gemm`]): every worker programs
//!   its own array once per claimed shard and streams the batch through —
//!   the paper's batch-1 accounting, where weights are re-programmed on
//!   every call.
//! - **Resident** ([`TernaryGemmEngine::register_weight`] +
//!   [`TernaryGemmEngine::gemm_resident`]): weights are registered once;
//!   a second-chance [`resident::TileCache`] places their shards onto regions
//!   across the pool and a region is only (re)programmed on a cache
//!   miss, so steady-state serving pays zero weight-programming — the
//!   paper's actual weight-stationary premise. Cache hit/miss/evict
//!   counters land in [`EngineStats`].
//!
//! The pool is sized either directly ([`EngineConfig::with_pool`]) or by
//! a word budget ([`EngineConfig::with_capacity_words`] — e.g. the
//! paper's 2 M words = 32 arrays of 256×256), in which case a working
//! set larger than the budget serves under second-chance eviction
//! pressure with measured hit rates, still bit-exact.
//!
//! # Execution: the persistent stripe-scheduled executor
//!
//! Since PR 4 the engine no longer spawns scoped threads per call.
//! [`TernaryGemmEngine::new`] starts a long-lived worker pool
//! ([`exec::Executor`]); `gemm`/`gemm_resident` decompose into one work
//! item per shard (each shard belongs to exactly one n-stripe of the
//! output), enqueue them — resident shards with a known placement
//! prefer the worker that owns their array, spilling to the shallowest
//! queue under load skew (see [`AffinityMode`]) — and block until the
//! job drains. Partials merge into per-n-stripe accumulators instead of
//! one global output mutex. Shard MACs execute through the
//! region-scoped [`crate::array::CimArray::dot_batch_region`] kernels,
//! so a packed small tile costs wall-clock proportional to its occupied
//! rows × columns — matching what the cycle accounting already claims —
//! rather than a full-array `dot_batch` that gets sliced. See `exec`
//! for the queue/affinity design.
//!
//! Since PR 5 the data path is zero-copy: job operands are shared
//! `Arc<[Trit]>` planes ([`TernaryGemmEngine::gemm_arc`] /
//! [`TernaryGemmEngine::gemm_resident_arc`] /
//! [`TernaryGemmEngine::register_weight_arc`]; the slice-based surface
//! delegates with exactly one copy at the boundary), and each worker
//! reuses monotonically-grown weight/input/partial scratch buffers, so
//! steady-state streaming performs zero per-item heap allocations in
//! the executor data path.
//!
//! # Multi-tenant pools and plan-programmed cold start
//!
//! Since PR 7 the pool can be partitioned between tenants
//! ([`TernaryGemmEngine::reserve_tenant`] carves a hard reservation out
//! of the shared partition; weights registered via
//! [`TernaryGemmEngine::register_weight_arc_in`] place only inside
//! their tenant's slots — see `resident`'s module docs), every
//! placement/programming counter is additionally charged to a
//! per-tenant book ([`TernaryGemmEngine::tenant_stats`], summing to the
//! global [`EngineStats`]), and a registered weight can be programmed
//! wholesale from an AOT placement plan
//! ([`TernaryGemmEngine::program_from_plan`]) so cold start replays the
//! artifact instead of discovering placement on first traffic —
//! plan-programming is charged to the separate `plan_write_rows`
//! counter so amortized-residency accounting can distinguish the
//! one-time load from traffic-driven re-programming.
//!
//! The specification for both paths is [`tiling::reference_gemm`] (tile
//! shape = array shape, the default) or the general
//! [`tiling::reference_gemm_sharded`] — `mac::dot_ref` composed over
//! array-shaped shard images — and both match it bit-for-bit for all
//! three backends, any thread count, any cache/capacity state and any
//! interleaving of concurrent submissions (tests/cim_conformance.rs,
//! tests/eviction_pressure.rs, tests/region_kernels.rs,
//! tests/executor_stress.rs).

mod exec;
pub mod resident;
pub mod tiling;

pub use self::exec::{AffinityMode, ExecStatsSnapshot};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, ensure, Context, Result};

use crate::array::area::Design;
use crate::array::encoding::Trit;
use crate::array::mac::GROUP_ROWS;
use crate::array::{make_array, CimArray};
use crate::device::Tech;
use self::exec::{Executor, GemmJob, JobKind, WorkItem, WorkerScratch};
use self::resident::{RegisteredWeight, TileCache, TileKey, WeightId, SHARED_PARTITION};
pub use self::resident::{plan_layout, PlannedShard};
use self::tiling::{Rect, Shard, TileGrid};

/// Engine shape: which backend design/tech, the array geometry, the pool
/// size (direct or word-budgeted), the placement tile shape and the
/// worker-thread count.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub design: Design,
    pub tech: Tech,
    /// Rows per array (K capacity per tile); multiple of 16.
    pub array_rows: usize,
    /// Columns per array (N capacity per tile).
    pub array_cols: usize,
    /// Arrays in the pool (the paper's system has 32). Overridden by
    /// `capacity_words` when that is set.
    pub n_arrays: usize,
    /// Worker threads (clamped to the pool size; 1 = single-threaded).
    pub n_threads: usize,
    /// Placement-granularity tile shape (`None` = the physical array
    /// shape). Rows must be a multiple of 16. Tiles smaller than an
    /// array pack several to an array; larger tiles shard across arrays.
    pub tile_rows: Option<usize>,
    pub tile_cols: Option<usize>,
    /// Capacity-bounded pool mode: size the pool to this many ternary
    /// words — ⌊words / array_words⌋ arrays (never exceeding the
    /// budget), with a floor of one array — and serve under second-chance eviction
    /// pressure when the working set is larger.
    pub capacity_words: Option<u64>,
    /// How submissions choose a worker queue (the schedule-replay test
    /// harness forces degenerate orders; production uses the default
    /// load-aware policy).
    pub affinity: AffinityMode,
    /// Load-aware spill threshold: a placed shard leaves its owning
    /// worker's queue for the shallowest one when the owner's queue
    /// holds at least `ratio × (shallowest depth + 1)` items.
    pub spill_depth_ratio: usize,
}

impl EngineConfig {
    /// The paper's system shape: 32 arrays of 256×256, one worker per
    /// available core.
    pub fn new(design: Design, tech: Tech) -> EngineConfig {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        EngineConfig {
            design,
            tech,
            array_rows: 256,
            array_cols: 256,
            n_arrays: 32,
            n_threads: threads.min(32),
            tile_rows: None,
            tile_cols: None,
            capacity_words: None,
            affinity: AffinityMode::LoadAware,
            spill_depth_ratio: 4,
        }
    }

    pub fn with_threads(mut self, n_threads: usize) -> EngineConfig {
        self.n_threads = n_threads.max(1);
        self
    }

    pub fn with_pool(mut self, n_arrays: usize) -> EngineConfig {
        self.n_arrays = n_arrays.max(1);
        self
    }

    pub fn with_array_dims(mut self, rows: usize, cols: usize) -> EngineConfig {
        self.array_rows = rows;
        self.array_cols = cols;
        self
    }

    /// Decouple placement granularity from the physical array shape.
    pub fn with_tile_dims(mut self, rows: usize, cols: usize) -> EngineConfig {
        assert!(
            rows > 0 && rows % GROUP_ROWS == 0,
            "tile rows must be a positive multiple of {GROUP_ROWS}"
        );
        assert!(cols > 0, "tiles must have columns");
        self.tile_rows = Some(rows);
        self.tile_cols = Some(cols);
        self
    }

    /// Bound the pool by a ternary-word budget instead of an array count
    /// (the paper's system capacity is 2 M words = 32 arrays of 256×256).
    pub fn with_capacity_words(mut self, words: u64) -> EngineConfig {
        self.capacity_words = Some(words);
        self
    }

    /// Override the submission policy (schedule-replay harness; see
    /// [`AffinityMode`]).
    pub fn with_affinity(mut self, mode: AffinityMode) -> EngineConfig {
        self.affinity = mode;
        self
    }

    /// Tune the load-aware spill threshold (clamped to ≥ 1; 1 = spill as
    /// soon as the preferred queue is deeper than the shallowest).
    pub fn with_spill_ratio(mut self, ratio: usize) -> EngineConfig {
        self.spill_depth_ratio = ratio.max(1);
        self
    }

    /// Placement tile rows (the array rows unless decoupled).
    pub fn tile_rows(&self) -> usize {
        self.tile_rows.unwrap_or(self.array_rows)
    }

    /// Placement tile columns (the array columns unless decoupled).
    pub fn tile_cols(&self) -> usize {
        self.tile_cols.unwrap_or(self.array_cols)
    }

    /// Arrays the pool will actually hold: ⌊capacity / array_words⌋ (at
    /// least one) when word-bounded, else `n_arrays`.
    pub fn pool_arrays(&self) -> usize {
        match self.capacity_words {
            Some(w) => ((w / (self.array_rows * self.array_cols) as u64) as usize).max(1),
            None => self.n_arrays,
        }
    }

    /// Tiles a K×N weight matrix occupies at *array* granularity — a
    /// conservative pool size for keeping it fully resident (packing can
    /// need fewer arrays, never more).
    pub fn tiles_for(&self, k: usize, n: usize) -> usize {
        k.div_ceil(self.array_rows) * n.div_ceil(self.array_cols)
    }
}

/// Cumulative work counters (functional-simulation accounting, feeding
/// the co-simulation cross-checks and the benches).
///
/// `tiles`/`write_rows` count *actual array programming* (content
/// level); `hits`/`misses`/`evictions` count resident-cache placement
/// lookups. The two can drift under adversarial interleavings (e.g. a
/// streaming call trashing a placed region makes the next resident
/// access a placement hit that still re-programs), which is exactly what
/// the split is meant to surface.
#[derive(Debug, Default)]
pub struct EngineStats {
    gemms: AtomicU64,
    tiles: AtomicU64,
    windows: AtomicU64,
    macs: AtomicU64,
    write_rows: AtomicU64,
    plan_write_rows: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl EngineStats {
    fn snapshot(&self) -> EngineStatsSnapshot {
        EngineStatsSnapshot {
            gemms: self.gemms.load(Ordering::Relaxed),
            tiles: self.tiles.load(Ordering::Relaxed),
            windows: self.windows.load(Ordering::Relaxed),
            macs: self.macs.load(Ordering::Relaxed),
            write_rows: self.write_rows.load(Ordering::Relaxed),
            plan_write_rows: self.plan_write_rows.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`EngineStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStatsSnapshot {
    pub gemms: u64,
    /// Weight shards actually programmed into array cells. One per tile
    /// when the tile shape is the array shape (the default).
    pub tiles: u64,
    /// 16-row MAC windows executed across all shards and input vectors.
    /// Partial k-shards only count their occupied windows (⌈k_len/16⌉),
    /// matching `arch::mapper::map_layer`.
    pub windows: u64,
    /// Useful multiply-accumulates covered (excludes padding).
    pub macs: u64,
    /// Occupied weight rows programmed by *traffic* (streaming calls and
    /// resident discovery/re-programming; matches mapper `write_rows`).
    pub write_rows: u64,
    /// Occupied weight rows programmed by [`TernaryGemmEngine::program_from_plan`]
    /// — the one-time AOT cold-start charge, kept out of `write_rows` so
    /// amortized-residency accounting is not polluted by plan replay.
    pub plan_write_rows: u64,
    /// Resident-cache placement hits (shard already routed to a region).
    pub hits: u64,
    /// Resident-cache placement misses (shard had to be placed).
    pub misses: u64,
    /// Resident regions displaced by placements (second-chance victims).
    pub evictions: u64,
}

/// Per-pipeline-stage flush accounting: how many merged flushes touched
/// a given layer stage and how many activation rows they carried in
/// total. The layer-pipelined serving path admits rows at every layer
/// boundary, so under continuous arrivals `rows / flushes` *grows* with
/// the stage index relative to layer-0-only admission — that growth is
/// the utilization the pipeline exists to buy, and this counter is how
/// benches and the metrics report observe it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageFlushSnapshot {
    /// Layer index the stage executed.
    pub stage: usize,
    /// Merged GEMM flushes that ran this stage.
    pub flushes: u64,
    /// Total activation rows those flushes carried (M summed).
    pub rows: u64,
}

impl StageFlushSnapshot {
    /// Mean merged rows per flush at this stage (0 before any flush).
    pub fn rows_per_flush(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.rows as f64 / self.flushes as f64
        }
    }
}

impl EngineStatsSnapshot {
    /// Resident placement hit rate over all lookups so far (0 when no
    /// resident lookup has happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// The counter deltas accumulated since an earlier snapshot of the
    /// same engine (counters are monotonic), e.g.
    /// `engine.stats().since(&before).hit_rate()` for a measurement
    /// window's hit rate.
    pub fn since(&self, before: &EngineStatsSnapshot) -> EngineStatsSnapshot {
        EngineStatsSnapshot {
            gemms: self.gemms - before.gemms,
            tiles: self.tiles - before.tiles,
            windows: self.windows - before.windows,
            macs: self.macs - before.macs,
            write_rows: self.write_rows - before.write_rows,
            plan_write_rows: self.plan_write_rows - before.plan_write_rows,
            hits: self.hits - before.hits,
            misses: self.misses - before.misses,
            evictions: self.evictions - before.evictions,
        }
    }
}

/// One pool slot: the functional array plus per-region content tags —
/// which placed rects currently hold which shard key (empty after the
/// streaming path borrowed the array). Tags are authoritative for array
/// *content*; the placement cache is only routing. A resident worker
/// re-programs whenever its (rect, key) tag is absent, which keeps every
/// interleaving of streaming/resident/concurrent callers bit-exact.
struct PoolSlot {
    arr: Box<dyn CimArray>,
    programmed: Vec<(Rect, TileKey)>,
}

impl PoolSlot {
    fn holds(&self, rect: &Rect, key: TileKey) -> bool {
        self.programmed.iter().any(|(r, k)| r == rect && *k == key)
    }

    /// Drop every tag whose cells a write to `rect` will clobber.
    fn clear_overlapping(&mut self, rect: &Rect) {
        self.programmed.retain(|(r, _)| !r.overlaps(rect));
    }
}

/// The engine's shared state: configuration, array pool, placement
/// cache, weight registry and work counters. The executor's worker
/// threads hold an `Arc` of this; the public [`TernaryGemmEngine`] is a
/// handle over it plus the executor itself.
pub(crate) struct EngineCore {
    cfg: EngineConfig,
    pool: Vec<Mutex<PoolSlot>>,
    stats: EngineStats,
    /// Second-chance placement of registered shards onto pool regions.
    cache: Mutex<TileCache>,
    /// Registered weights by id (ids are never reused).
    registry: RwLock<Vec<Arc<RegisteredWeight>>>,
    /// Per-tenant work counter books, indexed by cache partition (entry
    /// 0 = shared partition; grown by `reserve_tenant`). Every charge to
    /// the global `stats` book is mirrored into exactly one tenant book,
    /// so tenant books always sum to the global counters.
    tenant_stats: RwLock<Vec<Arc<EngineStats>>>,
    /// Per-layer-stage `(flushes, rows)` flush counters, indexed by
    /// stage and grown on first use (the engine does not know network
    /// depth up front). Charged by the coordinator's per-layer resident
    /// path; a plain mutex is fine — one charge per layer per merged
    /// flush, not per work item.
    stage_flushes: Mutex<Vec<(u64, u64)>>,
}

impl EngineCore {
    /// Lock a pool slot, recovering from poisoning. The engine is shared
    /// across serving workers that catch panics and keep going; a panic
    /// mid-programming must not brick every later request. Recovery is
    /// safe because a region's tag is cleared *before* any write to its
    /// rect and only restored after it completes — an interrupted write
    /// leaves the region untagged, so the next user re-programs it.
    fn lock_slot(&self, slot: usize) -> std::sync::MutexGuard<'_, PoolSlot> {
        self.pool[slot].lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Lock the placement cache, recovering from poisoning (the cache is
    /// routing only — stale routing at worst costs a re-program).
    fn lock_cache(&self) -> std::sync::MutexGuard<'_, TileCache> {
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Physical arrays in the pool (the executor sizes its worker count
    /// by this).
    pub(crate) fn pool_len(&self) -> usize {
        self.pool.len()
    }

    /// The per-tenant stats book for `partition` (0 = shared). A book is
    /// created before any weight can name its partition
    /// (`reserve_tenant`), so the index is always present.
    fn tenant(&self, partition: usize) -> Arc<EngineStats> {
        let books = self.tenant_stats.read().unwrap_or_else(std::sync::PoisonError::into_inner);
        Arc::clone(&books[partition])
    }

    /// Execute one queued work item: run its shard's region-scoped MAC
    /// through the worker's reusable scratch buffers and merge the
    /// partial into the job's n-stripe accumulator. Called from executor
    /// worker threads; `worker` is the executing worker's index (= the
    /// pool slot it owns for streaming work). Steady state performs zero
    /// per-item heap allocations here: operands are shared `Arc` planes
    /// and the scratch buffers only grow.
    pub(crate) fn run_item(&self, worker: usize, item: &WorkItem, scratch: &mut WorkerScratch) {
        let job = &item.job;
        let shard = &job.shards()[item.shard];
        match &job.kind {
            JobKind::Streaming { x, w, grid, .. } => {
                self.exec_streaming_shard(worker, x, w, job.m, grid, shard, scratch);
            }
            JobKind::Resident { reg, x } => {
                self.exec_resident_shard(reg, x, job.m, item.shard, shard, scratch);
            }
        }
        job.merge(shard, &scratch.partial);
    }

    /// Streaming shard: program this worker's own array (only the
    /// shard's region — everything else is never read) and run the
    /// region-scoped batch MAC at the array's top-left. The partial
    /// lands in `scratch.partial`.
    #[allow(clippy::too_many_arguments)]
    fn exec_streaming_shard(
        &self,
        slot_idx: usize,
        x: &[Trit],
        w: &[Trit],
        m: usize,
        grid: &TileGrid,
        shard: &Shard,
        scratch: &mut WorkerScratch,
    ) {
        let rect = Rect { row0: 0, rows: shard.padded_rows(), col0: 0, cols: shard.n_len };
        // This worker is about to overwrite its array: drop any resident
        // placement routed to it (lock order is always cache → pool).
        self.lock_cache().invalidate_slot(slot_idx);
        let mut slot = self.lock_slot(slot_idx);
        // Size only: `extract_shard_weights` zero-fills the whole image
        // itself, so stable-shape reuse does no redundant clearing.
        scratch.wbuf.resize(rect.rows * rect.cols, 0);
        tiling::extract_shard_weights(
            w, grid.k, grid.n, shard, rect.rows, rect.cols, &mut scratch.wbuf,
        );
        slot.programmed.clear();
        slot.arr.write_region(0, 0, rect.rows, rect.cols, &scratch.wbuf);
        extract_batch_inputs(x, grid.k, shard, m, rect.rows, &mut scratch.xbuf);
        slot.arr.dot_batch_region_scratch_into(
            &rect,
            &scratch.xbuf,
            m,
            &mut scratch.region,
            &mut scratch.partial,
        );
        drop(slot);
        let windows = (m * shard.k_len.div_ceil(GROUP_ROWS)) as u64;
        // Streaming work is tenant-less; it charges the shared book so
        // tenant books still sum to the global counters.
        let book = self.tenant(SHARED_PARTITION);
        for s in [&self.stats, &*book] {
            s.tiles.fetch_add(1, Ordering::Relaxed);
            s.write_rows.fetch_add(shard.k_len as u64, Ordering::Relaxed);
            s.windows.fetch_add(windows, Ordering::Relaxed);
            s.macs.fetch_add((m * shard.k_len * shard.n_len) as u64, Ordering::Relaxed);
        }
    }

    /// Resident shard: route through the placement cache to a region,
    /// program only when the region's content tag does not already hold
    /// the shard, run the region-scoped batch MAC in place. The partial
    /// lands in `scratch.partial`.
    fn exec_resident_shard(
        &self,
        reg: &RegisteredWeight,
        x: &[Trit],
        m: usize,
        shard_idx: usize,
        shard: &Shard,
        scratch: &mut WorkerScratch,
    ) {
        let key: TileKey = (reg.id, shard_idx);
        let book = self.tenant(reg.partition);
        let placement = self.lock_cache().place_in(reg.partition, key, shard.k_len, shard.n_len);
        for s in [&self.stats, &*book] {
            if placement.hit {
                s.hits.fetch_add(1, Ordering::Relaxed);
            } else {
                s.misses.fetch_add(1, Ordering::Relaxed);
                s.evictions.fetch_add(placement.evicted, Ordering::Relaxed);
            }
        }
        let rect = placement.rect;
        let mut slot = self.lock_slot(placement.slot);
        if !slot.holds(&rect, key) {
            scratch.wbuf.resize(rect.rows * rect.cols, 0);
            tiling::extract_shard_weights(
                &reg.w, reg.grid.k, reg.grid.n, shard, rect.rows, rect.cols, &mut scratch.wbuf,
            );
            // Overlapping tags are dropped across the write so an
            // interrupted programming pass can never masquerade as a
            // valid region.
            slot.clear_overlapping(&rect);
            slot.arr.write_region(rect.row0, rect.col0, rect.rows, rect.cols, &scratch.wbuf);
            slot.programmed.push((rect, key));
            for s in [&self.stats, &*book] {
                s.tiles.fetch_add(1, Ordering::Relaxed);
                s.write_rows.fetch_add(shard.k_len as u64, Ordering::Relaxed);
            }
        }
        extract_batch_inputs(x, reg.grid.k, shard, m, rect.rows, &mut scratch.xbuf);
        slot.arr.dot_batch_region_scratch_into(
            &rect,
            &scratch.xbuf,
            m,
            &mut scratch.region,
            &mut scratch.partial,
        );
        drop(slot);
        let windows = (m * shard.k_len.div_ceil(GROUP_ROWS)) as u64;
        for s in [&self.stats, &*book] {
            s.windows.fetch_add(windows, Ordering::Relaxed);
            s.macs.fetch_add((m * shard.k_len * shard.n_len) as u64, Ordering::Relaxed);
        }
    }
}

/// Extract the shard's k-slice of every batch row into `buf` (resized
/// to `m × rect_rows`, capacity retained — the worker's input-slice
/// scratch). `extract_shard_inputs` zero-fills each row slice itself
/// and the loop covers every slice, so no separate clearing pass runs.
fn extract_batch_inputs(
    x: &[Trit],
    k: usize,
    shard: &Shard,
    m: usize,
    rect_rows: usize,
    buf: &mut Vec<Trit>,
) {
    buf.resize(m * rect_rows, 0);
    for r in 0..m {
        tiling::extract_shard_inputs(
            &x[r * k..(r + 1) * k],
            shard,
            0,
            &mut buf[r * rect_rows..(r + 1) * rect_rows],
        );
    }
}

/// Functional tiled ternary GEMM over a pool of [`CimArray`] backends,
/// executed by a persistent stripe-scheduled worker pool (see [`exec`]'s
/// module docs — per-slot affinity, work stealing, per-stripe merge).
pub struct TernaryGemmEngine {
    core: Arc<EngineCore>,
    exec: Executor,
}

impl TernaryGemmEngine {
    pub fn new(cfg: EngineConfig) -> TernaryGemmEngine {
        assert!(
            cfg.array_rows > 0 && cfg.array_rows % GROUP_ROWS == 0,
            "array_rows must be a positive multiple of {GROUP_ROWS}"
        );
        assert!(cfg.array_cols > 0);
        let n_arrays = cfg.pool_arrays();
        let pool = (0..n_arrays)
            .map(|_| {
                Mutex::new(PoolSlot {
                    arr: make_array(cfg.design, cfg.tech, cfg.array_rows, cfg.array_cols),
                    programmed: Vec::new(),
                })
            })
            .collect();
        let core = Arc::new(EngineCore {
            cache: Mutex::new(TileCache::new(n_arrays, cfg.array_rows, cfg.array_cols)),
            registry: RwLock::new(Vec::new()),
            cfg,
            pool,
            stats: EngineStats::default(),
            tenant_stats: RwLock::new(vec![Arc::new(EngineStats::default())]),
            stage_flushes: Mutex::new(Vec::new()),
        });
        let workers = core.cfg.n_threads.clamp(1, n_arrays);
        let exec = Executor::new(&core, workers);
        TernaryGemmEngine { core, exec }
    }

    pub fn cfg(&self) -> &EngineConfig {
        &self.core.cfg
    }

    /// Physical arrays in the pool.
    pub fn pool_arrays(&self) -> usize {
        self.core.pool.len()
    }

    /// Ternary-word capacity of the pool.
    pub fn capacity_words(&self) -> u64 {
        (self.core.pool.len() * self.core.cfg.array_rows * self.core.cfg.array_cols) as u64
    }

    /// Regions (placed shards) currently resident in the pool.
    pub fn resident_tiles(&self) -> usize {
        self.core.lock_cache().resident_regions()
    }

    pub fn stats(&self) -> EngineStatsSnapshot {
        self.core.stats.snapshot()
    }

    /// Per-tenant work counters: the same books as [`Self::stats`],
    /// charged by cache partition (0 = shared). Every global charge goes
    /// to exactly one tenant book, so across all tenants the books sum
    /// to the global counters.
    pub fn tenant_stats(&self, tenant: usize) -> EngineStatsSnapshot {
        self.core.tenant(tenant).snapshot()
    }

    /// Number of tenant partitions (≥ 1; partition 0 is the shared pool).
    pub fn n_tenants(&self) -> usize {
        self.core.lock_cache().n_partitions()
    }

    /// Pool arrays owned by tenant partition `tenant`.
    pub fn tenant_slots(&self, tenant: usize) -> usize {
        self.core.lock_cache().partition_slots(tenant).len()
    }

    /// Carve a hard-reserved tenant partition of ⌊`words` /
    /// array_words⌋ (min 1 — the same rounding as
    /// [`EngineConfig::pool_arrays`]) arrays out of the shared
    /// partition, returning the tenant id for
    /// [`Self::register_weight_arc_in`] / [`Self::tenant_stats`]. Takes
    /// the highest-numbered shared slots (their residents are
    /// invalidated, not moved) and fails when the reservation would
    /// leave the shared pool empty.
    pub fn reserve_tenant(&self, words: u64) -> Result<usize> {
        let array_words = (self.core.cfg.array_rows * self.core.cfg.array_cols) as u64;
        let slots = ((words / array_words) as usize).max(1);
        let tenant = self.core.lock_cache().reserve_partition(slots).with_context(|| {
            format!(
                "cannot reserve {slots} of {} pool arrays (the shared partition keeps at least one)",
                self.core.pool.len()
            )
        })?;
        let mut books =
            self.core.tenant_stats.write().unwrap_or_else(std::sync::PoisonError::into_inner);
        while books.len() <= tenant {
            books.push(Arc::new(EngineStats::default()));
        }
        Ok(tenant)
    }

    /// Charge one merged flush of `rows` activation rows to layer stage
    /// `stage`'s flush book. Called by the coordinator's per-layer
    /// resident path (serial and pipelined alike), so
    /// [`Self::stage_flush_stats`] reports real per-stage M regardless
    /// of admission policy.
    pub fn note_stage_flush(&self, stage: usize, rows: usize) {
        let mut book =
            self.core.stage_flushes.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if book.len() <= stage {
            book.resize(stage + 1, (0, 0));
        }
        book[stage].0 += 1;
        book[stage].1 += rows as u64;
    }

    /// Per-stage flush counters charged via [`Self::note_stage_flush`],
    /// one entry per layer stage seen so far (empty before any charge).
    pub fn stage_flush_stats(&self) -> Vec<StageFlushSnapshot> {
        let book =
            self.core.stage_flushes.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        book.iter()
            .enumerate()
            .map(|(stage, &(flushes, rows))| StageFlushSnapshot { stage, flushes, rows })
            .collect()
    }

    /// Executor counters: items submitted/executed, the
    /// affine/stolen/spilled split, deepest queue seen, panics survived.
    pub fn exec_stats(&self) -> ExecStatsSnapshot {
        self.exec.stats()
    }

    /// Live executor backlog: work items currently queued across all
    /// executor workers (relaxed counters — approximate under
    /// concurrent submission). This is the scrapeable companion to
    /// [`ExecStatsSnapshot::queue_depth_max`], and the signal ingress
    /// load-shedding watermarks are tuned against.
    pub fn exec_queue_depth(&self) -> u64 {
        self.exec.queue_depth()
    }

    /// The tile grid a GEMM of this shape maps to on this engine's
    /// placement granularity (the array shape unless decoupled).
    pub fn grid(&self, k: usize, n: usize) -> TileGrid {
        TileGrid::new(k, n, self.core.cfg.tile_rows(), self.core.cfg.tile_cols())
    }

    /// Register a row-major K×N ternary weight matrix for resident
    /// execution. The engine keeps the single weight copy (callers can
    /// drop theirs); its shards are placed lazily by
    /// [`Self::gemm_resident`] and stay programmed until evicted or
    /// trashed by a streaming call. One copy at this boundary; callers
    /// that already hold an `Arc` plane should use
    /// [`Self::register_weight_arc`] instead (zero copies).
    pub fn register_weight(&self, w: &[Trit], k: usize, n: usize) -> Result<WeightId> {
        self.register_weight_arc(Arc::from(w), k, n)
    }

    /// [`Self::register_weight`] without the copy: the registration
    /// shares the caller's weight plane, and every resident job shares
    /// it in turn (the plane is only read, never re-cloned).
    pub fn register_weight_arc(&self, w: Arc<[Trit]>, k: usize, n: usize) -> Result<WeightId> {
        self.register_weight_arc_in(w, k, n, SHARED_PARTITION)
    }

    /// [`Self::register_weight_arc`] into a tenant partition: the
    /// weight's shards place only onto the partition's slots and its
    /// work charges the partition's book. `tenant` must be 0 (shared) or
    /// an id returned by [`Self::reserve_tenant`].
    pub fn register_weight_arc_in(
        &self,
        w: Arc<[Trit]>,
        k: usize,
        n: usize,
        tenant: usize,
    ) -> Result<WeightId> {
        ensure!(k > 0 && n > 0, "empty weight matrix ({k}×{n})");
        ensure!(w.len() == k * n, "weights must be k×n = {k}×{n}, got {} trits", w.len());
        ensure!(
            tenant < self.n_tenants(),
            "unknown tenant partition {tenant} (reserve_tenant first)"
        );
        let grid = self.grid(k, n);
        let shards = grid.shards(self.core.cfg.array_rows, self.core.cfg.array_cols);
        let mut reg =
            self.core.registry.write().unwrap_or_else(std::sync::PoisonError::into_inner);
        let id = reg.len();
        reg.push(Arc::new(RegisteredWeight { id, k, n, grid, shards, w, partition: tenant }));
        Ok(WeightId(id))
    }

    /// Drop every placed region of `id` (placements and content tags),
    /// returning its space to its partition — the hot-swap path retires
    /// a drained model version this way. The registration itself stays
    /// (weight ids are never reused); a later resident call simply
    /// re-places and re-programs.
    pub fn invalidate_weight(&self, id: WeightId) {
        self.core.lock_cache().invalidate_weight(id.0);
        for s in 0..self.core.pool.len() {
            self.core.lock_slot(s).programmed.retain(|(_, key)| key.0 != id.0);
        }
    }

    /// Program a registered weight's shards straight from an AOT
    /// placement plan — the cold-start path that replaces discovery
    /// misses on first traffic. On an *empty* partition the replay is
    /// strict: every placement must land exactly where the plan says
    /// (partition-relative slot rank plus region origin), which pins the
    /// artifact's analytically-mirrored packing against the live
    /// allocator. On a non-empty partition (hot-swap programming a new
    /// version into headroom) placements go wherever first-fit plus
    /// eviction puts them — eager programming still avoids discovery
    /// misses, and bit-exactness never depends on *where* regions land
    /// (content tags are authoritative). Programming charges
    /// `plan_write_rows` (and `tiles`), not `write_rows`/`misses`, so
    /// amortized-residency accounting sees the one-time load separately
    /// from traffic-driven programming.
    pub fn program_from_plan(&self, id: WeightId, plan: &[PlannedShard]) -> Result<()> {
        let reg = {
            let registry =
                self.core.registry.read().unwrap_or_else(std::sync::PoisonError::into_inner);
            match registry.get(id.0) {
                Some(r) => Arc::clone(r),
                None => bail!("unknown weight id {} (register_weight first)", id.0),
            }
        };
        for p in plan {
            ensure!(
                p.shard < reg.shards.len(),
                "plan shard index {} out of range for a {}-shard weight",
                p.shard,
                reg.shards.len()
            );
            let s = &reg.shards[p.shard];
            ensure!(
                (s.k0, s.k_len, s.n0, s.n_len) == (p.k0, p.k_len, p.n0, p.n_len),
                "plan shard {} covers ({},{})+({},{}) but the engine decomposes it as \
                 ({},{})+({},{}) — regenerate the artifact for this array geometry",
                p.shard,
                p.k0,
                p.n0,
                p.k_len,
                p.n_len,
                s.k0,
                s.n0,
                s.k_len,
                s.n_len
            );
        }
        let strict = self.core.lock_cache().partition_resident(reg.partition) == 0;
        let book = self.core.tenant(reg.partition);
        let mut wbuf: Vec<Trit> = Vec::new();
        for p in plan {
            let shard = &reg.shards[p.shard];
            let key: TileKey = (reg.id, p.shard);
            let (placement, rank) = {
                let mut cache = self.core.lock_cache();
                let pl = cache.place_in(reg.partition, key, shard.k_len, shard.n_len);
                let rank = cache.slot_rank(reg.partition, pl.slot);
                (pl, rank)
            };
            if strict {
                ensure!(
                    !placement.hit
                        && placement.evicted == 0
                        && rank == Some(p.slot)
                        && placement.rect.row0 == p.row0
                        && placement.rect.col0 == p.col0,
                    "placement plan diverges at shard {}: plan says slot {} @ ({}, {}), engine \
                     placed slot rank {:?} @ ({}, {}) — the artifact was built with different \
                     packing rules",
                    p.shard,
                    p.slot,
                    p.row0,
                    p.col0,
                    rank,
                    placement.rect.row0,
                    placement.rect.col0
                );
            }
            let rect = placement.rect;
            let mut slot = self.core.lock_slot(placement.slot);
            if !slot.holds(&rect, key) {
                wbuf.resize(rect.rows * rect.cols, 0);
                tiling::extract_shard_weights(
                    &reg.w, reg.grid.k, reg.grid.n, shard, rect.rows, rect.cols, &mut wbuf,
                );
                slot.clear_overlapping(&rect);
                slot.arr.write_region(rect.row0, rect.col0, rect.rows, rect.cols, &wbuf);
                slot.programmed.push((rect, key));
                for s in [&self.core.stats, &*book] {
                    s.tiles.fetch_add(1, Ordering::Relaxed);
                    s.plan_write_rows.fetch_add(shard.k_len as u64, Ordering::Relaxed);
                }
            }
        }
        Ok(())
    }

    /// Shape (k, n) of a registered weight.
    pub fn registered_shape(&self, id: WeightId) -> Option<(usize, usize)> {
        self.core
            .registry
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .get(id.0)
            .map(|r| (r.k, r.n))
    }

    /// Execute a ternary GEMM in streaming mode: `x` (row-major M×K
    /// trits) × `w` (row-major K×N trits) → row-major M×N i32 outputs,
    /// under the backend's MAC semantics (saturating per 16-row group for
    /// the CiM flavors, exact for near-memory). Every shard is programmed
    /// on every call. The shards run as work items on the persistent
    /// executor (each on its executing worker's own array).
    /// Deterministic: bit-identical to
    /// [`tiling::reference_gemm_sharded`] regardless of thread count
    /// (= [`tiling::reference_gemm`] at the default tile shape). Pays
    /// one operand copy at this boundary; [`Self::gemm_arc`] pays none.
    pub fn gemm(&self, x: &[Trit], w: &[Trit], m: usize, k: usize, n: usize) -> Result<Vec<i32>> {
        self.gemm_arc(Arc::from(x), Arc::from(w), m, k, n)
    }

    /// [`Self::gemm`] with zero operand copies: the job shares the
    /// caller's `Arc` planes end to end — submission clones reference
    /// counts, the long-lived workers read the planes in place, and the
    /// caller keeps its handles. Bit-identical to [`Self::gemm`].
    pub fn gemm_arc(
        &self,
        x: Arc<[Trit]>,
        w: Arc<[Trit]>,
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<Vec<i32>> {
        ensure!(m > 0, "empty batch (m = 0)");
        ensure!(k > 0 && n > 0, "empty GEMM ({k}×{n})");
        ensure!(x.len() == m * k, "x must be m×k = {m}×{k}, got {} trits", x.len());
        ensure!(w.len() == k * n, "w must be k×n = {k}×{n}, got {} trits", w.len());
        let grid = self.grid(k, n);
        let shards = grid.shards(self.core.cfg.array_rows, self.core.cfg.array_cols);
        let hints = vec![None; shards.len()];
        let job = GemmJob::streaming(x, w, grid, shards, m, n);
        let out = self.exec.run(job, &hints)?;
        self.core.stats.gemms.fetch_add(1, Ordering::Relaxed);
        self.core.tenant(SHARED_PARTITION).gemms.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    /// Execute a ternary GEMM against a registered weight in resident
    /// mode: shards already placed in the pool are reused as-is
    /// (placement hit → no programming), missing shards are placed via
    /// second-chance region eviction and programmed once. Work items for
    /// already-placed shards prefer the worker that owns their array,
    /// spilling to the shallowest queue under load skew. Bit-identical
    /// to the streaming path and to the sharded reference for any thread
    /// count, any cache state, any pool capacity and any
    /// concurrent-submission interleaving. Pays one input copy at this
    /// boundary; [`Self::gemm_resident_arc`] pays none.
    pub fn gemm_resident(&self, id: WeightId, x: &[Trit], m: usize) -> Result<Vec<i32>> {
        self.gemm_resident_arc(id, Arc::from(x), m)
    }

    /// [`Self::gemm_resident`] with a shared input plane: the job holds
    /// the caller's `Arc` (and the registered weight's shared plane)
    /// instead of copies — the serving backend threads one activation
    /// plane through every layer this way.
    pub fn gemm_resident_arc(&self, id: WeightId, x: Arc<[Trit]>, m: usize) -> Result<Vec<i32>> {
        let reg = {
            let registry =
                self.core.registry.read().unwrap_or_else(std::sync::PoisonError::into_inner);
            match registry.get(id.0) {
                Some(r) => Arc::clone(r),
                None => anyhow::bail!("unknown weight id {} (register_weight first)", id.0),
            }
        };
        ensure!(m > 0, "empty batch (m = 0)");
        ensure!(
            x.len() == m * reg.k,
            "x must be m×k = {m}×{}, got {} trits",
            reg.k,
            x.len()
        );
        // Affinity probe: shards with a known placement prefer the
        // worker that owns their array (a read-only peek — routing is
        // not a use, so it leaves the second-chance bit alone).
        let hints: Vec<Option<usize>> = {
            let cache = self.core.lock_cache();
            (0..reg.shards.len()).map(|i| cache.peek_slot((reg.id, i))).collect()
        };
        let partition = reg.partition;
        let job = GemmJob::resident(reg, x, m);
        let out = self.exec.run(job, &hints)?;
        self.core.stats.gemms.fetch_add(1, Ordering::Relaxed);
        self.core.tenant(partition).gemms.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::tiling::reference_gemm_sharded;
    use super::*;
    use crate::array::mac::Flavor;
    use crate::util::rng::Rng;

    fn small_engine(design: Design, threads: usize) -> TernaryGemmEngine {
        TernaryGemmEngine::new(
            EngineConfig::new(design, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_pool(4)
                .with_threads(threads),
        )
    }

    #[test]
    fn gemm_matches_tiled_reference_all_designs() {
        let mut rng = Rng::new(41);
        let (m, k, n) = (3usize, 150usize, 50usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        for design in Design::ALL {
            let eng = small_engine(design, 2);
            let got = eng.gemm(&x, &w, m, k, n).unwrap();
            let want = tiling::reference_gemm(&x, &w, m, &eng.grid(k, n), design.flavor());
            assert_eq!(got, want, "{design:?}");
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = Rng::new(42);
        let (m, k, n) = (2usize, 200usize, 90usize);
        let x = rng.ternary_vec(m * k, 0.4);
        let w = rng.ternary_vec(k * n, 0.4);
        let single = small_engine(Design::Cim1, 1).gemm(&x, &w, m, k, n).unwrap();
        let multi = small_engine(Design::Cim1, 4).gemm(&x, &w, m, k, n).unwrap();
        assert_eq!(single, multi);
    }

    #[test]
    fn stats_account_tiles_windows_and_macs() {
        let mut rng = Rng::new(43);
        // k = 100 on 64-row arrays: the second k-tile holds 36 rows, so
        // its windows must count ⌈36/16⌉ = 3, not 64/16 = 4 (the ragged
        // partial-tile accounting bug this pins down).
        let (m, k, n) = (2usize, 100usize, 40usize);
        let eng = small_engine(Design::Cim2, 2);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        let _ = eng.gemm(&x, &w, m, k, n).unwrap();
        let s = eng.stats();
        let grid = eng.grid(k, n);
        assert_eq!(s.gemms, 1);
        assert_eq!(s.tiles, grid.n_tiles_total() as u64);
        assert_eq!(s.macs, (m * k * n) as u64);
        // ⌈100/16⌉ = 7 windows per vector per n-stripe, 2 n-stripes.
        assert_eq!(s.windows, (m * k.div_ceil(GROUP_ROWS) * grid.n_tiles) as u64);
        assert_eq!(s.windows, 28);
        // Occupied rows only: K rows per n-stripe.
        assert_eq!(s.write_rows, (k * grid.n_tiles) as u64);
    }

    #[test]
    fn gemm_shape_violations_are_errors_not_panics() {
        let eng = small_engine(Design::Cim1, 1);
        let x_short = vec![0i8; 10];
        let x_full = vec![0i8; 64];
        let w = vec![0i8; 64 * 32];
        assert!(eng.gemm(&x_short, &w, 0, 64, 32).is_err(), "m = 0");
        assert!(eng.gemm(&x_short, &w, 1, 64, 32).is_err(), "bad x len");
        assert!(eng.gemm(&x_full, &w, 1, 64, 31).is_err(), "bad w len");
        assert!(eng.gemm(&x_full, &w, 1, 0, 32).is_err(), "k = 0");
        // The engine still works after rejecting bad shapes.
        let mut rng = Rng::new(7);
        let x = rng.ternary_vec(64, 0.5);
        let w = rng.ternary_vec(64 * 32, 0.5);
        assert!(eng.gemm(&x, &w, 1, 64, 32).is_ok());
    }

    #[test]
    fn single_tile_gemm_equals_plain_dot() {
        let mut rng = Rng::new(44);
        let eng = small_engine(Design::Cim1, 1);
        let x = rng.ternary_vec(64, 0.5);
        let w = rng.ternary_vec(64 * 32, 0.5);
        let got = eng.gemm(&x, &w, 1, 64, 32).unwrap();
        let mut storage = crate::array::TernaryStorage::new(64, 32);
        storage.write_matrix(&w);
        assert_eq!(got, crate::array::mac::dot_ref(&storage, &x, Flavor::Cim1));
    }

    #[test]
    fn resident_matches_streaming_and_counts_hits() {
        let mut rng = Rng::new(45);
        let (m, k, n) = (2usize, 150usize, 60usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        for design in Design::ALL {
            // Pool of 6 ≥ the 3×2 = 6 tiles: fully resident.
            let eng = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_pool(6)
                    .with_threads(2),
            );
            let id = eng.register_weight(&w, k, n).unwrap();
            let n_tiles = eng.grid(k, n).n_tiles_total() as u64;
            let streaming = eng.gemm(&x, &w, m, k, n).unwrap();
            let r1 = eng.gemm_resident(id, &x, m).unwrap();
            let r2 = eng.gemm_resident(id, &x, m).unwrap();
            assert_eq!(r1, streaming, "{design:?} resident vs streaming");
            assert_eq!(r2, streaming, "{design:?} warm resident vs streaming");
            let s = eng.stats();
            assert_eq!(s.misses, n_tiles, "{design:?} cold pass places every tile");
            assert_eq!(s.hits, n_tiles, "{design:?} warm pass hits every tile");
            assert_eq!(s.evictions, 0, "{design:?} fully-resident set never evicts");
        }
    }

    #[test]
    fn resident_rejects_bad_inputs() {
        let eng = small_engine(Design::Cim1, 1);
        let mut rng = Rng::new(46);
        let w = rng.ternary_vec(64 * 32, 0.5);
        assert!(eng.register_weight(&w, 64, 31).is_err(), "len mismatch");
        assert!(eng.register_weight(&w, 0, 32).is_err(), "k = 0");
        let id = eng.register_weight(&w, 64, 32).unwrap();
        assert_eq!(eng.registered_shape(id), Some((64, 32)));
        let x = rng.ternary_vec(64, 0.5);
        assert!(eng.gemm_resident(id, &x, 0).is_err(), "m = 0");
        assert!(eng.gemm_resident(id, &x[..10], 1).is_err(), "bad x len");
        assert!(eng.gemm_resident(WeightId(99), &x, 1).is_err(), "unknown id");
        assert!(eng.gemm_resident(id, &x, 1).is_ok());
    }

    #[test]
    fn capacity_words_bound_the_pool_with_a_floor_of_one() {
        let cfg = EngineConfig::new(Design::Cim1, Tech::Femfet3T); // 256×256 arrays
        let paper = TernaryGemmEngine::new(cfg.clone().with_capacity_words(2 * 1024 * 1024));
        assert_eq!(paper.pool_arrays(), 32, "the paper's 2 M words = 32 arrays");
        assert_eq!(paper.capacity_words(), 2 * 1024 * 1024);
        // Floor semantics: a budget below one array still yields a
        // usable (single-array) pool, and a fractional budget never
        // rounds up past the bound.
        let one = TernaryGemmEngine::new(cfg.clone().with_capacity_words(100_000));
        assert_eq!(one.pool_arrays(), 1);
        let three = TernaryGemmEngine::new(cfg.with_capacity_words(3 * 65536 + 100));
        assert_eq!(three.pool_arrays(), 3);
    }

    #[test]
    fn small_weights_pack_several_per_array() {
        // Four 32×32 weights on one 64×64 array: sub-array packing keeps
        // all four resident at once where PR 2's slot-granular cache
        // would have thrashed a 1-array pool.
        let mut rng = Rng::new(47);
        for design in Design::ALL {
            let eng = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Sram8T)
                    .with_array_dims(64, 64)
                    .with_capacity_words(64 * 64)
                    .with_threads(2),
            );
            assert_eq!(eng.pool_arrays(), 1);
            let mut wants = Vec::new();
            let mut ids = Vec::new();
            let mut xs = Vec::new();
            for _ in 0..4 {
                let w = rng.ternary_vec(32 * 32, 0.5);
                let x = rng.ternary_vec(32, 0.5);
                let want =
                    tiling::reference_gemm(&x, &w, 1, &eng.grid(32, 32), design.flavor());
                ids.push(eng.register_weight(&w, 32, 32).unwrap());
                xs.push(x);
                wants.push(want);
            }
            for pass in 0..2 {
                for i in 0..4 {
                    assert_eq!(
                        eng.gemm_resident(ids[i], &xs[i], 1).unwrap(),
                        wants[i],
                        "{design:?} weight {i} pass {pass}"
                    );
                }
            }
            let s = eng.stats();
            assert_eq!(s.misses, 4, "{design:?} every shard placed once");
            assert_eq!(s.hits, 4, "{design:?} second pass all hits");
            assert_eq!(s.evictions, 0, "{design:?} all four pack into the array");
            assert_eq!(eng.resident_tiles(), 4);
        }
    }

    #[test]
    fn executor_drains_every_submitted_item() {
        let mut rng = Rng::new(50);
        let (m, k, n) = (2usize, 150usize, 60usize); // 3×2 grid = 6 shards
        let eng = small_engine(Design::Cim1, 2);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        eng.gemm(&x, &w, m, k, n).unwrap();
        let id = eng.register_weight(&w, k, n).unwrap();
        eng.gemm_resident(id, &x, m).unwrap();
        let s = eng.exec_stats();
        assert_eq!(s.submitted, 12, "6 shards × 2 GEMMs");
        assert_eq!(s.executed, 12, "every item drained");
        assert_eq!(s.affine + s.stolen + s.spilled, s.executed);
        assert!(s.queue_depth_max >= 1);
        assert_eq!(s.panics, 0);
    }

    #[test]
    fn arc_surface_is_bit_identical_and_shares_planes() {
        let mut rng = Rng::new(53);
        let (m, k, n) = (2usize, 150usize, 60usize);
        let x: Arc<[Trit]> = rng.ternary_vec(m * k, 0.5).into();
        let w: Arc<[Trit]> = rng.ternary_vec(k * n, 0.5).into();
        for design in Design::ALL {
            let eng = small_engine(design, 2);
            // Zero-copy registration: the engine holds the same plane,
            // not a clone of its contents. Checked before any job runs —
            // in-flight jobs hold transient clones of the job Arc.
            let id = eng.register_weight_arc(Arc::clone(&w), k, n).unwrap();
            assert_eq!(Arc::strong_count(&w), 2, "{design:?} registration shares the plane");
            let via_slice = eng.gemm(&x, &w, m, k, n).unwrap();
            let via_arc = eng.gemm_arc(Arc::clone(&x), Arc::clone(&w), m, k, n).unwrap();
            assert_eq!(via_arc, via_slice, "{design:?} arc vs slice");
            let via_res = eng.gemm_resident_arc(id, Arc::clone(&x), m).unwrap();
            assert_eq!(via_res, via_slice, "{design:?} resident arc");
        }
        // Both operands are still usable by the caller afterwards.
        assert_eq!(x.len(), m * k);
        assert_eq!(w.len(), k * n);
    }

    #[test]
    fn load_aware_submission_spills_off_a_deep_owner_queue() {
        // 8 small shards all placed on pool slots 0 and 1 of a 4-worker
        // engine (32×16 tiles pack 4 per 64×32 array). With spill ratio
        // 1 the warm submission — whose hints all point at workers 0/1 —
        // must divert items to the idle queues. The approximate policy's
        // relaxed depth snapshot reads drained (zero) queues between
        // sequential calls (job completion hands the counters over with
        // acquire/release ordering), so the spill decisions are
        // deterministic at submission; execution classification (affine
        // vs stolen) is not asserted.
        let mut rng = Rng::new(54);
        let eng = TernaryGemmEngine::new(
            EngineConfig::new(Design::Cim1, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_tile_dims(32, 16)
                .with_pool(4)
                .with_threads(4)
                .with_spill_ratio(1),
        );
        let (m, k, n) = (1usize, 64usize, 64usize); // 2×4 grid = 8 shards
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        let want =
            reference_gemm_sharded(&x, &w, m, &eng.grid(k, n), 64, 32, Design::Cim1.flavor());
        let id = eng.register_weight(&w, k, n).unwrap();
        assert_eq!(eng.gemm_resident(id, &x, m).unwrap(), want, "cold");
        for pass in 0..3 {
            assert_eq!(eng.gemm_resident(id, &x, m).unwrap(), want, "warm {pass}");
        }
        let s = eng.exec_stats();
        assert!(s.spilled > 0, "skewed placement must spill: {s:?}");
        assert_eq!(s.affine + s.stolen + s.spilled, s.executed);
        assert_eq!(s.panics, 0);
    }

    #[test]
    fn single_worker_executes_in_submission_order_all_affine() {
        // One worker: no stealing is possible, every item runs from its
        // own queue in FIFO order (the determinism the closed-form
        // eviction tests rely on).
        let mut rng = Rng::new(51);
        let (m, k, n) = (1usize, 300usize, 32usize);
        let eng = small_engine(Design::Cim2, 1);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        let id = eng.register_weight(&w, k, n).unwrap();
        eng.gemm_resident(id, &x, m).unwrap();
        eng.gemm_resident(id, &x, m).unwrap();
        let s = eng.exec_stats();
        assert_eq!(s.stolen, 0);
        assert_eq!(s.affine, s.executed);
    }

    #[test]
    fn concurrent_submissions_pipeline_through_one_executor() {
        // Several caller threads submit resident GEMMs against different
        // weights at once; every result must equal its single-threaded
        // reference (per-stripe merges never cross jobs).
        let mut rng = Rng::new(52);
        let eng = TernaryGemmEngine::new(
            EngineConfig::new(Design::Cim1, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_pool(8)
                .with_threads(4),
        );
        let mut cases = Vec::new();
        for _ in 0..4 {
            let (m, k, n) = (2usize, 130usize, 50usize);
            let x = rng.ternary_vec(m * k, 0.5);
            let w = rng.ternary_vec(k * n, 0.5);
            let want = tiling::reference_gemm(&x, &w, m, &eng.grid(k, n), Design::Cim1.flavor());
            let id = eng.register_weight(&w, k, n).unwrap();
            cases.push((id, x, m, want));
        }
        let engref = &eng;
        std::thread::scope(|sc| {
            for (id, x, m, want) in &cases {
                sc.spawn(move || {
                    for _ in 0..3 {
                        assert_eq!(engref.gemm_resident(*id, x, *m).unwrap(), *want);
                    }
                });
            }
        });
        let s = eng.exec_stats();
        assert_eq!(s.submitted, s.executed, "shutdown-free drain");
    }

    #[test]
    fn oversized_tiles_shard_across_arrays() {
        // 128×64 placement tiles on 64×32 physical arrays: one logical
        // tile = four shards with partial-sum recombination.
        let mut rng = Rng::new(48);
        let (m, k, n) = (2usize, 128usize, 64usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        for design in Design::ALL {
            let eng = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_tile_dims(128, 64)
                    .with_pool(4)
                    .with_threads(2),
            );
            let grid = eng.grid(k, n);
            assert_eq!(grid.n_tiles_total(), 1, "one oversized logical tile");
            let want = reference_gemm_sharded(&x, &w, m, &grid, 64, 32, design.flavor());
            assert_eq!(eng.gemm(&x, &w, m, k, n).unwrap(), want, "{design:?} streaming");
            let id = eng.register_weight(&w, k, n).unwrap();
            assert_eq!(eng.gemm_resident(id, &x, m).unwrap(), want, "{design:?} cold");
            assert_eq!(eng.gemm_resident(id, &x, m).unwrap(), want, "{design:?} warm");
            let s = eng.stats();
            assert_eq!(s.misses, 4, "{design:?} four shards placed");
            assert_eq!(s.hits, 4, "{design:?} four shard hits warm");
        }
    }

    #[test]
    fn program_from_plan_cold_start_has_no_discovery_misses() {
        let mut rng = Rng::new(57);
        let (m, k, n) = (2usize, 150usize, 60usize); // 3×2 grid = 6 shards
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        for design in Design::ALL {
            let eng = TernaryGemmEngine::new(
                EngineConfig::new(design, Tech::Femfet3T)
                    .with_array_dims(64, 32)
                    .with_pool(6)
                    .with_threads(2),
            );
            let plan = plan_layout(&[(k, n)], 64, 32, 6).expect("6 shards fit 6 slots");
            let id = eng.register_weight(&w, k, n).unwrap();
            eng.program_from_plan(id, &plan).unwrap();
            let s = eng.stats();
            let planned_rows: u64 = plan.iter().map(|p| p.k_len as u64).sum();
            assert_eq!(s.plan_write_rows, planned_rows, "{design:?} plan rows charged once");
            assert_eq!(s.write_rows, 0, "{design:?} no traffic writes during load");
            assert_eq!(s.misses, 0, "{design:?} plan replay is not a discovery miss");
            assert_eq!(eng.resident_tiles(), plan.len());
            // First traffic is all hits: cold start discovered nothing.
            let want = tiling::reference_gemm(&x, &w, m, &eng.grid(k, n), design.flavor());
            assert_eq!(eng.gemm_resident(id, &x, m).unwrap(), want, "{design:?}");
            let s = eng.stats();
            assert_eq!(s.hits, plan.len() as u64, "{design:?} first traffic all hits");
            assert_eq!(s.misses, 0, "{design:?}");
            assert_eq!(s.write_rows, 0, "{design:?} nothing re-programmed");
            // Replaying the same plan is idempotent (tags already held).
            eng.program_from_plan(id, &plan).unwrap();
            assert_eq!(eng.stats().plan_write_rows, planned_rows, "{design:?} idempotent");
        }
    }

    #[test]
    fn tenant_partitions_isolate_and_account() {
        let mut rng = Rng::new(58);
        let (m, k, n) = (2usize, 60usize, 30usize); // one 64×32 shard per weight
        let xa = rng.ternary_vec(m * k, 0.5);
        let wa = rng.ternary_vec(k * n, 0.5);
        let xb = rng.ternary_vec(m * k, 0.5);
        let wb = rng.ternary_vec(k * n, 0.5);
        let eng = TernaryGemmEngine::new(
            EngineConfig::new(Design::Cim1, Tech::Femfet3T)
                .with_array_dims(64, 32)
                .with_pool(3)
                .with_threads(1),
        );
        let tenant = eng.reserve_tenant(64 * 32).unwrap();
        assert_eq!(tenant, 1);
        assert_eq!(eng.n_tenants(), 2);
        assert_eq!(eng.tenant_slots(0), 2, "shared keeps the low slots");
        assert_eq!(eng.tenant_slots(tenant), 1, "reservation took one array");
        // A second reservation that would empty the shared pool fails.
        assert!(eng.reserve_tenant(2 * 64 * 32).is_err());
        let ida = eng.register_weight(&wa, k, n).unwrap();
        let idb = eng.register_weight_arc_in(wb.clone().into(), k, n, tenant).unwrap();
        assert!(
            eng.register_weight_arc_in(wb.clone().into(), k, n, 9).is_err(),
            "unknown tenant rejected"
        );
        let grid = eng.grid(k, n);
        let want_a = tiling::reference_gemm(&xa, &wa, m, &grid, Flavor::Cim1);
        let want_b = tiling::reference_gemm(&xb, &wb, m, &grid, Flavor::Cim1);
        for _ in 0..2 {
            assert_eq!(eng.gemm_resident(ida, &xa, m).unwrap(), want_a);
            assert_eq!(eng.gemm_resident(idb, &xb, m).unwrap(), want_b);
        }
        let (g, s0, s1) = (eng.stats(), eng.tenant_stats(0), eng.tenant_stats(tenant));
        for (name, global, parts) in [
            ("hits", g.hits, s0.hits + s1.hits),
            ("misses", g.misses, s0.misses + s1.misses),
            ("write_rows", g.write_rows, s0.write_rows + s1.write_rows),
            ("tiles", g.tiles, s0.tiles + s1.tiles),
            ("gemms", g.gemms, s0.gemms + s1.gemms),
            ("macs", g.macs, s0.macs + s1.macs),
        ] {
            assert_eq!(global, parts, "tenant books sum to global {name}");
        }
        // Per-tenant books: each tenant placed its one shard once and
        // hit it once; neither evicted the other.
        for (who, s) in [("shared", &s0), ("reserved", &s1)] {
            assert_eq!(s.misses, 1, "{who} placed once");
            assert_eq!(s.hits, 1, "{who} warm hit");
            assert_eq!(s.evictions, 0, "{who} never evicted");
            assert_eq!(s.write_rows, k as u64, "{who} programmed its rows once");
        }
    }

    #[test]
    fn invalidate_weight_forces_clean_replacement() {
        let mut rng = Rng::new(59);
        let (m, k, n) = (1usize, 60usize, 30usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w1 = rng.ternary_vec(k * n, 0.5);
        let w2 = rng.ternary_vec(k * n, 0.5);
        let eng = small_engine(Design::Cim1, 1);
        let grid = eng.grid(k, n);
        let id1 = eng.register_weight(&w1, k, n).unwrap();
        let id2 = eng.register_weight(&w2, k, n).unwrap();
        let want1 = tiling::reference_gemm(&x, &w1, m, &grid, Flavor::Cim1);
        let want2 = tiling::reference_gemm(&x, &w2, m, &grid, Flavor::Cim1);
        assert_eq!(eng.gemm_resident(id1, &x, m).unwrap(), want1);
        assert_eq!(eng.gemm_resident(id2, &x, m).unwrap(), want2);
        assert_eq!(eng.resident_tiles(), 2);
        // Retiring id1 frees its region; id2 stays resident and correct,
        // and a revived id1 re-places from its (kept) registration.
        eng.invalidate_weight(id1);
        assert_eq!(eng.resident_tiles(), 1);
        assert_eq!(eng.gemm_resident(id2, &x, m).unwrap(), want2, "survivor intact");
        assert_eq!(eng.gemm_resident(id1, &x, m).unwrap(), want1, "revived re-programs");
        let s = eng.stats();
        assert_eq!(s.misses, 3, "two cold places + one revival");
        assert_eq!(s.hits, 1, "id2 warm hit");
    }
}
