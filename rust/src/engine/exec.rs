//! The persistent stripe-scheduled executor.
//!
//! PR 1–3 ran every GEMM on per-call `std::thread::scope` workers that
//! claimed shards off a shared `AtomicUsize` and merged partials under
//! one global output mutex. That shape has three scaling problems the
//! paper's system-level numbers care about: every call pays thread
//! spawn/join, concurrent GEMMs (server batches) contend on the same
//! pool arrays implicitly instead of pipelining through disjoint ones,
//! and the single merge mutex serializes all partial-sum traffic — the
//! RRAM scalability literature's observation that partial-sum
//! orchestration, not array compute, becomes the bottleneck.
//!
//! The [`Executor`] replaces all of it:
//!
//! - **Long-lived workers.** `TernaryGemmEngine::new` spawns
//!   `min(n_threads, pool size)` worker threads that live as long as the
//!   engine. Worker *w* owns pool slot *w* for streaming work (it is the
//!   only worker that programs that array whole).
//! - **Zero-copy jobs.** Operands travel as `Arc<[Trit]>` planes: a
//!   streaming job shares the caller's input/weight planes and a
//!   resident job shares the registered weight (`RegisteredWeight`) plus
//!   the caller's input plane — submission clones reference counts, not
//!   trits. The slice-based `gemm` surface pays exactly one copy at the
//!   API boundary (`Arc::from`); `gemm_arc` callers pay none.
//! - **Stripe work queue.** A GEMM submission decomposes into one
//!   [`WorkItem`] per (job, shard) — each shard belongs to exactly one
//!   n-stripe of the output. Items land on per-worker queues; idle
//!   workers steal from the back of their neighbours' queues, so a
//!   single hot queue still drains at full parallelism while queue order
//!   stays FIFO for the owner.
//! - **Load-aware affinity.** A resident shard whose placement is
//!   already known prefers the worker that owns its array
//!   (`slot % n_workers`, probed via `TileCache::peek_slot` without
//!   touching the second-chance bit), so steady-state serving sends each
//!   array's work to the same thread. But affinity is no longer static:
//!   submission consults per-worker queue depths and *spills* the item
//!   to the shallowest queue when the preferred queue is
//!   `spill_depth_ratio` times deeper — a skewed working set where a
//!   couple of hot arrays own most shards no longer funnels everything
//!   through one worker. On the hot path the depths are *approximate*:
//!   relaxed atomic counters snapshotted once per submission before the
//!   queue lock, with the submission's own pushes simulated locally, so
//!   planning happens outside the critical section (continuous batching
//!   made submission hot enough to care). Unplaced/streaming items
//!   round-robin. The `spilled` / `queue_depth_max` counters in
//!   [`ExecStatsSnapshot`] make the policy observable, and
//!   [`AffinityMode`] lets the schedule-replay test harness force
//!   degenerate orders (all-pinned, all-spill) or pin the exact
//!   under-lock depth scan (`LoadAwareExact`) deterministically.
//! - **Stripe-sharded merge, scratch-reused MACs.** Each job carries one
//!   accumulator per n-stripe ([`GemmJob::merge`]); shards of different
//!   stripes merge with no shared lock at all, shards within a stripe
//!   serialize only on that stripe's mutex. `i32` addition commutes, so
//!   any merge order is bit-identical to the sequential reference. Each
//!   worker owns a [`WorkerScratch`] — weight-image, input-slice,
//!   partial-sum and region-kernel buffers grown monotonically — so the
//!   steady-state data path performs zero per-item heap allocations:
//!   CiM II's restricted stride masks and bit planes now live in the
//!   worker's `RegionScratch` (cached per region row span) instead of
//!   being rebuilt per call.
//!
//! Submitters block on the job's condvar until its last item completes,
//! then assemble the stripes into the row-major output — so the public
//! `gemm`/`gemm_resident` surface is unchanged and multiple server
//! workers can submit concurrently while their GEMMs pipeline through
//! the shared pool. A panic inside a shard item (poisoned storage
//! asserts, etc.) marks the job failed and is reported as an `Err` by
//! the submitter; the worker itself survives, which preserves the
//! coordinator's worker-never-dies property.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

use crate::array::encoding::Trit;

use super::resident::RegisteredWeight;
use super::tiling::{Shard, TileGrid};
use super::EngineCore;

/// How submissions choose a worker queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AffinityMode {
    /// Placed shards prefer the worker owning their array, spilling to
    /// the shallowest queue when the preferred queue is
    /// `spill_depth_ratio` times deeper (the production default). Depths
    /// come from one relaxed-atomic snapshot taken per submission —
    /// *before* the queue lock — with the submission's own pushes
    /// simulated locally, so the hot submission path no longer scans
    /// exact queue lengths inside the lock's critical section. Against
    /// drained queues (serial submissions) the snapshot equals the exact
    /// lengths, so the decisions match [`AffinityMode::LoadAwareExact`]
    /// deterministically; under concurrent submission the depths are
    /// approximate, which only shifts the affine/spilled *labels*, never
    /// correctness.
    LoadAware,
    /// Same policy as [`AffinityMode::LoadAware`] but with exact queue
    /// lengths read under the submission lock — the spill decisions are
    /// a pure function of the locked queue state. Deterministic
    /// schedule-replay harness.
    LoadAwareExact,
    /// Every item is enqueued to worker 0 regardless of placement; with
    /// more than one worker the rest serve purely by stealing. Schedule-
    /// replay harness: forces the all-steal order.
    PinToZero,
    /// Every item goes to the shallowest queue, ignoring placement
    /// affinity entirely (and counts as spilled when its enqueue worker
    /// executes it). Schedule-replay harness: forces the all-spill order.
    ForceSpill,
}

/// What a job executes against: a one-shot streaming GEMM (the job
/// shares both operand planes) or a registered resident weight.
pub(crate) enum JobKind {
    Streaming { x: Arc<[Trit]>, w: Arc<[Trit]>, grid: TileGrid, shards: Vec<Shard> },
    Resident { reg: Arc<RegisteredWeight>, x: Arc<[Trit]> },
}

/// One submitted GEMM: its operands, per-n-stripe output accumulators,
/// and completion state.
pub(crate) struct GemmJob {
    pub kind: JobKind,
    pub m: usize,
    n: usize,
    /// Stripe width in output columns (the grid's tile columns).
    stripe_cols: usize,
    /// One accumulator per n-stripe, each row-major `m × stripe_len`.
    stripes: Vec<Mutex<Vec<i32>>>,
    remaining: AtomicUsize,
    failed: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl GemmJob {
    pub fn streaming(
        x: Arc<[Trit]>,
        w: Arc<[Trit]>,
        grid: TileGrid,
        shards: Vec<Shard>,
        m: usize,
        n: usize,
    ) -> GemmJob {
        let n_shards = shards.len();
        GemmJob::new(JobKind::Streaming { x, w, grid, shards }, m, n, &grid, n_shards)
    }

    pub fn resident(reg: Arc<RegisteredWeight>, x: Arc<[Trit]>, m: usize) -> GemmJob {
        let (grid, n, n_shards) = (reg.grid, reg.n, reg.shards.len());
        GemmJob::new(JobKind::Resident { reg, x }, m, n, &grid, n_shards)
    }

    fn new(kind: JobKind, m: usize, n: usize, grid: &TileGrid, n_shards: usize) -> GemmJob {
        let stripe_cols = grid.cols;
        let stripes = (0..grid.n_tiles)
            .map(|j| {
                let len = stripe_cols.min(n - j * stripe_cols);
                Mutex::new(vec![0i32; m * len])
            })
            .collect();
        GemmJob {
            kind,
            m,
            n,
            stripe_cols,
            stripes,
            remaining: AtomicUsize::new(n_shards),
            failed: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        }
    }

    /// The job's shard list (the work-item index space).
    pub fn shards(&self) -> &[Shard] {
        match &self.kind {
            JobKind::Streaming { shards, .. } => shards,
            JobKind::Resident { reg, .. } => &reg.shards,
        }
    }

    fn stripe_len(&self, j: usize) -> usize {
        self.stripe_cols.min(self.n - j * self.stripe_cols)
    }

    /// Accumulate one shard's `m × shard.n_len` partial into its
    /// n-stripe. Shards of different stripes touch disjoint accumulators;
    /// within a stripe the per-stripe mutex serializes (i32 addition
    /// commutes, so order never matters).
    pub fn merge(&self, shard: &Shard, partial: &[i32]) {
        let j = shard.n0 / self.stripe_cols;
        let off = shard.n0 - j * self.stripe_cols;
        let len = self.stripe_len(j);
        let mut acc = self.stripes[j].lock().unwrap_or_else(PoisonError::into_inner);
        for r in 0..self.m {
            let src = &partial[r * shard.n_len..(r + 1) * shard.n_len];
            let dst = &mut acc[r * len + off..r * len + off + shard.n_len];
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
    }

    /// Concatenate the finished stripes into the row-major `m × n`
    /// output (submitter-side, after completion).
    fn assemble(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.m * self.n];
        for j in 0..self.stripes.len() {
            let len = self.stripe_len(j);
            let acc = self.stripes[j].lock().unwrap_or_else(PoisonError::into_inner);
            for r in 0..self.m {
                out[r * self.n + j * self.stripe_cols..][..len]
                    .copy_from_slice(&acc[r * len..(r + 1) * len]);
            }
        }
        out
    }
}

/// One queued unit of work: one shard of one job, plus whether the
/// load-aware policy diverted it off its preferred queue at submission.
pub(crate) struct WorkItem {
    pub job: Arc<GemmJob>,
    pub shard: usize,
    pub spilled: bool,
}

/// Per-worker reusable buffers: weight image, input slices and partial
/// sums, grown monotonically (capacity never shrinks), so steady-state
/// streaming performs zero per-item heap allocations in the executor
/// data path.
#[derive(Default)]
pub(crate) struct WorkerScratch {
    /// Zero-padded weight image of the shard being programmed.
    pub wbuf: Vec<Trit>,
    /// Region-local input slices for the whole batch.
    pub xbuf: Vec<Trit>,
    /// Partial-sum output of the region MAC.
    pub partial: Vec<i32>,
    /// Region-kernel scratch: CiM II's cached restricted stride masks
    /// and bit-plane buffers (see `array::mac::RegionScratch`).
    pub region: crate::array::mac::RegionScratch,
}

struct QueueState {
    /// One FIFO per worker; idle workers steal from neighbours' backs.
    queues: Vec<VecDeque<WorkItem>>,
    shutdown: bool,
}

struct ExecShared {
    state: Mutex<QueueState>,
    cv: Condvar,
    stats: ExecStats,
    /// Approximate per-queue depths, maintained with relaxed atomics at
    /// every push/pop/steal. `LoadAware` submissions snapshot these once
    /// per submission instead of scanning the exact queue lengths under
    /// the lock. May momentarily disagree with `queues[i].len()` by
    /// in-flight pushes/pops; drained queues always read 0 to a
    /// subsequent submitter (job completion hands the counters over with
    /// acquire/release ordering).
    depths: Vec<AtomicUsize>,
}

/// Cumulative executor counters.
#[derive(Default)]
struct ExecStats {
    submitted: AtomicU64,
    executed: AtomicU64,
    affine: AtomicU64,
    stolen: AtomicU64,
    spilled: AtomicU64,
    queue_depth_max: AtomicU64,
    panics: AtomicU64,
}

/// Point-in-time copy of the executor counters. Every executed item is
/// classified as exactly one of `affine` / `stolen` / `spilled`, so
/// `executed == affine + stolen + spilled` at every drain point.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecStatsSnapshot {
    /// Work items enqueued (one per shard per GEMM).
    pub submitted: u64,
    /// Work items completed.
    pub executed: u64,
    /// Items executed by the worker they were enqueued to, off the
    /// preferred (owner or round-robin) queue.
    pub affine: u64,
    /// Items executed by a different worker (work stealing).
    pub stolen: u64,
    /// Items diverted to the shallowest queue at submission (load-aware
    /// spill) and executed there.
    pub spilled: u64,
    /// Deepest any queue has been at enqueue time — how far behind the
    /// slowest worker got.
    pub queue_depth_max: u64,
    /// Items that panicked (job reported failed; worker survived).
    pub panics: u64,
}

/// Long-lived worker pool executing [`WorkItem`]s against an
/// [`EngineCore`]. Dropping it (with the owning engine) shuts the
/// workers down after the queues drain.
pub(crate) struct Executor {
    shared: Arc<ExecShared>,
    n_workers: usize,
    mode: AffinityMode,
    /// Spill threshold: divert when the preferred queue holds at least
    /// `ratio × (shallowest + 1)` items.
    spill_ratio: usize,
    rr: AtomicUsize,
    workers: Vec<JoinHandle<()>>,
}

/// First queue of minimal depth (deterministic tie-break: lowest index).
fn shallowest(queues: &[VecDeque<WorkItem>]) -> usize {
    let mut best = 0;
    for (i, q) in queues.iter().enumerate() {
        if q.len() < queues[best].len() {
            best = i;
        }
    }
    best
}

/// Same tie-break over a depth vector (the load-aware snapshot).
fn shallowest_depth(depths: &[usize]) -> usize {
    let mut best = 0;
    for (i, &d) in depths.iter().enumerate() {
        if d < depths[best] {
            best = i;
        }
    }
    best
}

impl Executor {
    /// Spawn `n_workers` threads over the core. Worker `w` owns pool
    /// slot `w` for streaming work, so `n_workers` must not exceed the
    /// pool size (the engine clamps). Affinity mode and spill ratio come
    /// from the core's `EngineConfig`.
    pub fn new(core: &Arc<EngineCore>, n_workers: usize) -> Executor {
        assert!(
            (1..=core.pool_len()).contains(&n_workers),
            "worker count must be in 1..=pool size (worker w owns slot w)"
        );
        let shared = Arc::new(ExecShared {
            state: Mutex::new(QueueState {
                queues: (0..n_workers).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            stats: ExecStats::default(),
            depths: (0..n_workers).map(|_| AtomicUsize::new(0)).collect(),
        });
        let workers = (0..n_workers)
            .map(|w| {
                let core = Arc::clone(core);
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sitecim-exec-{w}"))
                    .spawn(move || worker_loop(core, shared, w))
                    .expect("spawning engine executor worker")
            })
            .collect();
        Executor {
            shared,
            n_workers,
            mode: core.cfg.affinity,
            spill_ratio: core.cfg.spill_depth_ratio.max(1),
            rr: AtomicUsize::new(0),
            workers,
        }
    }

    pub fn stats(&self) -> ExecStatsSnapshot {
        let s = &self.shared.stats;
        ExecStatsSnapshot {
            submitted: s.submitted.load(Ordering::Relaxed),
            executed: s.executed.load(Ordering::Relaxed),
            affine: s.affine.load(Ordering::Relaxed),
            stolen: s.stolen.load(Ordering::Relaxed),
            spilled: s.spilled.load(Ordering::Relaxed),
            queue_depth_max: s.queue_depth_max.load(Ordering::Relaxed),
            panics: s.panics.load(Ordering::Relaxed),
        }
    }

    /// Live backlog: work items currently queued across all workers,
    /// from the same relaxed per-queue depth counters the load-aware
    /// submission policy reads. Approximate by a few items under
    /// concurrent submission — a watermark signal, not an exact census
    /// (the high-water record is `ExecStatsSnapshot::queue_depth_max`).
    pub fn queue_depth(&self) -> u64 {
        self.shared.depths.iter().map(|d| d.load(Ordering::Relaxed) as u64).sum()
    }

    /// Apply the load-aware rule to one item: spill to the shallowest
    /// queue when the preferred queue holds at least
    /// `spill_ratio × (shallowest + 1)` items.
    fn load_aware_target(&self, preferred: usize, depths: &[usize]) -> (usize, bool) {
        let shallow = shallowest_depth(depths);
        let (pd, sd) = (depths[preferred], depths[shallow]);
        if preferred != shallow && pd >= self.spill_ratio * (sd + 1) {
            (shallow, true)
        } else {
            (preferred, false)
        }
    }

    /// The preferred queue for a shard: the worker owning its placed
    /// array, or round-robin when unplaced/streaming.
    fn preferred_worker(&self, hint: &Option<usize>) -> usize {
        match hint {
            Some(slot) => slot % self.n_workers,
            None => self.rr.fetch_add(1, Ordering::Relaxed) % self.n_workers,
        }
    }

    /// Enqueue one item per shard (`hints[i]` = the pool slot shard `i`
    /// is expected to execute on, when known), block until the job
    /// drains, and assemble the output. Errors if any item panicked.
    ///
    /// `LoadAware` plans the whole submission *before* taking the queue
    /// lock, from one relaxed snapshot of the approximate depth counters
    /// with its own pushes simulated locally — the lock's critical
    /// section is just the pushes. The exact modes (`LoadAwareExact`,
    /// `ForceSpill`) still decide under the lock, where the decisions
    /// are deterministic given the queue depths at lock acquisition
    /// (workers cannot pop mid-submission).
    pub fn run(&self, job: GemmJob, hints: &[Option<usize>]) -> anyhow::Result<Vec<i32>> {
        let n_shards = job.shards().len();
        assert_eq!(hints.len(), n_shards);
        if n_shards == 0 {
            return Ok(job.assemble());
        }
        // LoadAware plans outside the lock: one relaxed snapshot, own
        // pushes simulated locally. Against drained queues this equals
        // the exact under-lock scan (see `AffinityMode::LoadAware`).
        let plan: Option<Vec<(usize, bool)>> = match self.mode {
            AffinityMode::LoadAware => {
                let mut depths: Vec<usize> =
                    self.shared.depths.iter().map(|d| d.load(Ordering::Relaxed)).collect();
                Some(
                    hints
                        .iter()
                        .map(|hint| {
                            let preferred = self.preferred_worker(hint);
                            let (target, spilled) = self.load_aware_target(preferred, &depths);
                            depths[target] += 1;
                            (target, spilled)
                        })
                        .collect(),
                )
            }
            _ => None,
        };
        let job = Arc::new(job);
        {
            let mut st = self.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            for (i, hint) in hints.iter().enumerate() {
                let (target, spilled) = match self.mode {
                    AffinityMode::PinToZero => (0, false),
                    AffinityMode::ForceSpill => (shallowest(&st.queues), true),
                    AffinityMode::LoadAware => plan.as_ref().expect("planned above")[i],
                    AffinityMode::LoadAwareExact => {
                        let preferred = self.preferred_worker(hint);
                        let depths: Vec<usize> = st.queues.iter().map(VecDeque::len).collect();
                        self.load_aware_target(preferred, &depths)
                    }
                };
                st.queues[target].push_back(WorkItem {
                    job: Arc::clone(&job),
                    shard: i,
                    spilled,
                });
                self.shared.depths[target].fetch_add(1, Ordering::Relaxed);
                let depth = st.queues[target].len() as u64;
                self.shared.stats.queue_depth_max.fetch_max(depth, Ordering::Relaxed);
            }
            self.shared.stats.submitted.fetch_add(n_shards as u64, Ordering::Relaxed);
            self.shared.cv.notify_all();
        }
        let mut done = job.done.lock().unwrap_or_else(PoisonError::into_inner);
        while !*done {
            done = job.done_cv.wait(done).unwrap_or_else(PoisonError::into_inner);
        }
        drop(done);
        if job.failed.load(Ordering::Acquire) {
            anyhow::bail!("engine worker panicked executing a shard; output discarded");
        }
        Ok(job.assemble())
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.shutdown = true;
            self.shared.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(core: Arc<EngineCore>, shared: Arc<ExecShared>, w: usize) {
    let mut scratch = WorkerScratch::default();
    loop {
        let (item, own) = {
            let mut st = shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if let Some(it) = st.queues[w].pop_front() {
                    shared.depths[w].fetch_sub(1, Ordering::Relaxed);
                    break (Some(it), true);
                }
                let n = st.queues.len();
                let mut stolen = None;
                for off in 1..n {
                    let victim = (w + off) % n;
                    if let Some(it) = st.queues[victim].pop_back() {
                        shared.depths[victim].fetch_sub(1, Ordering::Relaxed);
                        stolen = Some(it);
                        break;
                    }
                }
                if let Some(it) = stolen {
                    break (Some(it), false);
                }
                if st.shutdown {
                    break (None, false);
                }
                st = shared.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        let Some(item) = item else { return };
        // Exactly one of affine/stolen/spilled per executed item: stolen
        // wins over the submission-time spill tag (the item left its
        // enqueue queue after all).
        if !own {
            shared.stats.stolen.fetch_add(1, Ordering::Relaxed);
        } else if item.spilled {
            shared.stats.spilled.fetch_add(1, Ordering::Relaxed);
        } else {
            shared.stats.affine.fetch_add(1, Ordering::Relaxed);
        }
        let job = Arc::clone(&item.job);
        // A panicking shard (storage asserts, poisoned invariants) must
        // not kill the worker — that would strand every queued job and
        // permanently shrink the pool's parallelism. Mark the job failed
        // and keep serving; the submitter turns it into an `Err`.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            core.run_item(w, &item, &mut scratch);
        }));
        if result.is_err() {
            shared.stats.panics.fetch_add(1, Ordering::Relaxed);
            job.failed.store(true, Ordering::Release);
        }
        shared.stats.executed.fetch_add(1, Ordering::Relaxed);
        if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = job.done.lock().unwrap_or_else(PoisonError::into_inner);
            *done = true;
            job.done_cv.notify_all();
        }
    }
}
