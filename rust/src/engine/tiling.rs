//! K×N tile decomposition for the GEMM engine.
//!
//! Weight-stationary mapping, mirroring `arch::mapper`: a GEMM's K
//! (reduction) dimension maps to array rows, N (output channels) to
//! columns. Partial edge tiles are zero-padded — zero weights and zero
//! inputs are electrically inert, so padding never changes a group
//! output, and the row grouping of a padded tile is identical for every
//! tile in a grid (this is what makes the per-tile reference composition
//! exact).
//!
//! Since PR 3, *placement* granularity is independent of the physical
//! array: a [`TileGrid`]'s tile shape may differ from the array shape.
//! Each tile splits into array-fitting [`Shard`]s (at 16-row-aligned
//! boundaries), and every shard is placed onto a [`Rect`] — a row/col
//! sub-rectangle of one physical array. Small tiles therefore pack
//! several to an array, and one oversized tile shards across several
//! arrays with partial-sum recombination in the engine. Placement is
//! position-independent: because every pool array has the same row
//! count, a shard's 16-row group structure is identical at any
//! 16-aligned row offset (CiM I groups are consecutive 16-row windows;
//! CiM II co-groups rows congruent mod `n_rows/16`, and a common offset
//! cancels in the congruence), and foreign rows always see zero inputs,
//! which are inert. [`reference_gemm_sharded`] is the executable
//! statement of that specification.

use crate::array::encoding::Trit;
use crate::array::mac::{dot_exact, dot_ref, Flavor, GROUP_ROWS};
use crate::array::TernaryStorage;

/// A row/col sub-rectangle of one physical array — where a placed shard
/// lives. Defined in `array::mac` (the region-scoped MAC kernels take
/// it); re-exported here because placement is where rects come from.
pub use crate::array::mac::Rect;

/// One array-fitting piece of a (possibly oversized) tile: rows
/// `k0..k0+k_len` × columns `n0..n0+n_len` of the full K×N weight
/// matrix. Equal to its tile when the tile already fits one array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub k0: usize,
    pub k_len: usize,
    pub n0: usize,
    pub n_len: usize,
}

impl Shard {
    /// Rows the shard occupies on an array, padded up to whole 16-row
    /// MAC groups (what the region allocator reserves).
    pub fn padded_rows(&self) -> usize {
        self.k_len.div_ceil(GROUP_ROWS) * GROUP_ROWS
    }
}

/// The K×N tile grid of one GEMM on one array shape.
#[derive(Clone, Copy, Debug)]
pub struct TileGrid {
    pub k: usize,
    pub n: usize,
    /// Array rows (K capacity per tile); multiple of 16.
    pub rows: usize,
    /// Array columns (N capacity per tile).
    pub cols: usize,
    pub k_tiles: usize,
    pub n_tiles: usize,
}

/// One weight tile: rows `k0..k0+k_len` × columns `n0..n0+n_len` of the
/// full K×N weight matrix, padded to `rows × cols` on the array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    pub kt: usize,
    pub nt: usize,
    pub k0: usize,
    pub k_len: usize,
    pub n0: usize,
    pub n_len: usize,
}

impl TileGrid {
    pub fn new(k: usize, n: usize, rows: usize, cols: usize) -> TileGrid {
        assert!(k > 0 && n > 0, "empty GEMM ({k}×{n})");
        assert!(rows > 0 && rows % 16 == 0, "array rows must be a positive multiple of 16");
        assert!(cols > 0, "array must have columns");
        TileGrid { k, n, rows, cols, k_tiles: k.div_ceil(rows), n_tiles: n.div_ceil(cols) }
    }

    pub fn n_tiles_total(&self) -> usize {
        self.k_tiles * self.n_tiles
    }

    /// All tiles, k-major (every k-tile of an n-stripe is adjacent so a
    /// worker sweeping consecutive tiles reuses its output stripe).
    pub fn tiles(&self) -> Vec<Tile> {
        let mut out = Vec::with_capacity(self.n_tiles_total());
        for nt in 0..self.n_tiles {
            let n0 = nt * self.cols;
            let n_len = self.cols.min(self.n - n0);
            for kt in 0..self.k_tiles {
                let k0 = kt * self.rows;
                let k_len = self.rows.min(self.k - k0);
                out.push(Tile { kt, nt, k0, k_len, n0, n_len });
            }
        }
        out
    }

    /// Every tile split into pieces that fit one `array_rows × array_cols`
    /// physical array, in tile order (k-major within each tile's splits).
    /// K splits land on multiples of `array_rows` (which is a multiple of
    /// 16), so shard boundaries never cut a 16-row MAC group and the
    /// per-shard window counts sum to the per-tile counts. One shard per
    /// tile when the tile shape already fits the array.
    pub fn shards(&self, array_rows: usize, array_cols: usize) -> Vec<Shard> {
        assert!(
            array_rows > 0 && array_rows % GROUP_ROWS == 0,
            "array rows must be a positive multiple of {GROUP_ROWS}"
        );
        assert!(array_cols > 0, "array must have columns");
        let mut out = Vec::new();
        for tile in self.tiles() {
            for n_off in (0..tile.n_len).step_by(array_cols) {
                let n_len = array_cols.min(tile.n_len - n_off);
                for k_off in (0..tile.k_len).step_by(array_rows) {
                    let k_len = array_rows.min(tile.k_len - k_off);
                    out.push(Shard {
                        k0: tile.k0 + k_off,
                        k_len,
                        n0: tile.n0 + n_off,
                        n_len,
                    });
                }
            }
        }
        out
    }
}

/// Copy one tile of the row-major K×N weight matrix into a zero-padded
/// `rows × cols` array image.
pub fn extract_tile_weights(
    w: &[Trit],
    k: usize,
    n: usize,
    tile: &Tile,
    rows: usize,
    cols: usize,
    buf: &mut [Trit],
) {
    assert_eq!(w.len(), k * n);
    assert_eq!(buf.len(), rows * cols);
    buf.fill(0);
    for r in 0..tile.k_len {
        let src = (tile.k0 + r) * n + tile.n0;
        buf[r * cols..r * cols + tile.n_len].copy_from_slice(&w[src..src + tile.n_len]);
    }
}

/// Copy the k-slice of one input vector into a zero-padded `rows`-long
/// array input.
pub fn extract_tile_inputs(x_row: &[Trit], tile: &Tile, rows: usize, buf: &mut [Trit]) {
    assert_eq!(buf.len(), rows);
    buf.fill(0);
    buf[..tile.k_len].copy_from_slice(&x_row[tile.k0..tile.k0 + tile.k_len]);
}

/// Copy one shard of the row-major K×N weight matrix into a zero-padded
/// `rect_rows × rect_cols` region image (shard at the top-left).
pub fn extract_shard_weights(
    w: &[Trit],
    k: usize,
    n: usize,
    shard: &Shard,
    rect_rows: usize,
    rect_cols: usize,
    buf: &mut [Trit],
) {
    assert_eq!(w.len(), k * n);
    assert_eq!(buf.len(), rect_rows * rect_cols);
    assert!(shard.k_len <= rect_rows && shard.n_len <= rect_cols, "shard exceeds region");
    buf.fill(0);
    for r in 0..shard.k_len {
        let src = (shard.k0 + r) * n + shard.n0;
        buf[r * rect_cols..r * rect_cols + shard.n_len]
            .copy_from_slice(&w[src..src + shard.n_len]);
    }
}

/// Copy the k-slice of one input vector into an array-length input
/// image at the shard's placed row offset; every other row is zero, so
/// co-resident regions and stale cells of the same array are inert.
pub fn extract_shard_inputs(x_row: &[Trit], shard: &Shard, row0: usize, buf: &mut [Trit]) {
    assert!(row0 + shard.k_len <= buf.len(), "region rows exceed the array");
    buf.fill(0);
    buf[row0..row0 + shard.k_len].copy_from_slice(&x_row[shard.k0..shard.k0 + shard.k_len]);
}

/// The engine's specification: `dot_ref` (or the exact MAC when `flavor`
/// is `None`) composed over the tiles of `grid` — pure integer math, no
/// engine, no threads. `TernaryGemmEngine::gemm` must match this
/// bit-for-bit; the conformance tests and the accelerator co-simulation
/// both check against it.
pub fn reference_gemm(
    x: &[Trit],
    w: &[Trit],
    m: usize,
    grid: &TileGrid,
    flavor: Option<Flavor>,
) -> Vec<i32> {
    assert_eq!(x.len(), m * grid.k);
    assert_eq!(w.len(), grid.k * grid.n);
    let (rows, cols) = (grid.rows, grid.cols);
    let mut out = vec![0i32; m * grid.n];
    let mut wbuf = vec![0i8; rows * cols];
    let mut xbuf = vec![0i8; rows];
    for tile in grid.tiles() {
        extract_tile_weights(w, grid.k, grid.n, &tile, rows, cols, &mut wbuf);
        let mut storage = TernaryStorage::new(rows, cols);
        storage.write_matrix(&wbuf);
        for r in 0..m {
            extract_tile_inputs(&x[r * grid.k..(r + 1) * grid.k], &tile, rows, &mut xbuf);
            let partial: Vec<i32> = match flavor {
                Some(f) => dot_ref(&storage, &xbuf, f),
                None => dot_exact(&storage, &xbuf).into_iter().map(|v| v as i32).collect(),
            };
            let dst = &mut out[r * grid.n + tile.n0..r * grid.n + tile.n0 + tile.n_len];
            for (d, s) in dst.iter_mut().zip(&partial[..tile.n_len]) {
                *d += s;
            }
        }
    }
    out
}

/// The engine's specification when placement granularity differs from
/// the physical arrays: each array-fitting shard of `grid`'s tiles is
/// zero-padded into an `array_rows × array_cols` storage, evaluated with
/// `dot_ref` (or the exact MAC when `flavor` is `None`), and the partial
/// sums recombined. Pure integer math — no engine, no threads, no
/// placement. Equals [`reference_gemm`] whenever the grid's tile shape
/// is the array shape, because then every tile is its own single shard.
#[allow(clippy::too_many_arguments)]
pub fn reference_gemm_sharded(
    x: &[Trit],
    w: &[Trit],
    m: usize,
    grid: &TileGrid,
    array_rows: usize,
    array_cols: usize,
    flavor: Option<Flavor>,
) -> Vec<i32> {
    assert_eq!(x.len(), m * grid.k);
    assert_eq!(w.len(), grid.k * grid.n);
    let mut out = vec![0i32; m * grid.n];
    let mut wbuf = vec![0i8; array_rows * array_cols];
    let mut xbuf = vec![0i8; array_rows];
    for shard in grid.shards(array_rows, array_cols) {
        extract_shard_weights(w, grid.k, grid.n, &shard, array_rows, array_cols, &mut wbuf);
        let mut storage = TernaryStorage::new(array_rows, array_cols);
        storage.write_matrix(&wbuf);
        for r in 0..m {
            extract_shard_inputs(&x[r * grid.k..(r + 1) * grid.k], &shard, 0, &mut xbuf);
            let partial: Vec<i32> = match flavor {
                Some(f) => dot_ref(&storage, &xbuf, f),
                None => dot_exact(&storage, &xbuf).into_iter().map(|v| v as i32).collect(),
            };
            let dst = &mut out[r * grid.n + shard.n0..r * grid.n + shard.n0 + shard.n_len];
            for (d, s) in dst.iter_mut().zip(&partial[..shard.n_len]) {
                *d += s;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn grid_covers_ragged_dims() {
        let g = TileGrid::new(300, 70, 64, 32);
        assert_eq!((g.k_tiles, g.n_tiles), (5, 3));
        let tiles = g.tiles();
        assert_eq!(tiles.len(), 15);
        // Every (k, n) element is covered exactly once.
        let mut cover = vec![0u8; 300 * 70];
        for t in &tiles {
            for r in t.k0..t.k0 + t.k_len {
                for c in t.n0..t.n0 + t.n_len {
                    cover[r * 70 + c] += 1;
                }
            }
        }
        assert!(cover.iter().all(|&c| c == 1));
        // Edge tiles are short.
        let last = tiles.last().unwrap();
        assert_eq!((last.k_len, last.n_len), (300 - 4 * 64, 70 - 2 * 32));
    }

    #[test]
    fn extraction_pads_with_zeros() {
        let mut rng = Rng::new(1);
        let (k, n) = (20usize, 10usize);
        let w = rng.ternary_vec(k * n, 0.3);
        let g = TileGrid::new(k, n, 16, 8);
        let t = g.tiles()[3]; // kt=1, nt=1: 4×2 corner
        assert_eq!((t.k_len, t.n_len), (4, 2));
        let mut buf = vec![9i8; 16 * 8];
        extract_tile_weights(&w, k, n, &t, 16, 8, &mut buf);
        for r in 0..16 {
            for c in 0..8 {
                let want = if r < t.k_len && c < t.n_len { w[(t.k0 + r) * n + t.n0 + c] } else { 0 };
                assert_eq!(buf[r * 8 + c], want, "r={r} c={c}");
            }
        }
        let x = rng.ternary_vec(k, 0.3);
        let mut xb = vec![9i8; 16];
        extract_tile_inputs(&x, &t, 16, &mut xb);
        assert_eq!(&xb[..4], &x[16..20]);
        assert!(xb[4..].iter().all(|&v| v == 0));
    }

    #[test]
    fn reference_gemm_exact_flavor_is_plain_matmul() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (3usize, 40usize, 21usize);
        let x = rng.ternary_vec(m * k, 0.4);
        let w = rng.ternary_vec(k * n, 0.4);
        let g = TileGrid::new(k, n, 16, 8);
        let got = reference_gemm(&x, &w, m, &g, None);
        for r in 0..m {
            for c in 0..n {
                let want: i32 =
                    (0..k).map(|i| x[r * k + i] as i32 * w[i * n + c] as i32).sum();
                assert_eq!(got[r * n + c], want, "r={r} c={c}");
            }
        }
    }

    #[test]
    fn reference_gemm_tiling_independent_for_exact() {
        // The exact (unsaturated) composition must not depend on the
        // array shape; the saturating flavors legitimately do.
        let mut rng = Rng::new(3);
        let (m, k, n) = (2usize, 100usize, 30usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        let a = reference_gemm(&x, &w, m, &TileGrid::new(k, n, 32, 16), None);
        let b = reference_gemm(&x, &w, m, &TileGrid::new(k, n, 64, 30), None);
        assert_eq!(a, b);
    }

    #[test]
    fn shards_equal_tiles_when_tiles_fit_the_array() {
        let g = TileGrid::new(300, 70, 64, 32);
        let tiles = g.tiles();
        let shards = g.shards(64, 32);
        assert_eq!(shards.len(), tiles.len());
        for (s, t) in shards.iter().zip(&tiles) {
            assert_eq!((s.k0, s.k_len, s.n0, s.n_len), (t.k0, t.k_len, t.n0, t.n_len));
        }
    }

    #[test]
    fn oversized_tiles_shard_with_exact_cover() {
        // 128×64 tiles on 64×32 arrays: each full tile → 2×2 shards.
        let g = TileGrid::new(200, 100, 128, 64);
        assert_eq!((g.k_tiles, g.n_tiles), (2, 2));
        let shards = g.shards(64, 32);
        // Every (k, n) element covered exactly once, all shards fit.
        let mut cover = vec![0u8; 200 * 100];
        for s in &shards {
            assert!(s.k_len <= 64 && s.n_len <= 32);
            assert_eq!(s.padded_rows() % GROUP_ROWS, 0);
            for r in s.k0..s.k0 + s.k_len {
                for c in s.n0..s.n0 + s.n_len {
                    cover[r * 100 + c] += 1;
                }
            }
        }
        assert!(cover.iter().all(|&c| c == 1));
        // K split points are 16-aligned, so shard windows sum per tile.
        for s in &shards {
            assert_eq!(s.k0 % GROUP_ROWS, 0);
        }
    }

    #[test]
    fn sharded_reference_equals_reference_when_shapes_match() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (2usize, 150usize, 60usize);
        let x = rng.ternary_vec(m * k, 0.5);
        let w = rng.ternary_vec(k * n, 0.5);
        let g = TileGrid::new(k, n, 64, 32);
        for flavor in [Some(Flavor::Cim1), Some(Flavor::Cim2), None] {
            assert_eq!(
                reference_gemm_sharded(&x, &w, m, &g, 64, 32, flavor),
                reference_gemm(&x, &w, m, &g, flavor),
                "{flavor:?}"
            );
        }
    }

    #[test]
    fn sharded_reference_exact_flavor_is_plain_matmul() {
        // Oversized tiles + exact MAC: recombined partial sums must be
        // the plain integer matmul.
        let mut rng = Rng::new(5);
        let (m, k, n) = (3usize, 130usize, 70usize);
        let x = rng.ternary_vec(m * k, 0.4);
        let w = rng.ternary_vec(k * n, 0.4);
        let g = TileGrid::new(k, n, 128, 64); // tiles larger than arrays
        let got = reference_gemm_sharded(&x, &w, m, &g, 32, 16, None);
        for r in 0..m {
            for c in 0..n {
                let want: i32 = (0..k).map(|i| x[r * k + i] as i32 * w[i * n + c] as i32).sum();
                assert_eq!(got[r * n + c], want, "r={r} c={c}");
            }
        }
    }

}
