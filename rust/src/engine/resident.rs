//! Resident-tile placement: the cache that keeps registered weight
//! shards programmed in the array pool across GEMM calls.
//!
//! The paper's premise is weight-stationary CiM — weights sit in the
//! arrays and only inputs stream — so re-programming every tile on every
//! call (the streaming `gemm` path) throws away the architecture's main
//! win. The resident path splits placement from execution:
//!
//! - [`WeightId`] — handle returned by `TernaryGemmEngine::register_weight`;
//!   the engine keeps the (single) ternary weight copy for cache refills.
//! - [`TileCache`] — a second-chance (CLOCK) map from [`TileKey`]
//!   (weight, shard index) to *regions*: 16-row-aligned [`Rect`]s inside
//!   pool slots, handed out by a per-slot shelf allocator. Placement
//!   granularity is the shard, not the physical array, so several small
//!   shards pack into one array and an oversized tile's shards spread
//!   across arrays. `place` returns the slot + rect plus whether the
//!   placement was already cached; when no free rect exists anywhere,
//!   resident regions are evicted until the request fits (a request
//!   never exceeds one array — the engine shards first).
//!
//! # Eviction policy: sweep-resistant second chance
//!
//! PR 3's pure LRU had the classic pathology: a cyclic sweep of W tiles
//! through a C-array pool (W > C) evicts every tile just before its
//! reuse — 0% hits at *any* capacity below the working set. The CLOCK
//! variant here keeps a victim queue whose front is the next probe:
//!
//! - a placement **hit** sets the region's *referenced* bit (its second
//!   chance);
//! - a **new** region enters at the *front* of the queue with the bit
//!   clear, so the freshest unproven region is on probation and gets
//!   evicted first;
//! - the eviction scan pops the front: a referenced region is recycled
//!   to the back with its bit cleared, an unreferenced one is evicted.
//!
//! On a cyclic sweep the probation slot churns through the sweep while
//! regions that demonstrated reuse stay resident: steady-state hits are
//! proportional to capacity (roughly the fraction of the working set
//! that fits, minus the probation slot) instead of zero. The scan
//! terminates because every recycle clears a bit. Eviction *order* is
//! deterministic for a deterministic access order (the closed forms in
//! `tests/eviction_pressure.rs` pin it), and the policy only changes
//! *which* regions are resident — never correctness, which the content
//! tags guarantee under any placement.
//!
//! # Tenant partitions
//!
//! Multi-model serving partitions the pool between tenants. Partition 0
//! is the always-present **shared** pool: every slot starts there and
//! best-effort tenants contend under one CLOCK. `reserve_partition`
//! carves a **hard reservation** out of it — the highest-numbered shared
//! slots move to a new partition with its own private victim queue, so a
//! reserved tenant's hit rate cannot be disturbed by (or disturb) anyone
//! else's traffic. `place_in` scans only the named partition's slots (in
//! ascending physical index) and evicts only from its queue; the
//! single-tenant `place` is exactly `place_in(0, ..)`, so a server that
//! never reserves behaves identically to the pre-partition cache.
//! Placement plans record a shard's **partition-relative slot rank**
//! (index into the partition's slot list), which `plan_layout` computes
//! by replaying the same first-fit on a scratch cache; the versioned
//! artifact schema that carries such plans is documented in
//! `runtime::artifact`.
//!
//! The cache only decides *routing*. Whether a rect's cells actually
//! hold the shard is tracked by per-region `programmed` tags on the pool
//! slot under the array mutex (see `engine::PoolSlot`): the streaming
//! path clears a slot's tags when it borrows the array, programming a
//! region drops every overlapping tag first, and a resident worker
//! re-programs whenever its (rect, key) tag is absent. That split keeps
//! results bit-exact under any interleaving of streaming calls, resident
//! calls and concurrent callers — stale placements only cost an extra
//! programming pass. Regions are 16-row aligned so a shard keeps the MAC
//! group structure of the `tiling::reference_gemm_sharded` specification
//! at any placement (see the `tiling` module docs for the translation-
//! invariance argument).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::array::encoding::Trit;
use crate::array::mac::GROUP_ROWS;

use super::tiling::{Rect, Shard, TileGrid};

/// Handle to a weight matrix registered with the engine for resident
/// execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WeightId(pub(crate) usize);

/// Identity of one placed region: (registered weight id, shard index in
/// the weight's flat shard order).
pub(crate) type TileKey = (usize, usize);

/// A weight matrix registered for resident execution: the shared weight
/// plane (used to (re)program regions on cache misses — an `Arc`, so
/// `register_weight_arc` callers and resident jobs share one copy with
/// zero re-cloning) plus its precomputed shard decomposition on the
/// engine's array shape.
pub(crate) struct RegisteredWeight {
    pub id: usize,
    pub k: usize,
    pub n: usize,
    pub grid: TileGrid,
    pub shards: Vec<Shard>,
    pub w: Arc<[Trit]>,
    /// Cache partition this weight's shards place into (0 = shared).
    pub partition: usize,
}

/// Outcome of one placement lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Placement {
    /// Pool slot (array index) the region lives on.
    pub slot: usize,
    /// Where on the slot's array the region lives.
    pub rect: Rect,
    /// The key was already mapped (steady-state serving path).
    pub hit: bool,
    /// Resident regions displaced to make room (0 on a hit or a
    /// free-space placement; can exceed 1 when fragmented space must be
    /// drained before the request fits).
    pub evicted: u64,
}

/// One allocated-or-free span of columns inside a shelf. Spans partition
/// `[0, slot_cols)`; freeing coalesces with free neighbours.
#[derive(Clone, Debug)]
struct Seg {
    col0: usize,
    cols: usize,
    used: bool,
}

/// A horizontal band of one array, `rows` high (multiple of 16), packed
/// left-to-right with region segments.
#[derive(Clone, Debug)]
struct Shelf {
    row0: usize,
    rows: usize,
    segs: Vec<Seg>,
}

/// Free-space tracker for one pool array: classic shelf packing. All
/// shelf offsets and heights are multiples of 16 rows, so every region
/// keeps the reference MAC group structure (see module docs).
#[derive(Clone, Debug, Default)]
struct SlotSpace {
    shelves: Vec<Shelf>,
    used_rows: usize,
}

impl SlotSpace {
    /// First-fit: reuse a free span of a tall-enough shelf, else open a
    /// new shelf at the high-water mark. `None` when neither fits.
    fn alloc(
        &mut self,
        slot_rows: usize,
        slot_cols: usize,
        rows: usize,
        cols: usize,
    ) -> Option<Rect> {
        for shelf in &mut self.shelves {
            if shelf.rows < rows {
                continue;
            }
            for i in 0..shelf.segs.len() {
                if !shelf.segs[i].used && shelf.segs[i].cols >= cols {
                    let col0 = shelf.segs[i].col0;
                    let extra = shelf.segs[i].cols - cols;
                    shelf.segs[i].cols = cols;
                    shelf.segs[i].used = true;
                    if extra > 0 {
                        shelf
                            .segs
                            .insert(i + 1, Seg { col0: col0 + cols, cols: extra, used: false });
                    }
                    return Some(Rect { row0: shelf.row0, rows, col0, cols });
                }
            }
        }
        if self.used_rows + rows <= slot_rows && cols <= slot_cols {
            let row0 = self.used_rows;
            self.used_rows += rows;
            let mut segs = vec![Seg { col0: 0, cols, used: true }];
            if cols < slot_cols {
                segs.push(Seg { col0: cols, cols: slot_cols - cols, used: false });
            }
            self.shelves.push(Shelf { row0, rows, segs });
            return Some(Rect { row0, rows, col0: 0, cols });
        }
        None
    }

    /// Release a region previously returned by [`Self::alloc`].
    fn free(&mut self, rect: &Rect) {
        let shelf = self
            .shelves
            .iter_mut()
            .find(|s| s.row0 == rect.row0)
            .expect("freed region belongs to a shelf");
        let i = shelf
            .segs
            .iter()
            .position(|g| g.used && g.col0 == rect.col0 && g.cols == rect.cols)
            .expect("freed region is an allocated segment");
        shelf.segs[i].used = false;
        if i + 1 < shelf.segs.len() && !shelf.segs[i + 1].used {
            shelf.segs[i].cols += shelf.segs[i + 1].cols;
            shelf.segs.remove(i + 1);
        }
        if i > 0 && !shelf.segs[i - 1].used {
            shelf.segs[i - 1].cols += shelf.segs[i].cols;
            shelf.segs.remove(i);
        }
        // Pop fully-free shelves off the top so their rows can re-open
        // at a different height.
        while let Some(last) = self.shelves.last() {
            if last.segs.len() == 1 && !last.segs[0].used {
                self.used_rows = last.row0;
                self.shelves.pop();
            } else {
                break;
            }
        }
    }

    fn clear(&mut self) {
        self.shelves.clear();
        self.used_rows = 0;
    }
}

#[derive(Clone, Copy, Debug)]
struct RegionInfo {
    slot: usize,
    rect: Rect,
    /// Second-chance bit: set on every placement hit, cleared when the
    /// eviction scan recycles the region past the probe.
    referenced: bool,
}

/// Index of the always-present shared (best-effort) partition.
pub(crate) const SHARED_PARTITION: usize = 0;

/// One tenant's share of the pool: the physical slots it may place on
/// (ascending array index — a plan's slot *rank* is the index into this
/// list) and its private second-chance victim queue.
#[derive(Debug)]
struct Partition {
    slots: Vec<usize>,
    /// Victim queue: front = next eviction probe. New regions enter at
    /// the front (probation); referenced regions recycle to the back.
    order: VecDeque<TileKey>,
}

/// Second-chance (CLOCK) placement of shard keys onto sub-array regions
/// of the pool. Purely bookkeeping — no array access happens here;
/// callers hold the engine's cache mutex.
#[derive(Debug)]
pub(crate) struct TileCache {
    slot_rows: usize,
    slot_cols: usize,
    slots: Vec<SlotSpace>,
    map: HashMap<TileKey, RegionInfo>,
    /// Tenant partitions of the pool (see module docs). Partition 0 is
    /// the shared best-effort pool and always exists;
    /// [`Self::reserve_partition`] carves hard reservations out of it.
    partitions: Vec<Partition>,
}

impl TileCache {
    pub fn new(n_slots: usize, slot_rows: usize, slot_cols: usize) -> TileCache {
        assert!(n_slots > 0, "cache needs at least one slot");
        assert!(
            slot_rows > 0 && slot_rows % GROUP_ROWS == 0,
            "slot rows must be a positive multiple of {GROUP_ROWS}"
        );
        assert!(slot_cols > 0, "slots must have columns");
        TileCache {
            slot_rows,
            slot_cols,
            slots: vec![SlotSpace::default(); n_slots],
            map: HashMap::new(),
            partitions: vec![Partition {
                slots: (0..n_slots).collect(),
                order: VecDeque::new(),
            }],
        }
    }

    /// Number of currently mapped regions.
    pub fn resident_regions(&self) -> usize {
        self.map.len()
    }

    /// The slot `key` is currently routed to, if any — a read-only probe
    /// for the executor's queue affinity (does not touch the second-
    /// chance bit: routing a work item is not a use of the region).
    pub fn peek_slot(&self, key: TileKey) -> Option<usize> {
        self.map.get(&key).map(|info| info.slot)
    }

    /// Route `key` to a 16-row-aligned region of (at least) `rows × cols`
    /// cells in the shared partition: reuse its mapping on a hit,
    /// otherwise claim free space, evicting second-chance victims until
    /// some slot fits the request.
    pub fn place(&mut self, key: TileKey, rows: usize, cols: usize) -> Placement {
        self.place_in(SHARED_PARTITION, key, rows, cols)
    }

    /// [`Self::place`], restricted to one tenant partition: only its
    /// slots are scanned (ascending physical index) and only its victim
    /// queue supplies evictions, so tenants with hard reservations never
    /// disturb each other's residency.
    pub fn place_in(
        &mut self,
        partition: usize,
        key: TileKey,
        rows: usize,
        cols: usize,
    ) -> Placement {
        let rows = rows.div_ceil(GROUP_ROWS) * GROUP_ROWS;
        assert!(
            rows <= self.slot_rows && cols <= self.slot_cols,
            "region {rows}×{cols} exceeds the {}×{} array (shard before placing)",
            self.slot_rows,
            self.slot_cols
        );
        if let Some(info) = self.map.get_mut(&key) {
            info.referenced = true;
            return Placement { slot: info.slot, rect: info.rect, hit: true, evicted: 0 };
        }
        let mut evicted = 0u64;
        loop {
            let mut found = None;
            for &s in &self.partitions[partition].slots {
                if let Some(rect) = self.slots[s].alloc(self.slot_rows, self.slot_cols, rows, cols)
                {
                    found = Some((s, rect));
                    break;
                }
            }
            if let Some((s, rect)) = found {
                self.map.insert(key, RegionInfo { slot: s, rect, referenced: false });
                self.partitions[partition].order.push_front(key);
                return Placement { slot: s, rect, hit: false, evicted };
            }
            // No free rect anywhere in the partition: run the second-
            // chance scan from its probe and retry (each recycle clears
            // a bit, so the scan terminates; evicting drains some slot
            // to empty in the worst case, and any sharded request fits
            // an empty array, so the outer loop ends too).
            loop {
                let victim = self
                    .partitions[partition]
                    .order
                    .pop_front()
                    .expect("an array-fitting request cannot fail with nothing resident");
                let referenced =
                    self.map.get(&victim).expect("victim queue tracks the map").referenced;
                if referenced {
                    self.map.get_mut(&victim).unwrap().referenced = false;
                    self.partitions[partition].order.push_back(victim);
                } else {
                    let info = self.map.remove(&victim).unwrap();
                    self.slots[info.slot].free(&info.rect);
                    evicted += 1;
                    break;
                }
            }
        }
    }

    /// Deterministic snapshot of the shared partition's CLOCK state:
    /// the victim-queue order with each region's slot, placed rect and
    /// second-chance bit. The arch-level packed sweep-miss model
    /// (`arch::packed_sweep_model`) replays placements against a real
    /// `TileCache` and compares these snapshots to detect the
    /// steady-state cycle of the sweep.
    pub fn clock_signature(&self) -> Vec<(TileKey, usize, Rect, bool)> {
        self.partitions[SHARED_PARTITION]
            .order
            .iter()
            .map(|key| {
                let info = &self.map[key];
                (*key, info.slot, info.rect, info.referenced)
            })
            .collect()
    }

    /// Forget every region placed on `slot` (the streaming path borrowed
    /// the whole array, so no placement there matches its cells anymore).
    pub fn invalidate_slot(&mut self, slot: usize) {
        let map = &self.map;
        for p in &mut self.partitions {
            p.order.retain(|key| map.get(key).is_some_and(|info| info.slot != slot));
        }
        self.map.retain(|_, info| info.slot != slot);
        self.slots[slot].clear();
    }

    /// Forget every region belonging to registered weight `weight` and
    /// free its space — the hot-swap path retires an old model version
    /// this way once its in-flight GEMMs drain.
    pub fn invalidate_weight(&mut self, weight: usize) {
        let slots = &mut self.slots;
        self.map.retain(|key, info| {
            if key.0 == weight {
                slots[info.slot].free(&info.rect);
                false
            } else {
                true
            }
        });
        for p in &mut self.partitions {
            p.order.retain(|key| key.0 != weight);
        }
    }

    /// Carve `n_slots` arrays out of the shared partition into a new
    /// hard-reserved partition, returning its index. The highest-
    /// numbered shared slots move (any residents they hold are
    /// invalidated), so shared placements in low slots survive. `None`
    /// when the reservation would leave the shared pool without a slot.
    pub fn reserve_partition(&mut self, n_slots: usize) -> Option<usize> {
        if n_slots == 0 || self.partitions[SHARED_PARTITION].slots.len() <= n_slots {
            return None;
        }
        let taken = {
            let shared = &mut self.partitions[SHARED_PARTITION].slots;
            let keep = shared.len() - n_slots;
            shared.split_off(keep)
        };
        for &s in &taken {
            self.invalidate_slot(s);
        }
        self.partitions.push(Partition { slots: taken, order: VecDeque::new() });
        Some(self.partitions.len() - 1)
    }

    /// Number of tenant partitions (≥ 1; partition 0 is the shared pool).
    pub fn n_partitions(&self) -> usize {
        self.partitions.len()
    }

    /// The physical slots `partition` owns, ascending — a plan's slot
    /// rank indexes this list.
    pub fn partition_slots(&self, partition: usize) -> &[usize] {
        &self.partitions[partition].slots
    }

    /// Regions currently resident in `partition` (its victim-queue
    /// length). Zero means plan replay can be verified strictly: nothing
    /// placed, nothing to evict.
    pub fn partition_resident(&self, partition: usize) -> usize {
        self.partitions[partition].order.len()
    }

    /// Partition-relative rank of physical slot `slot` within
    /// `partition` (the form placement plans record), if owned by it.
    pub fn slot_rank(&self, partition: usize, slot: usize) -> Option<usize> {
        self.partitions[partition].slots.iter().position(|&s| s == slot)
    }
}

/// One shard's planned placement, as recorded in a versioned AOT
/// artifact and replayed by `TernaryGemmEngine::program_from_plan`: the
/// shard's coordinates inside its layer's weight matrix plus the
/// partition-relative slot rank and region origin that first-fit shelf
/// packing assigns it on an empty partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlannedShard {
    pub layer: usize,
    pub shard: usize,
    pub k0: usize,
    pub k_len: usize,
    pub n0: usize,
    pub n_len: usize,
    pub slot: usize,
    pub row0: usize,
    pub col0: usize,
}

/// Compute the placement plan an empty `n_slots`-array partition would
/// assign a model's layers ((k, n) per layer, tiled at array shape), by
/// replaying the engine's own first-fit shelf packing. Returns `None`
/// when the working set does not fit without eviction — a plan is only
/// meaningful if cold-start can program it wholesale.
/// `python/compile/placement.py` mirrors this function analytically; the
/// committed example artifact pins the two against each other.
pub fn plan_layout(
    layers: &[(usize, usize)],
    array_rows: usize,
    array_cols: usize,
    n_slots: usize,
) -> Option<Vec<PlannedShard>> {
    let mut cache = TileCache::new(n_slots, array_rows, array_cols);
    let mut plan = Vec::new();
    for (li, &(k, n)) in layers.iter().enumerate() {
        let grid = TileGrid::new(k, n, array_rows, array_cols);
        for (si, shard) in grid.shards(array_rows, array_cols).iter().enumerate() {
            let p = cache.place((li, si), shard.k_len, shard.n_len);
            if p.evicted > 0 {
                return None;
            }
            plan.push(PlannedShard {
                layer: li,
                shard: si,
                k0: shard.k0,
                k_len: shard.k_len,
                n0: shard.n0,
                n_len: shard.n_len,
                slot: p.slot,
                row0: p.rect.row0,
                col0: p.rect.col0,
            });
        }
    }
    Some(plan)
}

/// Number of physical `slot_rows × slot_cols` arrays that first-fit
/// shelf packing needs for `shapes` ((rows, cols) per tile; rows are
/// padded to whole 16-row groups here). The analytic counterpart of the
/// allocator [`TileCache`] drives — `arch::mapper` uses it for packed
/// array counts.
pub fn packed_array_count(shapes: &[(usize, usize)], slot_rows: usize, slot_cols: usize) -> usize {
    let mut slots: Vec<SlotSpace> = Vec::new();
    for &(rows, cols) in shapes {
        let rows = rows.div_ceil(GROUP_ROWS) * GROUP_ROWS;
        assert!(
            rows <= slot_rows && cols <= slot_cols,
            "tile {rows}×{cols} exceeds the {slot_rows}×{slot_cols} array"
        );
        let placed = slots.iter_mut().any(|s| s.alloc(slot_rows, slot_cols, rows, cols).is_some());
        if !placed {
            let mut s = SlotSpace::default();
            s.alloc(slot_rows, slot_cols, rows, cols).expect("fresh array fits a checked tile");
            slots.push(s);
        }
    }
    slots.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Whole-array regions on a 64×32 pool: behaves like the PR 2
    /// slot-granular cache.
    fn full(c: &mut TileCache, key: TileKey) -> Placement {
        c.place(key, 64, 32)
    }

    #[test]
    fn hits_after_first_placement() {
        let mut c = TileCache::new(2, 64, 32);
        let p0 = full(&mut c, (0, 0));
        assert!(!p0.hit && p0.evicted == 0);
        let p1 = full(&mut c, (0, 0));
        assert!(p1.hit);
        assert_eq!((p1.slot, p1.rect), (p0.slot, p0.rect));
        assert_eq!(c.resident_regions(), 1);
    }

    #[test]
    fn second_chance_evicts_probation_before_referenced() {
        let mut c = TileCache::new(2, 64, 32);
        let a = full(&mut c, (0, 0)).slot;
        let b = full(&mut c, (0, 1)).slot;
        assert_ne!(a, b);
        // (0,0) proves reuse; (0,1) never does.
        assert!(full(&mut c, (0, 0)).hit);
        let p = full(&mut c, (0, 2));
        assert!(!p.hit && p.evicted == 1);
        assert_eq!(p.slot, b, "the unreferenced region is the victim");
        assert!(full(&mut c, (0, 0)).hit, "the referenced region survived");
        assert!(!full(&mut c, (0, 1)).hit);
    }

    #[test]
    fn referenced_regions_recycle_once_then_yield() {
        // Both residents referenced: the scan recycles both (clearing
        // their bits) and evicts the first one it revisits — exactly one
        // eviction, never a livelock.
        let mut c = TileCache::new(2, 64, 32);
        full(&mut c, (0, 0));
        let b = full(&mut c, (0, 1)).slot;
        assert!(full(&mut c, (0, 0)).hit);
        assert!(full(&mut c, (0, 1)).hit);
        let p = full(&mut c, (0, 2));
        assert!(!p.hit);
        assert_eq!(p.evicted, 1);
        assert_eq!(p.slot, b, "the recycle order revisits (0,1) first");
        assert!(full(&mut c, (0, 0)).hit);
    }

    #[test]
    fn cyclic_sweep_hits_capacity_proportionally() {
        // The pathology the policy swap fixes: LRU measured 0% here. A
        // 4-tile cyclic sweep through 3 slots keeps C−1 = 2 regions
        // resident in steady state while the probation slot churns.
        let mut c = TileCache::new(3, 64, 32);
        for pass in 0..3 {
            let mut hits = 0;
            for t in 0..4 {
                hits += u64::from(full(&mut c, (0, t)).hit);
            }
            let want = if pass == 0 { 0 } else { 2 };
            assert_eq!(hits, want, "pass {pass}");
        }
    }

    #[test]
    fn peek_slot_routes_without_touching_the_bit() {
        let mut c = TileCache::new(2, 64, 32);
        assert_eq!(c.peek_slot((0, 0)), None);
        let s = full(&mut c, (0, 0)).slot;
        full(&mut c, (0, 1));
        assert_eq!(c.peek_slot((0, 0)), Some(s));
        // A peek is not a use: (0,0) stays unreferenced, so it loses to
        // the referenced (0,1) when the scan needs a victim.
        assert!(full(&mut c, (0, 1)).hit);
        full(&mut c, (0, 2));
        assert_eq!(c.peek_slot((0, 0)), None, "peeked-but-unreferenced region evicted");
    }

    #[test]
    fn small_regions_pack_into_one_slot() {
        // Four 32×16 regions tile one 64×32 array: two shelves of two
        // segments each. No eviction, four resident regions, one slot.
        let mut c = TileCache::new(2, 64, 32);
        let mut slots = Vec::new();
        for t in 0..4 {
            let p = c.place((0, t), 32, 16);
            assert!(!p.hit && p.evicted == 0, "region {t}");
            assert_eq!(p.rect.rows, 32);
            assert_eq!(p.rect.row0 % GROUP_ROWS, 0, "16-row aligned");
            slots.push(p.slot);
        }
        assert!(slots.iter().all(|&s| s == slots[0]), "all packed on one array");
        assert_eq!(c.resident_regions(), 4);
        // A fifth region spills to the next slot without eviction.
        let p = c.place((0, 4), 32, 16);
        assert!(!p.hit && p.evicted == 0);
        assert_ne!(p.slot, slots[0]);
    }

    #[test]
    fn rows_pad_to_whole_groups() {
        let mut c = TileCache::new(1, 64, 32);
        let p = c.place((1, 0), 20, 8); // 20 rows → a 32-row region
        assert_eq!(p.rect.rows, 32);
        // A 48-row request no longer fits beside the 32-row shelf
        // (32 + 48 > 64), so the first region must go.
        let q = c.place((1, 1), 33, 8);
        assert!(!q.hit);
        assert_eq!(q.rect.rows, 48);
        assert_eq!(q.evicted, 1, "33 rows only fit after evicting the first region");
    }

    #[test]
    fn eviction_drains_fragmented_space_until_the_request_fits() {
        // Two 32-row shelves occupied; a full-height region must evict
        // both residents of one... all slots, then fits.
        let mut c = TileCache::new(1, 64, 32);
        c.place((0, 0), 32, 32);
        c.place((0, 1), 32, 32);
        let p = c.place((0, 2), 64, 32);
        assert_eq!(p.evicted, 2);
        assert_eq!(c.resident_regions(), 1);
        assert_eq!(p.rect, Rect { row0: 0, rows: 64, col0: 0, cols: 32 });
    }

    #[test]
    fn invalidate_slot_frees_all_its_regions() {
        let mut c = TileCache::new(2, 64, 32);
        let s = c.place((7, 0), 32, 16).slot;
        c.place((7, 1), 32, 16); // packs on the same slot
        c.place((7, 2), 64, 32); // fills the other slot
        assert_eq!(c.resident_regions(), 3);
        c.invalidate_slot(s);
        assert_eq!(c.resident_regions(), 1);
        // The freed slot is reusable immediately, no eviction.
        let p = c.place((7, 3), 64, 32);
        assert_eq!(p.slot, s);
        assert_eq!(p.evicted, 0);
    }

    #[test]
    fn freeing_coalesces_and_reopens_shelves() {
        let mut c = TileCache::new(1, 64, 32);
        c.place((0, 0), 32, 16);
        c.place((0, 1), 32, 16);
        c.place((0, 2), 32, 32);
        // (0,2) proves reuse, so the scan recycles it and evicts the two
        // unreferenced 16-col neighbours instead — whose columns must
        // coalesce so a full-width region fits in their place.
        assert!(c.place((0, 2), 32, 32).hit);
        let p = c.place((0, 3), 32, 32);
        assert!(!p.hit);
        assert_eq!(p.evicted, 2, "both 16-col residents of the shelf evicted");
        assert_eq!(p.rect, Rect { row0: 0, rows: 32, col0: 0, cols: 32 });
        assert_eq!(c.resident_regions(), 2);
    }

    #[test]
    fn fast_mode_capacity_sweep_matches_seeded_baseline() {
        // The exact placement sequence benches/capacity_bench.rs replays
        // in fast mode (AlexNet-FC/8: (1152,512) + (512,512) + (512,128)
        // on 256×256 arrays = 10 + 4 + 2 tiles, one warm pass then two
        // measured passes), pinned against the hit-rate seeds committed
        // in BENCH_capacity_baseline.json. If this closed form moves,
        // the policy changed — update the seeds (and the bench gate)
        // deliberately, not accidentally.
        let dims = [(1152usize, 512usize), (512, 512), (512, 128)];
        // The *real* decomposition and order the engine places — if
        // `TileGrid`'s splitting ever changes, this sequence moves with
        // it instead of silently pinning a stale copy.
        let shapes: Vec<Vec<(usize, usize)>> = dims
            .iter()
            .map(|&(k, n)| {
                TileGrid::new(k, n, 256, 256)
                    .shards(256, 256)
                    .iter()
                    .map(|s| (s.k_len, s.n_len))
                    .collect()
            })
            .collect();
        let mut keys: Vec<Vec<usize>> = Vec::new();
        let mut next = 0usize;
        for lt in &shapes {
            keys.push((next..next + lt.len()).collect());
            next += lt.len();
        }
        assert_eq!(next, 16, "10 + 4 + 2 tiles");
        // (arrays, hits, misses, evictions) over the two measured passes.
        let expect = [
            (4usize, 6u64, 26u64, 26u64),
            (8, 14, 18, 18),
            (12, 24, 8, 8),
            (16, 32, 0, 0),
            (32, 32, 0, 0),
        ];
        for (arrays, hits, misses, evictions) in expect {
            let mut c = TileCache::new(arrays, 256, 256);
            let pass = |c: &mut TileCache| {
                let (mut h, mut m, mut e) = (0u64, 0u64, 0u64);
                for (ks, lt) in keys.iter().zip(&shapes) {
                    for (&key, &(rows, cols)) in ks.iter().zip(lt) {
                        let p = c.place((0, key), rows, cols);
                        if p.hit {
                            h += 1;
                        } else {
                            m += 1;
                        }
                        e += p.evicted;
                    }
                }
                (h, m, e)
            };
            pass(&mut c); // warm
            let (mut h, mut m, mut e) = (0u64, 0u64, 0u64);
            for _ in 0..2 {
                let (dh, dm, de) = pass(&mut c);
                h += dh;
                m += dm;
                e += de;
            }
            assert_eq!(
                (h, m, e),
                (hits, misses, evictions),
                "{arrays}-array sweep diverged from the seeded baseline"
            );
        }
    }

    #[test]
    fn fast_mode_tenant_split_matches_seeded_baseline() {
        // The two-tenant placement sequence benches/capacity_bench.rs
        // replays in fast mode (`proxy_tenant_counters`): layer 0 of the
        // AlexNet-FC/8 stack hard-reserves half the pool, layers 1..
        // share the remainder, one warm pass then two measured passes.
        // Pinned against the `tenant:res` / `tenant:shared` hit-rate
        // seeds committed in BENCH_capacity_baseline.json — if these
        // counts move, the partitioned policy changed: update the seeds
        // deliberately, not accidentally.
        let dims = [(1152usize, 512usize), (512, 512), (512, 128)];
        let shapes: Vec<Vec<(usize, usize)>> = dims
            .iter()
            .map(|&(k, n)| {
                TileGrid::new(k, n, 256, 256)
                    .shards(256, 256)
                    .iter()
                    .map(|s| (s.k_len, s.n_len))
                    .collect()
            })
            .collect();
        let mut keys: Vec<Vec<usize>> = Vec::new();
        let mut next = 0usize;
        for lt in &shapes {
            keys.push((next..next + lt.len()).collect());
            next += lt.len();
        }
        // (pool arrays, reserved (h, m, e), shared (h, m, e)) over the
        // two measured passes; reserve = pool/2 slots, layer 0 places
        // into the reservation (20 lookups), layers 1+2 into the shared
        // remainder (12 lookups).
        let expect = [
            (4usize, (2u64, 18u64, 18u64), (2u64, 10u64, 10u64)),
            (8, (6, 14, 14), (6, 6, 6)),
            (12, (10, 10, 10), (12, 0, 0)),
            (16, (14, 6, 6), (12, 0, 0)),
            (32, (20, 0, 0), (12, 0, 0)),
        ];
        for (arrays, want_res, want_shared) in expect {
            let mut c = TileCache::new(arrays, 256, 256);
            let res = c.reserve_partition(arrays / 2).expect("half-pool reservation fits");
            let pass = |c: &mut TileCache| {
                // Per-partition (hits, misses, evictions), reserved then
                // shared — the per-tenant stat books of the real engine.
                let mut counts = [(0u64, 0u64, 0u64); 2];
                for (li, (ks, lt)) in keys.iter().zip(&shapes).enumerate() {
                    let (part, book) = if li == 0 { (res, 0) } else { (SHARED_PARTITION, 1) };
                    for (&key, &(rows, cols)) in ks.iter().zip(lt) {
                        let p = c.place_in(part, (0, key), rows, cols);
                        if p.hit {
                            counts[book].0 += 1;
                        } else {
                            counts[book].1 += 1;
                        }
                        counts[book].2 += p.evicted;
                    }
                }
                counts
            };
            pass(&mut c); // warm
            let mut total = [(0u64, 0u64, 0u64); 2];
            for _ in 0..2 {
                let d = pass(&mut c);
                for (t, dt) in total.iter_mut().zip(d) {
                    t.0 += dt.0;
                    t.1 += dt.1;
                    t.2 += dt.2;
                }
            }
            assert_eq!(
                total[0], want_res,
                "{arrays}-array reserved tenant diverged from the seeded baseline"
            );
            assert_eq!(
                total[1], want_shared,
                "{arrays}-array shared tenant diverged from the seeded baseline"
            );
        }
    }

    #[test]
    fn reserve_takes_highest_slots_and_isolates_eviction_pressure() {
        let mut c = TileCache::new(3, 64, 32);
        full(&mut c, (0, 0)); // slot 0
        full(&mut c, (0, 1)); // slot 1
        full(&mut c, (0, 2)); // slot 2 — about to be reserved away
        let p = c.reserve_partition(1).expect("2 shared slots remain");
        assert_eq!(p, 1);
        assert_eq!(c.partition_slots(1), &[2]);
        assert_eq!(c.partition_slots(SHARED_PARTITION), &[0, 1]);
        assert_eq!(c.resident_regions(), 2, "slot 2's resident was invalidated");
        // A cyclic sweep inside the 1-slot reservation evicts only its
        // own regions; the shared residents are untouched by it.
        for t in 0..4 {
            let q = c.place_in(p, (9, t), 64, 32);
            assert_eq!(q.slot, 2);
            assert!(!q.hit);
        }
        assert!(full(&mut c, (0, 0)).hit, "shared resident survived tenant churn");
        assert!(full(&mut c, (0, 1)).hit);
        // And shared pressure cannot spill into the reservation: a third
        // shared region evicts a shared victim, never slot 2.
        let q = full(&mut c, (0, 3));
        assert!(q.slot < 2);
        assert_eq!(c.peek_slot((9, 3)), Some(2), "tenant region still resident");
    }

    #[test]
    fn reserve_partition_refuses_to_empty_the_shared_pool() {
        let mut c = TileCache::new(2, 64, 32);
        assert_eq!(c.reserve_partition(2), None);
        assert_eq!(c.reserve_partition(0), None);
        assert_eq!(c.reserve_partition(1), Some(1));
        assert_eq!(c.n_partitions(), 2);
        assert_eq!(c.slot_rank(1, 1), Some(0));
        assert_eq!(c.slot_rank(1, 0), None);
    }

    #[test]
    fn invalidate_weight_frees_only_that_weight() {
        let mut c = TileCache::new(2, 64, 32);
        c.place((3, 0), 32, 16);
        c.place((3, 1), 32, 16);
        c.place((4, 0), 32, 16);
        assert_eq!(c.resident_regions(), 3);
        c.invalidate_weight(3);
        assert_eq!(c.resident_regions(), 1);
        assert_eq!(c.peek_slot((4, 0)), Some(0));
        // The freed shelf space is immediately reusable without eviction.
        let p = c.place((5, 0), 32, 16);
        assert_eq!((p.slot, p.evicted), (0, 0));
    }

    #[test]
    fn plan_layout_matches_live_placement_and_detects_overflow() {
        let dims = [(1152usize, 512usize), (512, 512), (512, 128)];
        let plan = plan_layout(&dims, 256, 256, 16).expect("16 arrays fit the working set");
        assert_eq!(plan.len(), 16, "10 + 4 + 2 shards");
        // Replaying the plan's shards through a live cache reproduces
        // slot rank and region origin exactly.
        let mut c = TileCache::new(16, 256, 256);
        for s in &plan {
            let p = c.place((s.layer, s.shard), s.k_len, s.n_len);
            assert!(!p.hit && p.evicted == 0);
            assert_eq!((p.slot, p.rect.row0, p.rect.col0), (s.slot, s.row0, s.col0));
        }
        assert!(plan_layout(&dims, 256, 256, 4).is_none(), "4 arrays need evictions");
    }

    #[test]
    fn packed_array_count_packs_and_rounds() {
        // Four full-array tiles: no packing possible.
        assert_eq!(packed_array_count(&[(256, 256); 4], 256, 256), 4);
        // Four quarter arrays pack into one.
        assert_eq!(packed_array_count(&[(128, 128); 4], 256, 256), 1);
        // Ragged mix: (256,256) fills array 0; (44,256) pads to a
        // full-width 48-row shelf on array 1; (256,44) fits neither and
        // opens array 2; (44,44) opens a second shelf on array 1.
        let shapes = [(256, 256), (44, 256), (256, 44), (44, 44)];
        assert_eq!(packed_array_count(&shapes, 256, 256), 3);
        assert_eq!(packed_array_count(&[], 256, 256), 0);
    }
}
