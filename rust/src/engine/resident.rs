//! Resident-tile placement: the cache that keeps registered weight tiles
//! programmed in the array pool across GEMM calls.
//!
//! The paper's premise is weight-stationary CiM — weights sit in the
//! arrays and only inputs stream — so re-programming every tile on every
//! call (the streaming `gemm` path) throws away the architecture's main
//! win. The resident path splits placement from execution:
//!
//! - [`WeightId`] — handle returned by `TernaryGemmEngine::register_weight`;
//!   the engine keeps the (single) ternary weight copy for cache refills.
//! - [`TileCache`] — an LRU map from [`TileKey`] (weight, tile index) to
//!   pool slots. `place` returns the slot plus whether the placement was
//!   already cached; a miss evicts the least-recently-used slot.
//!
//! The cache only decides *routing*. Whether the slot's array actually
//! holds the tile is tracked by the pool slot's `programmed` tag under
//! the array mutex (see `engine::PoolSlot`): the streaming path clears
//! the tag when it borrows an array, and a resident worker re-programs
//! whenever tag ≠ key. That split keeps results bit-exact under any
//! interleaving of streaming calls, resident calls and concurrent
//! callers — stale placements only cost an extra programming pass.

use std::collections::HashMap;

use crate::array::encoding::Trit;

use super::tiling::{Tile, TileGrid};

/// Handle to a weight matrix registered with the engine for resident
/// execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WeightId(pub(crate) usize);

/// Identity of one placed tile: (registered weight id, tile index in its
/// k-major grid order).
pub(crate) type TileKey = (usize, usize);

/// A weight matrix registered for resident execution: the engine's own
/// copy of the trits (used to (re)program tiles on cache misses) plus its
/// precomputed tile decomposition.
pub(crate) struct RegisteredWeight {
    pub id: usize,
    pub k: usize,
    pub n: usize,
    pub grid: TileGrid,
    pub tiles: Vec<Tile>,
    pub w: Vec<Trit>,
}

/// Outcome of one placement lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct Placement {
    /// Pool slot (array index) the tile is routed to.
    pub slot: usize,
    /// The key was already mapped (steady-state serving path).
    pub hit: bool,
    /// A different key was displaced to make room.
    pub evicted: bool,
}

/// LRU placement of tile keys onto array-pool slots. Purely bookkeeping —
/// no array access happens here; callers hold the engine's cache mutex.
#[derive(Debug)]
pub(crate) struct TileCache {
    /// Per-slot reverse mapping + recency stamp (0 = never used / freed).
    keys: Vec<Option<TileKey>>,
    stamps: Vec<u64>,
    map: HashMap<TileKey, usize>,
    clock: u64,
}

impl TileCache {
    pub fn new(n_slots: usize) -> TileCache {
        assert!(n_slots > 0, "cache needs at least one slot");
        TileCache {
            keys: vec![None; n_slots],
            stamps: vec![0; n_slots],
            map: HashMap::new(),
            clock: 0,
        }
    }

    /// Number of currently mapped tiles.
    pub fn resident_tiles(&self) -> usize {
        self.map.len()
    }

    /// Route `key` to a slot: reuse its mapping on a hit, otherwise claim
    /// the least-recently-used slot (evicting whatever it held).
    pub fn place(&mut self, key: TileKey) -> Placement {
        self.clock += 1;
        if let Some(&slot) = self.map.get(&key) {
            self.stamps[slot] = self.clock;
            return Placement { slot, hit: true, evicted: false };
        }
        let slot = (0..self.stamps.len())
            .min_by_key(|&s| self.stamps[s])
            .expect("cache has at least one slot");
        let evicted = match self.keys[slot].take() {
            Some(old) => {
                self.map.remove(&old);
                true
            }
            None => false,
        };
        self.keys[slot] = Some(key);
        self.stamps[slot] = self.clock;
        self.map.insert(key, slot);
        Placement { slot, hit: false, evicted }
    }

    /// Forget whatever is placed on `slot` (the streaming path borrowed
    /// the array, so its contents no longer match the placement). The
    /// slot becomes the preferred LRU victim.
    pub fn invalidate_slot(&mut self, slot: usize) {
        if let Some(old) = self.keys[slot].take() {
            self.map.remove(&old);
        }
        self.stamps[slot] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_after_first_placement() {
        let mut c = TileCache::new(2);
        let p0 = c.place((0, 0));
        assert!(!p0.hit && !p0.evicted);
        let p1 = c.place((0, 0));
        assert!(p1.hit);
        assert_eq!(p1.slot, p0.slot);
        assert_eq!(c.resident_tiles(), 1);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = TileCache::new(2);
        let a = c.place((0, 0)).slot;
        let b = c.place((0, 1)).slot;
        assert_ne!(a, b);
        // Touch (0,0) so (0,1) is the LRU victim.
        assert!(c.place((0, 0)).hit);
        let p = c.place((0, 2));
        assert!(!p.hit && p.evicted);
        assert_eq!(p.slot, b);
        // (0,1) was displaced; (0,0) survived.
        assert!(c.place((0, 0)).hit);
        assert!(!c.place((0, 1)).hit);
    }

    #[test]
    fn sequential_sweep_larger_than_cache_never_hits() {
        // The classic LRU pathology the counters must make visible.
        let mut c = TileCache::new(3);
        for pass in 0..2 {
            for t in 0..4 {
                assert!(!c.place((0, t)).hit, "pass {pass} tile {t}");
            }
        }
    }

    #[test]
    fn invalidate_slot_frees_mapping_and_prefers_slot() {
        let mut c = TileCache::new(3);
        let s = c.place((7, 0)).slot;
        c.place((7, 1));
        c.invalidate_slot(s);
        assert_eq!(c.resident_tiles(), 1);
        // The freed slot is reused before any eviction happens.
        let p = c.place((7, 2));
        assert_eq!(p.slot, s);
        assert!(!p.evicted);
    }
}
