//! The paper's contribution: signed-ternary CiM arrays.
//!
//! - [`encoding`] — the W/I/O encodings and electrical truth tables.
//! - [`storage`] — bit-packed ternary weight planes (shared substrate).
//! - [`cim`] — the [`CimArray`] trait: one polymorphic surface over the
//!   three backends (storage plumbing, `mac_cycle`, `dot`, `dot_batch`),
//!   plus the boxed-backend factory the engine pools.
//! - [`sitecim1`] — SiTe CiM I: cross-coupled cells, voltage sensing.
//! - [`sitecim2`] — SiTe CiM II: cross-coupled sub-columns, current
//!   sensing, block-strided row assertion.
//! - [`near_memory`] — the row-by-row NM baseline with exact digital MAC.
//! - [`mac`] — the saturating MAC semantics both flavors implement, with
//!   bit-packed single, batched and region-scoped (`dot_region_*`, over
//!   a [`Rect`] of one array) fast paths for both flavors plus the
//!   exact region path for the NM baseline.
//! - [`metrics`] — latency/energy models per (design, op) → Figs 9/11.
//! - [`area`] — layout-area models → §V.1a/V.2a, Figs 8/10.
//! - [`variation`] — V_TH variation Monte Carlo → error probability.

pub mod area;
pub mod cim;
pub mod encoding;
pub mod mac;
pub mod metrics;
pub mod near_memory;
pub mod sitecim1;
pub mod sitecim2;
pub mod storage;
pub mod variation;

pub use area::Design;
pub use cim::{make_array, CimArray};
pub use mac::{Flavor, Rect};
pub use near_memory::NearMemoryArray;
pub use sitecim1::SiTeCim1Array;
pub use sitecim2::SiTeCim2Array;
pub use storage::TernaryStorage;
