//! V_TH-variation Monte-Carlo and the compute-error-probability analysis
//! (§III.2: total error probability 3.10e-3 with 16-row assertion).
//!
//! Error probability decomposes as
//!   P(err) = Σ_n  P(output = n) · P(sense error | margin(n))
//! where the margin comes from the calibrated bit-line ladder, the sensing
//! noise is Gaussian (σ from V_TH variation reflected onto the ADC
//! references), and the output-value occurrence distribution comes from
//! the workload's sparsity (sparse ternary DNNs rarely produce large
//! outputs — the effect the paper leans on to assert 16 rows).

use crate::circuit::bitline::VoltageBitline;
use crate::util::rng::Rng;

/// Gaussian tail: P(N(0,σ) > x).
pub fn q_func(x: f64, sigma: f64) -> f64 {
    if sigma <= 0.0 {
        return if x > 0.0 { 0.0 } else { 0.5 };
    }
    0.5 * erfc_approx(x / (sigma * std::f64::consts::SQRT_2))
}

/// Abramowitz–Stegun 7.1.26 erfc approximation (|ε| < 1.5e-7).
fn erfc_approx(x: f64) -> f64 {
    let sign_neg = x < 0.0;
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))))
        * (-x * x).exp();
    if sign_neg {
        2.0 - y
    } else {
        y
    }
}

/// Occurrence probability of each per-cycle output magnitude 0..=16 for a
/// 16-row group with i.i.d. sparse ternary inputs/weights.
/// `p_nz_in`/`p_nz_w`: probability an input/weight is non-zero.
pub fn output_distribution(p_nz_in: f64, p_nz_w: f64) -> Vec<f64> {
    // Per row, P(product = ±1) = p_nz_in · p_nz_w; the two RBL counts are
    // binomial. We want the distribution of each ADC's count (a or b):
    // product is +1 with q/2, −1 with q/2 where q = p_nz_in·p_nz_w.
    let q_half = p_nz_in * p_nz_w / 2.0;
    let n = 16usize;
    // Binomial(16, q_half) pmf.
    let mut pmf = vec![0.0f64; n + 1];
    for (k, p) in pmf.iter_mut().enumerate() {
        *p = binom_pmf(n, k, q_half);
    }
    pmf
}

fn binom_pmf(n: usize, k: usize, p: f64) -> f64 {
    let mut c = 1.0f64;
    for i in 0..k {
        c *= (n - i) as f64 / (i + 1) as f64;
    }
    c * p.powi(k as i32) * (1.0 - p).powi((n - k) as i32)
}

/// P(sense error | expected count = n): a Gaussian reference/signal offset
/// of σ volts flips the code when it exceeds the margin on either side.
pub fn sense_error_prob(bl: &VoltageBitline, n: usize, sigma_v: f64) -> f64 {
    let lo = if n == 0 { f64::INFINITY } else { bl.sense_margin(n) };
    let hi = if n >= 16 { f64::INFINITY } else { bl.sense_margin(n + 1) };
    let p = q_func(lo, sigma_v) + q_func(hi, sigma_v);
    p.min(1.0)
}

/// Total per-(column, cycle) compute error probability, combining the
/// occurrence distribution with the per-level sensing error.
pub fn total_error_prob(sigma_v: f64, p_nz_in: f64, p_nz_w: f64) -> f64 {
    let bl = VoltageBitline::new(1.0);
    let occ = output_distribution(p_nz_in, p_nz_w);
    occ.iter().enumerate().map(|(n, p)| p * sense_error_prob(&bl, n, sigma_v)).sum()
}

/// σ of the effective sensing offset from V_TH variation. The paper's
/// conservative design targets SM > 40 mV; a 16 mV σ (≈3.1σ at the n=1
/// margin, ≈2.5σ at n=8) reproduces the reported ~3.1e-3 total error
/// probability at the benchmark sparsity.
pub const SIGMA_VTH_SENSE_V: f64 = 0.016;

/// Monte-Carlo cross-check of `total_error_prob` by direct simulation.
pub fn mc_error_prob(sigma_v: f64, p_nz_in: f64, p_nz_w: f64, trials: usize, rng: &mut Rng) -> f64 {
    let bl = VoltageBitline::new(1.0);
    let mut errors = 0usize;
    for _ in 0..trials {
        // Draw a count from the workload distribution.
        let mut count = 0usize;
        for _ in 0..16 {
            if rng.chance(p_nz_in * p_nz_w / 2.0) {
                count += 1;
            }
        }
        let v = bl.v_after(count) + rng.normal_ms(0.0, sigma_v);
        // Ideal-reference quantize.
        let mut code = 0u32;
        for k in 1..=8usize {
            if v < bl.reference(k) {
                code += 1;
            }
        }
        if code != count.min(8) as u32 {
            errors += 1;
        }
    }
    errors as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_func_basics() {
        assert!((q_func(0.0, 1.0) - 0.5).abs() < 1e-6);
        assert!(q_func(3.0, 1.0) < 0.0015);
        assert!(q_func(-1.0, 1.0) > 0.8);
        assert_eq!(q_func(0.01, 0.0), 0.0);
    }

    #[test]
    fn output_distribution_sums_to_one() {
        let d = output_distribution(0.5, 0.5);
        let s: f64 = d.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        // Sparse workloads concentrate mass at small outputs.
        assert!(d[0] + d[1] + d[2] > 0.6, "{:?}", &d[..4]);
        assert!(d[9..].iter().sum::<f64>() < 1e-4);
    }

    #[test]
    fn error_prob_matches_paper_order_of_magnitude() {
        // §III.2: total error probability ≈ 3.10e-3.
        let p = total_error_prob(SIGMA_VTH_SENSE_V, 0.5, 0.5);
        assert!(p > 0.5e-3 && p < 8e-3, "P(err) = {p:.2e}");
    }

    #[test]
    fn denser_workload_errs_more() {
        let sparse = total_error_prob(SIGMA_VTH_SENSE_V, 0.3, 0.3);
        let dense = total_error_prob(SIGMA_VTH_SENSE_V, 0.9, 0.9);
        assert!(dense > sparse);
    }

    #[test]
    fn analytic_and_mc_agree() {
        let mut rng = Rng::new(2024);
        let ana = total_error_prob(SIGMA_VTH_SENSE_V, 0.5, 0.5);
        let mc = mc_error_prob(SIGMA_VTH_SENSE_V, 0.5, 0.5, 200_000, &mut rng);
        // Both small probabilities; agree within 2× (MC noise).
        assert!(mc < 2.5 * ana + 1e-3 && ana < 2.5 * mc + 1e-3, "ana={ana:.2e} mc={mc:.2e}");
    }

    #[test]
    fn zero_sigma_zero_errors() {
        assert_eq!(total_error_prob(0.0, 0.5, 0.5), 0.0);
    }
}
