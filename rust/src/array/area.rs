//! Layout-area model (paper §V.1a, §V.2a, Figs 8 & 10).
//!
//! Cell dimensions are pitch arithmetic in F (feature size), derived from
//! the paper's layout discussion:
//! - NM ternary cell = two binary bit-cells side by side.
//! - SiTe CiM I adds AX3/AX4 (+4F of width) and an RWL2 routing track
//!   (height bump); the relative hit is larger for the small 3T cells
//!   than the 8T SRAM — the paper's 18% / 34% / 34%.
//! - SiTe CiM II keeps the NM cell footprint and adds two poly pitches
//!   (8F) of shared-transistor strip per 16-row block: +8F / 128F ≈ 6%
//!   for every technology (the paper lays all three out at 8F row pitch).
//! - The TiM-DNN reference cell [20] uses two 6T SRAMs + 5 access/control
//!   transistors: ~1.8× the SiTe CiM I SRAM footprint (the paper reports
//!   our cell as 44% smaller).
//!
//! Macro-level area adds the column periphery: per-column ADCs for CiM
//! (the dominant overhead) vs the NMC MAC slice for the baselines.

use crate::device::{PeriphParams, Tech, TechParams};

/// Array design flavor for area/metric queries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Design {
    NearMemory,
    Cim1,
    Cim2,
}

impl Design {
    pub const ALL: [Design; 3] = [Design::NearMemory, Design::Cim1, Design::Cim2];

    pub fn name(&self) -> &'static str {
        match self {
            Design::NearMemory => "NM baseline",
            Design::Cim1 => "SiTe CiM I",
            Design::Cim2 => "SiTe CiM II",
        }
    }

    /// The design's saturating-MAC flavor (`None` for the exact
    /// near-memory baseline) — the single source for the design↔flavor
    /// mapping used by the trait layer, the engine and the references.
    pub fn flavor(&self) -> Option<super::mac::Flavor> {
        match self {
            Design::NearMemory => None,
            Design::Cim1 => Some(super::mac::Flavor::Cim1),
            Design::Cim2 => Some(super::mac::Flavor::Cim2),
        }
    }
}

/// Ternary-cell layout box (width × height in F) for a design point.
#[derive(Clone, Copy, Debug)]
pub struct CellGeom {
    pub w_f: f64,
    pub h_f: f64,
}

impl CellGeom {
    pub fn area_f2(&self) -> f64 {
        self.w_f * self.h_f
    }

    pub fn area_m2(&self, p: &TechParams) -> f64 {
        self.area_f2() * p.f_m * p.f_m
    }
}

/// Ternary-cell geometry for (tech, design). Heights for CiM II include
/// the amortized shared-transistor strip (8F per 16 rows → +0.5F/row).
pub fn cell_geom(p: &TechParams, design: Design) -> CellGeom {
    let (w, h) = (p.cell_w_f, p.cell_h_f);
    match design {
        // Two binary cells side by side.
        Design::NearMemory => CellGeom { w_f: 2.0 * w, h_f: h },
        // +4F width (AX3, AX4 at 2F pitch each) + RWL2 track height bump.
        Design::Cim1 => {
            let dh = match p.tech {
                Tech::Sram8T => 0.7,  // track absorbed into the tall 8T cell
                _ => 0.9,             // small 3T cells pay the full track
            };
            CellGeom { w_f: 2.0 * w + 4.0, h_f: h + dh }
        }
        // Paper lays CiM II cells at a uniform 8F row pitch; the block's
        // shared strip adds 8F per 16 rows (= 0.5F amortized per row).
        Design::Cim2 => {
            let h2 = 8.0;
            // Cell content that doesn't fit the 8F pitch moves sideways.
            let w2 = 2.0 * w * (h / h2);
            CellGeom { w_f: w2, h_f: h2 + 8.0 / 16.0 }
        }
    }
}

/// Ternary cell area overhead of a CiM design vs the NM baseline cell.
pub fn cell_overhead(p: &TechParams, design: Design) -> f64 {
    cell_geom(p, design).area_f2() / cell_geom(p, Design::NearMemory).area_f2() - 1.0
}

/// TiM-DNN [20] SRAM ternary cell: two 6T SRAM + 5 control/access
/// transistors; prior art the paper beats by 44% (§V.1a).
pub fn timdnn_cell_f2() -> f64 {
    // The TiM cell lays out at a relaxed CiM-compatible pitch: two 6T
    // SRAMs (~260 F² each at the dual-wordline pitch), a 5-transistor
    // access/control stripe (~220 F²) plus ternary routing tracks
    // (~100 F²) ≈ 840 F². Consistent with the paper's two published
    // comparisons: 44% larger than our CiM I SRAM cell, ~3.3–3.9× the
    // CiM I FEMFET cell [21].
    2.0 * 260.0 + 220.0 + 100.0
}

/// Array-core area (m²): n_rows × n_cols ternary cells.
pub fn array_core_area(p: &TechParams, design: Design, n_rows: usize, n_cols: usize) -> f64 {
    cell_geom(p, design).area_m2(p) * (n_rows * n_cols) as f64
}

/// Macro area (m²): array core + column periphery.
/// - CiM I: 2 voltage ADCs per column + digital subtractor slice.
/// - CiM II: 1 current ADC + comparator/subtractor slice per column.
/// - NM: voltage SAs (in-core pitch) + NMC MAC slice per ternary column.
pub fn macro_area(
    p: &TechParams,
    pp: &PeriphParams,
    design: Design,
    n_rows: usize,
    n_cols: usize,
) -> f64 {
    let core = array_core_area(p, design, n_rows, n_cols);
    let periph = match design {
        Design::NearMemory => n_cols as f64 * pp.a_nm_mac_col,
        Design::Cim1 => n_cols as f64 * (2.0 * pp.a_adc + 0.2 * pp.a_nm_mac_col),
        Design::Cim2 => n_cols as f64 * (pp.a_adc + pp.a_cmp_sub + 0.2 * pp.a_nm_mac_col),
    };
    core + periph
}

/// Macro-level area ratio of a CiM design vs the NM baseline macro.
pub fn macro_overhead_ratio(p: &TechParams, pp: &PeriphParams, design: Design) -> f64 {
    macro_area(p, pp, design, 256, 256) / macro_area(p, pp, Design::NearMemory, 256, 256)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{PeriphParams, TechParams};

    #[test]
    fn cim1_cell_overheads_match_paper_bands() {
        // Paper: 18% (SRAM), 34% (eDRAM), 34% (FEMFET), tolerance ±4pp.
        let expect = [(Tech::Sram8T, 0.18), (Tech::Edram3T, 0.34), (Tech::Femfet3T, 0.34)];
        for (tech, target) in expect {
            let p = TechParams::new(tech);
            let o = cell_overhead(&p, Design::Cim1);
            assert!((o - target).abs() < 0.04, "{}: overhead {:.3} vs {target}", tech.name(), o);
        }
    }

    #[test]
    fn cim2_cell_overhead_is_6pct_everywhere() {
        for tech in Tech::ALL {
            let p = TechParams::new(tech);
            let o = cell_overhead(&p, Design::Cim2);
            assert!((o - 0.0625).abs() < 0.01, "{}: {:.3}", tech.name(), o);
        }
    }

    #[test]
    fn sitecim1_sram_cell_44pct_below_timdnn() {
        let p = TechParams::new(Tech::Sram8T);
        let ours = cell_geom(&p, Design::Cim1).area_f2();
        let reduction = 1.0 - ours / timdnn_cell_f2();
        assert!((reduction - 0.44).abs() < 0.06, "reduction = {reduction:.3}");
    }

    #[test]
    fn macro_overheads_in_paper_ranges() {
        let pp = PeriphParams::default_45nm();
        for tech in Tech::ALL {
            let p = TechParams::new(tech);
            let r1 = macro_overhead_ratio(&p, &pp, Design::Cim1);
            let r2 = macro_overhead_ratio(&p, &pp, Design::Cim2);
            // Paper: CiM I 1.3–1.53×, CiM II 1.21–1.33× (±0.12 band).
            assert!((1.20..=1.65).contains(&r1), "{}: CiM I macro ratio {r1:.3}", tech.name());
            assert!((1.09..=1.45).contains(&r2), "{}: CiM II macro ratio {r2:.3}", tech.name());
            assert!(r2 < r1, "{}: CiM II should be denser", tech.name());
        }
    }

    #[test]
    fn cim2_denser_than_cim1_at_cell_level() {
        // §V.3: 10% lower cell area for SRAM, 21% for eDRAM/FEMFET.
        let expect = [(Tech::Sram8T, 0.10), (Tech::Edram3T, 0.21), (Tech::Femfet3T, 0.21)];
        for (tech, target) in expect {
            let p = TechParams::new(tech);
            let a1 = cell_geom(&p, Design::Cim1).area_f2();
            let a2 = cell_geom(&p, Design::Cim2).area_f2();
            let saving = 1.0 - a2 / a1;
            assert!((saving - target).abs() < 0.05, "{}: saving {saving:.3} vs {target}", tech.name());
        }
    }
}
