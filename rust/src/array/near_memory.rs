//! Near-memory (NM) baseline array (paper §V preamble).
//!
//! A standard 512×256 binary array (= 256×256 ternary words, two bit-cells
//! per ternary weight), voltage-sensed, read row-by-row. Dot products are
//! computed *outside* the array in a near-memory compute (NMC) unit: for
//! each of the 16 rows of a MAC window the row is read, multiplied by its
//! input trit and accumulated — exact digital arithmetic, no ADC, no
//! saturation. This is both the performance baseline and the accuracy
//! reference.
//!
//! As a [`CimArray`] backend it reports no [`super::mac::Flavor`]: the trait's `dot`
//! surface computes the exact MAC (`dot_exact` keeps the wide `i64`
//! inherent form for accuracy references).

use super::area::Design;
use super::cim::CimArray;
use super::encoding::Trit;
use super::storage::TernaryStorage;
use crate::device::{Tech, TechParams};

#[derive(Clone, Debug)]
pub struct NearMemoryArray {
    storage: TernaryStorage,
    pub params: TechParams,
}

impl NearMemoryArray {
    pub fn new(tech: Tech) -> NearMemoryArray {
        Self::with_dims(tech, 256, 256)
    }

    pub fn with_dims(tech: Tech, n_rows: usize, n_cols: usize) -> NearMemoryArray {
        NearMemoryArray {
            storage: TernaryStorage::new(n_rows, n_cols),
            params: TechParams::new(tech),
        }
    }

    /// The NMC unit's dot product at full precision: sequential row
    /// reads, exact MAC, `i64` accumulators. Rows with input 0 are
    /// skipped (the NMC unit gates them — the same sparsity the CiM
    /// designs exploit electrically).
    pub fn dot_exact(&self, inputs: &[Trit]) -> Vec<i64> {
        assert_eq!(inputs.len(), self.storage.n_rows());
        let mut acc = vec![0i64; self.storage.n_cols()];
        for (row, &i) in inputs.iter().enumerate() {
            if i == 0 {
                continue;
            }
            for (c, a) in acc.iter_mut().enumerate() {
                *a += i as i64 * self.storage.read(row, c) as i64;
            }
        }
        acc
    }

    /// Number of row reads the NMC dot product performs (for metrics).
    pub fn reads_for(&self, inputs: &[Trit]) -> usize {
        inputs.iter().filter(|&&i| i != 0).count()
    }
}

impl CimArray for NearMemoryArray {
    fn design(&self) -> Design {
        Design::NearMemory
    }

    fn storage(&self) -> &TernaryStorage {
        &self.storage
    }

    fn storage_mut(&mut self) -> &mut TernaryStorage {
        &mut self.storage
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dot_is_exact() {
        let mut rng = Rng::new(5);
        let mut a = NearMemoryArray::with_dims(Tech::Sram8T, 64, 16);
        let w = rng.ternary_vec(64 * 16, 0.3);
        a.write_matrix(&w);
        let inputs = rng.ternary_vec(64, 0.3);
        let out = a.dot_exact(&inputs);
        for c in 0..16 {
            let expect: i64 = (0..64).map(|r| inputs[r] as i64 * w[r * 16 + c] as i64).sum();
            assert_eq!(out[c], expect);
        }
        // The trait surface agrees (everything here fits i32).
        let trait_out: Vec<i64> = a.dot(&inputs).into_iter().map(|x| x as i64).collect();
        assert_eq!(trait_out, out);
    }

    #[test]
    fn zero_inputs_cost_no_reads() {
        let a = NearMemoryArray::with_dims(Tech::Edram3T, 32, 8);
        let mut inputs = vec![0i8; 32];
        inputs[3] = 1;
        inputs[17] = -1;
        assert_eq!(a.reads_for(&inputs), 2);
    }

    #[test]
    fn read_row_roundtrip() {
        let mut a = NearMemoryArray::with_dims(Tech::Femfet3T, 16, 4);
        a.write(2, 1, -1);
        a.write(2, 3, 1);
        assert_eq!(a.read_row(2), vec![0, -1, 0, 1]);
    }
}
