//! Near-memory (NM) baseline array (paper §V preamble).
//!
//! A standard 512×256 binary array (= 256×256 ternary words, two bit-cells
//! per ternary weight), voltage-sensed, read row-by-row. Dot products are
//! computed *outside* the array in a near-memory compute (NMC) unit: for
//! each of the 16 rows of a MAC window the row is read, multiplied by its
//! input trit and accumulated — exact digital arithmetic, no ADC, no
//! saturation. This is both the performance baseline and the accuracy
//! reference.

use super::encoding::Trit;
use super::storage::TernaryStorage;
use crate::device::{Tech, TechParams};

#[derive(Clone, Debug)]
pub struct NearMemoryArray {
    storage: TernaryStorage,
    pub params: TechParams,
}

impl NearMemoryArray {
    pub fn new(tech: Tech) -> NearMemoryArray {
        Self::with_dims(tech, 256, 256)
    }

    pub fn with_dims(tech: Tech, n_rows: usize, n_cols: usize) -> NearMemoryArray {
        NearMemoryArray {
            storage: TernaryStorage::new(n_rows, n_cols),
            params: TechParams::new(tech),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.storage.n_rows()
    }

    pub fn n_cols(&self) -> usize {
        self.storage.n_cols()
    }

    pub fn storage(&self) -> &TernaryStorage {
        &self.storage
    }

    pub fn write(&mut self, row: usize, col: usize, w: Trit) {
        self.storage.write(row, col, w);
    }

    pub fn write_matrix(&mut self, weights: &[Trit]) {
        self.storage.write_matrix(weights);
    }

    /// Memory read of one ternary row (both bit-cells sensed in parallel
    /// on the doubled binary columns).
    pub fn read_row(&self, row: usize) -> Vec<Trit> {
        (0..self.n_cols()).map(|c| self.storage.read(row, c)).collect()
    }

    /// The NMC unit's dot product: sequential row reads, exact MAC.
    /// Rows with input 0 are skipped (the NMC unit gates them — the same
    /// sparsity the CiM designs exploit electrically).
    pub fn dot(&self, inputs: &[Trit]) -> Vec<i64> {
        assert_eq!(inputs.len(), self.n_rows());
        let mut acc = vec![0i64; self.n_cols()];
        for (row, &i) in inputs.iter().enumerate() {
            if i == 0 {
                continue;
            }
            for (c, a) in acc.iter_mut().enumerate() {
                *a += i as i64 * self.storage.read(row, c) as i64;
            }
        }
        acc
    }

    /// Number of row reads the NMC dot product performs (for metrics).
    pub fn reads_for(&self, inputs: &[Trit]) -> usize {
        inputs.iter().filter(|&&i| i != 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dot_is_exact() {
        let mut rng = Rng::new(5);
        let mut a = NearMemoryArray::with_dims(Tech::Sram8T, 64, 16);
        let w = rng.ternary_vec(64 * 16, 0.3);
        a.write_matrix(&w);
        let inputs = rng.ternary_vec(64, 0.3);
        let out = a.dot(&inputs);
        for c in 0..16 {
            let expect: i64 = (0..64).map(|r| inputs[r] as i64 * w[r * 16 + c] as i64).sum();
            assert_eq!(out[c], expect);
        }
    }

    #[test]
    fn zero_inputs_cost_no_reads() {
        let a = NearMemoryArray::with_dims(Tech::Edram3T, 32, 8);
        let mut inputs = vec![0i8; 32];
        inputs[3] = 1;
        inputs[17] = -1;
        assert_eq!(a.reads_for(&inputs), 2);
    }

    #[test]
    fn read_row_roundtrip() {
        let mut a = NearMemoryArray::with_dims(Tech::Femfet3T, 16, 4);
        a.write(2, 1, -1);
        a.write(2, 3, 1);
        assert_eq!(a.read_row(2), vec![0, -1, 0, 1]);
    }
}
