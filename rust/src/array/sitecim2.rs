//! SiTe CiM II: cross-coupled sub-columns, current sensing (paper §IV).
//!
//! The array is organized as 16 blocks × 16 rows. Within a block, cells
//! share local read bit-lines (LRBL1/2) and four block-level coupling
//! transistors (AX_t1M1/M2 straight, AX_t2M1/M2 crossed) driven by
//! RWL_t1/RWL_t2. One row *per block* is asserted per MAC cycle (distinct
//! inputs within a block would fight over the shared RWL_t lines), so a
//! full 256-row dot product again takes 16 cycles — but the 16
//! simultaneous rows are strided across blocks.
//!
//! Sensing is current-mode: the comparator picks the sign, the analog
//! subtractor forms |I_RBL1 − I_RBL2| and a single 3-bit current ADC
//! digitizes it → O = sign·min(|a−b|, 8).
//!
//! The digital-ideal surface (`dot` / `mac_cycle`) comes from the
//! [`CimArray`] trait with `Flavor::Cim2` semantics; this module adds the
//! current-sensing analog path.

use super::area::Design;
use super::cim::CimArray;
use super::encoding::Trit;
use super::mac::{Flavor, GROUP_ROWS};
use super::storage::TernaryStorage;
use crate::circuit::adc::CurrentAdc;
use crate::circuit::sensing::{comparator_sign, i_hrs_effective, subtractor_magnitude_units, CurrentSense};
use crate::device::{Tech, TechParams};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SiTeCim2Array {
    storage: TernaryStorage,
    pub params: TechParams,
    pub sense: CurrentSense,
    /// LRBL capacitance (16 cells + local wire) — sets the HRS-effective
    /// charging current (§IV.1.ii).
    pub c_lrbl: f64,
    /// Current-sense window.
    pub t_sense: f64,
    adc: CurrentAdc,
}

impl SiTeCim2Array {
    pub fn new(tech: Tech) -> SiTeCim2Array {
        Self::with_dims(tech, 256, 256)
    }

    pub fn with_dims(tech: Tech, n_rows: usize, n_cols: usize) -> SiTeCim2Array {
        let params = TechParams::new(tech);
        let sense = CurrentSense::default_for(&params);
        // 16 cells × 1 junction + 16 × 8F of local wire.
        let c_lrbl = params.c_rbl(GROUP_ROWS, 1.0, 8.0);
        // Sense window scales with the unit current (weaker cells resolve
        // slower): C_sense·VDD / I_LRS with C_sense ≈ 25 fF.
        let t_sense = 25e-15 * params.vdd / params.i_lrs;
        SiTeCim2Array {
            storage: TernaryStorage::new(n_rows, n_cols),
            params,
            sense,
            c_lrbl,
            t_sense,
            adc: CurrentAdc::ideal(),
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.storage.n_rows() / GROUP_ROWS
    }

    /// The rows asserted in `cycle` (one per block).
    pub fn cycle_rows(&self, cycle: usize) -> Vec<usize> {
        Flavor::Cim2.group_rows(self.storage.n_rows(), cycle)
    }

    fn count_ab(&self, rows: &[usize], inputs: &[Trit], col: usize) -> (u32, u32) {
        let mut a = 0u32;
        let mut b = 0u32;
        for (&r, &i) in rows.iter().zip(inputs) {
            let p = i as i32 * self.storage.read(r, col) as i32;
            if p == 1 {
                a += 1;
            } else if p == -1 {
                b += 1;
            }
        }
        (a, b)
    }

    /// One MAC cycle through the current-sensing models: loaded RBL
    /// currents → comparator → subtractor → (optionally varied) ADC.
    pub fn mac_cycle_analog(&self, cycle: usize, inputs: &[Trit], adc: Option<&CurrentAdc>) -> Vec<i32> {
        assert_eq!(inputs.len(), GROUP_ROWS);
        let adc = adc.unwrap_or(&self.adc);
        let rows = self.cycle_rows(cycle);
        let p = &self.params;
        let i_hrs_eff = i_hrs_effective(p, self.c_lrbl, self.t_sense);
        let n_active = inputs.iter().filter(|&&i| i != 0).count();
        (0..self.storage.n_cols())
            .map(|c| {
                let (a, b) = self.count_ab(&rows, inputs, c);
                // Active rows whose coupled cell is HRS park the LRBL
                // charging current on that RBL.
                let hrs1 = n_active - a as usize;
                let hrs2 = n_active - b as usize;
                let i1 = self.sense.loaded_current(p, a as usize, hrs1, i_hrs_eff);
                let i2 = self.sense.loaded_current(p, b as usize, hrs2, i_hrs_eff);
                let sign = comparator_sign(i1, i2);
                let unit = p.i_lrs - i_hrs_eff;
                let mag = subtractor_magnitude_units(i1, i2, unit);
                sign * adc.quantize(mag) as i32
            })
            .collect()
    }

    /// Monte-Carlo analog dot product (σ in ADC reference units).
    pub fn dot_analog_mc(&self, inputs: &[Trit], sigma_units: f64, rng: &mut Rng) -> Vec<i32> {
        assert_eq!(inputs.len(), self.storage.n_rows());
        let mut out = vec![0i32; self.storage.n_cols()];
        for cycle in 0..self.n_blocks() {
            let rows = self.cycle_rows(cycle);
            let cyc_inputs: Vec<Trit> = rows.iter().map(|&r| inputs[r]).collect();
            let adc = CurrentAdc::with_variation(sigma_units, rng);
            for (o, p) in out.iter_mut().zip(self.mac_cycle_analog(cycle, &cyc_inputs, Some(&adc))) {
                *o += p;
            }
        }
        out
    }
}

impl CimArray for SiTeCim2Array {
    fn design(&self) -> Design {
        Design::Cim2
    }

    fn storage(&self) -> &TernaryStorage {
        &self.storage
    }

    fn storage_mut(&mut self) -> &mut TernaryStorage {
        &mut self.storage
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::mac::dot_ref;
    use crate::util::rng::Rng;

    fn loaded(seed: u64, sparsity: f64) -> (SiTeCim2Array, Vec<i8>) {
        let mut rng = Rng::new(seed);
        let mut a = SiTeCim2Array::with_dims(Tech::Edram3T, 64, 32);
        a.write_matrix(&rng.ternary_vec(64 * 32, sparsity));
        let inputs = rng.ternary_vec(64, sparsity);
        (a, inputs)
    }

    #[test]
    fn dot_matches_reference_semantics() {
        let (a, inputs) = loaded(31, 0.4);
        assert_eq!(a.dot(&inputs), dot_ref(a.storage(), &inputs, Flavor::Cim2));
    }

    #[test]
    fn analog_ideal_matches_digital_at_moderate_outputs() {
        // With sparse inputs (outputs well inside the robust range) the
        // loaded-current path must agree with the digital semantics.
        let (a, inputs) = loaded(32, 0.6);
        for cycle in 0..4 {
            let rows = a.cycle_rows(cycle);
            let ci: Vec<i8> = rows.iter().map(|&r| inputs[r]).collect();
            let dig = a.mac_cycle(cycle, &ci);
            let ana = a.mac_cycle_analog(cycle, &ci, None);
            let agree = dig.iter().zip(&ana).filter(|(d, a)| d == a).count();
            assert!(agree >= 31, "cycle {cycle}: only {agree}/32 agree");
        }
    }

    #[test]
    fn blocks_are_16_rows() {
        let a = SiTeCim2Array::new(Tech::Sram8T);
        assert_eq!(a.n_blocks(), 16);
        let rows = a.cycle_rows(3);
        assert_eq!(rows.len(), 16);
        assert!(rows.windows(2).all(|w| w[1] - w[0] == 16));
    }

    #[test]
    fn mc_zero_sigma_matches_analog_ideal() {
        let (a, inputs) = loaded(33, 0.5);
        let mut rng = Rng::new(4);
        let mc = a.dot_analog_mc(&inputs, 0.0, &mut rng);
        // σ=0 MC equals the plain analog path accumulated over cycles.
        let mut expect = vec![0i32; 32];
        for cycle in 0..4 {
            let rows = a.cycle_rows(cycle);
            let ci: Vec<i8> = rows.iter().map(|&r| inputs[r]).collect();
            for (e, p) in expect.iter_mut().zip(a.mac_cycle_analog(cycle, &ci, None)) {
                *e += p;
            }
        }
        assert_eq!(mc, expect);
    }

    #[test]
    fn mc_covers_all_cycles_of_tall_arrays() {
        // Regression: arrays taller than 256 rows have more than 16 MAC
        // cycles; the MC path used to cap at 16 and silently drop rows.
        let mut rng = Rng::new(35);
        let mut a = SiTeCim2Array::with_dims(Tech::Sram8T, 512, 8);
        a.write_matrix(&rng.ternary_vec(512 * 8, 0.5));
        let inputs = rng.ternary_vec(512, 0.5);
        let mut zrng = Rng::new(6);
        let mc = a.dot_analog_mc(&inputs, 0.0, &mut zrng);
        let mut expect = vec![0i32; 8];
        for cycle in 0..a.n_blocks() {
            let rows = a.cycle_rows(cycle);
            let ci: Vec<i8> = rows.iter().map(|&r| inputs[r]).collect();
            for (e, p) in expect.iter_mut().zip(a.mac_cycle_analog(cycle, &ci, None)) {
                *e += p;
            }
        }
        assert_eq!(mc, expect);
        assert_eq!(a.n_blocks(), 32); // all 32 cycles, not min(32, 16)
    }

    #[test]
    fn sense_window_tracks_cell_strength() {
        let sram = SiTeCim2Array::new(Tech::Sram8T);
        let fem = SiTeCim2Array::new(Tech::Femfet3T);
        // FEMFET's stronger LRS resolves faster.
        assert!(fem.t_sense < sram.t_sense);
    }
}
