//! Array-level latency/energy models for every (design, operation) pair —
//! the machinery behind Fig 9 (SiTe CiM I vs NM) and Fig 11 (SiTe CiM II
//! vs NM).
//!
//! Everything is mechanistic: capacitances come from the cell geometry
//! (`area::cell_geom`) and the device presets, currents from the device
//! models, and the peripheral costs from `PeriphParams`. The paper's
//! percentages are *outputs* of these formulas, checked by tests within
//! tolerance bands (DESIGN.md §5).
//!
//! Operation definitions (per 256-ternary-column array):
//! - `read`:  one full-row memory read (both bit-cells of each ternary
//!   word sensed in parallel — 512 binary columns for NM/CiM I).
//! - `write`: one full-row program.
//! - `mac`:   one 16-row MAC window over all columns. For the CiM designs
//!   this is a single massively-parallel cycle; for NM it is 16 sequential
//!   row reads feeding the NMC unit.

use super::area::{cell_geom, Design};
use crate::circuit::bitline;
use crate::device::{PeriphParams, TechParams};

/// Latency (s) and energy (J) of one operation.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpMetrics {
    pub latency: f64,
    pub energy: f64,
}

impl OpMetrics {
    pub fn speedup_vs(&self, base: &OpMetrics) -> f64 {
        base.latency / self.latency
    }

    pub fn energy_saving_vs(&self, base: &OpMetrics) -> f64 {
        1.0 - self.energy / base.energy
    }
}

/// Read/write/MAC metrics of one design point.
#[derive(Clone, Copy, Debug)]
pub struct DesignMetrics {
    pub design: Design,
    pub read: OpMetrics,
    pub write: OpMetrics,
    pub mac: OpMetrics,
}

/// Array shape used across the paper.
#[derive(Clone, Copy, Debug)]
pub struct ArrayGeom {
    pub n_rows: usize,
    pub n_cols: usize,
    pub n_active: usize,
}

impl Default for ArrayGeom {
    fn default() -> Self {
        ArrayGeom { n_rows: 256, n_cols: 256, n_active: 16 }
    }
}

/// Single-row read develops ~3·δ₀ of swing for robust single-ended
/// sensing (one cell, full develop) vs the δ₀ unit step used in CiM mode.
const READ_SWING_V: f64 = 0.30;
/// Average number of unit discharges per RBL during a CiM cycle at the
/// paper's workload sparsity (§III.2: sparsity keeps outputs small).
const AVG_CIM_UNITS: f64 = 2.0;
/// Activity factor: probability a sensed binary column discharges on read.
const READ_ACTIVITY: f64 = 0.5;

fn wl_energy(p: &TechParams, pp: &PeriphParams, n_cols: usize, gates_per_cell: f64, cell_w_f: f64) -> f64 {
    p.c_wl(n_cols, gates_per_cell, cell_w_f) * p.vdd * p.vdd + pp.e_wldrv
}

/// ---------------- NM baseline ----------------
pub fn nm_metrics(p: &TechParams, pp: &PeriphParams, g: ArrayGeom) -> DesignMetrics {
    let geom = cell_geom(p, Design::NearMemory);
    let c_rbl = p.c_rbl(g.n_rows, 1.0, geom.h_f);
    let n_bcols = 2 * g.n_cols; // binary columns

    // Read: precharge → WL → develop → SA.
    let t_dev = bitline::discharge_time(c_rbl, READ_SWING_V, p.i_lrs);
    let read = OpMetrics {
        latency: pp.t_prech + pp.t_wl + t_dev + p.t_sa_v,
        energy: n_bcols as f64
            * (READ_ACTIVITY * bitline::precharge_energy(c_rbl, p.vdd, p.vdd - READ_SWING_V)
                + p.e_sa_v)
            + wl_energy(p, pp, g.n_cols, 2.0, geom.w_f),
    };

    // Write: drive write BLs + WWL, settle the cell.
    let c_wbl = p.c_rbl(g.n_rows, 1.0, geom.h_f);
    let write = OpMetrics {
        latency: pp.t_prech + pp.t_wl + p.t_write_cell,
        energy: n_bcols as f64
            * (p.e_write_cell + 0.5 * c_wbl * p.v_write * p.v_write)
            + wl_energy(p, pp, g.n_cols, 2.0, geom.w_f),
    };

    // MAC window: n_active sequential row reads feeding the NMC unit.
    // Row *streaming* pipelines the next row's precharge + WL decode
    // behind the current row's sense, so the steady-state row cycle is
    // develop + SA only (a conservative, fast baseline — the paper's NM
    // design is given every standard memory optimization).
    let row_cycle = t_dev + p.t_sa_v;
    let mac = OpMetrics {
        latency: pp.t_prech + pp.t_wl + g.n_active as f64 * row_cycle + pp.t_nm_mac,
        energy: g.n_active as f64 * read.energy
            + (g.n_active * g.n_cols) as f64 * pp.e_nm_mac,
    };

    DesignMetrics { design: Design::NearMemory, read, write, mac }
}

/// ---------------- SiTe CiM I ----------------
pub fn cim1_metrics(p: &TechParams, pp: &PeriphParams, g: ArrayGeom) -> DesignMetrics {
    let geom = cell_geom(p, Design::Cim1);
    // Two read-port junctions per ternary cell per RBL (AX1+AX4 / AX2+AX3)
    // and a taller cell → the read/write overheads of §V.1c.
    let c_rbl = p.c_rbl(g.n_rows, 2.0, geom.h_f);
    let n_bcols = 2 * g.n_cols; // two RBLs per ternary column

    let t_dev_read = bitline::discharge_time(c_rbl, READ_SWING_V, p.i_lrs);
    let read = OpMetrics {
        latency: pp.t_prech + pp.t_wl + t_dev_read + p.t_sa_v,
        energy: n_bcols as f64
            * (READ_ACTIVITY * bitline::precharge_energy(c_rbl, p.vdd, p.vdd - READ_SWING_V)
                + p.e_sa_v)
            + wl_energy(p, pp, g.n_cols, 2.0, geom.w_f),
    };

    // Write: same bit-cells; the wider cell stretches the WWL wire →
    // slower write (RC of the WWL scales with cell width).
    let nm_geom = cell_geom(p, Design::NearMemory);
    let wl_stretch = geom.w_f / nm_geom.w_f;
    let c_wbl = p.c_rbl(g.n_rows, 1.0, geom.h_f);
    let write = OpMetrics {
        latency: pp.t_prech + pp.t_wl * (1.0 + 2.0 * (wl_stretch - 1.0)) + p.t_write_cell,
        energy: n_bcols as f64
            * (p.e_write_cell + 0.5 * c_wbl * p.v_write * p.v_write)
            + wl_energy(p, pp, g.n_cols, 2.0, geom.w_f) * wl_stretch,
    };

    // CiM cycle: precharge both RBLs → assert ≤16 input WLs → parallel
    // develop (one δ per discharging cell, concurrent) → 2× ADC → digital
    // subtract.
    let t_dev_cim = bitline::discharge_time(c_rbl, bitline::DELTA0_V, p.i_lrs);
    let e_recover = bitline::precharge_energy(c_rbl, p.vdd, p.vdd - AVG_CIM_UNITS * bitline::DELTA0_V);
    let mac = OpMetrics {
        latency: pp.t_prech + pp.t_wl + t_dev_cim + pp.t_adc + pp.t_sub_dig,
        energy: n_bcols as f64 * (e_recover + pp.e_adc + pp.e_sa_extra)
            + g.n_active as f64 * wl_energy(p, pp, g.n_cols, 2.0, geom.w_f)
            + g.n_cols as f64 * pp.e_sub_dig,
    };

    DesignMetrics { design: Design::Cim1, read, write, mac }
}

/// ---------------- SiTe CiM II ----------------
pub fn cim2_metrics(p: &TechParams, pp: &PeriphParams, g: ArrayGeom) -> DesignMetrics {
    let geom = cell_geom(p, Design::Cim2);
    let n_blocks = g.n_rows / 16;
    // Global RBL sees only the shared transistors' junctions (2 per RBL
    // per block) plus the full-height wire.
    let c_rbl = {
        let junction = n_blocks as f64 * 2.0 * p.c_junct_port;
        let wire = g.n_rows as f64 * geom.h_f * p.c_wire_per_f;
        junction + wire
    };
    // Local RBL: 16 cell junctions + 16 rows of local wire.
    let c_lrbl = p.c_rbl(16, 1.0, geom.h_f);
    let n_bcols = 2 * g.n_cols;

    // Current-sense window: C_sense·VDD / I (weaker cells resolve slower).
    let t_sense_mac = 25e-15 * p.vdd / p.i_lrs;
    // Single-row read drives through the series shared transistor —
    // roughly half the drive → double the window (§V.2c's slower read).
    let t_sense_read = 2.0 * t_sense_mac;

    // Read: drive RBLs high → RWL + RWL_t1 → current sense.
    // Energy: partial re-drive of the RBLs + LRBL charge + static sense
    // current + the second word-line.
    // Sense current flows only in LRS columns (~half) and only until the
    // current SA latches (~half the window).
    let e_static_read = 0.25 * p.i_lrs * p.vdd * t_sense_read;
    let read = OpMetrics {
        latency: 1.5 * pp.t_prech + 2.0 * pp.t_wl + t_sense_read + p.t_sa_v,
        energy: n_bcols as f64
            * (bitline::precharge_energy(c_rbl, p.vdd, p.vdd - READ_SWING_V)
                + c_lrbl * p.vdd * p.vdd * READ_ACTIVITY
                + e_static_read
                + p.e_sa_v)
            + 2.0 * wl_energy(p, pp, g.n_cols, 2.0, geom.w_f),
    };

    // Write: same cells at NM pitch; the extra series transistor is on the
    // read path only, but the taller block stretches the WBL slightly.
    let c_wbl = p.c_rbl(g.n_rows, 1.0, geom.h_f);
    let write = OpMetrics {
        latency: pp.t_prech + pp.t_wl * 1.5 + p.t_write_cell,
        energy: n_bcols as f64
            * (p.e_write_cell + 0.5 * c_wbl * p.v_write * p.v_write)
            + wl_energy(p, pp, g.n_cols, 2.0, geom.w_f),
    };

    // CiM cycle: bit-lines start at 0 and are driven to VDD (current
    // sensing — §V.2b's full-swing penalty), 16 blocks' word-lines (RWL +
    // RWL_t), static sense current of all conducting paths, comparator +
    // analog subtractor + single ADC per column.
    let i_static_col = (AVG_CIM_UNITS * 2.0) * p.i_lrs + 16.0 * c_lrbl * p.vdd / t_sense_mac;
    let mac = OpMetrics {
        latency: 1.5 * pp.t_prech + 2.0 * pp.t_wl + t_sense_mac + pp.t_cmp_sub + pp.t_adc,
        energy: n_bcols as f64 * bitline::full_swing_energy(c_rbl, p.vdd)
            + (g.n_cols * 16) as f64 * c_lrbl * p.vdd * p.vdd * 0.66
            + g.n_active as f64 * 2.0 * wl_energy(p, pp, g.n_cols, 2.0, geom.w_f)
            + g.n_cols as f64 * (i_static_col * p.vdd * t_sense_mac)
            + g.n_cols as f64 * (pp.e_cmp_sub + pp.e_adc + pp.e_sa_extra),
    };

    DesignMetrics { design: Design::Cim2, read, write, mac }
}

/// All three design points for one technology.
pub fn all_designs(p: &TechParams, pp: &PeriphParams, g: ArrayGeom) -> [DesignMetrics; 3] {
    [nm_metrics(p, pp, g), cim1_metrics(p, pp, g), cim2_metrics(p, pp, g)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{PeriphParams, Tech, TechParams};

    fn setup(tech: Tech) -> (TechParams, PeriphParams, ArrayGeom) {
        (TechParams::new(tech), PeriphParams::default_45nm(), ArrayGeom::default())
    }

    #[test]
    fn cim1_mac_latency_benefit_near_88pct() {
        for tech in Tech::ALL {
            let (p, pp, g) = setup(tech);
            let nm = nm_metrics(&p, &pp, g);
            let c1 = cim1_metrics(&p, &pp, g);
            let reduction = 1.0 - c1.mac.latency / nm.mac.latency;
            // Paper: ~88% lower CiM latency. Band: 85–94%.
            assert!((0.85..=0.94).contains(&reduction), "{}: {reduction:.3}", tech.name());
        }
    }

    #[test]
    fn cim1_mac_energy_benefit_in_paper_band() {
        // Paper: 74% (SRAM), 78% (eDRAM), 78% (FEMFET). Band: ±8pp.
        for (tech, target) in [(Tech::Sram8T, 0.74), (Tech::Edram3T, 0.78), (Tech::Femfet3T, 0.78)] {
            let (p, pp, g) = setup(tech);
            let nm = nm_metrics(&p, &pp, g);
            let c1 = cim1_metrics(&p, &pp, g);
            let saving = c1.mac.energy_saving_vs(&nm.mac);
            assert!((saving - target).abs() < 0.08, "{}: saving {saving:.3} vs {target}", tech.name());
        }
    }

    #[test]
    fn cim2_mac_benefits_lower_than_cim1_but_real() {
        for tech in Tech::ALL {
            let (p, pp, g) = setup(tech);
            let nm = nm_metrics(&p, &pp, g);
            let c1 = cim1_metrics(&p, &pp, g);
            let c2 = cim2_metrics(&p, &pp, g);
            // Paper: 78–84% delay reduction, 61–63% energy vs NM.
            let dred = 1.0 - c2.mac.latency / nm.mac.latency;
            let esav = c2.mac.energy_saving_vs(&nm.mac);
            assert!((0.70..=0.90).contains(&dred), "{}: delay red {dred:.3}", tech.name());
            assert!((0.53..=0.71).contains(&esav), "{}: energy sav {esav:.3}", tech.name());
            // And CiM II is slower + hungrier than CiM I (§V.3).
            assert!(c2.mac.latency > c1.mac.latency, "{}", tech.name());
            assert!(c2.mac.energy > c1.mac.energy, "{}", tech.name());
        }
    }

    #[test]
    fn cim1_vs_cim2_ratios_in_band() {
        // §V.3: CiM II has 1.5–1.7× the CiM energy and 1.3–1.8× the
        // latency of CiM I. Allow 1.3–2.1.
        for tech in Tech::ALL {
            let (p, pp, g) = setup(tech);
            let c1 = cim1_metrics(&p, &pp, g);
            let c2 = cim2_metrics(&p, &pp, g);
            let e_ratio = c2.mac.energy / c1.mac.energy;
            let l_ratio = c2.mac.latency / c1.mac.latency;
            assert!((1.2..=2.1).contains(&e_ratio), "{}: E ratio {e_ratio:.2}", tech.name());
            assert!((1.2..=2.1).contains(&l_ratio), "{}: L ratio {l_ratio:.2}", tech.name());
        }
    }

    #[test]
    fn cim1_read_write_overheads_right_sign_and_size() {
        for tech in Tech::ALL {
            let (p, pp, g) = setup(tech);
            let nm = nm_metrics(&p, &pp, g);
            let c1 = cim1_metrics(&p, &pp, g);
            let e_over = c1.read.energy / nm.read.energy - 1.0;
            let l_over = c1.read.latency / nm.read.latency - 1.0;
            let w_over = c1.write.latency / nm.write.latency - 1.0;
            // Paper: +17–24% read energy, +7–19% read latency, +4–10%
            // write latency. Bands widened to ±~8pp.
            assert!((0.08..=0.32).contains(&e_over), "{}: read E +{e_over:.3}", tech.name());
            assert!((0.03..=0.30).contains(&l_over), "{}: read D +{l_over:.3}", tech.name());
            assert!((0.01..=0.18).contains(&w_over), "{}: write D +{w_over:.3}", tech.name());
            // Write energy "comparable" (±20%).
            let we = c1.write.energy / nm.write.energy;
            assert!((0.8..=1.3).contains(&we), "{}: write E ratio {we:.3}", tech.name());
        }
    }

    #[test]
    fn cim2_read_slower_than_nm_by_paper_band() {
        // Paper: 2.4× / 2.6× / 1.8× slower read; band 1.5–3.0×.
        for tech in Tech::ALL {
            let (p, pp, g) = setup(tech);
            let nm = nm_metrics(&p, &pp, g);
            let c2 = cim2_metrics(&p, &pp, g);
            let slow = c2.read.latency / nm.read.latency;
            assert!((1.5..=3.0).contains(&slow), "{}: read {slow:.2}x slower", tech.name());
            let e_over = c2.read.energy / nm.read.energy - 1.0;
            // Paper: +44–79% read energy; band 0.3–1.1.
            assert!((0.30..=1.10).contains(&e_over), "{}: read E +{e_over:.3}", tech.name());
        }
    }

    #[test]
    fn metrics_are_positive_and_sane() {
        for tech in Tech::ALL {
            let (p, pp, g) = setup(tech);
            for m in all_designs(&p, &pp, g) {
                for op in [m.read, m.write, m.mac] {
                    assert!(op.latency > 10e-12 && op.latency < 100e-9);
                    assert!(op.energy > 1e-15 && op.energy < 1e-9);
                }
            }
        }
    }
}
