//! Bit-packed ternary weight storage shared by both array flavors.
//!
//! Each column stores two bit-planes (`wp` = "M1" = weight is +1,
//! `wn` = "M2" = weight is −1) packed into u64 words, so a 16-row MAC
//! group reduces to a handful of AND + POPCNT operations — this is the
//! functional-simulation hot path behind the end-to-end example.
//!
//! Layout: plane[col * words_per_col + word], rows little-endian within a
//! word. 16-row blocks never straddle a word (16 | 64).

use super::encoding::{self, Trit};

#[derive(Clone, Debug)]
pub struct TernaryStorage {
    n_rows: usize,
    n_cols: usize,
    words_per_col: usize,
    wp: Vec<u64>,
    wn: Vec<u64>,
}

impl TernaryStorage {
    pub fn new(n_rows: usize, n_cols: usize) -> TernaryStorage {
        assert!(n_rows % 16 == 0, "rows must be a multiple of the block size (16)");
        let words_per_col = n_rows.div_ceil(64);
        TernaryStorage {
            n_rows,
            n_cols,
            words_per_col,
            wp: vec![0; words_per_col * n_cols],
            wn: vec![0; words_per_col * n_cols],
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Packed words per column (rows / 64, rounded up).
    pub fn words_per_col(&self) -> usize {
        self.words_per_col
    }

    /// The (M1-plane, M2-plane) packed words of one column, rows
    /// little-endian within each word — the raw substrate behind the
    /// strided (CiM II) fast path.
    #[inline]
    pub fn col_words(&self, col: usize) -> (&[u64], &[u64]) {
        let lo = col * self.words_per_col;
        let hi = lo + self.words_per_col;
        (&self.wp[lo..hi], &self.wn[lo..hi])
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> (usize, u64) {
        (col * self.words_per_col + row / 64, 1u64 << (row % 64))
    }

    /// Program one ternary weight (differential M1/M2 write).
    pub fn write(&mut self, row: usize, col: usize, w: Trit) {
        debug_assert!(encoding::is_trit(w));
        let (i, m) = self.idx(row, col);
        let (m1, m2) = encoding::encode_weight(w);
        if m1 {
            self.wp[i] |= m;
        } else {
            self.wp[i] &= !m;
        }
        if m2 {
            self.wn[i] |= m;
        } else {
            self.wn[i] &= !m;
        }
    }

    /// Read back one weight (digital view of the cell state).
    pub fn read(&self, row: usize, col: usize) -> Trit {
        let (i, m) = self.idx(row, col);
        encoding::decode_weight(self.wp[i] & m != 0, self.wn[i] & m != 0)
            .expect("storage never holds M1=M2=1")
    }

    /// Program a whole row from a slice of trits (length = n_cols).
    pub fn write_row(&mut self, row: usize, weights: &[Trit]) {
        assert_eq!(weights.len(), self.n_cols);
        for (col, &w) in weights.iter().enumerate() {
            self.write(row, col, w);
        }
    }

    /// Program the full array from a row-major matrix (rows × cols).
    pub fn write_matrix(&mut self, weights: &[Trit]) {
        assert_eq!(weights.len(), self.n_rows * self.n_cols);
        for r in 0..self.n_rows {
            self.write_row(r, &weights[r * self.n_cols..(r + 1) * self.n_cols]);
        }
    }

    /// The (M1-plane, M2-plane) 16-bit masks for a block of 16 rows
    /// starting at `row_base` (must be 16-aligned) in one column.
    #[inline]
    pub fn block_masks(&self, row_base: usize, col: usize) -> (u16, u16) {
        debug_assert!(row_base % 16 == 0);
        let word = col * self.words_per_col + row_base / 64;
        let shift = row_base % 64;
        (((self.wp[word] >> shift) & 0xFFFF) as u16, ((self.wn[word] >> shift) & 0xFFFF) as u16)
    }

    /// Count of (+1-product, −1-product) pairs in one 16-row block given
    /// the input masks (ip = rows with I=+1, in_ = rows with I=−1).
    /// This is the digital equivalent of the two RBL discharge counts
    /// ('a' and 'b' in §III.2).
    #[inline]
    pub fn block_ab(&self, row_base: usize, col: usize, ip: u16, in_: u16) -> (u32, u32) {
        let (wp, wn) = self.block_masks(row_base, col);
        let a = (ip & wp).count_ones() + (in_ & wn).count_ones();
        let b = (ip & wn).count_ones() + (in_ & wp).count_ones();
        (a, b)
    }

    /// Exact (unclamped) dot product of a full input vector with one
    /// column — the arbitrary-precision reference.
    pub fn column_dot_exact(&self, col: usize, inputs: &[Trit]) -> i64 {
        assert_eq!(inputs.len(), self.n_rows);
        let mut acc = 0i64;
        for (row, &i) in inputs.iter().enumerate() {
            if i != 0 {
                acc += (i as i64) * (self.read(row, col) as i64);
            }
        }
        acc
    }
}

/// Pack a full input vector into (positive, negative) bit-planes with the
/// same word layout as the storage columns (rows little-endian per u64).
pub fn pack_inputs_words(inputs: &[Trit]) -> (Vec<u64>, Vec<u64>) {
    let words = inputs.len().div_ceil(64);
    let mut ip = vec![0u64; words];
    let mut in_ = vec![0u64; words];
    for (r, &i) in inputs.iter().enumerate() {
        match i {
            1 => ip[r / 64] |= 1u64 << (r % 64),
            -1 => in_[r / 64] |= 1u64 << (r % 64),
            _ => {}
        }
    }
    (ip, in_)
}

/// Pack a 16-trit input group into (positive-mask, negative-mask).
pub fn pack_inputs16(inputs: &[Trit]) -> (u16, u16) {
    debug_assert!(inputs.len() <= 16);
    let mut ip = 0u16;
    let mut in_ = 0u16;
    for (k, &i) in inputs.iter().enumerate() {
        match i {
            1 => ip |= 1 << k,
            -1 => in_ |= 1 << k,
            _ => {}
        }
    }
    (ip, in_)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn write_read_roundtrip() {
        let mut s = TernaryStorage::new(64, 8);
        let mut rng = Rng::new(1);
        let w: Vec<i8> = rng.ternary_vec(64 * 8, 0.3);
        s.write_matrix(&w);
        for r in 0..64 {
            for c in 0..8 {
                assert_eq!(s.read(r, c), w[r * 8 + c]);
            }
        }
    }

    #[test]
    fn rewrite_clears_old_state() {
        let mut s = TernaryStorage::new(16, 1);
        s.write(3, 0, 1);
        s.write(3, 0, -1);
        assert_eq!(s.read(3, 0), -1);
        s.write(3, 0, 0);
        assert_eq!(s.read(3, 0), 0);
    }

    #[test]
    fn block_ab_matches_naive_count() {
        let mut rng = Rng::new(7);
        let mut s = TernaryStorage::new(64, 4);
        let w: Vec<i8> = rng.ternary_vec(64 * 4, 0.4);
        s.write_matrix(&w);
        for base in (0..64).step_by(16) {
            let inputs: Vec<i8> = rng.ternary_vec(16, 0.4);
            let (ip, in_) = pack_inputs16(&inputs);
            for c in 0..4 {
                let (a, b) = s.block_ab(base, c, ip, in_);
                let mut na = 0;
                let mut nb = 0;
                for k in 0..16 {
                    let p = inputs[k] as i32 * w[(base + k) * 4 + c] as i32;
                    if p == 1 {
                        na += 1;
                    } else if p == -1 {
                        nb += 1;
                    }
                }
                assert_eq!((a, b), (na, nb), "base={base} col={c}");
            }
        }
    }

    #[test]
    fn column_dot_exact_matches_scalar() {
        let mut rng = Rng::new(9);
        let mut s = TernaryStorage::new(32, 2);
        let w: Vec<i8> = rng.ternary_vec(32 * 2, 0.2);
        s.write_matrix(&w);
        let inputs: Vec<i8> = rng.ternary_vec(32, 0.2);
        for c in 0..2 {
            let expect: i64 =
                (0..32).map(|r| inputs[r] as i64 * w[r * 2 + c] as i64).sum();
            assert_eq!(s.column_dot_exact(c, &inputs), expect);
        }
    }

    #[test]
    fn pack_inputs_words_matches_storage_layout() {
        let mut rng = Rng::new(11);
        let inputs: Vec<i8> = rng.ternary_vec(80, 0.4);
        let (ip, in_) = pack_inputs_words(&inputs);
        assert_eq!(ip.len(), 2);
        for (r, &i) in inputs.iter().enumerate() {
            assert_eq!((ip[r / 64] >> (r % 64)) & 1 == 1, i == 1, "row {r}");
            assert_eq!((in_[r / 64] >> (r % 64)) & 1 == 1, i == -1, "row {r}");
        }
    }

    #[test]
    fn col_words_expose_block_masks() {
        let mut rng = Rng::new(12);
        let mut s = TernaryStorage::new(128, 3);
        s.write_matrix(&rng.ternary_vec(128 * 3, 0.4));
        for col in 0..3 {
            let (wp, wn) = s.col_words(col);
            assert_eq!(wp.len(), s.words_per_col());
            for base in (0..128).step_by(16) {
                let (bp, bn) = s.block_masks(base, col);
                assert_eq!(((wp[base / 64] >> (base % 64)) & 0xFFFF) as u16, bp);
                assert_eq!(((wn[base / 64] >> (base % 64)) & 0xFFFF) as u16, bn);
            }
        }
    }

    #[test]
    fn pack_inputs_masks() {
        let (ip, in_) = pack_inputs16(&[1, -1, 0, 1]);
        assert_eq!(ip, 0b1001);
        assert_eq!(in_, 0b0010);
    }

    #[test]
    #[should_panic]
    fn non_multiple_of_block_rejected() {
        TernaryStorage::new(40, 4);
    }
}
