//! The `CimArray` trait: one polymorphic surface over the three array
//! backends (SiTe CiM I, SiTe CiM II, near-memory baseline).
//!
//! # Contract
//!
//! Every backend wraps a [`TernaryStorage`] of `n_rows × n_cols` ternary
//! weights, with `n_rows` a multiple of [`GROUP_ROWS`] (16 — the number
//! of word-lines asserted per MAC cycle; [`TernaryStorage::new`] enforces
//! this, and partial final groups must be padded with zero rows, which
//! are electrically inert).
//!
//! - **Grouping**: a full dot product takes `n_rows / 16` MAC cycles.
//!   Which rows a cycle asserts is the backend's [`Flavor`]'s business:
//!   CiM I asserts 16 *consecutive* rows, CiM II one row per 16-row
//!   block (strided — the coupling transistors are shared per block,
//!   §IV.3). The near-memory baseline has no flavor ([`flavor`] returns
//!   `None`) and reads row by row.
//! - **Saturation**: CiM backends digitize each cycle's (a, b) discharge
//!   counts through their flavor's ADC path, clamping at ±[`SAT`]
//!   (= ±8) per group — `O = min(a,8) − min(b,8)` for CiM I,
//!   `O = sign(a−b)·min(|a−b|,8)` for CiM II; the two differ whenever a
//!   single count exceeds 8 (see `mac.rs` §III.2/§IV.3). The NM baseline
//!   computes the exact digital MAC, no saturation.
//! - **Non-destructive compute**: MAC cycles never disturb the stored
//!   weights; `read_row` after any number of `dot` calls returns what
//!   was written.
//!
//! The default methods implement the whole digital surface on top of the
//! two storage accessors, so backends only provide storage plumbing,
//! identity hooks, and their analog (circuit-model) paths.

use super::area::Design;
use super::encoding::Trit;
use super::mac::{self, Flavor, Rect, GROUP_ROWS, SAT};
use super::storage::TernaryStorage;
use crate::array::metrics::ArrayGeom;

/// Polymorphic interface over the functional ternary array backends.
pub trait CimArray: Send {
    /// Which design point this backend models (metrics/area hook).
    fn design(&self) -> Design;

    /// The saturating-MAC flavor, or `None` for the exact NM baseline.
    fn flavor(&self) -> Option<Flavor> {
        self.design().flavor()
    }

    /// The shared bit-packed weight substrate.
    fn storage(&self) -> &TernaryStorage;

    /// Mutable access for the write path.
    fn storage_mut(&mut self) -> &mut TernaryStorage;

    // ---- storage plumbing (shared by every backend) ----

    fn n_rows(&self) -> usize {
        self.storage().n_rows()
    }

    fn n_cols(&self) -> usize {
        self.storage().n_cols()
    }

    /// Array geometry for the metrics models.
    fn geom(&self) -> ArrayGeom {
        ArrayGeom { n_rows: self.n_rows(), n_cols: self.n_cols(), n_active: GROUP_ROWS }
    }

    /// Program one ternary weight (differential M1/M2 write).
    fn write(&mut self, row: usize, col: usize, w: Trit) {
        self.storage_mut().write(row, col, w);
    }

    /// Program the whole array from a row-major `rows × cols` matrix.
    fn write_matrix(&mut self, weights: &[Trit]) {
        self.storage_mut().write_matrix(weights);
    }

    /// Program a `rows × cols` sub-rectangle of the array from a
    /// row-major image, leaving every other cell untouched — the engine's
    /// sub-array region placement path (several weight shards share one
    /// physical array). Differential writes, same per-cell path as
    /// [`CimArray::write`].
    fn write_region(&mut self, row0: usize, col0: usize, rows: usize, cols: usize, w: &[Trit]) {
        assert_eq!(w.len(), rows * cols, "region image must be rows × cols");
        assert!(
            row0 + rows <= self.n_rows() && col0 + cols <= self.n_cols(),
            "region {rows}×{cols} at ({row0}, {col0}) exceeds the array"
        );
        let s = self.storage_mut();
        for r in 0..rows {
            for c in 0..cols {
                s.write(row0 + r, col0 + c, w[r * cols + c]);
            }
        }
    }

    /// Memory-mode read of one row.
    fn read_row(&self, row: usize) -> Vec<Trit> {
        (0..self.n_cols()).map(|c| self.storage().read(row, c)).collect()
    }

    // ---- digital-ideal MAC surface ----

    /// One MAC cycle, digital-ideal semantics. `inputs` are the 16 trits
    /// applied to the cycle's asserted rows *in assertion order* (for
    /// CiM II, `inputs[blk]` drives the selected row of block `blk`).
    /// The NM baseline computes the exact partial sum over the 16
    /// consecutive rows of window `cycle`.
    fn mac_cycle(&self, cycle: usize, inputs: &[Trit]) -> Vec<i32> {
        assert_eq!(inputs.len(), GROUP_ROWS);
        let s = self.storage();
        match self.flavor() {
            Some(f) => {
                let rows = f.group_rows(s.n_rows(), cycle);
                (0..s.n_cols())
                    .map(|c| {
                        let mut a = 0u32;
                        let mut b = 0u32;
                        for (&r, &i) in rows.iter().zip(inputs) {
                            let p = i as i32 * s.read(r, c) as i32;
                            if p == 1 {
                                a += 1;
                            } else if p == -1 {
                                b += 1;
                            }
                        }
                        f.group_output(a, b)
                    })
                    .collect()
            }
            None => {
                let base = cycle * GROUP_ROWS;
                (0..s.n_cols())
                    .map(|c| {
                        (0..GROUP_ROWS)
                            .map(|k| inputs[k] as i32 * s.read(base + k, c) as i32)
                            .sum::<i32>()
                    })
                    .collect()
            }
        }
    }

    /// Full dot product of `inputs` (length = `n_rows`) against every
    /// column, accumulated in the digital periphery. Saturating per the
    /// backend's flavor; exact for the NM baseline. Outputs are bounded
    /// by `±(n_rows/16)·SAT` (CiM) or `±n_rows` (NM), so `i32` is exact.
    fn dot(&self, inputs: &[Trit]) -> Vec<i32> {
        match self.flavor() {
            Some(f) => mac::dot_fast(self.storage(), inputs, f),
            None => mac::dot_exact(self.storage(), inputs)
                .into_iter()
                .map(|x| x as i32)
                .collect(),
        }
    }

    /// Batched dot products: `m` row-major input vectors → row-major
    /// `m × n_cols` outputs. The engine's hot path; backends share the
    /// bit-packed batch kernel, the NM baseline loops the exact MAC.
    fn dot_batch(&self, inputs: &[Trit], m: usize) -> Vec<i32> {
        let n_rows = self.n_rows();
        assert_eq!(inputs.len(), m * n_rows, "batch of {m} vectors × {n_rows} rows");
        match self.flavor() {
            Some(f) => mac::dot_fast_batch(self.storage(), inputs, m, f),
            None => {
                let mut out = Vec::with_capacity(m * self.n_cols());
                for r in 0..m {
                    out.extend(
                        mac::dot_exact(self.storage(), &inputs[r * n_rows..(r + 1) * n_rows])
                            .into_iter()
                            .map(|x| x as i32),
                    );
                }
                out
            }
        }
    }

    /// Region-scoped batched dot products — the engine's packed-tile hot
    /// path. `inputs` are `m` row-major *region-local* vectors (each
    /// `rect.rows` long; `inputs[j]` drives array row `rect.row0 + j`),
    /// and the result is the row-major `m × rect.cols` output of the
    /// region's columns. Bit-identical to [`CimArray::dot_batch`] on
    /// inputs zero-padded to the full array, sliced to
    /// `rect.col0..rect.col0 + rect.cols` — the zero rows are
    /// electrically inert — but costs wall-clock proportional to the
    /// region's occupied windows and column span (CiM II keeps the
    /// full-array stride grouping, restricted to the region's word
    /// span; see `mac`'s region kernels).
    fn dot_batch_region(&self, rect: &Rect, inputs: &[Trit], m: usize) -> Vec<i32> {
        let mut out = Vec::new();
        self.dot_batch_region_into(rect, inputs, m, &mut out);
        out
    }

    /// [`CimArray::dot_batch_region`] into a caller-provided buffer
    /// (resized to `m × rect.cols`, capacity retained) — the executor's
    /// per-worker scratch path: steady-state streaming reuses one
    /// partial-sum buffer per worker instead of allocating a fresh
    /// output per work item. Only sizes the buffer; the kernels accept
    /// dirty contents and zero-fill themselves, so reuse at a stable
    /// shape performs no work here at all.
    fn dot_batch_region_into(&self, rect: &Rect, inputs: &[Trit], m: usize, out: &mut Vec<i32>) {
        out.resize(m * rect.cols, 0);
        match self.flavor() {
            Some(Flavor::Cim1) => mac::dot_region_cim1_into(self.storage(), rect, inputs, m, out),
            Some(Flavor::Cim2) => mac::dot_region_cim2_into(self.storage(), rect, inputs, m, out),
            None => mac::dot_region_exact_into(self.storage(), rect, inputs, m, out),
        }
    }

    /// [`CimArray::dot_batch_region_into`] against a per-worker
    /// [`mac::RegionScratch`] — the executor's steady-state path. CiM I
    /// and the exact baseline are already allocation-free per call; CiM
    /// II additionally reuses the scratch's cached restricted stride
    /// masks and bit-plane buffers, making every region kernel
    /// allocation-free in steady state. Bit-identical to the plain
    /// variant.
    fn dot_batch_region_scratch_into(
        &self,
        rect: &Rect,
        inputs: &[Trit],
        m: usize,
        scratch: &mut mac::RegionScratch,
        out: &mut Vec<i32>,
    ) {
        out.resize(m * rect.cols, 0);
        match self.flavor() {
            Some(Flavor::Cim1) => mac::dot_region_cim1_into(self.storage(), rect, inputs, m, out),
            Some(Flavor::Cim2) => {
                mac::dot_region_cim2_scratch_into(self.storage(), rect, inputs, m, scratch, out)
            }
            None => mac::dot_region_exact_into(self.storage(), rect, inputs, m, out),
        }
    }

    /// Upper bound on `|dot|` per output — `SAT` per group for the
    /// saturating flavors, the full row count for the exact baseline.
    fn dot_bound(&self) -> i32 {
        match self.flavor() {
            Some(_) => (self.n_rows() / GROUP_ROWS) as i32 * SAT as i32,
            None => self.n_rows() as i32,
        }
    }
}

/// Construct a boxed backend of the given design — the engine's array
/// pool factory.
pub fn make_array(
    design: Design,
    tech: crate::device::Tech,
    n_rows: usize,
    n_cols: usize,
) -> Box<dyn CimArray> {
    match design {
        Design::Cim1 => Box::new(super::SiTeCim1Array::with_dims(tech, n_rows, n_cols)),
        Design::Cim2 => Box::new(super::SiTeCim2Array::with_dims(tech, n_rows, n_cols)),
        Design::NearMemory => Box::new(super::NearMemoryArray::with_dims(tech, n_rows, n_cols)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Tech;
    use crate::util::rng::Rng;

    #[test]
    fn factory_builds_every_design() {
        for design in Design::ALL {
            let a = make_array(design, Tech::Sram8T, 64, 8);
            assert_eq!(a.design(), design);
            assert_eq!((a.n_rows(), a.n_cols()), (64, 8));
            assert_eq!(a.flavor().is_none(), design == Design::NearMemory);
        }
    }

    #[test]
    fn trait_dot_matches_backend_semantics() {
        let mut rng = Rng::new(17);
        let w = rng.ternary_vec(64 * 12, 0.4);
        let inputs = rng.ternary_vec(64, 0.4);
        for design in Design::ALL {
            let mut a = make_array(design, Tech::Femfet3T, 64, 12);
            a.write_matrix(&w);
            let got = a.dot(&inputs);
            let want: Vec<i32> = match a.flavor() {
                Some(f) => mac::dot_ref(a.storage(), &inputs, f),
                None => mac::dot_exact(a.storage(), &inputs)
                    .into_iter()
                    .map(|x| x as i32)
                    .collect(),
            };
            assert_eq!(got, want, "{design:?}");
            assert!(got.iter().all(|&o| o.abs() <= a.dot_bound()), "{design:?}");
        }
    }

    #[test]
    fn write_region_leaves_other_cells_untouched() {
        let mut rng = Rng::new(19);
        for design in Design::ALL {
            let mut a = make_array(design, Tech::Sram8T, 64, 16);
            let base = rng.ternary_vec(64 * 16, 0.5);
            a.write_matrix(&base);
            let region = rng.ternary_vec(32 * 8, 0.5);
            a.write_region(16, 4, 32, 8, &region);
            for r in 0..64 {
                for c in 0..16 {
                    let want = if (16..48).contains(&r) && (4..12).contains(&c) {
                        region[(r - 16) * 8 + (c - 4)]
                    } else {
                        base[r * 16 + c]
                    };
                    assert_eq!(a.storage().read(r, c), want, "{design:?} r={r} c={c}");
                }
            }
        }
    }

    #[test]
    fn dot_batch_region_equals_padded_full_array_slice() {
        let mut rng = Rng::new(23);
        for design in Design::ALL {
            let mut a = make_array(design, Tech::Sram8T, 128, 24);
            a.write_matrix(&rng.ternary_vec(128 * 24, 0.5));
            let m = 2;
            let rect = Rect { row0: 32, rows: 48, col0: 5, cols: 11 };
            let region_inputs = rng.ternary_vec(m * rect.rows, 0.5);
            let got = a.dot_batch_region(&rect, &region_inputs, m);
            // The contract: zero-pad to the full array, batch, slice.
            let mut padded = vec![0i8; m * 128];
            for v in 0..m {
                padded[v * 128 + rect.row0..v * 128 + rect.row0 + rect.rows]
                    .copy_from_slice(&region_inputs[v * rect.rows..(v + 1) * rect.rows]);
            }
            let full = a.dot_batch(&padded, m);
            let want: Vec<i32> = (0..m)
                .flat_map(|v| full[v * 24 + rect.col0..v * 24 + rect.col0 + rect.cols].to_vec())
                .collect();
            assert_eq!(got, want, "{design:?}");
        }
    }

    #[test]
    fn mac_cycles_accumulate_to_dot() {
        let mut rng = Rng::new(18);
        let w = rng.ternary_vec(64 * 8, 0.5);
        let inputs = rng.ternary_vec(64, 0.5);
        for design in Design::ALL {
            let mut a = make_array(design, Tech::Edram3T, 64, 8);
            a.write_matrix(&w);
            let mut acc = vec![0i32; 8];
            for cycle in 0..4 {
                let cyc_inputs: Vec<i8> = match a.flavor() {
                    Some(f) => f.group_rows(64, cycle).iter().map(|&r| inputs[r]).collect(),
                    None => inputs[cycle * 16..(cycle + 1) * 16].to_vec(),
                };
                for (o, p) in acc.iter_mut().zip(a.mac_cycle(cycle, &cyc_inputs)) {
                    *o += p;
                }
            }
            assert_eq!(acc, a.dot(&inputs), "{design:?}");
        }
    }
}
